"""Repo tooling: repro-lint (tools.lint), README executor, trace reports."""

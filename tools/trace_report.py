"""Render a recorded telemetry run (manifest + metrics JSONL) as text.

A run directory is what ``repro.telemetry.report.write_run`` produces —
``manifest.json`` next to ``metrics.jsonl`` — e.g. from
``benchmarks/bench_network_sim.py --run-dir`` or the example demos'
``--out``.  One directory per positional argument:

    PYTHONPATH=src python tools/trace_report.py <run_dir> [<run_dir> ...]

Prints the manifest header (backend hash, mesh, seed, git rev), the
per-chunk convergence/staleness/drop-attribution lines (long runs elided
to head + tail), and the final-state recap.
"""

from __future__ import annotations

import argparse
import os
import sys

_REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO, "src"))

from repro.telemetry.report import load_run, render_summary  # noqa: E402


def main(argv=None) -> int:
    """CLI entry point; returns a process exit code."""
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("run_dirs", nargs="+",
                    help="directories holding manifest.json + metrics.jsonl")
    args = ap.parse_args(argv)
    status = 0
    for d in args.run_dirs:
        try:
            manifest, rows = load_run(d)
        except OSError as e:
            print(f"{d}: not a run directory ({e})", file=sys.stderr)
            status = 1
            continue
        print(f"== {d} ==")
        print(render_summary(manifest, rows))
    return status


if __name__ == "__main__":
    raise SystemExit(main())

"""Enforce per-package coverage floors from a coverage.py JSON report.

Stdlib-only (like tools/lint): the CI test lane runs pytest with
``--cov … --cov-report=json:coverage.json`` and then gates on this
script, which aggregates covered/total executable lines per configured
package prefix and fails when any package is under its floor.

    python tools/check_coverage.py coverage.json

Floors live here (not in pytest.ini) so a local ``pytest`` run without
pytest-cov installed is unaffected.
"""

from __future__ import annotations

import argparse
import json
import sys

# package path prefix (as it appears in the report) -> minimum % covered
FLOORS = {
    "src/repro/optim": 85.0,
    "src/repro/train": 85.0,
}


def package_rates(files: dict) -> dict:
    """prefix -> (covered, total) aggregated over the report's files."""
    totals = {prefix: [0, 0] for prefix in FLOORS}
    for path, entry in files.items():
        norm = path.replace("\\", "/")
        for prefix in FLOORS:
            if norm.startswith(prefix + "/") or norm == prefix:
                s = entry["summary"]
                totals[prefix][0] += s["covered_lines"]
                totals[prefix][1] += s["num_statements"]
    return totals


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("report", help="coverage.py JSON report path")
    args = ap.parse_args()
    with open(args.report) as f:
        files = json.load(f)["files"]

    failures = 0
    for prefix, (covered, total) in sorted(package_rates(files).items()):
        floor = FLOORS[prefix]
        if total == 0:
            print(f"FAIL {prefix}: no measured files (report ran without "
                  f"--cov for this package?)")
            failures += 1
            continue
        pct = 100.0 * covered / total
        status = "ok  " if pct >= floor else "FAIL"
        print(f"{status} {prefix}: {pct:.1f}% ({covered}/{total} lines, "
              f"floor {floor:.0f}%)")
        failures += pct < floor
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())

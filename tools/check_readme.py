"""Execute every fenced ``bash``/``python`` block in README.md (docs lane).

The documented quickstart commands must keep working: this script extracts
each fenced code block, skips the ones explicitly annotated with an HTML
comment ``<!-- docs-lane: skip -->`` on one of the three lines above the
fence (reserved for heavy lanes and illustrative fragments), and executes
the rest from the repository root with ``PYTHONPATH=src`` — bash blocks
via ``bash -euo pipefail``, python blocks via ``python -c``.  Any nonzero
exit fails the lane.

    python tools/check_readme.py [--file README.md] [--timeout 600]
"""

from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
import time

SKIP_MARK = "docs-lane: skip"
FENCE = re.compile(r"^```(\w+)?\s*$")


def extract_blocks(text: str):
    """(lang, code, start_line, skipped) for every fenced block."""
    lines = text.splitlines()
    blocks = []
    i = 0
    while i < len(lines):
        m = FENCE.match(lines[i])
        if not m or not m.group(1):
            i += 1
            continue
        lang = m.group(1)
        skip = any(SKIP_MARK in lines[j]
                   for j in range(max(0, i - 3), i))
        body = []
        j = i + 1
        while j < len(lines) and not lines[j].startswith("```"):
            body.append(lines[j])
            j += 1
        blocks.append((lang, "\n".join(body), i + 1, skip))
        i = j + 1
    return blocks


def run_block(lang: str, code: str, repo: str, timeout: int) -> int:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(repo, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    if lang == "bash":
        argv = ["bash", "-euo", "pipefail", "-c", code]
    else:
        argv = [sys.executable, "-c", code]
    proc = subprocess.run(argv, cwd=repo, env=env, timeout=timeout)
    return proc.returncode


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--file", default="README.md")
    ap.add_argument("--timeout", type=int, default=600,
                    help="per-block timeout (s)")
    args = ap.parse_args()
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    path = os.path.join(repo, args.file)
    with open(path) as f:
        blocks = extract_blocks(f.read())

    runnable = [(lng, c, ln) for lng, c, ln, skip in blocks
                if not skip and lng in ("bash", "python")]
    skipped = sum(1 for *_, skip in blocks if skip)
    if not runnable:
        print(f"ERROR: {args.file} has no executable bash/python blocks "
              f"(all {len(blocks)} skipped?) — the docs lane would be "
              f"vacuous")
        return 1

    failures = 0
    for lang, code, line in runnable:
        head = code.strip().splitlines()[0] if code.strip() else "<empty>"
        print(f"--- {args.file}:{line} [{lang}] {head}", flush=True)
        t0 = time.perf_counter()
        rc = run_block(lang, code, repo, args.timeout)
        dt = time.perf_counter() - t0
        status = "OK" if rc == 0 else f"FAIL (rc={rc})"
        print(f"--- {status} in {dt:.1f}s", flush=True)
        failures += rc != 0
    print(f"{len(runnable)} blocks executed, {skipped} skipped, "
          f"{failures} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Engine of repro-lint: finding model, rule registry, waivers, file walker.

Stdlib-only by design — the CI static-analysis lane runs the linter without
installing any dependency.  Rules receive a :class:`FileContext` (parsed
AST + raw source + comment map) and yield :class:`Finding` objects; the
engine then applies inline waivers and decides the exit status.

Waiver syntax (``# repro-lint: disable=RPL002[,RPL004]  <justification>``):
the justification string is mandatory — a waiver without one does not
suppress anything and is itself reported as ``RPL000``.  A trailing waiver
covers findings on its own line; a standalone waiver comment covers the
line directly below it.
"""

from __future__ import annotations

import ast
import dataclasses
import io
import pathlib
import re
import tokenize
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

#: Reserved code for engine-level problems (broken waivers, parse errors).
BAD_WAIVER = "RPL000"

WAIVER_RE = re.compile(
    r"#\s*repro-lint:\s*disable=([A-Z]{3}\d{3}(?:\s*,\s*[A-Z]{3}\d{3})*)"
    r"[ \t]*(.*)$")


@dataclasses.dataclass
class Finding:
    """One rule violation at a precise source location."""

    code: str
    path: str
    line: int
    col: int
    message: str
    waived: bool = False
    justification: Optional[str] = None

    def format(self) -> str:
        """Human-readable one-liner (``path:line:col: CODE message``)."""
        tag = "  [waived: %s]" % self.justification if self.waived else ""
        return f"{self.path}:{self.line}:{self.col}: " \
               f"{self.code} {self.message}{tag}"

    def to_json(self) -> dict:
        """JSON-ready dict (the ``--format json`` row)."""
        return dataclasses.asdict(self)


@dataclasses.dataclass
class Waiver:
    """One parsed ``# repro-lint: disable=...`` comment."""

    codes: Tuple[str, ...]
    justification: str
    line: int
    standalone: bool

    def covers(self, line: int) -> bool:
        """Whether a finding on ``line`` is in this waiver's scope."""
        return line == self.line or (self.standalone
                                     and line == self.line + 1)


class Rule:
    """Base class: one registered invariant check.

    Subclasses set ``code`` (stable RPLnnn identifier), ``name`` (short
    slug) and ``summary`` (one-line invariant statement), may narrow
    ``applies`` (path-part scoping), and implement ``check``.
    """

    code = "RPL000"
    name = "base"
    summary = ""

    def applies(self, parts: Tuple[str, ...]) -> bool:
        """Whether the rule runs on a file with these relative path parts."""
        return True

    def check(self, ctx: "FileContext") -> Iterable[Finding]:
        """Yield findings for one parsed file."""
        raise NotImplementedError


_REGISTRY: Dict[str, Rule] = {}


def register(cls):
    """Class decorator adding a rule to the global registry."""
    inst = cls()
    if inst.code in _REGISTRY:
        raise ValueError(f"duplicate rule code {inst.code}")
    _REGISTRY[inst.code] = inst
    return cls


def all_rules() -> List[Rule]:
    """Registered rules, sorted by code."""
    return [_REGISTRY[c] for c in sorted(_REGISTRY)]


def dotted_name(node) -> Optional[str]:
    """``a.b.c`` for a Name/Attribute chain, else None."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


class FileContext:
    """Parsed source of one file, shared by every rule that runs on it.

    ``rel`` is the repo-relative path (display + scoping); ``parts`` its
    path segments.  ``comments`` maps line -> (text, standalone) for every
    comment token; built with :mod:`tokenize` so strings containing ``#``
    never masquerade as comments.
    """

    def __init__(self, rel: str, text: str):
        self.rel = str(rel).replace("\\", "/")
        self.parts = tuple(p for p in self.rel.split("/") if p)
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text)
        self.comments: Dict[int, Tuple[str, bool]] = {}
        for tok in _tokens(text):
            if tok.type == tokenize.COMMENT:
                line = tok.start[0]
                before = self.lines[line - 1][: tok.start[1]]
                self.comments[line] = (tok.string, not before.strip())

    def finding(self, code: str, node, message: str) -> Finding:
        """Finding anchored at an AST node (or a bare line number)."""
        line = getattr(node, "lineno", node if isinstance(node, int) else 1)
        col = getattr(node, "col_offset", 0) + 1
        return Finding(code, self.rel, line, col, message)

    def comment_lines(self, pattern: str) -> set:
        """Line numbers whose comment matches ``pattern`` (regex search)."""
        rx = re.compile(pattern)
        return {ln for ln, (txt, _) in self.comments.items()
                if rx.search(txt)}

    def has_marker(self, node, lines: set) -> bool:
        """Whether a marker comment covers a statement: on any line of the
        statement's span, or in the contiguous standalone-comment block
        directly above it (so multi-line explanations still count)."""
        end = getattr(node, "end_lineno", node.lineno)
        if any(ln in lines for ln in range(node.lineno, end + 1)):
            return True
        ln = node.lineno - 1
        while ln >= 1 and ln in self.comments and self.comments[ln][1]:
            if ln in lines:
                return True
            ln -= 1
        return False


def _tokens(text: str):
    try:
        yield from tokenize.generate_tokens(io.StringIO(text).readline)
    except (tokenize.TokenError, IndentationError):  # pragma: no cover
        return


def parse_waivers(ctx: FileContext) -> Tuple[List[Waiver], List[Finding]]:
    """Extract waivers from a file's comments.

    Returns (valid waivers, RPL000 findings for waivers missing their
    mandatory justification string).
    """
    waivers: List[Waiver] = []
    bad: List[Finding] = []
    for line, (txt, standalone) in sorted(ctx.comments.items()):
        m = WAIVER_RE.search(txt)
        if not m:
            continue
        codes = tuple(c.strip() for c in m.group(1).split(","))
        justification = m.group(2).strip(" \t-—:")
        if not justification:
            bad.append(Finding(
                BAD_WAIVER, ctx.rel, line, 1,
                f"waiver for {','.join(codes)} has no justification "
                f"string (required: '# repro-lint: disable=<codes>  "
                f"<why this is safe>')"))
            continue
        waivers.append(Waiver(codes, justification, line, standalone))
    return waivers, bad


def lint_source(text: str, rel: str = "src/repro/snippet.py",
                select: Optional[Sequence[str]] = None) -> List[Finding]:
    """Lint one source string under a virtual repo-relative path.

    The path drives per-rule scoping exactly as for on-disk files, so
    fixture tests can probe scope rules.  Waivers are applied; waived
    findings are returned with ``waived=True`` rather than dropped.
    """
    try:
        ctx = FileContext(rel, text)
    except SyntaxError as e:
        return [Finding(BAD_WAIVER, str(rel), e.lineno or 1, 1,
                        f"syntax error: {e.msg}")]
    findings: List[Finding] = []
    for rule in all_rules():
        if select and rule.code not in select:
            continue
        if not rule.applies(ctx.parts):
            continue
        findings.extend(rule.check(ctx))
    waivers, bad = parse_waivers(ctx)
    for f in findings:
        for w in waivers:
            if f.code in w.codes and w.covers(f.line):
                f.waived = True
                f.justification = w.justification
                break
    findings.extend(bad)
    findings.sort(key=lambda f: (f.line, f.col, f.code))
    return findings


def iter_python_files(paths: Sequence[str],
                      root: Optional[pathlib.Path] = None):
    """Yield (abs_path, repo_relative_path) for every .py file under paths."""
    root = pathlib.Path.cwd() if root is None else pathlib.Path(root)
    for p in paths:
        base = pathlib.Path(p)
        if not base.is_absolute():
            base = root / base
        if base.is_file():
            files = [base]
        else:
            files = sorted(x for x in base.rglob("*.py")
                           if "__pycache__" not in x.parts
                           and not any(part.startswith(".")
                                       for part in x.parts))
        for f in files:
            try:
                rel = f.resolve().relative_to(root.resolve())
            except ValueError:  # outside the root: display as given
                rel = f
            yield f, str(rel)


def lint_paths(paths: Sequence[str],
               select: Optional[Sequence[str]] = None,
               root: Optional[pathlib.Path] = None) -> List[Finding]:
    """Lint every Python file under the given paths."""
    out: List[Finding] = []
    for path, rel in iter_python_files(paths, root):
        out.extend(lint_source(path.read_text(), rel, select))
    return out

"""The RPL rule set (one module per rule; importing registers them all).

=======  ====================================================================
Code     Invariant
=======  ====================================================================
RPL001   no concrete-kernel imports outside ``src/repro/kernels/``
RPL002   duplicate-target ``.set``-style scatters carry a winner-policy
         marker (``# scatter: <policy>``)
RPL003   no host nondeterminism (np.random / random / time / datetime)
         reachable inside jit- or scan-traced code
RPL004   reductions/dots over bf16/int8 (or ``*_dtype``-configurable)
         operands declare an f32 accumulator (``dtype=`` /
         ``preferred_element_type=``)
RPL005   no ``interpret=True`` defaults or call-sites outside tests and
         benchmarks (auto-selection must never pick interpret mode)
RPL006   collectives bind their axis name: lexically inside a shard_map
         body, or under a documented must-run-inside-shard_map contract
RPL007   no raw ``// record_every`` chunking — use
         ``core.sparse.record_chunks``
=======  ====================================================================
"""

from tools.lint.rules import (  # noqa: F401
    rpl001_kernel_imports,
    rpl002_scatter_policy,
    rpl003_host_nondeterminism,
    rpl004_mixed_precision,
    rpl005_interpret_default,
    rpl006_axis_binding,
    rpl007_record_chunking,
)

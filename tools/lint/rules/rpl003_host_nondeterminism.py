"""RPL003: no host nondeterminism reachable inside jit/scan-traced code.

``np.random`` / ``random`` / ``time`` / ``datetime`` calls inside a traced
function execute once at trace time and bake a single draw into the
compiled program — the scan replays a constant, silently breaking the
RNG-schedule parity the engines are anchored on (and differing between a
cached and a fresh compilation).  Traced randomness must come from
``jax.random`` keys threaded through the carry; timestamps belong on the
host side of the chunk loop.

Traced scope = functions decorated with ``jax.jit`` (directly or through
``functools.partial``), functions passed to ``jax.jit(...)`` /
``lax.scan(...)``, and anything they call by name in the same module
(one-level module-local reachability).
"""

from __future__ import annotations

import ast

from tools.lint.core import FileContext, Rule, dotted_name, register

#: Module roots whose use inside traced code is nondeterministic.
BAD_MODULES = {"random", "time", "datetime"}
#: Names commonly imported *from* those modules.
BAD_FROM = {"random": {"*"}, "time": {"*"}, "datetime": {"*"},
            "numpy.random": {"*"}}


def _is_jit_expr(e) -> bool:
    d = dotted_name(e)
    if d and d.split(".")[-1] == "jit":
        return True
    if isinstance(e, ast.Call):
        f = dotted_name(e.func)
        if f and f.split(".")[-1] == "jit":
            return True
        if f and f.split(".")[-1] == "partial":
            return any(_is_jit_expr(a) for a in e.args)
    return False


def _collect_aliases(tree):
    """(numpy aliases, bad-module aliases, names imported from bad mods)."""
    np_alias, bad_alias, bad_names = set(), set(), set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                bound = a.asname or a.name.split(".")[0]
                if a.name == "numpy" or a.name.startswith("numpy."):
                    np_alias.add(bound)
                    if a.name.startswith("numpy.random"):
                        bad_alias.add(bound)
                if a.name.split(".")[0] in BAD_MODULES:
                    bad_alias.add(bound)
        elif isinstance(node, ast.ImportFrom) and node.module:
            mod = node.module
            if mod == "numpy":
                for a in node.names:
                    if a.name == "random":
                        bad_alias.add(a.asname or a.name)
            elif mod in BAD_MODULES or mod == "numpy.random":
                for a in node.names:
                    bad_names.add(a.asname or a.name)
    return np_alias, bad_alias, bad_names


def _traced_roots(tree):
    """Function defs / lambdas that enter a trace, plus traced call names."""
    names, nodes = set(), []
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(_is_jit_expr(d) for d in node.decorator_list):
                names.add(node.name)
        elif isinstance(node, ast.Call):
            f = dotted_name(node.func)
            tail = f.split(".")[-1] if f else ""
            if tail in {"jit", "scan"} and node.args:
                arg = node.args[0]
                if isinstance(arg, ast.Name):
                    names.add(arg.id)
                elif isinstance(arg, ast.Lambda):
                    nodes.append(arg)
                elif isinstance(arg, ast.Call):
                    for a in [arg.func] + list(arg.args):
                        if isinstance(a, ast.Name):
                            names.add(a.id)
    return names, nodes


def _functions_by_name(tree):
    out = {}
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            out.setdefault(node.name, []).append(node)
    return out


@register
class HostNondeterminism(Rule):
    code = "RPL003"
    name = "host-nondeterminism"
    summary = ("np.random/random/time/datetime never execute inside "
               "jit- or lax.scan-traced code (jax.random keys only)")

    def check(self, ctx: FileContext):
        np_alias, bad_alias, bad_names = _collect_aliases(ctx.tree)
        root_names, root_nodes = _traced_roots(ctx.tree)
        by_name = _functions_by_name(ctx.tree)

        # module-local reachability: traced functions mark their callees
        marked = set()
        frontier = list(root_names)
        while frontier:
            name = frontier.pop()
            if name in marked or name not in by_name:
                marked.add(name)
                continue
            marked.add(name)
            for fn in by_name[name]:
                for node in ast.walk(fn):
                    if isinstance(node, ast.Call) \
                            and isinstance(node.func, ast.Name) \
                            and node.func.id not in marked:
                        frontier.append(node.func.id)

        traced = [fn for name in marked for fn in by_name.get(name, [])]
        traced.extend(root_nodes)

        reported = set()
        for fn in traced:
            for node in ast.walk(fn):
                bad = self._bad_use(node, np_alias, bad_alias, bad_names)
                if bad is None:
                    continue
                key = (node.lineno, node.col_offset)
                if key in reported:
                    continue
                reported.add(key)
                yield ctx.finding(
                    self.code, node,
                    f"host nondeterminism `{bad}` reachable inside "
                    f"jit/scan-traced code — thread a jax.random key "
                    f"through the carry instead")

    @staticmethod
    def _bad_use(node, np_alias, bad_alias, bad_names):
        if isinstance(node, ast.Attribute):
            d = dotted_name(node)
            if not d:
                return None
            seg = d.split(".")
            if seg[0] in bad_alias:
                return d
            if seg[0] in np_alias and len(seg) > 1 and seg[1] == "random":
                return d
        elif isinstance(node, ast.Name) and isinstance(node.ctx, ast.Load):
            if node.id in bad_names:
                return node.id
        return None

"""RPL001: no concrete-kernel imports outside ``src/repro/kernels/``.

Production call sites resolve kernels through ``kernels.dispatch`` (the
PR 2 registry) so backend selection rules — platform auto, interpret
opt-in, impl overrides — apply uniformly.  A direct import of a concrete
kernel module bypasses every one of them.  Tests are exempt (they validate
concrete kernels on purpose); ``tests/test_dispatch.py``'s architecture
check delegates to this rule.
"""

from __future__ import annotations

import ast

from tools.lint.core import FileContext, Rule, register

#: Concrete kernel modules under src/repro/kernels/ (dispatch/ops/ref are
#: the sanctioned indirection layers and stay importable).
CONCRETE = frozenset(
    {"graph_mix", "sparse_mix", "admm_update", "flash_attention",
     "round_fuse", "sharded"})


@register
class KernelImports(Rule):
    code = "RPL001"
    name = "kernel-imports"
    summary = ("concrete kernel modules are imported only inside "
               "src/repro/kernels/ (everything else goes through "
               "kernels.dispatch)")

    def applies(self, parts):
        return "kernels" not in parts and "tests" not in parts

    def check(self, ctx: FileContext):
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    seg = alias.name.split(".")
                    if ("kernels" in seg
                            and CONCRETE & set(seg[seg.index("kernels"):])):
                        yield ctx.finding(
                            self.code, node,
                            f"direct concrete-kernel import "
                            f"`import {alias.name}` — resolve through "
                            f"repro.kernels.dispatch")
            elif isinstance(node, ast.ImportFrom):
                mod = node.module or ""
                seg = mod.split(".") if mod else []
                from_kernels = "kernels" in seg
                if not from_kernels and not node.level:
                    continue
                if not from_kernels:
                    continue  # relative import of a non-kernels module
                tail = set(seg[seg.index("kernels") + 1:])
                names = {a.name for a in node.names}
                hit = (tail & CONCRETE) or (not tail and names & CONCRETE)
                if hit:
                    yield ctx.finding(
                        self.code, node,
                        f"direct concrete-kernel import `from {mod or '.'} "
                        f"import {', '.join(sorted(names))}` — resolve "
                        f"through repro.kernels.dispatch")

"""RPL005: ``interpret=True`` never appears outside tests and benchmarks.

Pallas interpret mode is a validation device (orders of magnitude slower
than compiled; semantics subtly different around scatter collisions).  The
dispatch registry must never auto-select it, and no production default or
call-site may hard-code it — interpret is an explicit per-run opt-in
(``ReproBackend(interpret=True)`` / ``REPRO_PALLAS_INTERPRET=1``).  The
rule flags both ``def f(..., interpret=True)`` defaults and
``fn(..., interpret=True)`` call-sites; tests and benchmarks (which
validate kernels off-TPU on purpose) are exempt.
"""

from __future__ import annotations

import ast

from tools.lint.core import FileContext, Rule, register


def _true_const(e) -> bool:
    return isinstance(e, ast.Constant) and e.value is True


@register
class InterpretDefault(Rule):
    code = "RPL005"
    name = "no-interpret-default"
    summary = ("interpret=True appears only in tests/benchmarks — "
               "production resolves interpret via the explicit opt-in")

    def applies(self, parts):
        return "tests" not in parts and "benchmarks" not in parts

    def check(self, ctx: FileContext):
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                                 ast.Lambda)):
                a = node.args
                params = a.args + a.kwonlyargs
                defaults = (
                    [None] * (len(a.args) - len(a.defaults))
                    + list(a.defaults) + list(a.kw_defaults))
                for param, default in zip(params, defaults):
                    if param.arg == "interpret" and default is not None \
                            and _true_const(default):
                        yield ctx.finding(
                            self.code, node,
                            "parameter default interpret=True — interpret "
                            "mode must be an explicit opt-in (default "
                            "False)")
            elif isinstance(node, ast.Call):
                for kw in node.keywords:
                    if kw.arg == "interpret" and _true_const(kw.value):
                        yield ctx.finding(
                            self.code, kw.value,
                            "call-site interpret=True outside tests/"
                            "benchmarks — pass the opt-in from the caller "
                            "(ReproBackend(interpret=True) or "
                            "REPRO_PALLAS_INTERPRET=1)")

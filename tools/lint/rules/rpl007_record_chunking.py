"""RPL007: no raw ``// record_every`` chunking outside the shared policy.

PR 4 fixed a silent zero-step-recording bug (``steps < record_every``
made ``n_rec = 0``: the scan ran nothing and returned an empty history)
by routing every chunking site through ``core.sparse.record_chunks``
(clamp to ``[1, steps]``, floor to whole chunks).  A fresh
``x // record_every`` reintroduces exactly that class unless its inputs
are already normalized — sites downstream of a ``record_chunks`` call
waive this rule with that justification.
"""

from __future__ import annotations

import ast

from tools.lint.core import FileContext, Rule, register


@register
class RecordChunking(Rule):
    code = "RPL007"
    name = "record-chunking"
    summary = ("chunked recording derives (record_every, n_rec) via "
               "core.sparse.record_chunks, never a raw // record_every")

    def applies(self, parts):
        return "tests" not in parts

    def check(self, ctx: FileContext):
        # the policy function itself is the one sanctioned division site
        exempt = set()
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and node.name == "record_chunks":
                exempt.update(
                    (n.lineno, n.col_offset) for n in ast.walk(node)
                    if hasattr(n, "lineno"))
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.BinOp)
                    and isinstance(node.op, ast.FloorDiv)):
                continue
            if (node.lineno, node.col_offset) in exempt:
                continue
            names = {s.id if isinstance(s, ast.Name) else s.attr
                     for s in (node.left, node.right)
                     if isinstance(s, (ast.Name, ast.Attribute))}
            if "record_every" in names:
                yield ctx.finding(
                    self.code, node,
                    "raw `// record_every` chunking — derive "
                    "(record_every, n_rec) through "
                    "core.sparse.record_chunks (or waive citing the "
                    "upstream normalization)")

"""RPL002: duplicate-target ``.set``-style scatters need a winner-policy
marker.

When a scatter's index array can name the same target twice, the surviving
value is backend/implementation-defined (jax ``.at[].set``) or silently
last-write-wins / duplicate-dropping (numpy fancy assignment, ``x[i] += v``).
This class shipped real bugs twice: the PR 7 ``round_step`` winner dedup
exists because duplicate (row, slot) scatters resolved differently across
backends, and PR 8's ``stream_dirty_chunks`` clobbered True writes under
duplicate targets.  Commutative scatters (``.at[].add/max/min``) are
order-independent and exempt.

Any potentially-duplicate ``.set``/assignment scatter must carry a marker
comment — on a line of the statement or directly above it — naming the
policy that makes it deterministic::

    # scatter: unique targets (rows of one partition block)
    blk[local_pos[mask]] = theta[mask]

The marker text is free-form but must be non-empty; typical policies are
``unique targets``, ``idempotent (all writes equal)``,
``last-write-wins (intended)``, ``winner dedup upstream``.
"""

from __future__ import annotations

import ast

from tools.lint.core import FileContext, Rule, register

MARKER = r"#\s*scatter:\s*\S"

#: jax .at[...] methods with order-dependent duplicate semantics.  add/
#: max/min/mul are commutative and therefore deterministic under dups.
NONCOMMUTATIVE = frozenset({"set"})


def _scalar_names(func) -> set:
    """Names provably scalar inside ``func``: range/enumerate loop indices
    and names bound to integer constants."""
    out = set()
    for node in ast.walk(func):
        if isinstance(node, ast.For) and isinstance(node.iter, ast.Call):
            f = node.iter.func
            fname = f.id if isinstance(f, ast.Name) else getattr(f, "attr", "")
            tgt = node.target
            if fname == "range" and isinstance(tgt, ast.Name):
                out.add(tgt.id)
            elif fname == "enumerate" and isinstance(tgt, ast.Tuple) \
                    and tgt.elts and isinstance(tgt.elts[0], ast.Name):
                out.add(tgt.elts[0].id)
        elif isinstance(node, ast.Assign) and len(node.targets) == 1 \
                and isinstance(node.targets[0], ast.Name) \
                and isinstance(node.value, ast.Constant) \
                and isinstance(node.value.value, int):
            out.add(node.targets[0].id)
    return out


def _maybe_dup(e, scalars: set) -> bool:
    """Whether an index expression can address the same target twice."""
    if e is None or isinstance(e, ast.Constant):
        return False
    if isinstance(e, ast.Slice):
        return False  # a slice enumerates distinct positions
    if isinstance(e, ast.Name):
        return e.id not in scalars
    if isinstance(e, ast.UnaryOp):
        return _maybe_dup(e.operand, scalars)
    if isinstance(e, ast.BinOp):
        return (_maybe_dup(e.left, scalars)
                or _maybe_dup(e.right, scalars))
    if isinstance(e, ast.Tuple):
        return any(_maybe_dup(x, scalars) for x in e.elts)
    return True  # Call / Subscript / Attribute / Compare / ...


def _array_tainted_names(func) -> set:
    """Names assigned from array-producing expressions (calls, comparisons,
    subscripts) within ``func`` — candidates for fancy-index scatters."""
    out = set()
    for node in ast.walk(func):
        if not isinstance(node, ast.Assign):
            continue
        if not isinstance(node.value,
                          (ast.Call, ast.Compare, ast.Subscript, ast.BinOp,
                           ast.BoolOp)):
            continue
        for tgt in node.targets:
            elts = tgt.elts if isinstance(tgt, ast.Tuple) else [tgt]
            out.update(e.id for e in elts if isinstance(e, ast.Name))
    return out


def _index_is_computed(e, tainted: set) -> bool:
    """Numpy-branch gate: the index is itself an array expression (call /
    subscript / comparison) or a name assigned from one."""
    if isinstance(e, (ast.Call, ast.Subscript, ast.Compare, ast.BoolOp)):
        return True
    if isinstance(e, ast.Name):
        return e.id in tainted
    if isinstance(e, (ast.Tuple, ast.BinOp)):
        kids = e.elts if isinstance(e, ast.Tuple) else [e.left, e.right]
        return any(_index_is_computed(k, tainted) for k in kids)
    return False


def _at_scatter(call: ast.Call):
    """(index, method) when ``call`` is ``<x>.at[index].<method>(...)``."""
    f = call.func
    if not (isinstance(f, ast.Attribute) and isinstance(f.value,
                                                        ast.Subscript)):
        return None
    sub = f.value
    if isinstance(sub.value, ast.Attribute) and sub.value.attr == "at":
        return sub.slice, f.attr
    return None


@register
class ScatterPolicy(Rule):
    code = "RPL002"
    name = "scatter-winner-policy"
    summary = ("duplicate-target .set scatters and fancy-index assignments "
               "carry an explicit '# scatter: <policy>' marker")

    def applies(self, parts):
        return "tests" not in parts

    def check(self, ctx: FileContext):
        markers = ctx.comment_lines(MARKER)
        scalars = _scalar_names(ctx.tree)
        tainted = _array_tainted_names(ctx.tree)
        for node in ast.walk(ctx.tree):
            hit = None
            if isinstance(node, ast.Call):
                at = _at_scatter(node)
                if at and at[1] in NONCOMMUTATIVE \
                        and _maybe_dup(at[0], scalars):
                    hit = f".at[...].{at[1]}() scatter"
            elif isinstance(node, (ast.Assign, ast.AugAssign)):
                tgts = (node.targets if isinstance(node, ast.Assign)
                        else [node.target])
                for tgt in tgts:
                    if isinstance(tgt, ast.Subscript) \
                            and _index_is_computed(tgt.slice, tainted) \
                            and _maybe_dup(tgt.slice, scalars):
                        hit = ("fancy-index augmented assignment "
                               "(numpy += drops duplicate targets)"
                               if isinstance(node, ast.AugAssign)
                               else "fancy-index assignment")
                        break
            if hit is None:
                continue
            if not ctx.has_marker(node, markers):
                yield ctx.finding(
                    self.code, node,
                    f"{hit} whose index may carry duplicate targets "
                    f"has no winner-policy marker "
                    f"('# scatter: <policy>')")

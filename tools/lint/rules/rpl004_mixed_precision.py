"""RPL004: reductions over low-precision operands declare f32 accumulation.

The halo codecs (``launch/sim_mesh.py``), the coupling wire formats
(``coupling/strategies.py``) and the bf16 optimizer moments ship bf16/int8
payloads — but every *reduction* over them (sum / mean / dot / einsum /
matmul) must accumulate in float32, or the results drift with operand
order and shard count, breaking the bit-for-bit parity anchors.  The rule
flags reduction calls whose operands are low-precision tainted — cast via
``.astype(bfloat16 / float16 / int8)`` or via a ``*_dtype`` configuration
knob (which may be set to bf16 by callers) — without an explicit
``dtype=`` / ``preferred_element_type=`` accumulator.

jnp reductions accept ``dtype=``; dots/einsums take
``preferred_element_type=`` (see kernels/graph_mix.py for the idiom).
"""

from __future__ import annotations

import ast
import re

from tools.lint.core import FileContext, Rule, dotted_name, register

REDUCERS = frozenset(
    {"sum", "mean", "dot", "matmul", "einsum", "tensordot", "vdot", "prod",
     "dot_general"})

#: dtype expressions that (may) denote a sub-f32 wire format: concrete
#: low-precision dtypes, or a ``*_dtype`` config attribute that callers can
#: set to one.
LOWPREC_RE = re.compile(
    r"(bfloat16|float16|int8|int4|float8|\w+_dtype\b)")

ACC_KWARGS = {"dtype", "preferred_element_type"}


def _is_lowprec_cast(call: ast.Call) -> bool:
    """``<x>.astype(<lowprec>)`` or ``asarray(x, <lowprec>)``."""
    f = call.func
    name = f.attr if isinstance(f, ast.Attribute) else getattr(f, "id", "")
    if name == "astype" and call.args:
        return bool(LOWPREC_RE.search(ast.unparse(call.args[0])))
    if name in {"asarray", "array", "full", "zeros", "ones"}:
        for a in list(call.args[1:]) + [k.value for k in call.keywords
                                        if k.arg == "dtype"]:
            if LOWPREC_RE.search(ast.unparse(a)):
                return True
    return False


def _tainted_names(tree) -> set:
    out = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call) \
                and _is_lowprec_cast(node.value):
            for tgt in node.targets:
                elts = tgt.elts if isinstance(tgt, ast.Tuple) else [tgt]
                out.update(e.id for e in elts if isinstance(e, ast.Name))
    return out


def _expr_tainted(e, tainted: set) -> bool:
    for node in ast.walk(e):
        if isinstance(node, ast.Name) and node.id in tainted:
            return True
        if isinstance(node, ast.Call) and _is_lowprec_cast(node):
            return True
    return False


@register
class MixedPrecision(Rule):
    code = "RPL004"
    name = "f32-accumulation"
    summary = ("reductions/dots over bf16/int8 (or *_dtype-configurable) "
               "operands pass dtype=/preferred_element_type= for f32 "
               "accumulation")

    def applies(self, parts):
        return "tests" not in parts

    def check(self, ctx: FileContext):
        tainted = _tainted_names(ctx.tree)
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if not isinstance(f, ast.Attribute) or f.attr not in REDUCERS:
                continue
            if any(k.arg in ACC_KWARGS for k in node.keywords):
                continue
            root = dotted_name(f.value)
            if root in {"np", "numpy", "jnp", "jax.numpy", "jax.lax", "lax",
                        "math"}:
                operands = list(node.args)
            else:
                operands = [f.value] + list(node.args)
            if any(_expr_tainted(o, tainted) for o in operands):
                yield ctx.finding(
                    self.code, node,
                    f"`{f.attr}` reduction over a low-precision-tainted "
                    f"operand without an explicit f32 accumulator "
                    f"(dtype=/preferred_element_type=jnp.float32)")

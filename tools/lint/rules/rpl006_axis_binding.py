"""RPL006: collectives bind their axis name to a shard_map context.

``ppermute`` / ``all_gather`` / ``psum`` / ``axis_index`` resolve their
``axis_name`` against the innermost enclosing ``shard_map`` (or vmapped
``spmd_axis_name``) binding; a collective issued outside one fails at
trace time in the best case and silently binds a *different* mesh axis in
the worst (2-D meshes are on the roadmap).  The rule accepts a collective
when either

* it sits lexically inside a function that this module passes to a
  ``shard_map``-family call (``jax.shard_map``, ``shard_map_1d``,
  ``_shard_map``, ...) — the binding is visible locally; or
* an enclosing function's docstring mentions ``shard_map`` — the
  documented caller-binds contract (e.g. ``halo_exchange_fn``'s closures,
  ``gossip_mix_tree``), which keeps the obligation readable at the def.

Anything else is an unbound collective.
"""

from __future__ import annotations

import ast

from tools.lint.core import FileContext, Rule, dotted_name, register

COLLECTIVES = frozenset(
    {"ppermute", "all_gather", "psum", "pmean", "pmax", "pmin",
     "all_to_all", "axis_index", "pshuffle", "pbroadcast"})

LAX_ROOTS = {"jax", "lax"}


def _collective(call: ast.Call):
    d = dotted_name(call.func)
    if not d:
        return None
    seg = d.split(".")
    if seg[-1] in COLLECTIVES and (seg[0] in LAX_ROOTS or "lax" in seg):
        return d
    return None


def _bound_names(tree) -> set:
    """Function names passed (possibly wrapped) to shard_map-family calls."""
    out = set()

    def harvest(e):
        if isinstance(e, ast.Name):
            out.add(e.id)
        elif isinstance(e, ast.Call):  # e.g. jax.vmap(body), partial(f, ...)
            for a in list(e.args):
                harvest(a)

    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            d = dotted_name(node.func) or ""
            if "shard_map" in d.split(".")[-1] and node.args:
                harvest(node.args[0])
    return out


def _scopes(tree):
    """Yield (scope node, enclosing function chain incl. the scope itself
    when it is a function) depth-first; the module is the outermost scope."""
    def visit(node, chain):
        is_fn = isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
        here = chain + (node,) if is_fn else chain
        yield node, here
        for child in ast.iter_child_nodes(node):
            yield from visit(child, here)

    yield from visit(tree, ())


def _own_nodes(scope):
    """Walk a scope's body without descending into nested function defs."""
    todo = list(ast.iter_child_nodes(scope))
    while todo:
        node = todo.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        yield node
        todo.extend(ast.iter_child_nodes(node))


@register
class AxisBinding(Rule):
    code = "RPL006"
    name = "collective-axis-binding"
    summary = ("collectives run inside a module-visible shard_map body or "
               "under a documented must-run-inside-shard_map contract")

    def check(self, ctx: FileContext):
        bound = _bound_names(ctx.tree)
        for scope, chain in _scopes(ctx.tree):
            if not isinstance(scope, (ast.Module, ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                continue
            ok = any(f.name in bound for f in chain) or any(
                "shard_map" in (ast.get_docstring(f) or "").lower()
                for f in chain)
            if ok:
                continue
            for sub in _own_nodes(scope):
                if isinstance(sub, ast.Call):
                    d = _collective(sub)
                    if d:
                        yield ctx.finding(
                            self.code, sub,
                            f"collective `{d}` with no visible shard_map "
                            f"binding — wrap in shard_map here, or "
                            f"document the caller-binds contract in the "
                            f"enclosing docstring")

"""CLI: ``python -m tools.lint [paths...] [--format text|json] ...``.

Exit status 0 when every finding is waived (with justification), 1 when
any unwaived finding remains, 2 on usage errors.  ``--format json`` emits
a machine-readable report (rule code, path:line, waiver status) so CI and
future PRs can gate on finding deltas the way the bench lanes gate on
``BENCH_*.json``.
"""

from __future__ import annotations

import argparse
import json
import sys

from tools.lint.core import all_rules, lint_paths

DEFAULT_PATHS = ("src", "tests", "benchmarks", "examples")


def main(argv=None) -> int:
    """Run the linter; returns the process exit status."""
    ap = argparse.ArgumentParser(
        prog="python -m tools.lint",
        description="repro-lint: AST invariant checks (DESIGN.md §17)")
    ap.add_argument("paths", nargs="*", default=list(DEFAULT_PATHS),
                    help="files or directories (default: %(default)s)")
    ap.add_argument("--format", choices=("text", "json"), default="text")
    ap.add_argument("--select", default=None,
                    help="comma-separated rule codes to run (e.g. RPL001)")
    ap.add_argument("--show-waived", action="store_true",
                    help="also print waived findings in text mode")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule table and exit")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in all_rules():
            print(f"{rule.code}  {rule.name}: {rule.summary}")
        return 0

    select = None
    if args.select:
        select = [c.strip().upper() for c in args.select.split(",")]
        known = {r.code for r in all_rules()}
        bad = sorted(set(select) - known)
        if bad:
            print(f"unknown rule code(s): {', '.join(bad)}",
                  file=sys.stderr)
            return 2

    findings = lint_paths(args.paths, select)
    unwaived = [f for f in findings if not f.waived]
    waived = [f for f in findings if f.waived]

    if args.format == "json":
        per_rule = {}
        for f in findings:
            row = per_rule.setdefault(f.code, {"total": 0, "waived": 0})
            row["total"] += 1
            row["waived"] += int(f.waived)
        print(json.dumps({
            "findings": [f.to_json() for f in findings],
            "counts": {
                "total": len(findings),
                "waived": len(waived),
                "unwaived": len(unwaived),
                "per_rule": per_rule,
            },
        }, indent=2, sort_keys=True))
        return 1 if unwaived else 0

    shown = findings if args.show_waived else unwaived
    for f in sorted(shown, key=lambda f: (f.path, f.line, f.col)):
        print(f.format())
    if unwaived:
        print(f"\n{len(unwaived)} unwaived finding(s) "
              f"({len(waived)} waived)", file=sys.stderr)
        return 1
    print(f"repro-lint: clean ({len(waived)} waived finding(s))")
    return 0


if __name__ == "__main__":
    sys.exit(main())

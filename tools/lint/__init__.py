"""repro-lint: AST-based invariant checks for determinism, dispatch, and
sharding rules (DESIGN.md §17).

The repo's bit-for-bit parity anchors only hold because of a handful of
coding invariants — deterministic duplicate-target scatters, no host state
inside jitted scans, f32 accumulation around bf16/int8 wire formats,
dispatch-registry discipline, shard_map axis-name binding, shared record
chunking.  Each rule here encodes one of them as enforceable lint with a
stable code (RPL001...); violations that are intentional carry an inline
waiver with a mandatory justification::

    python -m tools.lint src tests benchmarks examples
    python -m tools.lint --format json src

Waiver syntax (same line as the finding, or the line directly above)::

    theta = theta.at[idx].set(new)  # repro-lint: disable=RPL002  <why>

Rules live in :mod:`tools.lint.rules` (one module per rule); the engine —
file walking, waiver parsing, finding model — in :mod:`tools.lint.core`.
"""

from tools.lint.core import (  # noqa: F401
    Finding,
    Rule,
    all_rules,
    lint_paths,
    lint_source,
)
from tools.lint import rules  # noqa: F401  (registers the RPL rules)

"""Network-simulator scaling benchmark (DESIGN.md §4, §9, §14).

Runs the sparse event-driven MP-gossip engine across agent counts and fault
scenarios, recording throughput (rounds/s, events/s) and peak host memory.
The point of the exercise: at n = 10,000 (k = 8, p = 32) the dense
(n, n, p) knowledge state alone would be 12.8 GB (x5 for ADMM) and blows the
4 GB host budget — the sparse engine's whole footprint is tens of MB, so
10k-50k agents are routine.

    PYTHONPATH=src python benchmarks/bench_network_sim.py \
        --ns 1000,10000 --scenarios clean,lossy-10 --rounds 200

``--sharded`` additionally runs the graph-partitioned engine
(``simulate.partition``) on a mesh of ``--shards`` devices and reports the
event-throughput ratio over the single-device run.  On a CPU-only host the
devices are XLA fake host devices; this script force-creates them (the flag
must precede jax init, so it is set at import time when --sharded is given).
Each sharded run also records the per-round halo wire bytes under every
``HaloCodec`` and fails when int8 exceeds ``--halo-max-int8-ratio`` (0.35)
of f32.  ``--fused`` (mp only) reruns each config through the fused
``round_step`` dispatch op and reports its events/s speedup over the
per-op sequence (gated by ``--fused-min-ratio`` when given; the fused run
must also reproduce the default engine's exact event counters).

Besides the CSV rows (name,us,derived — same convention as the other
benchmarks), every invocation writes a machine-readable
``BENCH_network_sim.json`` (``--out``) with per-run events/s, RSS, core
count, sharded ratio, and — under ``--overhead`` — the telemetry-enabled
rerun and its events/s overhead percentage.  ``--baseline
BENCH_network_sim.baseline.json`` turns the run into a CI gate: it fails on
>2x per-run events/s regression after normalizing by the median slowdown
across all runs (so a uniformly slower runner doesn't trip it) and on any
drift in the deterministic delivered/dropped/invalid counters when the
invocation shape matches the baseline's.  Refresh the committed baseline
with the CI invocation plus ``--out BENCH_network_sim.baseline.json``.

``--run-dir DIR`` records each telemetry-enabled run as a run directory
(manifest.json + metrics.jsonl, rendered by ``tools/trace_report.py``);
``--profile DIR`` wraps one timed single-device run per (scenario, n) in
``jax.profiler.trace`` so the ``repro/<op>/<impl>`` named scopes from
``kernels.dispatch`` show up attributed in TensorBoard/Perfetto.
"""

from __future__ import annotations

import argparse
import json
import os
import resource
import sys
import time


def _requested_shards(argv) -> int:
    for i, a in enumerate(argv):
        if a == "--shards" and i + 1 < len(argv):
            return int(argv[i + 1])
        if a.startswith("--shards="):
            return int(a.split("=", 1)[1])
    return 8


if "--sharded" in sys.argv and \
        "host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count="
          f"{_requested_shards(sys.argv)}").strip()

import jax  # noqa: E402
import numpy as np  # noqa: E402

sys.path.insert(0, __file__.rsplit("/", 1)[0])
from common import emit, time_call  # noqa: E402

from repro.core.losses import pad_datasets, solitary_mean  # noqa: E402
from repro.kernels.dispatch import ReproBackend  # noqa: E402
from repro.launch.sim_mesh import (HaloCodec,  # noqa: E402
                                   halo_payload_bytes)
from repro.simulate import (GraphPartition, get_scenario,  # noqa: E402
                            greedy_partition, random_geometric_topology,
                            run_cl_scenario, run_cl_scenario_sharded,
                            run_joint_scenario, run_joint_scenario_sharded,
                            run_mp_scenario, run_mp_scenario_sharded)
from repro.telemetry import (TelemetryConfig, build_manifest,  # noqa: E402
                             trace_rows, write_run)

#: graph-learning knobs for --algo joint (rate/temperature/cadence chosen so
#: the learned graph moves every few rounds without pruning the whole
#: candidate set; see DESIGN.md §13)
JOINT_KW = dict(eta_graph=0.3, lam=1.0, graph_every=5, prune_eps=1e-3)

#: events/s regression gate vs baseline, after machine-speed normalization
MAX_SLOWDOWN = 2.0


def peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def _make_data(n: int, p: int, seed: int):
    """Per-agent quadratic-loss samples (3 draws around a random mean)."""
    rng = np.random.default_rng(seed + 1)
    x = rng.standard_normal((n, 3, p)).astype(np.float32)
    return pad_datasets(list(x), [np.zeros(3)] * n)


def _single_runner(algo: str, topo, p: int, seed: int):
    """(run(cond, rounds, batch, record_every) -> trace) for one device."""
    rng = np.random.default_rng(seed)
    if algo == "admm":
        data = _make_data(topo.n, p, seed)
        sol = np.asarray(solitary_mean(data), np.float32)
        return lambda cond, **kw: run_cl_scenario(topo, data, 0.1, 1.0,
                                                  cond, theta_sol=sol, **kw)
    theta_sol = rng.standard_normal((topo.n, p)).astype(np.float32)
    c = rng.uniform(0.05, 1.0, topo.n).astype(np.float32)
    if algo == "joint":
        return lambda cond, **kw: run_joint_scenario(
            topo, theta_sol, c, 0.9, cond, **JOINT_KW, **kw)
    return lambda cond, **kw: run_mp_scenario(topo, theta_sol, c, 0.9,
                                              cond, **kw)


def _sharded_runner(algo: str, topo, p: int, seed: int):
    rng = np.random.default_rng(seed)
    if algo == "admm":
        data = _make_data(topo.n, p, seed)
        sol = np.asarray(solitary_mean(data), np.float32)
        return lambda cond, **kw: run_cl_scenario_sharded(
            topo, data, 0.1, 1.0, cond, theta_sol=sol, **kw)
    theta_sol = rng.standard_normal((topo.n, p)).astype(np.float32)
    c = rng.uniform(0.05, 1.0, topo.n).astype(np.float32)
    if algo == "joint":
        return lambda cond, **kw: run_joint_scenario_sharded(
            topo, theta_sol, c, 0.9, cond, **JOINT_KW, **kw)
    return lambda cond, **kw: run_mp_scenario_sharded(topo, theta_sol, c,
                                                      0.9, cond, **kw)


def bench_one(n: int, k: int, p: int, scenario_name: str, rounds: int,
              batch: int, seed: int = 0, algo: str = "mp", repeats: int = 1,
              telemetry=None, profile_dir=None, backend=None):
    """Timed single-device run; returns (report row, trace).

    ``backend`` (mp only) routes the round body through a fused
    ``round_step`` dispatch impl instead of the per-op sequence.
    """
    scenario = get_scenario(scenario_name)
    t0 = time.perf_counter()
    topo = random_geometric_topology(n, k=k, seed=seed)
    build_s = time.perf_counter() - t0

    cond = scenario.make_conditions(rounds)
    run = _single_runner(algo, topo, p, seed)

    # warmup with IDENTICAL static args + shapes: the engine's runner is a
    # module-level jit, so this compiles the exact program the timed run
    # reuses (steady-state events/s, no trace/compile in the measurement)
    record_every = max(1, rounds // 10)
    kw = dict(rounds=rounds, batch=batch, seed=seed,
              record_every=record_every, telemetry=telemetry)
    if backend is not None:
        kw["backend"] = backend
    tr = run(cond, **kw)
    if profile_dir is not None:
        with jax.profiler.trace(profile_dir):
            run(cond, **kw)
    dt = time_call(run, cond, repeats=repeats, warmup=0, **kw) / 1e6

    # the ADMM state carries 5 extra (n, k, p) arrays beyond MP's one; the
    # joint engine adds the learned (n, k) weight + liveness tables
    state_mb = topo.state_bytes(p) / 2**20
    if algo == "admm":
        state_mb += 4 * 4 * n * topo.k_max * p / 2**20
    elif algo == "joint":
        state_mb += 5 * n * topo.k_max / 2**20
    row = {
        "n": n, "k_max": topo.k_max, "p": p, "scenario": scenario_name,
        "rounds": tr.rounds, "batch": batch, "events": tr.events,
        "time_s": dt, "build_s": build_s,
        "rounds_per_s": tr.rounds / dt, "events_per_s": tr.events / dt,
        "delivered": tr.delivered, "dropped": tr.dropped,
        "invalid": tr.invalid,
        "sparse_state_mb": state_mb,
        "dense_state_mb": topo.dense_state_bytes(p) / 2**20
        * (5 if algo == "admm" else 1),
        "peak_rss_mb": peak_rss_mb(),
    }
    return row, tr


def bench_one_sharded(n: int, k: int, p: int, scenario_name: str,
                      rounds: int, batch: int, shards: int,
                      seed: int = 0, algo: str = "mp",
                      repeats: int = 1) -> dict:
    """Timed sharded run (partition + event-stream build reported apart)."""
    scenario = get_scenario(scenario_name)
    topo = random_geometric_topology(n, k=k, seed=seed)
    cond = scenario.make_conditions(rounds)
    record_every = max(1, rounds // 10)
    run = _sharded_runner(algo, topo, p, seed)

    t0 = time.perf_counter()
    assignment = greedy_partition(topo, shards)
    part_s = time.perf_counter() - t0

    kw = dict(rounds=rounds, batch=batch, seed=seed,
              record_every=record_every, n_shards=shards,
              assignment=assignment)
    tr = run(cond, **kw)                                        # warmup
    dt = time_call(run, cond, repeats=repeats, warmup=0, **kw) / 1e6
    # per-round halo wire bytes under each codec (what the telemetry
    # halo_bytes column would account; the CL payload stacks 1 + 3k rows)
    part = GraphPartition.build(topo, assignment, tr.n_shards)
    row_shape = (1 + 3 * topo.k_max, p) if algo == "admm" else (p,)
    halo_bytes = {
        name: halo_payload_bytes(part.n_shards, part.boundary_size,
                                 HaloCodec(name).row_nbytes(row_shape),
                                 part.halo_size)
        for name in HaloCodec.NAMES}
    return {
        "time_s": dt, "part_s": part_s, "events": tr.events,
        "events_per_s": tr.events / dt, "n_shards": tr.n_shards,
        "edge_cut": tr.edge_cut, "halo": tr.halo_size,
        "local_batch": tr.local_batch, "overflow": tr.overflow,
        "halo_bytes_per_round": halo_bytes,
        "peak_rss_mb": peak_rss_mb(),
    }


def compare_to_baseline(report: dict, baseline: dict) -> list:
    """Gate failures of ``report`` vs a committed baseline (see module
    docstring for the rules).  Returns human-readable failure strings."""
    failures = []
    base_runs = {r["name"]: r for r in baseline.get("runs", [])}
    meta_keys = ("rounds", "k", "p", "algo", "batch")
    same_shape = all(report["meta"].get(m) == baseline.get("meta", {}).get(m)
                     for m in meta_keys)
    pairs = []               # (name, cur events/s, base events/s)
    for r in report["runs"]:
        b = base_runs.get(r["name"])
        if b is None:
            continue
        pairs.append((r["name"], r["events_per_s"], b["events_per_s"]))
        if "sharded" in r and "sharded" in b:
            pairs.append((r["name"] + "/sharded",
                          r["sharded"]["events_per_s"],
                          b["sharded"]["events_per_s"]))
        if "fused" in r and "fused" in b:
            pairs.append((r["name"] + "/fused",
                          r["fused"]["events_per_s"],
                          b["fused"]["events_per_s"]))
        if same_shape:
            for c in ("events", "delivered", "dropped", "invalid"):
                if c in b and r.get(c) != b[c]:
                    failures.append(
                        f"counter drift: {r['name']} {c} {r.get(c)} vs "
                        f"baseline {b[c]} (same seed+shape must be exact)")
    if pairs:
        # slowdown = base/cur; median across runs = runner speed, so only
        # runs that regressed relative to the rest of the suite trip the gate
        slowdowns = sorted(b / max(c, 1e-9) for _, c, b in pairs)
        machine = slowdowns[len(slowdowns) // 2]
        for name, cur, base in pairs:
            rel = (base / max(cur, 1e-9)) / max(machine, 1e-9)
            if rel > MAX_SLOWDOWN:
                failures.append(
                    f"throughput regression: {name} {cur:.0f} events/s vs "
                    f"baseline {base:.0f} ({rel:.2f}x the suite median "
                    f"drift)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ns", default="1000,10000")
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--p", type=int, default=32)
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--batch", type=int, default=0,
                    help="wake-ups per round (default n // 10)")
    ap.add_argument("--scenarios", default="clean,lossy-10")
    ap.add_argument("--algo", default="mp", choices=("mp", "admm", "joint"),
                    help="engine: MP gossip (run_mp_scenario), CL-ADMM "
                         "(run_cl_scenario), or joint model+graph learning "
                         "(run_joint_scenario)")
    ap.add_argument("--fused", action="store_true",
                    help="(mp only) also run the engine through the fused "
                         "round_step op and report the events/s speedup "
                         "over the per-op sequence")
    ap.add_argument("--fused-min-ratio", type=float, default=None,
                    help="fail if any fused run's speedup over the per-op "
                         "sequence falls below this ratio")
    ap.add_argument("--halo-max-int8-ratio", type=float, default=0.35,
                    help="with --sharded: fail if the int8 halo codec's "
                         "per-round wire bytes exceed this fraction of "
                         "f32's (0 disables the check)")
    ap.add_argument("--sharded", action="store_true",
                    help="also run the partitioned engine and report the "
                         "event-throughput ratio over one device")
    ap.add_argument("--shards", type=int, default=8,
                    help="mesh size for --sharded (forced as fake host "
                         "devices when the process has fewer)")
    ap.add_argument("--repeats", type=int, default=1,
                    help="timed repeats per run (min is reported)")
    ap.add_argument("--overhead", action="store_true",
                    help="rerun each config with telemetry enabled and "
                         "report the events/s overhead percentage")
    ap.add_argument("--out", default="BENCH_network_sim.json")
    ap.add_argument("--baseline", default=None,
                    help="committed baseline JSON to gate against (fail on "
                         ">2x normalized events/s regression or counter "
                         "drift)")
    ap.add_argument("--run-dir", default=None,
                    help="record telemetry-enabled runs as run directories "
                         "(manifest.json + metrics.jsonl) under this path")
    ap.add_argument("--profile", default=None,
                    help="wrap one timed run per config in "
                         "jax.profiler.trace writing to this directory")
    args = ap.parse_args(argv)

    ns = [int(x) for x in args.ns.split(",") if x]
    names = [s for s in args.scenarios.split(",") if s]
    if args.fused and args.algo != "mp":
        print("# --fused applies to --algo mp only; ignoring", flush=True)
        args.fused = False
    print("name,us,derived", flush=True)
    runs = []
    failures = []
    worst_rss = 0.0
    worst_ratio = None
    worst_fused = None
    worst_overhead = None
    used_shards = 0
    for n in ns:
        batch = args.batch or max(1, n // 10)
        for name in names:
            r, _ = bench_one(n, args.k, args.p, name, args.rounds, batch,
                             algo=args.algo, repeats=args.repeats,
                             profile_dir=args.profile)
            r["name"] = f"network_sim/{args.algo}/{name}/n{n}"
            worst_rss = max(worst_rss, r["peak_rss_mb"])
            emit(r["name"], r["time_s"] * 1e6,
                 f"events/s={r['events_per_s']:.0f} "
                 f"rounds/s={r['rounds_per_s']:.1f} "
                 f"delivered={r['delivered']} dropped={r['dropped']} "
                 f"sparse_state_mb={r['sparse_state_mb']:.1f} "
                 f"dense_state_would_be_mb={r['dense_state_mb']:.0f} "
                 f"peak_rss_mb={r['peak_rss_mb']:.0f}")
            if args.overhead or args.run_dir:
                tr_row, tr = bench_one(n, args.k, args.p, name, args.rounds,
                                       batch, algo=args.algo,
                                       repeats=args.repeats,
                                       telemetry=TelemetryConfig(
                                           enabled=True))
                if args.overhead:
                    pct = 100.0 * (1.0 - tr_row["events_per_s"]
                                   / max(r["events_per_s"], 1e-9))
                    r["telemetry"] = {
                        "events_per_s": tr_row["events_per_s"],
                        "overhead_pct": pct,
                    }
                    worst_overhead = pct if worst_overhead is None \
                        else max(worst_overhead, pct)
                    emit(r["name"] + "/telemetry", tr_row["time_s"] * 1e6,
                         f"events/s={tr_row['events_per_s']:.0f} "
                         f"overhead_pct={pct:.1f}")
                if args.run_dir:
                    d = os.path.join(args.run_dir,
                                     f"{args.algo}-{name}-n{n}")
                    manifest = build_manifest(seed=0, extra={
                        "scenario": name, "n": n, "algo": args.algo,
                        "rounds": args.rounds, "batch": batch})
                    write_run(d, manifest, trace_rows(tr))
                    print(f"# wrote run dir {d}", flush=True)
            if args.fused:
                f_row, _ = bench_one(
                    n, args.k, args.p, name, args.rounds, batch,
                    algo=args.algo, repeats=args.repeats,
                    backend=ReproBackend.using(round_step="xla"))
                speedup = f_row["events_per_s"] / r["events_per_s"]
                for cnt in ("delivered", "dropped", "invalid"):
                    if f_row[cnt] != r[cnt]:
                        failures.append(
                            f"fused counter drift: {r['name']} {cnt} "
                            f"{f_row[cnt]} vs {r[cnt]} (the fused round "
                            f"must replay the identical scenario)")
                r["fused"] = {
                    "impl": "xla", "time_s": f_row["time_s"],
                    "events_per_s": f_row["events_per_s"],
                    "speedup_vs_default": speedup,
                }
                worst_fused = speedup if worst_fused is None \
                    else min(worst_fused, speedup)
                emit(r["name"] + "/fused", f_row["time_s"] * 1e6,
                     f"events/s={f_row['events_per_s']:.0f} "
                     f"speedup_vs_default={speedup:.2f}x")
            if args.sharded:
                s = bench_one_sharded(n, args.k, args.p, name, args.rounds,
                                      batch, args.shards, algo=args.algo,
                                      repeats=args.repeats)
                ratio = s["events_per_s"] / r["events_per_s"]
                s["ratio_vs_1dev"] = ratio
                r["sharded"] = s
                hb = s["halo_bytes_per_round"]
                if args.halo_max_int8_ratio and hb["f32"] > 0 \
                        and hb["int8"] > args.halo_max_int8_ratio * hb["f32"]:
                    failures.append(
                        f"halo codec regression: {r['name']} int8 wire "
                        f"bytes {hb['int8']} > "
                        f"{args.halo_max_int8_ratio:.2f}x f32 {hb['f32']}")
                worst_ratio = ratio if worst_ratio is None \
                    else min(worst_ratio, ratio)
                worst_rss = max(worst_rss, s["peak_rss_mb"])
                used_shards = s["n_shards"]
                emit(f"{r['name']}/sharded{s['n_shards']}",
                     s["time_s"] * 1e6,
                     f"events/s={s['events_per_s']:.0f} "
                     f"speedup_vs_1dev={ratio:.2f}x "
                     f"edge_cut={s['edge_cut']} halo={s['halo']} "
                     f"local_batch={s['local_batch']} "
                     f"overflow={s['overflow']} "
                     f"partition_s={s['part_s']:.2f} "
                     f"peak_rss_mb={s['peak_rss_mb']:.0f}")
            runs.append(r)
    budget_mb = 4096.0
    status = "OK" if worst_rss < budget_mb else "OVER"
    print(f"# peak_rss {worst_rss:.0f} MB vs budget {budget_mb:.0f} MB "
          f"-> {status}", flush=True)
    if worst_ratio is not None:
        print(f"# sharded speedup (min over runs) {worst_ratio:.2f}x on "
              f"{used_shards} devices ({os.cpu_count()} host cores)",
              flush=True)
    if worst_fused is not None:
        print(f"# fused round_step speedup (min over runs) "
              f"{worst_fused:.2f}x over the per-op sequence", flush=True)
        if args.fused_min_ratio and worst_fused < args.fused_min_ratio:
            failures.append(
                f"fused round_step speedup {worst_fused:.2f}x below the "
                f"--fused-min-ratio {args.fused_min_ratio:.2f}x target")
    if worst_overhead is not None:
        print(f"# telemetry overhead (max over runs) {worst_overhead:.1f}% "
              f"events/s", flush=True)

    report = {
        "meta": {
            "platform": jax.default_backend(),
            "jax": jax.__version__,
            "cores": os.cpu_count(),
            "algo": args.algo, "k": args.k, "p": args.p,
            "rounds": args.rounds, "batch": args.batch,
            "repeats": args.repeats,
            "ns": ns, "scenarios": names,
            "sharded": bool(args.sharded), "shards": used_shards or None,
            "fused": bool(args.fused),
            # cores is os.cpu_count() of THIS host: on CPU runners the
            # fake host devices time-share those cores, so ratio_vs_1dev
            # measures partition/collective overhead (not parallel
            # speedup) whenever shards > cores — compare ratios only
            # across runs with matching cores/shards
            "ratio_vs_1dev_caveat": (
                f"{used_shards or args.shards} shards on "
                f"{os.cpu_count()} host core(s); ratio_vs_1dev is not a "
                f"parallel-speedup claim when shards > cores"
            ) if args.sharded else None,
        },
        "runs": runs,
        "summary": {
            "peak_rss_mb": worst_rss,
            "rss_budget_mb": budget_mb,
            "rss_ok": worst_rss < budget_mb,
            "min_sharded_ratio": worst_ratio,
            "min_fused_speedup": worst_fused,
            "telemetry_overhead_pct": worst_overhead,
        },
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}", flush=True)

    if args.baseline:
        with open(args.baseline) as f:
            baseline = json.load(f)
        failures += compare_to_baseline(report, baseline)
    for fail in failures:
        print(f"BASELINE FAILURE: {fail}", flush=True)
    if failures:
        return 1
    if args.baseline:
        print(f"baseline gate OK vs {args.baseline}", flush=True)
    return 0 if worst_rss < budget_mb else 1


if __name__ == "__main__":
    raise SystemExit(main())

"""Paper Fig. 2 (left/middle): MP with vs without confidence values.

Sweeps the dataset-unbalancedness eps; reports mean L2 error of both
variants and the win ratio in favor of confidence values. Claims C3:
win ratio ~0.5 at eps=0, rising to ~0.85 at eps=1; error of the
with-confidence variant stays ~flat.
"""

from __future__ import annotations

import numpy as np

from repro.core import closed_form, solitary_mean, confidences_from_counts
from repro.data import mean_estimation_problem


def run(eps_values=(0.0, 0.25, 0.5, 0.75, 1.0), n_instances: int = 50,
        n_agents: int = 100, alpha: float = 0.99, seed: int = 0):
    rows = []
    for eps in eps_values:
        errs_c, errs_nc, wins = [], [], []
        for inst in range(n_instances):
            g, data, targets, c_true = mean_estimation_problem(
                n=n_agents, eps=eps, seed=seed + 1000 * inst + int(eps * 17))
            sol = np.asarray(solitary_mean(data))
            conf = np.asarray(confidences_from_counts(data.counts))
            with_c = np.asarray(closed_form(g, sol, conf, alpha))[:, 0]
            no_c = np.asarray(closed_form(g, sol, np.ones(g.n), alpha))[:, 0]
            e_c = float(np.mean((with_c - targets) ** 2))
            e_nc = float(np.mean((no_c - targets) ** 2))
            errs_c.append(e_c)
            errs_nc.append(e_nc)
            if abs(e_c - e_nc) < 1e-12:
                wins.append(0.5)          # tie (balanced data: C == I)
            else:
                wins.append(1.0 if e_c < e_nc else 0.0)
        rows.append({"eps": eps,
                     "l2_with_conf": float(np.mean(errs_c)),
                     "l2_no_conf": float(np.mean(errs_nc)),
                     "win_ratio": float(np.mean(wins))})
    return rows


def main(fast: bool = True):
    rows = run(n_instances=20 if fast else 1000,
               n_agents=100 if fast else 300)
    for r in rows:
        print(f"mean_estimation,eps={r['eps']:.2f},"
              f"l2_conf={r['l2_with_conf']:.4f},"
              f"l2_noconf={r['l2_no_conf']:.4f},win={r['win_ratio']:.2f}")
    return rows


if __name__ == "__main__":
    main(fast=False)

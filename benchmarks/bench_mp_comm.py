"""Paper Fig. 2 (right): synchronous vs asynchronous model propagation,
L2 error vs number of pairwise communications (claim C4: async matches the
sync trade-off without any synchronization)."""

from __future__ import annotations

import numpy as np

from repro.core import (closed_form, synchronous, async_gossip, solitary_mean,
                        confidences_from_counts)
from repro.data import mean_estimation_problem


def run(n_agents: int = 100, alpha: float = 0.99, seed: int = 0,
        n_async_runs: int = 5, ticks: int = 4000):
    g, data, targets, _ = mean_estimation_problem(n=n_agents, eps=1.0,
                                                  seed=seed)
    sol = np.asarray(solitary_mean(data))
    conf = np.asarray(confidences_from_counts(data.counts))
    n_edges = len(g.edges())

    rows = []
    # synchronous: one iteration = 2|E| pairwise communications
    for steps in (1, 2, 4, 8, 16):
        th = np.asarray(synchronous(g, sol, conf, alpha, steps=steps))[:, 0]
        rows.append({"algo": "sync", "comms": 2 * n_edges * steps,
                     "l2": float(np.mean((th - targets) ** 2))})
    # asynchronous: one tick = 2 communications; average over runs
    errs = None
    for r in range(n_async_runs):
        tr = async_gossip(g, sol, conf, alpha, steps=ticks, seed=seed + r,
                          record_every=max(ticks // 20, 1))
        e = np.mean((tr.theta_hist[:, :, 0] - targets[None]) ** 2, axis=1)
        errs = e if errs is None else errs + e
        comms = tr.comms_hist
    errs = errs / n_async_runs
    for c, e in zip(comms, errs):
        rows.append({"algo": "async", "comms": int(c), "l2": float(e)})
    # optimum for reference
    star = np.asarray(closed_form(g, sol, conf, alpha))[:, 0]
    rows.append({"algo": "optimal", "comms": -1,
                 "l2": float(np.mean((star - targets) ** 2))})
    return rows


def main(fast: bool = True):
    rows = run(n_agents=60 if fast else 300,
               ticks=2000 if fast else 20000,
               n_async_runs=3 if fast else 100)
    for r in rows:
        print(f"mp_comm,algo={r['algo']},comms={r['comms']},l2={r['l2']:.4f}")
    return rows


if __name__ == "__main__":
    main(fast=False)

"""Benchmark harness entry point (deliverable d).

One module per paper table/figure (DESIGN.md §9):
  Fig 2 left/middle -> bench_mean_estimation     Fig 2 right -> bench_mp_comm
  Fig 3 left/middle -> bench_linclass            Fig 3 right -> bench_cl_comm
  Fig 5 (App. E)    -> bench_scalability
  kernels           -> bench_kernels             §Roofline   -> roofline

``python -m benchmarks.run`` prints ``name,us_per_call,derived`` CSV rows
(fast settings); ``--full`` approaches the paper-scale settings.
"""

from __future__ import annotations

import argparse
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default="")
    args, _ = ap.parse_known_args()
    fast = not args.full

    from . import (bench_mean_estimation, bench_mp_comm, bench_linclass,
                   bench_cl_comm, bench_scalability, bench_kernels, roofline)
    suites = [
        ("mean_estimation", bench_mean_estimation.main),
        ("mp_comm", bench_mp_comm.main),
        ("linclass", bench_linclass.main),
        ("cl_comm", bench_cl_comm.main),
        ("scalability", bench_scalability.main),
        ("kernels", bench_kernels.main),
        ("roofline", roofline.main),
    ]
    failures = 0
    for name, fn in suites:
        if args.only and args.only != name:
            continue
        t0 = time.time()
        print(f"### {name}", flush=True)
        try:
            fn(fast=fast)
            print(f"{name},{(time.time()-t0)*1e6:.0f},ok", flush=True)
        except Exception:
            traceback.print_exc()
            print(f"{name},,FAILED", flush=True)
            failures += 1
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()

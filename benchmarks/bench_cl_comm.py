"""Paper Fig. 3 (right): async CL vs sync CL vs async MP — test accuracy vs
pairwise communications (claim C7: async CL matches sync CL; MP converges
~an order of magnitude faster and is a good warm start)."""

from __future__ import annotations

import numpy as np

from repro.core import (solitary_gd, confidences_from_counts, async_gossip,
                        async_admm, sync_admm)
from repro.data import linear_classification_problem, accuracy


def run(n=60, p=50, seed=0, alpha=0.8, mu=0.05, rho=1.0,
        sync_steps=40, async_ticks=4000):
    g, train, test, targets = linear_classification_problem(n=n, p=p,
                                                            seed=seed)
    sol = np.asarray(solitary_gd(train, "hinge", steps=250))
    conf = np.asarray(confidences_from_counts(train.counts))
    n_edges = len(g.edges())
    rows = []

    tr = sync_admm(g, train, mu, rho, "hinge", steps=sync_steps, k_steps=12,
                   lr=0.05, theta_sol=sol)
    for i in range(0, sync_steps, max(sync_steps // 10, 1)):
        rows.append({"algo": "cl_sync", "comms": 2 * n_edges * (i + 1),
                     "acc": float(np.mean(accuracy(tr.theta_hist[i], test)))})

    tra = async_admm(g, train, mu, rho, "hinge", steps=async_ticks,
                     k_steps=12, lr=0.05,
                     record_every=max(async_ticks // 10, 1), theta_sol=sol)
    for c, th in zip(tra.comms_hist, tra.theta_hist):
        rows.append({"algo": "cl_async", "comms": int(c),
                     "acc": float(np.mean(accuracy(th, test)))})

    trm = async_gossip(g, sol, conf, alpha, steps=async_ticks, seed=seed,
                       record_every=max(async_ticks // 10, 1))
    for c, th in zip(trm.comms_hist, trm.theta_hist):
        rows.append({"algo": "mp_async", "comms": int(c),
                     "acc": float(np.mean(accuracy(th, test)))})
    rows.append({"algo": "solitary", "comms": 0,
                 "acc": float(np.mean(accuracy(sol, test)))})
    return rows


def main(fast: bool = True):
    rows = run(n=40 if fast else 100, sync_steps=20 if fast else 60,
               async_ticks=1500 if fast else 10000)
    for r in rows:
        print(f"cl_comm,algo={r['algo']},comms={r['comms']},acc={r['acc']:.3f}")
    return rows


if __name__ == "__main__":
    main(fast=False)

"""Backend-dispatch benchmark: time every available implementation of the
MP-mix and ADMM-primal hot loops (plus the sparse gather-mix and the fused
``round_step`` gossip round at B = 64/512/4096 event batches) and write a
``BENCH_dispatch.json`` with per-backend timings and parity errors.

    PYTHONPATH=src python benchmarks/bench_dispatch.py            # full
    PYTHONPATH=src python benchmarks/bench_dispatch.py --smoke    # CI lane

``--smoke`` shrinks shapes and forces the Pallas implementations through
interpret mode so backend-parity regressions surface in CI even on CPU
runners (interpret timings are NOT perf numbers — the maxerr columns are
the point).  Off-TPU without ``--smoke``/``--interpret``, Pallas impls are
recorded as skipped.

``--baseline BENCH_dispatch.baseline.json`` turns the run into a CI gate:
it fails on parity drift (any op/impl maxerr above 1e-5 AND 10x its
baseline) and on >2x per-op slowdown.  Slowdowns are normalized by the
median slowdown across all timed (op, impl) pairs, so a uniformly slower
runner doesn't trip the gate — only ops that regressed *relative to the
rest of the suite* do.  Pallas interpret timings are never gated (they are
validation artifacts, not perf numbers).  Refresh the committed baseline
with ``--smoke --out BENCH_dispatch.baseline.json`` when op timings shift
on purpose.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
from common import time_call  # noqa: E402

from repro.kernels import dispatch  # noqa: E402
from repro.kernels.dispatch import ReproBackend, resolve  # noqa: E402


def _time_loop(fn, repeats: int) -> float:
    """Shared min-of-repeats estimator, synced through the device queue."""
    return time_call(fn, repeats=repeats, sync=jax.block_until_ready)


def _runnable_impls(op: str, interpret: bool):
    """(impl, backend, note) triples for every registered implementation."""
    out = []
    for name in dispatch.implementations(op):
        backend = ReproBackend.using(
            interpret=interpret or None, **{op: name})
        if dispatch.available(op, name, interpret=interpret):
            out.append((name, backend, None))
        else:
            out.append((name, None,
                        "needs TPU (or --interpret/--smoke for the slow "
                        "interpret mode)"))
    return out


def _maxerr(got, want) -> float:
    ga = got if isinstance(got, (tuple, list)) else (got,)
    wa = want if isinstance(want, (tuple, list)) else (want,)
    return max(float(jnp.abs(jnp.asarray(g, jnp.float32)
                             - jnp.asarray(w, jnp.float32)).max())
               for g, w in zip(ga, wa))


def bench_mix(smoke: bool, interpret: bool, repeats: int) -> dict:
    n, D = (16, 2048) if smoke else (32, 65536)
    loops = 5 if smoke else 50
    k1, k2, k3, k4 = jax.random.split(jax.random.PRNGKey(0), 4)
    theta = jax.random.normal(k1, (n, D))
    sol = jax.random.normal(k2, (n, D))
    A = jax.random.uniform(k3, (n, n)) / n
    b = jax.random.uniform(k4, (n,))
    want = resolve("mix", ReproBackend.using(mix="reference"))(
        theta, sol, A, b)
    impls = {}
    for name, backend, skip in _runnable_impls("mix", interpret):
        if skip:
            impls[name] = {"skipped": skip}
            continue
        mix = resolve("mix", backend)
        loop = jax.jit(lambda th, m=mix: jax.lax.scan(
            lambda t, _: (m(t, sol, A, b), None), th, None, length=loops)[0])
        impls[name] = {
            "maxerr": _maxerr(mix(theta, sol, A, b), want),
            "us_per_loop": _time_loop(lambda: loop(theta), repeats),
            "loop_iters": loops,
        }
    return {"shape": {"n": n, "D": D}, "impls": impls}


def bench_sparse_mix(smoke: bool, interpret: bool, repeats: int) -> dict:
    n, k, p = (256, 8, 64) if smoke else (4096, 16, 256)
    loops = 5 if smoke else 50
    rng = np.random.default_rng(0)
    idx = jnp.asarray(rng.integers(0, n, (n, k)), jnp.int32)
    w = jnp.asarray(rng.uniform(0, 1, (n, k)), jnp.float32)
    b = jnp.asarray(rng.uniform(0, 1, n), jnp.float32)
    table = jnp.asarray(rng.standard_normal((n, p)), jnp.float32)
    sol = jnp.asarray(rng.standard_normal((n, p)), jnp.float32)
    want = resolve("sparse_mix", ReproBackend.using(
        sparse_mix="reference"))(table, idx, w, b, sol)
    impls = {}
    for name, backend, skip in _runnable_impls("sparse_mix", interpret):
        if skip:
            impls[name] = {"skipped": skip}
            continue
        mix = resolve("sparse_mix", backend)
        loop = jax.jit(lambda t, m=mix: jax.lax.scan(
            lambda tt, _: (m(tt, idx, w, b, sol), None), t, None,
            length=loops)[0])
        impls[name] = {
            "maxerr": _maxerr(mix(table, idx, w, b, sol), want),
            "us_per_loop": _time_loop(lambda: loop(table), repeats),
            "loop_iters": loops,
        }
    return {"shape": {"n": n, "k": k, "p": p}, "impls": impls}


def bench_admm_primal(smoke: bool, interpret: bool, repeats: int) -> dict:
    n, k, p = (32, 8, 32) if smoke else (256, 16, 512)
    loops = 5 if smoke else 50
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.uniform(0.1, 1, (n, k)), jnp.float32)
    live = jnp.asarray(rng.uniform(size=(n, k)) < 0.8)
    zo, zn, lo, ln = (jnp.asarray(rng.standard_normal((n, k, p)), jnp.float32)
                      for _ in range(4))
    D = jnp.asarray(rng.uniform(1, 4, n), jnp.float32)
    m = jnp.asarray(rng.integers(1, 100, n), jnp.float32)
    sx = jnp.asarray(rng.standard_normal((n, p)), jnp.float32)
    mu, rho = 0.05, 1.0

    def batched(primal, name=""):
        if name.endswith("_sharded"):
            # sharded impls take the stacked batched form natively
            return lambda *a: primal(*a, mu, rho)
        return jax.vmap(lambda w_, l_, a, b_, c_, d_, D_, m_, s_:
                        primal(w_, l_, a, b_, c_, d_, D_, m_, s_, mu, rho))

    want = batched(resolve("admm_primal", ReproBackend.using(
        admm_primal="reference")))(w, live, zo, zn, lo, ln, D, m, sx)
    impls = {}
    for name, backend, skip in _runnable_impls("admm_primal", interpret):
        if skip:
            impls[name] = {"skipped": skip}
            continue
        primal = batched(resolve("admm_primal", backend), name)

        def body(carry, _, primal=primal):
            zo_, zn_ = carry
            theta_l, theta_js = primal(w, live, zo_, zn_, lo, ln, D, m, sx)
            # feed the solution back so the loop has a real dependency chain
            return (0.9 * zo_ + 0.1 * theta_js,
                    0.9 * zn_ + 0.1 * theta_l[:, None, :]), None

        loop = jax.jit(lambda z, body=body: jax.lax.scan(
            body, z, None, length=loops)[0][0])
        impls[name] = {
            "maxerr": _maxerr(primal(w, live, zo, zn, lo, ln, D, m, sx),
                              want),
            "us_per_loop": _time_loop(lambda: loop((zo, zn)), repeats),
            "loop_iters": loops,
        }
    return {"shape": {"n": n, "k": k, "p": p}, "impls": impls}


def bench_admm_edge(smoke: bool, interpret: bool, repeats: int) -> dict:
    # smoke shape sized so the timed loop is comparable to the other ops'
    # (sub-100us loops are pure dispatch noise and destabilize the gate)
    E, p = (512, 64) if smoke else (4096, 256)
    loops = 5 if smoke else 50
    rng = np.random.default_rng(2)
    args = tuple(jnp.asarray(rng.standard_normal((E, p)), jnp.float32)
                 for _ in range(8))
    rho = 1.3
    want = resolve("admm_edge", ReproBackend.using(
        admm_edge="reference"))(*args, rho=rho)
    impls = {}
    for name, backend, skip in _runnable_impls("admm_edge", interpret):
        if skip:
            impls[name] = {"skipped": skip}
            continue
        edge = resolve("admm_edge", backend)

        def body(carry, _, edge=edge):
            t_ii, l_own_i = carry
            out = edge(t_ii, *args[1:4], l_own_i, *args[5:], rho=rho)
            # feed z_i / the updated dual back for a real dependency chain
            return (0.9 * t_ii + 0.1 * out[0], out[2]), None

        loop = jax.jit(lambda c, body=body: jax.lax.scan(
            body, c, None, length=loops)[0][0])
        impls[name] = {
            "maxerr": _maxerr(edge(*args, rho=rho), want),
            "us_per_loop": _time_loop(lambda: loop((args[0], args[4])),
                                      repeats),
            "loop_iters": loops,
        }
    return {"shape": {"E": E, "p": p}, "impls": impls}


def bench_edge_reweight(smoke: bool, interpret: bool, repeats: int) -> dict:
    n, k = (512, 8) if smoke else (8192, 16)
    loops = 5 if smoke else 50
    rng = np.random.default_rng(3)
    live = jnp.asarray(rng.uniform(size=(n, k)) < 0.8)
    w0 = rng.uniform(0, 1, (n, k)) * np.asarray(live)
    w0 = jnp.asarray(w0 / np.maximum(w0.sum(axis=1, keepdims=True), 1e-9),
                     jnp.float32)
    d = jnp.asarray(rng.uniform(0, 4, (n, k)), jnp.float32)
    eta, lam = 0.3, 1.0
    want = resolve("edge_reweight", ReproBackend.using(
        edge_reweight="reference"))(d, w0, live, eta=eta, lam=lam)
    impls = {}
    for name, backend, skip in _runnable_impls("edge_reweight", interpret):
        if skip:
            impls[name] = {"skipped": skip}
            continue
        rw = resolve("edge_reweight", backend)

        def body(w, _, rw=rw):
            # feed the learned weights back for a real dependency chain
            return rw(d, w, live, eta=eta, lam=lam), None

        loop = jax.jit(lambda w, body=body: jax.lax.scan(
            body, w, None, length=loops)[0])
        impls[name] = {
            "maxerr": _maxerr(rw(d, w0, live, eta=eta, lam=lam), want),
            "us_per_loop": _time_loop(lambda: loop(w0), repeats),
            "loop_iters": loops,
        }
    return {"shape": {"n": n, "k": k}, "impls": impls}


def bench_round_step(smoke: bool, interpret: bool, repeats: int,
                     batch: int) -> dict:
    """The fused MP gossip round (DESIGN.md §15) at one event-batch size.

    The timed loop prefetches each round's operands from the carried flat
    slot table (``round_prefetch``) and feeds ``(theta, Ke, got_ever)``
    back through the same event batch — exactly the scenario engines' scan
    carry — so us_per_loop / loop_iters is the per-round cost the engine
    pays at this batch size.
    """
    from repro.kernels.dispatch import round_prefetch, round_scales
    n, k, p = (2048, 8, 16) if smoke else (10000, 8, 32)
    loops = 5 if smoke else 50
    rng = np.random.default_rng(4)
    f32 = jnp.float32
    K = jnp.asarray(rng.standard_normal((n, k, p)), f32)
    Ke = dispatch.encode_slots(K)
    nbr_p = jnp.asarray(rng.uniform(0, 1, (n, k)), f32)
    theta = jnp.asarray(rng.standard_normal((n, p)), f32)
    got0 = jnp.zeros((n,), bool)
    base = jnp.asarray(rng.standard_normal((n, p)), f32)
    c = jnp.asarray(rng.uniform(0.1, 1, n), f32)
    a_w = round_scales(nbr_p, c, alpha=0.9)
    # collision-free targets: duplicate winners are realization-dependent
    # (see round_fuse docstring), which would read as parity drift here
    codes = rng.choice(n * k, size=2 * batch, replace=False)
    ev = (jnp.asarray(codes[batch:] // k, jnp.int32),
          jnp.asarray(codes[:batch] // k, jnp.int32),
          jnp.asarray(codes[batch:] % k, jnp.int32),
          jnp.asarray(codes[:batch] % k, jnp.int32),
          jnp.asarray(rng.uniform(size=batch) < 0.8),
          jnp.asarray(rng.uniform(size=batch) < 0.8),
          jnp.asarray(rng.uniform(size=batch) < 0.2),
          jnp.asarray(rng.uniform(size=batch) < 0.2))
    ops0 = round_prefetch(theta, theta, Ke, *ev)
    want = resolve("round_step", ReproBackend.using(
        round_step="reference"))(theta, Ke, got0, *ops0, base, a_w)
    impls = {}
    for name, backend, skip in _runnable_impls("round_step", interpret):
        if skip is None and name == "pallas" and batch > 512 \
                and jax.default_backend() != "tpu":
            # the interpret-mode event loop is ~seconds per round here;
            # parity is already pinned at B <= 512 and in tests/
            skip = "interpret mode too slow at this batch (parity covered " \
                   "at B <= 512)"
        if skip:
            impls[name] = {"skipped": skip}
            continue
        step = resolve("round_step", backend)

        def body(carry, _, step=step):
            th, ke, go = carry
            th2, ke2, go2, _ = step(th, ke, go,
                                    *round_prefetch(th, th, ke, *ev),
                                    base, a_w)
            return (th2, ke2, go2), None

        loop = jax.jit(lambda s0, body=body: jax.lax.scan(
            body, s0, None, length=loops)[0][0])
        impls[name] = {
            "maxerr": _maxerr(step(theta, Ke, got0, *ops0, base, a_w)[:2],
                              want[:2]),
            "us_per_loop": _time_loop(lambda: loop((theta, Ke, got0)),
                                      repeats),
            "loop_iters": loops,
        }
    return {"shape": {"n": n, "k": k, "p": p, "B": batch}, "impls": impls}


PARITY_FLOOR = 1e-5          # drift below this is float noise, never gated
MAX_SLOWDOWN = 2.0           # vs baseline, after machine-speed normalization


def _is_gated_timing(op: str, impl: str) -> bool:
    """Pallas interpret-mode timings are validation artifacts, not perf."""
    import re

    from repro.kernels.dispatch import _REGISTRY
    op = re.sub(r"_b\d+$", "", op)     # round_step_b512 -> round_step
    entry = _REGISTRY.get(op, {}).get(impl)
    return entry is not None and not entry.pallas


def compare_to_baseline(report: dict, baseline: dict) -> list:
    """Gate failures of ``report`` vs a committed baseline (see module
    docstring for the rules).  Returns human-readable failure strings."""
    failures = []
    pairs = []               # (op, impl, cur_us, base_us)
    for op, entry in report["ops"].items():
        base_op = baseline.get("ops", {}).get(op, {}).get("impls", {})
        for impl, row in entry["impls"].items():
            base = base_op.get(impl)
            if "maxerr" not in row or base is None or "maxerr" not in base:
                continue
            if row["maxerr"] > max(10.0 * base["maxerr"], PARITY_FLOOR):
                failures.append(
                    f"parity drift: {op}/{impl} maxerr {row['maxerr']:.2e} "
                    f"vs baseline {base['maxerr']:.2e}")
            if _is_gated_timing(op, impl):
                pairs.append((op, impl, row["us_per_loop"],
                              base["us_per_loop"]))
    if pairs:
        slowdowns = sorted(c / max(b, 1e-9) for _, _, c, b in pairs)
        machine = slowdowns[len(slowdowns) // 2]        # median = runner speed
        for op, impl, cur, base in pairs:
            rel = (cur / max(base, 1e-9)) / max(machine, 1e-9)
            if rel > MAX_SLOWDOWN:
                failures.append(
                    f"slowdown: {op}/{impl} {cur:.1f}us vs baseline "
                    f"{base:.1f}us ({rel:.2f}x the suite median drift)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + interpret-mode Pallas (CI parity lane)")
    ap.add_argument("--interpret", action="store_true",
                    help="include Pallas impls via interpret mode off-TPU")
    ap.add_argument("--repeats", type=int, default=None)
    ap.add_argument("--out", default="BENCH_dispatch.json")
    ap.add_argument("--baseline", default=None,
                    help="committed baseline JSON to gate against (fail on "
                         "parity drift or >2x normalized slowdown)")
    ap.add_argument("--profile", default=None,
                    help="wrap the op sweep in jax.profiler.trace writing "
                         "to this directory (kernels are attributable via "
                         "the repro/<op>/<impl> named scopes)")
    args = ap.parse_args(argv)
    # gating needs stable medians; plain smoke stays cheap
    repeats = args.repeats or (5 if args.baseline or not args.smoke else 1)
    interpret = args.smoke or args.interpret

    def sweep():
        return {
            "mix": bench_mix(args.smoke, interpret, repeats),
            "sparse_mix": bench_sparse_mix(args.smoke, interpret, repeats),
            "admm_primal": bench_admm_primal(args.smoke, interpret, repeats),
            "admm_edge": bench_admm_edge(args.smoke, interpret, repeats),
            "edge_reweight": bench_edge_reweight(args.smoke, interpret,
                                                 repeats),
            # the fused gossip round across engine-realistic batch sizes
            # (n // 10 wake-ups per round at n = 640 / 5k / 40k)
            **{f"round_step_b{B}": bench_round_step(args.smoke, interpret,
                                                    repeats, B)
               for B in (64, 512, 4096)},
        }

    if args.profile:
        with jax.profiler.trace(args.profile):
            ops = sweep()
    else:
        ops = sweep()
    report = {
        "meta": {
            "platform": jax.default_backend(),
            "jax": jax.__version__,
            "smoke": args.smoke,
            "interpret": interpret,
            "repeats": repeats,
        },
        "ops": ops,
    }

    worst = 0.0
    for op, entry in report["ops"].items():
        for impl, row in entry["impls"].items():
            if "maxerr" in row:
                worst = max(worst, row["maxerr"])
                print(f"bench_dispatch,{op},{impl},"
                      f"us={row['us_per_loop']:.1f},maxerr={row['maxerr']:.2e}",
                      flush=True)
            else:
                print(f"bench_dispatch,{op},{impl},skipped", flush=True)
    report["meta"]["worst_maxerr"] = worst

    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}")
    if worst > 1e-4:
        print(f"PARITY FAILURE: worst maxerr {worst:.2e} > 1e-4")
        return 1
    if args.baseline:
        with open(args.baseline) as f:
            baseline = json.load(f)
        failures = compare_to_baseline(report, baseline)
        for fail in failures:
            print(f"BASELINE FAILURE: {fail}")
        if failures:
            return 1
        print(f"baseline gate OK vs {args.baseline}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

"""Paper Fig. 5 (App. E): number of pairwise communications needed to reach
90% of the optimal accuracy vs network size n (kNN graph) — claim C8:
scales ~linearly with n."""

from __future__ import annotations

import numpy as np

from repro.core import (closed_form, solitary_gd, confidences_from_counts,
                        async_gossip)
from repro.data import linear_classification_problem, accuracy


def comms_to_90(n, p=50, seed=0, alpha=0.8, knn=10, max_ticks=20000):
    g, train, test, _ = linear_classification_problem(n=n, p=p, seed=seed,
                                                      knn=knn)
    sol = np.asarray(solitary_gd(train, "hinge", steps=200))
    conf = np.asarray(confidences_from_counts(train.counts))
    star = np.asarray(closed_form(g, sol, conf, alpha))
    target = 0.9 * float(np.mean(accuracy(star, test)))
    tr = async_gossip(g, sol, conf, alpha, steps=max_ticks, seed=seed,
                      record_every=max(max_ticks // 40, 1))
    for c, th in zip(tr.comms_hist, tr.theta_hist):
        if float(np.mean(accuracy(th, test))) >= target:
            return int(c)
    return -1


def run(sizes=(100, 200, 400), seed=0, max_ticks=20000):
    rows = []
    for n in sizes:
        c = comms_to_90(n, seed=seed, max_ticks=max_ticks * max(n // 100, 1))
        rows.append({"n": n, "comms_to_90": c})
    return rows


def main(fast: bool = True):
    rows = run(sizes=(50, 100, 200) if fast else (100, 200, 400, 700, 1000),
               max_ticks=8000 if fast else 30000)
    for r in rows:
        print(f"scalability,n={r['n']},comms_to_90={r['comms_to_90']}")
    # linearity check: comms/n roughly constant
    ratios = [r["comms_to_90"] / r["n"] for r in rows if r["comms_to_90"] > 0]
    if ratios:
        print(f"scalability,ratio_spread={max(ratios)/max(min(ratios),1e-9):.2f}")
    return rows


if __name__ == "__main__":
    main(fast=False)

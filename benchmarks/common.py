"""Shared benchmark utilities: timing + CSV emission.

Every benchmark in this directory times through :func:`time_call` so the
estimator is identical suite-wide: ``warmup`` untimed calls (compile +
cache fill), then the **minimum** wall time over ``repeats`` timed calls.
Min, not median: scheduler noise only ever adds time, so the minimum is
the stable estimator — which is what the baseline gates need on shared CI
runners.  Pass ``sync=jax.block_until_ready`` for JAX callables so the
timed region covers device execution, not just dispatch.
"""

from __future__ import annotations

import time
from typing import Callable, Optional


def time_call(fn: Callable, *args, repeats: int = 3, warmup: int = 1,
              sync: Optional[Callable] = None, **kw) -> float:
    """Min wall time of ``fn(*args, **kw)`` in microseconds.

    ``sync`` (e.g. ``jax.block_until_ready``) is applied to the return
    value inside the timed region so asynchronous dispatch is charged to
    the call that issued it.
    """
    for _ in range(max(0, warmup)):
        out = fn(*args, **kw)
        if sync is not None:
            sync(out)
    best = float("inf")
    for _ in range(max(1, repeats)):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        if sync is not None:
            sync(out)
        best = min(best, (time.perf_counter() - t0) * 1e6)
    return best


def emit(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}", flush=True)

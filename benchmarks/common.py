"""Shared benchmark utilities: timing + CSV emission."""

from __future__ import annotations

import time
from typing import Callable


def time_call(fn: Callable, *args, repeats: int = 3, **kw) -> float:
    """Median wall time in microseconds (after one warmup)."""
    fn(*args, **kw)
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args, **kw)
        ts.append((time.perf_counter() - t0) * 1e6)
    ts.sort()
    return ts[len(ts) // 2]


def emit(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}", flush=True)

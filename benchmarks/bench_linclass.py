"""Paper Fig. 3 (left/middle): collaborative linear classification.

Left: test accuracy of solitary / consensus / MP / CL vs feature dimension p.
Middle: accuracy vs local training-set size at p=50.
Claims C5 (CL > MP > solitary >> consensus) and C6 (CL equalizes accuracy
across training sizes).
"""

from __future__ import annotations

import numpy as np

from repro.core import (closed_form, solitary_gd, confidences_from_counts,
                        consensus_model, sync_admm)
from repro.data import linear_classification_problem, accuracy


def evaluate(n=100, p=50, seed=0, alpha=0.8, mu=0.05, rho=1.0,
             admm_steps=60):
    g, train, test, targets = linear_classification_problem(n=n, p=p,
                                                            seed=seed)
    sol = np.asarray(solitary_gd(train, "hinge", steps=250))
    conf = np.asarray(confidences_from_counts(train.counts))
    mp = np.asarray(closed_form(g, sol, conf, alpha))
    cons = np.tile(np.asarray(consensus_model(train, "hinge", steps=300)),
                   (n, 1))
    cl = np.asarray(sync_admm(g, train, mu, rho, "hinge", steps=admm_steps,
                              k_steps=12, lr=0.05, theta_sol=sol
                              ).theta_hist[-1])
    out = {}
    for name, th in (("solitary", sol), ("consensus", cons), ("mp", mp),
                     ("cl", cl)):
        out[name] = accuracy(th, test)
    counts = np.asarray(train.counts)
    return out, counts


def run_dim_sweep(p_values=(2, 20, 50, 100), n=100, n_instances=3, seed=0,
                  admm_steps=60):
    rows = []
    for p in p_values:
        accs = {k: [] for k in ("solitary", "consensus", "mp", "cl")}
        for i in range(n_instances):
            out, _ = evaluate(n=n, p=p, seed=seed + 31 * i + p,
                              admm_steps=admm_steps)
            for k, v in out.items():
                accs[k].append(float(np.mean(v)))
        rows.append({"p": p, **{k: float(np.mean(v))
                                for k, v in accs.items()}})
    return rows


def run_size_profile(n=100, p=50, n_instances=3, seed=0, admm_steps=60):
    """Accuracy vs m_i buckets (1-5, 6-10, 11-15, 16-20)."""
    buckets = [(1, 5), (6, 10), (11, 15), (16, 20)]
    sums = {k: np.zeros(len(buckets)) for k in
            ("solitary", "consensus", "mp", "cl")}
    cnts = np.zeros(len(buckets))
    for i in range(n_instances):
        out, counts = evaluate(n=n, p=p, seed=seed + 77 * i,
                               admm_steps=admm_steps)
        for bi, (lo, hi) in enumerate(buckets):
            m = (counts >= lo) & (counts <= hi)
            if m.sum():
                cnts[bi] += 1
                for k in sums:
                    sums[k][bi] += float(np.mean(out[k][m]))
    rows = []
    for bi, (lo, hi) in enumerate(buckets):
        d = max(cnts[bi], 1)
        rows.append({"bucket": f"{lo}-{hi}",
                     **{k: float(sums[k][bi] / d) for k in sums}})
    return rows


def main(fast: bool = True):
    kw = dict(n=40 if fast else 100, n_instances=2 if fast else 10,
              admm_steps=40 if fast else 120)
    rows = run_dim_sweep(p_values=(2, 20, 50) if fast else (2, 20, 50, 100),
                         n=kw["n"], n_instances=kw["n_instances"],
                         admm_steps=kw["admm_steps"])
    for r in rows:
        print(f"linclass_dim,p={r['p']},sol={r['solitary']:.3f},"
              f"cons={r['consensus']:.3f},mp={r['mp']:.3f},cl={r['cl']:.3f}")
    rows2 = run_size_profile(n=kw["n"], n_instances=kw["n_instances"],
                             admm_steps=kw["admm_steps"])
    for r in rows2:
        print(f"linclass_size,m={r['bucket']},sol={r['solitary']:.3f},"
              f"cons={r['consensus']:.3f},mp={r['mp']:.3f},cl={r['cl']:.3f}")
    return rows, rows2


if __name__ == "__main__":
    main(fast=False)

"""Kernel micro-benchmarks: Pallas (interpret) vs jnp oracle — correctness
columns + call timing. (Wall-times on CPU interpret mode are NOT TPU perf;
the derived column reports max |err| vs the oracle.)"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from .common import time_call, emit


def main(fast: bool = True):
    key = jax.random.PRNGKey(0)

    n, D = 16, 4096 if fast else 65536
    k1, k2, k3, k4 = jax.random.split(key, 4)
    theta = jax.random.normal(k1, (n, D))
    sol = jax.random.normal(k2, (n, D))
    A = jax.random.uniform(k3, (n, n)) / n
    b = jax.random.uniform(k4, (n,))
    got = ops.graph_mix(theta, sol, A, b)
    want = ref.graph_mix(theta, sol, A, b)
    err = float(jnp.abs(got - want).max())
    us = time_call(ops.graph_mix, theta, sol, A, b,
                   sync=jax.block_until_ready)
    emit("kernel_graph_mix", us, f"maxerr={err:.2e}")

    B, S, H, hd = 1, 256, 2, 64
    q = jax.random.normal(k1, (B, S, H, hd))
    kk = jax.random.normal(k2, (B, S, H, hd))
    v = jax.random.normal(k3, (B, S, H, hd))
    got = ops.flash_attention(q, kk, v, block_q=64, block_k=64)
    want = ref.flash_attention(q, kk, v)
    err = float(jnp.abs(got - want).max())
    us = time_call(ops.flash_attention, q, kk, v, block_q=64, block_k=64,
                   sync=jax.block_until_ready)
    emit("kernel_flash_attention", us, f"maxerr={err:.2e}")

    E, p = 16, 2048
    args = [jax.random.normal(k, (E, p)) for k in jax.random.split(key, 8)]
    got = ops.admm_edge_update(*args, rho=1.5)
    want = ref.admm_edge_update(*args, rho=1.5)
    err = max(float(jnp.abs(g - w).max()) for g, w in zip(got, want))
    us = time_call(ops.admm_edge_update, *args, rho=1.5,
                   sync=jax.block_until_ready)
    emit("kernel_admm_update", us, f"maxerr={err:.2e}")


if __name__ == "__main__":
    main(fast=False)

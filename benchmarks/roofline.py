"""§Roofline report generator: reads results/dryrun_*.json and prints the
per-(arch x shape) table with the three roofline terms, dominant bottleneck,
MODEL_FLOPS ratio, and a what-would-move-it-down note."""

from __future__ import annotations

import json
import os
import sys

NOTES = {
    "compute": "more chips or lower-precision matmuls; compute-bound is the "
               "good end state",
    "memory": "fuse/attend in VMEM (flash), shard activations (seq-parallel),"
              " cut optimizer bytes (bf16 moments already on)",
    "collective": "matching-gossip schedule instead of all-gather, chunked "
                  "coupling, coupling every k steps, bf16 wire dtype",
}


def load(path="results/dryrun_1pod.json"):
    with open(path) as f:
        return json.load(f)


def table(records, file=sys.stdout):
    w = file.write
    w("arch,shape,devices,compute_s,memory_s,collective_s,dominant,"
      "model_gflops,hlo_gflops_total,useful_ratio,fits_hbm\n")
    for r in sorted(records, key=lambda r: (r.get("arch", ""),
                                            r.get("shape", ""))):
        if not r.get("ok"):
            w(f"{r.get('arch')},{r.get('shape')},,,,,FAILED:"
              f"{r.get('error','?')},,,,\n")
            continue
        if "roofline" not in r:   # compile-proof-only record (multi-pod)
            gb = (r.get("argument_size_in_bytes", 0)
                  + r.get("temp_size_in_bytes", 0)) / 1e9
            w(f"{r.get('arch')},{r.get('shape')},{r.get('n_devices')},"
              f",,,compile-ok,,,,{'yes' if gb <= 16 else f'NO({gb:.1f}GB)'}\n")
            continue
        roof = r["roofline"]
        hbm_need = (r.get("argument_size_in_bytes", 0)
                    + r.get("temp_size_in_bytes", 0)) / 1e9
        fits = "yes" if hbm_need <= 16.0 else f"NO({hbm_need:.1f}GB)"
        w(f"{r['arch']},{r['shape']},{r['n_devices']},"
          f"{roof['compute_s']:.4f},{roof['memory_s']:.4f},"
          f"{roof['collective_s']:.4f},{roof['dominant']},"
          f"{r.get('model_flops', 0)/1e9:.0f},"
          f"{r.get('cost_flops', 0)*r['n_devices']/1e9:.0f},"
          f"{r.get('useful_flop_ratio', 0):.3f},{fits}\n")


def main(fast: bool = True):
    for path in ("results/dryrun_1pod.json", "results/dryrun_2pod.json"):
        if os.path.exists(path):
            print(f"== {path} ==")
            table(load(path))
        else:
            print(f"roofline,{path},missing (run repro.launch.dryrun)")


if __name__ == "__main__":
    main(fast=False)

"""Gossip-backed personalization-service benchmark (DESIGN.md §16).

Runs MP gossip under faults with a Poisson-ish inference-request stream
interleaved, then times the serving plane in isolation: per record chunk
the benchmark *commits* the chunk's snapshot to the agent-state store,
*invalidates* the mixed-model cache at exactly the agents the chunk's
deliveries rewrote, and *serves* every request of the chunk by batched
decode.  The scan artifacts (theta history, replayed staleness counters,
dirty sets, request chunks) are precomputed once so the timed region is
pure serving — commit + invalidate + cache lookup + batched predict —
and requests/s measures the read path, not gossip.

    PYTHONPATH=src python benchmarks/bench_serve_collab.py \
        --ns 1000,10000 --rounds 200 --rate 50

Every run first proves the acceptance property in-bench: the gossip
trajectory with serving attached is bit-for-bit identical to the
serve-free run (reads never touch the scan).  Besides the CSV rows
(name,us,derived — same convention as the other benchmarks), every
invocation writes a machine-readable ``BENCH_serve_collab.json``
(``--out``) with per-run requests/s, cache hit rate, p50/p99 served
staleness, and the deterministic service counters.  ``--baseline
BENCH_serve_collab.baseline.json`` turns the run into a CI gate: it
fails on >2x per-run requests/s regression after normalizing by the
median slowdown across all runs (so a uniformly slower runner doesn't
trip it) and on any drift in the deterministic counters (requests,
hits, misses, invalidations) when the invocation shape matches the
baseline's.  Refresh the committed baseline with the CI invocation plus
``--out BENCH_serve_collab.baseline.json``.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import resource
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, __file__.rsplit("/", 1)[0])
from common import emit, time_call  # noqa: E402

from repro.serve import AgentStateStore, CollabServeEngine  # noqa: E402
from repro.simulate import (NetworkConditions, ScenarioSpec,  # noqa: E402
                            precompute_event_stream,
                            precompute_serve_stream,
                            random_geometric_topology, run_scenario,
                            serve_chunk_requests)
from repro.core.sparse import record_chunks  # noqa: E402
from repro.telemetry.metrics import (stream_dirty_chunks,  # noqa: E402
                                     stream_staleness_chunks)

#: requests/s regression gate vs baseline, after machine-speed normalization
MAX_SLOWDOWN = 2.0

#: deterministic service counters that must match the baseline exactly
#: whenever the invocation shape does
COUNTERS = ("requests", "cache_hits", "cache_misses", "cache_invalidations")


def peak_rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def bench_one(n: int, k: int, p: int, rounds: int, rate: float, batch: int,
              serve_batch: int, seed: int = 0, repeats: int = 1):
    """One timed serve pass; returns (report row, failure strings)."""
    failures = []
    topo = random_geometric_topology(n, k=k, seed=seed)
    rng = np.random.default_rng(seed)
    theta_sol = rng.standard_normal((n, p)).astype(np.float32)
    c = rng.uniform(0.05, 1.0, n).astype(np.float32)
    record_every = max(1, rounds // 10)
    spec = ScenarioSpec(
        algo="mp", topology=topo, theta_sol=theta_sol, c=c, alpha=0.9,
        conditions=NetworkConditions(drop_prob=0.15, churn_rate=0.005),
        rounds=rounds, batch=batch, seed=seed, record_every=record_every,
        serve=precompute_serve_stream(n, rounds, rate=rate, seed=seed),
        serve_batch=serve_batch)

    tr = run_scenario(spec)                    # gossip + serve (warms jits)
    bare = run_scenario(dataclasses.replace(spec, serve=None))
    if not np.array_equal(tr.theta_hist, bare.theta_hist):
        failures.append(
            f"serve perturbation: n={n} gossip trajectory differs with "
            f"serving attached (reads must never touch the scan)")

    # precompute the scan artifacts the service consumes, so the timed
    # region is commit + invalidate + lookup + batched predict only
    record_every, n_rec = record_chunks(rounds, record_every)
    stream = precompute_event_stream(
        topo.device_tables(), jnp.asarray(topo.partition_halves()),
        spec.conditions, batch, seed, n_rec * record_every)
    dirty = stream_dirty_chunks(stream, n, n_rec, record_every)
    staleness = stream_staleness_chunks(stream, n, n_rec, record_every)
    requests = serve_chunk_requests(spec.serve, n_rec, record_every)
    hist = np.asarray(tr.theta_hist)

    def serve_pass():
        store = AgentStateStore(n, p)
        eng = CollabServeEngine(store, n, p, batch_size=serve_batch)
        for ci in range(n_rec):
            eng.commit((ci + 1) * record_every, hist[ci], staleness[ci],
                       dirty[ci])
            users, _ = requests[ci]
            if users.size:
                eng.serve(users)
        return eng.report()

    rep = serve_pass()                                          # warmup
    dt = time_call(serve_pass, repeats=repeats, warmup=0) / 1e6
    summ = rep.summary()
    if summ["requests"] != tr.serve.requests \
            or summ["cache_hits"] != tr.serve.hits:
        failures.append(
            f"replay drift: n={n} timed serve pass counters "
            f"{summ['requests']}/{summ['cache_hits']} vs in-run "
            f"{tr.serve.requests}/{tr.serve.hits}")
    row = {
        "n": n, "k_max": topo.k_max, "p": p, "rounds": rounds,
        "rate": rate, "batch": batch, "serve_batch": serve_batch,
        "chunks": n_rec, "time_s": dt,
        "requests_per_s": summ["requests"] / max(dt, 1e-9),
        "cache_hit_rate": summ["cache_hit_rate"],
        "served_staleness_p50": summ["served_staleness_p50"],
        "served_staleness_p99": summ["served_staleness_p99"],
        "peak_rss_mb": peak_rss_mb(),
        **{c_: summ[c_] for c_ in COUNTERS},
    }
    return row, failures


def compare_to_baseline(report: dict, baseline: dict) -> list:
    """Gate failures of ``report`` vs a committed baseline (see module
    docstring for the rules).  Returns human-readable failure strings."""
    failures = []
    base_runs = {r["name"]: r for r in baseline.get("runs", [])}
    meta_keys = ("rounds", "k", "p", "rate", "batch", "serve_batch")
    same_shape = all(report["meta"].get(m) == baseline.get("meta", {}).get(m)
                     for m in meta_keys)
    pairs = []               # (name, cur requests/s, base requests/s)
    for r in report["runs"]:
        b = base_runs.get(r["name"])
        if b is None:
            continue
        pairs.append((r["name"], r["requests_per_s"], b["requests_per_s"]))
        if same_shape:
            for c in COUNTERS + ("served_staleness_p50",
                                 "served_staleness_p99"):
                if c in b and r.get(c) != b[c]:
                    failures.append(
                        f"counter drift: {r['name']} {c} {r.get(c)} vs "
                        f"baseline {b[c]} (same seed+shape must be exact)")
    if pairs:
        # slowdown = base/cur; median across runs = runner speed, so only
        # runs that regressed relative to the rest of the suite trip the gate
        slowdowns = sorted(b / max(c, 1e-9) for _, c, b in pairs)
        machine = slowdowns[len(slowdowns) // 2]
        for name, cur, base in pairs:
            rel = (base / max(cur, 1e-9)) / max(machine, 1e-9)
            if rel > MAX_SLOWDOWN:
                failures.append(
                    f"throughput regression: {name} {cur:.0f} requests/s "
                    f"vs baseline {base:.0f} ({rel:.2f}x the suite median "
                    f"drift)")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ns", default="1000,10000")
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--p", type=int, default=32)
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--rate", type=float, default=50.0,
                    help="inference requests per gossip round")
    ap.add_argument("--batch", type=int, default=0,
                    help="gossip wake-ups per round (default n // 10)")
    ap.add_argument("--serve-batch", type=int, default=256,
                    help="decode batch size (users per predict dispatch)")
    ap.add_argument("--repeats", type=int, default=1,
                    help="timed repeats per run (min is reported)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny problem (CI bench-gate lane)")
    ap.add_argument("--out", default="BENCH_serve_collab.json")
    ap.add_argument("--baseline", default=None,
                    help="committed baseline JSON to gate against (fail on "
                         ">2x normalized requests/s regression or counter "
                         "drift)")
    args = ap.parse_args(argv)
    if args.smoke:
        args.ns, args.rounds, args.rate = "500", 80, 20.0

    ns = [int(x) for x in args.ns.split(",") if x]
    print("name,us,derived", flush=True)
    runs = []
    failures = []
    worst_rss = 0.0
    for n in ns:
        batch = args.batch or max(1, n // 10)
        r, fails = bench_one(n, args.k, args.p, args.rounds, args.rate,
                             batch, args.serve_batch, repeats=args.repeats)
        failures += fails
        r["name"] = f"serve_collab/mp/n{n}"
        worst_rss = max(worst_rss, r["peak_rss_mb"])
        emit(r["name"], r["time_s"] * 1e6,
             f"requests/s={r['requests_per_s']:.0f} "
             f"hit_rate={r['cache_hit_rate']:.2f} "
             f"staleness_p50={r['served_staleness_p50']:.0f} "
             f"staleness_p99={r['served_staleness_p99']:.0f} "
             f"invalidations={r['cache_invalidations']} "
             f"peak_rss_mb={r['peak_rss_mb']:.0f}")
        runs.append(r)

    report = {
        "meta": {
            "platform": jax.default_backend(),
            "jax": jax.__version__,
            "cores": os.cpu_count(),
            "k": args.k, "p": args.p, "rounds": args.rounds,
            "rate": args.rate, "batch": args.batch,
            "serve_batch": args.serve_batch, "repeats": args.repeats,
            "ns": ns, "smoke": bool(args.smoke),
        },
        "runs": runs,
        "summary": {
            "peak_rss_mb": worst_rss,
            "max_requests_per_s": max(r["requests_per_s"] for r in runs),
            "min_cache_hit_rate": min(r["cache_hit_rate"] for r in runs),
            "worst_staleness_p99": max(r["served_staleness_p99"]
                                       for r in runs),
        },
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {args.out}", flush=True)

    if args.baseline:
        with open(args.baseline) as f:
            baseline = json.load(f)
        failures += compare_to_baseline(report, baseline)
    for fail in failures:
        print(f"BASELINE FAILURE: {fail}", flush=True)
    if failures:
        return 1
    if args.baseline:
        print(f"baseline gate OK vs {args.baseline}", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())

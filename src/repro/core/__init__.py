"""Core: the paper's algorithms (model propagation + collaborative ADMM)."""

from .graph import (Graph, gaussian_kernel_graph, angular_kernel_graph,
                    knn_graph_from_similarity, two_moons, ring_graph,
                    random_geometric_graph)
from .losses import (AgentData, pad_datasets, quadratic_loss, hinge_loss,
                     logistic_loss, solitary_mean, solitary_gd,
                     confidences_from_counts, total_loss, LOSSES,
                     masked_sum, guarded_loss)
from .primal import (ExactQuadraticPrimal, InexactPrimal, flat_predictor,
                     solitary_adamw)
from .model_propagation import (closed_form, synchronous, async_gossip,
                                mp_objective, mp_mix_operator,
                                label_propagation, AsyncTrace)
from .sparse import (NeighborTables, DeviceTables, padded_neighbor_tables,
                     tables_from_adjacency, to_device, sample_event,
                     live_slots, neighbor_aggregate, quadratic_primal_core)
from .graph_learning import (GraphRecovery, cluster_edge_recovery,
                             learned_weight_tables, prune_rows,
                             reweight_rows, slot_sq_distances)
from .collaborative import (cl_objective, direct_minimize, init_state,
                            async_admm, sync_admm, ADMMState, CLTrace)
from .consensus import consensus_model, consensus_mean

__all__ = [n for n in dir() if not n.startswith("_")]

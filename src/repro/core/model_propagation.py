"""Model Propagation (paper §3).

Three equivalent solvers for  Q_MP(Theta) =
    1/2 ( sum_{i<j} W_ij ||theta_i - theta_j||^2
          + mu sum_i D_ii c_i ||theta_i - theta_i^sol||^2 ):

* ``closed_form``   — Prop. 1:  Theta* = abar (I - abar(I-C) - a P)^{-1} C Theta_sol
* ``synchronous``   — fixed-point iteration Eq. (5)
* ``async_gossip``  — the paper's asynchronous gossip algorithm (§3.2),
                      simulated exactly: uniform agent wake-up, one random
                      neighbor, communication + update steps, full
                      Theta_tilde in R^{n x n x p} state (row i = agent i's
                      knowledge of everyone; only N_i u {i} entries are live).

Convergence of async_gossip in expectation to Theta* is Theorem 1; it is
validated in tests/test_model_propagation.py and exercised at scale in
benchmarks/bench_mp_comm.py.

The inner wake-up sampling and neighbor aggregation go through the shared
padded-neighbor helpers in ``core.sparse`` so the O(n k p) event-driven
engine in ``repro.simulate`` reproduces this reference bit-for-bit
(DESIGN.md §4, tests/test_simulate.py).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.dispatch import ReproBackend, resolve

from .graph import Graph
from .sparse import (neighbor_aggregate, padded_neighbor_tables,
                     record_chunks, sample_event, to_device)


def mp_mix_operator(P_rows, c, alpha):
    """Eq. (5) as a "mix" op:  theta' = A_mix @ theta + b * theta_sol.

    A_mix = diag(alpha / (alpha + abar c)) P,  b = abar c / (alpha + abar c).
    ``P_rows`` may be the dense (n, n) stochastic matrix or the (n, k)
    padded-neighbor slot weights (row scaling is identical) — the single
    derivation shared by ``synchronous``, ``simulate.engines.sparse_sync_mp``
    and ``experiments.sweep``.
    """
    abar = 1.0 - alpha
    denom = alpha + abar * c
    A_mix = (alpha / denom)[:, None] * P_rows
    b = abar * c / denom
    return A_mix, b


def mp_objective(theta, theta_sol, W, c, mu):
    """Q_MP — used by tests to verify optimality of the closed form."""
    W = jnp.asarray(W)
    diff = theta[:, None, :] - theta[None, :, :]
    # sum_{i<j} W_ij ||.||^2 == 1/2 sum_{i,j} W_ij ||.||^2 for symmetric W,
    # and Q_MP carries an outer 1/2 -> 0.25 overall.
    smooth = 0.25 * jnp.sum(W * jnp.sum(diff * diff, axis=-1))
    D = jnp.sum(W, axis=1)
    anchor = 0.5 * mu * jnp.sum(D * c * jnp.sum((theta - theta_sol) ** 2, axis=-1))
    return smooth + anchor


def closed_form(graph: Graph, theta_sol, c, alpha: float) -> jnp.ndarray:
    """Prop. 1:  Theta* = abar (I - abar(I - C) - alpha P)^{-1} C Theta_sol."""
    n = graph.n
    ftype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    P = jnp.asarray(graph.P, ftype)
    theta_sol = jnp.asarray(theta_sol, ftype).reshape(n, -1)
    c = jnp.asarray(c, ftype)
    abar = 1.0 - alpha
    A = jnp.eye(n) - abar * (jnp.eye(n) - jnp.diag(c)) - alpha * P
    return abar * jnp.linalg.solve(A, c[:, None] * theta_sol)


def synchronous(graph: Graph, theta_sol, c, alpha: float, steps: int,
                theta0=None,
                backend: Optional[ReproBackend] = None) -> jnp.ndarray:
    """Fixed-point iteration Eq. (5); converges to Theta* for any init.

    Each iterate is one ``mix`` op — A_mix @ theta + b * theta_sol with
    A_mix = diag(alpha/(alpha+abar c)) P and b = abar c/(alpha+abar c) —
    resolved through ``kernels.dispatch`` (fused XLA on CPU/GPU, Pallas
    kernel on TPU, overridable per call via ``backend``).
    """
    n = graph.n
    P = jnp.asarray(graph.P, jnp.float32)
    theta_sol = jnp.asarray(theta_sol, jnp.float32).reshape(n, -1)
    c = jnp.asarray(c, jnp.float32)
    A_mix, b = mp_mix_operator(P, c, alpha)
    theta = theta_sol if theta0 is None else jnp.asarray(theta0, jnp.float32)
    mix = resolve("mix", backend)

    def step(theta, _):
        """One Eq. (5) iterate (the "mix" op)."""
        return mix(theta, theta_sol, A_mix, b), None

    theta, _ = jax.lax.scan(step, theta, None, length=steps)
    return theta


@dataclasses.dataclass
class AsyncTrace:
    """Result of the async gossip simulation.

    theta_hist: (n_records, n, p) — each agent's OWN model over time
    comms_hist: (n_records,)      — cumulative pairwise communications
    final_knowledge: (n, n, p)    — full Theta_tilde at the end
    """

    theta_hist: np.ndarray
    comms_hist: np.ndarray
    final_knowledge: np.ndarray


@partial(jax.jit, static_argnames=("steps", "record_every", "backend"))
def _async_scan(nbr_idx, nbr_p, slot_cdf, deg_count, theta_sol, c, alpha,
                key, steps, record_every, T0, backend=None):
    """Exact async gossip (§3.2) as a lax.scan.

    T is (n, n, p): T[i, j] = agent i's knowledge of agent j's model.
    One scan step = one clock tick = 2 pairwise communications (i->j, j->i).
    Neighbor selection and aggregation use the shared slot tables so the
    sparse engine (repro.simulate.engines) matches bit-for-bit.
    """
    n, _, p = T0.shape
    abar = 1.0 - alpha

    def local_update(T, l, tgt):
        """Update step Eq. (6) for agent l using its own knowledge row."""
        nbrs = T[l][nbr_idx[l]]                   # (k_max, p) gathered slots
        agg = neighbor_aggregate(nbr_p[l], nbrs, backend)  # (p,)
        new = (alpha * agg + abar * c[l] * theta_sol[l]) / (alpha + abar * c[l])
        # scatter: unique target — single scalar (tgt, l) cell
        return T.at[tgt, l].set(new, mode="drop")

    def step(carry, key):
        """One wake-up tick (§3.2): exchange self-models, update both
        endpoints via Eq. (6)."""
        T = carry
        i, s = sample_event(key, n, slot_cdf, deg_count)
        # degree-0 waker -> no-op (same masking as the sparse engines):
        # out-of-bounds scatter targets are dropped
        valid = deg_count[i] > 0
        j = nbr_idx[i, s]
        ti = jnp.where(valid, i, n)
        tj = jnp.where(valid, j, n)
        # communication step: exchange current self-models
        T = T.at[ti, j].set(T[j, j], mode="drop")  # scatter: unique target
        T = T.at[tj, i].set(T[i, i], mode="drop")  # scatter: unique target
        # update step for both endpoints
        T = local_update(T, i, ti)
        T = local_update(T, j, tj)
        return T, T[jnp.arange(n), jnp.arange(n)] if record_every == 1 else None

    if record_every == 1:
        keys = jax.random.split(key, steps)
        T, hist = jax.lax.scan(step, T0, keys)
        return T, hist

    # repro-lint: disable=RPL007  callers normalize via core.sparse.record_chunks
    n_rec = steps // record_every

    def outer(T, key):
        """One record chunk; emits a model snapshot."""
        keys = jax.random.split(key, record_every)
        T, _ = jax.lax.scan(lambda c, k: (step(c, k)[0], None), T, keys)
        return T, T[jnp.arange(n), jnp.arange(n)]

    keys = jax.random.split(key, n_rec)
    T, hist = jax.lax.scan(outer, T0, keys)
    return T, hist


def async_gossip(graph: Graph, theta_sol, c, alpha: float, steps: int,
                 seed: int = 0, record_every: int = 100,
                 theta0=None,
                 backend: Optional[ReproBackend] = None) -> AsyncTrace:
    """Run the asynchronous gossip MP algorithm (paper §3.2).

    ``steps`` clock ticks; each tick = 2 pairwise communications.
    Neighbor selection pi_i is uniform over N_i (as in the paper's §5).
    """
    n = graph.n
    theta_sol = jnp.asarray(theta_sol, jnp.float32).reshape(n, -1)
    p = theta_sol.shape[1]
    tabs = to_device(padded_neighbor_tables(graph))
    c = jnp.asarray(c, jnp.float32)

    if theta0 is None:
        # warm start with solitary models everywhere the agent has knowledge
        T0 = jnp.where(((graph.W > 0) | np.eye(n, dtype=bool))[:, :, None],
                       jnp.broadcast_to(theta_sol[None], (n, n, p)), 0.0)
        T0 = jnp.asarray(T0, jnp.float32)
    else:
        T0 = jnp.asarray(theta0, jnp.float32)

    key = jax.random.PRNGKey(seed)
    # shared recording policy (core.sparse.record_chunks): horizon floored
    # to a whole number of record chunks, never silently zero steps
    record_every, n_rec = record_chunks(steps, record_every)
    T, hist = _async_scan(tabs.nbr_idx, tabs.nbr_p, tabs.slot_cdf,
                          tabs.deg_count, theta_sol, c, alpha, key,
                          n_rec * record_every, record_every, T0, backend)
    comms = 2 * record_every * (np.arange(hist.shape[0]) + 1)
    return AsyncTrace(np.asarray(hist), comms, np.asarray(T))


def label_propagation(graph: Graph, labels, alpha: float) -> jnp.ndarray:
    """Zhou et al. (2004) — the C = I special case (paper §3.1 remark)."""
    n = graph.n
    return closed_form(graph, labels, np.ones(n), alpha)

"""Collaborative Learning via decentralized ADMM (paper §4 + App. D).

Objective:
    Q_CL(Theta) = sum_{i<j} W_ij ||theta_i - theta_j||^2
                  + mu * sum_i D_ii L_i(theta_i)

Partial-consensus reformulation (paper Eq. 8): each agent i keeps local
copies Theta_tilde_i of its own and its neighbors' models; per edge
e = (i, j) there are 4 secondary variables and 4 duals.

Data layout (dense, mask = W > 0):
    T[i, j]      = Theta_tilde_i^j   — agent i's copy of model j  (n, n, p);
                   live entries: j in N_i u {i}
    Z_own[i, j]  = Z_{e i}^{i}  — agent i's secondary var for ITS OWN model
                   on edge (i,j)
    Z_nbr[i, j]  = Z_{e i}^{j}  — agent i's secondary var for j's model
    L_own[i, j]  = Lambda_{e i}^{i},   L_nbr[i, j] = Lambda_{e i}^{j}

The constraint set C_E (Z_{ei}^i = Z_{ej}^i etc.) reads
    Z_own[i, j] == Z_nbr[j, i]  for every edge — maintained by construction
by the Z update (paper step 2).

Primal step (paper step 1): exact closed form for the quadratic loss
(block elimination — see ``_primal_quadratic``), K subgradient steps for
hinge (§4.2: "ADMM is typically robust to approximate solutions").  The
scenario engines generalize the same robustness into a pluggable
strategy — ``core.primal`` (DESIGN.md §18) solves the primal with B AdamW
steps on the reduced Lagrangian, which is how nonlinear agent models ride
the otherwise-unchanged ADMM substrate.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.dispatch import ReproBackend

from .graph import Graph
from .losses import AgentData, LOSSES
from .sparse import (padded_neighbor_tables, quadratic_primal_core,
                     record_chunks, sample_event, to_device)


def cl_objective(theta, W, mu, loss_fn, data: AgentData):
    """Q_CL for per-agent models theta (n, p)."""
    W = jnp.asarray(W)
    diff = theta[:, None, :] - theta[None, :, :]
    smooth = 0.5 * jnp.sum(W * jnp.sum(diff * diff, axis=-1))  # sum_{i<j}
    D = jnp.sum(W, axis=1)
    per_agent = jax.vmap(loss_fn)(theta, data.x, data.y, data.mask)
    return smooth + mu * jnp.sum(D * per_agent)


def direct_minimize(graph: Graph, data: AgentData, mu: float, loss: str,
                    steps: int = 2000, lr: float = None) -> jnp.ndarray:
    """Centralized gradient descent on Q_CL — oracle for tests/benchmarks."""
    loss_fn = LOSSES[loss]
    W = jnp.asarray(graph.W, jnp.float32)
    n, _, p = data.x.shape
    if lr is None:
        # conservative: smoothness term has Lipschitz ~ 4 max_i D_ii
        lr = 0.5 / float(4.0 * graph.degrees.max() * max(mu, 1.0) + 1.0)
    obj = lambda th: cl_objective(th, W, mu, loss_fn, data)
    grad = jax.grad(obj)

    def step(th, _):
        """One gradient-descent step on Q_CL."""
        return th - lr * grad(th), None

    theta, _ = jax.lax.scan(step, jnp.zeros((n, p)), None, length=steps)
    return theta


# ---------------------------------------------------------------------------
# ADMM state
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class ADMMState:
    """Dense partial-consensus ADMM state (paper §4.2).

    T[l] is agent l's primal block (its own model at T[l, l], copies of its
    neighbors elsewhere); Z_own/Z_nbr are the per-edge secondary variables
    and L_own/L_nbr the scaled duals, one (n, n, p) array each (the sparse
    engines store the same five blocks as (n, k, p) slot rows).
    """

    T: jnp.ndarray       # (n, n, p)
    Z_own: jnp.ndarray   # (n, n, p)
    Z_nbr: jnp.ndarray   # (n, n, p)
    L_own: jnp.ndarray   # (n, n, p)
    L_nbr: jnp.ndarray   # (n, n, p)

    def models(self) -> jnp.ndarray:
        """(n, p) personal models — the diagonal blocks Theta_l^l."""
        n = self.T.shape[0]
        return self.T[jnp.arange(n), jnp.arange(n)]


def init_state(graph: Graph, theta_sol) -> ADMMState:
    """Warm start (paper §4.2): share solitary models with neighbors."""
    n = graph.n
    theta_sol = jnp.asarray(theta_sol, jnp.float32).reshape(n, -1)
    p = theta_sol.shape[1]
    adj = jnp.asarray((graph.W > 0) | np.eye(n, dtype=bool))
    T = jnp.where(adj[:, :, None], jnp.broadcast_to(theta_sol[None], (n, n, p)),
                  0.0).astype(jnp.float32)
    edge = jnp.asarray(graph.W > 0)
    Z_own = jnp.where(edge[:, :, None],
                      jnp.broadcast_to(theta_sol[:, None], (n, n, p)), 0.0)
    Z_nbr = jnp.where(edge[:, :, None],
                      jnp.broadcast_to(theta_sol[None], (n, n, p)), 0.0)
    zeros = jnp.zeros((n, n, p), jnp.float32)
    return ADMMState(T, Z_own.astype(jnp.float32), Z_nbr.astype(jnp.float32),
                     zeros, zeros)


# ---------------------------------------------------------------------------
# Primal updates
# ---------------------------------------------------------------------------


def _primal_quadratic(state: ADMMState, l, nbr_idx, nbr_w, deg_count, D,
                      mu, rho, data: AgentData, backend=None):
    """Exact argmin of L_rho^l for the quadratic loss, by block elimination.

    Stationarity for neighbor blocks j in N_l:
        (W_lj + rho) T^j  =  W_lj T^l + rho Z_nbr[l,j] - L_nbr[l,j]
    Substituting into the self block gives a scalar equation per coordinate.
    L_l(theta) = sum_k ||theta - x_k||^2  =>  grad = 2 (m_l theta - sum_k x_k).

    Gathered over the padded-neighbor slot tables and solved by the shared
    ``quadratic_primal_core`` so the sparse ADMM engine matches bit-for-bit.
    """
    k = nbr_idx.shape[1]
    idx = nbr_idx[l]                               # (k,)
    live = jnp.arange(k) < deg_count[l]
    w = nbr_w[l]                                   # (k,) 0 at pads
    m_l = jnp.sum(data.mask[l])
    sx = jnp.sum(data.x[l] * data.mask[l][:, None], axis=0)   # (p,)
    theta_l, theta_js = quadratic_primal_core(
        w, live, state.Z_own[l][idx], state.Z_nbr[l][idx],
        state.L_own[l][idx], state.L_nbr[l][idx], D[l], m_l, sx, mu, rho,
        backend)
    # scatter: last-write-wins — pad slots collide on row l and are
    # overwritten by the unconditional .at[l].set immediately below
    row = state.T[l].at[jnp.where(live, idx, l)].set(
        jnp.where(live[:, None], theta_js, theta_l[None]))
    row = row.at[l].set(theta_l)  # scatter: unique target (scalar index l)
    return state.T.at[l].set(row)  # scatter: unique target (scalar index l)


def _primal_subgrad(state: ADMMState, l, W, D, mask, mu, rho,
                    data: AgentData, loss: str, k_steps: int, lr: float):
    """K (sub)gradient steps on L_rho^l over the row T[l] (hinge etc.)."""
    loss_fn = LOSSES[loss]
    w = W[l] * mask[l]
    mrow = mask[l][:, None]

    def lagrangian(row):
        theta_l = row[l]
        smooth = 0.5 * jnp.sum(w * jnp.sum((theta_l[None] - row) ** 2, axis=-1))
        local = mu * D[l] * loss_fn(theta_l, data.x[l], data.y[l], data.mask[l])
        lin = jnp.sum(mrow * (state.L_own[l] * (theta_l[None] - state.Z_own[l])
                              + state.L_nbr[l] * (row - state.Z_nbr[l])))
        quad = 0.5 * rho * jnp.sum(
            mrow * ((theta_l[None] - state.Z_own[l]) ** 2
                    + (row - state.Z_nbr[l]) ** 2))
        return smooth + local + lin + quad

    grad = jax.grad(lagrangian)

    def gd(row, _):
        return row - lr * grad(row), None

    row, _ = jax.lax.scan(gd, state.T[l], None, length=k_steps)
    # keep non-live entries untouched
    live = mask[l][:, None] | (jnp.arange(row.shape[0]) == l)[:, None]
    row = jnp.where(live, row, state.T[l])
    return state.T.at[l].set(row)  # scatter: unique target (scalar index l)


# ---------------------------------------------------------------------------
# Z / Lambda updates for one edge (paper steps 2-3), both endpoints
# ---------------------------------------------------------------------------


def _edge_zl_update(state: ADMMState, i, j, rho) -> ADMMState:
    T, Z_own, Z_nbr, L_own, L_nbr = (state.T, state.Z_own, state.Z_nbr,
                                     state.L_own, state.L_nbr)
    # Z for model i on edge e: owned by i as Z_own[i,j], by j as Z_nbr[j,i]
    z_i = 0.5 * ((L_own[i, j] + L_nbr[j, i]) / rho + T[i, i] + T[j, i])
    # Z for model j on edge e: owned by j as Z_own[j,i], by i as Z_nbr[i,j]
    z_j = 0.5 * ((L_own[j, i] + L_nbr[i, j]) / rho + T[j, j] + T[i, j])
    # scatter: unique targets — (i, j) and (j, i) are distinct cells of one
    # undirected edge i != j
    Z_own = Z_own.at[i, j].set(z_i).at[j, i].set(z_j)
    Z_nbr = Z_nbr.at[i, j].set(z_j).at[j, i].set(z_i)  # scatter: unique targets
    # dual updates
    L_own = L_own.at[i, j].add(rho * (T[i, i] - z_i))
    L_own = L_own.at[j, i].add(rho * (T[j, j] - z_j))
    L_nbr = L_nbr.at[i, j].add(rho * (T[i, j] - z_j))
    L_nbr = L_nbr.at[j, i].add(rho * (T[j, i] - z_i))
    return ADMMState(T, Z_own, Z_nbr, L_own, L_nbr)


def _all_zl_update(state: ADMMState, mask, rho) -> ADMMState:
    """Synchronous Z + dual update for ALL edges at once (App. D steps 2-3)."""
    T, Z_own, Z_nbr, L_own, L_nbr = (state.T, state.Z_own, state.Z_nbr,
                                     state.L_own, state.L_nbr)
    n = T.shape[0]
    diag = T[jnp.arange(n), jnp.arange(n)]                    # (n, p) own models
    # For ordered pair (i, j): z_own_new[i,j] = Z for model i on edge (i,j)
    #   = 1/2 [ (L_own[i,j] + L_nbr[j,i]) / rho + T[i,i] + T[j,i] ]
    z_own_new = 0.5 * ((L_own + jnp.swapaxes(L_nbr, 0, 1)) / rho
                       + diag[:, None, :] + jnp.swapaxes(T, 0, 1))
    z_nbr_new = jnp.swapaxes(z_own_new, 0, 1)
    m3 = mask[:, :, None]
    Z_own_n = jnp.where(m3, z_own_new, Z_own)
    Z_nbr_n = jnp.where(m3, z_nbr_new, Z_nbr)
    L_own_n = jnp.where(m3, L_own + rho * (diag[:, None, :] - Z_own_n), L_own)
    L_nbr_n = jnp.where(m3, L_nbr + rho * (T - Z_nbr_n), L_nbr)
    return ADMMState(T, Z_own_n, Z_nbr_n, L_own_n, L_nbr_n)


# ---------------------------------------------------------------------------
# Drivers
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CLTrace:
    """CL-ADMM run record: model snapshots + cumulative communications."""

    theta_hist: np.ndarray   # (n_records, n, p)
    comms_hist: np.ndarray   # cumulative pairwise communications
    final: "ADMMState"


def _make_primal(tabs, W, D, mask, mu, rho, data, loss, k_steps, lr,
                 backend=None):
    if loss == "quadratic":
        return lambda st, l: _primal_quadratic(st, l, tabs.nbr_idx, tabs.nbr_w,
                                               tabs.deg_count, D, mu, rho,
                                               data, backend)
    return lambda st, l: _primal_subgrad(st, l, W, D, mask, mu, rho, data,
                                         loss, k_steps, lr)


def async_admm(graph: Graph, data: AgentData, mu: float, rho: float,
               loss: str = "quadratic", steps: int = 1000, seed: int = 0,
               record_every: int = 50, k_steps: int = 10, lr: float = 0.05,
               theta_sol=None, state: Optional[ADMMState] = None,
               backend: Optional[ReproBackend] = None) -> CLTrace:
    """Asynchronous decentralized ADMM (paper §4.2).

    One scan step = one wake-up: agent i (uniform) picks neighbor j ~ pi_i
    (uniform over N_i), both primal-update, edge (i,j)'s Z and duals update.
    = 2 pairwise communications per step (i->j and j->i messages).
    """
    n = graph.n
    W = jnp.asarray(graph.W, jnp.float32)
    D = jnp.asarray(graph.degrees, jnp.float32)
    mask = jnp.asarray(graph.W > 0)
    tabs = to_device(padded_neighbor_tables(graph))
    if state is None:
        if theta_sol is None:
            raise ValueError("need theta_sol (warm start) or explicit state")
        state = init_state(graph, theta_sol)
    primal = _make_primal(tabs, W, D, mask, mu, rho, data, loss, k_steps, lr,
                          backend)

    def tick(st: ADMMState, key):
        """One wake-up (§4.2): both endpoints primal-update, then the
        waking edge's Z/dual update."""
        i, s = sample_event(key, n, tabs.slot_cdf, tabs.deg_count)
        # degree-0 waker -> no-op: out-of-bounds targets drop every scatter
        valid = tabs.deg_count[i] > 0
        ti = jnp.where(valid, i, n)
        tj = jnp.where(valid, tabs.nbr_idx[i, s], n)
        T = primal(st, ti)
        st = ADMMState(T, st.Z_own, st.Z_nbr, st.L_own, st.L_nbr)
        T = primal(st, tj)
        st = ADMMState(T, st.Z_own, st.Z_nbr, st.L_own, st.L_nbr)
        return _edge_zl_update(st, ti, tj, rho)

    # shared recording policy (core.sparse.record_chunks): horizon floored
    # to a whole number of record chunks — never zero, never an overrun
    record_every, n_rec = record_chunks(steps, record_every)

    @jax.jit
    def run(state, key):
        """Scan ``n_rec`` record chunks of ``record_every`` ticks."""
        def outer(st, key):
            """One record chunk; emits a model snapshot."""
            keys = jax.random.split(key, record_every)
            st = jax.lax.scan(lambda s, k: (tick(s, k), None), st, keys)[0]
            return st, st.models()
        keys = jax.random.split(key, n_rec)
        return jax.lax.scan(outer, state, keys)

    final, hist = run(state, jax.random.PRNGKey(seed))
    comms = 2 * record_every * (np.arange(n_rec) + 1)
    return CLTrace(np.asarray(hist), comms, final)


def sync_admm(graph: Graph, data: AgentData, mu: float, rho: float,
              loss: str = "quadratic", steps: int = 100,
              k_steps: int = 10, lr: float = 0.05,
              theta_sol=None, state: Optional[ADMMState] = None,
              backend: Optional[ReproBackend] = None) -> CLTrace:
    """Synchronous decentralized ADMM (paper App. D).

    One iteration = every agent primal-updates, then all Z/dual updates;
    costs 2|E| pairwise communications.
    """
    n = graph.n
    W = jnp.asarray(graph.W, jnp.float32)
    D = jnp.asarray(graph.degrees, jnp.float32)
    mask = jnp.asarray(graph.W > 0)
    tabs = to_device(padded_neighbor_tables(graph))
    if state is None:
        if theta_sol is None:
            raise ValueError("need theta_sol (warm start) or explicit state")
        state = init_state(graph, theta_sol)
    primal = _make_primal(tabs, W, D, mask, mu, rho, data, loss, k_steps, lr,
                          backend)

    @jax.jit
    def run(state):
        """Scan ``steps`` synchronous App. D iterations."""
        def it(st, _):
            """One iteration: all primals, then all Z/dual updates."""
            def body(l, s):
                """Agent l's exact primal block update."""
                T = primal(s, l)
                return ADMMState(T, s.Z_own, s.Z_nbr, s.L_own, s.L_nbr)
            st = jax.lax.fori_loop(0, n, body, st)
            st = _all_zl_update(st, mask, rho)
            return st, st.models()
        return jax.lax.scan(it, state, None, length=steps)

    final, hist = run(state)
    comms = 2 * len(graph.edges()) * (np.arange(steps) + 1)
    return CLTrace(np.asarray(hist), comms, final)

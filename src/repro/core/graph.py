"""Similarity graphs over agents (paper §2.1, §5).

A graph is represented by its dense symmetric nonnegative weight matrix
``W`` (n x n, zero diagonal). Derived quantities:

* ``D`` (degree diagonal), ``P = D^{-1} W`` (stochastic similarity matrix),
* neighbor sets / uniform neighbor-selection distributions ``pi_i``,
* greedy edge-colorings into *matchings* — the structured-gossip schedule
  used by the TPU-scale coupling layer (DESIGN.md §2).

Everything here is plain numpy/jnp; graphs are small (n = #agents).
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import List, Tuple

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class Graph:
    """Weighted undirected graph over ``n`` agents (paper §2.1).

    ``W`` is validated once here, so every derived quantity (``P``,
    ``laplacian``, the neighbor tables of ``core.sparse``) can assume a
    finite, nonnegative, exactly symmetric, zero-diagonal matrix:

    * non-finite or negative entries raise ``ValueError``;
    * an asymmetry beyond float tolerance raises; an asymmetry *within*
      tolerance (e.g. a kernel evaluated in a non-symmetric expression
      order) is silently-dangerous no more — it is symmetrized to
      ``(W + W.T) / 2`` with a ``UserWarning`` (previously such matrices
      were accepted as-is and leaked row-dependent ``P`` matrices into the
      engines);
    * the diagonal is zeroed (self-loops carry no information in Eq. (1)).
    """

    W: np.ndarray  # (n, n) symmetric, nonnegative, zero diagonal

    def __post_init__(self):
        W = np.asarray(self.W, dtype=np.float64)
        if W.ndim != 2 or W.shape[0] != W.shape[1]:
            raise ValueError(f"W must be square, got {W.shape}")
        if not np.isfinite(W).all():
            raise ValueError("W must be finite (contains NaN or inf)")
        if (W < 0).any():
            raise ValueError("W must be nonnegative")
        if not np.array_equal(W, W.T):
            if not np.allclose(W, W.T):
                raise ValueError("W must be symmetric")
            warnings.warn(
                "W is asymmetric within float tolerance; symmetrizing to "
                "(W + W.T) / 2", UserWarning, stacklevel=3)
            W = 0.5 * (W + W.T)
        object.__setattr__(self, "W", W * (1.0 - np.eye(W.shape[0])))

    @property
    def n(self) -> int:
        """Number of agents."""
        return self.W.shape[0]

    @property
    def degrees(self) -> np.ndarray:
        """(n,) weighted degrees D_ii = sum_j W_ij (paper §2.1)."""
        return self.W.sum(axis=1)

    @property
    def D(self) -> np.ndarray:
        """Degree diagonal matrix D (paper Prop. 1)."""
        return np.diag(self.degrees)

    @property
    def P(self) -> np.ndarray:
        """Stochastic similarity matrix P = D^{-1} W (paper Prop. 1)."""
        d = self.degrees
        if (d <= 0).any():
            raise ValueError("graph has an isolated agent (zero degree)")
        return self.W / d[:, None]

    @property
    def laplacian(self) -> np.ndarray:
        """Graph Laplacian L = D - W (the smoothness operator of
        Eq. (1)'s quadratic term)."""
        return self.D - self.W

    def edges(self) -> List[Tuple[int, int]]:
        """Undirected edges (i < j) with positive weight."""
        iu, ju = np.nonzero(np.triu(self.W, k=1))
        return list(zip(iu.tolist(), ju.tolist()))

    def neighbors(self, i: int) -> np.ndarray:
        """Ids of N_i — agents sharing a positive-weight edge with i."""
        return np.nonzero(self.W[i])[0]

    def neighbor_distribution(self) -> np.ndarray:
        """Uniform neighbor-selection distributions pi_i (paper §3.2).

        Returns (n, n) row-stochastic matrix with pi[i, j] > 0 iff j in N_i.
        """
        A = (self.W > 0).astype(np.float64)
        deg = A.sum(axis=1)
        if (deg <= 0).any():
            raise ValueError("graph has an isolated agent")
        return A / deg[:, None]

    def is_connected(self) -> bool:
        """Whether the positive-weight edge set connects all agents."""
        n = self.n
        seen = np.zeros(n, dtype=bool)
        stack = [0]
        seen[0] = True
        while stack:
            i = stack.pop()
            for j in self.neighbors(i):
                if not seen[j]:
                    seen[j] = True
                    stack.append(int(j))
        return bool(seen.all())

    def edge_coloring(self) -> List[List[Tuple[int, int]]]:
        """Greedy proper edge coloring -> list of matchings covering E.

        Each matching is a set of vertex-disjoint edges: the agent pairs that
        can gossip *simultaneously* without conflicts. Misra–Gries would give
        <= Delta+1 colors; greedy gives <= 2*Delta-1 which is fine here
        (n = #agents is small, and only coverage/disjointness matter).
        """
        matchings: List[List[Tuple[int, int]]] = []
        # sort for determinism: heaviest edges first
        es = sorted(self.edges(), key=lambda e: -self.W[e[0], e[1]])
        used: List[set] = []
        for (i, j) in es:
            placed = False
            for color, busy in enumerate(used):
                if i not in busy and j not in busy:
                    matchings[color].append((i, j))
                    busy.add(i)
                    busy.add(j)
                    placed = True
                    break
            if not placed:
                matchings.append([(i, j)])
                used.append({i, j})
        return matchings


# ---------------------------------------------------------------------------
# Graph constructors used by the paper's experiments
# ---------------------------------------------------------------------------


def gaussian_kernel_graph(points: np.ndarray, sigma: float = 0.1,
                          threshold: float = 0.0) -> Graph:
    """Complete graph with W_ij = exp(-||v_i - v_j||^2 / (2 sigma^2)).

    Used in the mean-estimation task (paper §5.1) over 2-D auxiliary vectors.
    ``threshold`` zeroes negligible weights (paper §5.2 'edges with negligible
    weights are ignored').  ``sigma`` must be positive: the sigma -> 0 limit
    is a graph of isolated agents for distinct points and 0/0 for identical
    ones, so it is rejected rather than silently producing NaN weights.
    Exactly identical points get the kernel's supremum weight 1.
    """
    if sigma <= 0:
        raise ValueError(f"sigma must be > 0, got {sigma}")
    v = np.asarray(points, dtype=np.float64)
    sq = ((v[:, None, :] - v[None, :, :]) ** 2).sum(-1)
    W = np.exp(-sq / (2.0 * sigma ** 2))
    np.fill_diagonal(W, 0.0)
    if threshold > 0:
        W = np.where(W >= threshold, W, 0.0)
    return Graph(W)


def angular_kernel_graph(models: np.ndarray, sigma: float = 0.1,
                         threshold: float = 1e-3) -> Graph:
    """W_ij = exp((cos(phi_ij) - 1)/sigma) over target-model angles (§5.2).

    ``sigma`` must be positive (see :func:`gaussian_kernel_graph`);
    zero-norm model rows are treated as unit-norm so the cosine is defined.
    """
    if sigma <= 0:
        raise ValueError(f"sigma must be > 0, got {sigma}")
    m = np.asarray(models, dtype=np.float64)
    norms = np.linalg.norm(m, axis=1, keepdims=True)
    norms = np.where(norms == 0, 1.0, norms)
    u = m / norms
    cos = np.clip(u @ u.T, -1.0, 1.0)
    W = np.exp((cos - 1.0) / sigma)
    np.fill_diagonal(W, 0.0)
    W = np.where(W >= threshold, W, 0.0)
    # symmetrize exactly (cos is symmetric but thresholding keeps it so)
    return Graph(np.maximum(W, W.T))


def knn_graph_from_similarity(sim: np.ndarray, k: int) -> Graph:
    """k-nearest-neighbor graph with 0/1 weights (paper App. E).

    Agent i is linked to the k agents with largest similarity; the result is
    symmetrized (an edge exists if either endpoint selects the other),
    matching the usual kNN-graph construction.
    """
    s = np.asarray(sim, dtype=np.float64).copy()
    np.fill_diagonal(s, -np.inf)
    n = s.shape[0]
    W = np.zeros((n, n))
    idx = np.argsort(-s, axis=1)[:, :k]
    rows = np.repeat(np.arange(n), k)
    W[rows, idx.ravel()] = 1.0  # scatter: idempotent (every value is 1.0)
    W = np.maximum(W, W.T)
    return Graph(W)


def two_moons(n: int, noise: float = 0.05,
              seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Two intertwining moons in R^2 (paper §5.1 / Zhou et al. 2004).

    Returns (points (n,2), labels (n,) in {0,1}) — label 0 = upper moon
    (mean +1), label 1 = lower moon (mean -1).
    """
    rng = np.random.default_rng(seed)
    n0 = n // 2
    n1 = n - n0
    t0 = rng.uniform(0.0, np.pi, n0)
    t1 = rng.uniform(0.0, np.pi, n1)
    upper = np.stack([np.cos(t0), np.sin(t0)], axis=1)
    lower = np.stack([1.0 - np.cos(t1), 0.5 - np.sin(t1)], axis=1)
    pts = np.concatenate([upper, lower], axis=0)
    pts += noise * rng.standard_normal(pts.shape)
    labels = np.concatenate([np.zeros(n0, dtype=int), np.ones(n1, dtype=int)])
    perm = rng.permutation(n)
    return pts[perm], labels[perm]


def ring_graph(n: int, weight: float = 1.0) -> Graph:
    """Ring over n agents — default small-agent-count graph at TPU scale."""
    W = np.zeros((n, n))
    for i in range(n):
        W[i, (i + 1) % n] = weight  # scatter: unique target per iteration
        W[(i + 1) % n, i] = weight  # scatter: unique target per iteration
    return Graph(W)


def random_geometric_graph(n: int, k: int = 3, seed: int = 0) -> Graph:
    """kNN graph over random 2-D positions — agent topology generator."""
    rng = np.random.default_rng(seed)
    pts = rng.uniform(size=(n, 2))
    sq = ((pts[:, None, :] - pts[None, :, :]) ** 2).sum(-1)
    return knn_graph_from_similarity(-sq, k)


def as_jnp(graph: Graph, dtype=jnp.float32):
    """(W, P, degrees) as jnp arrays for use inside jitted code."""
    return (jnp.asarray(graph.W, dtype),
            jnp.asarray(graph.P, dtype),
            jnp.asarray(graph.degrees, dtype))

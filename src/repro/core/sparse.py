"""Padded-neighbor (CSR-style) tables shared by the dense reference engines
and the sparse event-driven simulator (DESIGN.md §4).

The asynchronous algorithms (MP gossip §3.2, CL-ADMM §4.2) only ever touch an
agent's own row of state — its neighbors' models, its per-edge secondary
variables.  Everything they compute can therefore be expressed over a padded
neighbor layout:

    nbr_idx  (n, k_max) int32  — sorted neighbor ids; pad slots repeat the
                                 row's last real neighbor (never selected,
                                 weight exactly 0)
    rev_slot (n, k_max) int32  — rev_slot[i, s] = position of i in the
                                 neighbor list of j = nbr_idx[i, s]
    nbr_w    (n, k_max) f32    — raw edge weights W_ij (0 at pads)
    nbr_p    (n, k_max) f32    — stochastic weights P_ij = W_ij / D_ii
    slot_cdf (n, k_max) f32    — cumsum of the uniform neighbor-selection
                                 distribution pi_i over slots (flat at pads)
    deg_count (n,)      int32  — number of live slots per row

The dense reference engines in ``model_propagation`` / ``collaborative`` keep
their (n, n, p) state but route every inner aggregation, neighbor-selection
draw, and primal solve through the helpers below, gathered over these same
slot tables.  The sparse engines in ``repro.simulate`` apply the *identical*
jnp expressions to their (n, k_max, p) state.  Identical ops on identical
values make the two trajectories match bit-for-bit given the same RNG stream
— the property tested in tests/test_simulate.py.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.dispatch import ReproBackend, resolve


class NeighborTables(NamedTuple):
    """Host-side (numpy) padded-neighbor tables; see module docstring."""

    nbr_idx: np.ndarray    # (n, k_max) int32
    rev_slot: np.ndarray   # (n, k_max) int32
    deg_count: np.ndarray  # (n,) int32
    nbr_w: np.ndarray      # (n, k_max) float32, raw W
    nbr_p: np.ndarray      # (n, k_max) float32, W / D
    slot_cdf: np.ndarray   # (n, k_max) float32
    deg_w: np.ndarray      # (n,) float64 weighted degree D_ii

    @property
    def n(self) -> int:
        """Number of agents (rows)."""
        return self.nbr_idx.shape[0]

    @property
    def k_max(self) -> int:
        """Padded slot count (max degree over agents)."""
        return self.nbr_idx.shape[1]

    def with_weights(self, nbr_w_new: np.ndarray) -> "NeighborTables":
        """New tables carrying updated per-slot weights (time-varying graphs).

        The *candidate* structure — ``nbr_idx``, ``rev_slot``, ``deg_count``
        and the uniform wake-up cdf ``slot_cdf`` (pi_i, paper §3.2) — is kept
        frozen: the joint graph-learning engines (DESIGN.md §13) only move
        the weights within a fixed candidate support, which is what keeps
        the event process precomputable and replayable.  ``nbr_w``,
        ``nbr_p`` and ``deg_w`` are recomputed from ``nbr_w_new`` (dead
        slots zeroed; zero-degree rows get an all-zero stochastic row).
        """
        live = np.arange(self.k_max)[None, :] < self.deg_count[:, None]
        w = np.where(live, np.asarray(nbr_w_new, np.float64), 0.0)
        deg_w = w.sum(axis=1)
        nbr_p = np.where(live, w / np.where(deg_w > 0, deg_w, 1.0)[:, None],
                         0.0)
        return self._replace(nbr_w=w.astype(np.float32),
                             nbr_p=nbr_p.astype(np.float32), deg_w=deg_w)


def tables_from_adjacency(nbr_lists: Sequence[np.ndarray],
                          weight_lists: Sequence[np.ndarray],
                          deg_w: Optional[np.ndarray] = None,
                          allow_isolated: bool = False) -> NeighborTables:
    """Build NeighborTables from per-agent sorted neighbor/weight lists.

    Never materializes an n x n matrix: O(n * k_max) memory throughout, so it
    is the constructor used by the large-topology generators as well as by
    ``padded_neighbor_tables`` (which extracts the lists from a dense Graph).

    ``deg_w`` overrides the weighted degrees — Graph-derived tables pass the
    dense ``W.sum(axis=1)`` so D_ii matches the reference engines bitwise.

    ``allow_isolated=True`` admits degree-0 agents (churned-out sensors,
    stragglers that never joined): their rows carry deg_count 0, all-zero
    weights and a flat slot cdf, and every event engine treats a wake-up of
    such an agent as a no-op (see ``sample_event`` / ``scheduler.draw_events``).
    """
    n = len(nbr_lists)
    deg_count = np.array([len(a) for a in nbr_lists], np.int32)
    if (deg_count == 0).any() and not allow_isolated:
        raise ValueError("every agent needs at least one neighbor")
    k_max = max(1, int(deg_count.max()))

    nbr_idx = np.zeros((n, k_max), np.int32)
    nbr_w = np.zeros((n, k_max), np.float32)
    for i, (nb, wt) in enumerate(zip(nbr_lists, weight_lists)):
        d = len(nb)
        if d == 0:
            continue                     # isolated: all-zero row
        nbr_idx[i, :d] = nb
        nbr_idx[i, d:] = nb[-1]          # pads duplicate the last neighbor
        nbr_w[i, :d] = wt

    if deg_w is None:
        deg_w = np.array([np.asarray(w, np.float64).sum()
                          for w in weight_lists])
    deg_w = np.asarray(deg_w, np.float64)
    live = np.arange(k_max)[None, :] < deg_count[:, None]
    nbr_p = np.where(live, nbr_w.astype(np.float64)
                     / np.where(deg_w > 0, deg_w, 1.0)[:, None],
                     0.0).astype(np.float32)

    # uniform neighbor-selection cdf over slots (pi_i, paper §3.2); float32
    # cumsum so both engines compare u against bit-identical thresholds
    probs = np.where(live,
                     (1.0 / np.maximum(deg_count, 1)[:, None])
                     .astype(np.float32),
                     np.float32(0.0)).astype(np.float32)
    slot_cdf = np.cumsum(probs, axis=1, dtype=np.float32)

    # rev_slot via one lexsort over the directed edge list: within each
    # destination block, the rank of (dst, src) is src's slot in dst's row
    src = np.repeat(np.arange(n, dtype=np.int64), deg_count)
    dst = np.concatenate([np.asarray(a, np.int64) for a in nbr_lists])
    slot = np.concatenate([np.arange(d, dtype=np.int64) for d in deg_count])
    order = np.lexsort((src, dst))
    block_start = np.concatenate([[0], np.cumsum(deg_count)[:-1]])
    rank = np.empty(len(src), np.int64)
    # scatter: unique targets (order is a permutation)
    rank[order] = np.arange(len(src)) - block_start[dst[order]]
    rev = np.zeros((n, k_max), np.int32)
    rev[src, slot] = rank  # scatter: unique targets
    for i in range(n):                   # pads copy the last real slot's rev
        rev[i, deg_count[i]:] = rev[i, deg_count[i] - 1]

    return NeighborTables(nbr_idx, rev, deg_count, nbr_w, nbr_p,
                          slot_cdf, deg_w)


def padded_neighbor_tables(graph, allow_isolated: bool = False
                           ) -> NeighborTables:
    """NeighborTables of a ``core.graph.Graph`` (small/medium n only).

    ``allow_isolated`` passes through to :func:`tables_from_adjacency`:
    graphs with zero-degree agents (e.g. a thresholded kernel graph that
    disconnected a point) are rejected by default, admitted as no-op rows
    when True.
    """
    W = np.asarray(graph.W)
    nbrs = [np.nonzero(W[i])[0] for i in range(W.shape[0])]
    wts = [W[i, nb] for i, nb in enumerate(nbrs)]
    return tables_from_adjacency(nbrs, wts, deg_w=W.sum(axis=1),
                                 allow_isolated=allow_isolated)


class DeviceTables(NamedTuple):
    """Device-resident mirror of NeighborTables (what jitted engines take)."""

    nbr_idx: jnp.ndarray
    rev_slot: jnp.ndarray
    deg_count: jnp.ndarray
    nbr_w: jnp.ndarray
    nbr_p: jnp.ndarray
    slot_cdf: jnp.ndarray
    deg_w: jnp.ndarray


def to_device(tables: NeighborTables, dtype=jnp.float32) -> DeviceTables:
    """Mirror host-side tables onto the default device (weights cast to
    ``dtype``)."""
    return DeviceTables(
        jnp.asarray(tables.nbr_idx), jnp.asarray(tables.rev_slot),
        jnp.asarray(tables.deg_count), jnp.asarray(tables.nbr_w, dtype),
        jnp.asarray(tables.nbr_p, dtype), jnp.asarray(tables.slot_cdf, dtype),
        jnp.asarray(tables.deg_w, dtype))


# ---------------------------------------------------------------------------
# Shared jnp building blocks (used verbatim by dense AND sparse engines)
# ---------------------------------------------------------------------------


def live_slots(deg_count, k_max: int):
    """(n, k_max) bool mask of live (non-pad) slots — ``slot < deg_count``.

    The expression every engine previously inlined; exposed so the joint
    graph-learning state (``w``, ``live``) initializes identically on the
    single-device and partitioned paths.
    """
    return jnp.arange(k_max)[None, :] < deg_count[:, None]


def sample_event(key, n: int, slot_cdf, deg_count):
    """One wake-up draw: (agent i, neighbor slot s) — paper §3.2 / §4.2.

    i is uniform over agents; the slot is drawn from pi_i by inverting the
    float32 slot cdf (clipped to the live range so pads are never selected).

    Degree-0 agents (``allow_isolated`` tables) have an all-zero cdf: the
    raw clamp ``min(s, deg - 1)`` would yield -1, which wraps via negative
    indexing into the last pad slot and fabricates a phantom edge.  The slot
    is therefore clamped to [0, max(deg - 1, 0)] and every consumer must
    treat an event with ``deg_count[i] == 0`` as a no-op (the engines
    redirect their scatters out of bounds, where they are dropped).
    """
    ki, kj = jax.random.split(key)
    i = jax.random.randint(ki, (), 0, n)
    u = jax.random.uniform(kj)
    s = jnp.searchsorted(slot_cdf[i], u, side="right").astype(jnp.int32)
    s = jnp.maximum(jnp.minimum(s, deg_count[i] - 1), 0)
    return i, s


def record_chunks(steps: int, record_every: int) -> tuple:
    """The repo-wide recording policy for chunked scan engines.

    Every engine that records one snapshot per ``record_every`` steps uses

        record_every, n_rec = record_chunks(steps, record_every)

    and runs exactly ``n_rec * record_every`` steps: ``record_every`` is
    clamped to ``[1, steps]`` and the horizon is floored to a whole number
    of chunks.  This guarantees the run is never silently empty
    (``steps < record_every`` previously yielded ``n_rec = 0`` — zero steps
    and an empty history) and never overruns the requested horizon
    (``max(1, steps // record_every)`` previously ran a full oversized
    chunk).  Non-divisible ``steps`` are floored; traces report the actual
    count.  ``steps < 1`` raises.
    """
    if steps < 1:
        raise ValueError(f"steps must be >= 1, got {steps}")
    record_every = max(1, min(int(record_every), int(steps)))
    return record_every, steps // record_every


def neighbor_aggregate(w_slots, theta_slots,
                       backend: Optional[ReproBackend] = None):
    """sum_s w[s] * theta[s]  over the k_max slot axis: (k,), (k, p) -> (p,).

    The single shared reduction both engines use — same shapes, same HLO,
    bit-identical result (pad slots contribute an exact 0.0 * value).
    Dispatched through ``kernels.dispatch`` ("neighbor_aggregate" op); both
    engines must pass the same ``backend`` to keep their trajectories
    bit-identical.
    """
    return resolve("neighbor_aggregate", backend)(w_slots, theta_slots)


def batched_model_update(nbr_p_rows, K_rows, c_rows, sol_rows, alpha,
                         backend: Optional[ReproBackend] = None):
    """Eq. (6) model update for a batch of agents' slot rows.

    nbr_p_rows: (B, k) stochastic weights; K_rows: (B, k, p) neighbor
    models; c_rows: (B,) confidences; sol_rows: (B, p) solitary models.
    Returns the (B, p) updated models

        theta_i = (alpha * sum_s P[i,s] K[i,s] + (1-alpha) c_i sol_i)
                  / (alpha + (1-alpha) c_i)

    This is THE per-shard step: the single-device scenario engine applies
    it to rows of its global (n, k, p) state, the partitioned engine
    (``simulate.partition``) to rows of each shard's local block, and the
    dense references reach the same reduction through
    ``neighbor_aggregate`` — all dispatched through ``kernels.dispatch``,
    so the trajectories agree bit-for-bit whichever layout ran them.
    """
    agg = jax.vmap(lambda w_, K_: neighbor_aggregate(w_, K_, backend))(
        nbr_p_rows, K_rows)
    abar = 1.0 - alpha
    return (alpha * agg + abar * c_rows[:, None] * sol_rows) \
        / (alpha + abar * c_rows)[:, None]


def personalized_predict(theta_rows, x_rows):
    """Batched decode step of the personalization service (DESIGN.md §16).

    theta_rows: (B, p) personalized model rows — each user's current
    gossip-smoothed Eq. (6) / Eq. (7) model, snapshotted from the
    :class:`repro.serve.store.AgentStateStore`; x_rows: (B, p) feature
    rows.  Returns the (B,) predictions ``<theta_u, x_u>`` — the linear
    / mean-estimation model family of paper §5, evaluated for many users
    in one fused op.  This is the arithmetic the serve engine jits: one
    tick serves a whole batch of users from their own parameter rows.
    """
    return jnp.sum(theta_rows * x_rows, axis=-1)


def quadratic_primal_core(w, live, z_own_s, z_nbr_s, l_own_s, l_nbr_s,
                          D_l, m_l, sx, mu, rho,
                          backend: Optional[ReproBackend] = None):
    """Exact argmin of the CL-ADMM local Lagrangian for the quadratic loss,
    over one agent's slot row (block elimination; paper §4.2 step 1).

    w: (k,) raw edge weights (0 at pads); live: (k,) bool;
    z/l slices: (k, p) agent-l secondary/dual rows; D_l, m_l scalars;
    sx: (p,) sum of l's local samples.  Returns (theta_l (p,), theta_js (k, p)).

    Dispatched through ``kernels.dispatch`` ("admm_primal" op); the math
    lives in ``kernels.ref.quadratic_primal`` (reference) with a fused XLA
    variant selected by default.
    """
    return resolve("admm_primal", backend)(
        w, live, z_own_s, z_nbr_s, l_own_s, l_nbr_s, D_l, m_l, sx, mu, rho)


def batched_admm_primal(w_rows, live_rows, z_own_rows, z_nbr_rows,
                        l_own_rows, l_nbr_rows, D_rows, m_rows, sx_rows,
                        mu, rho, backend: Optional[ReproBackend] = None):
    """Quadratic CL-ADMM primal (paper §4.2 step 1) for a batch of agents'
    slot rows: all leading axes are the batch B; returns (theta (B, p),
    theta_js (B, k, p)).

    This is the per-shard ADMM step the scenario engines share: the
    single-device ``run_cl_scenario`` applies it to rows of its global
    (n, k, p) state and the partitioned engine to rows of each shard's
    local block, so the trajectories agree bit-for-bit whichever layout ran
    them (same property as ``batched_model_update`` for MP).

    Dispatched through "admm_primal": row-wise implementations are vmapped;
    ``*_sharded`` implementations consume the stacked rows directly.

    This closed form is also one PrimalSolver among several: the engines'
    ``primal=None`` default calls it directly, and
    ``core.primal.ExactQuadraticPrimal`` delegates here verbatim, while
    ``core.primal.InexactPrimal`` replaces it with B AdamW steps for
    nonquadratic losses / nonlinear agent models (DESIGN.md §18).
    """
    if backend is None:
        from repro.kernels.dispatch import _env_default
        backend = ReproBackend(default=_env_default())
    fn = resolve("admm_primal", backend)
    if backend.impl_for("admm_primal").endswith("_sharded"):
        return fn(w_rows, live_rows, z_own_rows, z_nbr_rows, l_own_rows,
                  l_nbr_rows, D_rows, m_rows, sx_rows, mu, rho)
    return jax.vmap(lambda w, lv, zo, zn, lo, ln, D, m, sx: fn(
        w, lv, zo, zn, lo, ln, D, m, sx, mu, rho))(
        w_rows, live_rows, z_own_rows, z_nbr_rows, l_own_rows, l_nbr_rows,
        D_rows, m_rows, sx_rows)


def admm_edge_halfstep(theta_own, k_own, l_own, l_nbr,
                       theta_pay, k_pay, l_own_pay, l_nbr_pay, rho):
    """One endpoint's half of the CL-ADMM edge update (paper §4.2 steps 2-3).

    The waking edge's endpoints exchange payloads (the partner's post-primal
    self model, its copy-of-me slot, and its two dual slots) and each side
    updates its OWN (Z_own, Z_nbr, L_own, L_nbr) slots.  All arrays are
    (..., p) slices for a batch of event sides:

      theta_own — this side's post-primal self model
      k_own     — this side's copy of the partner (its K slot)
      l_own / l_nbr — this side's dual slots for the edge
      *_pay     — the same four quantities from the partner's payload

    Returns (z_own, z_nbr, l_own_new, l_nbr_new).  With a fresh (current)
    payload the two sides compute bit-identical Z values and the step is
    exactly ``simulate.engines._sparse_edge_zl``; under staleness or
    one-sided drops the mirrored copies may diverge — the asynchronous
    regime DJAM (arXiv:1803.09737) analyzes.
    """
    z_own = 0.5 * ((l_own + l_nbr_pay) / rho + theta_own + k_pay)
    z_nbr = 0.5 * ((l_own_pay + l_nbr) / rho + theta_pay + k_own)
    l_own_new = l_own + rho * (theta_own - z_own)
    l_nbr_new = l_nbr + rho * (k_own - z_nbr)
    return z_own, z_nbr, l_own_new, l_nbr_new

"""Joint learning of the collaboration graph alongside the models
(DESIGN.md §13).

The source paper assumes the similarity graph is *given* (§2.1) and only
the models move.  Its natural successor — Zantedeschi, Bellet & Tommasi,
*Fully Decentralized Joint Learning of Personalized Models and
Collaboration Graphs* (arXiv:1901.08460) — alternates two block updates:

1. **model step** — the usual personalized update under the current graph
   (here: the paper's MP gossip Eq. (6), unchanged);
2. **graph step** — each agent i locally re-estimates its *outgoing* edge
   weights over a fixed candidate neighbor set from the dissimilarity of
   its model to its neighbor copies,

       w_i  <-  (1 - eta) w_i + eta argmin_{w in simplex} <w, d_i> + lam ||w||^2

   whose argmin is the sparse simplex projection of ``-d_i / (2 lam)``
   (the "edge_reweight" op in ``kernels.dispatch``).

DJAM (Almeida & Xavier, arXiv:1803.09737) analyzes exactly the
asynchronous wake-up machinery these steps ride on, which is why the joint
engines (``simulate.engines.run_joint_scenario`` and its sharded twin)
reuse the MP scenario substrate verbatim: the *candidate* slot tables stay
frozen (so the event process remains precomputable and replayable), while
the weights — and hence the mixing matrix — become per-round state.

Everything here is expressed over batches of agent *slot rows* so the
single-device engine (rows = all n agents) and the partitioned engine
(rows = one shard's local block) run the identical arithmetic — the same
bit-for-bit-by-construction property ``core.sparse.batched_model_update``
gives the model step.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.kernels.dispatch import ReproBackend, resolve

#: Distance placed at dead (padded / pruned) slots so they never enter the
#: projection support.  Finite (not inf) so sorts and cumsums stay NaN-free.
DEAD_DISTANCE = 1e30


def slot_sq_distances(theta_rows, K_rows, live_rows):
    """Per-slot squared model distances d[i, s] = ||theta_i - K[i, s]||^2.

    theta_rows: (B, p) own models; K_rows: (B, k, p) neighbor copies;
    live_rows: (B, k) bool.  Dead slots get :data:`DEAD_DISTANCE`.  This is
    the "model similarity" dissimilarity of Zantedeschi et al. (2019)
    computed from purely local state — agent i's own model and the copies
    already sitting in its neighbor slots — so the graph step needs no
    extra communication round.
    """
    d = jnp.sum((theta_rows[:, None, :] - K_rows) ** 2, axis=-1)
    return jnp.where(live_rows, d, DEAD_DISTANCE)


def reweight_rows(theta_rows, K_rows, w_rows, live_rows, *, eta: float,
                  lam: float, backend: Optional[ReproBackend] = None):
    """One graph step for a batch of agents' slot rows.

    Computes the local dissimilarities and applies the "edge_reweight" op
    (sparse simplex projection + convex blend; see ``kernels.ref``).  This
    is THE per-shard graph step: the single-device joint engine applies it
    to all n rows, the partitioned engine to each shard's local block, and
    the row-local arithmetic is identical either way.
    """
    d = slot_sq_distances(theta_rows, K_rows, live_rows)
    return resolve("edge_reweight", backend)(d, w_rows, live_rows,
                                             eta=eta, lam=lam)


def prune_rows(w_rows, live_rows, prune_eps: float):
    """Permanently drop slots whose learned weight fell to ``<= prune_eps``.

    Returns (w', live'): pruned slots leave the live mask *monotonically*
    (they can never rejoin — their distance is pinned at
    :data:`DEAD_DISTANCE`, so the projection can never revive them) and
    their weight is forced to an exact 0.  Monotone pruning is what makes
    halo re-compaction sound in the partitioned engine: a pruned
    cross-shard slot never needs its remote row again
    (``simulate.partition.run_joint_scenario_sharded``).
    """
    live = live_rows & (w_rows > prune_eps)
    return jnp.where(live, w_rows, 0.0), live


# ---------------------------------------------------------------------------
# Host-side: handing a learned graph back / measuring cluster recovery
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GraphRecovery:
    """Cluster-recovery metrics of a learned weight table (host-side).

    intra_recovered: fraction of planted intra-cluster candidate (directed)
        edges carrying weight > eps after learning;
    inter_suppressed: fraction of inter-cluster candidate edges driven to
        weight <= eps;
    inter_mass: share of total learned weight sitting on inter edges.
    """

    intra_recovered: float
    inter_suppressed: float
    inter_mass: float
    n_intra: int
    n_inter: int


def cluster_edge_recovery(nbr_idx, deg_count, w, labels,
                          eps: float = 1e-4) -> GraphRecovery:
    """Score a learned weight table against planted cluster labels.

    nbr_idx/deg_count: the *candidate* slot tables (``core.sparse``);
    w: (n, k) learned weights; labels: (n,) planted cluster ids.  The
    two-cluster acceptance bar (ISSUE 5) is ``intra_recovered >= 0.9``.
    """
    nbr_idx = np.asarray(nbr_idx)
    deg_count = np.asarray(deg_count)
    w = np.asarray(w)
    labels = np.asarray(labels)
    k = nbr_idx.shape[1]
    cand = np.arange(k)[None, :] < deg_count[:, None]          # (n, k)
    intra = cand & (labels[:, None] == labels[nbr_idx])
    inter = cand & ~intra
    on = w > eps
    n_intra = int(intra.sum())
    n_inter = int(inter.sum())
    total = float(w[cand].sum())
    return GraphRecovery(
        intra_recovered=float((on & intra).sum()) / max(n_intra, 1),
        inter_suppressed=float((~on & inter).sum()) / max(n_inter, 1),
        inter_mass=float(w[inter].sum()) / max(total, 1e-30),
        n_intra=n_intra, n_inter=n_inter)


def learned_weight_tables(tables, w, live):
    """Fold learned weights back into host-side ``NeighborTables``.

    tables: the candidate ``core.sparse.NeighborTables``; w/live: (n, k)
    learned weights + surviving-slot mask (device or host arrays).  Returns
    a new NeighborTables via :meth:`NeighborTables.with_weights`, usable by
    every fixed-graph engine (the learned rows are already row-stochastic,
    so ``nbr_p == nbr_w`` up to renormalization of pruned rows).
    """
    w = np.where(np.asarray(live), np.asarray(w, np.float64), 0.0)
    return tables.with_weights(w)

"""Global consensus baseline (paper Eq. 2): one model for everyone.

This is the objective solved by classic decentralized optimization
(Nedic & Ozdaglar 2009, Duchi et al. 2012, ...) and — at TPU scale — by
standard data-parallel training with gradient all-reduce. The paper's §5.2
shows it performs very poorly when agents have heterogeneous objectives;
we reproduce that, and the framework exposes it as ``coupling="consensus"``.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .losses import AgentData, LOSSES


@partial(jax.jit, static_argnames=("loss", "steps"))
def consensus_model(data: AgentData, loss: str = "hinge", steps: int = 500,
                    lr: float = 0.05, l2: float = 1e-4) -> jnp.ndarray:
    """Minimize sum_i L_i(theta) over a single shared theta."""
    loss_fn = LOSSES[loss]
    n, _, p = data.x.shape
    total = jnp.maximum(jnp.sum(data.mask), 1.0)

    def obj(theta):
        """Pooled objective: mean loss over every agent's samples."""
        per_agent = jax.vmap(lambda x, y, m: loss_fn(theta, x, y, m))(
            data.x, data.y, data.mask)
        return jnp.sum(per_agent) / total + 0.5 * l2 * jnp.sum(theta * theta)

    grad = jax.grad(obj)

    def step(theta, _):
        """One gradient-descent step on the pooled objective."""
        return theta - lr * grad(theta), None

    theta, _ = jax.lax.scan(step, jnp.zeros(p), None, length=steps)
    return theta


def consensus_mean(data: AgentData) -> jnp.ndarray:
    """Closed form for the quadratic loss: the global mean of all samples."""
    s = jnp.sum(data.x * data.mask[..., None], axis=(0, 1))
    return s / jnp.maximum(jnp.sum(data.mask), 1.0)

"""Pluggable CL-ADMM primal solvers (DESIGN.md §18).

The paper's ADMM derivation (§4.2) never requires the primal phase to be
solved exactly — only approximately.  This module makes the primal step
of the CL engines a *strategy*:

* :class:`ExactQuadraticPrimal` — the historical closed-form block
  elimination for the quadratic loss (``core.sparse.batched_admm_primal``
  unchanged; the default, and the bit-anchor for everything else);
* :class:`InexactPrimal` — B AdamW steps on the reduced local Lagrangian
  (the ``admm_primal_inexact`` dispatch op), supporting arbitrary
  differentiable losses and nonlinear agent models whose parameters ride
  the flat slot-row layout via ``models.flatten.ParamFlattener``.

Both are frozen (hashable) dataclasses so they travel through ``jax.jit``
static arguments of the scenario scans; everything traced (loss
callables, optimizer config) is resolved *at trace time* inside
``solve_batch``.  The contract every solver implements:

    solve_batch(w_rows (R, k), live_rows (R, k), z_own, z_nbr, l_own,
                l_nbr (R, k, p), D_rows (R,), m_rows (R,), sx_rows (R, q),
                xym, theta_rows (R, p), mu, rho, backend)
        -> (new_theta (R, p), theta_js (R, k, p))

where ``xym`` is the tuple of per-row local data ``(x (R, m, q),
y (R, m), mask (R, m))`` when ``needs_data`` is True and ``()``
otherwise, and ``theta_rows`` is the rows' round-start models (the
inexact solver's warm start).  The computation must be row-local — both
the single-device scan and the shard_map'd partition engine call it on
compacted row blocks.
"""

from __future__ import annotations

import dataclasses
from typing import Any, ClassVar, Optional

import jax
import jax.numpy as jnp

from repro.core.losses import AgentData, guarded_loss
from repro.core.sparse import batched_admm_primal
from repro.kernels.dispatch import resolve
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

_LOSS_NAMES = ("quadratic", "hinge", "logistic")


def flat_predictor(model):
    """``predict(theta_row (p,), x (m, q)) -> (m,)`` for a flattened agent
    model — the glue between the engines' slot rows and the model's pytree
    ``apply`` (used by the inexact primal, serving, and accuracy eval)."""
    flat = model.flattener()

    def predict(theta, xs):
        return model.apply(flat.unflatten(theta), xs)
    return predict


@dataclasses.dataclass(frozen=True)
class ExactQuadraticPrimal:
    """The paper's closed-form quadratic primal as a PrimalSolver.

    Delegates to ``core.sparse.batched_admm_primal`` with the rows'
    sufficient statistics (m_i, sum x) — the identical traced program the
    engines ran before primal solvers were pluggable, so passing this
    solver explicitly is bit-for-bit ``primal=None``.
    """

    needs_data: ClassVar[bool] = False

    def solve_batch(self, w_rows, live_rows, z_own, z_nbr, l_own, l_nbr,
                    D_rows, m_rows, sx_rows, xym, theta_rows, mu, rho,
                    backend=None):
        """Closed-form solve of the compacted rows (xym/theta unused)."""
        return batched_admm_primal(w_rows, live_rows, z_own, z_nbr, l_own,
                                   l_nbr, D_rows, m_rows, sx_rows, mu, rho,
                                   backend)


@dataclasses.dataclass(frozen=True)
class InexactPrimal:
    """DiNNO-style inexact primal: ``b_steps`` AdamW steps per wake-up on
    ``mu D_l loss(theta) + lambda-coupling + rho-consensus`` (the reduced
    local Lagrangian — see ``kernels.ref.inexact_primal``).

    ``model`` is a frozen agent model (``models.flatten.MLPAgent`` /
    ``LoRAAgent``) whose flat parameter rows the engines consensus-couple,
    or ``None`` for the flat linear/mean model (theta used directly).
    ``b_steps=None`` selects the provable B -> inf fixed point and is
    restricted to the quadratic loss with ``model=None`` — the
    configuration whose trajectories reproduce the exact primal (the
    anchor tests of tests/test_primal.py).
    """

    loss: str = "logistic"
    model: Any = None
    b_steps: Optional[int] = 8
    lr: float = 0.05
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8

    needs_data: ClassVar[bool] = True

    def __post_init__(self):
        if self.loss not in _LOSS_NAMES:
            raise ValueError(
                f"unknown loss {self.loss!r}; one of {_LOSS_NAMES}")
        if self.b_steps is None and (self.loss != "quadratic"
                                     or self.model is not None):
            raise ValueError(
                "b_steps=None is the closed-form B->inf limit, provable "
                "only for the quadratic loss with model=None")
        if self.model is not None and self.loss == "quadratic":
            raise ValueError("quadratic loss is mean estimation — it takes "
                             "no model")

    def opt_config(self) -> AdamWConfig:
        """Per-row AdamW (no decay/clip — the Lagrangian already couples;
        f32 moments keep the primal deterministic across backends)."""
        return AdamWConfig(lr=self.lr, b1=self.b1, b2=self.b2, eps=self.eps,
                           weight_decay=0.0, grad_clip=0.0,
                           moment_dtype=jnp.float32)

    def loss_fn(self):
        """The guarded local loss ``l(theta; x, y, mask)`` (flat params)."""
        if self.model is None:
            return guarded_loss(self.loss)
        return guarded_loss(self.loss, flat_predictor(self.model))

    def batch_local_loss(self, theta_all, x, y, mask):
        """(n,) guarded local losses — telemetry's Eq. 7 loss term."""
        return jax.vmap(self.loss_fn())(theta_all, x, y, mask)

    def solve_batch(self, w_rows, live_rows, z_own, z_nbr, l_own, l_nbr,
                    D_rows, m_rows, sx_rows, xym, theta_rows, mu, rho,
                    backend=None):
        """vmap the rowwise ``admm_primal_inexact`` op over the compacted
        rows (m_rows/sx_rows are the exact solver's sufficient statistics
        — unused here except by the b_steps=None closed form, which
        recomputes them row-locally from xym)."""
        fn = resolve("admm_primal_inexact", backend)
        loss_fn = self.loss_fn()
        opt = self.opt_config()
        b_steps = self.b_steps
        x, y, mask = xym

        def row(w, lv, zo, zn, lo, ln, d, xr, yr, mr, t0):
            return fn(w, lv, zo, zn, lo, ln, d, xr, yr, mr, t0, mu, rho,
                      loss_fn=loss_fn, b_steps=b_steps, opt=opt)
        return jax.vmap(row)(w_rows, live_rows, z_own, z_nbr, l_own, l_nbr,
                             D_rows, x, y, mask, theta_rows)


def solitary_adamw(data: AgentData, *, loss: str = "logistic", model=None,
                   steps: int = 200, opt: Optional[AdamWConfig] = None,
                   seed: int = 0, theta0=None, init_scale: float = 1.0):
    """Purely-local training: per-agent AdamW on the guarded local loss.

    The "no collaboration" baseline of the ``federated_moons`` acceptance
    experiment, and the ``theta_sol`` warm start nonlinear
    ``run_cl_scenario`` runs need (solvers inherit the slot-row width from
    it).  Returns the (n, p) flat parameter rows after ``steps`` updates.
    """
    if opt is None:
        opt = AdamWConfig(lr=0.05, weight_decay=0.0, grad_clip=0.0,
                          moment_dtype=jnp.float32)
    if model is None:
        loss_fn = guarded_loss(loss)
    else:
        loss_fn = guarded_loss(loss, flat_predictor(model))
    n = data.n
    if theta0 is None:
        if model is None:
            theta0 = jnp.zeros((n, data.x.shape[-1]), jnp.float32)
        else:
            flat = model.flattener()
            keys = jax.random.split(jax.random.PRNGKey(seed), n)
            theta0 = jax.vmap(
                lambda k: flat.flatten(model.init(k, init_scale)))(keys)
    grad = jax.vmap(jax.grad(loss_fn))

    @jax.jit
    def run(th0, x, y, mask):
        def step(carry, _):
            th, st = carry
            th, st, _ = adamw_update(grad(th, x, y, mask), st, th, opt)
            return (th, st), None
        (th, _), _ = jax.lax.scan(step, (th0, adamw_init(th0, opt)), None,
                                  length=steps)
        return th
    return run(theta0, data.x, data.y, data.mask)

"""Convex losses + solitary-model training (paper Eq. 1).

Datasets are padded to a common max size with a boolean mask so that the
whole agent population can be processed with vmap/scan (agents have widely
varying m_i by design — that unbalancedness is central to the paper).
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class AgentData:
    """Padded per-agent datasets.

    x: (n, m_max, p)   features (for mean estimation p-dim 'features' = samples)
    y: (n, m_max)      labels (+-1 for classification; unused for mean est.)
    mask: (n, m_max)   1.0 for real examples, 0.0 for padding
    """

    x: jnp.ndarray
    y: jnp.ndarray
    mask: jnp.ndarray

    @property
    def n(self) -> int:
        """Number of agents."""
        return self.x.shape[0]

    @property
    def counts(self) -> jnp.ndarray:
        """(n,) live-sample counts m_i (drives confidences, §2.2)."""
        return self.mask.sum(axis=1)


def pad_datasets(xs, ys=None) -> AgentData:
    """Stack variable-length per-agent datasets into an AgentData."""
    n = len(xs)
    m_max = max(1, max(len(x) for x in xs))
    p = 1
    for xi in xs:
        a = np.asarray(xi)
        if a.size:
            p = a.shape[1] if a.ndim > 1 else 1
            break
    x = np.zeros((n, m_max, p))
    y = np.zeros((n, m_max))
    mask = np.zeros((n, m_max))
    for i, xi in enumerate(xs):
        m = len(xi)
        if m:
            x[i, :m] = np.asarray(xi, dtype=np.float64).reshape(m, -1)
            mask[i, :m] = 1.0
            if ys is not None:
                y[i, :m] = np.asarray(ys[i], dtype=np.float64)
    return AgentData(jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.float32),
                     jnp.asarray(mask, jnp.float32))


# ---------------------------------------------------------------------------
# Losses  l(theta; x, y).  All return the SUM over the local dataset
# (paper Eq. 1: L_i(theta) = sum_j l(theta; x_j, y_j)).
# ---------------------------------------------------------------------------


def quadratic_loss(theta, x, y, mask):
    """Mean estimation: l(theta; x) = ||theta - x||^2 (paper §5.1)."""
    r = theta[None, :] - x
    return jnp.sum(mask * jnp.sum(r * r, axis=-1))


def hinge_loss(theta, x, y, mask):
    """l(theta; (x,y)) = max(0, 1 - y theta^T x) (paper §5.2)."""
    margins = 1.0 - y * (x @ theta)
    return jnp.sum(mask * jnp.maximum(0.0, margins))


def logistic_loss(theta, x, y, mask):
    """log(1 + exp(-y theta^T x)) — extra loss beyond the paper's two."""
    z = y * (x @ theta)
    return jnp.sum(mask * jnp.logaddexp(0.0, -z))


LOSSES = {"quadratic": quadratic_loss, "hinge": hinge_loss,
          "logistic": logistic_loss}


def masked_sum(vals, mask):
    """Sum ``vals`` over live rows with an exact-zero pad contribution.

    The single ``where`` suffices for the *value*; it also zeroes the pad
    rows' cotangent, so gradients through ``vals`` at pads are exactly 0
    provided ``vals`` itself was computed from sanitized (finite) inputs —
    pair with the input-side ``where`` as in :func:`guarded_loss`
    (the double-where pattern, DESIGN.md §18).
    """
    return jnp.sum(jnp.where(mask > 0, vals, 0.0))


def guarded_loss(loss: str, predict_fn=None):
    """Build the guarded local loss ``l(theta; x, y, mask)`` the inexact
    primal differentiates (DESIGN.md §18).

    Unlike the closed-form sums above — whose pad rows are benign only
    because ``pad_datasets`` zero-fills them — the returned callable
    applies the double-where pattern: pad rows of ``x``/``y`` are replaced
    with zeros *before* the model runs and the per-sample losses are
    masked *after*, so padding contributes an exactly-zero value AND
    gradient even if a caller feeds non-finite garbage in the pad slots.

    ``predict_fn(theta, x) -> (m,)`` scores a batch with the flat
    parameter row (e.g. a ``ParamFlattener``-backed MLP); ``None`` means
    the linear model ``x @ theta`` for hinge/logistic and mean estimation
    (``theta`` is the model itself) for quadratic.
    """
    if loss == "quadratic":
        if predict_fn is not None:
            raise ValueError("quadratic loss is mean estimation — theta is "
                             "the model; it takes no predict_fn")

        def quadratic(theta, x, y, mask):
            """Guarded ``sum_j mask_j ||theta - x_j||^2``."""
            xs = jnp.where(mask[:, None] > 0, x, 0.0)
            r = theta[None, :] - xs
            return masked_sum(jnp.sum(r * r, axis=-1), mask)
        return quadratic
    if loss not in ("hinge", "logistic"):
        raise ValueError(f"unknown loss {loss!r}; one of {tuple(LOSSES)}")
    hinge = loss == "hinge"

    def margin_loss(theta, x, y, mask):
        """Guarded hinge / logistic loss of ``predict_fn`` scores."""
        xs = jnp.where(mask[:, None] > 0, x, 0.0)
        ys = jnp.where(mask > 0, y, 0.0)
        f = xs @ theta if predict_fn is None else predict_fn(theta, xs)
        z = ys * f
        vals = jnp.maximum(0.0, 1.0 - z) if hinge \
            else jnp.logaddexp(0.0, -z)
        return masked_sum(vals, mask)
    return margin_loss


def total_loss(loss_fn, theta_all, data: AgentData):
    """Sum_i L_i(theta_i) for per-agent parameters theta_all (n, p)."""
    per_agent = jax.vmap(loss_fn)(theta_all, data.x, data.y, data.mask)
    return jnp.sum(per_agent)


# ---------------------------------------------------------------------------
# Solitary models (paper Eq. 1)
# ---------------------------------------------------------------------------


def solitary_mean(data: AgentData) -> jnp.ndarray:
    """Closed-form solitary model for the quadratic loss: the local mean.

    Agents with m_i = 0 get theta = 0 (their confidence will be ~0, so the
    value is irrelevant — it is fully overridden by propagation).
    """
    cnt = data.counts[:, None]
    s = jnp.sum(data.x * data.mask[..., None], axis=1)
    return jnp.where(cnt > 0, s / jnp.maximum(cnt, 1.0), 0.0)


@partial(jax.jit, static_argnames=("loss", "steps"))
def solitary_gd(data: AgentData, loss: str = "hinge", steps: int = 200,
                lr: float = 0.05, l2: float = 1e-3) -> jnp.ndarray:
    """Solitary models by (sub)gradient descent on the local loss.

    A small L2 term makes the hinge problem well-posed for tiny m_i
    (some agents have a single example).
    """
    loss_fn = LOSSES[loss]
    n, _, p = data.x.shape

    def agent_obj(theta, x, y, mask):
        """One agent's mean local loss over its live samples."""
        m = jnp.maximum(jnp.sum(mask), 1.0)
        return loss_fn(theta, x, y, mask) / m + 0.5 * l2 * jnp.sum(theta * theta)

    grad = jax.grad(agent_obj)

    def step(thetas, _):
        """One vmapped gradient-descent step, all agents at once."""
        g = jax.vmap(grad)(thetas, data.x, data.y, data.mask)
        return thetas - lr * g, None

    theta0 = jnp.zeros((n, p))
    thetas, _ = jax.lax.scan(step, theta0, None, length=steps)
    return thetas


def confidences_from_counts(counts, floor: float = 1e-3) -> jnp.ndarray:
    """c_i = m_i / max_j m_j (+ small constant when m_i = 0) — paper §3.1."""
    counts = jnp.asarray(counts, jnp.float32)
    c = counts / jnp.maximum(jnp.max(counts), 1.0)
    return jnp.clip(c, floor, 1.0)

"""Static (trace-time) telemetry configuration."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class TelemetryConfig:
    """Switch for the carry-resident metrics layer (DESIGN.md §14).

    Frozen/hashable so engines can take it as a ``jax.jit`` static
    argument: the metric accumulators (per-agent staleness counters, drop
    attribution, update counts, per-chunk objective snapshots) are traced
    into the scan only when ``enabled`` — with ``enabled=False`` (or the
    engines' default ``telemetry=None``) the compiled program is the exact
    pre-telemetry scan, which is the bit-for-bit anchor the parity tests
    pin.
    """

    enabled: bool = False


def telemetry_on(telemetry) -> bool:
    """Normalize the engines' ``telemetry`` kwarg (None = off) to a bool."""
    return telemetry is not None and telemetry.enabled

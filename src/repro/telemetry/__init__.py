"""Carry-resident run telemetry for the scenario engines (DESIGN.md §14).

The metrics layer the paper's convergence story needs: per-record-chunk
objective residuals (Eq. 3 / Eq. 7 local views), per-agent staleness
counters, drop attribution by ``NetworkConditions`` cause, halo payload
accounting for the sharded engines, and run manifests + JSONL emission so
``tools/trace_report.py`` can render any run after the fact.

Everything in-scan accumulates inside the jitted carry — no host
callbacks — and every per-agent metric is emitted as a full (n,) vector
per chunk and reduced host-side in canonical agent order, which is what
makes sharded and single-device telemetry *exactly* equal (the same
bit-for-bit strategy the engines themselves use).  With
``TelemetryConfig(enabled=False)`` (or ``telemetry=None``) the engines
trace the identical program they traced before telemetry existed.
"""

from .config import TelemetryConfig
from .frames import TelemetryFrames
from .manifest import backend_config_hash, build_manifest
from .metrics import (batch_drop_causes, cl_local_objective,
                      mp_local_objective, staleness_step,
                      stream_chunk_totals, stream_drop_causes)
from .report import (format_row, load_run, render_summary, trace_rows,
                     write_run)

__all__ = [n for n in dir() if not n.startswith("_")]

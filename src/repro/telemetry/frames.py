"""Host-side container for a run's accumulated metrics.

``TelemetryFrames`` is what the engines attach to their traces
(``SimTrace.telemetry``) when telemetry is enabled: per-record-chunk
per-agent vectors (objective residuals, staleness) plus cumulative
counters (updates, delivered, drop attribution, halo bytes).  All global
reductions — objective sums in float64, staleness percentiles — happen
here, in canonical agent order, so sharded and single-device runs reduce
identical vectors to identical summaries.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class TelemetryFrames:
    """Per-record-chunk metrics of one scenario run (DESIGN.md §14).

    rounds:          (n_rec,) global round index at each snapshot (the end
                     of each record chunk, 1-based)
    objective:       (n_rec, n) per-agent local objective residuals
                     (Eq. 3 / Eq. 7 views; ``metrics.mp_local_objective``
                     / ``metrics.cl_local_objective``)
    staleness:       (n_rec, n) int32 rounds since each agent last
                     absorbed a neighbor update, at each snapshot
    updates:         (n_rec,) cumulative applied model-update ops
    delivered / drop_link / drop_churn / drop_partition / invalid:
                     (n_rec,) cumulative message accounting, drops
                     attributed by cause (``metrics`` module docstring)
    halo_bytes:      (n_rec,) cumulative halo payload bytes published by
                     all shards (sharded runs; None on one device)
    overflow_per_shard: (P,) events that missed a shard's static buffers
                     (sharded runs; None on one device)
    suppressed:      (n_rec,) cumulative deliveries voided by a pruned
                     receiver slot (joint runs; None otherwise)
    serve_requests / serve_hits / serve_misses / serve_invalidations:
                     (n_rec,) cumulative personalization-service counters
                     (DESIGN.md §16) — requests served from each chunk's
                     committed snapshot, mixed-model cache hits/misses,
                     and cache entries invalidated by that chunk's
                     model-update deliveries (None without a serve stream)
    """

    rounds: np.ndarray
    objective: np.ndarray
    staleness: np.ndarray
    updates: np.ndarray
    delivered: np.ndarray
    drop_link: np.ndarray
    drop_churn: np.ndarray
    drop_partition: np.ndarray
    invalid: np.ndarray
    halo_bytes: Optional[np.ndarray] = None
    overflow_per_shard: Optional[np.ndarray] = None
    suppressed: Optional[np.ndarray] = None
    serve_requests: Optional[np.ndarray] = None
    serve_hits: Optional[np.ndarray] = None
    serve_misses: Optional[np.ndarray] = None
    serve_invalidations: Optional[np.ndarray] = None

    @property
    def n_records(self) -> int:
        """Number of record-chunk snapshots in the run."""
        return int(self.rounds.shape[0])

    def summarize(self) -> list:
        """One JSONL-ready dict per record chunk.

        The per-agent vectors are reduced here — and only here — in
        canonical agent order: ``objective`` is the float64 sum over
        agents, ``staleness_p50/p99/max`` are percentiles over agents.
        Identical vectors therefore reduce to identical rows whatever
        mesh produced them.
        """
        rows = []
        for t in range(self.n_records):
            obj = np.asarray(self.objective[t], np.float64)
            st = np.asarray(self.staleness[t], np.float64)
            row = {
                "round": int(self.rounds[t]),
                "objective": float(obj.sum()),
                "objective_mean": float(obj.mean()),
                "staleness_p50": float(np.percentile(st, 50)),
                "staleness_p99": float(np.percentile(st, 99)),
                "staleness_max": int(st.max()),
                "updates": int(self.updates[t]),
                "delivered": int(self.delivered[t]),
                "drop_link": int(self.drop_link[t]),
                "drop_churn": int(self.drop_churn[t]),
                "drop_partition": int(self.drop_partition[t]),
                "invalid": int(self.invalid[t]),
            }
            if self.halo_bytes is not None:
                row["halo_bytes"] = int(self.halo_bytes[t])
            if self.suppressed is not None:
                row["suppressed"] = int(self.suppressed[t])
            if self.serve_requests is not None:
                row["serve_requests"] = int(self.serve_requests[t])
                row["serve_hits"] = int(self.serve_hits[t])
                row["serve_misses"] = int(self.serve_misses[t])
                row["serve_invalidations"] = int(self.serve_invalidations[t])
            rows.append(row)
        if self.overflow_per_shard is not None and rows:
            rows[-1]["overflow_per_shard"] = [
                int(v) for v in np.asarray(self.overflow_per_shard)]
        return rows

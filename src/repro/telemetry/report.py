"""Turn traces into JSONL runs and render them back as text.

The on-disk layout of a recorded run is one directory with two files:

    manifest.json   — what produced the run (:mod:`repro.telemetry.manifest`)
    metrics.jsonl   — one JSON object per record chunk
                      (:meth:`TelemetryFrames.summarize` rows)

``write_run``/``load_run`` are the only code that touches that layout;
``tools/trace_report.py`` and the demos render through ``format_row`` /
``render_summary`` so every CLI prints runs the same way.
"""

from __future__ import annotations

import json
import os
from typing import Optional


def trace_rows(trace) -> list:
    """JSONL-ready rows for any engine trace (Sim/CLSim/JointSimTrace).

    With telemetry enabled the rows are the frames'
    :meth:`~repro.telemetry.frames.TelemetryFrames.summarize` output; a
    telemetry-less trace still yields one terminal row from the trace's
    own accounting counters, so report paths work on any run.
    """
    frames = getattr(trace, "telemetry", None)
    if frames is not None:
        return frames.summarize()
    row = {
        "round": int(trace.rounds),
        "delivered": int(trace.delivered),
        "dropped": int(trace.dropped),
        "invalid": int(trace.invalid),
        "events": int(trace.events),
    }
    suppressed = getattr(trace, "suppressed", None)
    if suppressed is not None:
        row["suppressed"] = int(suppressed)
    return [row]


def format_row(row: dict) -> str:
    """One fixed-width text line for a metrics row."""
    parts = [f"round {row['round']:>6d}"]
    if "objective" in row:
        parts.append(f"obj {row['objective']:.6e}")
    if "staleness_p50" in row:
        parts.append(f"stale p50/p99 {row['staleness_p50']:.0f}/"
                     f"{row['staleness_p99']:.0f}")
    if "delivered" in row:
        parts.append(f"delivered {row['delivered']}")
    drops = [row.get(k, 0) for k in
             ("drop_link", "drop_churn", "drop_partition")]
    if any(k in row for k in
           ("drop_link", "drop_churn", "drop_partition")):
        parts.append("drops l/c/p {}/{}/{}".format(*drops))
    elif "dropped" in row:
        parts.append(f"dropped {row['dropped']}")
    if "halo_bytes" in row:
        parts.append(f"halo {row['halo_bytes']}B")
    if "suppressed" in row:
        parts.append(f"suppressed {row['suppressed']}")
    return "  ".join(parts)


def render_summary(manifest: Optional[dict], rows: list) -> str:
    """Multi-line text report of a run: manifest header + metric lines.

    Long runs are elided to the first/last few record chunks; the final
    row additionally gets a convergence/staleness recap so a glance shows
    where the run ended up.
    """
    lines = []
    if manifest:
        mesh = manifest.get("mesh_shape")
        lines.append("run: backend={} mesh={} seed={} rev={} jax={}".format(
            manifest.get("backend_hash"),
            "x".join(map(str, mesh)) if mesh else "single-device",
            manifest.get("seed"), manifest.get("git_rev"),
            manifest.get("jax_version")))
    shown = rows if len(rows) <= 8 else rows[:3] + [None] + rows[-3:]
    for row in shown:
        lines.append("  ..." if row is None else "  " + format_row(row))
    if rows:
        last = rows[-1]
        total_drops = sum(last.get(k, 0) for k in
                          ("drop_link", "drop_churn", "drop_partition"))
        lines.append(
            "final: delivered={} dropped={} invalid={}".format(
                last.get("delivered"), total_drops or last.get("dropped"),
                last.get("invalid")))
        if "objective" in last and len(rows) > 1:
            first = rows[0]
            lines.append(
                "convergence: objective {:.6e} -> {:.6e}".format(
                    first["objective"], last["objective"]))
        if "staleness_max" in last:
            lines.append("staleness: p50={:.0f} p99={:.0f} max={}".format(
                last["staleness_p50"], last["staleness_p99"],
                last["staleness_max"]))
        if "overflow_per_shard" in last:
            lines.append("overflow_per_shard: {}".format(
                last["overflow_per_shard"]))
    return "\n".join(lines)


def write_run(run_dir: str, manifest: dict, rows: list) -> str:
    """Persist a run as ``<run_dir>/manifest.json`` + ``metrics.jsonl``."""
    os.makedirs(run_dir, exist_ok=True)
    with open(os.path.join(run_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
        f.write("\n")
    with open(os.path.join(run_dir, "metrics.jsonl"), "w") as f:
        for row in rows:
            f.write(json.dumps(row, sort_keys=True) + "\n")
    return run_dir


def load_run(run_dir: str) -> tuple:
    """Read back (manifest, rows) written by :func:`write_run`.

    A missing manifest yields ``(None, rows)`` so partial runs still
    render.
    """
    manifest_path = os.path.join(run_dir, "manifest.json")
    manifest = None
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)
    rows = []
    with open(os.path.join(run_dir, "metrics.jsonl")) as f:
        for line in f:
            line = line.strip()
            if line:
                rows.append(json.loads(line))
    return manifest, rows

"""Run manifests: what produced a metrics file, hashed for comparison.

A manifest pins everything needed to interpret (or re-run) a recorded
scenario: the kernel backend configuration and its hash, the device mesh
shape, the RNG seed, the git revision, and the library versions.  It is
deliberately a plain JSON-able dict — ``report.write_run`` drops it next
to the metrics JSONL and ``tools/trace_report.py`` reads it back.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import platform
import subprocess
from typing import Optional


def _as_jsonable(obj):
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        return {k: _as_jsonable(v)
                for k, v in dataclasses.asdict(obj).items()}
    if isinstance(obj, dict):
        return {str(k): _as_jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_as_jsonable(v) for v in obj]
    return str(obj)


def backend_config_hash(backend) -> str:
    """Short stable hash of a kernel backend config (or any dataclass).

    Canonical JSON (sorted keys) -> sha256 -> first 12 hex chars; two runs
    share a hash iff their backend selections match field-for-field.
    """
    blob = json.dumps(_as_jsonable(backend), sort_keys=True)
    return hashlib.sha256(blob.encode()).hexdigest()[:12]


def _git_rev() -> Optional[str]:
    try:
        out = subprocess.run(
            ["git", "rev-parse", "--short=12", "HEAD"],
            capture_output=True, text=True, timeout=5)
        rev = out.stdout.strip()
        return rev if out.returncode == 0 and rev else None
    except (OSError, subprocess.SubprocessError):
        return None


def build_manifest(backend=None, mesh_shape=None, seed=None,
                   extra: Optional[dict] = None) -> dict:
    """Assemble the run manifest dict.

    backend: the kernel BackendConfig (or None for library defaults);
    mesh_shape: device-mesh shape tuple for sharded runs (None on one
    device); seed: the scenario RNG seed; extra: caller-specific fields
    (scenario name, conditions, sizes) merged in last.
    """
    import jax

    manifest = {
        "backend_config": _as_jsonable(backend),
        "backend_hash": backend_config_hash(backend),
        "mesh_shape": list(mesh_shape) if mesh_shape is not None else None,
        "seed": seed,
        "git_rev": _git_rev(),
        "jax_version": jax.__version__,
        "platform": platform.platform(),
        "device_count": jax.device_count(),
    }
    if extra:
        manifest.update(_as_jsonable(extra))
    return manifest

"""In-scan metric expressions + host-side stream reductions.

The in-scan helpers (:func:`mp_local_objective`, :func:`cl_local_objective`,
:func:`staleness_step`, :func:`batch_drop_causes`) are written as *row-local*
jnp expressions: each agent's contribution reads only that agent's own slot
row, so the sharded engines can apply the identical arithmetic to their
local (m, ...) blocks and the reassembled (n,) vectors are bit-for-bit the
single-device ones — the same parity strategy as the engines' model
updates (``core.sparse``).  Global reductions (objective sums, staleness
percentiles) happen host-side in canonical agent order
(:mod:`repro.telemetry.frames`), never inside the scan, so float summation
order cannot differ between mesh shapes.

The stream reductions (:func:`stream_drop_causes`,
:func:`stream_chunk_totals`) attribute every counted drop of a
materialized ``EventStream`` to its ``NetworkConditions`` cause using the
stream's ``cut``/``dead`` flags (recorded by ``scheduler.draw_events``
from the same draws that decided delivery — no extra RNG):

    partition — the pair straddled an active partition window
    churn     — otherwise, an endpoint was churned out
    link      — otherwise, the iid per-direction message loss

Causes are disjoint and exhaustive over counted drops, so
``link + churn + partition == dropped`` for every run.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# in-scan, row-local metric expressions
# ---------------------------------------------------------------------------


def mp_local_objective(theta, K, w, c, theta_sol, alpha: float):
    """Per-agent local view of the MP objective (paper Eq. 3) from slot rows.

    obj_i = alpha * sum_s w[i, s] ||theta_i - K[i, s]||^2
            + (1 - alpha) * c_i ||theta_i - theta_sol_i||^2

    ``w`` is the row-stochastic mixing weight table (``tabs.nbr_p``, or the
    learned weights of the joint engine — pruned/pad slots carry weight 0,
    so they contribute nothing).  The smoothness term reads the agent's
    *copies* ``K`` rather than true neighbor models — the quantity a
    decentralized agent can actually observe; with fresh copies it equals
    the Eq. 3 disagreement term up to the alpha/mu reparametrization.
    Shapes: theta (rows, p), K (rows, k, p), w (rows, k), c (rows,),
    theta_sol (rows, p) -> (rows,) float32.
    """
    d = theta[:, None, :] - K
    smooth = jnp.sum(w * jnp.sum(d * d, axis=-1), axis=-1)
    r = theta - theta_sol
    anchor = c * jnp.sum(r * r, axis=-1)
    return alpha * smooth + (1.0 - alpha) * anchor


def cl_local_objective(theta, K, nbr_w, live, D, m_counts, sx, sxx,
                       mu: float):
    """Per-agent local view of the CL objective (paper Eq. 7, quadratic).

    obj_i = 0.5 * sum_s W[i, s] ||theta_i - K[i, s]||^2
            + mu * D_i * L_i(theta_i)

    with the quadratic loss expanded through the engines' own sufficient
    statistics: L_i(theta) = m_i ||theta||^2 - 2 theta . sx_i + sxx_i
    (sxx_i = sum_k mask ||x_k||^2 is the one statistic the engines don't
    already carry; the telemetry path threads it in).  Row-local like
    :func:`mp_local_objective`.  Shapes: theta (rows, p), K (rows, k, p),
    nbr_w (rows, k), live (rows, k) bool, D/m_counts/sxx (rows,),
    sx (rows, p) -> (rows,) float32.
    """
    d = theta[:, None, :] - K
    wl = jnp.where(live, nbr_w, 0.0)
    smooth = 0.5 * jnp.sum(wl * jnp.sum(d * d, axis=-1), axis=-1)
    loss = (m_counts * jnp.sum(theta * theta, axis=-1)
            - 2.0 * jnp.sum(theta * sx, axis=-1) + sxx)
    return smooth + mu * D * loss


def cl_local_objective_from_loss(theta, K, nbr_w, live, D, loss_vec,
                                 mu: float):
    """:func:`cl_local_objective` for arbitrary losses (DESIGN.md §18).

    Nonlinear agents have no (m, sx, sxx) sufficient statistic, so the
    engines evaluate ``loss_vec[i] = L_i(theta_i)`` directly (the inexact
    primal's guarded loss, vmapped over agents) and only the consensus
    term is computed here.  Row-local; shapes as in
    :func:`cl_local_objective` with loss_vec (rows,) -> (rows,) float32.
    """
    d = theta[:, None, :] - K
    wl = jnp.where(live, nbr_w, 0.0)
    smooth = 0.5 * jnp.sum(wl * jnp.sum(d * d, axis=-1), axis=-1)
    return smooth + mu * D * loss_vec


def staleness_step(stale, got, rows, n_rows: int):
    """One round of per-agent staleness counters.

    ``stale`` (n_rows,) int32 counts rounds since each agent last absorbed
    a neighbor update; an agent listed in ``rows`` with ``got`` True
    resets to 0, everyone else ages by one.  ``rows`` may repeat and may
    contain out-of-range padding (scattered with mode="drop"), matching
    exactly the engines' own theta-update scatter condition.
    """
    # scatter: idempotent — every delivered row writes True
    recv = jnp.zeros((n_rows,), bool).at[
        jnp.where(got, rows, n_rows)].set(True, mode="drop")
    return jnp.where(recv, 0, stale + 1).astype(jnp.int32)


def batch_drop_causes(deliver_ij, deliver_ji, valid, cut, dead):
    """(link, churn, partition) int32 drop counts for one event batch.

    Counts both directions of every *valid* event whose message was lost,
    attributed by the disjoint priority partition > churn > link (see the
    module docstring).  The same expression :func:`stream_drop_causes`
    applies host-side, so inline-engine counters and stream reductions
    always agree.
    """
    link = jnp.int32(0)
    churn = jnp.int32(0)
    part = jnp.int32(0)
    for deliver in (deliver_ij, deliver_ji):
        drop = valid & ~deliver
        part += jnp.sum(drop & cut)
        churn += jnp.sum(drop & ~cut & dead)
        link += jnp.sum(drop & ~cut & ~dead)
    return link, churn, part


# ---------------------------------------------------------------------------
# host-side reductions over materialized event streams
# ---------------------------------------------------------------------------


def stream_drop_causes(stream) -> tuple:
    """Total (link, churn, partition) drop attribution of an EventStream."""
    valid = np.asarray(stream.valid)
    cut = np.asarray(stream.cut)
    dead = np.asarray(stream.dead)
    link = churn = part = 0
    for deliver in (np.asarray(stream.deliver_ij),
                    np.asarray(stream.deliver_ji)):
        drop = valid & ~deliver
        part += int((drop & cut).sum())
        churn += int((drop & ~cut & dead).sum())
        link += int((drop & ~cut & ~dead).sum())
    return link, churn, part


def stream_dirty_chunks(stream, n: int, n_rec: int,
                        record_every: int) -> np.ndarray:
    """(n_rec, n) bool: which agents' models changed in each record chunk.

    An agent is *dirty* in a chunk when any event of the chunk delivered a
    message to it — ``deliver_ji`` marks waker ``i`` a receiver,
    ``deliver_ij`` marks neighbor ``j`` — which is exactly the condition
    under which the engines scatter a new theta row (their ``got`` mask:
    the deliver flags already fold churned-out endpoints).  This is the
    cache-invalidation signal of the personalization service
    (DESIGN.md §16): a served model cached before the chunk stays valid
    iff its agent is clean.  For joint graph-learning runs with pruning
    the set is conservative (a delivery voided by a pruned receiver slot
    still marks its target dirty) — over-invalidation is always safe.
    """
    def _chunked(x):
        return np.asarray(x).reshape(n_rec, record_every, -1)

    i, j = _chunked(stream.i), _chunked(stream.j)
    d_ij, d_ji = _chunked(stream.deliver_ij), _chunked(stream.deliver_ji)
    dirty = np.zeros((n_rec, n), bool)
    rows = np.repeat(np.arange(n_rec), record_every * i.shape[-1])
    # scatter only the delivering events
    for recv, d in ((i, d_ji), (j, d_ij)):
        hit = d.ravel()
        # scatter: idempotent — duplicate (row, agent) targets all write True
        dirty[rows[hit], recv.ravel()[hit]] = True
    return dirty


def stream_staleness_chunks(stream, n: int, n_rec: int,
                            record_every: int) -> np.ndarray:
    """(n_rec, n) int32 per-agent staleness at the end of each record chunk.

    The host-side replay of :func:`staleness_step` over a materialized
    stream: after round t (0-based), an agent that last absorbed an
    update in round ``t0`` counts ``t - t0`` rounds of staleness, an
    agent that never received counts ``t + 1``.  Bit-identical to the
    in-scan counters the telemetry path accumulates (the serve driver
    uses this so served-staleness reporting needs no telemetry opt-in).
    """
    # within a chunk the *last* receiving round decides; replay per round
    i = np.asarray(stream.i).reshape(n_rec, record_every, -1)
    j = np.asarray(stream.j).reshape(n_rec, record_every, -1)
    d_ij = np.asarray(stream.deliver_ij).reshape(n_rec, record_every, -1)
    d_ji = np.asarray(stream.deliver_ji).reshape(n_rec, record_every, -1)
    last = np.full(n, -1, np.int64)
    out = np.empty((n_rec, n), np.int32)
    for ci in range(n_rec):
        for t in range(record_every):
            g = ci * record_every + t
            last[i[ci, t][d_ji[ci, t]]] = g  # scatter: idempotent
            last[j[ci, t][d_ij[ci, t]]] = g  # scatter: idempotent
        end = (ci + 1) * record_every - 1
        out[ci] = np.where(last >= 0, end - last, end + 1).astype(np.int32)
    return out


def stream_chunk_totals(stream, n_rec: int, record_every: int) -> dict:
    """Cumulative per-record-chunk accounting of an EventStream.

    Returns (n_rec,) int64 arrays — delivered, drop_link, drop_churn,
    drop_partition, invalid — each cumulative up to the end of its chunk,
    so the last entries equal ``stream_totals`` + :func:`stream_drop_causes`
    of the whole stream.
    """
    def _chunked(x):
        return np.asarray(x).reshape(n_rec, record_every, -1)

    d_ij, d_ji = _chunked(stream.deliver_ij), _chunked(stream.deliver_ji)
    valid = _chunked(stream.valid)
    cut, dead = _chunked(stream.cut), _chunked(stream.dead)
    link = np.zeros(n_rec, np.int64)
    churn = np.zeros(n_rec, np.int64)
    part = np.zeros(n_rec, np.int64)
    for deliver in (d_ij, d_ji):
        drop = valid & ~deliver
        part += (drop & cut).sum(axis=(1, 2))
        churn += (drop & ~cut & dead).sum(axis=(1, 2))
        link += (drop & ~cut & ~dead).sum(axis=(1, 2))
    return {
        "delivered": np.cumsum(d_ij.sum(axis=(1, 2))
                               + d_ji.sum(axis=(1, 2))),
        "drop_link": np.cumsum(link),
        "drop_churn": np.cumsum(churn),
        "drop_partition": np.cumsum(part),
        "invalid": np.cumsum((~valid).sum(axis=(1, 2))),
    }

"""The paper's algorithms as cross-agent coupling strategies at pod scale.

Each data-parallel row of the mesh is an *agent* with personalized parameters
(leading agent dim A on every leaf). After local optimizer updates, a coupling
strategy mixes parameters across the agent axis:

  mode="none"       solitary training (paper Eq. 1 baseline)
  mode="consensus"  uniform averaging == gradient all-reduce fixed point
                    (paper Eq. 2 baseline — what the paper argues *against*)
  mode="mp"         model propagation: one Eq. (5) iterate per application,
                    anchored at a maintained "solitary" snapshot with
                    per-agent confidences (paper §3)
  mode="cl"         collaborative learning: the Q_CL coupling term (paper §4).
                    Default realization is a Laplacian proximal pull
                    (exact gradient of the smoothness term); the full
                    ADMM realization with per-edge Z/Lambda state is
                    available as ``cl_admm`` (costs 4x edge-param memory).

Two communication schedules realize the SAME mixing operator (DESIGN.md §2):

  schedule="dense"   einsum over the agent axis -> XLA lowers to all-gather.
                     This is the paper-faithful *synchronous* operator.
  schedule="gossip"  the paper's pairwise-exchange pattern: the graph is
                     edge-colored into matchings; each matching is executed
                     as paired collective_permutes and partial sums are
                     accumulated — after cycling all matchings the result
                     EQUALS the dense operator (tests/test_coupling.py),
                     but no all-gather ever materializes: peak comm buffer
                     is one neighbor slice instead of A-1.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.graph import Graph
from repro.kernels.dispatch import ReproBackend, resolve


def _shard_map(f, *, mesh, in_specs, out_specs):
    """Version-compat shard_map: jax.shard_map (new, check_vma) or
    jax.experimental.shard_map.shard_map (jax <= 0.4.x, check_rep)."""
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_vma=False)
    from jax.experimental.shard_map import shard_map as sm
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_rep=False)


@dataclasses.dataclass(frozen=True)
class CouplingConfig:
    mode: str = "mp"              # none | consensus | mp | cl | cl_admm
    schedule: str = "dense"       # dense | gossip
    alpha: float = 0.99           # MP trade-off (mu = (1-alpha)/alpha)
    mu: float = 0.01              # CL trade-off
    rho: float = 1.0              # ADMM penalty
    every: int = 1                # apply every k optimizer steps
    use_kernel: bool = False      # deprecated: force the Pallas "mix" impl
    mix_dtype: Any = jnp.float32  # wire dtype for cross-agent traffic
    # kernels.dispatch.ReproBackend choosing the "mix" implementation
    # (None = platform auto: Pallas compiled on TPU, fused XLA elsewhere)
    backend: Optional[ReproBackend] = None

    def mix_backend(self) -> Optional[ReproBackend]:
        if self.backend is not None:
            return self.backend
        if self.use_kernel:
            return ReproBackend.using(
                mix="pallas",
                interpret=None if jax.default_backend() == "tpu" else True)
        return None


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class CouplingState:
    """Per-run mixing operators (device-resident pytree).

    The gossip matching schedule (``send_to``) is *static* host data — it
    parameterizes collective_permute patterns, which must be known at trace
    time — so it lives in metadata, not as an array leaf.
    """
    A_mix: jnp.ndarray            # (A, A)  diag(alpha/(alpha+abar c)) P  (mp)
    b_anchor: jnp.ndarray         # (A,)    abar c / (alpha + abar c)     (mp)
    W: jnp.ndarray                # (A, A)  raw weights (cl)
    # (M, A) int32 host array: partner id per matching round (-1 = idle)
    send_to: tuple = dataclasses.field(metadata=dict(static=True),
                                       default=())


def mp_matrices(graph: Graph, confidences, alpha: float):
    """Eq. (5) as out = A_mix @ theta + b_anchor * theta_sol."""
    c = np.asarray(confidences, np.float64)
    abar = 1.0 - alpha
    denom = alpha + abar * c
    A_mix = (alpha / denom)[:, None] * np.asarray(graph.P)
    b = abar * c / denom
    return A_mix.astype(np.float32), b.astype(np.float32)


def make_state(graph: Graph, confidences=None, alpha: float = 0.99) -> CouplingState:
    n = graph.n
    if confidences is None:
        confidences = np.ones(n)
    A_mix, b = mp_matrices(graph, confidences, alpha)
    matchings = graph.edge_coloring()
    send_to = np.full((len(matchings), n), -1, np.int32)
    for m, pairs in enumerate(matchings):
        for (i, j) in pairs:
            send_to[m, i] = j
            send_to[m, j] = i
    return CouplingState(
        A_mix=jnp.asarray(A_mix), b_anchor=jnp.asarray(b),
        W=jnp.asarray(graph.W, jnp.float32),
        send_to=tuple(map(tuple, send_to.tolist())))


# ---------------------------------------------------------------------------
# Mixing operators over (A, ...) stacked pytrees
# ---------------------------------------------------------------------------


def _per_leaf(fn, *trees):
    return jax.tree_util.tree_map(fn, *trees)


def dense_mix_tree(params, solitary, state: CouplingState,
                   cfg: CouplingConfig):
    """out = A_mix @ theta + b * theta_sol per leaf, via the "mix" op.

    The implementation (fused XLA einsum, Pallas kernel compiled or
    interpret) is resolved through ``kernels.dispatch`` from
    ``cfg.backend`` — platform auto when None.  All operands (including
    A_mix) are quantized to ``cfg.mix_dtype`` as the wire format; the
    impls accumulate in float32.
    """
    A_mix = state.A_mix.astype(cfg.mix_dtype)
    b = state.b_anchor
    mix_fn = resolve("mix", cfg.mix_backend())

    def mix(leaf, sol):
        n = leaf.shape[0]
        out = mix_fn(leaf.reshape(n, -1).astype(cfg.mix_dtype),
                     sol.reshape(n, -1).astype(cfg.mix_dtype),
                     A_mix, b)
        return out.reshape(leaf.shape).astype(leaf.dtype)

    return _per_leaf(mix, params, solitary)


def gossip_mix_tree(params, solitary, state: CouplingState,
                    cfg: CouplingConfig, axis_names: Tuple[str, ...]):
    """Same operator as dense_mix_tree, via matching-scheduled ppermute.

    Must be called INSIDE shard_map over ``axis_names`` (the agent axes) with
    per-agent slices (leading dim 1 stripped by the caller). Accumulates
    sum_j A_mix[i, j] theta_j one matching at a time; no all-gather.
    """
    send_to = np.asarray(state.send_to, np.int32)  # (M, A) static
    M, A = send_to.shape
    idx = jax.lax.axis_index(axis_names)

    def mix(leaf, sol):
        acc = state.A_mix[idx, idx] * leaf.astype(cfg.mix_dtype)  # self term
        for m in range(M):
            partner = send_to[m]                   # (A,) static int32
            perm = [(int(s), int(d)) for s, d in enumerate(partner) if d >= 0]
            if not perm:
                continue
            recv = jax.lax.ppermute(leaf.astype(cfg.mix_dtype),
                                    axis_name=axis_names, perm=perm)
            pvec = jnp.asarray(partner)
            w = state.A_mix[idx, pvec[idx]]
            w = jnp.where(pvec[idx] >= 0, w, 0.0)
            acc = acc + w * recv
        anchored = state.b_anchor[idx] * sol.astype(cfg.mix_dtype)
        return (acc + anchored).astype(leaf.dtype)

    return _per_leaf(mix, params, solitary)


def consensus_mean_tree(params, cfg: CouplingConfig):
    """Uniform average over the agent axis (Eq. 2 baseline)."""
    def mix(leaf):
        return jnp.broadcast_to(
            jnp.mean(leaf.astype(cfg.mix_dtype), axis=0, keepdims=True,
                     dtype=jnp.float32),
            leaf.shape).astype(leaf.dtype)
    return _per_leaf(mix, params)


def laplacian_pull_tree(params, state: CouplingState, cfg: CouplingConfig,
                        lr: float):
    """CL smoothness-term gradient step (paper §4 objective, SGD realization):

        theta_i <- theta_i - lr * 2 sum_j W_ij (theta_i - theta_j)

    Exactly the gradient of sum_{i<j} W_ij ||theta_i - theta_j||^2. Combined
    with the local-loss optimizer step this is decentralized SGD on Q_CL.
    """
    W = state.W.astype(cfg.mix_dtype)
    deg = W.sum(axis=1, dtype=jnp.float32)

    def mix(leaf):
        lf = leaf.astype(cfg.mix_dtype)
        nbr = jnp.einsum("ab,b...->a...", W, lf,
                         preferred_element_type=jnp.float32)
        grad = 2.0 * (deg.reshape((-1,) + (1,) * (leaf.ndim - 1)) * lf - nbr)
        return (lf - lr * grad).astype(leaf.dtype)

    return _per_leaf(mix, params)


# ---------------------------------------------------------------------------
# Strategy factory
# ---------------------------------------------------------------------------


def make_coupling(cfg: CouplingConfig, state: CouplingState,
                  axis_names: Tuple[str, ...] = ("pod", "data"),
                  mesh=None, param_specs=None):
    """Returns apply(params, solitary, step) -> params.

    ``schedule="gossip"`` wraps the matching rounds in shard_map over the
    agent axes of ``mesh`` (required). ``param_specs`` (stacked
    PartitionSpec tree, agent axis leading) keeps tensor-parallel dims local
    inside the shard_map — without it leaves are assumed replicated beyond
    the agent axis. "dense" works under plain jit/GSPMD.
    """
    if cfg.mode == "none":
        return lambda params, solitary, step: params

    if cfg.mode == "consensus":
        def apply_consensus(params, solitary, step):
            do = (step % cfg.every) == 0
            mixed = consensus_mean_tree(params, cfg)
            return jax.tree_util.tree_map(
                lambda a, b: jnp.where(do, a, b), mixed, params)
        return apply_consensus

    if cfg.mode == "cl":
        def apply_cl(params, solitary, step):
            do = (step % cfg.every) == 0
            # lr folded into mu: proximal step size on the smoothness term
            mixed = laplacian_pull_tree(params, state, cfg, lr=cfg.mu)
            return jax.tree_util.tree_map(
                lambda a, b: jnp.where(do, a, b), mixed, params)
        return apply_cl

    if cfg.mode == "mp":
        if cfg.schedule == "gossip":
            if mesh is None:
                raise ValueError("gossip schedule needs a mesh")
            names = tuple(a for a in axis_names if a in mesh.axis_names)

            def apply_gossip(params, solitary, step):
                if param_specs is not None:
                    specs_in = param_specs
                else:
                    specs_in = jax.tree_util.tree_map(
                        lambda l: P(names, *([None] * (l.ndim - 1))), params)

                def body(p_slice, s_slice):
                    p_loc = jax.tree_util.tree_map(lambda a: a[0], p_slice)
                    s_loc = jax.tree_util.tree_map(lambda a: a[0], s_slice)
                    out = gossip_mix_tree(p_loc, s_loc, state, cfg, names)
                    return jax.tree_util.tree_map(lambda a: a[None], out)

                mixed = _shard_map(
                    body, mesh=mesh, in_specs=(specs_in, specs_in),
                    out_specs=specs_in)(params, solitary)
                do = (step % cfg.every) == 0
                return jax.tree_util.tree_map(
                    lambda a, b: jnp.where(do, a, b), mixed, params)
            return apply_gossip

        def apply_dense(params, solitary, step):
            do = (step % cfg.every) == 0
            mixed = dense_mix_tree(params, solitary, state, cfg)
            return jax.tree_util.tree_map(
                lambda a, b: jnp.where(do, a, b), mixed, params)
        return apply_dense

    raise ValueError(f"unknown coupling mode {cfg.mode!r}")

"""Coupling: the paper's algorithms as cross-agent distribution strategies."""

from .strategies import (CouplingConfig, CouplingState, make_coupling,
                         make_state, mp_matrices, dense_mix_tree,
                         gossip_mix_tree, consensus_mean_tree,
                         laplacian_pull_tree)

__all__ = ["CouplingConfig", "CouplingState", "make_coupling", "make_state",
           "mp_matrices", "dense_mix_tree", "gossip_mix_tree",
           "consensus_mean_tree", "laplacian_pull_tree"]

"""Data pipelines: per-agent synthetic LM streams + the paper's generators."""

from .synthetic import (PersonalizedLMConfig, personalized_token_stream,
                        make_lm_batches, mean_estimation_problem,
                        linear_classification_problem, accuracy,
                        federated_moons_problem, model_accuracy,
                        delay_pattern, undelay_pattern)

__all__ = ["PersonalizedLMConfig", "personalized_token_stream",
           "make_lm_batches", "mean_estimation_problem",
           "linear_classification_problem", "accuracy",
           "federated_moons_problem", "model_accuracy", "delay_pattern",
           "undelay_pattern"]

"""Synthetic data generators.

Two tiers (DESIGN.md §3):

* Paper experiments — ``mean_estimation_problem`` (§5.1: two moons auxiliary
  info, N(+-1, 40) sample streams, c_i ~ U(1/2 +- eps/2), m_i = round(100 c_i))
  and ``linear_classification_problem`` (§5.2: target models in a 2-D
  subspace of R^p, angular-kernel graph, m_i ~ U{1..20}, 5% label flips).

* Personalized LM streams — each agent draws tokens from its own 2-gram
  process; neighboring agents (on the given graph) share most of their
  transition structure, so graph-coupled training has signal to exploit.
  This feeds the end-to-end driver (examples/personalized_lm.py).

Also: MusicGen codebook delay pattern utilities (audio arch support).
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from repro.core.graph import Graph, two_moons, gaussian_kernel_graph, \
    angular_kernel_graph
from repro.core.losses import AgentData, pad_datasets


# ---------------------------------------------------------------------------
# Paper §5.1 — collaborative mean estimation
# ---------------------------------------------------------------------------


def mean_estimation_problem(n: int = 300, eps: float = 1.0, sigma: float = 0.1,
                            var: float = 40.0, max_samples: int = 100,
                            seed: int = 0):
    """Returns (graph, data, targets, confidences)."""
    rng = np.random.default_rng(seed)
    pts, labels = two_moons(n, seed=seed)
    graph = gaussian_kernel_graph(pts, sigma=sigma)
    targets = np.where(labels == 0, 1.0, -1.0)
    c = rng.uniform(0.5 - eps / 2.0, 0.5 + eps / 2.0, n)
    m = np.maximum(np.rint(c * max_samples).astype(int), 0)
    xs = [targets[i] + np.sqrt(var) * rng.standard_normal((m[i], 1))
          for i in range(n)]
    data = pad_datasets(xs)
    return graph, data, targets, c


def two_cluster_mean_problem(n: int, p: int = 4, sep: float = 2.0,
                             noise: float = 0.5, seed: int = 0):
    """Two planted clusters of agents estimating opposite means — the
    synthetic task the joint graph-learning acceptance runs on (ISSUE 5 /
    DESIGN.md §13; the mean-estimation analogue of §5.1 with cluster
    structure in the *targets* instead of the two-moons geometry).

    Agents in cluster 0 target ``+sep/2 * 1``, cluster 1 ``-sep/2 * 1`` (in
    R^p); solitary models are the targets plus N(0, noise^2) estimation
    noise.  Returns ``(labels, targets, theta_sol, c)`` with labels the
    contiguous-block cluster ids matching
    ``simulate.topology.planted_partition_topology(n, 2, ...)``.
    """
    rng = np.random.default_rng(seed)
    labels = (np.arange(n) >= n // 2).astype(np.int32)
    targets = np.where(labels[:, None] == 0, sep / 2.0, -sep / 2.0) \
        * np.ones((n, p))
    theta_sol = (targets + noise * rng.standard_normal((n, p))) \
        .astype(np.float32)
    c = rng.uniform(0.3, 1.0, n).astype(np.float32)
    return labels, targets.astype(np.float32), theta_sol, c


# ---------------------------------------------------------------------------
# Paper §5.2 — collaborative linear classification
# ---------------------------------------------------------------------------


def linear_classification_problem(n: int = 100, p: int = 50,
                                  sigma: float = 0.1, label_noise: float = 0.05,
                                  max_train: int = 20, n_test: int = 100,
                                  seed: int = 0, knn: Optional[int] = None):
    """Returns (graph, train AgentData, test AgentData, target models)."""
    rng = np.random.default_rng(seed)
    targets = np.zeros((n, p))
    targets[:, :2] = rng.standard_normal((n, 2))
    if knn is None:
        graph = angular_kernel_graph(targets, sigma=sigma, threshold=1e-2)
    else:
        u = targets / np.linalg.norm(targets, axis=1, keepdims=True)
        from repro.core.graph import knn_graph_from_similarity
        graph = knn_graph_from_similarity(u @ u.T, knn)

    def gen(m_per_agent):
        xs, ys = [], []
        for i in range(n):
            m = m_per_agent[i]
            x = rng.uniform(-1, 1, (m, p))
            y = np.sign(x @ targets[i])
            y[y == 0] = 1.0  # scatter: unique targets (boolean mask)
            flip = rng.uniform(size=m) < label_noise
            y = np.where(flip, -y, y)
            xs.append(x)
            ys.append(y)
        return pad_datasets(xs, ys)

    m_train = rng.integers(1, max_train + 1, n)
    train = gen(m_train)
    test = gen(np.full(n, n_test))
    return graph, train, test, targets


def accuracy(theta_all, data: AgentData) -> np.ndarray:
    """Per-agent accuracy of linear models on (padded) datasets."""
    pred = np.sign(np.einsum("nmp,np->nm", np.asarray(data.x),
                             np.asarray(theta_all)))
    correct = (pred == np.asarray(data.y)) * np.asarray(data.mask)
    return correct.sum(1) / np.maximum(np.asarray(data.mask).sum(1), 1)


# ---------------------------------------------------------------------------
# Nonlinear personalized boundaries — federated two moons (DESIGN.md §18)
# ---------------------------------------------------------------------------


def federated_moons_problem(n: int = 24, n_clusters: int = 2,
                            m_lo: int = 3, m_hi: int = 8,
                            noise: float = 0.15, n_test: int = 256,
                            seed: int = 0, k_intra: int = 4,
                            k_inter: int = 1):
    """Per-cluster nonlinear decision boundaries for the inexact-primal
    acceptance run (ISSUE 10): tiny local samples of a two-moons boundary
    that only collaboration can resolve.

    Each cluster owns a transformed copy of the two-moons problem —
    cluster ``c``'s points are rotated by ``pi c / n_clusters`` about the
    moons' centroid, and odd clusters additionally flip their labels —
    and every agent draws just ``m_i ~ U{m_lo..m_hi}`` training points
    from its cluster's distribution: far too few to learn the nonlinear
    boundary alone, plenty in aggregate per cluster.  The
    planted-partition topology (intra-cluster ring + random links,
    ``k_inter`` cross-cluster noise links per agent) gives the CL-ADMM
    consensus the right neighbors to pool with — while the label flips
    make naive *global* averaging actively harmful, the personalization
    regime of the paper.

    Returns ``(topo, train, test_x, test_y)``: a SparseTopology, the
    padded train AgentData (labels in {-1, +1} for the margin losses),
    and per-agent test sets ``test_x (n, n_test, 2)`` /
    ``test_y (n, n_test)`` drawn from each agent's own cluster.
    """
    from repro.simulate.topology import planted_partition_topology

    rng = np.random.default_rng(seed)
    topo = planted_partition_topology(n, n_clusters=n_clusters,
                                      k_intra=k_intra, k_inter=k_inter,
                                      seed=seed)
    center = np.array([0.5, 0.25])

    def sample(ci, m, sub_seed):
        pts, labels = two_moons(m, noise=noise, seed=sub_seed)
        ang = np.pi * ci / n_clusters
        rot = np.array([[np.cos(ang), -np.sin(ang)],
                        [np.sin(ang), np.cos(ang)]])
        pts = (pts - center) @ rot.T
        y = np.where(labels == 0, 1.0, -1.0)
        return pts, (-y if ci % 2 else y)

    m_i = rng.integers(m_lo, m_hi + 1, n)
    xs, ys, tx, ty = [], [], [], []
    for i in range(n):
        ci = int(topo.groups[i])
        pts, y = sample(ci, int(m_i[i]), int(rng.integers(2 ** 31)))
        xs.append(pts)
        ys.append(y)
        pts_t, y_t = sample(ci, n_test, int(rng.integers(2 ** 31)))
        tx.append(pts_t)
        ty.append(y_t)
    return (topo, pad_datasets(xs, ys),
            np.stack(tx).astype(np.float32), np.stack(ty).astype(np.float32))


def model_accuracy(theta_all, predict_fn, x, y) -> np.ndarray:
    """Per-agent accuracy of flat-row models under a score function.

    The nonlinear counterpart of :func:`accuracy`:
    ``predict_fn(theta (p,), x (m, q)) -> (m,)`` scores whose sign is the
    predicted ±1 label (e.g. ``core.primal.flat_predictor(model)``).
    theta_all (n, p), x (n, m, q), y (n, m) -> (n,) accuracies.
    """
    import jax
    import jax.numpy as jnp

    scores = np.asarray(jax.vmap(predict_fn)(
        jnp.asarray(theta_all, jnp.float32), jnp.asarray(x, jnp.float32)))
    return (np.sign(scores) == np.sign(np.asarray(y))).mean(axis=1)


# ---------------------------------------------------------------------------
# Personalized LM streams
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PersonalizedLMConfig:
    vocab_size: int
    n_agents: int
    seq_len: int
    batch_per_agent: int
    share: float = 0.9          # fraction of transition mass shared with neighbors
    concentration: float = 0.3  # Dirichlet concentration of private structure
    seed: int = 0


def _agent_bigrams(cfg: PersonalizedLMConfig, graph: Graph) -> np.ndarray:
    """Per-agent 2-gram transition matrices (n_agents, V, V).

    Base = shared global structure; each agent blends in a *cluster* tilt
    derived from its graph community (spectral sign of the Fiedler vector) and
    a small private tilt — neighbors end up statistically similar.
    """
    rng = np.random.default_rng(cfg.seed)
    V = cfg.vocab_size
    base = rng.dirichlet(np.full(V, 1.0), size=V)
    # community split via the sign pattern of the Laplacian's Fiedler vector
    lap = graph.laplacian
    _, vecs = np.linalg.eigh(lap)
    fiedler = vecs[:, 1] if lap.shape[0] > 1 else np.zeros(1)
    tilts = {s: rng.dirichlet(np.full(V, cfg.concentration), size=V)
             for s in (-1, 1)}
    out = np.empty((cfg.n_agents, V, V))
    for a in range(cfg.n_agents):
        s = 1 if fiedler[a] >= 0 else -1
        private = rng.dirichlet(np.full(V, cfg.concentration), size=V)
        out[a] = (cfg.share * base + (1 - cfg.share) *
                  (0.8 * tilts[s] + 0.2 * private))
    return out / out.sum(-1, keepdims=True)


def personalized_token_stream(cfg: PersonalizedLMConfig, graph: Graph
                              ) -> Iterator[np.ndarray]:
    """Yields batches (n_agents, batch_per_agent, seq_len + 1) of token ids.

    tokens = batch[..., :-1], labels = batch[..., 1:].
    """
    trans = _agent_bigrams(cfg, graph)
    cum = np.cumsum(trans, axis=-1)
    rng = np.random.default_rng(cfg.seed + 1)
    A, b, S = cfg.n_agents, cfg.batch_per_agent, cfg.seq_len + 1
    agent_idx = np.arange(A)[:, None]                      # (A, 1)
    while True:
        out = np.empty((A, b, S), np.int32)
        state = rng.integers(0, cfg.vocab_size, (A, b))
        out[..., 0] = state
        u = rng.uniform(size=(A, b, S - 1))
        for t in range(1, S):
            rows = cum[agent_idx, state]                   # (A, b, V)
            state = (rows >= u[..., t - 1:t]).argmax(-1)
            state = np.minimum(state, cfg.vocab_size - 1)
            out[..., t] = state
        yield out


def make_lm_batches(cfg: PersonalizedLMConfig, graph: Graph, n_batches: int):
    """Materialize a finite list of batches (for tests / examples)."""
    it = personalized_token_stream(cfg, graph)
    return [next(it) for _ in range(n_batches)]


# ---------------------------------------------------------------------------
# MusicGen delay pattern (audio arch)
# ---------------------------------------------------------------------------


def delay_pattern(tokens: np.ndarray, pad_id: int) -> np.ndarray:
    """Apply the MusicGen codebook delay: codebook k is shifted right by k.

    tokens: (B, K, S) -> (B, K, S + K - 1) padded with pad_id.
    """
    B, K, S = tokens.shape
    out = np.full((B, K, S + K - 1), pad_id, tokens.dtype)
    for k in range(K):
        out[:, k, k:k + S] = tokens[:, k]
    return out


def undelay_pattern(tokens: np.ndarray) -> np.ndarray:
    """Inverse of delay_pattern. tokens: (B, K, S + K - 1) -> (B, K, S)."""
    B, K, Sp = tokens.shape
    S = Sp - K + 1
    out = np.empty((B, K, S), tokens.dtype)
    for k in range(K):
        out[:, k] = tokens[:, k, k:k + S]
    return out

"""Vmapped multi-seed × hyperparameter sweep runner (paper Fig. 1–3 style).

The paper's experiments (and Bellet et al. 2018 / Zantedeschi et al. 2019
follow-ups) average every curve over many random problem instances and
hyperparameter settings.  Run naively that is a Python loop of hundreds of
small jitted programs; here each sweep is ONE jitted call vmapped over a
trial axis:

* :func:`mean_estimation_trials` — stack T = |seeds| × |alphas| × |noises|
  instances of the §5.1 collaborative mean-estimation problem (per-seed
  graph/data, optional multiplicative edge noise) into dense trial arrays.
* :func:`run_mp_sweep` — synchronous MP (Eq. 5) on all trials at once; each
  iterate is the dispatch-layer "mix" op under ``vmap``, emitting per-trial
  Q_MP objective and L2-error trajectories.
* :func:`closed_form_comparison` — the seed experiment itself (Prop. 1 with
  vs without confidence values) as one vmapped linear solve.
* :func:`admm_mean_estimation_trials` / :func:`run_admm_sweep` — synchronous
  CL-ADMM (quadratic loss) over a (seed, mu, rho) grid; the primal step is
  the dispatch-layer "admm_primal" op vmapped over agents AND trials (the
  per-agent primal touches disjoint state, so the reference engine's
  sequential agent loop parallelizes exactly).

Backend note: trials run under ``jax.vmap``, so the default resolves to the
fused XLA implementations (batched einsum/dot); Pallas impls can be forced
via ``backend`` where the platform supports batched pallas_call.
"""

from __future__ import annotations

import dataclasses
import itertools
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.collaborative import ADMMState, _all_zl_update, cl_objective
from repro.core.graph_learning import DEAD_DISTANCE
from repro.core.losses import LOSSES, AgentData, solitary_mean, \
    confidences_from_counts
from repro.core.model_propagation import mp_mix_operator, mp_objective
from repro.data.synthetic import mean_estimation_problem
from repro.kernels.dispatch import ReproBackend, resolve


# ---------------------------------------------------------------------------
# Trial containers (host-side stacked arrays; leading axis = trial)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MPTrials:
    """T stacked mean-estimation instances for the MP sweep."""

    W: np.ndarray          # (T, n, n) edge weights
    P: np.ndarray          # (T, n, n) stochastic mixing matrices
    theta_sol: np.ndarray  # (T, n, p) solitary models
    c: np.ndarray          # (T, n)   confidence values
    alpha: np.ndarray      # (T,)     MP trade-off per trial
    targets: np.ndarray    # (T, n, p) ground-truth models
    seed: np.ndarray       # (T,) int  instance seed per trial
    graph_noise: np.ndarray  # (T,)   edge-noise level per trial

    @property
    def n_trials(self) -> int:
        return self.W.shape[0]


@dataclasses.dataclass(frozen=True)
class MPSweepResult:
    """Per-trial trajectories from one vmapped MP sweep."""

    trials: MPTrials
    objective_hist: np.ndarray  # (T, sweeps) Q_MP after each iterate
    err_hist: np.ndarray        # (T, sweeps) mean L2 error to targets
    theta_final: np.ndarray     # (T, n, p)


@dataclasses.dataclass(frozen=True)
class ADMMTrials:
    """T stacked quadratic-loss instances for the CL-ADMM sweep."""

    W: np.ndarray         # (T, n, n)
    adj: np.ndarray       # (T, n, n) bool adjacency, from the *float64* W —
                          # kernel weights can underflow to 0 in float32
    x: np.ndarray         # (T, n, m, p) local samples
    y: np.ndarray         # (T, n, m)    unused by the quadratic loss
    mask: np.ndarray      # (T, n, m)    live-sample mask
    theta_sol: np.ndarray  # (T, n, p)   warm start
    mu: np.ndarray        # (T,)
    rho: np.ndarray       # (T,)
    targets: np.ndarray   # (T, n, p)
    seed: np.ndarray      # (T,)

    @property
    def n_trials(self) -> int:
        return self.W.shape[0]


@dataclasses.dataclass(frozen=True)
class ADMMSweepResult:
    trials: ADMMTrials
    objective_hist: np.ndarray  # (T, iters) Q_CL after each iteration
    err_hist: np.ndarray        # (T, iters) mean L2 error to targets
    theta_final: np.ndarray     # (T, n, p)


# ---------------------------------------------------------------------------
# Trial builders (host loops — one problem instance per seed)
# ---------------------------------------------------------------------------


def _noisy_graph(W: np.ndarray, noise: float, rng) -> np.ndarray:
    """Symmetric multiplicative edge perturbation: W_ij *= exp(noise * g)."""
    if noise == 0.0:
        return W
    g = rng.standard_normal(W.shape)
    g = (g + g.T) / np.sqrt(2.0)
    return W * np.exp(noise * g)


def mean_estimation_trials(seeds: Sequence[int],
                           alphas: Sequence[float],
                           graph_noises: Sequence[float] = (0.0,),
                           n: int = 100, eps: float = 1.0,
                           noise_seed: int = 0) -> MPTrials:
    """Cartesian (seed × alpha × graph-noise) grid of §5.1 instances.

    The graph and data depend on the seed (and the optional edge noise);
    alpha only changes the algorithm, so those trials share instance arrays.
    """
    Ws, Ps, sols, cs, als, tgts, sds, nss = [], [], [], [], [], [], [], []
    nrng = np.random.default_rng(noise_seed)
    for seed, noise in itertools.product(seeds, graph_noises):
        g, data, targets, _ = mean_estimation_problem(n=n, eps=eps, seed=seed)
        W = _noisy_graph(np.asarray(g.W, np.float64), noise, nrng)
        D = W.sum(axis=1)
        P = W / D[:, None]
        sol = np.asarray(solitary_mean(data), np.float32)
        conf = np.asarray(confidences_from_counts(data.counts), np.float32)
        for alpha in alphas:
            Ws.append(W.astype(np.float32))
            Ps.append(P.astype(np.float32))
            sols.append(sol)
            cs.append(conf)
            als.append(np.float32(alpha))
            tgts.append(targets[:, None].astype(np.float32))
            sds.append(seed)
            nss.append(np.float32(noise))
    return MPTrials(np.stack(Ws), np.stack(Ps), np.stack(sols), np.stack(cs),
                    np.asarray(als), np.stack(tgts),
                    np.asarray(sds, np.int64), np.asarray(nss))


def admm_mean_estimation_trials(seeds: Sequence[int],
                                mus: Sequence[float],
                                rhos: Sequence[float],
                                n: int = 20, eps: float = 1.0) -> ADMMTrials:
    """Cartesian (seed × mu × rho) grid of quadratic CL instances."""
    insts = []
    for seed in seeds:
        g, data, targets, _ = mean_estimation_problem(n=n, eps=eps, seed=seed)
        sol = np.asarray(solitary_mean(data), np.float32)
        insts.append((seed, g, data, targets, sol))
    # different seeds draw different sample counts -> pad to a common m_max
    m_max = max(inst[2].x.shape[1] for inst in insts)

    def pad_m(a):
        return np.pad(np.asarray(a, np.float32),
                      ((0, 0), (0, m_max - a.shape[1])) + ((0, 0),) *
                      (a.ndim - 2))

    Ws, adjs, xs, ys, ms, sols, mus_, rhos_, tgts, sds = (
        [] for _ in range(10))
    for seed, g, data, targets, sol in insts:
        for mu, rho in itertools.product(mus, rhos):
            Ws.append(np.asarray(g.W, np.float32))
            adjs.append(np.asarray(g.W) > 0)
            xs.append(pad_m(data.x))
            ys.append(pad_m(data.y))
            ms.append(pad_m(data.mask))
            sols.append(sol)
            mus_.append(np.float32(mu))
            rhos_.append(np.float32(rho))
            tgts.append(targets[:, None].astype(np.float32))
            sds.append(seed)
    return ADMMTrials(np.stack(Ws), np.stack(adjs), np.stack(xs),
                      np.stack(ys), np.stack(ms), np.stack(sols),
                      np.asarray(mus_), np.asarray(rhos_), np.stack(tgts),
                      np.asarray(sds, np.int64))


# ---------------------------------------------------------------------------
# MP sweep — one jitted program over the trial axis
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("sweeps", "backend"))
def _mp_sweep_prog(P, W, sol, c, alpha, targets, *, sweeps: int,
                   backend: Optional[ReproBackend]):
    mix = resolve("mix", backend)

    def one_trial(P, W, sol, c, alpha, targets):
        A_mix, b = mp_mix_operator(P, c, alpha)
        mu = (1.0 - alpha) / alpha             # Q_MP anchor weight (§3.1)

        def step(theta, _):
            theta = mix(theta, sol, A_mix, b)
            obj = mp_objective(theta, sol, W, c, mu)
            err = jnp.mean(jnp.sum((theta - targets) ** 2, axis=-1))
            return theta, (obj, err)

        theta, (objs, errs) = jax.lax.scan(step, sol, None, length=sweeps)
        return theta, objs, errs

    return jax.vmap(one_trial)(P, W, sol, c, alpha, targets)


def run_mp_sweep(trials: MPTrials, sweeps: int = 300,
                 backend: Optional[ReproBackend] = None) -> MPSweepResult:
    """Synchronous MP (Eq. 5) on every trial at once — one jitted call."""
    theta, objs, errs = _mp_sweep_prog(
        jnp.asarray(trials.P), jnp.asarray(trials.W),
        jnp.asarray(trials.theta_sol), jnp.asarray(trials.c),
        jnp.asarray(trials.alpha), jnp.asarray(trials.targets),
        sweeps=sweeps, backend=backend)
    return MPSweepResult(trials, np.asarray(objs), np.asarray(errs),
                         np.asarray(theta))


@jax.jit
def _closed_form_prog(P, sol, c, alpha, targets):
    def one_trial(P, sol, c, alpha, targets):
        n = P.shape[0]

        def solve(conf):
            abar = 1.0 - alpha
            A = (jnp.eye(n) - abar * (jnp.eye(n) - jnp.diag(conf))
                 - alpha * P)
            star = abar * jnp.linalg.solve(A, conf[:, None] * sol)
            return jnp.mean(jnp.sum((star - targets) ** 2, axis=-1))

        e_c = solve(c)
        e_nc = solve(jnp.ones_like(c))
        win = jnp.where(jnp.abs(e_c - e_nc) < 1e-12, 0.5,
                        (e_c < e_nc).astype(jnp.float32))
        return e_c, e_nc, win

    return jax.vmap(one_trial)(P, sol, c, alpha, targets)


def closed_form_comparison(trials: MPTrials) -> Tuple[np.ndarray, np.ndarray,
                                                      np.ndarray]:
    """Paper Fig. 2 experiment as ONE jitted call over all trials.

    Returns per-trial (err_with_conf, err_without_conf, win) — win is 1.0
    where confidence values help, 0.5 on exact ties (balanced data).
    """
    e_c, e_nc, win = _closed_form_prog(
        jnp.asarray(trials.P), jnp.asarray(trials.theta_sol),
        jnp.asarray(trials.c), jnp.asarray(trials.alpha),
        jnp.asarray(trials.targets))
    return np.asarray(e_c), np.asarray(e_nc), np.asarray(win)


# ---------------------------------------------------------------------------
# Joint graph-learning sweep — synchronous alternation over a
# (seed × alpha × graph-learning strength) grid (DESIGN.md §13)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class JointTrials:
    """T stacked §5.1 instances with a graph-learning-strength axis.

    ``labels`` carries the two-moons cluster of each agent so the sweep can
    report how much learned weight stays on intra-cluster candidate edges.
    """

    W: np.ndarray          # (T, n, n) candidate edge weights
    P: np.ndarray          # (T, n, n) initial stochastic mixing matrices
    adj: np.ndarray        # (T, n, n) bool candidate support
    theta_sol: np.ndarray  # (T, n, p)
    c: np.ndarray          # (T, n)
    alpha: np.ndarray      # (T,)
    eta: np.ndarray        # (T,)  graph-learning rate (0 = frozen graph)
    lam: np.ndarray        # (T,)  simplex-projection temperature
    targets: np.ndarray    # (T, n, p)
    labels: np.ndarray     # (T, n) two-moons cluster ids
    seed: np.ndarray       # (T,)

    @property
    def n_trials(self) -> int:
        return self.W.shape[0]


@dataclasses.dataclass(frozen=True)
class JointSweepResult:
    """Per-trial trajectories from one vmapped joint sweep."""

    trials: JointTrials
    objective_hist: np.ndarray   # (T, sweeps) Q_MP under the candidate W
    err_hist: np.ndarray         # (T, sweeps) mean L2 error to targets
    intra_mass_hist: np.ndarray  # (T, sweeps) learned weight share on
    #                              intra-cluster candidate edges
    theta_final: np.ndarray      # (T, n, p)
    P_final: np.ndarray          # (T, n, n) learned mixing matrices


def joint_mean_estimation_trials(seeds: Sequence[int],
                                 alphas: Sequence[float],
                                 etas: Sequence[float],
                                 lams: Sequence[float] = (1.0,),
                                 n: int = 100, eps: float = 1.0
                                 ) -> JointTrials:
    """Cartesian (seed × alpha × eta × lam) grid of §5.1 instances for the
    joint sweep — ``etas`` is the graph-learning-strength axis."""
    Ws, Ps, adjs, sols, cs, als, ets, lms, tgts, lbls, sds = (
        [] for _ in range(11))
    for seed in seeds:
        g, data, targets, _ = mean_estimation_problem(n=n, eps=eps,
                                                      seed=seed)
        W = np.asarray(g.W, np.float64)
        P = W / W.sum(axis=1)[:, None]
        sol = np.asarray(solitary_mean(data), np.float32)
        conf = np.asarray(confidences_from_counts(data.counts), np.float32)
        labels = (targets < 0).astype(np.int32)
        for alpha, eta, lam in itertools.product(alphas, etas, lams):
            Ws.append(W.astype(np.float32))
            Ps.append(P.astype(np.float32))
            adjs.append(W > 0)
            sols.append(sol)
            cs.append(conf)
            als.append(np.float32(alpha))
            ets.append(np.float32(eta))
            lms.append(np.float32(lam))
            tgts.append(targets[:, None].astype(np.float32))
            lbls.append(labels)
            sds.append(seed)
    return JointTrials(np.stack(Ws), np.stack(Ps), np.stack(adjs),
                       np.stack(sols), np.stack(cs), np.asarray(als),
                       np.asarray(ets), np.asarray(lms), np.stack(tgts),
                       np.stack(lbls), np.asarray(sds, np.int64))


@partial(jax.jit, static_argnames=("sweeps", "graph_every", "backend"))
def _joint_sweep_prog(P, W, adj, sol, c, alpha, eta, lam, targets, intra, *,
                      sweeps: int, graph_every: int,
                      backend: Optional[ReproBackend]):
    mix = resolve("mix", backend)
    reweight = resolve("edge_reweight", backend)

    def one_trial(P0, W, adj, sol, c, alpha, eta, lam, targets, intra):
        mu = (1.0 - alpha) / alpha

        def step(carry, t):
            """One mix iterate + (every graph_every-th step) a graph step."""
            def do_graph(Pr):
                """Re-estimate all rows from current pairwise distances."""
                diff = theta[:, None, :] - theta[None, :, :]
                d = jnp.where(adj, jnp.sum(diff * diff, axis=-1),
                              DEAD_DISTANCE)
                return reweight(d, Pr, adj, eta=eta, lam=lam)

            theta, Pr = carry
            A_mix, b = mp_mix_operator(Pr, c, alpha)
            theta = mix(theta, sol, A_mix, b)
            # the predicate is batch-invariant, so under vmap this stays a
            # real cond: the O(n^2 p) distance matrix + projection only run
            # on graph rounds (same pattern as the scenario engines)
            Pr = jax.lax.cond((t + 1) % graph_every == 0, do_graph,
                              lambda Pr: Pr, Pr)
            # Q_MP under the fixed candidate W (mp_objective assumes a
            # symmetric W; the learned Pr is tracked via intra-mass instead)
            # — this also keeps the eta = 0 column an exact run_mp_sweep
            # anchor for the objective, not just theta/err
            obj = mp_objective(theta, sol, W, c, mu)
            err = jnp.mean(jnp.sum((theta - targets) ** 2, axis=-1))
            mass = jnp.sum(Pr * intra) / jnp.maximum(jnp.sum(Pr), 1e-30)
            return (theta, Pr), (obj, err, mass)

        (theta, Pr), (objs, errs, masses) = jax.lax.scan(
            step, (sol, P0), jnp.arange(sweeps))
        return theta, Pr, objs, errs, masses

    return jax.vmap(one_trial)(P, W, adj, sol, c, alpha, eta, lam, targets,
                               intra)


def run_joint_sweep(trials: JointTrials, sweeps: int = 300,
                    graph_every: int = 10,
                    backend: Optional[ReproBackend] = None
                    ) -> JointSweepResult:
    """Synchronous joint MP + graph learning on every trial at once.

    Each iterate is one Eq. (5) "mix" op under the *current* learned
    mixing matrix, followed (every ``graph_every`` iterates) by the
    "edge_reweight" op on the dense candidate rows — the dense mirror of
    ``simulate.engines.run_joint_scenario``'s alternation, vmapped over the
    (seed × alpha × eta × lam) grid in one jitted call.  Trials with
    ``eta == 0`` reproduce :func:`run_mp_sweep` exactly (the blend is the
    identity), so the frozen-graph column doubles as a regression anchor.
    """
    intra = (trials.labels[:, :, None] == trials.labels[:, None, :]) \
        & trials.adj
    theta, Pf, objs, errs, masses = _joint_sweep_prog(
        jnp.asarray(trials.P), jnp.asarray(trials.W),
        jnp.asarray(trials.adj), jnp.asarray(trials.theta_sol),
        jnp.asarray(trials.c), jnp.asarray(trials.alpha),
        jnp.asarray(trials.eta), jnp.asarray(trials.lam),
        jnp.asarray(trials.targets), jnp.asarray(intra, jnp.float32),
        sweeps=sweeps, graph_every=graph_every, backend=backend)
    return JointSweepResult(trials, np.asarray(objs), np.asarray(errs),
                            np.asarray(masses), np.asarray(theta),
                            np.asarray(Pf))


# ---------------------------------------------------------------------------
# CL-ADMM sweep — synchronous App. D iteration, vectorized over agents
# ---------------------------------------------------------------------------


def _admm_primal_all(T, Z_own, Z_nbr, L_own, L_nbr, W, mask, D, m, sx,
                     mu, rho, backend):
    """All agents' exact quadratic primal at once.

    The reference engine's sequential agent loop is embarrassingly parallel
    (agent l reads only its Z/L rows and writes only T row l), so one vmap
    of the "admm_primal" op over the agent axis reproduces it exactly.
    Dense layout: agent l's "slot row" is the full agent set with live mask
    = mask[l] (so w carries exact zeros at non-edges, as in the CSR layout).
    """
    n = T.shape[0]
    primal = resolve("admm_primal", backend)
    theta_l, theta_js = jax.vmap(
        lambda w, live, zo, zn, lo, ln, D_l, m_l, sx_l:
        primal(w, live, zo, zn, lo, ln, D_l, m_l, sx_l, mu, rho))(
            W, mask, Z_own, Z_nbr, L_own, L_nbr, D, m, sx)
    T = jnp.where(mask[:, :, None], theta_js, T)
    # scatter: unique targets (diagonal cells)
    return T.at[jnp.arange(n), jnp.arange(n)].set(theta_l)


@partial(jax.jit, static_argnames=("iters", "backend"))
def _admm_sweep_prog(W, adj, x, y, smask, sol, mu, rho, targets, *,
                     iters: int, backend: Optional[ReproBackend]):
    loss_fn = LOSSES["quadratic"]

    def one_trial(W, mask, x, y, smask, sol, mu, rho, targets):
        n, p = sol.shape
        D = jnp.sum(W, axis=1)
        m = jnp.sum(smask, axis=1)                          # (n,) sample counts
        sx = jnp.sum(x * smask[..., None], axis=1)          # (n, p)
        adj = mask | jnp.eye(n, dtype=bool)
        T0 = jnp.where(adj[:, :, None],
                       jnp.broadcast_to(sol[None], (n, n, p)), 0.0)
        Z_own0 = jnp.where(mask[:, :, None],
                           jnp.broadcast_to(sol[:, None], (n, n, p)), 0.0)
        Z_nbr0 = jnp.where(mask[:, :, None],
                           jnp.broadcast_to(sol[None], (n, n, p)), 0.0)
        zeros = jnp.zeros((n, n, p), jnp.float32)
        st0 = ADMMState(T0, Z_own0, Z_nbr0, zeros, zeros)
        data = AgentData(x=x, y=y, mask=smask)

        def it(st, _):
            T = _admm_primal_all(st.T, st.Z_own, st.Z_nbr, st.L_own,
                                 st.L_nbr, W, mask, D, m, sx, mu, rho,
                                 backend)
            st = ADMMState(T, st.Z_own, st.Z_nbr, st.L_own, st.L_nbr)
            st = _all_zl_update(st, mask, rho)
            theta = st.models()
            obj = cl_objective(theta, W, mu, loss_fn, data)
            err = jnp.mean(jnp.sum((theta - targets) ** 2, axis=-1))
            return st, (obj, err)

        st, (objs, errs) = jax.lax.scan(it, st0, None, length=iters)
        return st.models(), objs, errs

    return jax.vmap(one_trial)(W, adj, x, y, smask, sol, mu, rho, targets)


def run_admm_sweep(trials: ADMMTrials, iters: int = 50,
                   backend: Optional[ReproBackend] = None) -> ADMMSweepResult:
    """Synchronous quadratic CL-ADMM on every (seed, mu, rho) trial at once."""
    theta, objs, errs = _admm_sweep_prog(
        jnp.asarray(trials.W), jnp.asarray(trials.adj),
        jnp.asarray(trials.x), jnp.asarray(trials.y),
        jnp.asarray(trials.mask), jnp.asarray(trials.theta_sol),
        jnp.asarray(trials.mu), jnp.asarray(trials.rho),
        jnp.asarray(trials.targets), iters=iters, backend=backend)
    return ADMMSweepResult(trials, np.asarray(objs), np.asarray(errs),
                           np.asarray(theta))


# ---------------------------------------------------------------------------
# ScenarioSpec-driven sweeps over the asynchronous scenario engines
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScenarioSweepResult:
    """All cells of one ``run_scenario`` grid sweep.

    ``cells[i]`` is the axis-value dict of trial i (cartesian order,
    itertools.product over the axes as given); ``specs``/``traces`` line
    up with it.
    """

    cells: Tuple[dict, ...]
    specs: tuple
    traces: tuple

    @property
    def n_trials(self) -> int:
        return len(self.traces)


def run_scenario_sweep(base, **axes: Sequence) -> ScenarioSweepResult:
    """Cartesian sweep of :func:`repro.simulate.run_scenario` over
    ``ScenarioSpec`` fields.

    ``base`` is a fully-specified :class:`~repro.simulate.ScenarioSpec`;
    each axis is ``field_name=sequence_of_values`` and every grid cell
    runs ``run_scenario(dataclasses.replace(base, **cell))``.  Because a
    spec is frozen, cells with identical static shapes (same topology /
    rounds / batch) reuse the engines' jit cache — a seed axis costs one
    compile total.  The unified-API twin of the dense vmapped sweeps
    above for experiments that need the event-driven engines (faults,
    sharding, serving) rather than the synchronous iterates.
    """
    from repro.simulate import run_scenario

    names = tuple(axes)
    for name in names:
        if not hasattr(base, name):
            raise ValueError(f"ScenarioSpec has no field {name!r}")
    cells = tuple(dict(zip(names, values))
                  for values in itertools.product(*axes.values()))
    specs = tuple(dataclasses.replace(base, **cell) for cell in cells)
    return ScenarioSweepResult(cells, specs,
                               tuple(run_scenario(s) for s in specs))


def inexact_primal_axis(b_steps: Sequence[Optional[int]], **kw):
    """A ``primal=`` axis for :func:`run_scenario_sweep`: one
    ``core.primal.InexactPrimal`` per inner-step budget (``None`` = the
    B -> inf closed form, the exact-engine anchor column — DESIGN.md §18).

    Solvers are frozen/hashable, so cells along this axis share the
    engines' jit cache per distinct solver config::

        run_scenario_sweep(base, primal=inexact_primal_axis(
            [1, 4, 16, None], loss="quadratic", lr=0.2))
    """
    from repro.core.primal import InexactPrimal

    return tuple(InexactPrimal(b_steps=b, **kw) for b in b_steps)

"""Paper-style experiment drivers (vmapped multi-trial sweeps)."""

from .sweep import (ADMMSweepResult, ADMMTrials, JointSweepResult,
                    JointTrials, MPSweepResult, MPTrials,
                    ScenarioSweepResult, admm_mean_estimation_trials,
                    closed_form_comparison, inexact_primal_axis,
                    joint_mean_estimation_trials, mean_estimation_trials,
                    run_admm_sweep, run_joint_sweep, run_mp_sweep,
                    run_scenario_sweep)

__all__ = [n for n in dir() if not n.startswith("_")]

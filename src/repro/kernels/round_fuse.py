"""Fused gossip-round megakernel (DESIGN.md §15).

Two ops, three realizations each, executing an entire event batch of the
scenario engines in one pass:

``round_step`` — the MP gossip round (``simulate.engines._scenario_scan``
round body) over a *flat* slot table ``Ke (n*k, p+1)``: column ``p`` is an
id column that records the index of the event that last wrote the slot.
Each round scatters ``[message | event-id]`` rows at the encoded targets
``enc = row*k + slot`` (undelivered events ride at the ``n*k`` sentinel and
are OOB-dropped), then reads the id column back: an event "keeps" exactly
when its own id survived, which identifies the true scatter winner under
duplicate (row, slot) targets regardless of the backend's collision policy.
The receiver update is *telescoped*: since Eq. 6 is affine in the slot
aggregate, the winner contributes ``a_i w_is (msg - k_old)`` to its row via
one masked scatter-add — no slot re-gather, no einsum, no argsort, which is
where the >= 1.5x CPU events/s win comes from (BENCH_network_sim.json).
On a row's *first* receipt the op first swaps in ``theta_base = f(K0)``
(the Eq. 6 image of the warm-start slots): the engine warm-starts theta at
the solitary models (paper §3.2), so the telescoped sum needs the affine
base once — exact because a row's slots cannot change before its first
receipt.  ``got_ever`` carries that per-row flag across rounds.

Contract (scheduler conformance): the op assumes delivery implies an
active receiver — ``simulate.scheduler.draw_events`` masks deliveries at
dead endpoints — so it never consults an ``active`` vector.  Feeding it
deliveries to inactive rows updates them anyway.

The engine overlaps rounds with a software-pipelined prefetch
(:func:`round_prefetch`): round t+1's messages and pre-scatter slot values
``k_old`` are gathered at the *end* of round t, after t's scatters — a
gather of old state held live across that state's scatter forces XLA's
copy insertion and pessimizes the scatter into a full-array expansion
(~mss per round on CPU), which the post-scatter placement avoids.

``cl_edge_step`` — the CL-ADMM edge phase (payload selection under
staleness, ``admm_edge_halfstep`` math, four OOB-masked slot scatters).  The
``reference`` and ``xla`` registrations share one callable whose expressions
mirror ``simulate.engines._cl_scenario_scan`` line for line, so routing the
engine through dispatch is bit-for-bit; the Pallas variant is the TPU
megakernel.

Pallas layout (both kernels): grid ``(2, n_event_blocks)`` — the last grid
dimension is the sequential TPU dimension, so every phase-0 block runs
before any phase-1 block, giving the same "all communication lands before
any update reads" barrier the engines rely on.  State arrays use full-array
BlockSpecs with constant index maps (fetched into VMEM once, written back
once at the end); event columns are tiled ``(block_b, 1)`` per grid step so
the pipeline double-buffers the next block's fetch behind the current
block's compute (the ``@pl.when`` idiom of ``kernels/flash_attention.py``).
Events are processed sequentially inside a block (``fori_loop``), which
resolves duplicate (row, slot) scatter targets in event order — the one
place the Pallas realization may pick a different duplicate winner than
XLA's scatter (both are valid realizations of the unordered batch, and the
id column keeps each realization self-consistent; see
tests/test_round_fuse.py).

Whole-state-in-VMEM is the operating point: the kernels size for
``n * k * p`` f32 state within the ~16 MB VMEM budget (n=10k, k=8, p=32 is
~10 MB).  Larger states belong to the fused-XLA impl or the sharded engines.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# ---------------------------------------------------------------------------
# Flat slot-table layout helpers
# ---------------------------------------------------------------------------


def encode_slots(K):
    """(n, k, p) slot table -> flat (n*k, p+1) with the id column at -1."""
    n, k, p = K.shape
    flat = K.reshape(n * k, p)
    return jnp.concatenate(
        [flat, jnp.full((n * k, 1), -1.0, flat.dtype)], axis=1)


def decode_slots(Ke, k):
    """Flat (n*k, p+1) -> the (n, k, p) slot table (id column dropped)."""
    nk, p1 = Ke.shape
    return Ke[:, : p1 - 1].reshape(nk // k, k, p1 - 1)


def round_scales(nbr_p, c, *, alpha: float):
    """Flat (n*k,) per-slot Eq. 6 gain ``a_i * w_is`` with
    ``a_i = alpha / (alpha + (1 - alpha) c_i)`` — the factor a slot delta
    carries into its row's model under the telescoped update."""
    a = alpha / (alpha + (1.0 - alpha) * c)
    return (a[:, None] * nbr_p).reshape(-1)


def round_stale_src(theta_prev, ev_i, ev_j):
    """(2B, p) sender rows of the *previous* model for one event batch.

    The stale-message source for :func:`round_prefetch`.  The engine
    gathers it *before* round t's theta scatter consumes ``theta_prev``
    (ordering pinned with an ``optimization_barrier``): once this gather is
    the buffer's last read, XLA updates theta in place instead of copying
    the full model table every round.
    """
    return theta_prev[jnp.concatenate([ev_i, ev_j])]


def round_prefetch(theta, theta_prev, Ke, ev_i, ev_j, ev_s, ev_r,
                   d_ij, d_ji, st_ij, st_ji, *, stale_src=None,
                   no_stale=False):
    """Gather one event batch's ``round_step`` operands.

    Returns ``(msg, tgt_row, enc, k_old)`` for the 2B directed sends
    (i->j slot r first, then j->i slot s, matching the engine's scatter
    order): the sender models (``theta_prev`` where stale), the receiver
    rows (``n`` where undelivered), the encoded flat targets (``n*k``
    sentinel where undelivered), and the pre-scatter slot values.  Call it
    *after* the round whose ``Ke`` it reads has scattered (the engine calls
    it at the end of round t for round t+1) — gathering ahead of a pending
    scatter on the same buffer defeats XLA's in-place scatter on CPU.

    ``stale_src`` optionally supplies :func:`round_stale_src`'s gather,
    already taken before the round's theta scatter (``theta_prev`` is then
    ignored) — the pipelined engine's in-place-theta arrangement.
    ``no_stale=True`` (a static fact about the scenario: zero staleness)
    skips the previous-model gather and select outright; the stale masks
    are all-False then, so the result is unchanged.
    """
    n, p = theta.shape
    nk = Ke.shape[0]
    km = nk // n
    send = jnp.concatenate([ev_i, ev_j])
    if no_stale:
        msg = theta[send]
    else:
        stale = jnp.concatenate([st_ij, st_ji])
        if stale_src is None:
            stale_src = theta_prev[send]
        msg = jnp.where(stale[:, None], stale_src, theta[send])
    tgt_row = jnp.concatenate([jnp.where(d_ij, ev_j, n),
                               jnp.where(d_ji, ev_i, n)])
    tgt_slot = jnp.concatenate([ev_r, ev_s])
    enc = jnp.where(tgt_row < n,
                    jnp.minimum(tgt_row, n - 1) * km + tgt_slot, nk)
    k_old = Ke[jnp.minimum(enc, nk - 1), :p]
    return msg, tgt_row, enc, k_old


# ---------------------------------------------------------------------------
# Fused-XLA round_step (CPU/GPU default): id-column dedup + telescoped theta
# ---------------------------------------------------------------------------


def round_step_xla(theta, Ke, got_ever, msg, tgt_row, enc, k_old,
                   theta_base, a_w):
    """Fused MP round over the flat slot table (see module docstring).

    Two flat scatters land ``[msg | id]`` (two halves of ~B rows each beat
    one 2B-row scatter on CPU); the id read-back picks the winners; ONE
    row scatter-add applies the telescoped deltas with the first-receipt
    base swap folded in as a ``theta_base - theta`` correction (a second
    scalar id scatter picks one first-receipt winner per row, so the
    correction lands exactly once even when a row's first round delivers
    into several slots).  Returns ``(theta, Ke, got_ever, keep)`` with
    ``keep`` the per-event winner mask (exactly one True per landed
    (row, slot) target).

    The first-receipt machinery (the base swap and the ``got_ever``
    update) is gated behind a runtime ``lax.cond`` on
    ``all(got_ever)``: once every row has received a message the
    correction is identically zero, and steady-state rounds run only the
    telescoped scatter-add — ~25% cheaper on CPU at n=10k.  The warm
    branch computes exactly what the ungated body did, so results are
    bitwise identical either way.
    """
    n = theta.shape[0]
    nk, p1 = Ke.shape
    p = p1 - 1
    m = msg.shape[0]
    half = m // 2
    ids = jnp.arange(m, dtype=Ke.dtype)               # exact in f32: m < 2^24
    payload = jnp.concatenate([msg, ids[:, None]], axis=1)
    # scatter: winner dedup downstream — the id column records which
    # duplicate landed; `keep` (below) reads it back, so any scatter order
    # yields a consistent winner
    Ke = Ke.at[enc[:half]].set(payload[:half], mode="drop")
    Ke = Ke.at[enc[half:]].set(payload[half:], mode="drop")  # scatter: winner dedup
    enc_c = jnp.minimum(enc, nk - 1)
    keep = (tgt_row < n) & (Ke[enc_c, p] == ids)
    row_c = jnp.minimum(tgt_row, n - 1)
    srow = jnp.where(keep, tgt_row, n)
    delta = jnp.where(keep, a_w[enc_c], 0.0)[:, None] * (msg - k_old)

    def _warm(got_ever):
        first = keep & ~got_ever[row_c]
        frow = jnp.where(first, tgt_row, n)
        # scatter: winner dedup downstream — first_w reads back which
        # duplicate first-receipt event landed in fid
        fid = jnp.zeros((n,), Ke.dtype).at[frow].set(ids, mode="drop")
        first_w = first & (fid[row_c] == ids)
        base_corr = jnp.where(first_w, 1.0, 0.0)[:, None] * (
            theta_base[row_c] - theta[row_c]
        )
        # scatter: idempotent (every value is True)
        return delta + base_corr, got_ever.at[frow].set(True, mode="drop")

    def _steady(got_ever):
        return delta, got_ever

    # the cond returns only the (2B, p) update payload — theta itself
    # stays outside the branches, so its scatter still runs in place
    upd, got_ever = jax.lax.cond(jnp.all(got_ever), _steady, _warm,
                                 got_ever)
    theta = theta.at[srow].add(upd, mode="drop")
    return theta, Ke, got_ever, keep


# ---------------------------------------------------------------------------
# Pallas round_step megakernel (TPU)
# ---------------------------------------------------------------------------


def _load_row(ref, i):
    """(X, p) ref -> row i as (p,)."""
    return pl.load(ref, (pl.ds(i, 1), slice(None)))[0]


def _load_slot(ref, i, s):
    """(X, k, p) ref -> slot (i, s) as (p,)."""
    return pl.load(ref, (pl.ds(i, 1), pl.ds(s, 1), slice(None)))[0, 0]


def _load_scalar(ref, i, s=None):
    """(X, 1) or (X, k) ref -> scalar at (i[, s])."""
    if s is None:
        return pl.load(ref, (pl.ds(i, 1), slice(None)))[0, 0]
    return pl.load(ref, (pl.ds(i, 1), pl.ds(s, 1)))[0, 0]


def _store_row(ref, i, val):
    pl.store(ref, (pl.ds(i, 1), slice(None)), val[None])


def _store_slot(ref, i, s, val):
    pl.store(ref, (pl.ds(i, 1), pl.ds(s, 1), slice(None)), val[None, None])


def _store_scalar(ref, i, s, val):
    pl.store(ref, (pl.ds(i, 1), pl.ds(s, 1)), val[None, None])


def _mp_round_kernel(theta_ref, ke_ref, got_ref, msg_ref, row_ref, enc_ref,
                     kold_ref, base_ref, aw_ref,
                     theta_o, ke_o, got_o, keep_o, *, nk: int, block_b: int):
    ph = pl.program_id(0)
    bi = pl.program_id(1)
    f32 = jnp.float32

    @pl.when((ph == 0) & (bi == 0))
    def _init():
        theta_o[...] = theta_ref[...]
        ke_o[...] = ke_ref[...]
        got_o[...] = got_ref[...]

    @pl.when(ph == 0)
    def _land():
        # sequential per-event scatter of [msg | id]: duplicates resolve in
        # event order, and the surviving id names the winner for phase 1
        def body(e, carry):
            g = bi * block_b + e
            encv = enc_ref[e, 0]
            landed = encv < nk
            slot = jnp.where(landed, encv, 0)
            new = jnp.concatenate([_load_row(msg_ref, e),
                                   g.astype(f32)[None]])
            cur = _load_row(ke_o, slot)
            _store_row(ke_o, slot, jnp.where(landed, new, cur))
            return carry
        jax.lax.fori_loop(0, block_b, body, 0)

    @pl.when(ph == 1)
    def _update():
        p = base_ref.shape[1]

        def body(e, carry):
            g = bi * block_b + e
            encv = enc_ref[e, 0]
            landed = encv < nk
            slot = jnp.where(landed, encv, 0)
            win = landed & (_load_scalar(ke_o, slot, p) == g.astype(f32))
            _store_scalar(keep_o, g, 0, win.astype(jnp.int32))
            row = jnp.where(win, row_ref[e, 0], 0)
            go = _load_scalar(got_o, row) != 0
            first = win & ~go
            th = _load_row(theta_o, row)
            th = jnp.where(first, _load_row(base_ref, row), th)
            delta = jnp.where(win, _load_scalar(aw_ref, slot)
                              * (_load_row(msg_ref, e)
                                 - _load_row(kold_ref, e)), 0.0)
            _store_row(theta_o, row, th + delta)
            _store_scalar(got_o, row, 0, (go | win).astype(jnp.int32))
            return carry
        jax.lax.fori_loop(0, block_b, body, 0)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def round_step_pallas(theta, Ke, got_ever, msg, tgt_row, enc, k_old,
                      theta_base, a_w, *, block_b: int = 128,
                      interpret: bool = False):
    """Pallas megakernel over :func:`round_step_xla`'s signature.

    ``interpret`` is an explicit opt-in (CPU validation only); use
    ``kernels.dispatch`` for automatic selection.  See the module docstring
    for the grid/phase layout and the whole-state-in-VMEM sizing rule.
    """
    n, p = theta.shape
    nk = Ke.shape[0]
    m = msg.shape[0]
    block_b = max(1, min(block_b, m))
    pad = (-m) % block_b
    nb = (m + pad) // block_b

    def col(x, fill):
        # (2B,) event field -> padded (2B + pad, 1) int32; pads ride at the
        # sentinels (enc = n*k, row = n) so they are no-ops in both phases
        x = jnp.asarray(x).astype(jnp.int32)
        if pad:
            x = jnp.concatenate([x, jnp.full((pad,), fill, jnp.int32)])
        return x.reshape(-1, 1)

    def mat(x):
        x = jnp.asarray(x, jnp.float32)
        if pad:
            x = jnp.concatenate(
                [x, jnp.zeros((pad, x.shape[1]), jnp.float32)])
        return x

    f32 = functools.partial(jnp.asarray, dtype=jnp.float32)
    full = lambda a: pl.BlockSpec(a.shape, lambda ph, bi, _nd=a.ndim:
                                  (0,) * _nd)
    ev_col = pl.BlockSpec((block_b, 1), lambda ph, bi: (bi, 0))
    ev_mat = pl.BlockSpec((block_b, p), lambda ph, bi: (bi, 0))
    args = (f32(theta), f32(Ke), got_ever.astype(jnp.int32).reshape(n, 1),
            mat(msg), col(tgt_row, n), col(enc, nk), mat(k_old),
            f32(theta_base), f32(a_w).reshape(nk, 1))
    kernel = functools.partial(_mp_round_kernel, nk=nk, block_b=block_b)
    theta_o, ke_o, got_o, keep_o = pl.pallas_call(
        kernel,
        grid=(2, nb),
        in_specs=[full(args[0]), full(args[1]), full(args[2]),
                  ev_mat, ev_col, ev_col, ev_mat,
                  full(args[7]), full(args[8])],
        out_specs=[full(args[0]), full(args[1]), full(args[2]),
                   pl.BlockSpec((m + pad, 1), lambda ph, bi: (0, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, p), jnp.float32),
                   jax.ShapeDtypeStruct((nk, p + 1), jnp.float32),
                   jax.ShapeDtypeStruct((n, 1), jnp.int32),
                   jax.ShapeDtypeStruct((m + pad, 1), jnp.int32)],
        interpret=interpret,
    )(*args)
    return (theta_o, ke_o, got_o[:, 0].astype(bool),
            keep_o[:m, 0].astype(bool))


# ---------------------------------------------------------------------------
# cl_edge_step — the CL-ADMM edge phase as one op
# ---------------------------------------------------------------------------


def cl_edge_step(theta, K, Z_own, Z_nbr, L_own, L_nbr,
                 pv_th, pv_K, pv_Lo, pv_Ln,
                 upd, own_s, oth_a, oth_s, stale, got, *, rho: float):
    """One batched CL-ADMM edge phase (scenario-engine semantics).

    theta (n, p) / K (n, k, p) are *post-primal*; Z/L slot arrays and the
    previous-round publish snapshot ``pv_*`` are round-start.  Per event
    side e: agent ``upd[e]`` updates its slot ``own_s[e]`` from partner
    ``oth_a[e]``'s payload (slot ``oth_s[e]``; ``stale`` selects the
    snapshot), scattered only where ``got`` (OOB-dropped otherwise).

    The expressions mirror ``simulate.engines._cl_scenario_scan`` line for
    line (payload selection, ``core.sparse.admm_edge_halfstep`` math, four
    masked scatters) — same compute graph, so the dispatch-routed engine's
    trajectory is bit-for-bit what the inline code produced.  Registered as
    both ``reference`` and ``xla``: the masked gather/scatter expression
    already lowers to one fused XLA program (same precedent as
    ``edge_reweight``).
    """
    n = theta.shape[0]
    stale_c = stale[:, None]
    th_pay = jnp.where(stale_c, pv_th[oth_a], theta[oth_a])
    k_pay = jnp.where(stale_c, pv_K[oth_a, oth_s], K[oth_a, oth_s])
    lo_pay = jnp.where(stale_c, pv_Lo[oth_a, oth_s], L_own[oth_a, oth_s])
    ln_pay = jnp.where(stale_c, pv_Ln[oth_a, oth_s], L_nbr[oth_a, oth_s])
    theta_own = theta[upd]
    k_own = K[upd, own_s]
    l_own = L_own[upd, own_s]
    l_nbr = L_nbr[upd, own_s]
    # core.sparse.admm_edge_halfstep, inlined to keep kernels/ free of a
    # core -> kernels -> core import cycle (expressions kept identical)
    z_own = 0.5 * ((l_own + ln_pay) / rho + theta_own + k_pay)
    z_nbr = 0.5 * ((lo_pay + l_nbr) / rho + th_pay + k_own)
    lo_new = l_own + rho * (theta_own - z_own)
    ln_new = l_nbr + rho * (k_own - z_nbr)
    rowu = jnp.where(got, upd, n)
    # scatter: unique targets — each event side writes its own (agent, slot)
    # cell; a slot belongs to one edge and each edge fires once per round
    Z_own = Z_own.at[rowu, own_s].set(z_own, mode="drop")
    Z_nbr = Z_nbr.at[rowu, own_s].set(z_nbr, mode="drop")  # scatter: unique targets
    L_own = L_own.at[rowu, own_s].set(lo_new, mode="drop")  # scatter: unique targets
    L_nbr = L_nbr.at[rowu, own_s].set(ln_new, mode="drop")  # scatter: unique targets
    return Z_own, Z_nbr, L_own, L_nbr


def _cl_edge_kernel(theta_ref, K_ref, Zo_ref, Zn_ref, Lo_ref, Ln_ref,
                    pth_ref, pK_ref, pLo_ref, pLn_ref,
                    av, sv, ov, tv, stv, gv,
                    Zo_o, Zn_o, Lo_o, Ln_o,
                    zo_scr, zn_scr, lo_scr, ln_scr, *,
                    rho: float, block_b: int):
    ph = pl.program_id(0)
    bi = pl.program_id(1)

    @pl.when((ph == 0) & (bi == 0))
    def _init():
        Zo_o[...] = Zo_ref[...]
        Zn_o[...] = Zn_ref[...]
        Lo_o[...] = Lo_ref[...]
        Ln_o[...] = Ln_ref[...]

    @pl.when(ph == 0)
    def _compute():
        # every half-step reads round-start refs only -> no hazard; results
        # park in scratch until all of phase 0 has run
        def body(e, carry):
            g = bi * block_b + e
            a = av[e, 0]
            so = sv[e, 0]
            o = ov[e, 0]
            ot = tv[e, 0]
            stl = stv[e, 0] != 0
            th_pay = jnp.where(stl, _load_row(pth_ref, o),
                               _load_row(theta_ref, o))
            k_pay = jnp.where(stl, _load_slot(pK_ref, o, ot),
                              _load_slot(K_ref, o, ot))
            lo_pay = jnp.where(stl, _load_slot(pLo_ref, o, ot),
                               _load_slot(Lo_ref, o, ot))
            ln_pay = jnp.where(stl, _load_slot(pLn_ref, o, ot),
                               _load_slot(Ln_ref, o, ot))
            theta_own = _load_row(theta_ref, a)
            k_own = _load_slot(K_ref, a, so)
            l_own = _load_slot(Lo_ref, a, so)
            l_nbr = _load_slot(Ln_ref, a, so)
            z_own = 0.5 * ((l_own + ln_pay) / rho + theta_own + k_pay)
            z_nbr = 0.5 * ((lo_pay + l_nbr) / rho + th_pay + k_own)
            _store_row(zo_scr, g, z_own)
            _store_row(zn_scr, g, z_nbr)
            _store_row(lo_scr, g, l_own + rho * (theta_own - z_own))
            _store_row(ln_scr, g, l_nbr + rho * (k_own - z_nbr))
            return carry
        jax.lax.fori_loop(0, block_b, body, 0)

    @pl.when(ph == 1)
    def _scatter():
        def body(e, carry):
            g = bi * block_b + e
            ok = gv[e, 0] != 0
            row = jnp.where(ok, av[e, 0], 0)
            slot = jnp.where(ok, sv[e, 0], 0)
            for scr, out in ((zo_scr, Zo_o), (zn_scr, Zn_o),
                             (lo_scr, Lo_o), (ln_scr, Ln_o)):
                old = _load_slot(out, row, slot)
                _store_slot(out, row, slot,
                            jnp.where(ok, _load_row(scr, g), old))
            return carry
        jax.lax.fori_loop(0, block_b, body, 0)


@functools.partial(jax.jit, static_argnames=("rho", "block_b", "interpret"))
def cl_edge_step_pallas(theta, K, Z_own, Z_nbr, L_own, L_nbr,
                        pv_th, pv_K, pv_Lo, pv_Ln,
                        upd, own_s, oth_a, oth_s, stale, got, *,
                        rho: float, block_b: int = 128,
                        interpret: bool = False):
    """Pallas realization of :func:`cl_edge_step` (same signature).

    Grid ``(2, n_event_blocks)``: phase 0 computes every half-step from
    round-start state into VMEM scratch, phase 1 lands the masked scatters —
    the same all-reads-before-any-write barrier the XLA form gets from
    functional updates.
    """
    n, k, p = K.shape
    E = upd.shape[0]
    block_b = max(1, min(block_b, E))
    pad = (-E) % block_b
    nb = (E + pad) // block_b

    def col(x):
        x = jnp.asarray(x).astype(jnp.int32)
        if pad:
            x = jnp.concatenate([x, jnp.zeros((pad,), jnp.int32)])
        return x.reshape(-1, 1)

    f32 = functools.partial(jnp.asarray, dtype=jnp.float32)
    full = lambda a: pl.BlockSpec(a.shape, lambda ph, bi, _nd=a.ndim:
                                  (0,) * _nd)
    ev_spec = pl.BlockSpec((block_b, 1), lambda ph, bi: (bi, 0))
    args = (f32(theta), f32(K), f32(Z_own), f32(Z_nbr), f32(L_own),
            f32(L_nbr), f32(pv_th), f32(pv_K), f32(pv_Lo), f32(pv_Ln),
            col(upd), col(own_s), col(oth_a), col(oth_s), col(stale),
            col(got))
    kernel = functools.partial(_cl_edge_kernel, rho=rho, block_b=block_b)
    slot_shape = jax.ShapeDtypeStruct((n, k, p), jnp.float32)
    return pl.pallas_call(
        kernel,
        grid=(2, nb),
        in_specs=[full(a) for a in args[:10]] + [ev_spec] * 6,
        out_specs=[full(K)] * 4,
        out_shape=[slot_shape] * 4,
        scratch_shapes=[pltpu.VMEM((E + pad, p), jnp.float32)] * 4,
        interpret=interpret,
    )(*args)

"""Multi-device (shard_map) wrappers for the mixing hot paths (DESIGN.md §11).

Row-partition the agent axis of a mix op across a 1-D sim mesh
(``launch.sim_mesh``): every shard owns a contiguous block of output rows,
all-gathers the model table its gathers read from, and runs one of the
existing single-device implementations (fused XLA or the Pallas kernel) on
its block.  The wrappers are shape-preserving — global arrays in, global
arrays out — so they register in ``kernels.dispatch`` as ordinary
implementations (``xla_sharded`` / ``pallas_sparse_sharded``) and engine
code stays backend-agnostic.

This is the *graph-oblivious* sharding seam: it cannot know which rows a
shard actually needs, so it exchanges the full table every call.  The
event-driven engines in ``repro.simulate.partition`` sit above this seam
and do better — they precompute a graph partition and exchange only the
halo (boundary) rows.

On a mesh of one device the wrappers degenerate to the inner impl plus a
no-op collective, so they are safe defaults anywhere.
"""

from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.launch.sim_mesh import AGENT_AXIS, make_sim_mesh, mesh_shards
from repro.launch.sim_mesh import shard_map_1d


def _pad_rows(x, rows: int):
    if x.shape[0] == rows:
        return x
    return jnp.pad(x, ((0, rows - x.shape[0]),) + ((0, 0),) * (x.ndim - 1))


def sharded_sparse_mix(table, idx, w, b, sol, *, inner: Callable, mesh=None):
    """CSR gather-mix with the agent axis sharded over the sim mesh.

    table, sol: (n, p); idx: (n, k); w: (n, k); b: (n,) -> (n, p).
    Each shard all-gathers the model table (gather targets are arbitrary
    rows), then runs ``inner`` — any single-device sparse_mix impl — on its
    row block.  Pad rows carry w == 0 / b == 0, so they mix to 0 and are
    sliced off.
    """
    mesh = make_sim_mesh() if mesh is None else mesh
    n = table.shape[0]
    rows = mesh_shards(mesh) * math.ceil(n / mesh_shards(mesh))

    def block(table_blk, idx_blk, w_blk, b_blk, sol_blk):
        full = jax.lax.all_gather(table_blk, AGENT_AXIS, tiled=True)
        return inner(full, idx_blk, w_blk, b_blk, sol_blk)

    spec = P(AGENT_AXIS)
    run = shard_map_1d(block, mesh, in_specs=(spec,) * 5, out_specs=spec)
    padded = [_pad_rows(a, rows) for a in (table, idx, w, b, sol)]
    return run(*padded)[:n]


def sharded_admm_primal(
    w, live, z_own, z_nbr, l_own, l_nbr, D, m, sx, mu, rho, *, inner, mesh=None
):
    """Batched quadratic CL-ADMM primal with the agent axis sharded.

    w, live: (n, k); z/l rows: (n, k, p); D, m: (n,); sx: (n, p) ->
    (theta (n, p), theta_js (n, k, p)).  The primal is embarrassingly
    row-parallel (each agent's solve reads only its own slot row), so no
    collective is needed: every shard vmaps ``inner`` — any single-row
    admm_primal impl — over its row block.  Pad rows carry D == 1 and an
    all-False live mask so their (discarded) solves stay finite.
    """
    mesh = make_sim_mesh() if mesh is None else mesh
    n = w.shape[0]
    rows = mesh_shards(mesh) * math.ceil(n / mesh_shards(mesh))

    def row_solve(w_, lv, zo, zn, lo, ln, D_, m_, sx_):
        return inner(w_, lv, zo, zn, lo, ln, D_, m_, sx_, mu, rho)

    spec = P(AGENT_AXIS)
    run = shard_map_1d(
        jax.vmap(row_solve), mesh, in_specs=(spec,) * 9, out_specs=(spec, spec)
    )
    D_pad = jnp.pad(D, (0, rows - n), constant_values=1.0)
    padded = [_pad_rows(a, rows) for a in (w, live, z_own, z_nbr, l_own, l_nbr)]
    theta, theta_js = run(*padded, D_pad, _pad_rows(m, rows), _pad_rows(sx, rows))
    return theta[:n], theta_js[:n]


def sharded_admm_edge(
    t_ii,
    t_ji,
    t_jj,
    t_ij,
    l_own_i,
    l_nbr_j_of_i,
    l_own_j,
    l_nbr_i_of_j,
    *,
    rho,
    inner,
    mesh=None,
):
    """Fused CL-ADMM Z + dual edge update with the edge axis sharded.

    Eight (E, p) inputs -> six (E, p) outputs, signature-identical to the
    single-device admm_edge impls; each shard runs ``inner`` on its edge
    block (the update is independent per edge, so no collective).
    """
    mesh = make_sim_mesh() if mesh is None else mesh
    n_edges = t_ii.shape[0]
    rows = mesh_shards(mesh) * math.ceil(n_edges / mesh_shards(mesh))

    def block(*args):
        return inner(*args, rho=rho)

    spec = P(AGENT_AXIS)
    run = shard_map_1d(block, mesh, in_specs=(spec,) * 8, out_specs=(spec,) * 6)
    padded = [
        _pad_rows(a, rows)
        for a in (t_ii, t_ji, t_jj, t_ij, l_own_i, l_nbr_j_of_i, l_own_j, l_nbr_i_of_j)
    ]
    return tuple(out[:n_edges] for out in run(*padded))


def sharded_edge_reweight(d, w, live, *, eta, lam, inner: Callable, mesh=None):
    """Collaboration-graph re-estimation with the agent (row) axis sharded.

    d, w: (n, k); live: (n, k) bool -> (n, k).  The simplex projection is
    row-local (each agent re-estimates only its own outgoing weights), so
    no collective is needed: every shard runs ``inner`` — any single-device
    edge_reweight impl — on its row block.  Pad rows carry an all-False
    live mask and come back all-zero.
    """
    mesh = make_sim_mesh() if mesh is None else mesh
    n = d.shape[0]
    rows = mesh_shards(mesh) * math.ceil(n / mesh_shards(mesh))

    def block(d_blk, w_blk, live_blk):
        return inner(d_blk, w_blk, live_blk, eta=eta, lam=lam)

    spec = P(AGENT_AXIS)
    run = shard_map_1d(block, mesh, in_specs=(spec,) * 3, out_specs=spec)
    padded = [_pad_rows(a, rows) for a in (d, w, live)]
    return run(*padded)[:n]


def sharded_graph_mix(theta, theta_sol, A, b, *, inner: Callable, mesh=None):
    """Dense Eq. (5) mix with the agent (row) axis sharded over the sim mesh.

    theta, theta_sol: (n, D); A: (n, n); b: (n,) -> (n, D).
    A is row-sharded; theta is all-gathered so every shard can form its
    A_blk @ theta product.  Zero pad columns of A mean the pad rows of the
    gathered theta contribute nothing.
    """
    mesh = make_sim_mesh() if mesh is None else mesh
    n = theta.shape[0]
    rows = mesh_shards(mesh) * math.ceil(n / mesh_shards(mesh))
    A_pad = jnp.pad(A, ((0, rows - n), (0, rows - n)))

    def block(theta_blk, sol_blk, A_blk, b_blk):
        full = jax.lax.all_gather(theta_blk, AGENT_AXIS, tiled=True)
        return inner(full, sol_blk, A_blk, b_blk)

    spec = P(AGENT_AXIS)
    run = shard_map_1d(block, mesh, in_specs=(spec,) * 4, out_specs=spec)
    padded = [_pad_rows(a, rows) for a in (theta, theta_sol, A_pad, b)]
    return run(*padded)[:n]

"""Unified backend dispatch for the mixing/ADMM hot paths (DESIGN.md §10).

The paper's two algorithms share a handful of hot-path primitives — the
graph-weighted model mix (Eq. 5), its CSR gather-mix counterpart, the
quadratic CL-ADMM primal, the fused ADMM edge update, the per-agent
neighbor reduction, and causal attention for the LM workloads.  Each exists
in up to three realizations (pure-jnp oracle, fused XLA expression, Pallas
TPU kernel); before this module every call site picked one ad-hoc.

This module is the single chooser.  A registry keyed by

    op   ∈ {mix, sparse_mix, admm_primal, admm_edge, round_step,
            cl_edge_step, edge_reweight, neighbor_aggregate, attention}
    impl ∈ {reference, xla, pallas, pallas_sparse}

maps to concrete callables; ``resolve(op, backend)`` returns the callable a
call site should use.  Selection rules:

* **auto** (the default): Pallas *compiled* on TPU, fused XLA on CPU/GPU.
  Auto never selects an interpret-mode Pallas impl — interpret is a
  validation tool, orders of magnitude slower than XLA, and must be
  requested explicitly together with the impl
  (``ReproBackend.using(interpret=True, <op>="pallas")``).  Impls
  registered ``interpret_only=True`` (e.g. the ``admm_edge`` Pallas kernel,
  ~36x slower than its fused-XLA form even compiled) are additionally
  skipped by auto on TPU and require the interpret opt-in everywhere.
* per-op **overrides** via :class:`ReproBackend`, threaded through
  ``core.model_propagation`` / ``core.collaborative`` / ``core.sparse`` /
  ``simulate.engines`` / ``coupling.strategies`` / ``models.blocks``.
* env escape hatches for experiments without code changes:
  ``REPRO_BACKEND=<impl>`` forces the default implementation,
  ``REPRO_PALLAS_INTERPRET=1`` opts in to interpret mode off-TPU.
  Both are read at TRACE time: jitted engines whose static backend arg is
  unchanged keep their compiled program, so flipping an env var
  mid-process does not retrace — pass an explicit ``ReproBackend`` to
  switch implementations reliably.

``ReproBackend`` is a frozen (hashable) dataclass so it can ride through
``jax.jit`` static arguments; resolution happens at trace time, so the
chosen implementation is baked into the compiled program.

Registering a new op implementation::

    from repro.kernels import dispatch

    @dispatch.register("mix", "my_impl")
    def _mix_my_impl(theta, theta_sol, A, b):
        ...

Pallas implementations register a *factory* taking the interpret flag::

    @dispatch.register("mix", "my_pallas", pallas=True)
    def _mix_my_pallas(interpret):
        return functools.partial(my_kernel, interpret=interpret)

Every implementation of an op must share the op's canonical signature
(documented per-op below); parity with ``reference`` within 1e-5 on
randomized inputs is enforced by tests/test_dispatch.py.
"""

from __future__ import annotations

import dataclasses
import functools
import os
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import admm_update as _au
from . import flash_attention as _fa
from . import graph_mix as _gm
from . import ref
from . import round_fuse as _rf
from . import sharded as _sh
from . import sparse_mix as _sm
# Backend-independent slot-table/prefetch helpers for the round_step op,
# re-exported so engine code reaches them through dispatch (the
# no-direct-kernel-imports invariant) — they are layout utilities shared by
# every round_step impl, not selectable implementations themselves.
from .round_fuse import (decode_slots, encode_slots,  # noqa: F401
                         round_prefetch, round_scales, round_stale_src)

IMPLS = ("reference", "xla", "pallas", "pallas_sparse", "xla_sharded",
         "pallas_sparse_sharded")
# Pallas impls "auto" may pick (single-device only; the sharded wrappers are
# explicit opt-ins — they reshard their inputs, which auto must never do
# silently).
_PALLAS_IMPLS = ("pallas", "pallas_sparse")


class BackendUnavailable(RuntimeError):
    """Requested implementation cannot run on this platform as configured."""


@dataclasses.dataclass(frozen=True)
class _Impl:
    """One registered implementation of an op.

    ``make(interpret)`` returns the callable; non-Pallas impls ignore the
    flag.  ``pallas`` marks impls that lower through pallas_call and hence
    need a TPU (compiled) or an explicit interpret opt-in (CPU/GPU).
    ``interpret_only`` marks Pallas impls kept for validation only (their
    compiled form loses to fused XLA): auto never selects them on any
    platform and resolving one requires the interpret opt-in even on TPU.
    """

    name: str
    make: Callable[[bool], Callable]
    pallas: bool = False
    interpret_only: bool = False


_REGISTRY: Dict[str, Dict[str, _Impl]] = {}


def register(op: str, impl: str, *, pallas: bool = False,
             interpret_only: bool = False):
    """Decorator registering ``fn`` as implementation ``impl`` of ``op``.

    Plain impls register the op callable itself; Pallas impls (``pallas=
    True``) register a factory ``make(interpret: bool) -> callable``;
    ``interpret_only=True`` (implies Pallas semantics) demotes the impl to
    an explicit-opt-in validation tool.
    """
    def deco(fn):
        # profiler attribution: every registered hot-path callable runs
        # under a stable "repro/<op>/<impl>" scope, so jax.profiler traces
        # group kernel time by the dispatch decision that produced it
        # (DESIGN.md §14).  Scoping happens here — not in resolve() — so
        # ``make(interpret)`` is memoized and repeated resolution returns
        # the identical callable (jit caches keyed on it stay warm, and
        # selection can be asserted with ``is``).  Reference impls stay
        # unwrapped: they are parity oracles, not profiled hot paths.
        scope = f"repro/{op}/{impl}"

        def _scoped(inner):
            @functools.wraps(inner)
            def run(*args, **kwargs):
                with jax.named_scope(scope):
                    return inner(*args, **kwargs)
            return run

        if impl == "reference":
            make = (lambda interpret, _fn=fn: _fn)
        elif pallas:
            make = functools.lru_cache(maxsize=None)(
                lambda interpret, _fn=fn: _scoped(_fn(interpret)))
        else:
            make = (lambda interpret, _fn=_scoped(fn): _fn)
        _REGISTRY.setdefault(op, {})[impl] = _Impl(impl, make, pallas,
                                                   interpret_only)
        return fn
    return deco


def ops() -> Tuple[str, ...]:
    """All registered op names."""
    return tuple(sorted(_REGISTRY))


def implementations(op: str) -> Tuple[str, ...]:
    """Registered implementation names for ``op`` (reference first)."""
    impls = _REGISTRY[op]
    return tuple(sorted(impls, key=lambda n: (n != "reference", n)))


def _env_default() -> str:
    return os.environ.get("REPRO_BACKEND", "auto")


def _env_interpret() -> bool:
    # same parse as kernels.ops._interpret: set-and-not-falsy means opt-in
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    return env is not None and env not in ("0", "false", "False")


def _platform() -> str:
    return jax.default_backend()


@dataclasses.dataclass(frozen=True)
class ReproBackend:
    """Backend selection config threaded through the algorithm layers.

    default:   implementation used for every op without an override —
               "auto" picks Pallas-compiled on TPU and fused XLA elsewhere.
    overrides: per-op (op, impl) pairs, e.g. (("mix", "pallas"),).
    interpret: explicit opt-in to Pallas interpret mode off-TPU (None
               defers to the REPRO_PALLAS_INTERPRET env var; on TPU the
               kernels always compile unless interpret is True).

    Frozen/hashable so it can be a jit static argument.
    """

    default: str = "auto"
    overrides: Tuple[Tuple[str, str], ...] = ()
    interpret: Optional[bool] = None

    @classmethod
    def using(cls, default: str = "auto",
              interpret: Optional[bool] = None, **per_op: str) -> "ReproBackend":
        """Keyword-friendly constructor: ``ReproBackend.using(mix="pallas")``."""
        return cls(default=default,
                   overrides=tuple(sorted(per_op.items())),
                   interpret=interpret)

    def impl_for(self, op: str) -> str:
        for o, impl in self.overrides:
            if o == op:
                return impl
        return self.default

    def wants_interpret(self) -> bool:
        if self.interpret is not None:
            return self.interpret
        return _env_interpret()


def _auto_impl(op: str) -> str:
    """Platform default: Pallas compiled on TPU (when the op has a Pallas
    impl that is not interpret-only), fused XLA otherwise.  Auto never
    selects an impl that would run in interpret mode — interpret is a
    validation tool and must be requested together with an explicit impl
    override (tests/test_dispatch.py pins this rule)."""
    impls = _REGISTRY[op]
    if _platform() == "tpu":
        name = next((n for n in _PALLAS_IMPLS
                     if n in impls and not impls[n].interpret_only), None)
        if name is not None:
            return name
    return "xla" if "xla" in impls else "reference"


def available(op: str, impl: str, *, interpret: Optional[bool] = None) -> bool:
    """Whether (op, impl) can run here. Pallas impls need a TPU or an
    interpret opt-in; interpret-only impls need the opt-in everywhere."""
    entry = _REGISTRY.get(op, {}).get(impl)
    if entry is None:
        return False
    if not entry.pallas:
        return True
    if interpret is None:
        interpret = _env_interpret()
    if entry.interpret_only:
        return bool(interpret)
    return _platform() == "tpu" or bool(interpret)


def resolve(op: str, backend: Optional[ReproBackend] = None) -> Callable:
    """Return the callable implementing ``op`` under ``backend``.

    Happens at trace time (cheap, deterministic): jitted engines bake the
    chosen implementation into the compiled program.
    """
    if op not in _REGISTRY:
        raise KeyError(f"unknown op {op!r}; registered: {ops()}")
    if backend is None:
        backend = ReproBackend(default=_env_default())
    name = backend.impl_for(op)
    if name == "auto":
        name = _auto_impl(op)
    entry = _REGISTRY[op].get(name)
    if entry is None:
        raise KeyError(
            f"op {op!r} has no implementation {name!r}; "
            f"registered: {implementations(op)}")
    interpret = False
    if entry.pallas:
        interpret = backend.wants_interpret()
        if entry.interpret_only and not interpret:
            raise BackendUnavailable(
                f"{op}/{name} is an interpret-only validation kernel (its "
                f"compiled form loses to the fused XLA impl). Pass "
                f"ReproBackend(interpret=True) (or set "
                f"REPRO_PALLAS_INTERPRET=1) to run it, or use the 'xla' "
                f"implementation.")
        if _platform() != "tpu" and not interpret:
            raise BackendUnavailable(
                f"{op}/{name} is a Pallas kernel: it compiles on TPU only. "
                f"On {_platform()!r} pass ReproBackend(interpret=True) (or "
                f"set REPRO_PALLAS_INTERPRET=1) to opt in to the slow "
                f"interpret mode, or use the 'xla' implementation.")
        if _platform() == "tpu" and backend.interpret is None \
                and not entry.interpret_only:
            interpret = False          # compiled is the TPU default
    return entry.make(interpret)


# ---------------------------------------------------------------------------
# mix — dense graph-weighted model mixing (paper Eq. 5):
#   (theta (n, D), theta_sol (n, D), A (n, n), b (n,)) -> (n, D)
#   out = A @ theta + b[:, None] * theta_sol
# ---------------------------------------------------------------------------


register("mix", "reference")(ref.graph_mix)


@register("mix", "xla")
def _mix_xla(theta, theta_sol, A, b):
    """Fused single-pass XLA form (f32 accumulate, MXU-friendly dot)."""
    acc = jnp.dot(A.astype(jnp.float32), theta.astype(jnp.float32),
                  preferred_element_type=jnp.float32)
    return (acc + b.astype(jnp.float32)[:, None]
            * theta_sol.astype(jnp.float32)).astype(theta.dtype)


@register("mix", "pallas", pallas=True)
def _mix_pallas(interpret):
    return functools.partial(_gm.graph_mix, interpret=interpret)


@register("mix", "xla_sharded")
def _mix_xla_sharded(theta, theta_sol, A, b):
    """Row-sharded mix over the sim mesh (all-gathered theta); per-shard
    math is the fused XLA form, so parity with it is exact."""
    return _sh.sharded_graph_mix(theta, theta_sol, A, b, inner=_mix_xla)


# ---------------------------------------------------------------------------
# sparse_mix — CSR gather-mix over padded-neighbor tables:
#   (table (n, p), idx (n, k) int32, w (n, k), b (n,), sol (n, p)) -> (n, p)
#   out[i] = sum_s w[i, s] * table[idx[i, s]] + b[i] * sol[i]
# ---------------------------------------------------------------------------


register("sparse_mix", "reference")(ref.sparse_gather_mix)


@register("sparse_mix", "xla")
def _sparse_mix_xla(table, idx, w, b, sol):
    """Fused take → einsum → fma (the O(n k p) simulator hot loop)."""
    gathered = table[idx].astype(jnp.float32)                # (n, k, p)
    mixed = jnp.einsum("nk,nkp->np", w.astype(jnp.float32), gathered)
    return (mixed + b.astype(jnp.float32)[:, None]
            * sol.astype(jnp.float32)).astype(table.dtype)


@register("sparse_mix", "pallas_sparse", pallas=True)
def _sparse_mix_pallas(interpret):
    return functools.partial(_sm.sparse_gather_mix, interpret=interpret)


@register("sparse_mix", "xla_sharded")
def _sparse_mix_xla_sharded(table, idx, w, b, sol):
    """Agent-sharded gather-mix over the sim mesh: each shard all-gathers
    the model table and runs the fused XLA mix on its row block."""
    return _sh.sharded_sparse_mix(table, idx, w, b, sol,
                                  inner=_sparse_mix_xla)


@register("sparse_mix", "pallas_sparse_sharded", pallas=True)
def _sparse_mix_pallas_sharded(interpret):
    inner = functools.partial(_sm.sparse_gather_mix, interpret=interpret)
    return functools.partial(_sh.sharded_sparse_mix, inner=inner)


# ---------------------------------------------------------------------------
# admm_primal — exact quadratic CL-ADMM primal for one agent's slot row
# (paper §4.2 step 1, block elimination):
#   (w (k,), live (k,) bool, z_own (k, p), z_nbr (k, p), l_own (k, p),
#    l_nbr (k, p), D_l, m_l, sx (p,), mu, rho) -> (theta_l (p,), theta_js (k, p))
# ---------------------------------------------------------------------------


register("admm_primal", "reference")(ref.quadratic_primal)


def _admm_primal_batched_call(fn, w, live, z_own_s, z_nbr_s, l_own_s,
                              l_nbr_s, D_l, m_l, sx, mu, rho):
    """Accept the canonical rowwise signature on a batched impl ``fn`` by
    lifting single-row inputs to a batch of one."""
    one = [a[None] for a in (w, live, z_own_s, z_nbr_s, l_own_s, l_nbr_s)]
    D_b = jnp.asarray(D_l, jnp.float32)[None]
    m_b = jnp.asarray(m_l, jnp.float32)[None]
    theta, theta_js = fn(*one, D_b, m_b, sx[None], mu, rho)
    return theta[0], theta_js[0]


@register("admm_primal", "xla")
def _admm_primal_xla(w, live, z_own_s, z_nbr_s, l_own_s, l_nbr_s,
                     D_l, m_l, sx, mu, rho):
    """Fused XLA form: one masked pass over the slot row, dot-product
    reductions instead of where-sums."""
    f = jnp.float32
    w = w.astype(f)
    wl = jnp.where(live, w, 0.0)                              # (k,)
    b = rho * z_nbr_s.astype(f) - l_nbr_s.astype(f)           # (k, p)
    denom = jnp.where(live, w + rho, 1.0)                     # (k,)
    n_nbrs = jnp.sum(live)
    a = (D_l + 2.0 * mu * D_l * m_l + rho * n_nbrs
         - jnp.sum(wl * wl / denom))
    zo = jnp.where(live[:, None], rho * z_own_s.astype(f)
                   - l_own_s.astype(f), 0.0)
    rhs = (2.0 * mu * D_l * sx
           + jnp.sum(zo, axis=0)
           + (wl / denom) @ jnp.where(live[:, None], b, 0.0))
    theta_l = rhs / a
    theta_js = (w[:, None] * theta_l[None, :] + b) / denom[:, None]
    return theta_l, theta_js


@register("admm_primal", "xla_sharded")
def _admm_primal_xla_sharded(w, live, z_own_s, z_nbr_s, l_own_s, l_nbr_s,
                             D_l, m_l, sx, mu, rho):
    """Agent-row-sharded primal over the sim mesh (per-shard vmap of the
    fused XLA row solve — the solve is row-local, so no collective).

    Accepts the canonical rowwise signature AND the stacked batched form
    ((n, k), ... (n,), (n, p)); ``core.sparse.batched_admm_primal`` feeds
    sharded impls the batched form directly instead of vmapping them.
    """
    run = functools.partial(_sh.sharded_admm_primal, inner=_admm_primal_xla)
    if w.ndim == 1:
        return _admm_primal_batched_call(
            run, w, live, z_own_s, z_nbr_s, l_own_s, l_nbr_s,
            D_l, m_l, sx, mu, rho)
    return run(w, live, z_own_s, z_nbr_s, l_own_s, l_nbr_s, D_l, m_l, sx,
               mu, rho)


# ---------------------------------------------------------------------------
# admm_primal_inexact — B AdamW steps on the reduced local Lagrangian
# (DiNNO-style inexact primal for arbitrary differentiable losses,
# DESIGN.md §18).  Canonical rowwise signature:
#   (w (k,), live (k,) bool, z_own (k, p), z_nbr (k, p), l_own (k, p),
#    l_nbr (k, p), D_l, x (m, q), y (m,), mask (m,), theta0 (p,), mu, rho,
#    *, loss_fn, b_steps, opt) -> (theta_l (p,), theta_js (k, p))
# loss_fn / b_steps / opt are trace-time constants supplied by the
# PrimalSolver (core.primal.InexactPrimal), which vmaps the row op over
# the round's compacted agent rows; b_steps=None is the provable B -> inf
# fixed point (quadratic loss only — the exact quadratic_primal solve).
# ---------------------------------------------------------------------------


register("admm_primal_inexact", "reference")(ref.inexact_primal)
# the reference is already a fused scan of AdamW steps; CPU/GPU reuse it
register("admm_primal_inexact", "xla")(ref.inexact_primal)


# ---------------------------------------------------------------------------
# admm_edge — fused CL-ADMM Z + dual update for a batch of edges
# (paper §4.2 steps 2-3): 8 inputs (E, p), rho kw-only -> 6 outputs (E, p)
# ---------------------------------------------------------------------------


register("admm_edge", "reference")(ref.admm_edge_update)


@register("admm_edge", "xla")
def _admm_edge_xla(t_ii, t_ji, t_jj, t_ij, l_own_i, l_nbr_j_of_i,
                   l_own_j, l_nbr_i_of_j, *, rho: float):
    return ref.admm_edge_update(t_ii, t_ji, t_jj, t_ij, l_own_i,
                                l_nbr_j_of_i, l_own_j, l_nbr_i_of_j, rho)


@register("admm_edge", "xla_sharded")
def _admm_edge_xla_sharded(t_ii, t_ji, t_jj, t_ij, l_own_i, l_nbr_j_of_i,
                           l_own_j, l_nbr_i_of_j, *, rho: float):
    """Edge-axis-sharded Z/dual update over the sim mesh; per-shard math is
    the reference expression, so parity with it is exact."""
    return _sh.sharded_admm_edge(t_ii, t_ji, t_jj, t_ij, l_own_i,
                                 l_nbr_j_of_i, l_own_j, l_nbr_i_of_j,
                                 rho=rho, inner=ref.admm_edge_update)


# Interpret-only: the compiled form of this kernel is ~36x slower than the
# fused XLA expression (BENCH_dispatch) — it stays registered as a parity
# target for the Pallas gather/scatter idiom, never as a hot path.
@register("admm_edge", "pallas", pallas=True, interpret_only=True)
def _admm_edge_pallas(interpret):
    return functools.partial(_au.admm_edge_update, interpret=interpret)


# ---------------------------------------------------------------------------
# round_step — one fused MP gossip round (scenario-engine semantics) over
# the flat slot table (round_fuse module docstring, DESIGN.md §15):
#   (theta (n,p), Ke (n*k, p+1) slots + id column, got_ever (n,) bool,
#    msg (2B,p), tgt_row (2B,) int32, enc (2B,) int32, k_old (2B,p),
#    theta_base (n,p), a_w (n*k,)) -> (theta', Ke', got_ever', keep (2B,))
# Event operands come from ``round_fuse.round_prefetch`` (gathered *after*
# the previous round's scatters); ``Ke``/``a_w``/``theta_base`` come from
# ``encode_slots`` / ``round_scales`` / the Eq. 6 image of the warm-start
# slots.  The op assumes the scheduler's delivery => active-receiver
# guarantee and never consults an ``active`` vector.
# ---------------------------------------------------------------------------


register("round_step", "reference")(ref.gossip_round_step)
register("round_step", "xla")(_rf.round_step_xla)


@register("round_step", "pallas", pallas=True)
def _round_step_pallas(interpret):
    return functools.partial(_rf.round_step_pallas, interpret=interpret)


# ---------------------------------------------------------------------------
# cl_edge_step — one fused CL-ADMM edge phase (scenario-engine semantics):
#   (theta (n,p), K (n,k,p), Z_own, Z_nbr, L_own, L_nbr (n,k,p),
#    pv_th (n,p), pv_K/pv_Lo/pv_Ln (n,k,p) publish snapshot,
#    upd/own_s/oth_a/oth_s (E,) int32, stale/got (E,) bool, *, rho)
#   -> (Z_own', Z_nbr', L_own', L_nbr')
# ---------------------------------------------------------------------------


register("cl_edge_step", "reference")(_rf.cl_edge_step)
# The masked gather/halfstep/scatter expression already lowers to one fused
# XLA program; registering the identical callable keeps the scenario
# engine's trajectory bit-for-bit whichever name resolves (same precedent
# as edge_reweight).
register("cl_edge_step", "xla")(_rf.cl_edge_step)


@register("cl_edge_step", "pallas", pallas=True)
def _cl_edge_step_pallas(interpret):
    return functools.partial(_rf.cl_edge_step_pallas, interpret=interpret)


# ---------------------------------------------------------------------------
# edge_reweight — local collaboration-graph re-estimation (Zantedeschi et
# al. 2019): sparse simplex projection of per-slot dissimilarities, blended
# into the current row-stochastic weights:
#   (d (B, k), w (B, k), live (B, k) bool, *, eta, lam) -> (B, k)
#   out = (1 - eta) * w + eta * proj_simplex(-d / (2 lam), live)
# ---------------------------------------------------------------------------


register("edge_reweight", "reference")(ref.edge_reweight)
# The sort/cumsum projection already lowers to one fused XLA program; the
# reference expression IS the fused form (same precedent as
# neighbor_aggregate), and registering the identical callable keeps the
# joint engines' bit-for-bit trajectory match intact whichever name
# resolves.
register("edge_reweight", "xla")(ref.edge_reweight)


@register("edge_reweight", "xla_sharded")
def _edge_reweight_xla_sharded(d, w, live, *, eta: float, lam: float):
    """Agent-row-sharded re-weighting over the sim mesh (the projection is
    row-local, so no collective); per-shard math is the reference
    expression, so parity with it is exact."""
    return _sh.sharded_edge_reweight(d, w, live, eta=eta, lam=lam,
                                     inner=ref.edge_reweight)


# ---------------------------------------------------------------------------
# neighbor_aggregate — per-agent slot reduction shared by the dense and
# sparse engines:  (w (k,), theta (k, p)) -> (p,)
# ---------------------------------------------------------------------------


register("neighbor_aggregate", "reference")(ref.neighbor_aggregate)
# The einsum IS the fused XLA form; registering the same callable keeps the
# dense/sparse engines' bit-for-bit trajectory match (identical HLO) intact
# whichever name resolves.
register("neighbor_aggregate", "xla")(ref.neighbor_aggregate)


# ---------------------------------------------------------------------------
# attention — causal (optionally sliding-window) attention with GQA
# expansion:  (q (B,S,H,hd), k (B,S,K,hd), v (B,S,K,hd), *, window) -> (B,S,H,hd)
# ---------------------------------------------------------------------------


def _gqa_expand(q, k, v):
    H, K = q.shape[2], k.shape[2]
    if K != H:
        k = jnp.repeat(k, H // K, axis=2)
        v = jnp.repeat(v, H // K, axis=2)
    return k, v


@register("attention", "reference")
def _attention_reference(q, k, v, *, window=None):
    k, v = _gqa_expand(q, k, v)
    return ref.flash_attention(q, k, v, window=window)


# Dense softmax attention lowers to fused XLA ops directly; the reference
# expression is the XLA path.
register("attention", "xla")(_attention_reference)


@register("attention", "pallas", pallas=True)
def _attention_pallas(interpret):
    def run(q, k, v, *, window=None, block_q: int = 256, block_k: int = 256):
        k, v = _gqa_expand(q, k, v)
        return _fa.flash_attention(q, k, v, window=window, block_q=block_q,
                                   block_k=block_k, interpret=interpret)
    return run

"""Pallas TPU kernels (validated with interpret=True on CPU) + jnp oracles."""

from . import ops, ref

__all__ = ["ops", "ref"]

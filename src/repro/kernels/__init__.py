"""Pallas TPU kernels (validated with interpret=True on CPU) + jnp oracles
+ the backend dispatch registry that chooses between them per platform."""

from . import dispatch, ops, ref
from .dispatch import BackendUnavailable, ReproBackend, resolve

__all__ = ["dispatch", "ops", "ref", "ReproBackend", "resolve",
           "BackendUnavailable"]

"""Pallas TPU kernel: fused CL-ADMM edge update (paper §4.2 steps 2-3).

For a batch of edges the Z update and all four dual updates are pure
elementwise arithmetic over (E, p) slabs; unfused this is 6 reads + 6 writes
of every operand through HBM. The kernel fuses everything into one pass:
8 input tiles in, 6 output tiles out, zero intermediate traffic — a pure
memory-roofline win for large p (deep-model coupling, DESIGN.md §3).

Grid: (num_edge_blocks, num_p_blocks); tiles (bE, bP) in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(tii, tji, tjj, tij, loi, lnj, loj, lni, zi, zj, loi_o, lnj_o,
            loj_o, lni_o, *, rho: float):
    inv = 1.0 / rho
    t_ii = tii[...].astype(jnp.float32)
    t_ji = tji[...].astype(jnp.float32)
    t_jj = tjj[...].astype(jnp.float32)
    t_ij = tij[...].astype(jnp.float32)
    l_oi = loi[...].astype(jnp.float32)
    l_nj = lnj[...].astype(jnp.float32)
    l_oj = loj[...].astype(jnp.float32)
    l_ni = lni[...].astype(jnp.float32)
    z_i = 0.5 * ((l_oi + l_ni) * inv + t_ii + t_ji)
    z_j = 0.5 * ((l_oj + l_nj) * inv + t_jj + t_ij)
    zi[...] = z_i.astype(zi.dtype)
    zj[...] = z_j.astype(zj.dtype)
    loi_o[...] = (l_oi + rho * (t_ii - z_i)).astype(loi_o.dtype)
    lnj_o[...] = (l_nj + rho * (t_ij - z_j)).astype(lnj_o.dtype)
    loj_o[...] = (l_oj + rho * (t_jj - z_j)).astype(loj_o.dtype)
    lni_o[...] = (l_ni + rho * (t_ji - z_i)).astype(lni_o.dtype)


@functools.partial(jax.jit, static_argnames=("rho", "block_e", "block_p",
                                             "interpret"))
def admm_edge_update(t_ii, t_ji, t_jj, t_ij, l_own_i, l_nbr_j_of_i, l_own_j,
                     l_nbr_i_of_j, *, rho: float, block_e: int = 8,
                     block_p: int = 512, interpret: bool = False):
    """All inputs (E, p). Returns (z_i, z_j, 4 updated duals) like ref.py.

    ``interpret`` is an explicit opt-in (CPU validation only); the default
    compiles for TPU — use ``kernels.dispatch`` for automatic selection.
    """
    E, p = t_ii.shape
    block_e = min(block_e, E)
    block_p = min(block_p, max(p, 1))
    Ep = pl.cdiv(E, block_e) * block_e
    pp = pl.cdiv(p, block_p) * block_p
    args = (t_ii, t_ji, t_jj, t_ij, l_own_i, l_nbr_j_of_i, l_own_j,
            l_nbr_i_of_j)
    if (Ep, pp) != (E, p):
        args = tuple(jnp.pad(a, ((0, Ep - E), (0, pp - p))) for a in args)
    grid = (Ep // block_e, pp // block_p)
    spec = pl.BlockSpec((block_e, block_p), lambda i, j: (i, j))
    dtype = t_ii.dtype
    outs = pl.pallas_call(
        functools.partial(_kernel, rho=rho),
        grid=grid,
        in_specs=[spec] * 8,
        out_specs=[spec] * 6,
        out_shape=[jax.ShapeDtypeStruct((Ep, pp), dtype)] * 6,
        interpret=interpret,
    )(*args)
    return tuple(o[:E, :p] for o in outs)

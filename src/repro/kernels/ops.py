"""Jit'd wrappers that ALWAYS run the Pallas kernels (kernel validation).

Used by tests/benchmarks that exercise the kernels themselves: on TPU the
kernels compile natively; elsewhere they run under the (slow) interpreter so
the kernel code path stays testable on CPU. Production call sites should go
through ``kernels.dispatch`` instead, which only picks a Pallas kernel when
it can compile (or when interpret mode is explicitly requested) and falls
back to fused XLA otherwise. GQA head expansion for flash_attention happens
here so the kernel sees equal head counts.
"""

from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from . import graph_mix as _gm
from . import flash_attention as _fa
from . import admm_update as _au
from . import sparse_mix as _sm


def _interpret() -> bool:
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"


def graph_mix(theta, theta_sol, A, b, *, block_d: int = _gm.DEFAULT_BLOCK_D):
    return _gm.graph_mix(theta, theta_sol, A, b, block_d=block_d,
                         interpret=_interpret())


def sparse_gather_mix(table, idx, w, b, sol, *,
                      block_n: int = _sm.DEFAULT_BLOCK_N):
    return _sm.sparse_gather_mix(table, idx, w, b, sol, block_n=block_n,
                                 interpret=_interpret())


def flash_attention(q, k, v, *, window: Optional[int] = None,
                    block_q: int = 256, block_k: int = 256):
    """q: (B, S, H, hd); k, v: (B, S, K, hd) with K | H (GQA)."""
    H, K = q.shape[2], k.shape[2]
    if K != H:
        k = jnp.repeat(k, H // K, axis=2)
        v = jnp.repeat(v, H // K, axis=2)
    return _fa.flash_attention(q, k, v, window=window, block_q=block_q,
                               block_k=block_k, interpret=_interpret())


def admm_edge_update(*args, rho: float, block_e: int = 8, block_p: int = 512):
    return _au.admm_edge_update(*args, rho=rho, block_e=block_e,
                                block_p=block_p, interpret=_interpret())

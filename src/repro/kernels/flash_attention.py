"""Pallas TPU kernel: blocked causal attention with optional sliding window.

Grid: (batch*heads, num_q_blocks, num_kv_blocks). The last grid dim is the
sequential (arbitrary-marched) TPU dimension; online-softmax statistics (m, l)
and the output accumulator persist in VMEM scratch across kv steps and are
finalized on the last one. Causal + window structure skips fully-masked kv
blocks via @pl.when (no MXU work issued for them).

BlockSpec tiling (VMEM working set per grid step, bf16):
  q: (bQ, hd) + k,v: (bK, hd) + acc: (bQ, hd) f32 + p: (bQ, bK) f32
  with bQ=bK=256, hd=128: ~0.6 MB << 16 MB VMEM; MXU dims are multiples
  of 128 (bQ, bK, hd).

The GQA head expansion happens in ops.py (kv heads repeated to q heads)
so the kernel sees equal head counts.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, block_q: int, block_k: int, n_kv_blocks: int,
            window: Optional[int], seq_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * block_q
    k_start = ki * block_k
    # block-level reachability: any (q, k) pair in range?
    causal_live = k_start <= q_start + block_q - 1
    window_live = True
    if window is not None:
        # newest q in block attends back `window`; block dead if entirely older
        window_live = k_start + block_k - 1 > q_start - window

    @pl.when(causal_live & window_live)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                     # (bQ, hd)
        k = k_ref[0].astype(jnp.float32)                     # (bK, hd)
        v = v_ref[0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale      # (bQ, bK)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos <= qpos
        if window is not None:
            mask &= kpos > qpos - window
        logits = jnp.where(mask, logits, NEG_INF)
        m_prev = m_ref[...]                                  # (bQ, 1)
        m_new = jnp.maximum(m_prev, logits.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-20)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("window", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q, k, v, *, window: Optional[int] = None,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool = False):
    """q, k, v: (B, S, H, hd) equal head counts -> (B, S, H, hd), causal.

    ``interpret`` is an explicit opt-in (CPU validation only); the default
    compiles for TPU — use ``kernels.dispatch`` for automatic selection.
    """
    B, S, H, hd = q.shape
    assert k.shape == v.shape == (B, S, H, hd)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    scale = hd ** -0.5
    # fold (B, H) into one grid axis; layout (BH, S, hd)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kf = k.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    vf = v.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    n_q, n_k = S // block_q, S // block_k
    grid = (B * H, n_q, n_k)
    kernel = functools.partial(
        _kernel, scale=scale, block_q=block_q, block_k=block_k,
        n_kv_blocks=n_k, window=window, seq_len=S)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),   # output accumulator
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),    # running denom l
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)

"""Pallas TPU kernel: sparse gather-mix — the CSR model-propagation sweep.

Computes, over padded-neighbor tables (DESIGN.md §4),

    out[i] = sum_s w[i, s] * table[idx[i, s]] + b[i] * sol[i]

i.e. one synchronous Eq. (5) sweep of the sparse simulator: each agent mixes
its k_max neighbor models (gathered by index from the stacked model table)
with its anchored solitary model.  This is the O(n k p) counterpart of
``graph_mix.py``'s dense (n x n) @ (n x D) MXU matmul: arithmetic intensity
drops to ~k, so the kernel is gather-bandwidth-bound; the win over the
unfused jnp path (take -> einsum -> fma) is a single pass over the slot
tables with the anchor fused in.

TPU mapping: the agent axis is tiled into blocks of ``block_n`` rows; the
model table stays resident and is gathered row-by-row with dynamic slices
(k_max is small — 8/16 — so the inner slot loop is fully unrolled).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_N = 128


def _kernel(idx_ref, w_ref, b_ref, sol_ref, table_ref, out_ref, *, k: int):
    bn = idx_ref.shape[0]

    def row(r, _):
        acc = b_ref[r, 0] * sol_ref[pl.ds(r, 1), :].astype(jnp.float32)
        for s in range(k):                       # k_max static, unrolled
            nbr = table_ref[pl.ds(idx_ref[r, s], 1), :].astype(jnp.float32)
            acc = acc + w_ref[r, s] * nbr
        out_ref[pl.ds(r, 1), :] = acc.astype(out_ref.dtype)  # scatter: unique targets
        return 0

    jax.lax.fori_loop(0, bn, row, 0)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def sparse_gather_mix(table, idx, w, b, sol, *,
                      block_n: int = DEFAULT_BLOCK_N,
                      interpret: bool = False):
    """table: (N, p); idx: (n, k) int32; w: (n, k); b: (n,); sol: (n, p)
    -> (n, p).

    The output row count follows ``idx``/``sol``; the gather table may hold
    more rows than are mixed (N >= n) — the partitioned engines mix each
    shard's n local rows against the all-gathered N-row global table.

    Pad slots must carry w == 0 (their gathered rows are multiplied away),
    which is exactly the NeighborTables convention.

    ``interpret`` is an explicit opt-in (CPU validation only); the default
    compiles for TPU. Prefer ``kernels.dispatch.resolve("sparse_mix",
    backend)``, which picks the right implementation per platform.
    """
    n_table, p = table.shape
    n, k = idx.shape
    np_ = pl.cdiv(n, block_n) * block_n
    if np_ != n:
        pad = ((0, np_ - n), (0, 0))
        idx_p = jnp.pad(idx, pad)                  # pad rows gather table[0]
        w_p = jnp.pad(w, pad)                      # ... with zero weight
        b_p = jnp.pad(b, (0, np_ - n))
        sol_p = jnp.pad(sol, pad)
    else:
        idx_p, w_p, b_p, sol_p = idx, w, b, sol
    grid = (np_ // block_n,)
    out = pl.pallas_call(
        functools.partial(_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),   # idx tile
            pl.BlockSpec((block_n, k), lambda i: (i, 0)),   # w tile
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),   # b tile
            pl.BlockSpec((block_n, p), lambda i: (i, 0)),   # sol tile
            pl.BlockSpec((n_table, p), lambda i: (0, 0)),   # table: resident
        ],
        out_specs=pl.BlockSpec((block_n, p), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, p), table.dtype),
        interpret=interpret,
    )(idx_p, w_p, b_p[:, None], sol_p, table)
    return out[:n]

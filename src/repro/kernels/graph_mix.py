"""Pallas TPU kernel: fused graph-weighted model mixing (the MP step).

Computes  out = A @ theta + b[:, None] * theta_sol  for stacked agent models
theta (n, D) where D is a flattened parameter block. This is the paper's
model-propagation update (Eq. 5/6) applied blockwise over a large parameter
vector — the compute hot-spot of the coupling layer (DESIGN.md §3).

TPU mapping: n (the agent count) is small (16/32 at pod scale, O(100) in the
paper's setting) and is padded to the 128-lane MXU width once; the parameter
axis D is tiled into VMEM-resident blocks. Each grid step does one
(n x n) @ (n x bD) MXU matmul plus a fused multiply-add — arithmetic
intensity ~n, so the kernel is HBM-bandwidth-bound and the win over the
unfused reference is one pass over theta/theta_sol instead of three.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_BLOCK_D = 512


def _kernel(a_ref, b_ref, theta_ref, sol_ref, out_ref):
    A = a_ref[...].astype(jnp.float32)            # (n, n)
    bvec = b_ref[...].astype(jnp.float32)         # (n, 1)
    th = theta_ref[...].astype(jnp.float32)       # (n, bD)
    sol = sol_ref[...].astype(jnp.float32)        # (n, bD)
    mixed = jnp.dot(A, th, preferred_element_type=jnp.float32)
    out_ref[...] = (mixed + bvec * sol).astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_d", "interpret"))
def graph_mix(theta, theta_sol, A, b, *, block_d: int = DEFAULT_BLOCK_D,
              interpret: bool = False):
    """theta, theta_sol: (n, D); A: (n, n); b: (n,) -> (n, D).

    ``interpret`` is an explicit opt-in (CPU validation only — orders of
    magnitude slower than the compiled kernel); the default compiles for
    TPU. Prefer ``kernels.dispatch.resolve("mix", backend)``, which picks
    the right implementation per platform.

    D is padded to a multiple of ``block_d`` (lane-aligned); n rides in the
    sublane dim and may be any size (the compiler pads to 8/16/32 sublanes).
    """
    n, D = theta.shape
    Dp = pl.cdiv(D, block_d) * block_d
    if Dp != D:
        pad = ((0, 0), (0, Dp - D))
        theta_p = jnp.pad(theta, pad)
        sol_p = jnp.pad(theta_sol, pad)
    else:
        theta_p, sol_p = theta, theta_sol
    grid = (Dp // block_d,)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),        # A: resident
            pl.BlockSpec((n, 1), lambda i: (0, 0)),        # b
            pl.BlockSpec((n, block_d), lambda i: (0, i)),  # theta tile
            pl.BlockSpec((n, block_d), lambda i: (0, i)),  # sol tile
        ],
        out_specs=pl.BlockSpec((n, block_d), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, Dp), theta.dtype),
        interpret=interpret,
    )(A, b[:, None], theta_p, sol_p)
    return out[:, :D]

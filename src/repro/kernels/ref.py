"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.optim.adamw import adamw_init, adamw_update

NEG_INF = -1e30


def graph_mix(theta, theta_sol, A, b):
    """Fused model-propagation step over stacked agent models.

    theta, theta_sol: (n, D)  — one row per agent, D = flattened param block
    A: (n, n) mixing matrix (e.g. diag(alpha/(alpha+abar c)) @ P)
    b: (n,)  anchor coefficients (abar c / (alpha + abar c))
    returns A @ theta + b[:, None] * theta_sol
    """
    return (A @ theta.astype(jnp.float32)
            + b[:, None] * theta_sol.astype(jnp.float32)).astype(theta.dtype)


def sparse_gather_mix(table, idx, w, b, sol):
    """CSR model-propagation sweep over padded-neighbor tables.

    table, sol: (n, p); idx: (n, k) int32 neighbor ids; w: (n, k) mixing
    weights (0 at pads); b: (n,) anchor coefficients.
    returns out[i] = sum_s w[i, s] * table[idx[i, s]] + b[i] * sol[i]
    """
    gathered = table[idx].astype(jnp.float32)            # (n, k, p)
    mixed = jnp.einsum("nk,nkp->np", w.astype(jnp.float32), gathered)
    return (mixed + b[:, None] * sol.astype(jnp.float32)).astype(table.dtype)


def neighbor_aggregate(w_slots, theta_slots):
    """sum_s w[s] * theta[s]  over the k_max slot axis: (k,), (k, p) -> (p,).

    The single shared reduction the dense and sparse engines both use — same
    shapes, same HLO, bit-identical result (pad slots contribute an exact
    0.0 * value).
    """
    return jnp.einsum("k,kp->p", w_slots, theta_slots)


def quadratic_primal(w, live, z_own_s, z_nbr_s, l_own_s, l_nbr_s,
                     D_l, m_l, sx, mu, rho):
    """Exact argmin of the CL-ADMM local Lagrangian for the quadratic loss,
    over one agent's slot row (block elimination; paper §4.2 step 1).

    w: (k,) raw edge weights (0 at pads); live: (k,) bool;
    z/l slices: (k, p) agent-l secondary/dual rows; D_l, m_l scalars;
    sx: (p,) sum of l's local samples.  Returns (theta_l (p,), theta_js (k, p)).
    """
    b = rho * z_nbr_s - l_nbr_s                               # (k, p)
    denom = jnp.where(live, w + rho, 1.0)                     # (k,)
    n_nbrs = jnp.sum(live)
    a = (D_l + 2.0 * mu * D_l * m_l + rho * n_nbrs
         - jnp.sum(jnp.where(live, w * w / denom, 0.0)))
    rhs = (2.0 * mu * D_l * sx
           + jnp.sum(jnp.where(live[:, None],
                               rho * z_own_s - l_own_s, 0.0), axis=0)
           + jnp.sum(jnp.where(live[:, None],
                               (w[:, None] * b) / denom[:, None], 0.0), axis=0))
    theta_l = rhs / a
    theta_js = (w[:, None] * theta_l[None, :] + b) / denom[:, None]
    return theta_l, theta_js


def inexact_primal(w, live, z_own_s, z_nbr_s, l_own_s, l_nbr_s, D_l,
                   x, y, mask, theta0, mu, rho, *, loss_fn, b_steps, opt):
    """Inexact CL-ADMM primal: ``b_steps`` AdamW steps on the *reduced*
    local Lagrangian (DiNNO-style; DESIGN.md §18), one agent's slot row.

    The neighbor copies are eliminated in closed form each step — the
    slot terms are quadratic in ``theta_js``, whose inner argmin is
    ``theta_js(theta) = (w theta + rho z_nbr - l_nbr) / (w + rho)`` — so
    the objective seen by the optimizer is

        F(theta) = mu D_l loss_fn(theta; x, y, mask)
                 + sum_live [ l_own (theta - z_own)
                              + rho/2 ||theta - z_own||^2 ]
                 + sum_live [ w/2 ||theta - theta_js||^2
                              + l_nbr (theta_js - z_nbr)
                              + rho/2 ||theta_js - z_nbr||^2 ].

    By the envelope theorem the eliminated copies contribute their partial
    gradient only through the explicit theta terms, and for the quadratic
    loss dF/dtheta = a theta - rhs with exactly the (a, rhs) of
    :func:`quadratic_primal` — the unique minimizer of F IS the exact
    block-elimination solve.  ``b_steps=None`` therefore evaluates that
    B -> inf fixed point in closed form (callers gate it to the quadratic
    loss, where the limit is provable); a finite ``b_steps`` runs AdamW
    from the warm start ``theta0`` (the agent's current model).

    w: (k,) edge weights (0 at pads); live: (k,) bool; z/l slices: (k, p);
    D_l scalar; x (m, q), y (m,), mask (m,) the agent's padded local data;
    theta0: (p,) warm start; loss_fn(theta, x, y, mask) -> scalar (a
    guarded ``core.losses`` loss); opt: AdamWConfig.  Returns
    (theta_l (p,), theta_js (k, p)) — dead slots of theta_js carry the
    same don't-care values as :func:`quadratic_primal` (the engines
    overwrite them under the live mask).
    """
    if b_steps is None:
        m_l = jnp.sum(mask)
        sx = jnp.sum(x * mask[:, None], axis=0)
        return quadratic_primal(w, live, z_own_s, z_nbr_s, l_own_s,
                                l_nbr_s, D_l, m_l, sx, mu, rho)

    b = rho * z_nbr_s - l_nbr_s                               # (k, p)
    denom = jnp.where(live, w + rho, 1.0)                     # (k,)

    def theta_js_of(theta):
        return (w[:, None] * theta[None, :] + b) / denom[:, None]

    def objective(theta):
        tjs = theta_js_of(theta)
        d_own = theta[None, :] - z_own_s
        d_js = theta[None, :] - tjs
        d_nbr = tjs - z_nbr_s
        slot = (jnp.sum(l_own_s * d_own, axis=-1)
                + 0.5 * rho * jnp.sum(d_own * d_own, axis=-1)
                + 0.5 * w * jnp.sum(d_js * d_js, axis=-1)
                + jnp.sum(l_nbr_s * d_nbr, axis=-1)
                + 0.5 * rho * jnp.sum(d_nbr * d_nbr, axis=-1))
        return (mu * D_l * loss_fn(theta, x, y, mask)
                + jnp.sum(jnp.where(live, slot, 0.0)))

    grad = jax.grad(objective)

    def step(carry, _):
        theta, opt_state = carry
        theta, opt_state, _ = adamw_update(grad(theta), opt_state, theta, opt)
        return (theta, opt_state), None

    (theta_l, _), _ = jax.lax.scan(
        step, (theta0, adamw_init(theta0, opt)), None, length=b_steps)
    return theta_l, theta_js_of(theta_l)


def flash_attention(q, k, v, *, window: Optional[int] = None):
    """Causal (optionally sliding-window) attention oracle.

    q, k, v: (B, S, H, hd) with equal head counts (GQA expansion happens in
    ops.py before the kernel). Returns (B, S, H, hd).
    """
    B, S, H, hd = q.shape
    scale = hd ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    qp = jnp.arange(S)
    m = qp[None, :] <= qp[:, None]
    if window is not None:
        m &= qp[None, :] > qp[:, None] - window
    logits = jnp.where(m[None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)


def simplex_project_rows(v, live):
    """Euclidean projection of each row of ``v`` onto the probability
    simplex restricted to its ``live`` slots (Held et al. 1974 / Duchi et
    al. 2008 sort-and-threshold form, vectorized over rows).

    v, live: (..., k).  Dead slots are excluded from the support and get an
    exact 0; rows with no live slot return all zeros.
    """
    f = jnp.float32
    vm = jnp.where(live, v.astype(f), NEG_INF)                 # (..., k)
    u = -jnp.sort(-vm, axis=-1)                                # descending
    css = jnp.cumsum(u, axis=-1)
    r = jnp.arange(1, v.shape[-1] + 1, dtype=f)
    cond = u * r > css - 1.0                                   # support test
    rho_n = jnp.sum(cond, axis=-1).astype(jnp.int32)           # support size
    idx = jnp.maximum(rho_n - 1, 0)
    tau = (jnp.take_along_axis(css, idx[..., None], axis=-1)[..., 0] - 1.0) \
        / jnp.maximum(rho_n, 1).astype(f)
    out = jnp.maximum(vm - tau[..., None], 0.0)
    return jnp.where(live & (rho_n > 0)[..., None], out, 0.0)


def edge_reweight(d, w, live, *, eta: float, lam: float):
    """Local collaboration-graph re-estimation step (Zantedeschi et al.
    2019, arXiv:1901.08460, graph block of the alternating scheme).

    Each agent row solves  min_{w in simplex(live)}  <w, d> + lam ||w||^2
    over its live candidate slots — the closed form is the sparse simplex
    projection of ``-d / (2 lam)`` — and relaxes toward it with step
    ``eta``:  w' = (1 - eta) w + eta proj(-d / (2 lam)).

    d: (..., k) per-slot dissimilarities (squared model distances; ignored
    at dead slots); w: (..., k) current row-stochastic weights; live:
    (..., k) bool candidate mask.  Returns the (..., k) updated weights —
    convex blending keeps each live row on the simplex, slots outside the
    live mask are forced to an exact 0, and small ``lam`` yields exact
    zeros inside it (the sparsity the projection is chosen for).  Rows with
    no live slot come back all-zero.
    """
    f = jnp.float32
    target = simplex_project_rows(-d.astype(f) / (2.0 * lam), live)
    out = (1.0 - eta) * w.astype(f) + eta * target
    return jnp.where(live, out, 0.0).astype(w.dtype)


def gossip_round_step(theta, Ke, got_ever, msg, tgt_row, enc, k_old,
                      theta_base, a_w):
    """One batched MP gossip round over the flat slot table — the oracle
    for the fused ``round_step`` implementations (kernels/round_fuse.py).

    State: theta / theta_base (n, p); Ke (n*k, p+1) flat neighbor slots
    with the id column at ``p``; got_ever (n,) bool first-receipt flags;
    a_w (n*k,) per-slot Eq. 6 gains.  Events (already prefetched, see
    ``round_fuse.round_prefetch``): msg / k_old (2B, p) sender models and
    pre-scatter slot values, tgt_row (2B,) receiver rows (``n`` where
    undelivered), enc (2B,) flat targets (``n*k`` sentinel where
    undelivered).

    Winner resolution is deliberately a different mechanism than the fused
    impls' id read-back: a stable sort of the encoded targets marks the
    *last* event of each duplicate run as the winner (matching the
    sequential two-half scatter order), then a single pre-masked scatter
    lands exactly the winning rows.  Receiver updates telescope the
    winners' ``a_w (msg - k_old)`` deltas, swapping in ``theta_base`` on a
    row's first receipt (the engine warm-starts theta at the solitary
    models; a row's slots cannot change before its first receipt, so the
    affine base is exact).
    """
    n = theta.shape[0]
    nk = Ke.shape[0]
    m = msg.shape[0]
    ids = jnp.arange(m)
    order = jnp.argsort(enc, stable=True)
    enc_s = enc[order]
    is_last = jnp.concatenate(
        [enc_s[1:] != enc_s[:-1], jnp.ones((1,), bool)])
    # scatter: unique targets (order is a permutation of 0..m-1)
    keep = jnp.zeros((m,), bool).at[order].set(is_last) & (tgt_row < n)
    payload = jnp.concatenate([msg, ids.astype(Ke.dtype)[:, None]], axis=1)
    # scatter: winner dedup upstream — keep selects exactly one event per enc
    Ke = Ke.at[jnp.where(keep, enc, nk)].set(payload, mode="drop")
    enc_c = jnp.minimum(enc, nk - 1)
    row_c = jnp.minimum(tgt_row, n - 1)
    first = keep & ~got_ever[row_c]
    frow = jnp.where(first, tgt_row, n)
    # scatter: idempotent — every write to row r is theta_base[r]
    theta = theta.at[frow].set(theta_base[row_c], mode="drop")
    delta = jnp.where(keep, a_w[enc_c], 0.0)[:, None] * (msg - k_old)
    theta = theta.at[jnp.where(keep, tgt_row, n)].add(delta, mode="drop")
    got_ever = got_ever.at[frow].set(True, mode="drop")  # scatter: idempotent
    return theta, Ke, got_ever, keep


def admm_edge_update(t_ii, t_ji, t_jj, t_ij, l_own_i, l_nbr_j_of_i,
                     l_own_j, l_nbr_i_of_j, rho: float):
    """Fused CL-ADMM Z + dual update for a batch of edges (paper steps 2-3).

    Inputs are (E, p) slices: for each edge e=(i,j),
      t_ii = Theta_i^i, t_ji = Theta_j^i, t_jj = Theta_j^j, t_ij = Theta_i^j
      l_own_i = Lambda_{ei}^i,  l_nbr_j_of_i = Lambda_{ei}^j   (agent i's duals)
      l_own_j = Lambda_{ej}^j,  l_nbr_i_of_j = Lambda_{ej}^i   (agent j's duals)
    Returns (z_i, z_j, and the four updated duals).
    """
    dtype = t_ii.dtype
    f = jnp.float32
    t_ii, t_ji, t_jj, t_ij = (a.astype(f) for a in (t_ii, t_ji, t_jj, t_ij))
    l_own_i, l_nbr_j_of_i, l_own_j, l_nbr_i_of_j = (
        a.astype(f) for a in (l_own_i, l_nbr_j_of_i, l_own_j, l_nbr_i_of_j))
    z_i = 0.5 * ((l_own_i + l_nbr_i_of_j) / rho + t_ii + t_ji)
    z_j = 0.5 * ((l_own_j + l_nbr_j_of_i) / rho + t_jj + t_ij)
    l_own_i_new = l_own_i + rho * (t_ii - z_i)
    l_nbr_j_of_i_new = l_nbr_j_of_i + rho * (t_ij - z_j)
    l_own_j_new = l_own_j + rho * (t_jj - z_j)
    l_nbr_i_of_j_new = l_nbr_i_of_j + rho * (t_ji - z_i)
    return tuple(a.astype(dtype) for a in
                 (z_i, z_j, l_own_i_new, l_nbr_j_of_i_new, l_own_j_new,
                  l_nbr_i_of_j_new))

"""Launch: production meshes, dry-run lowering, train/serve CLI drivers."""

"""Production meshes (DESIGN.md §4).

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run must set XLA_FLAGS before the first jax call.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_agents: int = 4, model: int = 2, *,
                    multi_pod: bool = False):
    """Small mesh for CPU tests (requires xla_force_host_platform_device_count)."""
    if multi_pod:
        return jax.make_mesh((2, n_agents, model), ("pod", "data", "model"))
    return jax.make_mesh((n_agents, model), ("data", "model"))


def use_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    Compat shim across jax versions: ``jax.set_mesh`` (new), else
    ``jax.sharding.use_mesh``, else the Mesh object's own context manager
    (the only spelling on jax <= 0.4.x).
    """
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    sharding_use = getattr(jax.sharding, "use_mesh", None)
    if sharding_use is not None:
        return sharding_use(mesh)
    return mesh


def n_agents_of(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("pod", 1) * sizes.get("data", 1)

"""Sharding spec assembly for the dry-run / production launchers.

All base model specs are written against the multi-pod axis universe
("pod", "data", "model"); helpers here (a) prepend the agent axis for
agent-stacked trees, (b) neutralize the batch/agent slot where a dim is
vmapped instead, and (c) filter axes absent from the actual mesh.
"""

from __future__ import annotations

from typing import Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import Model
from repro.models.common import adapt_pspec

AGENT_SLOT = ("pod", "data")


def agent_axes_of(mesh) -> Tuple[str, ...]:
    return tuple(a for a in AGENT_SLOT if a in mesh.axis_names)


def _is_spec(x):
    return isinstance(x, P)


def _map_specs(fn, tree):
    return jax.tree_util.tree_map(fn, tree, is_leaf=_is_spec)


def resolve(spec: P, mesh, batch_to=None) -> P:
    """Adapt one base spec: agent slot -> ``batch_to`` (or the mesh's agent
    axes), then drop axes the mesh doesn't have."""
    agent = agent_axes_of(mesh) if batch_to is None else batch_to
    out = []
    for entry in spec:
        if isinstance(entry, tuple) and entry == AGENT_SLOT:
            out.append(agent if agent else None)
        else:
            out.append(entry)
    return adapt_pspec(P(*out), tuple(mesh.axis_names))


def stacked_param_specs(model: Model, mesh):
    """Agent-stacked params: prepend the agent axes to every base leaf."""
    agent = agent_axes_of(mesh)
    return _map_specs(
        lambda s: adapt_pspec(P(agent, *s), tuple(mesh.axis_names)),
        model.param_pspecs())


def batch_specs(model: Model, mesh, mode: str = "train"):
    """Global-batch input specs (batch dim sharded over the agent axes)."""
    return _map_specs(lambda s: resolve(s, mesh), model.batch_pspecs(mode))


def stacked_cache_specs(model: Model, mesh):
    """Per-agent vmapped cache: (A, reps, b, ...) leaves.

    Base cache specs are (reps, batch@agents, ...); under per-agent vmap the
    batch slot is agent-local (None) and the new leading dim carries agents.
    """
    agent = agent_axes_of(mesh)

    def f(s: P) -> P:
        body = resolve(s, mesh, batch_to=())      # null the batch slot
        return adapt_pspec(P(agent, *body), tuple(mesh.axis_names))

    base = model.cache_pspecs()
    layers = _map_specs(f, base["layers"])
    pos = adapt_pspec(P(agent, None), tuple(mesh.axis_names))
    return {"layers": layers, "pos": pos}


def named(tree, mesh):
    return _map_specs(lambda s: NamedSharding(mesh, s), tree)

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

For every (architecture x input shape x mesh): build the REAL train/serve
step (personalized params, coupling collective, AdamW), lower it with
ShapeDtypeStruct inputs (no allocation), compile it for the production mesh,
and record memory_analysis + cost_analysis + collective stats for §Dry-run /
§Roofline.

The XLA_FLAGS line above MUST precede any jax import — jax locks the device
count on first init. Run each combo in its own process:

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
        --shape train_4k [--multi-pod] [--schedule gossip] --out results.json
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import ALIASES, get_config
from repro.coupling import CouplingConfig, make_state
from repro.core import random_geometric_graph
from repro.launch.mesh import make_production_mesh, n_agents_of, use_mesh
from repro.launch.shapes import SHAPES, InputShape, plan_decode
from repro.launch.sharding import (agent_axes_of, stacked_param_specs,
                                   batch_specs, stacked_cache_specs, named)
from repro.launch import hlo_analysis as ha
from repro.models import Model
from repro.models.common import batch_axes
from repro.optim import AdamWConfig
from repro.train import TrainConfig, make_train_step
from repro.train.trainer import TrainState, init_train_state


def active_param_count(cfg, model: Model) -> int:
    total = model.param_count()
    if not cfg.n_experts:
        return total
    expert_extra = 3 * cfg.d_model * cfg.d_ff * (cfg.n_experts - cfg.top_k)
    return total - expert_extra * cfg.n_layers


def abstract_like(tree):
    return jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree)


def build_train(cfg, shape: InputShape, mesh, schedule: str, coupling: str,
                every: int = 1, mix_dtype=jnp.float32):
    model = Model(cfg)
    A = n_agents_of(mesh)
    tcfg = TrainConfig(
        n_agents=A, steps=10_000, optimizer=AdamWConfig(),
        coupling=CouplingConfig(mode=coupling, schedule=schedule,
                                every=every, mix_dtype=mix_dtype))
    graph = random_geometric_graph(A, k=3, seed=0)
    cstate = make_state(graph, np.linspace(0.3, 1.0, A), tcfg.coupling.alpha)
    pspecs = stacked_param_specs(model, mesh)
    step = make_train_step(model, tcfg, cstate, mesh=mesh, spmd=True,
                           param_specs=pspecs)

    state_abs = jax.eval_shape(
        lambda: init_train_state(model, tcfg, jax.random.PRNGKey(0)))
    batch_abs = model.input_specs(shape.global_batch, shape.seq_len, "train")

    state_specs = TrainState(
        params=pspecs, solitary=pspecs,
        opt_state={"m": pspecs, "v": pspecs, "count": P()},
        step=P())
    bspecs = batch_specs(model, mesh, "train")
    agent = agent_axes_of(mesh)
    metric_specs = {"loss": P(), "loss_per_agent": P(agent), "grad_norm": P(),
                    "ce": P(), "aux": P()}
    jitted = jax.jit(step,
                     in_shardings=(named(state_specs, mesh),
                                   named(bspecs, mesh)),
                     out_shardings=(named(state_specs, mesh),
                                    named(metric_specs, mesh)))
    return jitted, (state_abs, batch_abs), model


def build_prefill(cfg, shape: InputShape, mesh):
    model = Model(cfg)
    A = n_agents_of(mesh)
    b = shape.global_batch // A
    assert b >= 1, (shape.name, A)
    plan = plan_decode(cfg, InputShape(shape.name, shape.seq_len,
                                       shape.global_batch, "decode"))
    agent = agent_axes_of(mesh)

    def prefill_step(params, batch):
        with batch_axes(()):
            return jax.vmap(
                lambda p, bb: model.prefill(p, bb, cache_len=plan.cache_len),
                spmd_axis_name=agent)(params, batch)

    pspecs = stacked_param_specs(model, mesh)
    base_b = model.input_specs(b, shape.seq_len, "train")
    batch_abs = jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((A,) + s.shape, s.dtype), base_b)
    bspecs = jax.tree_util.tree_map(
        lambda s: P(agent, *([None] * len(s.shape))), base_b)
    params_abs = jax.eval_shape(
        lambda: jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l[None], (A,) + l.shape),
            model.init(jax.random.PRNGKey(0))))
    jitted = jax.jit(prefill_step,
                     in_shardings=(named(pspecs, mesh), named(bspecs, mesh)))
    return jitted, (params_abs, batch_abs), model


def build_decode(cfg, shape: InputShape, mesh, lockstep: bool = False):
    """Personalized decode: each agent serves its own model on its batch
    slice (global_batch = A * b). When global_batch < n_agents (long_500k:
    one 524k-token stream), serving degenerates to a single shared model
    with pure tensor parallelism — the agent axes are idle, which is the
    honest picture for batch-1 decode and is called out in §Dry-run."""
    model = Model(cfg)
    A = n_agents_of(mesh)
    plan = plan_decode(cfg, shape)
    agent = agent_axes_of(mesh)
    personalized = shape.global_batch >= A

    if personalized:
        b = shape.global_batch // A

        def serve_step(params, cache, batch):
            with batch_axes(()):
                return jax.vmap(
                    lambda p, c, bb: model.decode_step(
                        p, c, bb, window=plan.window, ring=plan.ring,
                        lockstep=lockstep),
                    spmd_axis_name=agent)(params, cache, batch)

        pspecs = stacked_param_specs(model, mesh)
        cspecs = stacked_cache_specs(model, mesh)
        params_abs = jax.eval_shape(
            lambda: jax.tree_util.tree_map(
                lambda l: jnp.broadcast_to(l[None], (A,) + l.shape),
                model.init(jax.random.PRNGKey(0))))
        cache_abs = jax.eval_shape(
            lambda: jax.tree_util.tree_map(
                lambda l: jnp.broadcast_to(l[None], (A,) + l.shape),
                model.init_cache(b, plan.cache_len)))
        tok_shape = (A, b, cfg.n_codebooks) if cfg.family == "audio" \
            else (A, b)
        batch_abs = {"token": jax.ShapeDtypeStruct(tok_shape, jnp.int32)}
        bspecs = {"token": P(agent, *([None] * (len(tok_shape) - 1)))}
    else:
        b = shape.global_batch

        def serve_step(params, cache, batch):
            with batch_axes(()):
                return model.decode_step(params, cache, batch,
                                         window=plan.window, ring=plan.ring,
                                         lockstep=lockstep)

        from repro.launch.sharding import resolve, _map_specs
        pspecs = _map_specs(lambda s: resolve(s, mesh), model.param_pspecs())
        base_c = model.cache_pspecs()
        cspecs = {"layers": _map_specs(
            lambda s: resolve(s, mesh, batch_to=()), base_c["layers"]),
            "pos": P(None)}
        params_abs = jax.eval_shape(
            lambda: model.init(jax.random.PRNGKey(0)))
        cache_abs = jax.eval_shape(
            lambda: model.init_cache(b, plan.cache_len))
        tok_shape = (b, cfg.n_codebooks) if cfg.family == "audio" else (b,)
        batch_abs = {"token": jax.ShapeDtypeStruct(tok_shape, jnp.int32)}
        bspecs = {"token": P(*([None] * len(tok_shape)))}

    jitted = jax.jit(serve_step,
                     in_shardings=(named(pspecs, mesh), named(cspecs, mesh),
                                   named(bspecs, mesh)))
    return jitted, (params_abs, cache_abs, batch_abs), model


def _variant_cfg(cfg, reps_list):
    """Reduced-depth, scan-free cfg for exact HLO cost accounting.

    XLA's cost_analysis counts a while/scan body ONCE regardless of trip
    count, so the real (scanned) program under-reports flops/bytes/collective
    traffic. We therefore measure depth-1 and depth-2 unrolled variants
    (ref attention + parallel mLSTM = no scans anywhere except sLSTM's
    inherent time recurrence, corrected analytically) and extrapolate
    linearly in depth — exact for homogeneous layer stacks.
    """
    import dataclasses as dc
    groups = cfg.scan_groups()
    pattern = []
    for (unit, _), r in zip(groups, reps_list):
        pattern += list(unit) * r
    return dc.replace(cfg, n_layers=len(pattern), pattern=tuple(pattern),
                      scan_layers=False, attn_impl="ref",
                      mlstm_impl="parallel")


_COST_KEYS = ("flops", "bytes")


def _measure(cfg_v, shape, mesh, mode, schedule, coupling, every=1,
             mix_dtype=jnp.float32, lockstep=False):
    if mode == "train":
        jitted, args, _ = build_train(cfg_v, shape, mesh, schedule, coupling,
                                      every=every, mix_dtype=mix_dtype)
    elif mode == "prefill":
        jitted, args, _ = build_prefill(cfg_v, shape, mesh)
    else:
        jitted, args, _ = build_decode(cfg_v, shape, mesh, lockstep=lockstep)
    with use_mesh(mesh):
        compiled = jitted.lower(*args).compile()
    cost = ha.cost_dict(compiled)
    coll = ha.collective_stats(compiled.as_text())
    vec = {"flops": float(cost.get("flops", 0.0)),
           "bytes": float(cost.get("bytes accessed", 0.0))}
    for kind, st in coll.items():
        for f in ("count", "result_bytes", "wire_bytes"):
            vec[f"coll/{kind}/{f}"] = float(st[f])
    return vec


def _vec_op(a, b, f):
    return {k: f(a.get(k, 0.0), b.get(k, 0.0)) for k in set(a) | set(b)}


def _slstm_correction(cfg, shape, n_devices: int) -> dict:
    """Analytical flops/bytes for sLSTM time-scan bodies (counted once by
    XLA): recurrent gate matmuls 8*d*hd flops + ~40*d elementwise per step
    per sample, x3 for fwd+bwd-with-remat. Whole-program totals."""
    n_slstm = sum(1 for k in cfg.layer_kinds if k == "slstm")
    if not n_slstm or shape.mode == "decode":
        return {"flops": 0.0, "bytes": 0.0}
    d = cfg.d_model
    hd = d // cfg.n_heads
    steps = shape.seq_len * shape.global_batch      # token-steps
    fl = n_slstm * steps * (8.0 * d * hd + 40.0 * d)
    by = n_slstm * steps * (48.0 * d)
    mult = 3.0 if shape.mode == "train" else 1.0
    return {"flops": fl * mult, "bytes": by * mult}


def extrapolated_costs(cfg, shape, mesh, mode, schedule, coupling, every=1,
                       mix_dtype=jnp.float32, lockstep=False) -> dict:
    groups = cfg.scan_groups()
    G = len(groups)
    kw = dict(every=every, mix_dtype=mix_dtype, lockstep=lockstep)
    c0 = _measure(_variant_cfg(cfg, [1] * G), shape, mesh, mode, schedule,
                  coupling, **kw)
    total = dict(c0)
    for g, (unit, reps) in enumerate(groups):
        if reps == 1:
            continue
        reps_list = [2 if i == g else 1 for i in range(G)]
        cg = _measure(_variant_cfg(cfg, reps_list), shape, mesh, mode,
                      schedule, coupling, **kw)
        unit_cost = _vec_op(cg, c0, lambda a, b: a - b)
        total = _vec_op(total, unit_cost,
                        lambda a, b: a + (reps - 1) * b)
    corr = _slstm_correction(cfg, shape, int(np.prod(mesh.devices.shape)))
    nd = int(np.prod(mesh.devices.shape))
    total["flops"] += corr["flops"] / nd      # cost_analysis is per-device
    total["bytes"] += corr["bytes"] / nd
    return total


def run_one(arch: str, shape_name: str, multi_pod: bool, schedule: str,
            coupling: str, attn_impl: str, skip_variants: bool = False,
            every: int = 1, mix_dtype="f32", serve_dtype="f32",
            seq_shard: bool = True, lockstep: bool = False,
            moe_impl: str = "scatter", kv_shard: str = "seq",
            tag: str = "") -> dict:
    import dataclasses as dc
    cfg = get_config(arch, "full")
    overrides = {}
    if attn_impl:
        overrides["attn_impl"] = attn_impl
    if not seq_shard:
        overrides["seq_shard"] = False
    if moe_impl != "scatter":
        overrides["moe_impl"] = moe_impl
    if kv_shard != "seq":
        overrides["kv_shard"] = kv_shard
    shape = SHAPES[shape_name]
    if serve_dtype == "bf16" and shape.mode != "train":
        # serving weights in bf16 (training keeps f32 master weights)
        overrides["param_dtype"] = jnp.bfloat16
    if overrides:
        cfg = dc.replace(cfg, **overrides)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {"arch": cfg.name, "shape": shape_name, "mode": shape.mode,
           "multi_pod": multi_pod, "schedule": schedule, "coupling": coupling,
           "tag": tag,
           "mesh": "x".join(map(str, mesh.devices.shape)),
           "n_devices": int(np.prod(mesh.devices.shape))}
    mixd = jnp.bfloat16 if mix_dtype == "bf16" else jnp.float32
    t0 = time.time()
    if shape.mode == "train":
        jitted, args, model = build_train(cfg, shape, mesh, schedule,
                                          coupling, every=every,
                                          mix_dtype=mixd)
        tokens = shape.global_batch * shape.seq_len
        mf = ha.model_flops_train
    elif shape.mode == "prefill":
        jitted, args, model = build_prefill(cfg, shape, mesh)
        tokens = shape.global_batch * shape.seq_len
        mf = lambda n, t, a=0: ha.model_flops_decode(n, t, a)
    else:
        jitted, args, model = build_decode(cfg, shape, mesh,
                                           lockstep=lockstep)
        tokens = shape.global_batch
        mf = ha.model_flops_decode
    rec["param_count"] = model.param_count()
    rec["active_params"] = active_param_count(cfg, model)

    with use_mesh(mesh):
        lowered = jitted.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

    mem = compiled.memory_analysis()
    print(mem)
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        v = getattr(mem, attr, None)
        if v is not None:
            rec[attr] = int(v)
    cost = ha.cost_dict(compiled)
    # raw (scanned) numbers — under-report loop bodies; kept for reference
    rec["scanned_flops"] = float(cost.get("flops", 0.0))
    rec["scanned_bytes"] = float(cost.get("bytes accessed", 0.0))
    rec["collectives_scanned"] = ha.collective_stats(compiled.as_text())

    if skip_variants:
        rec["ok"] = True
        return rec

    ex = extrapolated_costs(cfg, shape, mesh, shape.mode, schedule, coupling,
                            every=every, mix_dtype=mixd, lockstep=lockstep)
    rec["cost_flops"] = ex["flops"]              # per-device, scan-corrected
    rec["cost_bytes"] = ex["bytes"]
    coll = {}
    for k, v in ex.items():
        if k.startswith("coll/"):
            _, kind, field = k.split("/")
            # repro-lint: disable=RPL002  dict write, not an array scatter
            coll.setdefault(kind, {})[field] = v
    rec["collectives"] = coll
    A = n_agents_of(mesh)
    score_est = ha.score_traffic_estimate(cfg, shape, A)
    rec["cost_bytes_flash"] = max(ex["bytes"] - score_est, 0.0)
    roof = ha.roofline_terms({"flops": ex["flops"],
                              "bytes accessed": rec["cost_bytes_flash"]},
                             coll, rec["n_devices"])
    rec["roofline"] = roof.as_dict()
    n_active = rec["active_params"]
    rec["model_flops"] = mf(rec["param_count"], tokens, n_active)
    # cost_flops is per-device; model_flops is whole-program
    total_hlo_flops = rec["cost_flops"] * rec["n_devices"]
    rec["useful_flop_ratio"] = (rec["model_flops"] / total_hlo_flops
                                if total_hlo_flops else 0.0)
    rec["ok"] = True
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--schedule", default="dense",
                    choices=["dense", "gossip"])
    ap.add_argument("--coupling", default="mp",
                    choices=["none", "consensus", "mp", "cl"])
    ap.add_argument("--attn", default="", help="override attn_impl")
    ap.add_argument("--skip-variants", action="store_true",
                    help="compile-proof + memory only (no cost extrapolation)")
    # perf levers (§Perf)
    ap.add_argument("--every", type=int, default=1,
                    help="apply coupling every k steps (amortization noted "
                         "in the analysis; the collective still appears in "
                         "HLO once)")
    ap.add_argument("--mix-dtype", default="f32", choices=["f32", "bf16"],
                    help="wire dtype of the coupling collective")
    ap.add_argument("--serve-dtype", default="f32",
                    choices=["f32", "bf16"],
                    help="serving weight dtype (baseline f32)")
    ap.add_argument("--lockstep", action="store_true",
                    help="fleet decode at a shared position (DUS cache writes)")
    ap.add_argument("--moe-impl", default="scatter",
                    choices=["scatter", "gather"])
    ap.add_argument("--kv-shard", default="seq", choices=["seq", "heads"])
    ap.add_argument("--no-seq-shard", action="store_true")
    ap.add_argument("--tag", default="", help="record tag for perf runs")
    ap.add_argument("--out", default="")
    args = ap.parse_args()

    archs = sorted(set(ALIASES.values())) if args.arch == "all" \
        else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    records = []
    for arch in archs:
        for shape in shapes:
            tag = f"{arch} x {shape} ({'2pod' if args.multi_pod else '1pod'})"
            print(f"=== DRYRUN {tag} ===", flush=True)
            try:
                rec = run_one(arch, shape, args.multi_pod, args.schedule,
                              args.coupling, args.attn,
                              skip_variants=args.skip_variants,
                              every=args.every, mix_dtype=args.mix_dtype,
                              serve_dtype=args.serve_dtype,
                              seq_shard=not args.no_seq_shard,
                              lockstep=args.lockstep, moe_impl=args.moe_impl,
                              kv_shard=args.kv_shard, tag=args.tag)
            except Exception as e:
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape,
                       "multi_pod": args.multi_pod, "ok": False,
                       "error": f"{type(e).__name__}: {e}"}
            records.append(rec)
            print(json.dumps({k: v for k, v in rec.items()
                              if k != "collectives"}, indent=1), flush=True)
            if args.out:
                existing = []
                if os.path.exists(args.out):
                    with open(args.out) as f:
                        existing = json.load(f)
                # replace same-key records
                keyf = lambda r: (r.get("arch"), r.get("shape"),
                                  r.get("multi_pod"), r.get("schedule"),
                                  r.get("coupling"), r.get("tag", ""))
                existing = [r for r in existing if keyf(r) != keyf(rec)]
                existing.append(rec)
                with open(args.out, "w") as f:
                    json.dump(existing, f, indent=1)
    bad = [r for r in records if not r.get("ok")]
    print(f"done: {len(records) - len(bad)} ok, {len(bad)} failed")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())

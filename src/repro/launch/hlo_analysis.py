"""HLO post-compile analysis: collective traffic + roofline terms.

``cost_analysis()`` gives FLOPs and HBM bytes but NOT collective bytes
(collectives are zero-flop ops to XLA), so we parse the compiled module text
and sum the sizes of every collective's result buffers. Wire-level bytes per
device are estimated with standard ring-algorithm factors.

Hardware constants (TPU v5e, per DESIGN.md §7): 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes / s / chip
ICI_BW = 50e9                # bytes / s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def cost_dict(compiled) -> dict:
    """Version-compat accessor for Compiled.cost_analysis().

    Newer jax returns a flat dict; jax <= 0.4.x returns a one-element list
    of dicts (and some backends return None).
    """
    c = compiled.cost_analysis()
    if isinstance(c, (list, tuple)):
        c = c[0] if c else {}
    return c or {}

# ring-algorithm wire factor per unit of *result* bytes
_WIRE_FACTOR = {
    "all-gather": 1.0,          # each device receives (n-1)/n of the result
    "all-reduce": 2.0,          # reduce-scatter + all-gather
    "reduce-scatter": 1.0,      # sends ~n-1 shards of result size... ~1x in
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}

_ARRAY_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^=]*?\)|[a-z0-9]+\[[^\]]*\]\S*)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(", re.M)


def _type_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _ARRAY_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective-kind result bytes, wire-model bytes, and op counts."""
    stats = {k: {"count": 0, "result_bytes": 0.0, "wire_bytes": 0.0}
             for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        b = _type_bytes(type_str)
        stats[kind]["count"] += 1
        stats[kind]["result_bytes"] += b
        stats[kind]["wire_bytes"] += b * _WIRE_FACTOR[kind]
    return stats


@dataclasses.dataclass
class Roofline:
    flops: float                 # HLO flops (per full program, all devices)
    hbm_bytes: float
    collective_bytes: float      # wire-model bytes (per device, see note)
    n_devices: int
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    def as_dict(self):
        return {**dataclasses.asdict(self), "dominant": self.dominant}


def roofline_terms(cost: Dict[str, float], coll: Dict[str, Dict[str, float]],
                   n_devices: int, links_per_chip: float = 2.0) -> Roofline:
    """Three roofline terms in seconds.

    cost_analysis of an SPMD executable is PER-DEVICE (the module is the
    per-device program); collective result bytes from the HLO text are also
    per-device buffer sizes.
    """
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    wire = sum(v["wire_bytes"] for v in coll.values())
    return Roofline(
        flops=flops, hbm_bytes=hbm, collective_bytes=wire,
        n_devices=n_devices,
        compute_s=flops / PEAK_FLOPS,
        memory_s=hbm / HBM_BW,
        collective_s=wire / (ICI_BW * links_per_chip),
    )


def score_traffic_estimate(cfg, shape, n_agents: int, tp: int = 16) -> float:
    """Per-device HBM bytes of materialized attention/mLSTM score matrices.

    The cost-measurement variants use the PARALLEL forms (ref attention,
    parallel mLSTM) whose S^2 score tensors hit HBM; the target Pallas
    kernels (flash_attention, chunked mLSTM) keep them in VMEM. Subtracting
    this estimate yields ``cost_bytes_flash`` — the memory-roofline term for
    the target implementation. Estimate: one f32 score tensor is written +
    read ~3x in fwd; backward with remat re-creates it and reads it ~3x more
    (train only).
    """
    S = shape.seq_len
    B_dev = max(shape.global_batch // n_agents, 1)
    mult = {"train": 6.0, "prefill": 3.0, "decode": 0.0}[shape.mode]
    if mult == 0.0:
        return 0.0
    total = 0.0
    for kind in cfg.layer_kinds:
        if kind.startswith("attn"):
            w = cfg.local_window if kind == "attn_local" else cfg.window
            kdim = min(S, w) if w else S
            h_dev = max(cfg.n_heads // tp, 1)
            total += B_dev * h_dev * S * kdim * 4.0 * mult
        elif kind == "mlstm":
            # logD + D + scores: ~3 (B,S,S,H) f32 tensors, heads unsharded
            total += B_dev * cfg.n_heads * S * S * 4.0 * mult * 2.0
    return total


def model_flops_train(n_params: int, n_tokens: int,
                      active_params: int = 0) -> float:
    """6 N D (dense) / 6 N_active D (MoE) — fwd+bwd per token."""
    n = active_params or n_params
    return 6.0 * n * n_tokens


def model_flops_decode(n_params: int, n_tokens: int,
                       active_params: int = 0) -> float:
    """2 N D for single-token decode (no backward)."""
    n = active_params or n_params
    return 2.0 * n * n_tokens

"""Assigned input shapes and the per-(arch, shape) lowering plan.

Decode shapes lower ``serve_step`` (one token against a seq_len-deep cache /
recurrent state); train/prefill shapes lower ``train_step`` / ``prefill``.

long_500k policy (DESIGN.md §5): recurrent/hybrid archs decode natively with
O(1) state; attention archs use their sliding window (native for starcoder2 /
recurrentgemma, the ``long_ctx_window`` variant otherwise), so the KV ring
buffer is window-sized — full O(S) caches at 524k would be dishonest for a
windowed model and full O(S^2) attention is excluded by construction.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.models import ModelConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    mode: str                     # train | prefill | decode


SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ServePlan:
    """How an (arch, decode-shape) pair is served."""
    cache_len: int
    ring: bool
    window: Optional[int]         # attention window override ("auto" = cfg)


def plan_decode(cfg: ModelConfig, shape: InputShape) -> ServePlan:
    assert shape.mode == "decode"
    # native window (starcoder2, recurrentgemma local attn) bounds the cache
    native_w = cfg.window
    if shape.seq_len > 65536:
        # long-context: attention archs switch to their sliding-window variant
        w = native_w if native_w is not None else cfg.long_ctx_window
        has_attn = any(k.startswith("attn") for k in cfg.layer_kinds)
        if not has_attn:
            return ServePlan(cache_len=1, ring=False, window=None)
        return ServePlan(cache_len=min(shape.seq_len, w), ring=True, window=w)
    if native_w is not None and native_w < shape.seq_len:
        return ServePlan(cache_len=native_w, ring=True, window=native_w)
    return ServePlan(cache_len=shape.seq_len, ring=False, window=native_w)


def train_seq_len(cfg: ModelConfig, shape: InputShape) -> int:
    """Total sequence (incl. media/cond prefix) equals the assigned seq_len."""
    return shape.seq_len

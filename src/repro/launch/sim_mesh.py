"""1-D agent meshes for partitioned network simulation (DESIGN.md §11).

The network simulator shards the *agent* axis: a ``(P,)`` mesh whose single
axis (``AGENT_AXIS = "shards"``) carries one graph shard per device.  This
is deliberately distinct from the production train/serve meshes in
``launch.mesh`` (("pod", "data", "model")): the simulator has no model
parallelism — every device runs the same per-shard event loop over its own
block of agents and exchanges halo models between event batches.

On a CPU-only host, multi-device runs use XLA's fake host devices::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python benchmarks/bench_network_sim.py --sharded

The flag must be set before the first jax call in the process (jax locks
the device count on first init), which is why the helpers here never force
a device count themselves — they size the mesh to whatever the process
already has.

Defined as functions so importing this module never touches jax device
state (same rule as ``launch.mesh``).
"""

from __future__ import annotations

from typing import Optional

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

AGENT_AXIS = "shards"

#: The recipe for getting P host devices out of a CPU-only process; must be
#: in the environment before the first jax import (see module docstring).
HOST_DEVICES_RECIPE = "XLA_FLAGS=--xla_force_host_platform_device_count=8"


def max_shards() -> int:
    """Largest usable shard count on this process (= device count)."""
    return jax.device_count()


def make_sim_mesh(n_shards: Optional[int] = None):
    """1-D mesh over ``n_shards`` devices (default: all local devices).

    ``n_shards`` is clamped to the available device count so callers can
    ask for the "ideal" P and degrade gracefully on smaller hosts (a
    single-device process gets a P = 1 mesh, on which the sharded engines
    reduce to the plain sparse path).
    """
    avail = max_shards()
    n = avail if n_shards is None else max(1, min(n_shards, avail))
    return jax.make_mesh((n,), (AGENT_AXIS,))


def mesh_shards(mesh) -> int:
    """Shard count of a sim mesh (size of its agent axis)."""
    return int(dict(zip(mesh.axis_names, mesh.devices.shape))[AGENT_AXIS])


def agent_sharding(mesh, *trailing_dims: Optional[str]) -> NamedSharding:
    """NamedSharding splitting the leading (agent) axis across the mesh."""
    return NamedSharding(mesh, P(AGENT_AXIS, *trailing_dims))


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def shard_map_1d(f, mesh, in_specs, out_specs):
    """Version-compat shard_map over a sim mesh.

    ``jax.shard_map`` (new API, ``check_vma``) when present, else
    ``jax.experimental.shard_map.shard_map`` (jax <= 0.4.x, ``check_rep``).
    Replication checking is disabled in both spellings: the simulator's
    per-shard programs mix replicated event streams with sharded state and
    gather/ppermute collectives the checker cannot type.
    """
    kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        try:
            return sm(f, check_vma=False, **kw)
        except TypeError:
            pass
    from jax.experimental.shard_map import shard_map as sm_old

    return sm_old(f, check_rep=False, **kw)

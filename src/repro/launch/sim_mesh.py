"""1-D agent meshes for partitioned network simulation (DESIGN.md §11).

The network simulator shards the *agent* axis: a ``(P,)`` mesh whose single
axis (``AGENT_AXIS = "shards"``) carries one graph shard per device.  This
is deliberately distinct from the production train/serve meshes in
``launch.mesh`` (("pod", "data", "model")): the simulator has no model
parallelism — every device runs the same per-shard event loop over its own
block of agents and exchanges halo models between event batches.

On a CPU-only host, multi-device runs use XLA's fake host devices::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python benchmarks/bench_network_sim.py --sharded

The flag must be set before the first jax call in the process (jax locks
the device count on first init), which is why the helpers here never force
a device count themselves — they size the mesh to whatever the process
already has.

Defined as functions so importing this module never touches jax device
state (same rule as ``launch.mesh``).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

AGENT_AXIS = "shards"

#: The recipe for getting P host devices out of a CPU-only process; must be
#: in the environment before the first jax import (see module docstring).
HOST_DEVICES_RECIPE = "XLA_FLAGS=--xla_force_host_platform_device_count=8"


def max_shards() -> int:
    """Largest usable shard count on this process (= device count)."""
    return jax.device_count()


def make_sim_mesh(n_shards: Optional[int] = None):
    """1-D mesh over ``n_shards`` devices (default: all local devices).

    ``n_shards`` is clamped to the available device count so callers can
    ask for the "ideal" P and degrade gracefully on smaller hosts (a
    single-device process gets a P = 1 mesh, on which the sharded engines
    reduce to the plain sparse path).
    """
    avail = max_shards()
    n = avail if n_shards is None else max(1, min(n_shards, avail))
    return jax.make_mesh((n,), (AGENT_AXIS,))


def mesh_shards(mesh) -> int:
    """Shard count of a sim mesh (size of its agent axis)."""
    return int(dict(zip(mesh.axis_names, mesh.devices.shape))[AGENT_AXIS])


def agent_sharding(mesh, *trailing_dims: Optional[str]) -> NamedSharding:
    """NamedSharding splitting the leading (agent) axis across the mesh."""
    return NamedSharding(mesh, P(AGENT_AXIS, *trailing_dims))


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


@dataclasses.dataclass(frozen=True)
class HaloCodec:
    """Wire format for the boundary rows a shard publishes each round.

    Three codecs (selected by ``name``), all decoding to f32 *on the
    receiving shard* so every downstream accumulation stays f32:

    ``f32``
        Identity — the bit-for-bit parity anchor.  The exchange path is
        byte-identical to the pre-codec code, so sharded trajectories
        under this codec reproduce the single-device engines exactly.
    ``bf16``
        Rows cast to bfloat16 on the wire (2x cut; relative round-trip
        error <= 2^-8 — bf16 keeps f32's exponent and 8 significand bits).
    ``int8``
        Per-row symmetric int8: each trailing-axis vector (one model /
        dual component of one boundary row) ships as int8 codes plus one
        f32 scale ``max|row| / 127`` (~4x cut; per-row relative error
        <= 2^-6).  Zero rows get scale 1.0 so they round-trip exactly.

    Frozen/hashable so it can ride through ``jax.jit`` static arguments
    (the sharded engines thread it as a static scan parameter).
    """

    name: str = "f32"

    NAMES = ("f32", "bf16", "int8")

    def __post_init__(self):
        if self.name not in self.NAMES:
            raise ValueError(
                f"unknown halo codec {self.name!r}; one of {self.NAMES}")

    @property
    def is_identity(self) -> bool:
        return self.name == "f32"

    def encode(self, x):
        """f32 rows -> tuple of wire arrays (payload first, then scales)."""
        if self.name == "f32":
            return (x,)
        if self.name == "bf16":
            return (x.astype(jnp.bfloat16),)
        amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
        scale = jnp.where(amax > 0, amax / 127.0, 1.0).astype(jnp.float32)
        q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
        return (q, scale)

    def decode(self, parts):
        """Tuple of wire arrays -> f32 rows (f32 math on the receiver)."""
        if self.name == "f32":
            return parts[0]
        if self.name == "bf16":
            return parts[0].astype(jnp.float32)
        q, scale = parts
        return q.astype(jnp.float32) * scale

    def row_nbytes(self, row_shape) -> int:
        """Wire bytes for one boundary row of the given trailing shape."""
        elems = int(math.prod(row_shape))
        if self.name == "f32":
            return 4 * elems
        if self.name == "bf16":
            return 2 * elems
        # int8 codes + one f32 scale per trailing-axis vector
        return elems + 4 * (elems // int(row_shape[-1]))


def resolve_halo_codec(codec: Union[str, HaloCodec, None]) -> HaloCodec:
    """Normalize a codec spec (name, instance, or None -> f32)."""
    if codec is None:
        return HaloCodec("f32")
    if isinstance(codec, HaloCodec):
        return codec
    return HaloCodec(str(codec))


def halo_exchange_fn(
    bnd_pos, halo_src_shard, halo_src_pos, n_halo, n_shards,
    exchange="all_gather", codec: Union[str, HaloCodec, None] = None,
):
    """Build the per-shard halo exchange used by the partitioned simulators.

    Returns ``run(x)`` mapping this shard's local rows ``x (m, ...)`` to the
    extended buffer ``[local | halo (H, ...) | zero-row]`` of shape
    ``(m + H + 1, ...)``: each shard publishes its boundary rows
    (``x[bnd_pos]``) and pulls its halo from the gathered boundary buffers —
    ``all_gather`` by default, or a P-1-step ``ppermute`` ring
    (``exchange="ring"``).  Must be called inside a ``shard_map`` over
    ``AGENT_AXIS``.  Works for any trailing shape, so the MP engine
    exchanges (m, p) model rows and the CL-ADMM engine (m, 1 + 3k, p)
    stacked model/dual payloads through the same code path.

    ``codec`` selects the :class:`HaloCodec` wire format: boundary rows are
    encoded *before* the collective (so the quantized representation is
    what crosses the interconnect) and decoded back to f32 on the
    receiving shard after halo selection.  The default f32 codec keeps the
    exchange byte-identical to the uncoded path.
    """
    codec = resolve_halo_codec(codec)

    def run(x):
        zero = jnp.zeros((1,) + x.shape[1:], x.dtype)
        if n_halo == 0:
            return jnp.concatenate([x, zero])
        send = x[bnd_pos]  # (B, ...)
        wire = codec.encode(send)
        if exchange == "ring":
            ring = [(s, (s + 1) % n_shards) for s in range(n_shards)]
            q_id = jax.lax.axis_index(AGENT_AXIS)
            halo = jnp.zeros((n_halo,) + x.shape[1:], x.dtype)
            bufs = wire
            bcast = (n_halo,) + (1,) * (x.ndim - 1)
            for step in range(1, n_shards):
                bufs = tuple(jax.lax.ppermute(b, AGENT_AXIS, ring)
                             for b in bufs)
                src = (q_id - step) % n_shards
                mask = (halo_src_shard == src).reshape(bcast)
                rows = codec.decode(tuple(b[halo_src_pos] for b in bufs))
                halo = jnp.where(mask, rows, halo)
        else:
            allb = tuple(jax.lax.all_gather(b, AGENT_AXIS)
                         for b in wire)  # each (P, B, ...)
            halo = codec.decode(
                tuple(b[halo_src_shard, halo_src_pos] for b in allb))
        return jnp.concatenate([x, halo, zero])

    return run


def halo_payload_bytes(
    n_shards: int, boundary_size: int, row_nbytes: int, halo_size: int
) -> int:
    """Bytes published per halo exchange across the whole mesh.

    Every shard all-gathers its ``boundary_size`` boundary rows each
    exchange regardless of which rows its neighbors actually consume, so
    the wire cost is ``P * B * row_nbytes`` — zero when the partition has
    no halo at all (``halo_size == 0``), in which case the engines skip the
    collective entirely.  ``row_nbytes`` is the *wire* size of one
    boundary row (``HaloCodec.row_nbytes`` for coded exchanges).  The
    telemetry layer multiplies this by the round count for the cumulative
    comm column.
    """
    if halo_size == 0:
        return 0
    return int(n_shards) * int(boundary_size) * int(row_nbytes)


def shard_read_route(owner, local_pos, users):
    """Route per-user state reads to the owning shard's store.

    ``owner``/``local_pos`` are the (n,) shard-assignment tables of a
    ``GraphPartition`` (agent a lives at row ``local_pos[a]`` of shard
    ``owner[a]``'s local block).  Returns the ``(shard, pos)`` int arrays
    for a batch of user ids — the lookup the sharded personalization
    service performs per inference request (DESIGN.md §16): reads go to
    the one shard that owns the user's row, never through a gathered
    global copy, so serving scales with the mesh exactly like the
    simulator state does.
    """
    users = np.asarray(users, np.int64)
    return (np.asarray(owner, np.int32)[users],
            np.asarray(local_pos, np.int32)[users])


def shard_map_1d(f, mesh, in_specs, out_specs):
    """Version-compat shard_map over a sim mesh.

    ``jax.shard_map`` (new API, ``check_vma``) when present, else
    ``jax.experimental.shard_map.shard_map`` (jax <= 0.4.x, ``check_rep``).
    Replication checking is disabled in both spellings: the simulator's
    per-shard programs mix replicated event streams with sharded state and
    gather/ppermute collectives the checker cannot type.
    """
    kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        try:
            return sm(f, check_vma=False, **kw)
        except TypeError:
            pass
    from jax.experimental.shard_map import shard_map as sm_old

    return sm_old(f, check_rep=False, **kw)

"""1-D agent meshes for partitioned network simulation (DESIGN.md §11).

The network simulator shards the *agent* axis: a ``(P,)`` mesh whose single
axis (``AGENT_AXIS = "shards"``) carries one graph shard per device.  This
is deliberately distinct from the production train/serve meshes in
``launch.mesh`` (("pod", "data", "model")): the simulator has no model
parallelism — every device runs the same per-shard event loop over its own
block of agents and exchanges halo models between event batches.

On a CPU-only host, multi-device runs use XLA's fake host devices::

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
        PYTHONPATH=src python benchmarks/bench_network_sim.py --sharded

The flag must be set before the first jax call in the process (jax locks
the device count on first init), which is why the helpers here never force
a device count themselves — they size the mesh to whatever the process
already has.

Defined as functions so importing this module never touches jax device
state (same rule as ``launch.mesh``).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

AGENT_AXIS = "shards"

#: The recipe for getting P host devices out of a CPU-only process; must be
#: in the environment before the first jax import (see module docstring).
HOST_DEVICES_RECIPE = "XLA_FLAGS=--xla_force_host_platform_device_count=8"


def max_shards() -> int:
    """Largest usable shard count on this process (= device count)."""
    return jax.device_count()


def make_sim_mesh(n_shards: Optional[int] = None):
    """1-D mesh over ``n_shards`` devices (default: all local devices).

    ``n_shards`` is clamped to the available device count so callers can
    ask for the "ideal" P and degrade gracefully on smaller hosts (a
    single-device process gets a P = 1 mesh, on which the sharded engines
    reduce to the plain sparse path).
    """
    avail = max_shards()
    n = avail if n_shards is None else max(1, min(n_shards, avail))
    return jax.make_mesh((n,), (AGENT_AXIS,))


def mesh_shards(mesh) -> int:
    """Shard count of a sim mesh (size of its agent axis)."""
    return int(dict(zip(mesh.axis_names, mesh.devices.shape))[AGENT_AXIS])


def agent_sharding(mesh, *trailing_dims: Optional[str]) -> NamedSharding:
    """NamedSharding splitting the leading (agent) axis across the mesh."""
    return NamedSharding(mesh, P(AGENT_AXIS, *trailing_dims))


def replicated(mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def halo_exchange_fn(
    bnd_pos, halo_src_shard, halo_src_pos, n_halo, n_shards, exchange="all_gather"
):
    """Build the per-shard halo exchange used by the partitioned simulators.

    Returns ``run(x)`` mapping this shard's local rows ``x (m, ...)`` to the
    extended buffer ``[local | halo (H, ...) | zero-row]`` of shape
    ``(m + H + 1, ...)``: each shard publishes its boundary rows
    (``x[bnd_pos]``) and pulls its halo from the gathered boundary buffers —
    ``all_gather`` by default, or a P-1-step ``ppermute`` ring
    (``exchange="ring"``).  Must be called inside a ``shard_map`` over
    ``AGENT_AXIS``.  Works for any trailing shape, so the MP engine
    exchanges (m, p) model rows and the CL-ADMM engine (m, 1 + 3k, p)
    stacked model/dual payloads through the same code path.
    """

    def run(x):
        zero = jnp.zeros((1,) + x.shape[1:], x.dtype)
        if n_halo == 0:
            return jnp.concatenate([x, zero])
        send = x[bnd_pos]  # (B, ...)
        if exchange == "ring":
            ring = [(s, (s + 1) % n_shards) for s in range(n_shards)]
            q_id = jax.lax.axis_index(AGENT_AXIS)
            halo = jnp.zeros((n_halo,) + x.shape[1:], x.dtype)
            buf = send
            bcast = (n_halo,) + (1,) * (x.ndim - 1)
            for step in range(1, n_shards):
                buf = jax.lax.ppermute(buf, AGENT_AXIS, ring)
                src = (q_id - step) % n_shards
                mask = (halo_src_shard == src).reshape(bcast)
                halo = jnp.where(mask, buf[halo_src_pos], halo)
        else:
            allb = jax.lax.all_gather(send, AGENT_AXIS)  # (P, B, ...)
            halo = allb[halo_src_shard, halo_src_pos]
        return jnp.concatenate([x, halo, zero])

    return run


def halo_payload_bytes(
    n_shards: int, boundary_size: int, row_nbytes: int, halo_size: int
) -> int:
    """Bytes published per halo exchange across the whole mesh.

    Every shard all-gathers its ``boundary_size`` boundary rows each
    exchange regardless of which rows its neighbors actually consume, so
    the wire cost is ``P * B * row_nbytes`` — zero when the partition has
    no halo at all (``halo_size == 0``), in which case the engines skip the
    collective entirely.  The telemetry layer multiplies this by the round
    count for the cumulative comm column.
    """
    if halo_size == 0:
        return 0
    return int(n_shards) * int(boundary_size) * int(row_nbytes)


def shard_map_1d(f, mesh, in_specs, out_specs):
    """Version-compat shard_map over a sim mesh.

    ``jax.shard_map`` (new API, ``check_vma``) when present, else
    ``jax.experimental.shard_map.shard_map`` (jax <= 0.4.x, ``check_rep``).
    Replication checking is disabled in both spellings: the simulator's
    per-shard programs mix replicated event streams with sharded state and
    gather/ppermute collectives the checker cannot type.
    """
    kw = dict(mesh=mesh, in_specs=in_specs, out_specs=out_specs)
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        try:
            return sm(f, check_vma=False, **kw)
        except TypeError:
            pass
    from jax.experimental.shard_map import shard_map as sm_old

    return sm_old(f, check_rep=False, **kw)

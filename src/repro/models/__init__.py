"""Model zoo: unified decoder engine over 6 architecture families."""

from .common import ModelConfig, adapt_pspec, adapt_pspec_tree, cross_entropy
from .flatten import LoRAAgent, MLPAgent, ParamFlattener
from .model import Model, AGENT_AXES

__all__ = ["ModelConfig", "Model", "AGENT_AXES", "adapt_pspec",
           "adapt_pspec_tree", "cross_entropy", "ParamFlattener",
           "MLPAgent", "LoRAAgent"]

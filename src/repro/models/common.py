"""Shared model machinery: config, parameter declaration, basic layers.

Parameters are declared ONCE via ``ParamDef`` (shape + PartitionSpec + init),
so ``init_params`` and ``param_pspecs`` can never drift apart (asserted by
tests/test_models_smoke.py::test_pspec_tree_matches_params).

Sharding conventions (DESIGN.md §4): the *base* model carries no agent dim —
PartitionSpecs here only reference the ``"model"`` tensor-parallel axis; the
coupling layer prepends the agent axis (("pod","data")) to every leaf.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    rope_theta: float = 10000.0
    # attention windowing: None = full causal. long-context decode shapes use
    # ``long_ctx_window`` on attention archs (DESIGN.md §5).
    window: Optional[int] = None
    long_ctx_window: int = 4096
    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    moe_seq_shard: bool = False      # expert-parallel all-to-all layout (perf lever)
    # dispatch realization: "scatter" writes tokens into the expert-sharded
    # buffer (SPMD turns that into a full-buffer reduce per layer);
    # "gather" scatters only int32 slot->token indices (tiny, replicated)
    # and gathers tokens locally — shard-local dispatch (§Perf A-series).
    moe_impl: str = "scatter"
    # hybrid (recurrentgemma / griffin)
    pattern: Tuple[str, ...] = ()    # per-layer mixer kinds; () -> all "attn"
    local_window: int = 2048
    conv_width: int = 4
    lru_dim: Optional[int] = None
    # ssm (xlstm)
    mlstm_proj_factor: float = 2.0
    slstm_ff: int = 0                # GeGLU hidden of sLSTM blocks (0 = 4d/3)
    mlstm_impl: str = "scan"         # scan (exact recurrent) | parallel (O(S^2))
    # vlm
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    n_media_tokens: int = 0
    # audio
    n_codebooks: int = 1
    n_cond_tokens: int = 0
    # ffn
    ffn_kind: str = "swiglu"         # swiglu | geglu | gelu
    # numerics / implementation
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16
    attn_impl: str = "chunked"       # ref | chunked | flash
    attn_chunk: int = 512
    remat: bool = True
    scan_layers: bool = True
    # Megatron-style sequence parallelism: the residual stream between blocks
    # is sharded over "model" along S, so remat-saved activations cost 1/TP.
    # GSPMD inserts the all-gather/reduce-scatter pair around each mixer/FFN.
    seq_shard: bool = True
    # KV-cache sharding over "model": "seq" = split-KV (S dim; GSPMD
    # replicates the cache around dynamic writes — §Perf C1), "heads" =
    # head_dim sharding (writes shard-local; attention combines partial
    # q.k dots with a logits-sized psum — §Perf C3).
    kv_shard: str = "seq"

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None \
            else self.d_model // self.n_heads

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        if self.pattern:
            assert len(self.pattern) == self.n_layers
            return self.pattern
        return ("attn",) * self.n_layers

    @property
    def r_dim(self) -> int:
        return self.lru_dim if self.lru_dim is not None else self.d_model

    @property
    def mlstm_inner(self) -> int:
        return int(self.mlstm_proj_factor * self.d_model)

    @property
    def slstm_hidden(self) -> int:
        if self.slstm_ff:
            return self.slstm_ff
        return int(math.ceil(self.d_model * 4 / 3 / 128) * 128)

    def scan_groups(self) -> Tuple[Tuple[Tuple[str, ...], int], ...]:
        """Decompose the layer stack into (unit, repetitions) scan groups.

        Finds the shortest repeating unit; a non-multiple tail becomes its own
        group (e.g. recurrentgemma 26L = (rec,rec,attn) x 8 + (rec,rec) x 1).
        """
        kinds = self.layer_kinds
        L = len(kinds)
        for ulen in range(1, L + 1):
            unit = kinds[:ulen]
            reps = L // ulen
            if kinds[:ulen * reps] == unit * reps:
                tail = kinds[ulen * reps:]
                groups = [(unit, reps)]
                if tail:
                    groups.append((tail, 1))
                return tuple(groups)
        return ((kinds, 1),)


# ---------------------------------------------------------------------------
# Parameter declaration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    spec: P                          # PartitionSpec over the base (agent-free) leaf
    init: str = "normal"             # normal | zeros | ones | lru_lambda
    scale: Optional[float] = None    # default: 1/sqrt(fan_in)


def _init_leaf(key, d: ParamDef, dtype):
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "lru_lambda":
        # RG-LRU Lambda init: a = sigmoid(Lambda) uniform in [0.9, 0.999]
        u = jax.random.uniform(key, d.shape, jnp.float32, 0.9, 0.999)
        return jnp.log(u / (1.0 - u)).astype(dtype)
    scale = d.scale
    if scale is None:
        fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
        scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (scale * jax.random.normal(key, d.shape, jnp.float32)).astype(dtype)


def init_from_defs(defs, key, dtype) -> Dict:
    """Materialize a (nested) dict of ParamDef into parameters."""
    leaves, treedef = jax.tree_util.tree_flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(k, d, dtype) for k, d in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def pspecs_from_defs(defs) -> Dict:
    return jax.tree_util.tree_map(lambda d: d.spec, defs,
                                  is_leaf=lambda x: isinstance(x, ParamDef))


def abstract_from_defs(defs, dtype) -> Dict:
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs,
        is_leaf=lambda x: isinstance(x, ParamDef))


def stack_defs(defs: Dict, reps: int) -> Dict:
    """Prepend a scan (layer-repetition) dim to every ParamDef in a subtree."""
    def f(d: ParamDef):
        return ParamDef((reps,) + d.shape, P(None, *d.spec), d.init, d.scale)
    return jax.tree_util.tree_map(f, defs, is_leaf=lambda x: isinstance(x, ParamDef))


# ---------------------------------------------------------------------------
# Basic layers (pure functions; params are dict leaves)
# ---------------------------------------------------------------------------


def rms_norm(x, gamma, eps: float = 1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps))
            * (1.0 + gamma.astype(jnp.float32))).astype(dtype)


def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def gelu_glu(x, w_gate, w_up, w_down):
    h = jax.nn.gelu(x @ w_gate) * (x @ w_up)
    return h @ w_down


# --- rotary embeddings ------------------------------------------------------


def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float, sections: Tuple[int, int, int]):
    """Qwen2-VL multimodal RoPE.

    x: (B, S, H, hd); positions3: (3, B, S) — temporal/height/width position
    ids. head_dim/2 frequency slots are split into ``sections`` (summing to
    hd/2); each section takes its angle from the corresponding position id.
    Text tokens carry identical ids in all three planes => reduces to RoPE.
    """
    hd = x.shape[-1]
    assert sum(sections) == hd // 2, (sections, hd)
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    ang_all = positions3[..., None].astype(jnp.float32) * freqs  # (3, B, S, hd/2)
    sel = jnp.repeat(jnp.arange(3), jnp.asarray(sections),
                     total_repeat_length=hd // 2)      # (hd/2,) section owner
    ang = jnp.take_along_axis(
        jnp.moveaxis(ang_all, 0, -1), sel[None, None, :, None], axis=-1)[..., 0]
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --- losses -----------------------------------------------------------------


def cross_entropy(logits, labels, mask=None):
    """Mean CE over valid positions. labels < 0 are ignored."""
    valid = (labels >= 0) if mask is None else mask & (labels >= 0)
    labels_safe = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32),
                               labels_safe[..., None], axis=-1)[..., 0]
    nll = logz - gold
    nll = jnp.where(valid, nll, 0.0)
    return jnp.sum(nll) / jnp.maximum(jnp.sum(valid), 1)


import contextlib
import contextvars

# Which mesh axes the *batch/agent* slot of activation constraints maps to.
# Direct (non-vmapped) execution: ("pod", "data"). Inside a per-agent vmap
# (spmd_axis_name carries the agent axes), the slot must resolve to None —
# the agent axes are already consumed by the vmapped dim.
_BATCH_AXES = contextvars.ContextVar("repro_batch_axes",
                                     default=("pod", "data"))
_AGENT_SLOT = ("pod", "data")


@contextlib.contextmanager
def batch_axes(names):
    token = _BATCH_AXES.set(tuple(names))
    try:
        yield
    finally:
        _BATCH_AXES.reset(token)


def _resolve_agent_slot(spec: P) -> P:
    cur = _BATCH_AXES.get()
    out = []
    for entry in spec:
        if isinstance(entry, tuple) and entry == _AGENT_SLOT:
            out.append(cur if cur else None)
        else:
            out.append(entry)
    return P(*out)


def adapt_pspec(spec: P, axis_names) -> P:
    """Drop references to mesh axes that don't exist in the ambient mesh.

    Specs in this package are written against the *multi-pod* axis set
    ("pod", "data", "model"); on a single-pod mesh the "pod" axis is absent
    and the spec degrades gracefully (("pod","data") -> "data").
    """
    out = []
    for entry in spec:
        if entry is None:
            out.append(None)
        elif isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in axis_names)
            out.append(kept[0] if len(kept) == 1 else (kept or None))
        else:
            out.append(entry if entry in axis_names else None)
    return P(*out)


def adapt_pspec_tree(tree, mesh):
    names = tuple(mesh.axis_names)
    return jax.tree_util.tree_map(
        lambda s: adapt_pspec(s, names), tree,
        is_leaf=lambda s: isinstance(s, P))


def constrain(x, spec: P):
    """with_sharding_constraint adapted to the ambient mesh; no-op without one."""
    spec = _resolve_agent_slot(spec)
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is not None and not mesh.empty:
            return jax.lax.with_sharding_constraint(
                x, adapt_pspec(spec, tuple(mesh.axis_names)))
        from jax.interpreters import pxla  # legacy `with mesh:` context
        pm = pxla.thread_resources.env.physical_mesh
        if pm.empty:
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.NamedSharding(
                pm, adapt_pspec(spec, tuple(pm.axis_names))))
    except (ValueError, RuntimeError, AttributeError):
        return x

"""Model engine: assembles block stacks into trainable/servable models.

A ``Model`` wraps a ModelConfig and provides:

    init(key)                -> params            (f32 master weights)
    param_pspecs()           -> PartitionSpec tree (base, agent-free)
    loss(params, batch)      -> (scalar, metrics) train objective (LM CE + aux)
    forward(params, batch)   -> logits
    prefill(params, batch, cache_len) -> (logits, cache)
    decode_step(params, cache, batch) -> (logits, cache)   # serve_step body
    init_cache(B, cache_len) / cache_pspecs() / input_specs(shape)

Layer stacks are grouped into scan units (cfg.scan_groups()); parameters of a
group are stacked over the repetition dim so the whole depth compiles to one
``lax.scan`` body (constant HLO size in depth).
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import (ModelConfig, ParamDef, init_from_defs, pspecs_from_defs,
                     abstract_from_defs, stack_defs, rms_norm, cross_entropy,
                     constrain)
from . import blocks as B

AGENT_AXES = ("pod", "data")


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.groups = cfg.scan_groups()

    # -- parameters ---------------------------------------------------------

    def defs(self) -> Dict:
        cfg = self.cfg
        dm, V = cfg.d_model, cfg.vocab_size
        d: Dict[str, Any] = {
            "final_norm": ParamDef((dm,), P(None), init="zeros")}
        if cfg.family == "audio":
            K = cfg.n_codebooks
            d["embed"] = ParamDef((K, V, dm), P(None, "model", None), scale=0.02)
            d["unembed"] = ParamDef((K, dm, V), P(None, None, "model"))
        else:
            d["embed"] = ParamDef((V, dm), P("model", None), scale=0.02)
            d["unembed"] = ParamDef((dm, V), P(None, "model"))
        d["groups"] = [
            stack_defs({f"b{i}": B.block_defs(cfg, kind)
                        for i, kind in enumerate(unit)}, reps)
            for unit, reps in self.groups]
        return d

    def init(self, key) -> Dict:
        return init_from_defs(self.defs(), key, self.cfg.param_dtype)

    def param_pspecs(self) -> Dict:
        return pspecs_from_defs(self.defs())

    def abstract_params(self) -> Dict:
        return abstract_from_defs(self.defs(), self.cfg.param_dtype)

    def param_count(self) -> int:
        import numpy as np
        leaves = jax.tree_util.tree_leaves(
            self.defs(), is_leaf=lambda x: isinstance(x, ParamDef))
        return int(sum(np.prod(l.shape) for l in leaves))

    # -- embedding / head per family ----------------------------------------

    def _embed(self, params, batch):
        """Returns (x (B,S,d), ctx kwargs, n_prefix)."""
        cfg = self.cfg
        cdt = cfg.compute_dtype
        if cfg.family == "audio":
            tok = batch["tokens"]                       # (B, K, S)
            emb = params["embed"].astype(cdt)           # (K, V, d)
            x = sum(emb[k][tok[:, k]] for k in range(cfg.n_codebooks))
            cond = batch["cond_embeds"].astype(cdt)     # (B, n_cond, d)
            x = jnp.concatenate([cond, x], axis=1)
            return x, {}, cfg.n_cond_tokens
        if cfg.family == "vlm":
            tok = batch["tokens"]                       # (B, S_text)
            x = params["embed"].astype(cdt)[tok]
            patches = batch["patch_embeds"].astype(cdt)
            x = jnp.concatenate([patches, x], axis=1)
            return x, {"positions3": batch["positions3"]}, cfg.n_media_tokens
        x = params["embed"].astype(self.cfg.compute_dtype)[batch["tokens"]]
        return x, {}, 0

    def _head(self, params, x, n_prefix):
        cfg = self.cfg
        x = x[:, n_prefix:]
        if cfg.family == "audio":
            return jnp.einsum("bsd,kdv->bksv", x,
                              params["unembed"].astype(cfg.compute_dtype),
                              preferred_element_type=jnp.float32)
        return jnp.einsum("bsd,dv->bsv", x,
                          params["unembed"].astype(cfg.compute_dtype),
                          preferred_element_type=jnp.float32)

    # -- sequence forward ----------------------------------------------------

    def _run_groups_seq(self, params, x, ctx: B.Ctx):
        """Apply all scan groups. Returns (x, caches per group, aux)."""
        cfg = self.cfg
        cdt = cfg.compute_dtype
        caches = []
        aux_total = jnp.zeros((), jnp.float32)

        for (unit, reps), gp in zip(self.groups, params["groups"]):
            gp = jax.tree_util.tree_map(lambda a: a.astype(cdt)
                                        if a.dtype == cfg.param_dtype else a, gp)

            def unit_apply(x, pslice, unit=unit):
                aux = jnp.zeros((), jnp.float32)
                centry = {}
                for i, kind in enumerate(unit):
                    x, c, a = B.block_apply_seq(cfg, kind, pslice[f"b{i}"], x,
                                                ctx)
                    centry[f"b{i}"] = c
                    aux = aux + a
                return x, centry, aux

            if cfg.remat:
                unit_apply = jax.checkpoint(
                    unit_apply, policy=jax.checkpoint_policies.nothing_saveable)

            if cfg.scan_layers and reps > 1:
                def body(carry, pslice):
                    x, aux = carry
                    x, centry, a = unit_apply(x, pslice)
                    return (x, aux + a), centry
                (x, aux_total), centries = jax.lax.scan(
                    body, (x, aux_total), gp)
                caches.append(centries)
            else:
                centries = []
                for r in range(reps):
                    pslice = jax.tree_util.tree_map(lambda a: a[r], gp)
                    x, centry, a = unit_apply(x, pslice)
                    aux_total = aux_total + a
                    centries.append(centry)
                caches.append(jax.tree_util.tree_map(
                    lambda *xs: jnp.stack(xs), *centries)
                    if ctx.cache_len else None)
        return x, caches, aux_total

    def forward(self, params, batch, *, window="auto"):
        cfg = self.cfg
        x, ctxkw, n_prefix = self._embed(params, batch)
        x = constrain(x, P(AGENT_AXES, None, None))
        Btot, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (Btot, S))
        ctx = B.Ctx(positions=positions, window=window, cache_len=0, **ctxkw)
        x, _, aux = self._run_groups_seq(params, x, ctx)
        x = rms_norm(x, params["final_norm"])
        logits = self._head(params, x, n_prefix)
        return logits, aux

    def loss(self, params, batch, *, window="auto"):
        cfg = self.cfg
        logits, aux = self.forward(params, batch, window=window)
        ce = cross_entropy(logits, batch["labels"])
        total = ce + cfg.router_aux_weight * aux
        return total, {"ce": ce, "aux": aux}

    # -- serving -------------------------------------------------------------

    def init_cache(self, Btot: int, cache_len: int, dtype=None):
        cfg = self.cfg
        dtype = dtype or cfg.compute_dtype
        caches = []
        for unit, reps in self.groups:
            entry = {f"b{i}": B.block_init_cache(cfg, kind, Btot, cache_len,
                                                 dtype)
                     for i, kind in enumerate(unit)}
            caches.append(jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(a[None], (reps,) + a.shape), entry))
        return {"layers": caches, "pos": jnp.zeros((Btot,), jnp.int32)}

    def cache_pspecs(self):
        cfg = self.cfg
        caches = []
        for unit, reps in self.groups:
            entry = {f"b{i}": B.block_cache_pspecs(cfg, kind)
                     for i, kind in enumerate(unit)}
            caches.append(jax.tree_util.tree_map(
                lambda s: P(None, *s), entry,
                is_leaf=lambda s: isinstance(s, P)))
        return {"layers": caches, "pos": P(AGENT_AXES)}

    def abstract_cache(self, Btot: int, cache_len: int, dtype=None):
        dtype = dtype or self.cfg.compute_dtype
        cache = jax.eval_shape(lambda: self.init_cache(Btot, cache_len, dtype))
        return cache

    def prefill(self, params, batch, cache_len: int, *, window="auto"):
        cfg = self.cfg
        x, ctxkw, n_prefix = self._embed(params, batch)
        Btot, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S)[None], (Btot, S))
        ctx = B.Ctx(positions=positions, window=window, cache_len=cache_len,
                    ring=cache_len < S, **ctxkw)
        x, caches, _ = self._run_groups_seq(params, x, ctx)
        x = rms_norm(x, params["final_norm"])
        logits = self._head(params, x[:, -1:], 0)
        cache = {"layers": caches,
                 "pos": jnp.full((Btot,), S, jnp.int32)}
        return logits, cache

    def decode_step(self, params, cache, batch, *, window="auto",
                    ring: bool = False, lockstep: bool = False):
        """One decode step. batch: {"token": (B,) or (B,K)} ; cache carries pos.

        ``lockstep=True``: all requests share one position (fleet decode) —
        cache writes become dynamic_update_slice, which stays shard-local
        under split-KV sharding (see blocks.attn_apply_dec).
        """
        cfg = self.cfg
        cdt = cfg.compute_dtype
        tok = batch["token"]
        if cfg.family == "audio":
            emb = params["embed"].astype(cdt)
            x = sum(emb[k][tok[:, k]] for k in range(cfg.n_codebooks))
        else:
            x = params["embed"].astype(cdt)[tok]
        Btot = x.shape[0]
        pos = cache["pos"]
        ctx = B.Ctx(positions=pos[0] if lockstep else pos, window=window,
                    ring=ring)
        new_layer_caches = []
        for (unit, reps), gp, gc in zip(self.groups, params["groups"],
                                        cache["layers"]):
            gp = jax.tree_util.tree_map(
                lambda a: a.astype(cdt) if a.dtype == cfg.param_dtype else a, gp)

            def body(x, slices, unit=unit):
                pslice, cslice = slices
                new_c = {}
                for i, kind in enumerate(unit):
                    x, c = B.block_apply_dec(cfg, kind, pslice[f"b{i}"], x,
                                             cslice[f"b{i}"], ctx)
                    new_c[f"b{i}"] = c
                return x, new_c

            if cfg.scan_layers and reps > 1:
                x, new_gc = jax.lax.scan(body, x, (gp, gc))
            else:
                outs = []
                for r in range(reps):
                    sl = jax.tree_util.tree_map(lambda a: a[r], (gp, gc))
                    x, c = body(x, sl)
                    outs.append(c)
                new_gc = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs),
                                                *outs)
            new_layer_caches.append(new_gc)
        x = rms_norm(x, params["final_norm"])
        if cfg.family == "audio":
            logits = jnp.einsum("bd,kdv->bkv", x,
                                params["unembed"].astype(cdt),
                                preferred_element_type=jnp.float32)
        else:
            logits = jnp.einsum("bd,dv->bv", x, params["unembed"].astype(cdt),
                                preferred_element_type=jnp.float32)
        return logits, {"layers": new_layer_caches, "pos": pos + 1}

    # -- abstract inputs -----------------------------------------------------

    def input_specs(self, batch_size: int, seq_len: int, mode: str = "train",
                    cache_len: Optional[int] = None) -> Dict:
        """ShapeDtypeStruct stand-ins for every model input (DESIGN §4).

        mode "train"/"prefill": token batch. mode "decode": one token + cache.
        """
        cfg = self.cfg
        i32 = jnp.int32
        sds = jax.ShapeDtypeStruct
        if mode == "decode":
            tok_shape = ((batch_size, cfg.n_codebooks) if cfg.family == "audio"
                         else (batch_size,))
            batch = {"token": sds(tok_shape, i32)}
            cache = self.abstract_cache(batch_size, cache_len or seq_len)
            return {"batch": batch, "cache": cache}
        if cfg.family == "audio":
            S_a = seq_len - cfg.n_cond_tokens
            return {"tokens": sds((batch_size, cfg.n_codebooks, S_a), i32),
                    "labels": sds((batch_size, cfg.n_codebooks, S_a), i32),
                    "cond_embeds": sds((batch_size, cfg.n_cond_tokens,
                                        cfg.d_model), cfg.compute_dtype)}
        if cfg.family == "vlm":
            S_t = seq_len - cfg.n_media_tokens
            return {"tokens": sds((batch_size, S_t), i32),
                    "labels": sds((batch_size, S_t), i32),
                    "patch_embeds": sds((batch_size, cfg.n_media_tokens,
                                         cfg.d_model), cfg.compute_dtype),
                    "positions3": sds((3, batch_size, seq_len), i32)}
        return {"tokens": sds((batch_size, seq_len), i32),
                "labels": sds((batch_size, seq_len), i32)}

    def batch_pspecs(self, mode: str = "train") -> Dict:
        cfg = self.cfg
        a = AGENT_AXES
        if mode == "decode":
            return {"batch": {"token": P(a)}, "cache": self.cache_pspecs()}
        if cfg.family == "audio":
            return {"tokens": P(a, None, None), "labels": P(a, None, None),
                    "cond_embeds": P(a, None, None)}
        if cfg.family == "vlm":
            return {"tokens": P(a, None), "labels": P(a, None),
                    "patch_embeds": P(a, None, None),
                    "positions3": P(None, a, None)}
        return {"tokens": P(a, None), "labels": P(a, None)}

"""Attention implementations.

* ``ref_attention``     — dense einsum softmax attention (small shapes, oracle)
* ``chunked_attention`` — lax.scan over KV blocks with online softmax
                          (flash-style in pure JAX): O(S) memory, small HLO.
                          Default for training/prefill and for the dry-run.
* ``decode_attention``  — one query token against a (possibly ring-buffered)
                          KV cache; with the cache sequence dim sharded over
                          the "model" mesh axis this lowers to split-KV
                          (flash-decoding) with an all-reduce combine.

All support GQA (n_kv_heads <= n_heads) and optional sliding windows.
The Pallas TPU kernel lives in repro.kernels.flash_attention; it is validated
against ``ref_attention`` (tests/test_kernels.py).
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _expand_kv(k, n_heads: int):
    """(B, S, K, hd) -> (B, S, H, hd) by repeating each kv head H/K times."""
    n_kv = k.shape[-2]
    if n_kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // n_kv, axis=-2)


def _mask(q_pos, k_pos, window: Optional[int]):
    """Causal (+ optional sliding window) mask: True = attend."""
    m = k_pos[None, :] <= q_pos[:, None]
    if window is not None:
        m &= k_pos[None, :] > (q_pos[:, None] - window)
    return m


def ref_attention(q, k, v, *, q_pos=None, k_pos=None,
                  window: Optional[int] = None, causal: bool = True):
    """q: (B, Sq, H, hd), k/v: (B, Sk, K, hd) -> (B, Sq, H, hd)."""
    B, Sq, H, hd = q.shape
    Sk = k.shape[1]
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    scale = hd ** -0.5
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if causal or window is not None:
        qp = jnp.arange(Sq) if q_pos is None else q_pos
        kp = jnp.arange(Sk) if k_pos is None else k_pos
        m = _mask(qp, kp, window) if causal else (
            kp[None, :] > qp[:, None] - window)
        logits = jnp.where(m[None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)


def chunked_attention(q, k, v, *, window: Optional[int] = None,
                      chunk: int = 512):
    """Causal attention via online softmax over KV chunks (self-attention).

    Equivalent to ref_attention(causal=True); memory O(Sq * chunk) instead of
    O(Sq * Sk). Both the training path and the dry-run use this.
    """
    B, S, H, hd = q.shape
    K = k.shape[-2]
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    if S % chunk != 0:
        return ref_attention(q, k, v, window=window)
    scale = hd ** -0.5
    n_chunks = S // chunk
    kc = k.reshape(B, n_chunks, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, H, hd).transpose(1, 0, 2, 3, 4)
    q_pos = jnp.arange(S)

    def body(carry, xs):
        o, m, l = carry                       # (B,S,H,hd), (B,H,S), (B,H,S)
        kb, vb, idx = xs                      # (B,chunk,H,hd), ..., scalar
        k_pos = idx * chunk + jnp.arange(chunk)
        logits = (jnp.einsum("bqhd,bkhd->bhqk", q, kb,
                             preferred_element_type=jnp.float32)
                  * scale).astype(jnp.float32)
        msk = _mask(q_pos, k_pos, window)     # (S, chunk)
        logits = jnp.where(msk[None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        o_new = (o * alpha.transpose(0, 2, 1)[..., None]
                 + jnp.einsum("bhqk,bkhd->bqhd", p.astype(vb.dtype), vb))
        return (o_new, m_new, l_new), None

    o0 = jnp.zeros_like(q, dtype=jnp.float32)
    m0 = jnp.full((B, H, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    (o, m, l), _ = jax.lax.scan(body, (o0, m0, l0),
                                (kc, vc, jnp.arange(n_chunks)))
    l = jnp.maximum(l, 1e-20)
    return (o / l.transpose(0, 2, 1)[..., None]).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, pos, *,
                     window: Optional[int] = None, ring: bool = False):
    """One-token decode: q (B, H, hd) vs cache (B, Sc, K, hd).

    ``pos`` is the (scalar or (B,)) absolute position of the new token.
    ``ring=True``: the cache is a ring buffer of size Sc holding the last Sc
    tokens — slot s currently stores absolute position p where
    p = pos - ((pos - s) mod Sc); valid if p >= 0 and p > pos - window.

    With the cache's Sc dim sharded over "model", GSPMD lowers the reductions
    here to partial-softmax + all-reduce == split-KV flash decoding.
    """
    B, Sc, K, hd = k_cache.shape
    H = q.shape[1]
    kc = _expand_kv(k_cache, H)
    vc = _expand_kv(v_cache, H)
    scale = hd ** -0.5
    logits = jnp.einsum("bhd,bshd->bhs", q, kc,
                        preferred_element_type=jnp.float32) * scale
    pos = jnp.asarray(pos)
    pos_b = jnp.broadcast_to(pos, (B,))[:, None]                # (B,1)
    slots = jnp.arange(Sc)[None, :]                             # (1,Sc)
    if ring:
        abs_pos = pos_b - jnp.mod(pos_b - slots, Sc)
    else:
        abs_pos = slots * jnp.ones_like(pos_b)
    valid = (abs_pos >= 0) & (abs_pos <= pos_b)
    if window is not None:
        valid &= abs_pos > pos_b - window
    logits = jnp.where(valid[:, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhs,bshd->bhd", w.astype(vc.dtype), vc)

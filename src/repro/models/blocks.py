"""Transformer / recurrent block zoo.

Every block kind exposes three functions with a common signature so the model
engine (model.py) can scan over heterogeneous stacks:

    defs(cfg, kind)                       -> nested dict of ParamDef
    apply_seq(cfg, kind, p, x, ctx)       -> (x, cache_entry)   full-sequence
    apply_dec(cfg, kind, p, x, cache, ctx)-> (x, cache)         one-token decode

``ctx`` carries positions / mrope ids / window overrides / cache_len.
Kinds: attn | attn_local | attn_moe | mlstm | slstm | rglru.
"""

from __future__ import annotations

import math
from typing import Any, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .common import (ModelConfig, ParamDef, rms_norm, swiglu, gelu_glu,
                     apply_rope, apply_mrope, constrain)
from .attention import (ref_attention, chunked_attention, decode_attention)


class Ctx(NamedTuple):
    positions: Any = None        # (B, S) int32 (seq mode) or (B,) (decode)
    positions3: Any = None       # (3, B, S) for M-RoPE (vlm)
    window: Any = None           # per-call window override ("auto" = cfg)
    cache_len: int = 0           # 0 => no cache wanted (pure training)
    ring: bool = False           # decode cache is a ring buffer


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------


def ffn_defs(cfg: ModelConfig) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.ffn_kind == "gelu":
        return {"w_up": ParamDef((d, f), P(None, "model")),
                "w_down": ParamDef((f, d), P("model", None))}
    return {"w_gate": ParamDef((d, f), P(None, "model")),
            "w_up": ParamDef((d, f), P(None, "model")),
            "w_down": ParamDef((f, d), P("model", None))}


def ffn_apply(cfg: ModelConfig, p: Dict, x):
    if cfg.ffn_kind == "gelu":
        return jax.nn.gelu(x @ p["w_up"]) @ p["w_down"]
    if cfg.ffn_kind == "geglu":
        return gelu_glu(x, p["w_gate"], p["w_up"], p["w_down"])
    return swiglu(x, p["w_gate"], p["w_up"], p["w_down"])


# ---------------------------------------------------------------------------
# MoE FFN (token-choice top-k with capacity, scatter/gather dispatch)
# ---------------------------------------------------------------------------


def moe_defs(cfg: ModelConfig) -> Dict:
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {"router": ParamDef((d, E), P(None, None), scale=0.02),
            "w_gate": ParamDef((E, d, f), P("model", None, None)),
            "w_up": ParamDef((E, d, f), P("model", None, None)),
            "w_down": ParamDef((E, f, d), P("model", None, None))}


def moe_apply(cfg: ModelConfig, p: Dict, x):
    """x: (B, S, d) -> (y, aux_loss). Token-choice top-k routing.

    Dispatch by scatter into an (E, C, d) buffer (capacity
    C = ceil(T k / E * cf)); tokens over capacity are dropped (standard).
    Expert weights are sharded over "model" => expert-parallel compute.
    """
    B, S, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S
    xt = x.reshape(T, d)
    logits = (xt @ p["router"]).astype(jnp.float32)          # (T, E)
    gates = jax.nn.softmax(logits, axis=-1)
    topv, topi = jax.lax.top_k(gates, k)                     # (T, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    C = int(math.ceil(T * k / E * cfg.capacity_factor))
    C = min(C, T)
    # position of each (token, choice) within its expert, in token order
    onehot = jax.nn.one_hot(topi.reshape(-1), E, dtype=jnp.int32)  # (T*k, E)
    pos_flat = (jnp.cumsum(onehot, axis=0) - onehot)               # exclusive
    pos = jnp.take_along_axis(pos_flat, topi.reshape(-1, 1),
                              axis=1).reshape(T, k)
    keep = pos < C
    slot = topi * C + jnp.minimum(pos, C - 1)                      # (T, k)

    if cfg.moe_impl == "gather":
        # scatter only indices (E*C int32 — KBs, stays replicated), then
        # gather tokens from the replicated activation: shard-local dispatch.
        src = jnp.full((E * C + 1,), T, jnp.int32)  # T = "no token" sentinel
        write_slot = jnp.where(keep, slot, E * C)   # dropped -> spill slot
        # scatter: unique targets — kept (token, choice) pairs own distinct
        # capacity slots; all dropped pairs collide only on the spill slot
        # E*C, which the [:E*C] slice below discards
        src = src.at[write_slot.reshape(-1)].set(jnp.arange(T * k) // k)
        src = src[:E * C]
        xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), x.dtype)], axis=0)
        buf = xt_pad[src]                           # (E*C, d) local gather
    else:
        buf = jnp.zeros((E * C, d), x.dtype)
        contrib = keep.astype(x.dtype)                             # (T, k)
        buf = buf.at[slot.reshape(-1)].add(
            (xt[:, None, :] * contrib[:, :, None]).reshape(T * k, d))
    expert_in = constrain(buf.reshape(E, C, d), P("model", None, None))

    h = jnp.einsum("ecd,edf->ecf", expert_in, p["w_gate"])
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", expert_in, p["w_up"])
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    expert_out = constrain(expert_out, P("model", None, None))

    gathered = expert_out.reshape(E * C, d)[slot.reshape(-1)].reshape(T, k, d)
    y = jnp.sum(gathered * (topv * keep).astype(x.dtype)[..., None], axis=1)

    # Switch-style load-balance auxiliary loss
    me = gates.mean(axis=0)                                   # (E,)
    ce = jax.nn.one_hot(topi[:, 0], E).mean(axis=0)
    aux = E * jnp.sum(me * ce)
    return y.reshape(B, S, d), aux.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Attention mixer
# ---------------------------------------------------------------------------


def attn_defs(cfg: ModelConfig) -> Dict:
    d, H, K, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    return {"wq": ParamDef((d, H * hd), P(None, "model")),
            "wk": ParamDef((d, K * hd), P(None, "model")),
            "wv": ParamDef((d, K * hd), P(None, "model")),
            "wo": ParamDef((H * hd, d), P("model", None))}


def _window_of(cfg: ModelConfig, kind: str, ctx: Ctx) -> Optional[int]:
    if kind == "attn_local":
        return cfg.local_window
    if ctx.window != "auto":
        return ctx.window
    return cfg.window


def _qkv(cfg: ModelConfig, p: Dict, x, ctx: Ctx, decode: bool):
    B = x.shape[0]
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    S = 1 if decode else x.shape[1]
    xq = (x @ p["wq"]).reshape(B, S, H, hd)
    xk = (x @ p["wk"]).reshape(B, S, K, hd)
    xv = (x @ p["wv"]).reshape(B, S, K, hd)
    if cfg.family == "vlm" and ctx.positions3 is not None:
        xq = apply_mrope(xq, ctx.positions3, cfg.rope_theta, cfg.mrope_sections)
        xk = apply_mrope(xk, ctx.positions3, cfg.rope_theta, cfg.mrope_sections)
    else:
        pos = ctx.positions
        if pos is None:
            pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        if decode:
            pos = jnp.broadcast_to(jnp.asarray(pos), (B,))[:, None]
        xq = apply_rope(xq, pos, cfg.rope_theta)
        xk = apply_rope(xk, pos, cfg.rope_theta)
    return xq, xk, xv


def attn_apply_seq(cfg: ModelConfig, kind: str, p: Dict, x, ctx: Ctx):
    B, S, d = x.shape
    window = _window_of(cfg, kind, ctx)
    xq, xk, xv = _qkv(cfg, p, x, ctx, decode=False)
    xq = constrain(xq, P(("pod", "data"), None, "model", None))
    if cfg.attn_impl == "ref" or S % cfg.attn_chunk != 0:
        o = ref_attention(xq, xk, xv, window=window)
    elif cfg.attn_impl == "flash":
        # "attention" op via dispatch: the Pallas flash kernel where it can
        # run (TPU compiled, or explicit interpret opt-in); elsewhere fall
        # back to the memory-bounded chunked path rather than the dense
        # (S x S)-materializing softmax.
        from repro.kernels.dispatch import ReproBackend, available, resolve
        if available("attention", "pallas"):
            o = resolve("attention", ReproBackend.using(attention="pallas"))(
                xq, xk, xv, window=window)
        else:
            o = chunked_attention(xq, xk, xv, window=window,
                                  chunk=cfg.attn_chunk)
    else:
        o = chunked_attention(xq, xk, xv, window=window, chunk=cfg.attn_chunk)
    y = o.reshape(B, S, cfg.n_heads * cfg.hd) @ p["wo"]
    cache = None
    if ctx.cache_len:
        Sc = ctx.cache_len
        K = cfg.n_kv_heads
        kc = jnp.zeros((B, Sc, K, cfg.hd), x.dtype)
        vc = jnp.zeros((B, Sc, K, cfg.hd), x.dtype)
        take = min(S, Sc)
        # token at absolute position p lives in slot p % Sc (ring semantics;
        # identity when Sc >= S). Keep the last `take` tokens.
        ps = jnp.arange(S - take, S)
        kc = kc.at[:, ps % Sc].set(xk[:, S - take:])  # scatter: unique targets
        vc = vc.at[:, ps % Sc].set(xv[:, S - take:])  # scatter: unique targets
        cache = {"k": kc, "v": vc}
    return y, cache


def attn_apply_dec(cfg: ModelConfig, kind: str, p: Dict, x, cache: Dict,
                   ctx: Ctx):
    """x: (B, d) one token at position ctx.positions (B,) or scalar."""
    B, d = x.shape
    window = _window_of(cfg, kind, ctx)
    xq, xk, xv = _qkv(cfg, p, x[:, None, :], ctx, decode=True)
    Sc = cache["k"].shape[1]
    pos = jnp.asarray(ctx.positions)
    if pos.ndim == 0:
        # lockstep fleet decode: all requests at the same position — a
        # dynamic_update_slice, which GSPMD handles shard-locally even when
        # the cache's S dim is sharded (split-KV). Scatter-at-(B,) indices
        # would force the partitioner to regather the whole cache
        # (EXPERIMENTS.md §Perf C1).
        slot = (jnp.mod(pos, Sc) if ctx.ring else pos).astype(jnp.int32)
        zero = jnp.zeros((), jnp.int32)
        kc = jax.lax.dynamic_update_slice(cache["k"], xk,
                                          (zero, slot, zero, zero))
        vc = jax.lax.dynamic_update_slice(cache["v"], xv,
                                          (zero, slot, zero, zero))
    else:
        slot = jnp.mod(pos, Sc) if ctx.ring else pos
        slot = jnp.broadcast_to(slot, (B,))
        kc = cache["k"].at[jnp.arange(B), slot].set(xk[:, 0])  # scatter: unique targets
        vc = cache["v"].at[jnp.arange(B), slot].set(xv[:, 0])  # scatter: unique targets
    o = decode_attention(xq[:, 0], kc, vc, pos, window=window, ring=ctx.ring)
    y = o.reshape(B, cfg.n_heads * cfg.hd) @ p["wo"]
    return y, {"k": kc, "v": vc}


def attn_init_cache(cfg: ModelConfig, B: int, cache_len: int, dtype):
    K, hd = cfg.n_kv_heads, cfg.hd
    return {"k": jnp.zeros((B, cache_len, K, hd), dtype),
            "v": jnp.zeros((B, cache_len, K, hd), dtype)}


def attn_cache_pspecs(cfg: ModelConfig):
    if cfg.kv_shard == "heads":
        # head_dim over "model" (always divisible; kv-head counts in the
        # pool go down to 1): cache writes are shard-local and attention
        # computes partial q.k dots combined with a small logits psum.
        s = P(("pod", "data"), None, None, "model")
    else:
        # batch over agents, sequence over "model" => split-KV (DESIGN §4)
        s = P(("pod", "data"), "model", None, None)
    return {"k": s, "v": s}


# ---------------------------------------------------------------------------
# RG-LRU mixer (RecurrentGemma / Griffin)
# ---------------------------------------------------------------------------


def rglru_defs(cfg: ModelConfig) -> Dict:
    d, r, cw = cfg.d_model, cfg.r_dim, cfg.conv_width
    return {"w_x": ParamDef((d, r), P(None, "model")),
            "w_gate": ParamDef((d, r), P(None, "model")),
            "conv_w": ParamDef((cw, r), P(None, "model"), scale=1.0 / math.sqrt(cw)),
            "lam": ParamDef((r,), P("model"), init="lru_lambda"),
            "w_inp": ParamDef((r, r), P(None, "model")),
            "w_rec": ParamDef((r, r), P(None, "model")),
            "w_out": ParamDef((r, d), P("model", None))}


_LRU_C = 8.0


def _rglru_gates(p, xb):
    """a_t (log-space) and gated input for the linear recurrence."""
    r_t = jax.nn.sigmoid(xb @ p["w_rec"])
    i_t = jax.nn.sigmoid(xb @ p["w_inp"])
    log_a = -_LRU_C * r_t * jax.nn.softplus(p["lam"])          # log a_t < 0
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    b = mult * (i_t * xb)
    return a.astype(xb.dtype), b.astype(xb.dtype)


def rglru_apply_seq(cfg: ModelConfig, kind: str, p: Dict, x, ctx: Ctx):
    B, S, d = x.shape
    xb = x @ p["w_x"]                                          # (B,S,r)
    gate = jax.nn.gelu(x @ p["w_gate"])
    # depthwise causal conv over time
    pad = jnp.pad(xb, ((0, 0), (cfg.conv_width - 1, 0), (0, 0)))
    conv = sum(pad[:, i:i + S] * p["conv_w"][i] for i in range(cfg.conv_width))
    a, b = _rglru_gates(p, conv)
    # linear recurrence h_t = a_t h_{t-1} + b_t via associative scan
    def comb(l, r_):
        return (l[0] * r_[0], r_[0] * l[1] + r_[1])
    _, h = jax.lax.associative_scan(comb, (a, b), axis=1)
    y = (h * gate) @ p["w_out"]
    cache = None
    if ctx.cache_len:
        cache = {"h": h[:, -1].astype(jnp.float32),
                 "conv": pad[:, -(cfg.conv_width - 1):] if cfg.conv_width > 1
                 else jnp.zeros((B, 0, cfg.r_dim), x.dtype)}
    return y, cache


def rglru_apply_dec(cfg: ModelConfig, kind: str, p: Dict, x, cache: Dict,
                    ctx: Ctx):
    B, d = x.shape
    xb = x @ p["w_x"]                                          # (B,r)
    gate = jax.nn.gelu(x @ p["w_gate"])
    hist = jnp.concatenate([cache["conv"], xb[:, None]], axis=1)  # (B,cw,r)
    conv = jnp.einsum("bcr,cr->br", hist, p["conv_w"])
    a, b = _rglru_gates(p, conv)
    h = a * cache["h"].astype(a.dtype) + b
    y = (h * gate) @ p["w_out"]
    return y, {"h": h.astype(jnp.float32), "conv": hist[:, 1:]}


def rglru_init_cache(cfg: ModelConfig, B: int, cache_len: int, dtype):
    return {"h": jnp.zeros((B, cfg.r_dim), jnp.float32),
            "conv": jnp.zeros((B, cfg.conv_width - 1, cfg.r_dim), dtype)}


def rglru_cache_pspecs(cfg: ModelConfig):
    return {"h": P(("pod", "data"), "model"),
            "conv": P(("pod", "data"), None, "model")}


# ---------------------------------------------------------------------------
# mLSTM mixer (xLSTM) — matrix memory, exact recurrent form
# ---------------------------------------------------------------------------


def mlstm_defs(cfg: ModelConfig) -> Dict:
    d, di, H = cfg.d_model, cfg.mlstm_inner, cfg.n_heads
    hd = di // H
    return {"w_up": ParamDef((d, 2 * di), P(None, "model")),
            "wq": ParamDef((di, di), P(None, "model")),
            "wk": ParamDef((di, di), P(None, "model")),
            "wv": ParamDef((di, di), P(None, "model")),
            "w_igate": ParamDef((di, H), P(None, None), scale=0.02),
            "w_fgate": ParamDef((di, H), P(None, None), scale=0.02),
            "skip_gamma": ParamDef((di,), P("model"), init="zeros"),
            "w_down": ParamDef((di, d), P("model", None))}


def _mlstm_cell(q, k, v, igate, fgate, state):
    """One step. q/k/v: (B,H,hd); i/f gates: (B,H) pre-activations.

    Stabilized exponential gating (xLSTM eq. 19-27):
      m_t = max(f~ + m_{t-1}, i~);  f' = exp(f~ + m_{t-1} - m_t); i' = exp(i~ - m_t)
      C_t = f' C_{t-1} + i' v k^T ;  n_t = f' n_{t-1} + i' k
      h~  = C_t q / max(|n_t . q|, 1)
    """
    C, n, m = state
    hd = q.shape[-1]
    k = k / math.sqrt(hd)
    m_new = jnp.maximum(fgate + m, igate)
    fp = jnp.exp(fgate + m - m_new)
    ip = jnp.exp(igate - m_new)
    C_new = fp[..., None, None] * C + ip[..., None, None] * (
        v[..., :, None] * k[..., None, :])
    n_new = fp[..., None] * n + ip[..., None] * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", n_new, q)), 1.0)
    h = jnp.einsum("bhde,bhe->bhd", C_new, q) / denom[..., None]
    return h, (C_new, n_new, m_new)


def _mlstm_state0(B, H, hd):
    return (jnp.zeros((B, H, hd, hd), jnp.float32),
            jnp.zeros((B, H, hd), jnp.float32),
            jnp.zeros((B, H), jnp.float32))


def _mlstm_parallel(q, k, v, ig, fg):
    """Parallel (quadratic) mLSTM forward — EXACTLY equal to the scan form.

    Uses the same running-max stabilizer: m_i = F_i + cummax_{j<=i}(i~_j - F_j)
    where F is the cumulative log forget gate, matching the recurrent
    m_t = max(f~_t + m_{t-1}, i~_t). Returns (h (B,S,H,hd), state at t=S-1).
    """
    B, S, H, hd = q.shape
    k = k / math.sqrt(hd)
    F = jnp.cumsum(fg, axis=1)                                 # (B,S,H)
    a = ig - F                                                 # i~_j - F_j
    # the zero initial state acts as a virtual j=-1 entry with i~=0, F_j=0:
    # recurrent m_t = max(F_t, max_{j<=t}(F_t - F_j + i~_j))
    m = F + jnp.maximum(jax.lax.cummax(a, axis=1), 0.0)        # (B,S,H)
    # D[i,j] = exp(F_i - F_j + ig_j - m_i) for j<=i
    logD = (F + 0)[:, :, None, :] - F[:, None, :, :] + ig[:, None, :, :] \
        - m[:, :, None, :]                                     # (B,Si,Sj,H)
    causal = jnp.tril(jnp.ones((S, S), bool))
    D = jnp.where(causal[None, :, :, None], jnp.exp(logD), 0.0)
    scores = jnp.einsum("bihd,bjhd->bijh", q, k) * D           # (B,Si,Sj,H)
    denom = jnp.maximum(jnp.abs(scores.sum(axis=2)), 1.0)      # (B,S,H)
    h = jnp.einsum("bijh,bjhd->bihd", scores, v) / denom[..., None]
    # final recurrent state (for prefill -> decode handoff)
    wC = jnp.exp(F[:, -1:, :] - F + ig - m[:, -1:, :])         # (B,S,H)
    C = jnp.einsum("bjh,bjhd,bjhe->bhde", wC, v, k)
    n = jnp.einsum("bjh,bjhd->bhd", wC, k)
    state = (C, n, m[:, -1])
    return h, state


def mlstm_apply_seq(cfg: ModelConfig, kind: str, p: Dict, x, ctx: Ctx):
    B, S, d = x.shape
    di, H = cfg.mlstm_inner, cfg.n_heads
    hd = di // H
    up = x @ p["w_up"]
    xb, z = jnp.split(up, 2, axis=-1)
    q = (xb @ p["wq"]).reshape(B, S, H, hd).astype(jnp.float32)
    k = (xb @ p["wk"]).reshape(B, S, H, hd).astype(jnp.float32)
    v = (xb @ p["wv"]).reshape(B, S, H, hd).astype(jnp.float32)
    ig = (xb @ p["w_igate"]).astype(jnp.float32)               # (B,S,H)
    fg = jax.nn.log_sigmoid((xb @ p["w_fgate"]).astype(jnp.float32))

    if cfg.mlstm_impl == "parallel":
        hs_bshd, state = _mlstm_parallel(q, k, v, ig, fg)
        h = hs_bshd.reshape(B, S, di).astype(x.dtype)
    else:
        def step(state, t):
            h, state = _mlstm_cell(q[:, t], k[:, t], v[:, t], ig[:, t],
                                   fg[:, t], state)
            return state, h

        state, hs = jax.lax.scan(step, _mlstm_state0(B, H, hd),
                                 jnp.arange(S))
        h = jnp.moveaxis(hs, 0, 1).reshape(B, S, di).astype(x.dtype)
    h = rms_norm(h, p["skip_gamma"]) + xb                      # skip
    y = (h * jax.nn.silu(z)) @ p["w_down"]
    cache = None
    if ctx.cache_len:
        cache = {"C": state[0], "n": state[1], "m": state[2]}
    return y, cache


def mlstm_apply_dec(cfg: ModelConfig, kind: str, p: Dict, x, cache: Dict,
                    ctx: Ctx):
    B, d = x.shape
    di, H = cfg.mlstm_inner, cfg.n_heads
    hd = di // H
    up = x @ p["w_up"]
    xb, z = jnp.split(up, 2, axis=-1)
    q = (xb @ p["wq"]).reshape(B, H, hd).astype(jnp.float32)
    k = (xb @ p["wk"]).reshape(B, H, hd).astype(jnp.float32)
    v = (xb @ p["wv"]).reshape(B, H, hd).astype(jnp.float32)
    ig = (xb @ p["w_igate"]).astype(jnp.float32)
    fg = jax.nn.log_sigmoid((xb @ p["w_fgate"]).astype(jnp.float32))
    h, state = _mlstm_cell(q, k, v, ig, fg,
                           (cache["C"], cache["n"], cache["m"]))
    h = h.reshape(B, di).astype(x.dtype)
    h = rms_norm(h, p["skip_gamma"]) + xb
    y = (h * jax.nn.silu(z)) @ p["w_down"]
    return y, {"C": state[0], "n": state[1], "m": state[2]}


def mlstm_init_cache(cfg: ModelConfig, B: int, cache_len: int, dtype):
    H = cfg.n_heads
    hd = cfg.mlstm_inner // H
    C, n, m = _mlstm_state0(B, H, hd)
    return {"C": C, "n": n, "m": m}


def mlstm_cache_pspecs(cfg: ModelConfig):
    return {"C": P(("pod", "data"), None, "model", None),
            "n": P(("pod", "data"), None, "model"),
            "m": P(("pod", "data"), None)}


# ---------------------------------------------------------------------------
# sLSTM mixer (xLSTM) — scalar memory, head-block-diagonal recurrence
# ---------------------------------------------------------------------------


def slstm_defs(cfg: ModelConfig) -> Dict:
    d, H = cfg.d_model, cfg.n_heads
    hd = d // H
    f = cfg.slstm_hidden
    defs = {f"w_{g}": ParamDef((d, d), P(None, "model")) for g in
            ("z", "i", "f", "o")}
    defs.update({f"r_{g}": ParamDef((H, hd, hd), P(None, "model", None),
                                    scale=1.0 / math.sqrt(hd))
                 for g in ("z", "i", "f", "o")})
    defs.update({"w_ff_gate": ParamDef((d, f), P(None, "model")),
                 "w_ff_up": ParamDef((d, f), P(None, "model")),
                 "w_ff_down": ParamDef((f, d), P("model", None)),
                 "norm_ff": ParamDef((d,), P(None), init="zeros")})
    return defs


def _slstm_cell(p, xz, xi, xf, xo, state, H, hd):
    """One step. x*: (B, d) gate pre-activations from the input."""
    c, n, h, m = state
    hh = h.reshape(h.shape[0], H, hd)
    rz = jnp.einsum("bhd,hde->bhe", hh, p["r_z"]).reshape(h.shape)
    ri = jnp.einsum("bhd,hde->bhe", hh, p["r_i"]).reshape(h.shape)
    rf = jnp.einsum("bhd,hde->bhe", hh, p["r_f"]).reshape(h.shape)
    ro = jnp.einsum("bhd,hde->bhe", hh, p["r_o"]).reshape(h.shape)
    z = jnp.tanh(xz + rz)
    o = jax.nn.sigmoid(xo + ro)
    i_t = xi + ri
    f_t = jax.nn.log_sigmoid(xf + rf)
    m_new = jnp.maximum(f_t + m, i_t)
    ip = jnp.exp(i_t - m_new)
    fp = jnp.exp(f_t + m - m_new)
    c_new = fp * c + ip * z
    n_new = fp * n + ip
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return h_new, (c_new, n_new, h_new, m_new)


def slstm_apply_seq(cfg: ModelConfig, kind: str, p: Dict, x, ctx: Ctx):
    B, S, d = x.shape
    H = cfg.n_heads
    hd = d // H
    xz = (x @ p["w_z"]).astype(jnp.float32)
    xi = (x @ p["w_i"]).astype(jnp.float32)
    xf = (x @ p["w_f"]).astype(jnp.float32)
    xo = (x @ p["w_o"]).astype(jnp.float32)
    state0 = tuple(jnp.zeros((B, d), jnp.float32) for _ in range(4))

    def step(state, t):
        h, state = _slstm_cell(p, xz[:, t], xi[:, t], xf[:, t], xo[:, t],
                               state, H, hd)
        return state, h

    state, hs = jax.lax.scan(step, state0, jnp.arange(S))
    h = jnp.moveaxis(hs, 0, 1).astype(x.dtype)                 # (B,S,d)
    y = h + gelu_glu(rms_norm(h, p["norm_ff"]), p["w_ff_gate"], p["w_ff_up"],
                     p["w_ff_down"])
    cache = None
    if ctx.cache_len:
        cache = {"c": state[0], "n": state[1], "h": state[2], "m": state[3]}
    return y, cache


def slstm_apply_dec(cfg: ModelConfig, kind: str, p: Dict, x, cache: Dict,
                    ctx: Ctx):
    B, d = x.shape
    H = cfg.n_heads
    hd = d // H
    xz = (x @ p["w_z"]).astype(jnp.float32)
    xi = (x @ p["w_i"]).astype(jnp.float32)
    xf = (x @ p["w_f"]).astype(jnp.float32)
    xo = (x @ p["w_o"]).astype(jnp.float32)
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    h, state = _slstm_cell(p, xz, xi, xf, xo, state, H, hd)
    h = h.astype(x.dtype)
    y = h + gelu_glu(rms_norm(h, p["norm_ff"]), p["w_ff_gate"], p["w_ff_up"],
                     p["w_ff_down"])
    return y, {"c": state[0], "n": state[1], "h": state[2], "m": state[3]}


def slstm_init_cache(cfg: ModelConfig, B: int, cache_len: int, dtype):
    d = cfg.d_model
    z = jnp.zeros((B, d), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z}


def slstm_cache_pspecs(cfg: ModelConfig):
    s = P(("pod", "data"), "model")
    return {"c": s, "n": s, "h": s, "m": s}


# ---------------------------------------------------------------------------
# Block = norm -> mixer -> residual [-> norm -> ffn -> residual]
# ---------------------------------------------------------------------------

_MIXER = {
    "attn": (attn_defs, attn_apply_seq, attn_apply_dec, attn_init_cache,
             attn_cache_pspecs),
    "attn_local": (attn_defs, attn_apply_seq, attn_apply_dec, attn_init_cache,
                   attn_cache_pspecs),
    "rglru": (rglru_defs, rglru_apply_seq, rglru_apply_dec, rglru_init_cache,
              rglru_cache_pspecs),
    "mlstm": (mlstm_defs, mlstm_apply_seq, mlstm_apply_dec, mlstm_init_cache,
              mlstm_cache_pspecs),
    "slstm": (slstm_defs, slstm_apply_seq, slstm_apply_dec, slstm_init_cache,
              slstm_cache_pspecs),
}


def _has_ffn(cfg: ModelConfig, kind: str) -> bool:
    if cfg.family == "ssm":
        return False                       # xLSTM blocks are self-contained
    return True


def _ffn_is_moe(cfg: ModelConfig, kind: str) -> bool:
    return cfg.n_experts > 0 and kind.startswith("attn")


def block_defs(cfg: ModelConfig, kind: str) -> Dict:
    mixer = kind if kind in _MIXER else "attn"
    d = {"norm1": ParamDef((cfg.d_model,), P(None), init="zeros"),
         "mixer": _MIXER[mixer][0](cfg)}
    if _has_ffn(cfg, kind):
        d["norm2"] = ParamDef((cfg.d_model,), P(None), init="zeros")
        d["ffn"] = moe_defs(cfg) if _ffn_is_moe(cfg, kind) else ffn_defs(cfg)
    return d


_SEQ_SPEC = P(("pod", "data"), "model", None)   # residual stream (B, S, d)


def block_apply_seq(cfg: ModelConfig, kind: str, p: Dict, x, ctx: Ctx):
    """Returns (x, cache_entry, aux_loss)."""
    if cfg.seq_shard:
        x = constrain(x, _SEQ_SPEC)
    mixer = kind if kind in _MIXER else "attn"
    h, cache = _MIXER[mixer][1](cfg, mixer if kind == "attn_local" else kind,
                                p["mixer"], rms_norm(x, p["norm1"]), ctx)
    x = x + h
    if cfg.seq_shard:
        x = constrain(x, _SEQ_SPEC)
    aux = jnp.zeros((), jnp.float32)
    if _has_ffn(cfg, kind):
        hin = rms_norm(x, p["norm2"])
        if _ffn_is_moe(cfg, kind):
            h2, aux = moe_apply(cfg, p["ffn"], hin)
        else:
            h2 = ffn_apply(cfg, p["ffn"], hin)
        x = x + h2
    return x, cache, aux


def block_apply_dec(cfg: ModelConfig, kind: str, p: Dict, x, cache, ctx: Ctx):
    mixer = kind if kind in _MIXER else "attn"
    h, cache = _MIXER[mixer][2](cfg, mixer if kind == "attn_local" else kind,
                                p["mixer"], rms_norm(x, p["norm1"]), cache, ctx)
    x = x + h
    if _has_ffn(cfg, kind):
        hin = rms_norm(x, p["norm2"])
        if _ffn_is_moe(cfg, kind):
            h2, _ = moe_apply(cfg, p["ffn"], hin[:, None, :])
            h2 = h2[:, 0]
        else:
            h2 = ffn_apply(cfg, p["ffn"], hin)
        x = x + h2
    return x, cache


def block_init_cache(cfg: ModelConfig, kind: str, B: int, cache_len: int,
                     dtype):
    mixer = kind if kind in _MIXER else "attn"
    return _MIXER[mixer][3](cfg, B, cache_len, dtype)


def block_cache_pspecs(cfg: ModelConfig, kind: str):
    mixer = kind if kind in _MIXER else "attn"
    return _MIXER[mixer][4](cfg)

"""Flat slot-row parameter layout for neural agents (DESIGN.md §18).

The collaborative engines treat every agent model as one f32 row of width
p — the slot-row layout the ADMM state arrays, halo exchange, telemetry,
and serving planes are all built on.  :class:`ParamFlattener` maps an
arbitrary parameter pytree onto such a row (leaves concatenated in treedef
order) and back, so small nonlinear models ride the existing CL-ADMM
substrate unchanged: the engines consensus-couple the rows, and the
inexact primal (``core.primal.InexactPrimal``) unflattens them per agent
to evaluate the local loss.

Two agent-model families cover the paper's "beyond linear" regime:

* :class:`MLPAgent` — a tiny fully-trainable MLP (the ``federated_moons``
  acceptance model);
* :class:`LoRAAgent` — a frozen random-feature layer with a trainable
  low-rank adapter + head, the LoRA-shaped parameterization where the
  consensus rows hold only the adapter.

Both are frozen dataclasses (hashable — they ride through ``jax.jit``
static arguments inside :class:`~repro.core.primal.InexactPrimal`), so
they hold no arrays: parameters come from ``init``, and the LoRA base
weights are derived deterministically from ``base_seed`` at trace time.
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_ACTIVATIONS = {"tanh": jnp.tanh, "relu": jax.nn.relu}


@dataclasses.dataclass(frozen=True)
class ParamFlattener:
    """Bijection between a fixed parameter pytree and a flat f32 row.

    Built from a template pytree (shapes + treedef only — no arrays are
    retained, so the flattener is hashable and jit-static).  ``flatten``
    and ``unflatten`` are row-local jnp programs: used under ``vmap`` they
    map an agent-stacked pytree to the (n, p) slot-row block and back.
    """

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]

    @classmethod
    def from_template(cls, tree) -> "ParamFlattener":
        """Build from any pytree of arrays (values are ignored)."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        shapes = tuple(tuple(int(d) for d in np.shape(leaf))
                       for leaf in leaves)
        return cls(treedef, shapes)

    @property
    def dim(self) -> int:
        """Total flat width p (the engines' model-row dimension)."""
        return sum(int(np.prod(s, dtype=np.int64)) for s in self.shapes)

    def flatten(self, tree) -> jnp.ndarray:
        """Pytree -> (dim,) f32 row (leaves in treedef order)."""
        leaves = jax.tree_util.tree_leaves(tree)
        return jnp.concatenate(
            [jnp.reshape(leaf, (-1,)).astype(jnp.float32)
             for leaf in leaves])

    def unflatten(self, vec: jnp.ndarray):
        """(dim,) row -> pytree with the template's structure and shapes."""
        leaves = []
        off = 0
        for shape in self.shapes:
            size = int(np.prod(shape, dtype=np.int64))
            leaves.append(jnp.reshape(vec[off:off + size], shape))
            off += size
        return jax.tree_util.tree_unflatten(self.treedef, leaves)


@dataclasses.dataclass(frozen=True)
class MLPAgent:
    """Tiny per-agent MLP ``R^in_dim -> R`` (scalar score head).

    Parameters are a tuple of ``{"w", "b"}`` layer dicts; ``apply`` maps a
    (m, in_dim) batch to (m,) scores whose sign is the predicted ±1 label.
    """

    in_dim: int
    hidden: Tuple[int, ...] = (8,)
    activation: str = "tanh"

    def _dims(self) -> Tuple[Tuple[int, int], ...]:
        sizes = (self.in_dim,) + tuple(self.hidden) + (1,)
        return tuple(zip(sizes[:-1], sizes[1:]))

    def init(self, key, scale: float = 1.0):
        """Glorot-style random parameters for one agent."""
        params = []
        for fan_in, fan_out in self._dims():
            key, kw = jax.random.split(key)
            w = jax.random.normal(kw, (fan_in, fan_out), jnp.float32) \
                * (scale / math.sqrt(fan_in))
            params.append({"w": w, "b": jnp.zeros((fan_out,), jnp.float32)})
        return tuple(params)

    def apply(self, params, x) -> jnp.ndarray:
        """(m, in_dim) -> (m,) scores."""
        act = _ACTIVATIONS[self.activation]
        h = x
        for layer in params[:-1]:
            h = act(h @ layer["w"] + layer["b"])
        out = h @ params[-1]["w"] + params[-1]["b"]
        return out[..., 0]

    def flattener(self) -> ParamFlattener:
        """The slot-row layout of this architecture's parameters."""
        template = tuple(
            {"w": np.zeros((fi, fo), np.float32),
             "b": np.zeros((fo,), np.float32)}
            for fi, fo in self._dims())
        return ParamFlattener.from_template(template)


@functools.lru_cache(maxsize=None)
def _lora_base(in_dim: int, width: int, base_seed: int):
    """Frozen random-feature first layer shared by every LoRAAgent with the
    same config (host-side RNG, derived once per config — never inside a
    traced body)."""
    rng = np.random.default_rng(base_seed)
    w0 = rng.standard_normal((in_dim, width)) / math.sqrt(in_dim)
    b0 = rng.uniform(-1.0, 1.0, width)
    return (jnp.asarray(w0, jnp.float32), jnp.asarray(b0, jnp.float32))


@dataclasses.dataclass(frozen=True)
class LoRAAgent:
    """LoRA-shaped agent: frozen random-feature layer + trainable low-rank
    adapter and linear head.

    The effective first-layer weight is ``W0 + A @ B`` with frozen
    ``W0 (in_dim, width)`` (derived from ``base_seed``) and trainable
    ``A (in_dim, rank)``, ``B (rank, width)``; the consensus rows carry
    only the adapter + head, so the flat dimension is
    ``rank * (in_dim + width) + width + 1`` regardless of ``width``.
    """

    in_dim: int
    width: int = 16
    rank: int = 2
    base_seed: int = 0
    activation: str = "tanh"

    def init(self, key, scale: float = 0.1):
        """Adapter (A random, B zero — standard LoRA init) + head."""
        ka, kh = jax.random.split(key)
        a = jax.random.normal(ka, (self.in_dim, self.rank), jnp.float32) \
            * (scale / math.sqrt(self.in_dim))
        head = jax.random.normal(kh, (self.width,), jnp.float32) \
            * (1.0 / math.sqrt(self.width))
        return {"a": a, "b": jnp.zeros((self.rank, self.width), jnp.float32),
                "head": head, "bias": jnp.zeros((), jnp.float32)}

    def apply(self, params, x) -> jnp.ndarray:
        """(m, in_dim) -> (m,) scores through the adapted frozen layer."""
        w0, b0 = _lora_base(self.in_dim, self.width, self.base_seed)
        act = _ACTIVATIONS[self.activation]
        h = act(x @ (w0 + params["a"] @ params["b"]) + b0)
        return h @ params["head"] + params["bias"]

    def flattener(self) -> ParamFlattener:
        """The slot-row layout of the trainable (adapter + head) leaves."""
        template = {
            "a": np.zeros((self.in_dim, self.rank), np.float32),
            "b": np.zeros((self.rank, self.width), np.float32),
            "head": np.zeros((self.width,), np.float32),
            "bias": np.zeros((), np.float32)}
        return ParamFlattener.from_template(template)

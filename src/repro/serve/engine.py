"""Batched serving engine.

Slot-based continuous batching over a fixed decode batch B:

  * requests (prompts) queue up; free slots are filled by prefilling the
    prompt and splicing its KV/recurrent state into the live batch cache;
  * one jitted ``decode_step`` advances ALL slots a token per tick;
  * finished slots (EOS or max_tokens) are harvested and recycled.

The decode batch layout matches the decode dry-run shapes: cache sharded
batch-over-agents and sequence-over-"model" (split-KV, DESIGN.md §4).
On CPU this runs the reduced configs for the demo/examples/tests; on TPU the
same engine drives the full configs.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.sparse import personalized_predict
from repro.models import Model
from repro.serve.store import MixedModelCache, ServeReport


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Decode-serving knobs: batch geometry, sampling, cache layout."""

    batch_size: int = 4
    cache_len: int = 256
    max_new_tokens: int = 64
    temperature: float = 0.0       # 0 => greedy
    eos_id: Optional[int] = None
    ring: bool = False
    seed: int = 0


def sample_token(logits, key, temperature: float):
    """Greedy argmax at temperature 0, else categorical sampling."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


@dataclasses.dataclass
class _Slot:
    request_id: int = -1
    generated: List[int] = dataclasses.field(default_factory=list)
    remaining: int = 0
    active: bool = False


class Engine:
    """Token-decode serving engine (slot-based continuous batching).

    After :meth:`run` returns, ``self.exhausted`` records whether the
    tick budget ran out with work still queued or in flight — callers
    must check it before treating the returned dict as complete.
    """

    def __init__(self, model: Model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        B = cfg.batch_size
        self.cache = model.init_cache(B, cfg.cache_len)
        self.slots = [_Slot() for _ in range(B)]
        self._results: Dict[int, List[int]] = {}
        self._next_id = 0
        self._key = jax.random.PRNGKey(cfg.seed)
        self._pending: List[Tuple[int, np.ndarray]] = []
        self.exhausted = False
        # token fed to idle slots (content irrelevant — output discarded)
        self._last_tok = np.zeros(self._tok_shape(B), np.int32)

        @jax.jit
        def _decode(params, cache, token, key):
            logits, cache = model.decode_step(params, cache, {"token": token},
                                              ring=cfg.ring)
            nxt = sample_token(logits, key, cfg.temperature)
            return logits, cache, nxt
        self._decode = _decode

        @partial(jax.jit, static_argnames=("prompt_len",))
        def _prefill_one(params, tokens, prompt_len):
            batch = {"tokens": tokens, "labels": tokens}
            logits, cache = model.prefill(params, batch, cache_len=cfg.cache_len)
            return logits, cache
        self._prefill_one = _prefill_one

    def _tok_shape(self, B):
        if self.model.cfg.family == "audio":
            return (B, self.model.cfg.n_codebooks)
        return (B,)

    # -- public API ----------------------------------------------------------

    def submit(self, prompt_tokens) -> int:
        """Queue a prompt; returns the request id."""
        rid = self._next_id
        self._next_id += 1
        self._pending.append((rid, np.asarray(prompt_tokens, np.int32)))
        return rid

    def result(self, rid: int) -> Optional[List[int]]:
        """Decoded tokens for a finished request id (None if pending)."""
        return self._results.get(rid)

    def run(self, max_ticks: int = 10_000) -> Dict[int, List[int]]:
        """Drive until all submitted requests finish.

        Previously a run that hit ``max_ticks`` with slots still active
        (or prompts still queued) returned its partial results
        indistinguishably from a completed one; ``self.exhausted`` now
        flags that case explicitly so callers can resubmit or raise.
        """
        ticks = 0
        while (self._pending or any(s.active for s in self.slots)) \
                and ticks < max_ticks:
            self._fill_slots()
            self._tick()
            ticks += 1
        self.exhausted = bool(self._pending
                              or any(s.active for s in self.slots))
        return dict(self._results)

    # -- internals -----------------------------------------------------------

    def _fill_slots(self):
        for b, slot in enumerate(self.slots):
            if slot.active or not self._pending:
                continue
            rid, prompt = self._pending.pop(0)
            tokens = jnp.asarray(prompt[None])          # (1, S_prompt)
            logits, pcache = self._prefill_one(self.params, tokens,
                                               prompt.shape[-1])
            # splice this request's cache into slot b of the live batch:
            # layer leaves are (reps, B, ...); pos is (B,)
            new_layers = jax.tree_util.tree_map(
                lambda live, new: live.at[:, b].set(new[:, 0]),
                self.cache["layers"], pcache["layers"])
            new_pos = self.cache["pos"].at[b].set(pcache["pos"][0])
            self.cache = {"layers": new_layers, "pos": new_pos}
            first = np.asarray(sample_token(logits[:, 0], self._split(),
                                            self.cfg.temperature))[0]
            self._last_tok[b] = first
            slot.request_id = rid
            slot.generated = [int(np.atleast_1d(first).ravel()[0])]
            slot.remaining = self.cfg.max_new_tokens - 1
            slot.active = True

    def _split(self):
        self._key, k = jax.random.split(self._key)
        return k

    def _tick(self):
        tok = jnp.asarray(self._last_tok)
        logits, self.cache, nxt = self._decode(self.params, self.cache, tok,
                                               self._split())
        nxt_np = np.asarray(nxt)
        for b, slot in enumerate(self.slots):
            if not slot.active:
                continue
            t = int(np.atleast_1d(nxt_np[b]).ravel()[0])
            slot.generated.append(t)
            slot.remaining -= 1
            self._last_tok[b] = nxt_np[b]
            if slot.remaining <= 0 or (self.cfg.eos_id is not None
                                       and t == self.cfg.eos_id):
                self._results[slot.request_id] = slot.generated
                slot.active = False


class CollabServeEngine:
    """Personalization service over a gossip-backed agent-state store
    (DESIGN.md §16).

    The serving half of the read/write split: the scenario driver is the
    writer (it :meth:`commit`\\ s each record chunk's models + staleness
    and the chunk's dirty set), inference requests are readers.  Serving
    is batched decode — each tick gathers up to ``batch_size`` users'
    personalized parameter rows (through the :class:`MixedModelCache`,
    falling back to the store for misses) and runs one jitted
    ``personalized_predict`` over the whole batch, so many users are
    served per dispatch from one (B, p) row block.

    Works over either an :class:`AgentStateStore` or a
    :class:`ShardedAgentStateStore` (both expose ``read_rows``); the
    predictions and served staleness are identical by the stores' parity
    contract.
    """

    def __init__(self, store, n: int, p: int, batch_size: int = 256):
        self.store = store
        self.n = int(n)
        self.p = int(p)
        self.batch_size = int(batch_size)
        self.cache = MixedModelCache(n, p)
        self._predict = jax.jit(personalized_predict)
        self._served_staleness: List[np.ndarray] = []
        self.requests = 0

    # -- writer side ---------------------------------------------------------

    def commit(self, round_: int, theta, staleness, dirty=None) -> int:
        """Publish a chunk snapshot and invalidate its dirty cache entries.

        ``dirty`` is the chunk's (n,) bool model-update delivery mask
        (``telemetry.metrics.stream_dirty_chunks``); returns how many
        live cache entries it voided.
        """
        self.store.commit(round_, theta, staleness)
        return self.cache.invalidate(dirty) if dirty is not None else 0

    # -- reader side ---------------------------------------------------------

    def serve(self, users, x=None):
        """Serve a batch of inference requests from the committed state.

        ``users`` (R,) int user ids; ``x`` optional (R, p) feature rows
        (defaults to all-ones, making the prediction the row sum — the
        linear model family of paper §5 with trivial features).  Returns
        ``(preds (R,) f32, staleness (R,) int32)``; staleness per request
        is recorded for the :meth:`report` percentiles.
        """
        users = np.asarray(users, np.int64)
        R = users.shape[0]
        preds = np.empty(R, np.float32)
        stale = np.empty(R, np.int32)
        for lo in range(0, R, self.batch_size):
            u = users[lo:lo + self.batch_size]
            snap_round = self.store.snapshot_round()
            hit, rows, stl = self.cache.lookup(u, snap_round)
            if not hit.all():
                miss = ~hit
                read = self.store.read_rows(u[miss])
                rows[miss] = read.theta
                stl[miss] = read.staleness
                self.cache.fill(u[miss], read.theta, read.staleness,
                                read.round)
            xb = (np.ones_like(rows) if x is None
                  else np.asarray(x[lo:lo + self.batch_size], np.float32))
            preds[lo:lo + u.shape[0]] = np.asarray(self._predict(rows, xb))
            stale[lo:lo + u.shape[0]] = stl
        self.requests += R
        self._served_staleness.append(stale)
        return preds, stale

    def report(self, requests_c=None, hits_c=None, misses_c=None,
               invalidations_c=None) -> ServeReport:
        """Snapshot the engine's accounting as a :class:`ServeReport`."""
        served = (np.concatenate(self._served_staleness)
                  if self._served_staleness else np.zeros(0, np.int32))
        zero = np.zeros(0, np.int64)
        return ServeReport(
            requests=self.requests,
            hits=self.cache.hits,
            misses=self.cache.misses,
            invalidations=self.cache.invalidations,
            served_staleness=served,
            requests_c=np.asarray(requests_c, np.int64)
            if requests_c is not None else zero,
            hits_c=np.asarray(hits_c, np.int64)
            if hits_c is not None else zero,
            misses_c=np.asarray(misses_c, np.int64)
            if misses_c is not None else zero,
            invalidations_c=np.asarray(invalidations_c, np.int64)
            if invalidations_c is not None else zero,
        )

"""Batched serving engine.

Slot-based continuous batching over a fixed decode batch B:

  * requests (prompts) queue up; free slots are filled by prefilling the
    prompt and splicing its KV/recurrent state into the live batch cache;
  * one jitted ``decode_step`` advances ALL slots a token per tick;
  * finished slots (EOS or max_tokens) are harvested and recycled.

The decode batch layout matches the decode dry-run shapes: cache sharded
batch-over-agents and sequence-over-"model" (split-KV, DESIGN.md §4).
On CPU this runs the reduced configs for the demo/examples/tests; on TPU the
same engine drives the full configs.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import Model


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    batch_size: int = 4
    cache_len: int = 256
    max_new_tokens: int = 64
    temperature: float = 0.0       # 0 => greedy
    eos_id: Optional[int] = None
    ring: bool = False
    seed: int = 0


def sample_token(logits, key, temperature: float):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


@dataclasses.dataclass
class _Slot:
    request_id: int = -1
    generated: List[int] = dataclasses.field(default_factory=list)
    remaining: int = 0
    active: bool = False


class Engine:
    def __init__(self, model: Model, params, cfg: ServeConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        B = cfg.batch_size
        self.cache = model.init_cache(B, cfg.cache_len)
        self.slots = [_Slot() for _ in range(B)]
        self._results: Dict[int, List[int]] = {}
        self._next_id = 0
        self._key = jax.random.PRNGKey(cfg.seed)
        self._pending: List[Tuple[int, np.ndarray]] = []
        # token fed to idle slots (content irrelevant — output discarded)
        self._last_tok = np.zeros(self._tok_shape(B), np.int32)

        @jax.jit
        def _decode(params, cache, token, key):
            logits, cache = model.decode_step(params, cache, {"token": token},
                                              ring=cfg.ring)
            nxt = sample_token(logits, key, cfg.temperature)
            return logits, cache, nxt
        self._decode = _decode

        @partial(jax.jit, static_argnames=("prompt_len",))
        def _prefill_one(params, tokens, prompt_len):
            batch = {"tokens": tokens, "labels": tokens}
            logits, cache = model.prefill(params, batch, cache_len=cfg.cache_len)
            return logits, cache
        self._prefill_one = _prefill_one

    def _tok_shape(self, B):
        if self.model.cfg.family == "audio":
            return (B, self.model.cfg.n_codebooks)
        return (B,)

    # -- public API ----------------------------------------------------------

    def submit(self, prompt_tokens) -> int:
        rid = self._next_id
        self._next_id += 1
        self._pending.append((rid, np.asarray(prompt_tokens, np.int32)))
        return rid

    def result(self, rid: int) -> Optional[List[int]]:
        return self._results.get(rid)

    def run(self, max_ticks: int = 10_000) -> Dict[int, List[int]]:
        """Drive until all submitted requests finish."""
        ticks = 0
        while (self._pending or any(s.active for s in self.slots)) \
                and ticks < max_ticks:
            self._fill_slots()
            self._tick()
            ticks += 1
        return dict(self._results)

    # -- internals -----------------------------------------------------------

    def _fill_slots(self):
        for b, slot in enumerate(self.slots):
            if slot.active or not self._pending:
                continue
            rid, prompt = self._pending.pop(0)
            tokens = jnp.asarray(prompt[None])          # (1, S_prompt)
            logits, pcache = self._prefill_one(self.params, tokens,
                                               prompt.shape[-1])
            # splice this request's cache into slot b of the live batch:
            # layer leaves are (reps, B, ...); pos is (B,)
            new_layers = jax.tree_util.tree_map(
                lambda live, new: live.at[:, b].set(new[:, 0]),
                self.cache["layers"], pcache["layers"])
            new_pos = self.cache["pos"].at[b].set(pcache["pos"][0])
            self.cache = {"layers": new_layers, "pos": new_pos}
            first = np.asarray(sample_token(logits[:, 0], self._split(),
                                            self.cfg.temperature))[0]
            self._last_tok[b] = first
            slot.request_id = rid
            slot.generated = [int(np.atleast_1d(first).ravel()[0])]
            slot.remaining = self.cfg.max_new_tokens - 1
            slot.active = True

    def _split(self):
        self._key, k = jax.random.split(self._key)
        return k

    def _tick(self):
        tok = jnp.asarray(self._last_tok)
        logits, self.cache, nxt = self._decode(self.params, self.cache, tok,
                                               self._split())
        nxt_np = np.asarray(nxt)
        for b, slot in enumerate(self.slots):
            if not slot.active:
                continue
            t = int(np.atleast_1d(nxt_np[b]).ravel()[0])
            slot.generated.append(t)
            slot.remaining -= 1
            self._last_tok[b] = nxt_np[b]
            if slot.remaining <= 0 or (self.cfg.eos_id is not None
                                       and t == self.cfg.eos_id):
                self._results[slot.request_id] = slot.generated
                slot.active = False

"""Agent-state read/write split for the personalization service
(DESIGN.md §16).

The collaborative engines are *writers*: one jitted gossip scan owns the
agent state and commits a snapshot per record chunk (models + per-agent
staleness).  Inference requests are *readers*: each snapshots a user's
current mixed model without ever touching the scan's buffers — reads are
pure host-side gathers over an immutable committed tuple, so serving
cannot perturb the trajectory (the bit-for-bit acceptance property of
tests/test_serve_collab.py) and a reader can never observe a torn
snapshot (a commit swaps one reference; a reader holds either the old
tuple or the new one, never a mix).

Three pieces:

* :class:`AgentStateStore` — the single-device store: committed
  ``(round, theta, staleness)`` snapshots behind an atomic swap.
* :class:`ShardedAgentStateStore` — P per-shard stores, each holding only
  its own local block rows (the ``GraphPartition`` layout); reads route
  to the owning shard via ``launch.sim_mesh.shard_read_route`` and match
  the single-device store bit-for-bit.
* :class:`MixedModelCache` — per-user cached model rows, invalidated by
  the model-update deliveries of each committed chunk
  (``telemetry.metrics.stream_dirty_chunks``): an agent that received no
  update has a bit-identical theta row, so a clean cache entry stays
  valid across commits by construction.
"""

from __future__ import annotations

import dataclasses
import threading
from typing import NamedTuple, Optional

import numpy as np

from repro.launch.sim_mesh import shard_read_route


class CommittedState(NamedTuple):
    """One immutable committed snapshot (what readers hold)."""

    round: int               # global round index at the snapshot (1-based)
    theta: np.ndarray        # (rows, p) committed personalized models
    staleness: np.ndarray    # (rows,) int32 rounds since last update


class AgentStateStore:
    """Read/write-split agent state: scan-side commits, request-side reads.

    The writer (the scenario driver, after each jitted chunk) calls
    :meth:`commit`; readers call :meth:`snapshot` / :meth:`read_rows`.
    Commits replace one tuple reference under a lock; reads are lock-free
    (a Python attribute read is atomic), so a burst of inference requests
    never blocks the writer and vice versa.
    """

    def __init__(self, n: int, p: int):
        self.n = int(n)
        self.p = int(p)
        self._lock = threading.Lock()
        self._committed = CommittedState(
            0, np.zeros((self.n, self.p), np.float32),
            np.zeros(self.n, np.int32))
        self.commits = 0

    def commit(self, round_: int, theta, staleness) -> None:
        """Publish a new snapshot (writer side; copies, then swaps)."""
        theta = np.ascontiguousarray(theta, np.float32)
        staleness = np.ascontiguousarray(staleness, np.int32)
        if theta.shape != (self.n, self.p):
            raise ValueError(
                f"commit shape {theta.shape} != ({self.n}, {self.p})")
        with self._lock:
            self._committed = CommittedState(int(round_), theta, staleness)
            self.commits += 1

    def snapshot(self) -> CommittedState:
        """The current committed tuple (immutable; reader side)."""
        return self._committed

    def snapshot_round(self) -> int:
        """Round index of the current committed snapshot."""
        return self._committed.round

    def read_rows(self, users) -> CommittedState:
        """Snapshot the requested users' rows: (round, theta, staleness).

        One consistent snapshot serves the whole batch — the tuple is
        grabbed once, so even a commit racing the gather leaves every
        returned row from the same (pre- or post-) snapshot.
        """
        snap = self.snapshot()
        users = np.asarray(users, np.int64)
        return CommittedState(snap.round, snap.theta[users],
                              snap.staleness[users])


class ShardedAgentStateStore:
    """P per-shard :class:`AgentStateStore` blocks behind one read router.

    Built from a ``GraphPartition``'s ``owner`` / ``local_pos`` tables:
    shard q's store holds only q's local block rows (padded to the shard
    size m), mirroring how the partitioned engines shard the scan state.
    :meth:`commit` takes canonical-order arrays (what the sharded traces
    report) and scatters each shard its own rows; :meth:`read_rows`
    routes every user to the owning shard's store and gathers its local
    row — bit-for-bit the single-device store's answer
    (tests/test_serve_collab.py).
    """

    def __init__(self, owner, local_pos, p: int,
                 n_shards: Optional[int] = None):
        self.owner = np.asarray(owner, np.int32)
        self.local_pos = np.asarray(local_pos, np.int32)
        self.n = int(self.owner.shape[0])
        self.p = int(p)
        self.n_shards = int(n_shards if n_shards is not None
                            else self.owner.max() + 1)
        m = 1
        for q in range(self.n_shards):
            sel = self.local_pos[self.owner == q]
            m = max(m, int(sel.max()) + 1 if sel.size else 1)
        self.shard_size = m
        self._stores = [AgentStateStore(m, p) for _ in range(self.n_shards)]

    def commit(self, round_: int, theta, staleness) -> None:
        """Commit canonical-order (n, p) state as per-shard local blocks."""
        theta = np.asarray(theta, np.float32)
        staleness = np.asarray(staleness, np.int32)
        for q in range(self.n_shards):
            mask = self.owner == q
            blk = np.zeros((self.shard_size, self.p), np.float32)
            stl = np.zeros(self.shard_size, np.int32)
            blk[self.local_pos[mask]] = theta[mask]  # scatter: unique targets
            stl[self.local_pos[mask]] = staleness[mask]  # scatter: unique targets
            self._stores[q].commit(round_, blk, stl)

    def snapshot_round(self) -> int:
        """Round index of the latest committed snapshot across shards."""
        return max(s.snapshot().round for s in self._stores)

    def read_rows(self, users) -> CommittedState:
        """Route each user to its owning shard's store and gather rows."""
        users = np.asarray(users, np.int64)
        shard, pos = shard_read_route(self.owner, self.local_pos, users)
        theta = np.empty((users.shape[0], self.p), np.float32)
        stale = np.empty(users.shape[0], np.int32)
        round_ = 0
        for q in np.unique(shard):
            sel = shard == q
            snap = self._stores[q].snapshot()
            theta[sel] = snap.theta[pos[sel]]  # scatter: unique targets (boolean mask)
            stale[sel] = snap.staleness[pos[sel]]  # scatter: unique targets
            round_ = max(round_, snap.round)
        return CommittedState(round_, theta, stale)


class MixedModelCache:
    """Per-user cache of served mixed-model rows with delivery invalidation.

    Vectorized over users: a (n,) validity mask plus cached theta rows.
    :meth:`invalidate` voids the entries of agents whose models a
    committed chunk rewrote (the dirty set of
    ``telemetry.metrics.stream_dirty_chunks``); :meth:`lookup` serves
    hits from the cache and reports which users need a store read.

    Staleness is *not* cached by value — a clean agent's staleness keeps
    aging across commits even though its theta row is frozen — but by the
    round its model last absorbed an update (``committed round -
    committed staleness``, which cannot change while the entry is clean),
    so a cache hit at committed round r serves the exact staleness
    ``r - last_update``, bit-identical to a fresh store read.

    Counters (hits / misses / invalidations) are cumulative over the
    cache's lifetime and flow into ``TelemetryFrames`` via the scenario
    driver.
    """

    def __init__(self, n: int, p: int):
        self.n = int(n)
        self.valid = np.zeros(self.n, bool)
        self.theta = np.zeros((self.n, int(p)), np.float32)
        self.last_update = np.zeros(self.n, np.int64)
        self.hits = 0
        self.misses = 0
        self.invalidations = 0

    def invalidate(self, dirty) -> int:
        """Void cached entries of dirty agents; returns how many were live."""
        dirty = np.asarray(dirty, bool)
        killed = int(np.count_nonzero(self.valid & dirty))
        self.valid &= ~dirty
        self.invalidations += killed
        return killed

    def lookup(self, users, round_: int):
        """(hit_mask, theta_rows, staleness_rows) for a user batch.

        ``round_`` is the current committed round (what hit staleness is
        computed against).  Rows of missing users are left zero — the
        caller fills them from the store via :meth:`fill` — and the
        hit/miss counters advance.
        """
        users = np.asarray(users, np.int64)
        hit = self.valid[users]
        self.hits += int(np.count_nonzero(hit))
        self.misses += int(users.shape[0] - np.count_nonzero(hit))
        stale = (int(round_) - self.last_update[users]).astype(np.int32)
        return hit, self.theta[users], stale

    def fill(self, users, theta_rows, staleness_rows, round_: int) -> None:
        """Insert freshly-read rows for the given users (marks them valid)."""
        users = np.asarray(users, np.int64)
        # scatter: idempotent — duplicate users in one batch carry identical
        # rows read from the same committed snapshot
        self.theta[users] = theta_rows
        self.last_update[users] = int(round_) - np.asarray(
            staleness_rows, np.int64)  # scatter: idempotent
        self.valid[users] = True  # scatter: idempotent (every value is True)


@dataclasses.dataclass
class ServeReport:
    """Host-side accounting of one scenario's served inference requests.

    requests / hits / misses / invalidations: totals over the run;
    served_staleness: (R,) int32 staleness of every served model (rounds
    since the user's model last absorbed a neighbor update, at the
    serving snapshot — the PR-6 counter, read at serve time);
    requests_c / hits_c / misses_c / invalidations_c: (n_rec,) cumulative
    per-record-chunk counters (what the telemetry frames attach).
    """

    requests: int
    hits: int
    misses: int
    invalidations: int
    served_staleness: np.ndarray
    requests_c: np.ndarray
    hits_c: np.ndarray
    misses_c: np.ndarray
    invalidations_c: np.ndarray

    @property
    def hit_rate(self) -> float:
        """Cache hit fraction over all served requests (0.0 if none)."""
        return self.hits / self.requests if self.requests else 0.0

    def staleness_percentile(self, q: float) -> float:
        """Percentile of served staleness (0.0 if nothing was served)."""
        if self.served_staleness.size == 0:
            return 0.0
        return float(np.percentile(self.served_staleness, q))

    def summary(self) -> dict:
        """JSON-ready scalar summary (the bench report row)."""
        return {
            "requests": self.requests,
            "cache_hits": self.hits,
            "cache_misses": self.misses,
            "cache_invalidations": self.invalidations,
            "cache_hit_rate": self.hit_rate,
            "served_staleness_p50": self.staleness_percentile(50),
            "served_staleness_p99": self.staleness_percentile(99),
        }

"""Serving: batched decode engine with slot-based continuous batching,
plus the gossip-backed personalization service (DESIGN.md §16)."""

from .engine import CollabServeEngine, Engine, ServeConfig, sample_token
from .store import (
    AgentStateStore,
    CommittedState,
    MixedModelCache,
    ServeReport,
    ShardedAgentStateStore,
)

__all__ = [
    "ServeConfig",
    "Engine",
    "sample_token",
    "CollabServeEngine",
    "AgentStateStore",
    "ShardedAgentStateStore",
    "CommittedState",
    "MixedModelCache",
    "ServeReport",
]

"""Serving: batched decode engine with slot-based continuous batching."""

from .engine import ServeConfig, Engine, sample_token

__all__ = ["ServeConfig", "Engine", "sample_token"]

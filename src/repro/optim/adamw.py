"""AdamW for personalized (agent-stacked) parameter trees.

Memory plan (DESIGN.md §6): personalization removes the ZeRO option across
the data axis (each agent's params are distinct), so optimizer state pays the
full A-way cost; we compensate with bf16 first/second moments (update math in
f32). Adam is elementwise, so agent-stacked leaves need no special handling.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: Any = jnp.bfloat16


def adamw_init(params, cfg: AdamWConfig):
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {"m": jax.tree_util.tree_map(zeros, params),
            "v": jax.tree_util.tree_map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def _global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(grads, opt_state, params, cfg: AdamWConfig,
                 lr_scale=1.0):
    count = opt_state["count"] + 1
    if cfg.grad_clip:
        gn = _global_norm(grads)
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gn, 1e-9))
        grads = jax.tree_util.tree_map(
            lambda g: (g.astype(jnp.float32) * scale), grads)
    else:
        gn = jnp.zeros(())
        grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)

    b1, b2 = cfg.b1, cfg.b2
    c = count.astype(jnp.float32)
    bias1 = 1.0 - b1 ** c
    bias2 = 1.0 - b2 ** c
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v32 = b2 * v.astype(jnp.float32) + (1 - b2) * (g * g)
        mhat = m32 / bias1
        vhat = v32 / bias2
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay:
            step = step + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * step
        return (newp.astype(p.dtype), m32.astype(cfg.moment_dtype),
                v32.astype(cfg.moment_dtype))

    out = jax.tree_util.tree_map(upd, params, grads, opt_state["m"],
                                 opt_state["v"])
    treedef = jax.tree_util.tree_structure(params)
    flat = jax.tree_util.tree_leaves(out, is_leaf=lambda x: isinstance(x, tuple))
    new_p = jax.tree_util.tree_unflatten(treedef, [t[0] for t in flat])
    new_m = jax.tree_util.tree_unflatten(treedef, [t[1] for t in flat])
    new_v = jax.tree_util.tree_unflatten(treedef, [t[2] for t in flat])
    return new_p, {"m": new_m, "v": new_v, "count": count}, gn


def cosine_schedule(step, total_steps: int, warmup: int = 100,
                    min_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
    prog = jnp.clip((step - warmup) / jnp.maximum(total_steps - warmup, 1),
                    0.0, 1.0)
    cos = min_frac + (1 - min_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return warm * cos

"""Optimizers (no external deps): AdamW with bf16 moments + schedules."""

from .adamw import AdamWConfig, adamw_init, adamw_update, cosine_schedule

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule"]

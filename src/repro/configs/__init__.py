"""Assigned-architecture configs (public-literature pool, DESIGN.md §5).

Each module defines FULL (the exact assigned config) and REDUCED (a smoke
variant of the same family: <=2 scan units, d_model<=512, <=4 experts).
"""

import importlib
from typing import List

from repro.models import ModelConfig

ARCHS: List[str] = [
    "deepseek_7b", "starcoder2_15b", "olmoe_1b_7b", "xlstm_1_3b",
    "qwen2_vl_7b", "recurrentgemma_2b", "phi3_5_moe", "llama3_8b",
    "minitron_8b", "musicgen_medium",
]

# canonical CLI ids (--arch <id>) -> module name
ALIASES = {
    "deepseek-7b": "deepseek_7b",
    "starcoder2-15b": "starcoder2_15b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "xlstm-1.3b": "xlstm_1_3b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe",
    "phi3.5-moe": "phi3_5_moe",
    "llama3-8b": "llama3_8b",
    "minitron-8b": "minitron_8b",
    "musicgen-medium": "musicgen_medium",
}


def get_config(name: str, variant: str = "full") -> ModelConfig:
    mod_name = ALIASES.get(name, name.replace("-", "_").replace(".", "_"))
    if mod_name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.FULL if variant == "full" else mod.REDUCED


def all_archs() -> List[str]:
    return list(ARCHS)

"""RecurrentGemma-2B — Griffin: RG-LRU + local attention, 1 attn : 2 rec,
MQA (kv=1), head_dim 256, GeGLU d_ff=7680, local window 2048
[arXiv:2402.19427]. 26L = (rec,rec,attn) x 8 + (rec,rec)."""
from repro.models import ModelConfig

_PATTERN = ("rglru", "rglru", "attn_local") * 8 + ("rglru", "rglru")

FULL = ModelConfig(
    name="recurrentgemma-2b", family="hybrid", n_layers=26, d_model=2560,
    n_heads=10, n_kv_heads=1, d_ff=7680, vocab_size=256000,
    rope_theta=10000.0, ffn_kind="geglu", pattern=_PATTERN,
    local_window=2048, conv_width=4, lru_dim=2560)

REDUCED = ModelConfig(
    name="recurrentgemma-2b-reduced", family="hybrid", n_layers=3,
    d_model=256, n_heads=2, n_kv_heads=1, d_ff=512, vocab_size=512,
    rope_theta=10000.0, ffn_kind="geglu",
    pattern=("rglru", "rglru", "attn_local"),
    local_window=16, conv_width=4, lru_dim=256, attn_impl="ref", remat=False)

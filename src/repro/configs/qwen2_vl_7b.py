"""Qwen2-VL-7B — VLM backbone with M-RoPE, GQA kv=4, dynamic resolution
[arXiv:2409.12191]. Vision encoder (ViT) is a sanctioned stub: the batch
carries precomputed patch embeddings (DESIGN.md §5)."""
from repro.models import ModelConfig

FULL = ModelConfig(
    name="qwen2-vl-7b", family="vlm", n_layers=28, d_model=3584,
    n_heads=28, n_kv_heads=4, d_ff=18944, vocab_size=152064,
    rope_theta=1000000.0, ffn_kind="swiglu",
    mrope_sections=(16, 24, 24), n_media_tokens=256)

REDUCED = ModelConfig(
    name="qwen2-vl-7b-reduced", family="vlm", n_layers=2, d_model=256,
    n_heads=4, n_kv_heads=2, d_ff=512, vocab_size=512,
    rope_theta=1000000.0, ffn_kind="swiglu",
    mrope_sections=(8, 12, 12), n_media_tokens=8, attn_impl="ref",
    remat=False)

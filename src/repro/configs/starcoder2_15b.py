"""StarCoder2-15B — dense, GQA kv=4, RoPE, native 4k sliding window
[arXiv:2402.19173]."""
from repro.models import ModelConfig

FULL = ModelConfig(
    name="starcoder2-15b", family="dense", n_layers=40, d_model=6144,
    n_heads=48, n_kv_heads=4, d_ff=24576, vocab_size=49152,
    rope_theta=100000.0, ffn_kind="gelu", window=4096)

REDUCED = ModelConfig(
    name="starcoder2-15b-reduced", family="dense", n_layers=2, d_model=256,
    n_heads=8, n_kv_heads=2, d_ff=512, vocab_size=512,
    rope_theta=100000.0, ffn_kind="gelu", window=16, attn_impl="ref",
    remat=False)

"""MusicGen-medium — decoder-only over EnCodec tokens, 4 codebooks with
delay pattern, text conditioning as prefix embeddings (stub frontend)
[arXiv:2306.05284]."""
from repro.models import ModelConfig

FULL = ModelConfig(
    name="musicgen-medium", family="audio", n_layers=48, d_model=1536,
    n_heads=24, n_kv_heads=24, d_ff=6144, vocab_size=2048,
    rope_theta=10000.0, ffn_kind="gelu", n_codebooks=4, n_cond_tokens=64)

REDUCED = ModelConfig(
    name="musicgen-medium-reduced", family="audio", n_layers=2, d_model=256,
    n_heads=8, n_kv_heads=8, d_ff=512, vocab_size=128,
    rope_theta=10000.0, ffn_kind="gelu", n_codebooks=4, n_cond_tokens=8,
    attn_impl="ref", remat=False)

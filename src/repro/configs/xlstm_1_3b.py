"""xLSTM-1.3B — sLSTM + mLSTM blocks, ratio 7:1 per the paper's xLSTM[7:1]
[arXiv:2405.04517]. d_ff=0: blocks carry their own projections."""
from repro.models import ModelConfig

# 48 layers = 6 x (7 mLSTM + 1 sLSTM)
_PATTERN = (("mlstm",) * 7 + ("slstm",)) * 6

# mlstm_impl="parallel": training uses the quadratic parallel form (exactly
# equivalent to the recurrent scan -- tests/test_parallel_forms.py). Backprop
# through a 4096-step materialized-state scan checkpoints every step's
# (B,H,hd,hd) matrix memory: measured 23 TB/device temp in the dry-run
# (EXPERIMENTS.md #Perf B0). Decode always uses the O(1)-state recurrent cell.
FULL = ModelConfig(
    name="xlstm-1.3b", family="ssm", n_layers=48, d_model=2048,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=50304,
    pattern=_PATTERN, mlstm_proj_factor=2.0, mlstm_impl="parallel")

REDUCED = ModelConfig(
    name="xlstm-1.3b-reduced", family="ssm", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=512,
    pattern=("mlstm", "slstm"), mlstm_proj_factor=2.0, remat=False)

"""OLMoE-1B-7B — MoE, 64 experts top-8, per-expert d_ff=1024
[arXiv:2409.02060]."""
from repro.models import ModelConfig

FULL = ModelConfig(
    name="olmoe-1b-7b", family="moe", n_layers=16, d_model=2048,
    n_heads=16, n_kv_heads=16, d_ff=1024, vocab_size=50304,
    n_experts=64, top_k=8, capacity_factor=1.25,
    rope_theta=10000.0, ffn_kind="swiglu")

REDUCED = ModelConfig(
    name="olmoe-1b-7b-reduced", family="moe", n_layers=2, d_model=256,
    n_heads=8, n_kv_heads=8, d_ff=128, vocab_size=512,
    n_experts=4, top_k=2, capacity_factor=1.25,
    rope_theta=10000.0, ffn_kind="swiglu", attn_impl="ref", remat=False)

"""Llama-3-8B — dense, GQA kv=8, 128k vocab [arXiv:2407.21783]."""
from repro.models import ModelConfig

FULL = ModelConfig(
    name="llama3-8b", family="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=14336, vocab_size=128256,
    rope_theta=500000.0, ffn_kind="swiglu")

REDUCED = ModelConfig(
    name="llama3-8b-reduced", family="dense", n_layers=2, d_model=256,
    n_heads=8, n_kv_heads=2, d_ff=512, vocab_size=512,
    rope_theta=500000.0, ffn_kind="swiglu", attn_impl="ref", remat=False)

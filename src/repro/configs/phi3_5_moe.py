"""Phi-3.5-MoE (42B total / 6.6B active) — 16 experts top-2
[hf:microsoft/Phi-3.5-MoE-instruct]."""
from repro.models import ModelConfig

FULL = ModelConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=6400, vocab_size=32064,
    n_experts=16, top_k=2, capacity_factor=1.25,
    rope_theta=10000.0, ffn_kind="swiglu")

REDUCED = ModelConfig(
    name="phi3.5-moe-reduced", family="moe", n_layers=2, d_model=256,
    n_heads=8, n_kv_heads=2, d_ff=256, vocab_size=512,
    n_experts=4, top_k=2, capacity_factor=1.25,
    rope_theta=10000.0, ffn_kind="swiglu", attn_impl="ref", remat=False)

"""DeepSeek-LLM 7B — dense llama-arch, MHA (kv=heads) [arXiv:2401.02954]."""
from repro.models import ModelConfig

FULL = ModelConfig(
    name="deepseek-7b", family="dense", n_layers=30, d_model=4096,
    n_heads=32, n_kv_heads=32, d_ff=11008, vocab_size=102400,
    rope_theta=10000.0, ffn_kind="swiglu")

REDUCED = ModelConfig(
    name="deepseek-7b-reduced", family="dense", n_layers=2, d_model=256,
    n_heads=8, n_kv_heads=8, d_ff=512, vocab_size=512,
    rope_theta=10000.0, ffn_kind="swiglu", attn_impl="ref", remat=False)

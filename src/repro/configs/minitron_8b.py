"""Minitron-8B — width/depth-pruned Nemotron-4, GQA kv=8, 256k vocab
[arXiv:2407.14679]."""
from repro.models import ModelConfig

FULL = ModelConfig(
    name="minitron-8b", family="dense", n_layers=32, d_model=4096,
    n_heads=32, n_kv_heads=8, d_ff=16384, vocab_size=256000,
    rope_theta=10000.0, ffn_kind="swiglu")

REDUCED = ModelConfig(
    name="minitron-8b-reduced", family="dense", n_layers=2, d_model=256,
    n_heads=8, n_kv_heads=2, d_ff=512, vocab_size=512,
    rope_theta=10000.0, ffn_kind="swiglu", attn_impl="ref", remat=False)

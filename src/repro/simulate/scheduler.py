"""Vectorized event engine: batched Poisson wake-ups + network conditions.

The asynchronous model of the paper (§3.2, §4.2) is a Poisson clock per
agent; conditioned on a tick, the waking agent is drawn proportionally to
its rate.  The scheduler exploits that: one scan step draws a *batch* of B
wake-ups (a superposition of B exponential arrivals) and the engine applies
them together — collisions (two events touching the same agent in one batch)
are deterministic because all communication scatters land before any model
update reads (repro.simulate.engines).

Pluggable network conditions, all vectorized per event:

  drop_prob      — iid per *direction* message loss
  stale_prob     — delayed delivery: the receiver gets the sender's model
                   from the previous round (one-round staleness). Drawn per
                   *sender agent* per round — a lagging link lags for the
                   whole round — so duplicate events in a batch carry
                   identical payloads (deterministic scatter collisions)
  straggler_frac / straggler_factor
                 — a random fraction of agents wakes at ``factor`` x the
                   base rate (heavy-tailed activity)
  churn_rate     — per-round probability an agent toggles active/inactive;
                   inactive agents neither wake nor accept messages
  partition      — during rounds [partition_start, partition_end) every
                   message crossing the topology's two halves is dropped,
                   then the network heals

DJAM (arXiv:1803.09737) and Zantedeschi et al. (arXiv:1901.08460) analyze
exactly this regime: asynchronous personal-model updates under random
wake-ups with per-agent communication bounded by neighborhood size.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class NetworkConditions:
    """Static (trace-time) fault model. All fields are plain python floats —
    the jitted round function closes over them as compile-time constants."""

    drop_prob: float = 0.0
    stale_prob: float = 0.0
    straggler_frac: float = 0.0
    straggler_factor: float = 0.1
    churn_rate: float = 0.0
    partition_start: int = -1     # round index; -1 = never partition
    partition_end: int = -1

    @property
    def has_partition(self) -> bool:
        """Whether a partition window [start, end) is configured."""
        return 0 <= self.partition_start < self.partition_end


class EventBatch(NamedTuple):
    """One round of wake-up events (all arrays (B,))."""

    i: jnp.ndarray            # waking agent
    s: jnp.ndarray            # chosen neighbor slot in i's row
    j: jnp.ndarray            # neighbor id  = nbr_idx[i, s]
    r: jnp.ndarray            # reverse slot = rev_slot[i, s]
    deliver_ij: jnp.ndarray   # bool: i's model reached j
    deliver_ji: jnp.ndarray   # bool: j's model reached i
    stale_ij: jnp.ndarray     # bool: delivered value is one round old
    stale_ji: jnp.ndarray
    valid: jnp.ndarray        # bool: a real wake-up (False for draws made
                              # with every agent churned out, or for a
                              # degree-0 waker) — excluded from the
                              # delivered/dropped accounting entirely
    cut: jnp.ndarray          # bool: the pair straddled an active partition
                              # window (both directions lost to the cut)
    dead: jnp.ndarray         # bool: an endpoint was churned out (both
                              # directions lost to churn unless cut first)


def straggler_rates(key, cond: NetworkConditions, n: int) -> jnp.ndarray:
    """Per-agent base wake rates: 1.0, or straggler_factor for stragglers.

    Non-uniform rates generalize the paper's unit-rate Poisson clocks
    (§3.2): conditioned on a tick, the waking agent is categorical in the
    rates, which is exactly what :func:`draw_wakeups` samples.
    """
    if cond.straggler_frac <= 0.0:
        return jnp.ones((n,), jnp.float32)
    mask = jax.random.bernoulli(key, cond.straggler_frac, (n,))
    return jnp.where(mask, jnp.float32(cond.straggler_factor), 1.0)


def draw_wakeups(key, weights, batch: int):
    """B wake-ups ~ categorical(weights) via inverse-cdf (O(n + B log n)).

    Returns ``(i, alive)``: the (B,) agent draws and a scalar bool that is
    False when the weight vector is all zero (e.g. every agent churned
    out).  In that degenerate case searchsorted lands past the end of the
    flat cdf and the clip would deterministically select agent n-1; callers
    must treat the whole batch as never-valid instead of charging those
    phantom events to an arbitrary agent.
    """
    n = weights.shape[0]
    cdf = jnp.cumsum(weights)
    alive = cdf[-1] > 0
    total = jnp.maximum(cdf[-1], 1e-30)
    u = jax.random.uniform(key, (batch,)) * total
    i = jnp.searchsorted(cdf, u, side="right")
    return jnp.clip(i, 0, n - 1).astype(jnp.int32), alive


def draw_slots(key, i, deg_count) -> jnp.ndarray:
    """Uniform neighbor slot per event (pi_i uniform over N_i — the
    neighbor-selection distribution of paper §3.2, also used for the §4.2
    edge wake-ups; the joint engines keep it frozen over the *candidate*
    slots so learned weights never perturb the event process,
    DESIGN.md §13).

    Degree-0 wakers are clamped to slot 0 instead of ``deg - 1 = -1`` (the
    negative index would wrap into the last pad slot and fabricate a
    phantom edge); ``draw_events`` marks such events invalid.
    """
    u = jax.random.uniform(key, i.shape)
    deg = deg_count[i].astype(jnp.float32)
    s = jnp.minimum((u * deg).astype(jnp.int32), deg_count[i] - 1)
    return jnp.maximum(s, 0)


def draw_events(key, cond: NetworkConditions, tabs, part_half, active,
                rates, t, batch: int) -> EventBatch:
    """Sample one round's EventBatch under the network conditions.

    tabs: DeviceTables; part_half: (n,) bool; active: (n,) bool;
    rates: (n,) f32 base rates; t: scalar round index.
    """
    kw, ks, k1, k2, k3, k4 = jax.random.split(key, 6)
    i, alive = draw_wakeups(kw, rates * active.astype(jnp.float32), batch)
    s = draw_slots(ks, i, tabs.deg_count)
    j = tabs.nbr_idx[i, s]
    r = tabs.rev_slot[i, s]

    B = i.shape[0]
    # never-valid events: the all-dead draw, or an isolated (degree-0)
    # waker — these are artifacts of the sampler, not lost messages
    valid = alive & (tabs.deg_count[i] > 0)
    ok = valid
    if cond.drop_prob > 0.0:
        drop_ij = jax.random.bernoulli(k1, cond.drop_prob, (B,))
        drop_ji = jax.random.bernoulli(k2, cond.drop_prob, (B,))
    else:
        drop_ij = drop_ji = jnp.zeros((B,), bool)
    if cond.has_partition:
        in_window = (t >= cond.partition_start) & (t < cond.partition_end)
        cut = in_window & (part_half[i] != part_half[j])
        ok &= ~cut
    else:
        cut = jnp.zeros((B,), bool)
    # an inactive endpoint kills both directions (i inactive can't happen
    # through the wake draw unless everyone is inactive; guard anyway)
    dead = ~(active[i] & active[j])
    ok &= ~dead
    if cond.stale_prob > 0.0:
        # per-sender-per-round draw: identical payload for duplicate events
        n = tabs.deg_count.shape[0]
        lagging = jax.random.bernoulli(k3, cond.stale_prob, (n,))
        stale_ij = lagging[i]
        stale_ji = lagging[j]
    else:
        stale_ij = stale_ji = jnp.zeros((B,), bool)
    return EventBatch(i, s, j, r, ok & ~drop_ij, ok & ~drop_ji,
                      stale_ij, stale_ji, valid, cut, dead)


def churn_step(key, cond: NetworkConditions, active) -> jnp.ndarray:
    """Toggle agents in/out of the network with prob churn_rate per round."""
    if cond.churn_rate <= 0.0:
        return active
    toggle = jax.random.bernoulli(key, cond.churn_rate, active.shape)
    return jnp.where(toggle, ~active, active)


class EventStream(NamedTuple):
    """A full scenario's wake-up events, materialized up front.

    The fault process (wake-ups, drops, staleness, churn) never reads model
    state, so it can be drawn once on one device and replayed by every
    shard of the partitioned engine — each shard then does zero O(n)
    sampling work per round.  All arrays are (rounds, B) except
    ``active_frac`` (rounds,), the live-agent fraction after each round's
    churn.  Field semantics match :class:`EventBatch` (whose fields must
    stay a prefix of this tuple — ``_draw_stream`` splats one into the
    other).
    """

    i: jnp.ndarray
    s: jnp.ndarray
    j: jnp.ndarray
    r: jnp.ndarray
    deliver_ij: jnp.ndarray
    deliver_ji: jnp.ndarray
    stale_ij: jnp.ndarray
    stale_ji: jnp.ndarray
    valid: jnp.ndarray
    cut: jnp.ndarray
    dead: jnp.ndarray
    active_frac: jnp.ndarray


def stream_totals(stream: EventStream) -> tuple:
    """(delivered, dropped, invalid) accounting of a materialized stream.

    Never-valid events (all-dead draws, degree-0 wakers) are excluded from
    both delivered and dropped, so for every stream

        delivered + dropped == 2 * (events - invalid).
    """
    d_ij = np.asarray(stream.deliver_ij)
    d_ji = np.asarray(stream.deliver_ji)
    valid = np.asarray(stream.valid)
    delivered = int(d_ij.sum() + d_ji.sum())
    dropped = int((valid & ~d_ij).sum() + (valid & ~d_ji).sum())
    return delivered, dropped, int((~valid).sum())


class ServeStream(NamedTuple):
    """A scenario's inference requests, materialized up front.

    The second event stream of the personalization service (DESIGN.md
    §16): request ``q`` asks for user ``user[q]``'s current personalized
    model during round ``round[q]``.  Requests are *reads* — they never
    touch model state, RNG, or the gossip event schedule — so the stream
    is drawn host-side from its own generator, entirely independent of
    :func:`precompute_event_stream`'s key schedule: a run with a serve
    stream replays the bit-identical gossip trajectory of the serve-free
    run (the acceptance property tests/test_serve_collab.py holds).

    ``round`` is sorted ascending; a request in round t is served from the
    first committed state snapshot covering t (the record chunk it falls
    in — ``chunk_of_round``), the read/write-split granularity at which
    the jitted scan publishes state (``repro.serve.store``).
    """

    user: np.ndarray     # (R,) int32 requested agent/user id
    round: np.ndarray    # (R,) int32 arrival round, sorted ascending

    @property
    def n_requests(self) -> int:
        """Total request count R."""
        return int(self.user.shape[0])


def precompute_serve_stream(n: int, rounds: int, rate: float,
                            seed: int = 0) -> ServeStream:
    """Draw ``rate`` requests/round for ``rounds`` rounds over ``n`` users.

    Uniform arrival rounds (sorted) and uniform users, from a dedicated
    ``numpy`` generator — deliberately not jax PRNG, so no accidental
    coupling with the gossip key schedule is even possible.  ``rate`` may
    be fractional; the total request count is ``round(rate * rounds)``.
    """
    if rounds < 1:
        raise ValueError(f"rounds must be >= 1, got {rounds}")
    n_req = int(round(rate * rounds))
    rng = np.random.default_rng(seed)
    t = np.sort(rng.integers(0, rounds, size=n_req)).astype(np.int32)
    user = rng.integers(0, n, size=n_req).astype(np.int32)
    return ServeStream(user=user, round=t)


def serve_chunk_requests(serve: ServeStream, n_rec: int,
                         record_every: int) -> list:
    """Split a ServeStream into per-record-chunk (user, round) slices.

    Chunk ``ci`` covers rounds ``[ci * record_every, (ci+1) * record_every)``
    — exactly the rounds whose updates the engines commit in snapshot
    ``ci`` — so every request is served from the snapshot of its own
    chunk: it observes all deliveries of that chunk (post-update
    visibility) and none of any later round.  Requests beyond the clamped
    horizon (``record_chunks`` floors it) are dropped.  Returns a list of
    ``n_rec`` (user, round) int32 array pairs.
    """
    edges = np.searchsorted(serve.round,
                            np.arange(n_rec + 1) * record_every)
    return [(serve.user[edges[ci]:edges[ci + 1]],
             serve.round[edges[ci]:edges[ci + 1]])
            for ci in range(n_rec)]


@partial(jax.jit, static_argnames=("conditions", "batch", "rounds"))
def _draw_stream(tabs, part_half, rates, keys, *,
                 conditions: NetworkConditions, batch: int, rounds: int):
    n = tabs.deg_count.shape[0]

    def step(active, inp):
        t, key = inp
        k_ev, k_churn = jax.random.split(key)
        ev = draw_events(k_ev, conditions, tabs, part_half, active, rates,
                         t, batch)
        active = churn_step(k_churn, conditions, active)
        frac = jnp.mean(active.astype(jnp.float32))
        return active, (ev, frac)

    ts = jnp.arange(rounds, dtype=jnp.int32)
    _, (evs, fracs) = jax.lax.scan(step, jnp.ones((n,), bool), (ts, keys))
    return EventStream(*evs, fracs)


def precompute_event_stream(tabs, part_half, conditions: NetworkConditions,
                            batch: int, seed: int, rounds: int) -> EventStream:
    """Draw the whole scenario's events with ``run_mp_scenario``'s exact key
    schedule (PRNGKey(seed) -> straggler split -> one key per round), so a
    replayed stream reproduces the inline engine's trajectory bit-for-bit.
    """
    key = jax.random.PRNGKey(seed)
    key, k_strag = jax.random.split(key)
    n = tabs.deg_count.shape[0]
    rates = straggler_rates(k_strag, conditions, n)
    keys = jax.random.split(key, rounds)
    return _draw_stream(tabs, part_half, rates, keys, conditions=conditions,
                        batch=batch, rounds=rounds)

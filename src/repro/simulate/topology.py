"""Sparse graph container + large-topology generators (DESIGN.md §4).

``SparseTopology`` wraps the padded-neighbor tables of ``core.sparse`` plus a
per-agent ``groups`` labeling (cluster / spatial half) used by the partition
scenarios.  Memory is O(n * k_max) end to end: the generators below build
adjacency *lists* directly and never materialize an n x n matrix, so
n = 10k-50k agents is routine (the dense (n, n, p) path needs n^2 * p * 4
bytes per array — 12.8 GB at n = 10k, p = 32, and the ADMM state holds five
such arrays — where the sparse engine's whole footprint is tens of MB).

``SparseTopology.from_graph`` goes through the exact same table constructor
the dense reference engines use, which is what makes the sparse engines'
trajectories bit-for-bit reproducible against them (tests/test_simulate.py).
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

import numpy as np

from repro.core.graph import Graph
from repro.core.sparse import (DeviceTables, NeighborTables,
                               padded_neighbor_tables, tables_from_adjacency,
                               to_device)


@dataclasses.dataclass(frozen=True)
class SparseTopology:
    """Padded-neighbor topology over n agents (host-side numpy arrays)."""

    tables: NeighborTables
    groups: np.ndarray          # (n,) int32 — cluster/half labels (partitions)

    @property
    def n(self) -> int:
        """Number of agents."""
        return self.tables.n

    @property
    def k_max(self) -> int:
        """Padded neighbor-slot count (max degree)."""
        return self.tables.k_max

    @property
    def n_edges(self) -> int:
        """Number of undirected edges."""
        return int(self.tables.deg_count.sum()) // 2

    def device_tables(self) -> DeviceTables:
        """The neighbor tables as device arrays (jnp)."""
        return to_device(self.tables)

    def state_bytes(self, p: int) -> int:
        """Bytes of the sparse MP simulator state (theta + neighbor slots)."""
        n, k = self.n, self.k_max
        return 4 * (n * p + n * k * p) + 4 * 4 * n * k  # models + tables

    def dense_state_bytes(self, p: int) -> int:
        """What the dense (n, n, p) knowledge state would cost."""
        return 4 * self.n * self.n * p

    def partition_halves(self) -> np.ndarray:
        """(n,) bool — the two sides the partition scenarios cut between."""
        g = self.groups
        return g < (int(g.max()) + 1) // 2 if g.max() > 0 else \
            np.arange(self.n) < self.n // 2

    @classmethod
    def from_graph(cls, graph: Graph,
                   groups: Optional[np.ndarray] = None) -> "SparseTopology":
        """Wrap a dense ``Graph`` via the shared padded-table constructor."""
        tabs = padded_neighbor_tables(graph)
        if groups is None:
            groups = (np.arange(graph.n) * 2 >= graph.n).astype(np.int32)
        return cls(tabs, np.asarray(groups, np.int32))


def _from_pairs(n: int, src: np.ndarray, dst: np.ndarray,
                groups: np.ndarray, weight: float = 1.0) -> SparseTopology:
    """Build a SparseTopology from directed edge pairs (symmetrized, deduped)."""
    a = np.concatenate([src, dst])
    b = np.concatenate([dst, src])
    keep = a != b
    a, b = a[keep], b[keep]
    pairs = np.unique(np.stack([a, b], axis=1), axis=0)   # sorted by (a, b)
    a, b = pairs[:, 0], pairs[:, 1]
    deg = np.bincount(a, minlength=n)
    if (deg == 0).any():
        raise ValueError("generator produced an isolated agent")
    splits = np.cumsum(deg)[:-1]
    nbr_lists = np.split(b.astype(np.int64), splits)      # sorted per row
    wt_lists = [np.full(len(x), weight, np.float64) for x in nbr_lists]
    tabs = tables_from_adjacency(nbr_lists, wt_lists)
    return SparseTopology(tabs, np.asarray(groups, np.int32))


def ring_topology(n: int, weight: float = 1.0) -> SparseTopology:
    """Ring over n agents — k_max = 2, the cheapest connected topology."""
    i = np.arange(n, dtype=np.int64)
    src = np.concatenate([i, i])
    dst = np.concatenate([(i + 1) % n, (i - 1) % n])
    groups = (2 * i >= n).astype(np.int32)
    return _from_pairs(n, src, dst, groups, weight)


def random_geometric_topology(n: int, k: int = 8,
                              seed: int = 0) -> SparseTopology:
    """Symmetrized kNN graph over random 2-D positions, without an n x n
    distance matrix: points are bucketed into a coarse grid and each point's
    k nearest are searched within its 3x3 cell neighborhood (O(n * k) work).

    Groups = left/right spatial half (what a geographic partition would cut).
    """
    rng = np.random.default_rng(seed)
    pts = rng.uniform(size=(n, 2))
    g = max(1, int(np.sqrt(n / max(4 * k, 1))))
    cell = np.minimum((pts * g).astype(np.int64), g - 1)
    cid = cell[:, 0] * g + cell[:, 1]
    order = np.argsort(cid, kind="stable")
    sorted_cid = cid[order]
    starts = np.searchsorted(sorted_cid, np.arange(g * g))
    ends = np.searchsorted(sorted_cid, np.arange(g * g), side="right")

    src_all: List[np.ndarray] = []
    dst_all: List[np.ndarray] = []
    for cx in range(g):
        for cy in range(g):
            mine = order[starts[cx * g + cy]:ends[cx * g + cy]]
            if len(mine) == 0:
                continue
            cand = []
            for dx in (-1, 0, 1):
                for dy in (-1, 0, 1):
                    x, y = cx + dx, cy + dy
                    if 0 <= x < g and 0 <= y < g:
                        cand.append(order[starts[x * g + y]:ends[x * g + y]])
            cand = np.concatenate(cand)
            d2 = ((pts[mine][:, None, :] - pts[cand][None, :, :]) ** 2).sum(-1)
            d2[cand[None, :] == mine[:, None]] = np.inf  # scatter: unique targets
            kk = min(k, len(cand) - 1)
            if kk <= 0:
                # lone point in an empty neighborhood: link to nearest overall
                # cell later via ring fallback — extremely unlikely for n >> g^2
                raise ValueError("grid too coarse; lower k or raise n")
            sel = np.argpartition(d2, kk - 1, axis=1)[:, :kk]
            src_all.append(np.repeat(mine, kk))
            dst_all.append(cand[sel].ravel())
    src = np.concatenate(src_all)
    dst = np.concatenate(dst_all)
    groups = (pts[:, 0] >= 0.5).astype(np.int32)
    return _from_pairs(n, src, dst, groups)


def planted_partition_topology(n: int, n_clusters: int = 2,
                               k_intra: int = 6, k_inter: int = 2,
                               seed: int = 0) -> SparseTopology:
    """Planted-partition candidate graph for joint graph learning
    (DESIGN.md §13): a ring inside each cluster (connectivity), ``k_intra``
    random same-cluster links per agent, and ``k_inter`` random
    *other*-cluster links per agent — the noise edges a graph learner
    should drive to zero while keeping the intra-cluster ones.

    Groups = planted cluster id (``tests/test_joint.py`` and
    ``examples/joint_graph_demo.py`` score recovery against it).
    """
    rng = np.random.default_rng(seed)
    bounds = np.linspace(0, n, n_clusters + 1).astype(np.int64)
    groups = np.zeros(n, np.int32)
    src_all: List[np.ndarray] = []
    dst_all: List[np.ndarray] = []
    for ci in range(n_clusters):
        lo, hi = bounds[ci], bounds[ci + 1]
        m = hi - lo
        groups[lo:hi] = ci
        ids = np.arange(lo, hi)
        src_all.append(ids)
        dst_all.append(lo + (ids - lo + 1) % m)          # intra ring
        if m > 2 and k_intra > 0:
            partners = lo + rng.integers(0, m, size=(m, k_intra))
            src_all.append(np.repeat(ids, k_intra))
            dst_all.append(partners.ravel())
        if n_clusters > 1 and k_inter > 0:
            # k_inter links per agent into the other clusters
            others = np.concatenate([np.arange(bounds[cj], bounds[cj + 1])
                                     for cj in range(n_clusters) if cj != ci])
            partners = rng.choice(others, size=(m, k_inter))
            src_all.append(np.repeat(ids, k_inter))
            dst_all.append(partners.ravel())
    return _from_pairs(n, np.concatenate(src_all), np.concatenate(dst_all),
                       groups)


def cluster_topology(n: int, n_clusters: int = 8, k_intra: int = 6,
                     bridges: int = 4, seed: int = 0) -> SparseTopology:
    """Clustered small-world topology: a ring inside each cluster (guarantees
    no isolated agent), k_intra random intra-cluster links per agent, and
    ``bridges`` random links between consecutive clusters.

    Groups = cluster id — partition scenarios cut between the cluster halves.
    """
    rng = np.random.default_rng(seed)
    bounds = np.linspace(0, n, n_clusters + 1).astype(np.int64)
    groups = np.zeros(n, np.int32)
    src_all: List[np.ndarray] = []
    dst_all: List[np.ndarray] = []
    for ci in range(n_clusters):
        lo, hi = bounds[ci], bounds[ci + 1]
        m = hi - lo
        groups[lo:hi] = ci
        ids = np.arange(lo, hi)
        # intra-cluster ring
        src_all.append(ids)
        dst_all.append(lo + (ids - lo + 1) % m)
        if m > 2 and k_intra > 0:
            partners = lo + rng.integers(0, m, size=(m, k_intra))
            src_all.append(np.repeat(ids, k_intra))
            dst_all.append(partners.ravel())
        # bridges to the next cluster (ring of clusters)
        nxt = (ci + 1) % n_clusters
        nlo, nhi = bounds[nxt], bounds[nxt + 1]
        nb = max(1, min(bridges, m, nhi - nlo))
        src_all.append(rng.integers(lo, hi, size=nb))
        dst_all.append(rng.integers(nlo, nhi, size=nb))
    src = np.concatenate(src_all)
    dst = np.concatenate(dst_all)
    return _from_pairs(n, src, dst, groups)

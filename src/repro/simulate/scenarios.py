"""Named fault scenarios for the event-driven simulator (DESIGN.md §4).

A Scenario is a NetworkConditions factory plus provenance: some conditions
(partition windows) depend on the run length, so ``make_conditions(rounds)``
resolves them per run.  Consumed by benchmarks/bench_network_sim.py, the
examples, and the fault-tolerance tests.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List

from .scheduler import NetworkConditions


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named fault profile: rounds -> NetworkConditions factory."""

    name: str
    description: str
    make_conditions: Callable[[int], NetworkConditions]


def _clean(rounds: int) -> NetworkConditions:
    return NetworkConditions()


def _lossy10(rounds: int) -> NetworkConditions:
    return NetworkConditions(drop_prob=0.10, stale_prob=0.05)


def _straggler_tail(rounds: int) -> NetworkConditions:
    return NetworkConditions(straggler_frac=0.2, straggler_factor=0.05,
                             stale_prob=0.10)


def _churn5(rounds: int) -> NetworkConditions:
    # ~5% of agents toggling over a 100-round horizon
    return NetworkConditions(churn_rate=0.05 / 100.0)


def _partition_heal(rounds: int) -> NetworkConditions:
    return NetworkConditions(partition_start=rounds // 3,
                             partition_end=2 * rounds // 3)


SCENARIOS: Dict[str, Scenario] = {
    s.name: s for s in [
        Scenario("clean", "no faults — pure asynchronous gossip", _clean),
        Scenario("lossy-10", "10% iid message loss + 5% stale deliveries",
                 _lossy10),
        Scenario("straggler-tail",
                 "20% of agents wake at 1/20 the base rate, 10% staleness",
                 _straggler_tail),
        Scenario("churn-5", "agents join/leave (~5% churn per 100 rounds)",
                 _churn5),
        Scenario("partition-heal",
                 "network splits in half for the middle third, then heals",
                 _partition_heal),
    ]
}


def get_scenario(name: str) -> Scenario:
    """Look up a scenario by name (KeyError lists the registry)."""
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"available: {sorted(SCENARIOS)}") from None


def list_scenarios() -> List[str]:
    """Sorted names of the registered fault scenarios."""
    return sorted(SCENARIOS)

"""Sparse event-driven P2P network simulator (DESIGN.md §4).

Replaces the dense (n, n, p) knowledge state of the reference engines with
padded-neighbor storage — O(n * k * p) — so 10k-50k agent experiments are
routine, and adds a vectorized fault-injecting event scheduler (drops,
staleness, stragglers, churn, partitions).
"""

from .topology import (SparseTopology, ring_topology,
                       random_geometric_topology, cluster_topology,
                       planted_partition_topology)
from .scheduler import (NetworkConditions, EventBatch, EventStream,
                        ServeStream, draw_wakeups, draw_slots, draw_events,
                        straggler_rates, churn_step, precompute_event_stream,
                        precompute_serve_stream, serve_chunk_requests,
                        stream_totals)
from .engines import (SparseTrace, SimTrace, CLSimTrace, JointSimTrace,
                      SparseADMMState, SparseCLTrace, sparse_async_gossip,
                      sparse_sync_mp, sparse_async_admm, init_sparse_admm)
from .partition import (GraphPartition, ShardedSimTrace, JointShardedTrace,
                        greedy_partition, block_partition, edge_cut,
                        default_local_batch, default_local_events)
# the unified scenario API; the six run_* names resolve to spec.py's
# deprecated wrappers (the undeprecated implementations stay importable as
# repro.simulate.engines.run_mp_scenario etc.)
from .spec import (ScenarioSpec, run_scenario, run_mp_scenario,
                   run_cl_scenario, run_joint_scenario,
                   run_mp_scenario_sharded, run_cl_scenario_sharded,
                   run_joint_scenario_sharded)
from repro.launch.sim_mesh import HaloCodec, resolve_halo_codec
from .scenarios import Scenario, SCENARIOS, get_scenario, list_scenarios

__all__ = [n for n in dir() if not n.startswith("_")]

"""Unified scenario API: one frozen spec, one entry point (DESIGN.md §16).

``run_scenario(ScenarioSpec(...))`` replaces the six historical entry
points (``run_{mp,cl,joint}_scenario`` and their ``_sharded`` twins),
which survive as thin deprecated wrappers that build a spec and dispatch
— bit-for-bit equivalent by construction (tests/test_scenario_api.py
asserts it for every algo x sharding cell).

The spec also carries the one capability the legacy signatures never
had: an optional *inference-request stream* (``serve``).  When set, the
driver runs the personalization service against the scan's committed
record-chunk snapshots — the read/write split of ``repro.serve.store``:
the jitted gossip scan is the sole writer, requests read immutable
committed state, so serving cannot perturb the trajectory and
``trace.theta_hist`` is bit-for-bit identical to the serve-free run.
"""

from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Optional

import jax.numpy as jnp
import numpy as np

from repro.core.sparse import record_chunks
from repro.telemetry.metrics import (stream_dirty_chunks,
                                     stream_staleness_chunks)

from . import engines as _engines
from . import partition as _partition
from .scheduler import (EventStream, NetworkConditions, ServeStream,
                        precompute_event_stream, serve_chunk_requests)

_ALGOS = ("mp", "cl", "joint")


@dataclasses.dataclass(frozen=True, eq=False)
class ScenarioSpec:
    """Everything that defines one collaborative-learning scenario run.

    Frozen (build once, ``dataclasses.replace`` for sweeps; ``eq=False``
    because ndarray payloads aren't hashable).  Field groups:

    core:     algo ("mp" | "cl" | "joint"), topology, conditions, rounds,
              batch, seed, record_every
    mp/joint: theta_sol (pure targets), c (confidence), alpha (Eq. 3 mix)
    cl:       data (AgentData), mu, rho (Eq. 7 / ADMM), state (warm ADMM
              state; single-device only), theta_sol (warm start), primal
              (PrimalSolver strategy — ``core.primal``; None = the exact
              closed-form quadratic solve)
    joint:    eta_graph, lam, graph_every, prune_eps (DESIGN.md §13)
    events:   stream — precomputed EventStream override (cl/joint; the mp
              engine draws inline by the identical RNG schedule and
              rejects an override)
    exec:     backend (fused round_step), telemetry (TelemetryConfig)
    sharding: sharded plus the partitioned-runner knobs (n_shards, mesh,
              assignment, local_batch, exchange, halo_codec,
              partition_seed, recompact_every/frac — joint only)
    serving:  serve (ServeStream of inference requests interleaved with
              the gossip rounds), serve_batch (decode batch width)
    """

    algo: str
    topology: Any
    conditions: NetworkConditions
    rounds: int
    batch: int
    seed: int = 0
    record_every: int = 10
    # mp / joint payload
    theta_sol: Any = None
    c: Any = None
    alpha: float = 0.5
    # cl payload
    data: Any = None
    mu: Optional[float] = None
    rho: Optional[float] = None
    state: Any = None
    primal: Any = None
    # joint graph-learning knobs
    eta_graph: float = 0.0
    lam: float = 1.0
    graph_every: int = 1
    prune_eps: Optional[float] = None
    # event stream / execution
    stream: Optional[EventStream] = None
    backend: Any = None
    telemetry: Any = None
    # sharding
    sharded: bool = False
    n_shards: Optional[int] = None
    mesh: Any = None
    assignment: Any = None
    local_batch: Optional[int] = None
    exchange: str = "all_gather"
    halo_codec: Any = "f32"
    partition_seed: int = 0
    recompact_every: Optional[int] = None
    recompact_frac: float = 0.25
    # serving
    serve: Optional[ServeStream] = None
    serve_batch: int = 256

    def __post_init__(self):
        if self.algo not in _ALGOS:
            raise ValueError(f"unknown algo {self.algo!r}; one of {_ALGOS}")
        if self.algo == "mp" and self.stream is not None:
            raise ValueError(
                "algo='mp' draws its event stream inline (identical RNG "
                "schedule); a stream override is only supported for "
                "'cl'/'joint'")
        if self.primal is not None and self.algo != "cl":
            raise ValueError(
                "primal solvers plug into the CL-ADMM engines only "
                "(algo='cl')")

    def _require(self, **fields):
        for name, val in fields.items():
            if val is None:
                raise ValueError(
                    f"algo={self.algo!r} requires ScenarioSpec.{name}")


def run_scenario(spec: ScenarioSpec):
    """Run the scenario a :class:`ScenarioSpec` describes.

    Dispatches to the algo's engine (single-device or partitioned), then
    — if the spec carries a ``serve`` stream — drives the personalization
    service over the committed snapshots and attaches the resulting
    ``ServeReport`` as ``trace.serve`` (plus the cumulative serve
    counters on ``trace.telemetry`` when telemetry is enabled).  Returns
    the engine's trace type unchanged otherwise.
    """
    common = dict(conditions=spec.conditions, rounds=spec.rounds,
                  batch=spec.batch, seed=spec.seed,
                  record_every=spec.record_every, telemetry=spec.telemetry)
    shard_kw = dict(n_shards=spec.n_shards, mesh=spec.mesh,
                    assignment=spec.assignment, local_batch=spec.local_batch,
                    exchange=spec.exchange, halo_codec=spec.halo_codec,
                    partition_seed=spec.partition_seed)
    if spec.sharded and spec.backend is not None and spec.algo != "joint":
        raise ValueError(
            "backend overrides apply to the single-device engines and the "
            "sharded joint runner only")
    if spec.algo == "mp":
        spec._require(theta_sol=spec.theta_sol, c=spec.c)
        if spec.sharded:
            trace = _partition.run_mp_scenario_sharded(
                spec.topology, spec.theta_sol, spec.c, spec.alpha,
                **common, **shard_kw)
        else:
            trace = _engines.run_mp_scenario(
                spec.topology, spec.theta_sol, spec.c, spec.alpha,
                backend=spec.backend, **common)
    elif spec.algo == "cl":
        spec._require(data=spec.data, mu=spec.mu, rho=spec.rho,
                      theta_sol=spec.theta_sol)
        if spec.sharded:
            if spec.state is not None:
                raise ValueError(
                    "warm ADMM state is single-device only (the sharded "
                    "runner rebuilds its own sharded state)")
            trace = _partition.run_cl_scenario_sharded(
                spec.topology, spec.data, spec.mu, spec.rho,
                theta_sol=spec.theta_sol, stream=spec.stream,
                primal=spec.primal, **common, **shard_kw)
        else:
            trace = _engines.run_cl_scenario(
                spec.topology, spec.data, spec.mu, spec.rho,
                theta_sol=spec.theta_sol, state=spec.state,
                stream=spec.stream, backend=spec.backend,
                primal=spec.primal, **common)
    else:  # joint
        spec._require(theta_sol=spec.theta_sol, c=spec.c)
        joint_kw = dict(eta_graph=spec.eta_graph, lam=spec.lam,
                        graph_every=spec.graph_every,
                        prune_eps=spec.prune_eps, stream=spec.stream,
                        backend=spec.backend)
        if spec.sharded:
            trace = _partition.run_joint_scenario_sharded(
                spec.topology, spec.theta_sol, spec.c, spec.alpha,
                recompact_every=spec.recompact_every,
                recompact_frac=spec.recompact_frac,
                **common, **shard_kw, **joint_kw)
        else:
            trace = _engines.run_joint_scenario(
                spec.topology, spec.theta_sol, spec.c, spec.alpha,
                **common, **joint_kw)
    if spec.serve is not None:
        trace = _drive_serve(spec, trace)
    return trace


def _drive_serve(spec: ScenarioSpec, trace):
    """Serve the spec's inference-request stream from the finished trace.

    The read/write split in action (DESIGN.md §16): per record chunk the
    driver *commits* the chunk's snapshot (theta rows + the host-replayed
    staleness counters) to an agent-state store, *invalidates* the mixed
    model cache at exactly the agents the chunk's deliveries rewrote, and
    *serves* every request whose arrival round falls inside the chunk
    from the committed state (post-update visibility).  Reads never touch
    the scan, so ``trace.theta_hist`` is untouched by construction.
    """
    from repro.serve import (AgentStateStore, CollabServeEngine,
                             ShardedAgentStateStore)

    topo = spec.topology
    n = topo.n
    record_every, n_rec = record_chunks(spec.rounds, spec.record_every)
    total_rounds = n_rec * record_every
    stream = spec.stream
    if stream is None:
        # the engines' own schedule (scheduler.precompute_event_stream is
        # documented to reproduce the inline draws exactly)
        stream = precompute_event_stream(
            topo.device_tables(), jnp.asarray(topo.partition_halves()),
            spec.conditions, spec.batch, spec.seed, total_rounds)
    dirty = stream_dirty_chunks(stream, n, n_rec, record_every)
    staleness = stream_staleness_chunks(stream, n, n_rec, record_every)
    requests = serve_chunk_requests(spec.serve, n_rec, record_every)

    p = int(trace.theta_hist.shape[-1])
    if spec.sharded:
        _, P_, _, part = _partition._sharded_setup(
            topo, spec.n_shards, spec.mesh, spec.assignment,
            spec.partition_seed)
        store = ShardedAgentStateStore(part.owner, part.local_pos, p, P_)
    else:
        store = AgentStateStore(n, p)
    eng = CollabServeEngine(store, n, p, batch_size=spec.serve_batch)

    counters = np.zeros((4, n_rec), np.int64)
    for ci in range(n_rec):
        eng.commit((ci + 1) * record_every, trace.theta_hist[ci],
                   staleness[ci], dirty[ci])
        users, _rounds = requests[ci]
        if users.size:
            eng.serve(users)
        counters[:, ci] = (eng.requests, eng.cache.hits, eng.cache.misses,
                           eng.cache.invalidations)
    report = eng.report(*counters)
    trace = dataclasses.replace(trace, serve=report)
    if trace.telemetry is not None:
        trace.telemetry.serve_requests = counters[0]
        trace.telemetry.serve_hits = counters[1]
        trace.telemetry.serve_misses = counters[2]
        trace.telemetry.serve_invalidations = counters[3]
    return trace


# ---------------------------------------------------------------------------
# deprecated legacy entry points (thin wrappers over run_scenario)
# ---------------------------------------------------------------------------


def _warn_legacy(old: str):
    warnings.warn(
        f"{old} is deprecated; build a ScenarioSpec and call "
        f"run_scenario(spec) instead (migration table: DESIGN.md §16)",
        DeprecationWarning, stacklevel=3)


def run_mp_scenario(topo, theta_sol, c, alpha, conditions, rounds, batch,
                    seed=0, record_every=10, telemetry=None, backend=None):
    """Deprecated wrapper: ``run_scenario(ScenarioSpec(algo="mp", ...))``."""
    _warn_legacy("run_mp_scenario")
    return run_scenario(ScenarioSpec(
        algo="mp", topology=topo, theta_sol=theta_sol, c=c, alpha=alpha,
        conditions=conditions, rounds=rounds, batch=batch, seed=seed,
        record_every=record_every, telemetry=telemetry, backend=backend))


def run_cl_scenario(topo, data, mu, rho, conditions, rounds, batch,
                    seed=0, record_every=10, theta_sol=None, state=None,
                    stream=None, backend=None, telemetry=None):
    """Deprecated wrapper: ``run_scenario(ScenarioSpec(algo="cl", ...))``."""
    _warn_legacy("run_cl_scenario")
    return run_scenario(ScenarioSpec(
        algo="cl", topology=topo, data=data, mu=mu, rho=rho,
        conditions=conditions, rounds=rounds, batch=batch, seed=seed,
        record_every=record_every, theta_sol=theta_sol, state=state,
        stream=stream, backend=backend, telemetry=telemetry))


def run_joint_scenario(topo, theta_sol, c, alpha, conditions, rounds, batch,
                       seed=0, record_every=10, *, eta_graph=0.0, lam=1.0,
                       graph_every=1, prune_eps=None, stream=None,
                       backend=None, telemetry=None):
    """Deprecated wrapper: ``run_scenario(ScenarioSpec(algo="joint", ...))``."""
    _warn_legacy("run_joint_scenario")
    return run_scenario(ScenarioSpec(
        algo="joint", topology=topo, theta_sol=theta_sol, c=c, alpha=alpha,
        conditions=conditions, rounds=rounds, batch=batch, seed=seed,
        record_every=record_every, eta_graph=eta_graph, lam=lam,
        graph_every=graph_every, prune_eps=prune_eps, stream=stream,
        backend=backend, telemetry=telemetry))


def run_mp_scenario_sharded(topo, theta_sol, c, alpha, conditions, rounds,
                            batch, seed=0, record_every=10, *,
                            n_shards=None, mesh=None, assignment=None,
                            local_batch=None, exchange="all_gather",
                            halo_codec="f32", partition_seed=0,
                            telemetry=None):
    """Deprecated wrapper: ``ScenarioSpec(algo="mp", sharded=True)``."""
    _warn_legacy("run_mp_scenario_sharded")
    return run_scenario(ScenarioSpec(
        algo="mp", topology=topo, theta_sol=theta_sol, c=c, alpha=alpha,
        conditions=conditions, rounds=rounds, batch=batch, seed=seed,
        record_every=record_every, telemetry=telemetry, sharded=True,
        n_shards=n_shards, mesh=mesh, assignment=assignment,
        local_batch=local_batch, exchange=exchange, halo_codec=halo_codec,
        partition_seed=partition_seed))


def run_cl_scenario_sharded(topo, data, mu, rho, conditions, rounds, batch,
                            seed=0, record_every=10, *, theta_sol=None,
                            n_shards=None, mesh=None, assignment=None,
                            local_batch=None, exchange="all_gather",
                            halo_codec="f32", partition_seed=0,
                            stream=None, telemetry=None):
    """Deprecated wrapper: ``ScenarioSpec(algo="cl", sharded=True)``."""
    _warn_legacy("run_cl_scenario_sharded")
    return run_scenario(ScenarioSpec(
        algo="cl", topology=topo, data=data, mu=mu, rho=rho,
        conditions=conditions, rounds=rounds, batch=batch, seed=seed,
        record_every=record_every, theta_sol=theta_sol, stream=stream,
        telemetry=telemetry, sharded=True, n_shards=n_shards, mesh=mesh,
        assignment=assignment, local_batch=local_batch, exchange=exchange,
        halo_codec=halo_codec, partition_seed=partition_seed))


def run_joint_scenario_sharded(topo, theta_sol, c, alpha, conditions,
                               rounds, batch, seed=0, record_every=10, *,
                               eta_graph=0.0, lam=1.0, graph_every=1,
                               prune_eps=None, recompact_every=None,
                               recompact_frac=0.25, n_shards=None,
                               mesh=None, assignment=None, local_batch=None,
                               exchange="all_gather", halo_codec="f32",
                               partition_seed=0, stream=None, backend=None,
                               telemetry=None):
    """Deprecated wrapper: ``ScenarioSpec(algo="joint", sharded=True)``."""
    _warn_legacy("run_joint_scenario_sharded")
    return run_scenario(ScenarioSpec(
        algo="joint", topology=topo, theta_sol=theta_sol, c=c, alpha=alpha,
        conditions=conditions, rounds=rounds, batch=batch, seed=seed,
        record_every=record_every, eta_graph=eta_graph, lam=lam,
        graph_every=graph_every, prune_eps=prune_eps,
        recompact_every=recompact_every, recompact_frac=recompact_frac,
        stream=stream, backend=backend, telemetry=telemetry, sharded=True,
        n_shards=n_shards, mesh=mesh, assignment=assignment,
        local_batch=local_batch, exchange=exchange, halo_codec=halo_codec,
        partition_seed=partition_seed))

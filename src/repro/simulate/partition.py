"""Graph-partitioned multi-device network simulation (DESIGN.md §11).

Shards the agent graph into P blocks, gives each shard padded local agent
state plus a *halo* buffer of remote-neighbor models, and runs the
event-driven MP-gossip engine under ``shard_map`` over a 1-D agent mesh
(``launch.sim_mesh``), exchanging halos between event batches.

Layout per shard (m = padded local agents, H = padded halo size)::

      theta_loc (m, p)   K_loc (m, k, p)   nbr_p_loc (m, k)  c/sol_loc
      ext = [ theta_loc | theta_halo (H, p) | 0-row ]   # message source

    fetch[q][agent] -> row of ext   (m + H = the zero row = "not here")

Between event batches each shard publishes its *boundary* rows (local
agents with a cross-shard edge, padded to B) and pulls its halo from the
gathered boundary buffers — ``all_gather`` by default, or a P-1-step
``ppermute`` ring (``exchange="ring"``).

Three properties make the sharded trajectory match the single-device
engine (``simulate.engines.run_mp_scenario``) bit-for-bit:

* the event stream is *precomputed* with the identical RNG schedule
  (``scheduler.precompute_event_stream``) and replayed by every shard —
  the fault process never reads model state, so this is exact;
* within a round, messages read round-start models; the halo refreshed at
  the top of each round IS the round-start snapshot of remote models (the
  previous round's halo serves the one-round-stale payloads);
* the per-agent update is the shared ``core.sparse.batched_model_update``
  applied to the receiver's own slot row — identical arithmetic whether
  the row lives in the global (n, k, p) state or a shard's local block.

The only approximation is the static per-shard update buffer: each round a
shard compacts its local delivery endpoints into ``local_batch`` slots
(default: mean + 8 sigma of the binomial receiver count, so overflow is
~never observed; overflowing events are counted in the trace and sized up
via ``local_batch`` if parity to the reference run is required).
"""

from __future__ import annotations

import dataclasses
import math
from collections import deque
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.core.graph_learning import prune_rows, reweight_rows
from repro.core.sparse import (admm_edge_halfstep, batched_admm_primal,
                               batched_model_update, live_slots,
                               record_chunks)
from repro.launch.sim_mesh import (AGENT_AXIS, HaloCodec, halo_exchange_fn,
                                   halo_payload_bytes, make_sim_mesh,
                                   mesh_shards, resolve_halo_codec,
                                   shard_map_1d)
from repro.telemetry import metrics as tmetrics
from repro.telemetry.config import TelemetryConfig, telemetry_on
from repro.telemetry.frames import TelemetryFrames
from .engines import (SimTrace, _reshape_stream, init_sparse_admm)
from .scheduler import (EventStream, NetworkConditions,
                        precompute_event_stream, stream_totals)
from .topology import SparseTopology


# ---------------------------------------------------------------------------
# Greedy edge-cut partitioner (linear deterministic greedy over a BFS order)
# ---------------------------------------------------------------------------


def _bfs_order(topo: SparseTopology, seed: int) -> np.ndarray:
    """Deterministic BFS visit order; the seed picks each component's root."""
    tabs = topo.tables
    n = topo.n
    rng = np.random.default_rng(seed)
    seen = np.zeros(n, bool)
    order = np.empty(n, np.int64)
    pos = 0
    start = int(rng.integers(n))
    for root in range(n):
        root = (root + start) % n
        if seen[root]:
            continue
        seen[root] = True
        q = deque([root])
        while q:
            v = q.popleft()
            order[pos] = v
            pos += 1
            for u in tabs.nbr_idx[v, :tabs.deg_count[v]]:
                if not seen[u]:
                    seen[u] = True
                    q.append(int(u))
    return order


def greedy_partition(topo: SparseTopology, n_shards: int, seed: int = 0,
                     refine_passes: int = 4) -> np.ndarray:
    """Greedy edge-cut assignment of agents to ``n_shards`` balanced shards.

    Linear deterministic greedy (Stanton & Kleinberg): visit agents in BFS
    order and put each on the shard holding most of its already-placed
    neighbors, discounted by shard fullness and hard-capped at
    ceil(n / P) agents; then ``refine_passes`` local passes move each agent
    to its majority-neighbor shard when balance allows (never increases the
    cut).  O(E) per pass; deterministic for a fixed seed (the seed only
    picks BFS roots).  Returns the (n,) int32 shard id per agent.
    """
    n = topo.n
    if n_shards <= 1:
        return np.zeros(n, np.int32)
    tabs = topo.tables
    cap = math.ceil(n / n_shards)
    assign = np.full(n, -1, np.int32)
    sizes = np.zeros(n_shards, np.int64)
    order = _bfs_order(topo, seed)
    for v in order:
        nbrs = tabs.nbr_idx[v, :tabs.deg_count[v]]
        placed = assign[nbrs]
        cnt = np.bincount(placed[placed >= 0], minlength=n_shards)
        open_ = sizes < cap
        if cnt.max(initial=0) > 0:
            score = np.where(open_, cnt * (1.0 - sizes / cap), -1.0)
        else:                       # no placed neighbor: least-loaded shard
            score = np.where(open_, -sizes.astype(np.float64), -np.inf)
        s = int(np.argmax(score))
        assign[v] = s  # scatter: unique target (scalar vertex id)
        sizes[s] += 1  # scatter: unique target (scalar shard id)
    # refinement tolerates ~6% imbalance so moves stay possible when every
    # shard sits exactly at cap (the LDG pass always ends there)
    refine_cap = cap + max(1, cap // 16)
    for _ in range(refine_passes):
        moved = False
        for v in order:
            nbrs = tabs.nbr_idx[v, :tabs.deg_count[v]]
            cnt = np.bincount(assign[nbrs], minlength=n_shards)
            cur = assign[v]
            t = int(np.argmax(cnt))
            if t != cur and cnt[t] > cnt[cur] and sizes[t] < refine_cap:
                assign[v] = t  # scatter: unique target (scalar vertex id)
                sizes[t] += 1  # scatter: unique target (scalar shard id)
                sizes[cur] -= 1  # scatter: unique target (scalar shard id)
                moved = True
        if not moved:
            break
    return assign


def block_partition(topo: SparseTopology, n_shards: int) -> np.ndarray:
    """Contiguous-id blocks — the trivial baseline the greedy cut beats."""
    m = math.ceil(topo.n / max(1, n_shards))
    return (np.arange(topo.n) // m).astype(np.int32)


def _directed_edges(tabs, live=None):
    """Directed (receiver, sender) pairs of the candidate slot tables.

    src = the row owner (the agent whose slot it is — the *receiver* of
    messages on that slot), dst = the slot's neighbor (the sender).  An
    optional (n, k_max) bool ``live`` mask restricts to surviving slots
    (joint graph learning prunes slots; DESIGN.md §13).
    """
    cand = np.arange(tabs.k_max)[None, :] < tabs.deg_count[:, None]
    if live is not None:
        cand = cand & np.asarray(live, bool)
    rows, slots = np.nonzero(cand)
    return rows.astype(np.int64), tabs.nbr_idx[rows, slots].astype(np.int64)


def edge_cut(topo: SparseTopology, assignment: np.ndarray) -> int:
    """Number of undirected edges crossing shard boundaries."""
    src, dst = _directed_edges(topo.tables)
    a = np.asarray(assignment)
    return int((a[src] != a[dst]).sum()) // 2


# ---------------------------------------------------------------------------
# Partition layout: local blocks, boundary buffers, halo fetch tables
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GraphPartition:
    """Host-side shard/halo layout of a topology (see module docstring).

    Shapes: owner/local_pos/perm_slot (n,); local_ids (P, m) with -1 pads;
    bnd_pos (P, B); halo_src_shard/halo_src_pos (P, H); fetch (P, n).
    ``fetch[q, a]`` is agent a's row in shard q's ext buffer: < m if local,
    m..m+H-1 if in q's halo, m+H (the zero row) otherwise.
    """

    n: int
    n_shards: int
    shard_size: int                 # m
    owner: np.ndarray
    local_pos: np.ndarray
    perm_slot: np.ndarray           # owner * m + local_pos
    local_ids: np.ndarray
    bnd_pos: np.ndarray
    halo_src_shard: np.ndarray
    halo_src_pos: np.ndarray
    fetch: np.ndarray
    edge_cut: int

    @property
    def halo_size(self) -> int:     # H (max over shards, 0 if no cut)
        """Per-shard halo slot count H (max over shards; 0 if no cut)."""
        return self.halo_src_shard.shape[1]

    @property
    def boundary_size(self) -> int:  # B
        """Per-shard boundary slot count B (rows other shards read)."""
        return self.bnd_pos.shape[1]

    @classmethod
    def build(cls, topo: SparseTopology, assignment: np.ndarray,
              n_shards: Optional[int] = None,
              live: Optional[np.ndarray] = None) -> "GraphPartition":
        """Shard/halo layout of ``topo`` under ``assignment``.

        ``live`` (optional, (n, k_max) bool) restricts the layout to the
        surviving directed slots of a joint graph-learning run: the halo
        of a shard then holds only the remote *senders* some local live
        slot still reads, and the boundary only the local agents some
        remote live slot still needs — the halo re-compaction the joint
        sharded engine performs when enough cross edges have been pruned
        (DESIGN.md §13).  The local block layout (owner / local_pos /
        perm_slot) depends only on ``assignment``, so re-compacted layouts
        are drop-in replacements for each other's sharded state.
        """
        tabs = topo.tables
        n = topo.n
        owner = np.asarray(assignment, np.int32)
        P_ = int(n_shards if n_shards is not None else owner.max() + 1)
        sizes = np.bincount(owner, minlength=P_)
        m = max(1, int(sizes.max()))

        by_shard = np.argsort(owner, kind="stable")      # id-sorted per shard
        local_pos = np.empty(n, np.int32)
        starts = np.concatenate([[0], np.cumsum(sizes)[:-1]])
        local_pos[by_shard] = (np.arange(n) - starts[owner[by_shard]]) \
            .astype(np.int32)  # scatter: unique targets (by_shard is a permutation)
        local_ids = np.full((P_, m), -1, np.int32)
        # scatter: unique targets ((owner, local_pos) pairs are distinct)
        local_ids[owner, local_pos] = np.arange(n, dtype=np.int32)
        perm_slot = owner.astype(np.int64) * m + local_pos

        src, dst = _directed_edges(tabs, live)
        cross = owner[src] != owner[dst]
        cut = int(cross.sum()) // 2

        # boundary: local agents some remote live slot reads (the *senders*
        # published each round), id-sorted per shard.  For the symmetric
        # live=None candidate tables this is exactly "local agents with any
        # cross edge".
        is_bnd = np.zeros(n, bool)
        is_bnd[dst[cross]] = True  # scatter: idempotent (every value is True)
        bnd_lists = [np.where(is_bnd & (owner == q))[0] for q in range(P_)]
        B = max((len(b) for b in bnd_lists), default=0)
        bnd_pos = np.zeros((P_, B), np.int32)
        bnd_rank = np.zeros(n, np.int64)
        for q, lst in enumerate(bnd_lists):
            bnd_pos[q, :len(lst)] = local_pos[lst]
            bnd_rank[lst] = np.arange(len(lst))

        # halo of q: remote endpoints of q's cross edges, id-sorted
        halo_lists = [np.unique(dst[cross & (owner[src] == q)])
                      for q in range(P_)]
        H = max((len(h) for h in halo_lists), default=0)
        halo_src_shard = np.zeros((P_, H), np.int32)
        halo_src_pos = np.zeros((P_, H), np.int32)
        fetch = np.full((P_, n), m + H, np.int32)
        fetch[owner, np.arange(n)] = local_pos  # scatter: unique targets
        for q, hl in enumerate(halo_lists):
            halo_src_shard[q, :len(hl)] = owner[hl]
            halo_src_pos[q, :len(hl)] = bnd_rank[hl]
            # scatter: unique targets (hl lists distinct halo agents)
            fetch[q, hl] = m + np.arange(len(hl), dtype=np.int32)

        return cls(n=n, n_shards=P_, shard_size=m, owner=owner,
                   local_pos=local_pos, perm_slot=perm_slot,
                   local_ids=local_ids, bnd_pos=bnd_pos,
                   halo_src_shard=halo_src_shard, halo_src_pos=halo_src_pos,
                   fetch=fetch, edge_cut=cut)

    def shard_rows(self, x: np.ndarray) -> np.ndarray:
        """Permute per-agent rows (n, ...) into the stacked padded layout
        (P * m, ...); pad rows are zero."""
        x = np.asarray(x)
        ids = self.local_ids.reshape(-1)
        out = x[np.maximum(ids, 0)]
        out[ids < 0] = 0  # scatter: unique targets (boolean mask)
        return out

    def unshard_rows(self, y):
        """Inverse of :meth:`shard_rows` along the last-but-(ndim-1) axis:
        (..., P * m, ...) indexed back to original agent order (..., n, ...).
        Works on the leading-agent axis right after any batch dims."""
        return np.asarray(y)[..., self.perm_slot, :]


# ---------------------------------------------------------------------------
# Sharded scenario engine
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ShardedSimTrace(SimTrace):
    """SimTrace plus partition diagnostics.

    overflow: events that missed the static per-shard update buffer (0 =>
    the trajectory is exactly the single-device one).
    """

    n_shards: int = 1
    edge_cut: int = 0
    halo_size: int = 0
    local_batch: int = 0
    overflow: int = 0


def _binomial_cap(trials: int, n_shards: int, cap: int) -> int:
    """mean + 8 sigma of Binomial(trials, 1/P), clamped to the lossless
    capacity ``cap`` — at 8 sigma overflow is ~never observed, and any
    occurrence is counted in the trace."""
    if n_shards <= 1:
        return cap
    q = 1.0 / n_shards
    mean = trials * q
    std = math.sqrt(trials * q * (1.0 - q))
    return int(min(cap, math.ceil(mean + 8.0 * std + 16)))


def default_local_batch(batch: int, n_shards: int) -> int:
    """Static per-shard update capacity (each of 2B endpoints lands on a
    given shard w.p. ~1/P; 2B = lossless whatever the draw)."""
    return _binomial_cap(2 * batch, n_shards, 2 * batch)


def default_local_events(batch: int, n_shards: int) -> int:
    """Static per-shard event capacity (an event is relevant to a shard
    when it owns either endpoint, w.p. <= 2/P)."""
    return _binomial_cap(2 * batch, n_shards, batch)


def _sharded_setup(topo, n_shards, mesh, assignment, partition_seed):
    """Shared preamble of the three sharded runners: resolve the mesh,
    the shard assignment (greedy by default, validated when explicit)
    and the graph partition.  Returns ``(mesh, P_, assignment, part)``.
    """
    mesh = make_sim_mesh(n_shards) if mesh is None else mesh
    P_ = mesh_shards(mesh)
    if assignment is None:
        assignment = greedy_partition(topo, P_, seed=partition_seed)
    elif int(np.max(assignment)) >= P_:
        raise ValueError(
            f"assignment uses shard {int(np.max(assignment))} but the mesh "
            f"has only {P_} devices (start the process with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=<P> for "
            f"fake host devices)")
    part = GraphPartition.build(topo, assignment, P_)
    return mesh, P_, assignment, part


def _local_capacities(batch: int, P_: int, local_batch) -> tuple:
    """Per-shard static (event, update) capacities ``(E, U)`` — the
    8-sigma defaults, or the lossless explicit-capacity override."""
    if local_batch is None:
        E = default_local_events(batch, P_)
        U = default_local_batch(batch, P_)
    else:                      # explicit capacity: lossless event selection
        E = batch
        U = max(1, min(local_batch, 2 * batch))
    return E, min(U, 2 * E)


def _scan_specs(P_spec, tree):
    return jax.tree_util.tree_map(lambda _: P_spec, tree)


def _sharded_frames(part: GraphPartition, stream, n_rec: int,
                    record_every: int, obj_h, stale_h, upd_h, overflow,
                    payload_row_bytes: int, halo_bytes=None,
                    suppressed=None) -> TelemetryFrames:
    """Reassemble per-shard telemetry blocks into canonical-order frames.

    obj_h / stale_h are (n_rec, P * m) gathered block outputs — indexed
    back to agent order via ``perm_slot`` (not ``unshard_rows``, which
    needs a trailing feature axis); upd_h is (n_rec, P) per-shard counters
    summed exactly here.  Delivery/drop-cause accounting reduces from the
    replayed stream (``metrics.stream_chunk_totals`` — the identical
    counts the single-device engines accumulate).  ``payload_row_bytes``
    sizes the per-boundary-row halo publish; ``halo_bytes`` overrides the
    static cumulative schedule (the joint driver recomputes it per
    segment as re-compaction shrinks the boundary).
    """
    rounds = (np.arange(n_rec, dtype=np.int64) + 1) * record_every
    if halo_bytes is None:
        halo_bytes = rounds * halo_payload_bytes(
            part.n_shards, part.boundary_size, payload_row_bytes,
            part.halo_size)
    return TelemetryFrames(
        rounds=rounds,
        objective=np.asarray(obj_h)[:, part.perm_slot],
        staleness=np.asarray(stale_h)[:, part.perm_slot],
        updates=np.asarray(upd_h, np.int64).sum(axis=1),
        halo_bytes=halo_bytes,
        overflow_per_shard=np.asarray(overflow),
        suppressed=suppressed,
        **tmetrics.stream_chunk_totals(stream, n_rec, record_every))


def _take_padded(x, sel, fill):
    """x[sel] where the out-of-range selector index len(x) reads ``fill``."""
    return jnp.concatenate([x, jnp.full((1,), fill, x.dtype)])[sel]


@partial(jax.jit,
         static_argnames=("mesh", "alpha", "m", "H", "E", "U", "n_rec",
                          "record_every", "exchange", "codec", "tel"))
def _sharded_scenario_scan(mesh, stream, theta0, K0, nbr_p, c, sol,
                           fetch, bnd_pos, halo_src_shard, halo_src_pos, *,
                           alpha: float, m: int, H: int, E: int, U: int,
                           n_rec: int, record_every: int, exchange: str,
                           codec: HaloCodec = HaloCodec("f32"),
                           tel: bool = False):
    """shard_map'd scan over rounds; every array argument before ``fetch``
    is either replicated (the event stream) or row-sharded (P * m leading
    axis); ``fetch``/``bnd_pos``/``halo_src_*`` carry one row per shard.

    ``tel`` (static) adds local-row staleness/update accumulators to each
    shard's carry and per-chunk (objective, staleness, updates) block
    outputs — the identical row-local expressions the single-device scan
    accumulates, applied to the shard's (m, ...) block, so the
    reassembled vectors are bit-for-bit the single-device ones whenever
    overflow is 0.  At the default False the traced program is exactly
    the pre-telemetry scan."""
    P_ = mesh_shards(mesh)
    batch = stream.i.shape[-1]

    def block_fn(ev, theta0_blk, K0_blk, nbr_p_blk, c_blk, sol_blk,
                 fetch_blk, bnd_blk, hsrc_blk, hpos_blk):
        fetch_q = fetch_blk[0]
        bnd = bnd_blk[0]
        hsrc, hpos = hsrc_blk[0], hpos_blk[0]
        # publish boundary rows, pull this shard's halo (round-start
        # snapshot of remote-neighbor models)
        exchange_halo = halo_exchange_fn(bnd, hsrc, hpos, H, P_, exchange,
                                         codec=codec)

        def round_fn(carry, ev_t):
            theta, K, ext_prev, overflow, *tstate = carry
            ext = exchange_halo(theta)

            # --- compact to the events touching this shard: everything
            # below (message gathers, slot scatters, updates) then runs at
            # O(E) ~ 2B/P instead of O(B) per shard
            rel = (fetch_q[ev_t.i] < m) | (fetch_q[ev_t.j] < m)
            sel = jnp.nonzero(rel, size=E, fill_value=batch)[0]
            i = _take_padded(ev_t.i, sel, 0)
            j = _take_padded(ev_t.j, sel, 0)
            s = _take_padded(ev_t.s, sel, 0)
            r = _take_padded(ev_t.r, sel, 0)
            d_ij = _take_padded(ev_t.deliver_ij, sel, False)
            d_ji = _take_padded(ev_t.deliver_ji, sel, False)
            st_ij = _take_padded(ev_t.stale_ij, sel, False)
            st_ji = _take_padded(ev_t.stale_ji, sel, False)
            overflow += jnp.maximum(jnp.sum(rel) - E, 0)

            # --- communication: deliver into local receivers' slots
            f_i, f_j = fetch_q[i], fetch_q[j]
            msg_i = jnp.where(st_ij[:, None], ext_prev[f_i], ext[f_i])
            msg_j = jnp.where(st_ji[:, None], ext_prev[f_j], ext[f_j])
            row_j = jnp.where(d_ij & (f_j < m), f_j, m)
            row_i = jnp.where(d_ji & (f_i < m), f_i, m)
            # scatter: last-write-wins — a repeated edge in one batch lands
            # the batch-order winner (mirrors the dense scenario engine)
            K = K.at[row_j, r].set(msg_i, mode="drop")
            K = K.at[row_i, s].set(msg_j, mode="drop")  # scatter: last-write-wins

            # --- update: compact local endpoints, shared Eq. (6) step
            f_u = jnp.concatenate([f_i, f_j])
            got = jnp.concatenate([d_ji, d_ij]) & (f_u < m)
            usel = jnp.nonzero(got, size=U, fill_value=2 * E)[0]
            lu = _take_padded(f_u, usel, m)
            lu_c = jnp.minimum(lu, m - 1)
            new = batched_model_update(nbr_p_blk[lu_c], K[lu_c], c_blk[lu_c],
                                       sol_blk[lu_c], alpha)
            # scatter: idempotent — duplicate rows in lu recompute the same
            # value from the same post-communication K
            theta = theta.at[jnp.where(lu < m, lu, m)].set(new, mode="drop")
            overflow += jnp.maximum(jnp.sum(got) - U, 0)
            if tel:
                stale, updates = tstate
                stale = tmetrics.staleness_step(stale, got, f_u, m)
                updates = updates + jnp.sum(got)
                tstate = (stale, updates)
            return (theta, K, ext, overflow, *tstate), None

        def outer(carry, ev_blk):
            carry, _ = jax.lax.scan(round_fn, carry, ev_blk)
            if tel:
                obj = tmetrics.mp_local_objective(
                    carry[0], carry[1], nbr_p_blk, c_blk, sol_blk, alpha)
                stale, updates = carry[4:]
                return carry, (carry[0], obj, stale, updates[None])
            return carry, carry[0]

        ext0 = exchange_halo(theta0_blk)                 # = warm-start halo
        carry0 = (theta0_blk, K0_blk, ext0, jnp.int32(0))
        if tel:
            carry0 = carry0 + (jnp.zeros((m,), jnp.int32), jnp.int32(0))
        carry, hist = jax.lax.scan(outer, carry0, ev)
        theta, overflow = carry[0], carry[3]
        if tel:
            hist, obj_h, stale_h, upd_h = hist
            return hist, theta, overflow[None], obj_h, stale_h, upd_h
        return hist, theta, overflow[None]

    ev_scan = _reshape_stream(stream, n_rec, record_every)
    out_specs = (P(None, AGENT_AXIS, None), P(AGENT_AXIS), P(AGENT_AXIS))
    if tel:
        out_specs = out_specs + (P(None, AGENT_AXIS), P(None, AGENT_AXIS),
                                 P(None, AGENT_AXIS))
    run = shard_map_1d(
        block_fn, mesh,
        in_specs=(_scan_specs(P(), ev_scan), P(AGENT_AXIS), P(AGENT_AXIS),
                  P(AGENT_AXIS), P(AGENT_AXIS), P(AGENT_AXIS),
                  P(AGENT_AXIS, None), P(AGENT_AXIS, None),
                  P(AGENT_AXIS, None), P(AGENT_AXIS, None)),
        out_specs=out_specs)
    return run(ev_scan, theta0, K0, nbr_p, c, sol, fetch, bnd_pos,
               halo_src_shard, halo_src_pos)


def run_mp_scenario_sharded(topo: SparseTopology, theta_sol, c, alpha: float,
                            conditions: NetworkConditions, rounds: int,
                            batch: int, seed: int = 0,
                            record_every: int = 10, *,
                            n_shards: Optional[int] = None, mesh=None,
                            assignment: Optional[np.ndarray] = None,
                            local_batch: Optional[int] = None,
                            exchange: str = "all_gather",
                            halo_codec="f32",
                            partition_seed: int = 0,
                            telemetry: Optional[TelemetryConfig] = None
                            ) -> ShardedSimTrace:
    """``run_mp_scenario`` over a graph partitioned across the sim mesh.

    Same scenario semantics and RNG schedule as the single-device engine —
    ``trace.theta_hist`` reproduces it exactly whenever ``trace.overflow``
    is 0 (see module docstring).  ``n_shards`` defaults to every local
    device; pass ``assignment`` to reuse a precomputed partition, and
    ``exchange="ring"`` for the ppermute halo path.  ``halo_codec``
    selects the boundary-row wire format (``launch.sim_mesh.HaloCodec``:
    "f32" — the default, bit-for-bit with the single-device trajectory —
    or the lossy "bf16"/"int8" encodings with f32 accumulation); the
    telemetry ``halo_bytes`` column accounts the coded wire size.
    """
    mesh, P_, assignment, part = _sharded_setup(
        topo, n_shards, mesh, assignment, partition_seed)

    tabs = topo.tables
    n = topo.n
    theta_sol = np.asarray(theta_sol, np.float32).reshape(n, -1)
    c = np.asarray(c, np.float32)
    record_every, n_rec = record_chunks(rounds, record_every)
    total_rounds = n_rec * record_every

    stream = precompute_event_stream(
        topo.device_tables(), jnp.asarray(topo.partition_halves()),
        conditions, batch, seed, total_rounds)

    K0 = theta_sol[tabs.nbr_idx]                     # warm start (§3.2)
    sharded = dict(
        theta0=part.shard_rows(theta_sol), K0=part.shard_rows(K0),
        nbr_p=part.shard_rows(tabs.nbr_p), c=part.shard_rows(c),
        sol=part.shard_rows(theta_sol))
    E, U = _local_capacities(batch, P_, local_batch)

    tel = telemetry_on(telemetry)
    codec = resolve_halo_codec(halo_codec)
    outs = _sharded_scenario_scan(
        mesh, stream, **{k: jnp.asarray(v) for k, v in sharded.items()},
        fetch=jnp.asarray(part.fetch), bnd_pos=jnp.asarray(part.bnd_pos),
        halo_src_shard=jnp.asarray(part.halo_src_shard),
        halo_src_pos=jnp.asarray(part.halo_src_pos),
        alpha=alpha, m=part.shard_size, H=part.halo_size,
        E=E, U=U, n_rec=n_rec, record_every=record_every,
        exchange=exchange, codec=codec, tel=tel)
    frames = None
    if tel:
        hist, theta, overflow, obj_h, stale_h, upd_h = outs
        frames = _sharded_frames(
            part, stream, n_rec, record_every, obj_h, stale_h, upd_h,
            overflow,
            payload_row_bytes=codec.row_nbytes((theta_sol.shape[1],)))
    else:
        hist, theta, overflow = outs

    delivered, dropped, invalid = stream_totals(stream)
    active_hist = np.asarray(stream.active_frac).reshape(
        n_rec, record_every)[:, -1]
    return ShardedSimTrace(
        theta_hist=part.unshard_rows(np.asarray(hist)),
        active_hist=active_hist, delivered=delivered, dropped=dropped,
        rounds=total_rounds, events=total_rounds * batch, invalid=invalid,
        telemetry=frames, n_shards=P_, edge_cut=part.edge_cut,
        halo_size=part.halo_size, local_batch=U,
        overflow=int(np.asarray(overflow).sum()))


# ---------------------------------------------------------------------------
# Sharded CL-ADMM scenario engine (DESIGN.md §12)
# ---------------------------------------------------------------------------


@partial(jax.jit,
         static_argnames=("mesh", "mu", "rho", "k", "m", "H", "E", "U",
                          "n_rec", "record_every", "exchange", "codec",
                          "tel", "primal"))
def _sharded_cl_scan(mesh, stream, theta0, K0, Zo0, Zn0, Lo0, Ln0,
                     nbr_w, deg_count, D, m_counts, sx,
                     fetch, bnd_pos, halo_src_shard, halo_src_pos,
                     tel_args=(), xym=(), *,
                     mu: float, rho: float, k: int, m: int, H: int, E: int,
                     U: int, n_rec: int, record_every: int, exchange: str,
                     codec: HaloCodec = HaloCodec("f32"),
                     tel: bool = False, primal=None):
    """shard_map'd CL-ADMM rounds: the six ADMM state arrays are row-sharded
    (P * m leading axis); the event stream is replicated and replayed per
    shard exactly as the MP engine does.

    Edge state never leaves its owner: for a cross-shard edge each endpoint
    shard keeps its own (Z_own, Z_nbr, L_own, L_nbr) slots and mirrors the
    partner's payload — post-primal theta + K plus round-start duals — into
    its halo via one exchange per round, placed *between* the primal and
    edge phases (the edge half-step reads post-primal remote models).  The
    previous round's ext buffer serves the one-round-stale payloads.

    ``primal`` (static) mirrors ``engines._cl_scenario_scan``: ``None``
    keeps the inline exact quadratic solve; a data-hungry PrimalSolver
    receives the rows' padded local data via the row-sharded ``xym``
    blocks (the solve is row-local, so sharding it is free).
    """
    P_ = mesh_shards(mesh)
    batch = stream.i.shape[-1]
    n_xym = 3 if (primal is not None and primal.needs_data) else 0

    def block_fn(ev, theta0_blk, K0_blk, Zo_blk, Zn_blk, Lo_blk, Ln_blk,
                 w_blk, degc_blk, D_blk, mc_blk, sx_blk,
                 fetch_blk, bnd_blk, hsrc_blk, hpos_blk, *extra_blks):
        xym_blk = extra_blks[:n_xym]
        tel_blks = extra_blks[n_xym:]
        fetch_q = fetch_blk[0]
        bnd = bnd_blk[0]
        hsrc, hpos = hsrc_blk[0], hpos_blk[0]
        exchange_halo = halo_exchange_fn(bnd, hsrc, hpos, H, P_, exchange,
                                         codec=codec)
        live_blk = jnp.arange(k)[None, :] < degc_blk[:, None]      # (m, k)

        def publish(theta, K, Lo, Ln):
            """Stacked payload rows [theta | K | L_own | L_nbr] -> ext."""
            pub = jnp.concatenate([theta[:, None, :], K, Lo, Ln], axis=1)
            return exchange_halo(pub)                  # (m + H + 1, 1+3k, p)

        def round_fn(carry, ev_t):
            theta, K, Zo, Zn, Lo, Ln, ext_prev, overflow, *tstate = carry

            # --- compact to the events touching this shard (O(E) ~ 2B/P)
            rel = (fetch_q[ev_t.i] < m) | (fetch_q[ev_t.j] < m)
            sel = jnp.nonzero(rel, size=E, fill_value=batch)[0]
            i = _take_padded(ev_t.i, sel, 0)
            j = _take_padded(ev_t.j, sel, 0)
            s = _take_padded(ev_t.s, sel, 0)
            r = _take_padded(ev_t.r, sel, 0)
            d_ij = _take_padded(ev_t.deliver_ij, sel, False)
            d_ji = _take_padded(ev_t.deliver_ji, sel, False)
            st_ij = _take_padded(ev_t.stale_ij, sel, False)
            st_ji = _take_padded(ev_t.stale_ji, sel, False)
            overflow += jnp.maximum(jnp.sum(rel) - E, 0)

            # --- primal phase: compact local handshake endpoints, shared
            # exact quadratic step (core.sparse.batched_admm_primal)
            f_i, f_j = fetch_q[i], fetch_q[j]
            f_u = jnp.concatenate([f_i, f_j])                     # (2E,)
            got = jnp.concatenate([d_ji, d_ij]) & (f_u < m)
            usel = jnp.nonzero(got, size=U, fill_value=2 * E)[0]
            lu = _take_padded(f_u, usel, m)
            lu_c = jnp.minimum(lu, m - 1)
            if primal is None:
                new_theta, theta_js = batched_admm_primal(
                    w_blk[lu_c], live_blk[lu_c], Zo[lu_c], Zn[lu_c],
                    Lo[lu_c], Ln[lu_c], D_blk[lu_c], mc_blk[lu_c],
                    sx_blk[lu_c], mu, rho)
            else:
                xr = tuple(a[lu_c] for a in xym_blk)
                new_theta, theta_js = primal.solve_batch(
                    w_blk[lu_c], live_blk[lu_c], Zo[lu_c], Zn[lu_c],
                    Lo[lu_c], Ln[lu_c], D_blk[lu_c], mc_blk[lu_c],
                    sx_blk[lu_c], xr, theta[lu_c], mu, rho, None)
            new_K = jnp.where(live_blk[lu_c][:, :, None], theta_js, K[lu_c])
            rowp = jnp.where(lu < m, lu, m)
            # scatter: idempotent — duplicate rows in lu derive identical
            # values from the same round-start Z/L state
            theta = theta.at[rowp].set(new_theta, mode="drop")
            K = K.at[rowp].set(new_K, mode="drop")  # scatter: idempotent
            overflow += jnp.maximum(jnp.sum(got) - U, 0)

            # --- publish + halo exchange (post-primal models, round-start
            # duals), then the edge phase reads payloads from ext
            ext = publish(theta, K, Lo, Ln)

            # --- edge phase: one half-step per delivered direction whose
            # receiver is local
            own_s = jnp.concatenate([s, r])
            oth_f = jnp.concatenate([f_j, f_i])
            oth_s = jnp.concatenate([r, s])
            stale = jnp.concatenate([st_ji, st_ij])[:, None, None]
            pay = jnp.where(stale, ext_prev[oth_f], ext[oth_f])
            ar = jnp.arange(oth_s.shape[0])
            th_pay = pay[:, 0]
            k_pay = pay[ar, 1 + oth_s]
            lo_pay = pay[ar, 1 + k + oth_s]
            ln_pay = pay[ar, 1 + 2 * k + oth_s]
            own_c = jnp.minimum(f_u, m - 1)
            z_own, z_nbr, lo_new, ln_new = admm_edge_halfstep(
                theta[own_c], K[own_c, own_s], Lo[own_c, own_s],
                Ln[own_c, own_s], th_pay, k_pay, lo_pay, ln_pay, rho)
            rowe = jnp.where(got, f_u, m)
            # scatter: unique targets — each event side writes its own
            # (row, slot) cell; a slot belongs to one edge and each edge
            # fires once per round
            Zo = Zo.at[rowe, own_s].set(z_own, mode="drop")
            Zn = Zn.at[rowe, own_s].set(z_nbr, mode="drop")  # scatter: unique targets
            Lo = Lo.at[rowe, own_s].set(lo_new, mode="drop")  # scatter: unique targets
            Ln = Ln.at[rowe, own_s].set(ln_new, mode="drop")  # scatter: unique targets
            if tel:
                stale, updates = tstate
                stale = tmetrics.staleness_step(stale, got, f_u, m)
                updates = updates + jnp.sum(got)
                tstate = (stale, updates)
            return (theta, K, Zo, Zn, Lo, Ln, ext, overflow, *tstate), None

        def outer(carry, ev_blk):
            carry, _ = jax.lax.scan(round_fn, carry, ev_blk)
            if tel:
                if primal is not None and primal.needs_data:
                    loss_vec = primal.batch_local_loss(carry[0], *xym_blk)
                    obj = tmetrics.cl_local_objective_from_loss(
                        carry[0], carry[1], w_blk, live_blk, D_blk,
                        loss_vec, mu)
                else:
                    (sxx_blk,) = tel_blks
                    obj = tmetrics.cl_local_objective(
                        carry[0], carry[1], w_blk, live_blk, D_blk, mc_blk,
                        sx_blk, sxx_blk, mu)
                stale, updates = carry[8:]
                return carry, (carry[0], obj, stale, updates[None])
            return carry, carry[0]

        ext0 = publish(theta0_blk, K0_blk, Lo_blk, Ln_blk)  # warm-start halo
        carry0 = (theta0_blk, K0_blk, Zo_blk, Zn_blk, Lo_blk, Ln_blk, ext0,
                  jnp.int32(0))
        if tel:
            carry0 = carry0 + (jnp.zeros((m,), jnp.int32), jnp.int32(0))
        carry, hist = jax.lax.scan(outer, carry0, ev)
        theta, overflow = carry[0], carry[7]
        if tel:
            hist, obj_h, stale_h, upd_h = hist
            return hist, theta, overflow[None], obj_h, stale_h, upd_h
        return hist, theta, overflow[None]

    ev_scan = _reshape_stream(stream, n_rec, record_every)
    row = P(AGENT_AXIS)
    per_shard = P(AGENT_AXIS, None)
    out_specs = (P(None, AGENT_AXIS, None), row, row)
    if tel:
        out_specs = out_specs + (P(None, AGENT_AXIS),) * 3
    run = shard_map_1d(
        block_fn, mesh,
        in_specs=(_scan_specs(P(), ev_scan),) + (row,) * 11
        + (per_shard,) * 4 + (row,) * n_xym + (row,) * len(tel_args),
        out_specs=out_specs)
    return run(ev_scan, theta0, K0, Zo0, Zn0, Lo0, Ln0, nbr_w, deg_count,
               D, m_counts, sx, fetch, bnd_pos, halo_src_shard,
               halo_src_pos, *xym, *tel_args)


def run_cl_scenario_sharded(topo: SparseTopology, data, mu: float,
                            rho: float, conditions: NetworkConditions,
                            rounds: int, batch: int, seed: int = 0,
                            record_every: int = 10, *, theta_sol=None,
                            n_shards: Optional[int] = None, mesh=None,
                            assignment: Optional[np.ndarray] = None,
                            local_batch: Optional[int] = None,
                            exchange: str = "all_gather",
                            halo_codec="f32",
                            partition_seed: int = 0,
                            stream: Optional[EventStream] = None,
                            telemetry: Optional[TelemetryConfig] = None,
                            primal=None) -> ShardedSimTrace:
    """``simulate.engines.run_cl_scenario`` over a graph partitioned across
    the sim mesh.

    Same scenario semantics and RNG schedule as the single-device CL-ADMM
    engine — ``trace.theta_hist`` reproduces it exactly whenever
    ``trace.overflow`` is 0.  The six sparse ADMM state arrays are
    row-sharded; per round one halo exchange mirrors each boundary agent's
    post-primal (theta, K) and round-start (L_own, L_nbr) rows onto the
    shards that hold the other endpoint of its cross-shard edges, and each
    shard then applies the shared edge half-step to its own slots only
    (DESIGN.md §12).  Knobs match ``run_mp_scenario_sharded``, including
    ``halo_codec`` — here the codec covers the full stacked
    ``[theta | K | L_own | L_nbr]`` payload rows, with one int8 scale per
    model/dual component.

    ``primal`` selects the primal-phase solver exactly as in
    ``engines.run_cl_scenario`` (``core.primal``); the primal solve is
    row-local, so the inexact solver shards the same way the exact one
    does — the agents' padded local datasets are row-sharded alongside
    the ADMM state.
    """
    mesh, P_, assignment, part = _sharded_setup(
        topo, n_shards, mesh, assignment, partition_seed)

    tabs = topo.tables
    record_every, n_rec = record_chunks(rounds, record_every)
    total_rounds = n_rec * record_every

    if stream is None:
        stream = precompute_event_stream(
            topo.device_tables(), jnp.asarray(topo.partition_halves()),
            conditions, batch, seed, total_rounds)
    else:
        if stream.i.shape[0] != total_rounds:
            raise ValueError(
                f"stream covers {stream.i.shape[0]} rounds but the clamped "
                f"horizon is {total_rounds}")
        batch = int(stream.i.shape[1])

    if theta_sol is None:
        raise ValueError("need theta_sol (warm start)")
    state0 = init_sparse_admm(topo, theta_sol)
    # the local-data reductions use the same jnp expressions as the
    # single-device engine (numpy's pairwise summation rounds differently,
    # which would break the bit-for-bit parity)
    mask = jnp.asarray(data.mask, jnp.float32)
    x = jnp.asarray(data.x, jnp.float32)
    m_counts = np.asarray(jnp.sum(mask, axis=1))
    sx = np.asarray(jnp.sum(x * mask[:, :, None], axis=1))
    needs_data = primal is not None and primal.needs_data
    xym = ()
    if needs_data:
        xym = tuple(jnp.asarray(part.shard_rows(np.asarray(a)))
                    for a in (x, jnp.asarray(data.y, jnp.float32), mask))
    sharded = dict(
        theta0=part.shard_rows(np.asarray(state0.theta)),
        K0=part.shard_rows(np.asarray(state0.K)),
        Zo0=part.shard_rows(np.asarray(state0.Z_own)),
        Zn0=part.shard_rows(np.asarray(state0.Z_nbr)),
        Lo0=part.shard_rows(np.asarray(state0.L_own)),
        Ln0=part.shard_rows(np.asarray(state0.L_nbr)),
        nbr_w=part.shard_rows(tabs.nbr_w),
        deg_count=part.shard_rows(tabs.deg_count),
        D=part.shard_rows(tabs.deg_w.astype(np.float32)),
        m_counts=part.shard_rows(m_counts),
        sx=part.shard_rows(sx))
    E, U = _local_capacities(batch, P_, local_batch)

    tel = telemetry_on(telemetry)
    tel_args = ()
    if tel and not needs_data:
        sxx = np.asarray(jnp.sum(mask * jnp.sum(x * x, axis=-1), axis=1))
        tel_args = (jnp.asarray(part.shard_rows(sxx)),)
    codec = resolve_halo_codec(halo_codec)
    outs = _sharded_cl_scan(
        mesh, stream, **{k_: jnp.asarray(v) for k_, v in sharded.items()},
        fetch=jnp.asarray(part.fetch), bnd_pos=jnp.asarray(part.bnd_pos),
        halo_src_shard=jnp.asarray(part.halo_src_shard),
        halo_src_pos=jnp.asarray(part.halo_src_pos), tel_args=tel_args,
        xym=xym,
        mu=mu, rho=rho, k=topo.k_max, m=part.shard_size, H=part.halo_size,
        E=E, U=U, n_rec=n_rec, record_every=record_every,
        exchange=exchange, codec=codec, tel=tel, primal=primal)
    frames = None
    if tel:
        hist, theta, overflow, obj_h, stale_h, upd_h = outs
        p_dim = int(np.asarray(state0.theta).shape[1])
        frames = _sharded_frames(
            part, stream, n_rec, record_every, obj_h, stale_h, upd_h,
            overflow,
            payload_row_bytes=codec.row_nbytes((1 + 3 * topo.k_max, p_dim)))
    else:
        hist, theta, overflow = outs

    delivered, dropped, invalid = stream_totals(stream)
    active_hist = np.asarray(stream.active_frac).reshape(
        n_rec, record_every)[:, -1]
    return ShardedSimTrace(
        theta_hist=part.unshard_rows(np.asarray(hist)),
        active_hist=active_hist, delivered=delivered, dropped=dropped,
        rounds=total_rounds, events=total_rounds * batch, invalid=invalid,
        telemetry=frames, n_shards=P_, edge_cut=part.edge_cut,
        halo_size=part.halo_size, local_batch=U,
        overflow=int(np.asarray(overflow).sum()))


# ---------------------------------------------------------------------------
# Sharded joint model + collaboration-graph learning (DESIGN.md §13)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class JointShardedTrace(ShardedSimTrace):
    """ShardedSimTrace plus graph-learning outputs and re-compaction stats.

    Fields mirror ``engines.JointSimTrace``; ``recompactions`` counts halo
    re-compactions performed (each shrinks ``halo_size`` to the live cross
    edges at that point — the reported ``halo_size`` is the final one).
    """

    final_w: Optional[np.ndarray] = None
    final_live: Optional[np.ndarray] = None
    live_edges_hist: Optional[np.ndarray] = None
    suppressed: int = 0
    recompactions: int = 0


def _slice_stream(stream: EventStream, lo: int, hi: int):
    """Rounds [lo, hi) of a materialized stream (active_frac dropped)."""
    return jax.tree_util.tree_map(lambda x: x[lo:hi],
                                  stream._replace(active_frac=None))


def _live_cross_edges(tabs, owner: np.ndarray, live: np.ndarray) -> int:
    """Directed live candidate slots whose sender lives on another shard
    (the same edge enumeration ``GraphPartition.build`` compacts halos
    from, so the re-compaction trigger and the rebuild always agree)."""
    src, dst = _directed_edges(tabs, live)
    return int((owner[src] != owner[dst]).sum())


@partial(jax.jit,
         static_argnames=("mesh", "alpha", "eta_graph", "lam", "graph_every",
                          "prune_eps", "m", "H", "E", "U", "n_rec",
                          "record_every", "exchange", "codec", "backend",
                          "tel"))
def _sharded_joint_scan(mesh, stream, ts, theta0, K0, theta_prev0, w0,
                        live0, c, sol, fetch, bnd_pos, halo_src_shard,
                        halo_src_pos, tel_args=(), *, alpha: float,
                        eta_graph: float, lam: float, graph_every: int,
                        prune_eps, m: int, H: int, E: int, U: int,
                        n_rec: int, record_every: int, exchange: str,
                        codec: HaloCodec = HaloCodec("f32"),
                        backend=None, tel: bool = False):
    """One jitted *segment* of the sharded joint engine.

    The MP round structure of ``_sharded_scenario_scan`` with the mixing
    weights ``w`` and candidate-liveness ``live`` promoted to row-sharded
    scan state, the shared graph step (``core.graph_learning``) applied to
    each shard's local rows every ``graph_every``-th global round, and
    deliveries into pruned receiver slots voided (counted in
    ``suppressed``).  ``theta_prev`` (the previous round's round-start
    models) rides the carry so the driver can rebuild the stale-payload
    ext buffer after a halo re-compaction changes ``H`` between segments.

    ``tel`` (static) threads each shard's staleness counters through the
    segment (``tel_args = (stale0,)`` row-sharded in, final counters out)
    and adds per-chunk (objective, staleness, updates, suppressed) block
    outputs, so segments compose into exactly the single-device
    accumulators.
    """
    P_ = mesh_shards(mesh)
    batch = stream.i.shape[-1]
    prune = eta_graph > 0.0 and prune_eps is not None

    def block_fn(ev, ts_blk, theta0_blk, K0_blk, thp0_blk, w0_blk, live0_blk,
                 c_blk, sol_blk, fetch_blk, bnd_blk, hsrc_blk, hpos_blk,
                 *tel_blks):
        fetch_q = fetch_blk[0]
        bnd = bnd_blk[0]
        hsrc, hpos = hsrc_blk[0], hpos_blk[0]
        exchange_halo = halo_exchange_fn(bnd, hsrc, hpos, H, P_, exchange,
                                         codec=codec)

        def round_fn(carry, inp):
            theta, K, theta_prev, w, live, ext_prev, suppressed, overflow, \
                *tstate = carry
            ev_t, t = inp
            theta_in = theta
            ext = exchange_halo(theta)

            # --- compact to the events touching this shard (O(E) ~ 2B/P)
            rel = (fetch_q[ev_t.i] < m) | (fetch_q[ev_t.j] < m)
            sel = jnp.nonzero(rel, size=E, fill_value=batch)[0]
            i = _take_padded(ev_t.i, sel, 0)
            j = _take_padded(ev_t.j, sel, 0)
            s = _take_padded(ev_t.s, sel, 0)
            r = _take_padded(ev_t.r, sel, 0)
            d_ij = _take_padded(ev_t.deliver_ij, sel, False)
            d_ji = _take_padded(ev_t.deliver_ji, sel, False)
            st_ij = _take_padded(ev_t.stale_ij, sel, False)
            st_ji = _take_padded(ev_t.stale_ji, sel, False)
            overflow += jnp.maximum(jnp.sum(rel) - E, 0)

            # --- communication (receiver-side pruning voids a delivery)
            f_i, f_j = fetch_q[i], fetch_q[j]
            if prune:
                lv_j = live[jnp.minimum(f_j, m - 1), r] & (f_j < m)
                lv_i = live[jnp.minimum(f_i, m - 1), s] & (f_i < m)
                ok_ij = d_ij & lv_j
                ok_ji = d_ji & lv_i
                suppressed = suppressed \
                    + jnp.sum(d_ij & (f_j < m) & ~lv_j) \
                    + jnp.sum(d_ji & (f_i < m) & ~lv_i)
            else:
                ok_ij, ok_ji = d_ij, d_ji
            msg_i = jnp.where(st_ij[:, None], ext_prev[f_i], ext[f_i])
            msg_j = jnp.where(st_ji[:, None], ext_prev[f_j], ext[f_j])
            row_j = jnp.where(ok_ij & (f_j < m), f_j, m)
            row_i = jnp.where(ok_ji & (f_i < m), f_i, m)
            # scatter: last-write-wins — a repeated edge in one batch lands
            # the batch-order winner (mirrors the dense scenario engine)
            K = K.at[row_j, r].set(msg_i, mode="drop")
            K = K.at[row_i, s].set(msg_j, mode="drop")  # scatter: last-write-wins

            # --- update: compact local endpoints, shared Eq. (6) step
            # under the current learned weights
            f_u = jnp.concatenate([f_i, f_j])
            got = jnp.concatenate([ok_ji, ok_ij]) & (f_u < m)
            usel = jnp.nonzero(got, size=U, fill_value=2 * E)[0]
            lu = _take_padded(f_u, usel, m)
            lu_c = jnp.minimum(lu, m - 1)
            new = batched_model_update(w[lu_c], K[lu_c], c_blk[lu_c],
                                       sol_blk[lu_c], alpha, backend)
            # scatter: idempotent — duplicate rows in lu recompute the same
            # value from the same post-communication K
            theta = theta.at[jnp.where(lu < m, lu, m)].set(new, mode="drop")
            overflow += jnp.maximum(jnp.sum(got) - U, 0)

            # --- graph step over this shard's local rows (compiled out at
            # rate 0; identical row arithmetic to the single-device engine)
            if eta_graph > 0.0:
                def do_graph(w, live):
                    w2 = reweight_rows(theta, K, w, live, eta=eta_graph,
                                       lam=lam, backend=backend)
                    if prune_eps is not None:
                        return prune_rows(w2, live, prune_eps)
                    return w2, live

                w, live = jax.lax.cond(
                    (t + 1) % graph_every == 0, do_graph,
                    lambda w, live: (w, live), w, live)

            if tel:
                stale, updates = tstate
                stale = tmetrics.staleness_step(stale, got, f_u, m)
                updates = updates + jnp.sum(got)
                tstate = (stale, updates)
            return (theta, K, theta_in, w, live, ext, suppressed,
                    overflow, *tstate), None

        def outer(carry, inp):
            carry, _ = jax.lax.scan(round_fn, carry, inp)
            theta, _, _, w, live = carry[:5]
            edges = jnp.sum(live & (w > 0))[None]
            if tel:
                obj = tmetrics.mp_local_objective(
                    theta, carry[1], jnp.where(live, w, 0.0), c_blk,
                    sol_blk, alpha)
                stale, updates = carry[8:]
                return carry, (theta, edges, obj, stale, updates[None],
                               carry[6][None])
            return carry, (theta, edges)

        ext_prev0 = exchange_halo(thp0_blk)
        carry0 = (theta0_blk, K0_blk, thp0_blk, w0_blk, live0_blk, ext_prev0,
                  jnp.int32(0), jnp.int32(0))
        if tel:
            carry0 = carry0 + (tel_blks[0], jnp.int32(0))
        carry, hist = jax.lax.scan(outer, carry0, (ev, ts_blk))
        theta, K, theta_prev, w, live, _, suppressed, overflow = carry[:8]
        base = (hist[0], hist[1], theta, K, theta_prev, w, live,
                suppressed[None], overflow[None])
        if tel:
            return base + (hist[2], hist[3], hist[4], hist[5], carry[8])
        return base

    ev_scan = _reshape_stream(stream, n_rec, record_every)
    row = P(AGENT_AXIS)
    per_shard = P(AGENT_AXIS, None)
    out_specs = (P(None, AGENT_AXIS, None), P(None, AGENT_AXIS)) \
        + (row,) * 5 + (P(AGENT_AXIS),) * 2
    if tel:
        out_specs = out_specs + (P(None, AGENT_AXIS),) * 4 + (row,)
    run = shard_map_1d(
        block_fn, mesh,
        in_specs=(_scan_specs(P(), ev_scan), P()) + (row,) * 7
        + (per_shard,) * 4 + (row,) * len(tel_args),
        out_specs=out_specs)
    return run(ev_scan, ts, theta0, K0, theta_prev0, w0, live0, c, sol,
               fetch, bnd_pos, halo_src_shard, halo_src_pos, *tel_args)


def run_joint_scenario_sharded(topo: SparseTopology, theta_sol, c,
                               alpha: float, conditions: NetworkConditions,
                               rounds: int, batch: int, seed: int = 0,
                               record_every: int = 10, *,
                               eta_graph: float = 0.0, lam: float = 1.0,
                               graph_every: int = 1,
                               prune_eps: Optional[float] = None,
                               recompact_every: Optional[int] = None,
                               recompact_frac: float = 0.25,
                               n_shards: Optional[int] = None, mesh=None,
                               assignment: Optional[np.ndarray] = None,
                               local_batch: Optional[int] = None,
                               exchange: str = "all_gather",
                               halo_codec="f32",
                               partition_seed: int = 0,
                               stream: Optional[EventStream] = None,
                               backend=None,
                               telemetry: Optional[TelemetryConfig] = None
                               ) -> JointShardedTrace:
    """``engines.run_joint_scenario`` over a graph partitioned across the
    sim mesh (DESIGN.md §13).

    Same scenario semantics and RNG schedule as the single-device joint
    engine — ``trace.theta_hist`` (and the learned ``final_w``/``final_live``)
    reproduce it exactly whenever ``trace.overflow`` is 0.  The learned
    weights and candidate-liveness are row-sharded scan state; the graph
    step is row-local, so it needs no extra collective.

    **Halo re-compaction**: with pruning enabled (``prune_eps``) and a
    ``recompact_every`` (rounds) cadence, the driver pauses between jitted
    segments, measures how many *cross-shard* candidate slots the graph
    step has pruned, and — once the live cross-edge count has dropped by
    ``recompact_frac`` since the last layout — rebuilds the halo/boundary
    tables restricted to live edges (``GraphPartition.build(live=...)``).
    Pruning is monotone (``core.graph_learning.prune_rows``), so dropped
    halo rows are never needed again and the trajectory is unaffected;
    only the exchange volume shrinks.  ``rounds`` is first floored by the
    shared recording policy; segment boundaries land on record chunks.
    """
    mesh, P_, assignment, part = _sharded_setup(
        topo, n_shards, mesh, assignment, partition_seed)
    owner = np.asarray(assignment, np.int32)
    full_cut = part.edge_cut

    tabs = topo.tables
    n = topo.n
    theta_sol = np.asarray(theta_sol, np.float32).reshape(n, -1)
    c = np.asarray(c, np.float32)
    record_every, n_rec = record_chunks(rounds, record_every)
    total_rounds = n_rec * record_every

    if stream is None:
        stream = precompute_event_stream(
            topo.device_tables(), jnp.asarray(topo.partition_halves()),
            conditions, batch, seed, total_rounds)
    else:
        if stream.i.shape[0] != total_rounds:
            raise ValueError(
                f"stream covers {stream.i.shape[0]} rounds but the clamped "
                f"horizon is {total_rounds}")
        batch = int(stream.i.shape[1])

    K0 = theta_sol[tabs.nbr_idx]                     # warm start (§3.2)
    live0 = np.asarray(live_slots(tabs.deg_count, tabs.k_max))
    theta = jnp.asarray(part.shard_rows(theta_sol))
    K = jnp.asarray(part.shard_rows(K0))
    theta_prev = theta
    w = jnp.asarray(part.shard_rows(tabs.nbr_p))
    live = jnp.asarray(part.shard_rows(live0))
    c_sh = jnp.asarray(part.shard_rows(c))
    sol_sh = jnp.asarray(part.shard_rows(theta_sol))
    E, U = _local_capacities(batch, P_, local_batch)

    # segment schedule (record chunks per jitted call)
    can_recompact = (eta_graph > 0.0 and prune_eps is not None
                     and recompact_every is not None)
    if can_recompact:
        # repro-lint: disable=RPL007  n_rec already record_chunks-normalized
        seg = recompact_every // record_every
        seg_rec = max(1, min(n_rec, seg))
    else:
        seg_rec = n_rec
    cross_at_compact = _live_cross_edges(tabs, owner, live0)

    tel = telemetry_on(telemetry)
    codec = resolve_halo_codec(halo_codec)
    p_dim = theta_sol.shape[1]
    stale = jnp.zeros((P_ * part.shard_size,), jnp.int32) if tel else None
    tel_obj, tel_stale, tel_upd, tel_sup, tel_halo = [], [], [], [], []
    upd_off = sup_off = halo_off = 0
    ovf_shards = np.zeros(P_, np.int64)

    hists, live_hists = [], []
    suppressed = overflow = recompactions = 0
    done = 0
    while done < n_rec:
        seg = min(seg_rec, n_rec - done)
        ev_seg = _slice_stream(stream, done * record_every,
                               (done + seg) * record_every)
        ts_seg = jnp.arange(done * record_every,
                            (done + seg) * record_every,
                            dtype=jnp.int32).reshape(seg, record_every)
        (hist, live_hist, theta, K, theta_prev, w, live, sup, ovf,
         *tel_out) = _sharded_joint_scan(
                mesh, ev_seg, ts_seg, theta, K, theta_prev, w, live,
                c_sh, sol_sh, jnp.asarray(part.fetch),
                jnp.asarray(part.bnd_pos),
                jnp.asarray(part.halo_src_shard),
                jnp.asarray(part.halo_src_pos),
                (stale,) if tel else (), alpha=alpha,
                eta_graph=eta_graph, lam=lam, graph_every=graph_every,
                prune_eps=prune_eps, m=part.shard_size, H=part.halo_size,
                E=E, U=U, n_rec=seg, record_every=record_every,
                exchange=exchange, codec=codec, backend=backend, tel=tel)
        hists.append(np.asarray(hist))
        live_hists.append(np.asarray(live_hist).sum(axis=1))
        suppressed += int(np.asarray(sup).sum())
        overflow += int(np.asarray(ovf).sum())
        if tel:
            obj_h, stale_h, upd_h, sup_h, stale = tel_out
            tel_obj.append(np.asarray(obj_h))
            tel_stale.append(np.asarray(stale_h))
            seg_upd = np.asarray(upd_h, np.int64).sum(axis=1)
            tel_upd.append(upd_off + seg_upd)
            upd_off = int(tel_upd[-1][-1])
            seg_sup = np.asarray(sup_h, np.int64).sum(axis=1)
            tel_sup.append(sup_off + seg_sup)
            sup_off = int(tel_sup[-1][-1])
            # halo payload of *this* segment's layout (re-compaction
            # shrinks the boundary between segments)
            per_round = halo_payload_bytes(
                P_, part.boundary_size, codec.row_nbytes((p_dim,)),
                part.halo_size)
            seg_rounds = (np.arange(seg, dtype=np.int64) + 1) * record_every
            tel_halo.append(halo_off + seg_rounds * per_round)
            halo_off = int(tel_halo[-1][-1])
            ovf_shards += np.asarray(ovf, np.int64)
        done += seg
        if done < n_rec and can_recompact and cross_at_compact > 0:
            live_host = part.unshard_rows(np.asarray(live))
            cur_cross = _live_cross_edges(tabs, owner, live_host)
            if cur_cross <= (1.0 - recompact_frac) * cross_at_compact:
                part = GraphPartition.build(topo, assignment, P_,
                                            live=live_host)
                cross_at_compact = cur_cross
                recompactions += 1

    frames = None
    if tel:
        frames = TelemetryFrames(
            rounds=(np.arange(n_rec, dtype=np.int64) + 1) * record_every,
            objective=np.concatenate(tel_obj)[:, part.perm_slot],
            staleness=np.concatenate(tel_stale)[:, part.perm_slot],
            updates=np.concatenate(tel_upd),
            halo_bytes=np.concatenate(tel_halo),
            overflow_per_shard=ovf_shards,
            suppressed=np.concatenate(tel_sup),
            **tmetrics.stream_chunk_totals(stream, n_rec, record_every))

    delivered, dropped, invalid = stream_totals(stream)
    active_hist = np.asarray(stream.active_frac).reshape(
        n_rec, record_every)[:, -1]
    return JointShardedTrace(
        theta_hist=part.unshard_rows(np.concatenate(hists, axis=0)),
        active_hist=active_hist, delivered=delivered, dropped=dropped,
        rounds=total_rounds, events=total_rounds * batch, invalid=invalid,
        telemetry=frames, n_shards=P_, edge_cut=full_cut,
        halo_size=part.halo_size, local_batch=U, overflow=overflow,
        final_w=part.unshard_rows(np.asarray(w)),
        final_live=part.unshard_rows(np.asarray(live)),
        live_edges_hist=np.concatenate(live_hists),
        suppressed=suppressed, recompactions=recompactions)

"""Sparse event-driven engines: MP gossip + CL-ADMM over padded-neighbor
state (DESIGN.md §4).

State is O(n * k * p) instead of the reference engines' O(n^2 * p):

  theta (n, p)        — each agent's own model
  K     (n, k_max, p) — K[i, s] = agent i's copy of neighbor nbr_idx[i, s]

Two operating modes:

* **exact** (``sparse_async_gossip`` / ``sparse_async_admm``): one event per
  scan tick, consuming the same RNG stream and the same shared slot helpers
  (``core.sparse``) as the dense references — trajectories match those of
  ``core.model_propagation.async_gossip`` / ``core.collaborative.async_admm``
  bit-for-bit given the same seed (tests/test_simulate.py).

* **scenario** (``run_mp_scenario`` / ``run_cl_scenario`` /
  ``run_joint_scenario``): batched wake-ups from the scheduler with message
  drops, staleness, stragglers, churn and partitions.  All communication
  scatters of a round land before any model update reads, so batch
  collisions are deterministic (duplicate updates compute identical values
  from the same post-communication state).  The joint engine additionally
  re-estimates the collaboration graph online (DESIGN.md §13).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph_learning import prune_rows, reweight_rows
from repro.core.losses import AgentData
from repro.core.sparse import (batched_admm_primal, batched_model_update,
                               live_slots, neighbor_aggregate,
                               quadratic_primal_core, record_chunks,
                               sample_event)
from repro.kernels.dispatch import (ReproBackend, decode_slots,
                                    encode_slots, resolve, round_prefetch,
                                    round_scales, round_stale_src)
from repro.telemetry import metrics as tmetrics
from repro.telemetry.config import TelemetryConfig, telemetry_on
from repro.telemetry.frames import TelemetryFrames
from . import scheduler as sched
from .scheduler import (EventStream, NetworkConditions,
                        precompute_event_stream, stream_totals)
from .topology import SparseTopology


# ---------------------------------------------------------------------------
# Exact sparse MP gossip (mirrors core.model_propagation.async_gossip)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SparseTrace:
    """theta_hist: (n_records, n, p); comms_hist: cumulative pairwise comms."""

    theta_hist: np.ndarray
    comms_hist: np.ndarray
    final_theta: np.ndarray
    final_knowledge: np.ndarray   # (n, k_max, p) neighbor slots


def _mp_warm_start(tabs, theta_sol):
    """Solitary models everywhere the agent has knowledge (paper §3.2)."""
    theta = theta_sol
    K = theta_sol[tabs.nbr_idx]              # (n, k_max, p)
    return theta, K


@partial(jax.jit, static_argnames=("steps", "record_every", "backend"))
def _sparse_async_scan(nbr_idx, nbr_p, slot_cdf, deg_count, rev_slot,
                       theta_sol, c, alpha, key, steps, record_every,
                       theta0, K0, backend=None):
    n, p = theta0.shape
    abar = 1.0 - alpha

    def local_update(theta, K, l, tgt):
        agg = neighbor_aggregate(nbr_p[l], K[l], backend)
        new = (alpha * agg + abar * c[l] * theta_sol[l]) / (alpha + abar * c[l])
        return theta.at[tgt].set(new, mode="drop")  # scatter: unique targets

    def step(carry, key):
        theta, K = carry
        i, s = sample_event(key, n, slot_cdf, deg_count)
        # a degree-0 waker is a no-op: redirect every scatter out of bounds
        # (dropped) instead of letting the clamped slot fabricate an edge
        valid = deg_count[i] > 0
        j = nbr_idx[i, s]
        r = rev_slot[i, s]
        ti = jnp.where(valid, i, n)
        tj = jnp.where(valid, j, n)
        # communication step: exchange current self-models
        K = K.at[ti, s].set(theta[j], mode="drop")  # scatter: unique targets
        K = K.at[tj, r].set(theta[i], mode="drop")  # scatter: unique targets
        # update step for both endpoints
        theta = local_update(theta, K, i, ti)
        theta = local_update(theta, K, j, tj)
        return (theta, K), theta if record_every == 1 else None

    if record_every == 1:
        keys = jax.random.split(key, steps)
        (theta, K), hist = jax.lax.scan(step, (theta0, K0), keys)
        return theta, K, hist

    # repro-lint: disable=RPL007  callers normalize via core.sparse.record_chunks
    n_rec = steps // record_every

    def outer(carry, key):
        keys = jax.random.split(key, record_every)
        carry, _ = jax.lax.scan(lambda c_, k: (step(c_, k)[0], None),
                                carry, keys)
        return carry, carry[0]

    keys = jax.random.split(key, n_rec)
    (theta, K), hist = jax.lax.scan(outer, (theta0, K0), keys)
    return theta, K, hist


def sparse_async_gossip(topo: SparseTopology, theta_sol, c, alpha: float,
                        steps: int, seed: int = 0, record_every: int = 100,
                        backend: Optional[ReproBackend] = None) -> SparseTrace:
    """The paper's async gossip MP algorithm over O(n k p) sparse state.

    Bit-for-bit equal to ``core.model_propagation.async_gossip`` for the same
    (graph, seed) — same RNG stream, same shared slot arithmetic — while
    scaling to tens of thousands of agents.  The horizon follows the shared
    recording policy (``core.sparse.record_chunks``): floored to a whole
    number of record chunks, never zero.
    """
    tabs = topo.device_tables()
    n = topo.n
    theta_sol = jnp.asarray(theta_sol, jnp.float32).reshape(n, -1)
    c = jnp.asarray(c, jnp.float32)
    theta0, K0 = _mp_warm_start(tabs, theta_sol)
    key = jax.random.PRNGKey(seed)
    record_every, n_rec = record_chunks(steps, record_every)
    theta, K, hist = _sparse_async_scan(
        tabs.nbr_idx, tabs.nbr_p, tabs.slot_cdf, tabs.deg_count,
        tabs.rev_slot, theta_sol, c, alpha, key, n_rec * record_every,
        record_every, theta0, K0, backend)
    comms = 2 * record_every * (np.arange(hist.shape[0]) + 1)
    return SparseTrace(np.asarray(hist), comms, np.asarray(theta),
                       np.asarray(K))


# ---------------------------------------------------------------------------
# Synchronous sparse sweep (Eq. 5 over CSR) — the gather-mix hot loop
# ---------------------------------------------------------------------------


def sparse_sync_mp(topo: SparseTopology, theta_sol, c, alpha: float,
                   sweeps: int, use_kernel: bool = False,
                   backend: Optional[ReproBackend] = None) -> jnp.ndarray:
    """Fixed-point iteration Eq. (5) over the sparse neighbor layout.

    theta_{t+1}[i] = (alpha * sum_s P[i,s] theta_t[nbr[i,s]]
                      + (1-alpha) c_i theta_sol[i]) / (alpha + (1-alpha) c_i)

    One sweep = one "sparse_mix" op (O(n * k * p) gather-mix over all
    agents), resolved through ``kernels.dispatch``: fused XLA take/einsum on
    CPU/GPU, the Pallas gather kernel on TPU.  ``use_kernel=True`` is the
    deprecated spelling of ``backend=ReproBackend.using(
    sparse_mix="pallas_sparse", interpret=<off-TPU>)``.
    """
    from repro.core.model_propagation import mp_mix_operator
    tabs = topo.device_tables()
    n = topo.n
    theta_sol = jnp.asarray(theta_sol, jnp.float32).reshape(n, -1)
    c = jnp.asarray(c, jnp.float32)
    # (n, k) mixing slot weights + (n,) anchor coefficients
    w, b = mp_mix_operator(tabs.nbr_p, c, alpha)

    if use_kernel and backend is None:
        backend = ReproBackend.using(
            sparse_mix="pallas_sparse",
            interpret=None if jax.default_backend() == "tpu" else True)
    mix = resolve("sparse_mix", backend)

    def sweep(theta, _):
        return mix(theta, tabs.nbr_idx, w, b, theta_sol), None

    theta, _ = jax.lax.scan(jax.jit(sweep), theta_sol, None, length=sweeps)
    return theta


# ---------------------------------------------------------------------------
# Scenario engine: batched wake-ups + network conditions (MP gossip)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SimTrace:
    """Result of a scenario run.

    theta_hist:   (n_records, n, p)
    active_hist:  (n_records,) fraction of live agents
    delivered:    total messages delivered;  dropped: total lost
    rounds, events: totals (events = wake-ups = 2 attempted messages each)
    invalid:      never-valid wake-ups (all-dead draws, degree-0 wakers) —
                  excluded from delivered AND dropped, so the accounting
                  invariant is  delivered + dropped == 2 * (events - invalid)
    telemetry:    TelemetryFrames when the run was launched with
                  ``TelemetryConfig(enabled=True)``, else None
    serve:        ``repro.serve.ServeReport`` when the run carried an
                  inference-request stream (``ScenarioSpec.serve``), else
                  None — serving reads committed snapshots only, so its
                  presence never changes theta_hist (DESIGN.md §16)
    """

    theta_hist: np.ndarray
    active_hist: np.ndarray
    delivered: int
    dropped: int
    rounds: int
    events: int
    invalid: int = 0
    telemetry: Optional[TelemetryFrames] = None
    serve: Optional[object] = None


@partial(jax.jit, static_argnames=("conditions", "alpha", "batch",
                                   "record_every", "n_rec", "tel",
                                   "backend"))
def _scenario_scan(tabs, part_half, rates, theta_sol, c, carry0, keys, ts, *,
                   conditions: NetworkConditions, alpha: float, batch: int,
                   record_every: int, n_rec: int, tel: bool = False,
                   backend: Optional[ReproBackend] = None):
    """Module-level jitted runner so repeated calls with the same static
    (conditions, alpha, batch, record_every, n_rec) and shapes hit the jit
    cache — benchmark warmups genuinely pre-compile the timed run.

    ``tel`` (static) appends the telemetry accumulators — per-agent
    staleness counters, applied-update and drop-cause counters — to the
    carry and per-chunk objective/staleness snapshots to the outputs; at
    the default False the traced program is exactly the pre-telemetry
    scan (the ``*tstate`` unpacking leaves the carry a 7-tuple).

    ``backend`` (static) opts in to the fused ``round_step`` op
    (kernels/round_fuse.py): the carry threads the flat slot table, the
    software-pipelined prefetch of the *next* round's events/operands
    (drawn at the end of each round, after its scatters), and the per-row
    first-receipt flags.  The caller passes ``carry0=None`` and the plain
    unshifted keys; the fused carry is built here, in-jit, and the keys
    are shifted one round ahead internally so the carried prefetch
    consumes the bitwise-identical RNG stream.  At the default None the
    traced program is the historic per-op gather/mix/scatter sequence,
    unchanged."""
    n = theta_sol.shape[0]
    fused = backend is not None
    step_fn = resolve("round_step", backend) if fused else None
    if fused:
        km = tabs.nbr_idx.shape[1]
        no_stale = conditions.stale_prob == 0.0
        a_w = round_scales(tabs.nbr_p, c, alpha=alpha)
        theta_base = batched_model_update(
            tabs.nbr_p, theta_sol[tabs.nbr_idx], c, theta_sol, alpha)
    if fused and carry0 is None:
        # build the fused carry in-jit (warm start, slot table, round 0's
        # prefetch from the unshifted first key, first-receipt flags); the
        # scan then consumes the keys shifted one round ahead (the last
        # key's second draw is discarded), so the carried prefetch sees
        # the bitwise-identical RNG stream
        theta0, K0 = _mp_warm_start(tabs, theta_sol)
        active0 = jnp.ones((n,), bool)
        Ke0 = encode_slots(K0)
        flat = keys.reshape(-1, 2)
        k_ev, k_churn = jax.random.split(flat[0])
        ev0 = sched.draw_events(k_ev, conditions, tabs, part_half, active0,
                                rates, 0, batch)
        pf0 = (ev0,) + round_prefetch(
            theta0, theta0, Ke0, ev0.i, ev0.j, ev0.s, ev0.r,
            ev0.deliver_ij, ev0.deliver_ji, ev0.stale_ij, ev0.stale_ji,
            no_stale=no_stale) + (k_churn,)
        keys = jnp.concatenate([flat[1:], flat[-1:]]).reshape(
            n_rec, record_every, 2)
        carry0 = (theta0, Ke0, pf0, active0, jnp.int32(0), jnp.int32(0),
                  jnp.int32(0), jnp.zeros((n,), bool))
        if tel:
            carry0 = carry0 + (jnp.zeros((n,), jnp.int32), jnp.int32(0),
                               jnp.int32(0), jnp.int32(0), jnp.int32(0))

    def round_fn(carry, inp):
        t, key = inp
        if fused:
            # key is the *next* round's key; this round's events and churn
            # key arrive pre-drawn in the carried prefetch
            theta_in, K, pf, active, delivered, dropped, invalid, \
                got_ever, *tstate = carry
            ev, msg, tgt_row, enc, k_old, k_churn = pf
            # round t+1's events depend only on RNG and the post-churn
            # active set — never on theta — so draw them and gather their
            # stale-message source from theta_in BEFORE this round's
            # scatters.  Once that gather is theta_in's last read, XLA
            # scatters theta in place instead of copying the model table
            # every round (~25% of the fused round on CPU at n=10k); the
            # barrier pins the gather-before-scatter order.
            active2 = sched.churn_step(k_churn, conditions, active)
            k_ev2, k_churn2 = jax.random.split(key)
            ev2 = sched.draw_events(k_ev2, conditions, tabs, part_half,
                                    active2, rates, t + 1, batch)
            if no_stale:
                # zero staleness (static): no previous-model reads at all,
                # so the step is already theta_in's last consumer
                stale_src = None
            else:
                stale_src = round_stale_src(theta_in, ev2.i, ev2.j)
                theta_in, stale_src = jax.lax.optimization_barrier(
                    (theta_in, stale_src))
            theta, K, got_ever, _ = step_fn(theta_in, K, got_ever, msg,
                                            tgt_row, enc, k_old, theta_base,
                                            a_w)
        else:
            theta, K, theta_prev, active, delivered, dropped, invalid, \
                *tstate = carry
            theta_in = theta              # next round's "one-round-old" model
            k_ev, k_churn = jax.random.split(key)
            ev = sched.draw_events(k_ev, conditions, tabs, part_half, active,
                                   rates, t, batch)
        upd = jnp.concatenate([ev.i, ev.j])                      # (2B,)
        got = jnp.concatenate([ev.deliver_ji, ev.deliver_ij])
        got &= active[upd]

        if not fused:
            # --- communication: all scatters land before any update reads
            msg_i = jnp.where(ev.stale_ij[:, None], theta_prev[ev.i],
                              theta[ev.i])
            msg_j = jnp.where(ev.stale_ji[:, None], theta_prev[ev.j],
                              theta[ev.j])
            # undelivered messages scatter out of bounds -> dropped by XLA
            row_j = jnp.where(ev.deliver_ij, ev.j, n)
            row_i = jnp.where(ev.deliver_ji, ev.i, n)
            # scatter: last-write-wins — a repeated edge in one batch lands
            # the batch-order winner; kernels/round_fuse.round_step dedups
            # to the same winner so both paths agree bit-for-bit
            K = K.at[row_j, ev.r].set(msg_i, mode="drop")
            K = K.at[row_i, ev.s].set(msg_j, mode="drop")  # scatter: last-write-wins

            # --- update: endpoints that received a message recompute
            # Eq. (6) via the shared per-shard step
            # (core.sparse.batched_model_update — the same function the
            # partitioned engine applies to local rows)
            new = batched_model_update(tabs.nbr_p[upd], K[upd], c[upd],
                                       theta_sol[upd], alpha)
            # scatter: idempotent — duplicate agents in upd recompute the
            # same row from the same post-communication K
            theta = theta.at[jnp.where(got, upd, n)].set(new, mode="drop")

        delivered = delivered + jnp.sum(ev.deliver_ij) + jnp.sum(ev.deliver_ji)
        dropped = dropped + jnp.sum(ev.valid & ~ev.deliver_ij) \
            + jnp.sum(ev.valid & ~ev.deliver_ji)
        invalid = invalid + jnp.sum(~ev.valid)
        active = active2 if fused \
            else sched.churn_step(k_churn, conditions, active)
        if tel:
            stale, updates, d_link, d_churn, d_part = tstate
            stale = tmetrics.staleness_step(stale, got, upd, n)
            updates = updates + jnp.sum(got)
            link, churn, part = tmetrics.batch_drop_causes(
                ev.deliver_ij, ev.deliver_ji, ev.valid, ev.cut, ev.dead)
            tstate = (stale, updates, d_link + link, d_churn + churn,
                      d_part + part)
        if fused:
            # --- finish round t+1's prefetch: its stale-message gather ran
            # pre-scatter (above); the fresh-model and k_old gathers must
            # run here, *after* this round's scatters (the placement the
            # pipelined layout exists for)
            pf = (ev2,) + round_prefetch(
                theta, theta_in, K, ev2.i, ev2.j, ev2.s, ev2.r,
                ev2.deliver_ij, ev2.deliver_ji, ev2.stale_ij, ev2.stale_ji,
                stale_src=stale_src, no_stale=no_stale) + (k_churn2,)
            base = (theta, K, pf, active, delivered, dropped, invalid,
                    got_ever)
        else:
            base = (theta, K, theta_in, active, delivered, dropped, invalid)
        return base + tuple(tstate), None

    def outer(carry, inp):
        ks, t0 = inp
        inner_ts = t0 + jnp.arange(record_every)
        carry, _ = jax.lax.scan(round_fn, carry, (inner_ts, ks))
        frac = jnp.mean(carry[3].astype(jnp.float32))
        if tel:
            theta, K = carry[0], carry[1]
            if fused:
                K = decode_slots(K, km)
            obj = tmetrics.mp_local_objective(theta, K, tabs.nbr_p, c,
                                              theta_sol, alpha)
            stale, updates, d_link, d_churn, d_part = carry[8 if fused
                                                            else 7:]
            return carry, (theta, frac, obj, stale, updates, carry[4],
                           d_link, d_churn, d_part, carry[6])
        return carry, (carry[0], frac)

    return jax.lax.scan(outer, carry0, (keys, ts))


def run_mp_scenario(topo: SparseTopology, theta_sol, c, alpha: float,
                    conditions: NetworkConditions, rounds: int,
                    batch: int, seed: int = 0, record_every: int = 10,
                    telemetry: Optional[TelemetryConfig] = None,
                    backend: Optional[ReproBackend] = None) -> SimTrace:
    """MP gossip under a fault scenario, B wake-ups per round.

    Per round: draw an EventBatch, land every delivered message (scatter into
    the receivers' neighbor slots; stale deliveries read the sender's model
    from the previous round), then every endpoint that received something
    recomputes its model from its post-communication slots (update step
    Eq. 6).  Inactive (churned-out) agents neither wake nor update.

    The horizon is floored to a multiple of record_every (record_every is
    clamped to ``rounds`` first); SimTrace.rounds reports the actual count.
    ``telemetry=TelemetryConfig(enabled=True)`` additionally accumulates
    the DESIGN.md §14 metrics inside the scan carry and attaches them as
    ``SimTrace.telemetry``; the default leaves the compiled program — and
    the trajectory — exactly as without the argument.

    ``backend`` opts in to the fused ``round_step`` round body
    (kernels/round_fuse.py; auto keeps fused XLA on CPU/GPU and the Pallas
    megakernel on TPU).  The fused path carries a flat id-column slot
    table, telescopes the Eq. 6 update from scattered slot deltas, and
    software-pipelines the next round's event draw + operand gathers
    behind the current round's scatters — the same RNG stream and event
    sequence, so counters match the default path exactly and the
    trajectory agrees to fp rounding (not bit-for-bit); ``backend=None``
    keeps the historic program exactly.
    """
    tabs = topo.device_tables()
    n = topo.n
    theta_sol = jnp.asarray(theta_sol, jnp.float32).reshape(n, -1)
    c = jnp.asarray(c, jnp.float32)
    part_half = jnp.asarray(topo.partition_halves())
    key = jax.random.PRNGKey(seed)
    key, k_strag = jax.random.split(key)
    rates = sched.straggler_rates(k_strag, conditions, n)

    record_every, n_rec = record_chunks(rounds, record_every)
    tel = telemetry_on(telemetry)

    keys = jax.random.split(key, n_rec * record_every).reshape(
        n_rec, record_every, 2)
    ts = jnp.asarray((np.arange(n_rec) * record_every).astype(np.int32))
    if backend is not None:
        # fused round body: the carry (warm start, flat id-column slot
        # table, round 0's prefetch, per-row first-receipt flags) is built
        # INSIDE the jitted scan from theta_sol — see _scenario_scan — so
        # the ~n*k*p slot table is neither materialized eagerly nor copied
        # in as an argument buffer (tens of ms per call at n=10k)
        carry0 = None
    else:
        theta0, K0 = _mp_warm_start(tabs, theta_sol)
        carry0 = (theta0, K0, theta0, jnp.ones((n,), bool),
                  jnp.int32(0), jnp.int32(0), jnp.int32(0))
        if tel:
            carry0 = carry0 + (jnp.zeros((n,), jnp.int32), jnp.int32(0),
                               jnp.int32(0), jnp.int32(0), jnp.int32(0))
    carry, outs = _scenario_scan(
        tabs, part_half, rates, theta_sol, c, carry0, keys, ts,
        conditions=conditions, alpha=alpha, batch=batch,
        record_every=record_every, n_rec=n_rec, tel=tel, backend=backend)
    theta, K, _, active, delivered, dropped, invalid = carry[:7]
    total_rounds = n_rec * record_every
    frames = None
    if tel:
        (hist, active_hist, obj_h, stale_h, upd_h, del_h, link_h, churn_h,
         part_h, inv_h) = outs
        frames = TelemetryFrames(
            rounds=(np.arange(n_rec) + 1) * record_every,
            objective=np.asarray(obj_h), staleness=np.asarray(stale_h),
            updates=np.asarray(upd_h, np.int64),
            delivered=np.asarray(del_h, np.int64),
            drop_link=np.asarray(link_h, np.int64),
            drop_churn=np.asarray(churn_h, np.int64),
            drop_partition=np.asarray(part_h, np.int64),
            invalid=np.asarray(inv_h, np.int64))
    else:
        hist, active_hist = outs
    return SimTrace(np.asarray(hist), np.asarray(active_hist),
                    int(delivered), int(dropped), total_rounds,
                    total_rounds * batch, int(invalid), telemetry=frames)


# ---------------------------------------------------------------------------
# Exact sparse CL-ADMM (mirrors core.collaborative.async_admm, quadratic)
# ---------------------------------------------------------------------------


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SparseADMMState:
    """Sparse partial-consensus state: per-agent self model + per-slot
    copies/secondary/dual variables (all neighbor arrays (n, k_max, p))."""

    theta: jnp.ndarray
    K: jnp.ndarray
    Z_own: jnp.ndarray
    Z_nbr: jnp.ndarray
    L_own: jnp.ndarray
    L_nbr: jnp.ndarray


def init_sparse_admm(topo: SparseTopology, theta_sol) -> SparseADMMState:
    """Warm start (paper §4.2): share solitary models with neighbors."""
    tabs = topo.device_tables()
    n = topo.n
    theta_sol = jnp.asarray(theta_sol, jnp.float32).reshape(n, -1)
    p = theta_sol.shape[1]
    K = theta_sol[tabs.nbr_idx]                               # copies of nbrs
    Z_own = jnp.broadcast_to(theta_sol[:, None, :],
                             (n, topo.k_max, p)).astype(jnp.float32)
    Z_nbr = K.astype(jnp.float32)
    zeros = jnp.zeros((n, topo.k_max, p), jnp.float32)
    return SparseADMMState(theta_sol, K.astype(jnp.float32),
                           Z_own, Z_nbr, zeros, zeros)


def _sparse_primal_quadratic(st: SparseADMMState, l, nbr_w, deg_count, D,
                             mu, rho, data: AgentData,
                             backend=None) -> SparseADMMState:
    """Slot-row mirror of core.collaborative._primal_quadratic."""
    k = nbr_w.shape[1]
    live = jnp.arange(k) < deg_count[l]
    w = nbr_w[l]
    m_l = jnp.sum(data.mask[l])
    sx = jnp.sum(data.x[l] * data.mask[l][:, None], axis=0)
    theta_l, theta_js = quadratic_primal_core(
        w, live, st.Z_own[l], st.Z_nbr[l], st.L_own[l], st.L_nbr[l],
        D[l], m_l, sx, mu, rho, backend)
    # scatter: unique targets (scalar index l)
    K = st.K.at[l].set(jnp.where(live[:, None], theta_js, st.K[l]))
    theta = st.theta.at[l].set(theta_l)  # scatter: unique target (scalar index l)
    return SparseADMMState(theta, K, st.Z_own, st.Z_nbr, st.L_own, st.L_nbr)


def _sparse_edge_zl(st: SparseADMMState, i, s, j, r, rho) -> SparseADMMState:
    """Slot mirror of core.collaborative._edge_zl_update for edge (i, j):
    slot s is j's position in i's row, slot r is i's position in j's row."""
    z_i = 0.5 * ((st.L_own[i, s] + st.L_nbr[j, r]) / rho
                 + st.theta[i] + st.K[j, r])
    z_j = 0.5 * ((st.L_own[j, r] + st.L_nbr[i, s]) / rho
                 + st.theta[j] + st.K[i, s])
    # scatter: unique targets — (i, s) and (j, r) are the two directed
    # slots of one edge, distinct cells by construction
    Z_own = st.Z_own.at[i, s].set(z_i).at[j, r].set(z_j)
    Z_nbr = st.Z_nbr.at[i, s].set(z_j).at[j, r].set(z_i)  # scatter: unique targets
    L_own = st.L_own.at[i, s].add(rho * (st.theta[i] - z_i))
    L_own = L_own.at[j, r].add(rho * (st.theta[j] - z_j))
    L_nbr = st.L_nbr.at[i, s].add(rho * (st.K[i, s] - z_j))
    L_nbr = L_nbr.at[j, r].add(rho * (st.K[j, r] - z_i))
    return SparseADMMState(st.theta, st.K, Z_own, Z_nbr, L_own, L_nbr)


@dataclasses.dataclass
class SparseCLTrace:
    """Recorded sparse CL-ADMM trajectory (models, comms, final state)."""

    theta_hist: np.ndarray
    comms_hist: np.ndarray
    final: SparseADMMState


def sparse_async_admm(topo: SparseTopology, data: AgentData, mu: float,
                      rho: float, steps: int = 1000, seed: int = 0,
                      record_every: int = 50, theta_sol=None,
                      state: Optional[SparseADMMState] = None,
                      backend: Optional[ReproBackend] = None) -> SparseCLTrace:
    """Asynchronous decentralized CL-ADMM (paper §4.2) over sparse edge state.

    Quadratic loss only (exact closed-form primal).  Bit-for-bit equal to
    ``core.collaborative.async_admm(..., loss="quadratic")`` for the same
    (graph, seed) while storing O(n k p) instead of 5 x O(n^2 p).
    """
    tabs = topo.device_tables()
    n = topo.n
    D = jnp.asarray(tabs.deg_w, jnp.float32)
    if state is None:
        if theta_sol is None:
            raise ValueError("need theta_sol (warm start) or explicit state")
        state = init_sparse_admm(topo, theta_sol)

    def tick(st: SparseADMMState, key):
        i, s = sample_event(key, n, tabs.slot_cdf, tabs.deg_count)
        # degree-0 waker -> no-op: out-of-bounds targets drop every scatter
        valid = tabs.deg_count[i] > 0
        ti = jnp.where(valid, i, n)
        tj = jnp.where(valid, tabs.nbr_idx[i, s], n)
        r = tabs.rev_slot[i, s]
        st = _sparse_primal_quadratic(st, ti, tabs.nbr_w, tabs.deg_count, D,
                                      mu, rho, data, backend)
        st = _sparse_primal_quadratic(st, tj, tabs.nbr_w, tabs.deg_count, D,
                                      mu, rho, data, backend)
        return _sparse_edge_zl(st, ti, s, tj, r, rho)

    record_every, n_rec = record_chunks(steps, record_every)

    @jax.jit
    def run(state, key):
        def outer(st, key):
            keys = jax.random.split(key, record_every)
            st = jax.lax.scan(lambda s_, k: (tick(s_, k), None), st, keys)[0]
            return st, st.theta
        keys = jax.random.split(key, n_rec)
        return jax.lax.scan(outer, state, keys)

    final, hist = run(state, jax.random.PRNGKey(seed))
    comms = 2 * record_every * (np.arange(n_rec) + 1)
    return SparseCLTrace(np.asarray(hist), comms, final)


# ---------------------------------------------------------------------------
# Scenario engine: batched wake-ups + network conditions (CL-ADMM)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class CLSimTrace(SimTrace):
    """SimTrace plus the final sparse ADMM state (single-device runs)."""

    final: Optional[SparseADMMState] = None


def _reshape_stream(stream: EventStream, n_rec: int, record_every: int):
    """(rounds, B) event arrays -> (n_rec, record_every, B) scan blocks."""
    return jax.tree_util.tree_map(
        lambda x: x.reshape(n_rec, record_every, *x.shape[1:]),
        stream._replace(active_frac=None))


@partial(jax.jit, static_argnames=("mu", "rho", "backend", "tel", "primal"))
def _cl_scenario_scan(nbr_w, deg_count, D, m_counts, sx, state0, ev,
                      tel_args=(), xym=(), *, mu: float, rho: float,
                      backend=None, tel: bool = False, primal=None):
    """Batched-event CL-ADMM rounds over a precomputed event stream.

    One round = one (record_every-chunked) EventStream slice of B wake-ups:

    1. **primal phase** — every endpoint that completed its half of the
       handshake (its partner's payload was delivered and both ends are
       active, all folded into the stream's deliver flags) recomputes its
       exact quadratic primal from its own round-start rows
       (``core.sparse.batched_admm_primal``) and rewrites theta + its K row.
       Duplicate endpoints in a batch read identical round-start state and
       scatter identical values, so collisions are deterministic.
    2. **publish** — the round's payload snapshot is (post-primal theta and
       K, round-start duals); the previous round's snapshot serves the
       one-round-stale deliveries (same convention as the MP engine).
    3. **edge phase** — each delivered direction updates the *receiver's*
       (Z_own, Z_nbr, L_own, L_nbr) slots via the fused ``cl_edge_step``
       op (kernels/round_fuse.py; the ``admm_edge_halfstep`` math) from
       its own post-primal values and the partner's payload.  With both
       directions fresh this is exactly ``_sparse_edge_zl``; a dropped
       direction leaves that side's edge copies untouched (the mirrored
       copies may diverge — the asynchronous regime of DJAM,
       arXiv:1803.09737).

    ``tel`` (static) appends staleness/update accumulators to the carry
    and per-chunk (objective, staleness, updates) snapshots to the
    history; ``tel_args`` then carries the extra sufficient statistic
    (sxx,) the Eq. 7 objective needs.  At the default False the traced
    program is exactly the pre-telemetry scan.

    ``primal`` (static) is a PrimalSolver (``core.primal``); ``None``
    keeps the inline exact quadratic solve — the identical traced program
    the scan ran before primal solvers were pluggable.  A data-hungry
    solver (``primal.needs_data``) additionally receives the rows' padded
    local datasets via ``xym = (x, y, mask)``.
    """
    n, k = nbr_w.shape
    edge_fn = resolve("cl_edge_step", backend)

    def round_fn(carry, ev_t):
        st, pub_prev, *tstate = carry
        # --- primal phase: endpoints whose incoming payload was delivered
        upd = jnp.concatenate([ev_t.i, ev_t.j])                    # (2B,)
        got = jnp.concatenate([ev_t.deliver_ji, ev_t.deliver_ij])
        live_rows = jnp.arange(k)[None, :] < deg_count[upd][:, None]
        if primal is None:
            new_theta, theta_js = batched_admm_primal(
                nbr_w[upd], live_rows, st.Z_own[upd], st.Z_nbr[upd],
                st.L_own[upd], st.L_nbr[upd], D[upd], m_counts[upd],
                sx[upd], mu, rho, backend)
        else:
            xr = tuple(a[upd] for a in xym) if primal.needs_data else ()
            new_theta, theta_js = primal.solve_batch(
                nbr_w[upd], live_rows, st.Z_own[upd], st.Z_nbr[upd],
                st.L_own[upd], st.L_nbr[upd], D[upd], m_counts[upd],
                sx[upd], xr, st.theta[upd], mu, rho, backend)
        new_K = jnp.where(live_rows[:, :, None], theta_js, st.K[upd])
        rowu = jnp.where(got, upd, n)
        # scatter: idempotent — duplicate agents in upd derive identical
        # rows from the same round-start Z/L state
        theta = st.theta.at[rowu].set(new_theta, mode="drop")
        K = st.K.at[rowu].set(new_K, mode="drop")  # scatter: idempotent

        # --- publish: post-primal models, round-start duals
        pub = (theta, K, st.L_own, st.L_nbr)

        # --- edge phase: one half-step per delivered direction, as one
        # fused op (kernels/round_fuse.cl_edge_step — CPU/GPU resolve the
        # expression-identical XLA form, so the trajectory is bit-for-bit
        # the inline code's; TPU gets the Pallas megakernel)
        own_s = jnp.concatenate([ev_t.s, ev_t.r])
        oth_a = jnp.concatenate([ev_t.j, ev_t.i])
        oth_s = jnp.concatenate([ev_t.r, ev_t.s])
        stale = jnp.concatenate([ev_t.stale_ji, ev_t.stale_ij])
        Z_own, Z_nbr, L_own, L_nbr = edge_fn(
            theta, K, st.Z_own, st.Z_nbr, st.L_own, st.L_nbr, *pub_prev,
            upd, own_s, oth_a, oth_s, stale, got, rho=rho)

        st = SparseADMMState(theta, K, Z_own, Z_nbr, L_own, L_nbr)
        if tel:
            stale, updates = tstate
            stale = tmetrics.staleness_step(stale, got, upd, n)
            updates = updates + jnp.sum(got)
            tstate = (stale, updates)
        return (st, pub, *tstate), None

    def outer(carry, ev_blk):
        carry, _ = jax.lax.scan(round_fn, carry, ev_blk)
        st = carry[0]
        if tel:
            live = jnp.arange(k)[None, :] < deg_count[:, None]
            if primal is not None and primal.needs_data:
                loss_vec = primal.batch_local_loss(st.theta, *xym)
                obj = tmetrics.cl_local_objective_from_loss(
                    st.theta, st.K, nbr_w, live, D, loss_vec, mu)
            else:
                (sxx,) = tel_args
                obj = tmetrics.cl_local_objective(st.theta, st.K, nbr_w,
                                                  live, D, m_counts, sx,
                                                  sxx, mu)
            stale, updates = carry[2:]
            return carry, (st.theta, obj, stale, updates)
        return carry, st.theta

    pub0 = (state0.theta, state0.K, state0.L_own, state0.L_nbr)
    carry0 = (state0, pub0)
    if tel:
        carry0 = carry0 + (jnp.zeros((n,), jnp.int32), jnp.int32(0))
    carry, hist = jax.lax.scan(outer, carry0, ev)
    return carry[0], hist


def run_cl_scenario(topo: SparseTopology, data: AgentData, mu: float,
                    rho: float, conditions: NetworkConditions, rounds: int,
                    batch: int, seed: int = 0, record_every: int = 10,
                    theta_sol=None, state: Optional[SparseADMMState] = None,
                    stream: Optional[EventStream] = None,
                    backend: Optional[ReproBackend] = None,
                    telemetry: Optional[TelemetryConfig] = None,
                    primal=None) -> CLSimTrace:
    """Asynchronous CL-ADMM (paper §4.2) under a fault scenario.

    The same batched-event substrate as ``run_mp_scenario``: the fault
    process is materialized once (``scheduler.precompute_event_stream``,
    identical RNG schedule) and replayed, B wake-ups per round, with drops,
    staleness, stragglers, churn and partition windows all honored.  Pass
    ``stream`` to replay an externally drawn schedule (e.g. the exact
    engine's tick sequence) — its shape then fixes ``rounds`` x ``batch``.

    With all-default ``NetworkConditions`` every handshake completes and a
    round is exactly ``batch`` ticks of ``sparse_async_admm`` (same primal,
    same edge update, collisions coalesced deterministically).  The horizon
    follows the shared recording policy (``core.sparse.record_chunks``).

    ``primal`` selects the primal-phase solver (``core.primal``): ``None``
    / ``ExactQuadraticPrimal()`` is the closed-form quadratic solve;
    ``InexactPrimal(...)`` runs B AdamW steps on the local Lagrangian,
    supporting nonlinear losses and flattened neural agents — then
    ``theta_sol`` must carry the (n, p) flat parameter rows (e.g. from
    ``core.primal.solitary_adamw``), which fix the slot-row width p
    independently of the feature dimension of ``data.x``.
    """
    tabs = topo.device_tables()
    n = topo.n
    record_every, n_rec = record_chunks(rounds, record_every)
    total_rounds = n_rec * record_every
    if state is None:
        if theta_sol is None:
            raise ValueError("need theta_sol (warm start) or explicit state")
        state = init_sparse_admm(topo, theta_sol)
    if stream is None:
        stream = precompute_event_stream(
            tabs, jnp.asarray(topo.partition_halves()), conditions, batch,
            seed, total_rounds)
    else:
        if stream.i.shape[0] != total_rounds:
            raise ValueError(
                f"stream covers {stream.i.shape[0]} rounds but the clamped "
                f"horizon is {total_rounds}")
        batch = int(stream.i.shape[1])

    D = jnp.asarray(tabs.deg_w, jnp.float32)
    mask = jnp.asarray(data.mask, jnp.float32)
    x = jnp.asarray(data.x, jnp.float32)
    m_counts = jnp.sum(mask, axis=1)
    sx = jnp.sum(x * mask[:, :, None], axis=1)
    needs_data = primal is not None and primal.needs_data
    xym = (x, jnp.asarray(data.y, jnp.float32), mask) if needs_data else ()
    tel = telemetry_on(telemetry)
    tel_args = ()
    if tel and not needs_data:
        # the quadratic objective's sufficient statistic; data-hungry
        # solvers evaluate their loss directly from xym instead
        sxx = jnp.sum(mask * jnp.sum(x * x, axis=-1), axis=1)
        tel_args = (sxx,)

    ev = _reshape_stream(stream, n_rec, record_every)
    st, hist = _cl_scenario_scan(
        tabs.nbr_w, tabs.deg_count, D, m_counts, sx, state, ev, tel_args,
        xym, mu=mu, rho=rho, backend=backend, tel=tel, primal=primal)
    delivered, dropped, invalid = stream_totals(stream)
    active_hist = np.asarray(stream.active_frac).reshape(
        n_rec, record_every)[:, -1]
    frames = None
    if tel:
        hist, obj_h, stale_h, upd_h = hist
        frames = TelemetryFrames(
            rounds=(np.arange(n_rec) + 1) * record_every,
            objective=np.asarray(obj_h), staleness=np.asarray(stale_h),
            updates=np.asarray(upd_h, np.int64),
            **tmetrics.stream_chunk_totals(stream, n_rec, record_every))
    return CLSimTrace(theta_hist=np.asarray(hist), active_hist=active_hist,
                      delivered=delivered, dropped=dropped,
                      rounds=total_rounds, events=total_rounds * batch,
                      invalid=invalid, final=st, telemetry=frames)


# ---------------------------------------------------------------------------
# Joint model + collaboration-graph learning (DESIGN.md §13)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class JointSimTrace(SimTrace):
    """SimTrace plus the graph-learning outputs.

    final_w / final_live: (n, k) learned row-stochastic weights and the
        surviving-candidate mask (live == candidate mask when pruning is
        off);
    live_edges_hist: (n_records,) live directed-slot count per snapshot;
    suppressed: deliveries voided because the *receiver* had pruned the
        edge — a subset of ``delivered`` (the stream-level accounting
        invariant is unchanged).
    """

    final_w: Optional[np.ndarray] = None
    final_live: Optional[np.ndarray] = None
    live_edges_hist: Optional[np.ndarray] = None
    suppressed: int = 0


@partial(jax.jit, static_argnames=("alpha", "eta_graph", "lam", "graph_every",
                                   "prune_eps", "backend", "tel"))
def _joint_scenario_scan(w0, live0, theta0, K0, c, theta_sol, ev, ts, *,
                         alpha: float, eta_graph: float, lam: float,
                         graph_every: int, prune_eps, backend=None,
                         tel: bool = False):
    """Batched-event joint MP + graph-learning rounds over a precomputed
    event stream (Zantedeschi-style alternation; DESIGN.md §13).

    One round = the MP-gossip round of ``_scenario_scan`` — communication
    scatters, then the shared Eq. (6) update — except that the mixing
    weights are the *learned* row-stochastic ``w`` carried in the scan
    state rather than the frozen ``tabs.nbr_p``, and deliveries into a
    pruned receiver slot are voided (counted in ``suppressed``).  Every
    ``graph_every``-th round ends with the graph step
    (``core.graph_learning.reweight_rows`` + optional ``prune_rows``) over
    all agent rows.

    With ``eta_graph == 0`` (a static argument) the graph step is compiled
    out and ``w`` stays the initial ``nbr_p`` array: the round body is the
    identical arithmetic of ``_scenario_scan``, which is what makes the
    rate-0 trajectory bit-for-bit equal to ``run_mp_scenario``'s
    (tests/test_joint.py).
    """
    n = theta0.shape[0]
    prune = eta_graph > 0.0 and prune_eps is not None

    def round_fn(carry, inp):
        theta, K, theta_prev, w, live, suppressed, *tstate = carry
        theta_in = theta
        ev_t, t = inp

        # --- communication: all scatters land before any update reads
        msg_i = jnp.where(ev_t.stale_ij[:, None], theta_prev[ev_t.i],
                          theta[ev_t.i])
        msg_j = jnp.where(ev_t.stale_ji[:, None], theta_prev[ev_t.j],
                          theta[ev_t.j])
        if prune:
            ok_ij = ev_t.deliver_ij & live[ev_t.j, ev_t.r]
            ok_ji = ev_t.deliver_ji & live[ev_t.i, ev_t.s]
            suppressed = suppressed \
                + jnp.sum(ev_t.deliver_ij & ~ok_ij) \
                + jnp.sum(ev_t.deliver_ji & ~ok_ji)
        else:
            ok_ij, ok_ji = ev_t.deliver_ij, ev_t.deliver_ji
        row_j = jnp.where(ok_ij, ev_t.j, n)
        row_i = jnp.where(ok_ji, ev_t.i, n)
        # scatter: last-write-wins — a repeated edge in one batch lands the
        # batch-order winner (same policy as the scenario engine above)
        K = K.at[row_j, ev_t.r].set(msg_i, mode="drop")
        K = K.at[row_i, ev_t.s].set(msg_j, mode="drop")  # scatter: last-write-wins

        # --- update: Eq. (6) under the current learned weights
        upd = jnp.concatenate([ev_t.i, ev_t.j])
        got = jnp.concatenate([ok_ji, ok_ij])
        new = batched_model_update(w[upd], K[upd], c[upd], theta_sol[upd],
                                   alpha, backend)
        # scatter: idempotent — duplicate agents in upd recompute the same
        # row from the same post-communication K
        theta = theta.at[jnp.where(got, upd, n)].set(new, mode="drop")

        # --- graph step (compiled out entirely at rate 0)
        if eta_graph > 0.0:
            def do_graph(w, live):
                w2 = reweight_rows(theta, K, w, live, eta=eta_graph,
                                   lam=lam, backend=backend)
                if prune_eps is not None:
                    return prune_rows(w2, live, prune_eps)
                return w2, live

            w, live = jax.lax.cond(
                (t + 1) % graph_every == 0, do_graph,
                lambda w, live: (w, live), w, live)

        if tel:
            stale, updates = tstate
            stale = tmetrics.staleness_step(stale, got, upd, n)
            updates = updates + jnp.sum(got)
            tstate = (stale, updates)
        return (theta, K, theta_in, w, live, suppressed, *tstate), None

    def outer(carry, inp):
        carry, _ = jax.lax.scan(round_fn, carry, inp)
        theta, K, _, w, live, suppressed, *tstate = carry
        edges = jnp.sum(live & (w > 0))
        if tel:
            # objective under the *learned* weights (pruned slots weigh 0)
            obj = tmetrics.mp_local_objective(
                theta, K, jnp.where(live, w, 0.0), c, theta_sol, alpha)
            stale, updates = tstate
            return carry, (theta, edges, obj, stale, updates, suppressed)
        return carry, (theta, edges)

    carry0 = (theta0, K0, theta0, w0, live0, jnp.int32(0))
    if tel:
        carry0 = carry0 + (jnp.zeros((n,), jnp.int32), jnp.int32(0))
    return jax.lax.scan(outer, carry0, (ev, ts))


def run_joint_scenario(topo: SparseTopology, theta_sol, c, alpha: float,
                       conditions: NetworkConditions, rounds: int,
                       batch: int, seed: int = 0, record_every: int = 10, *,
                       eta_graph: float = 0.0, lam: float = 1.0,
                       graph_every: int = 1,
                       prune_eps: Optional[float] = None,
                       stream: Optional[EventStream] = None,
                       backend: Optional[ReproBackend] = None,
                       telemetry: Optional[TelemetryConfig] = None
                       ) -> JointSimTrace:
    """Joint MP gossip + collaboration-graph learning under a fault scenario
    (Zantedeschi et al. 2019 alternation on the DJAM-style asynchronous
    substrate; DESIGN.md §13).

    The same batched-event machinery as ``run_mp_scenario`` — identical RNG
    schedule, same ``NetworkConditions`` — with the topology itself now
    state: the *candidate* slot tables stay frozen (wake-ups remain uniform
    over the candidate neighbors, so the event stream is precomputed and
    replayable), while the mixing weights start at ``tabs.nbr_p`` and are
    re-estimated every ``graph_every`` rounds from local model distances
    (rate ``eta_graph``, sparsity temperature ``lam``).  ``prune_eps``
    permanently drops slots whose weight falls below it — the edge churn
    the partitioned engine's halo re-compaction keys off.

    ``eta_graph=0`` reproduces ``run_mp_scenario`` bit-for-bit on the
    identical event schedule (the graph step is compiled out).  The horizon
    follows the shared recording policy (``core.sparse.record_chunks``).
    """
    tabs = topo.device_tables()
    n = topo.n
    theta_sol = jnp.asarray(theta_sol, jnp.float32).reshape(n, -1)
    c = jnp.asarray(c, jnp.float32)
    record_every, n_rec = record_chunks(rounds, record_every)
    total_rounds = n_rec * record_every
    if stream is None:
        stream = precompute_event_stream(
            tabs, jnp.asarray(topo.partition_halves()), conditions, batch,
            seed, total_rounds)
    else:
        if stream.i.shape[0] != total_rounds:
            raise ValueError(
                f"stream covers {stream.i.shape[0]} rounds but the clamped "
                f"horizon is {total_rounds}")
        batch = int(stream.i.shape[1])

    theta0, K0 = _mp_warm_start(tabs, theta_sol)
    w0 = tabs.nbr_p
    live0 = live_slots(tabs.deg_count, topo.k_max)
    tel = telemetry_on(telemetry)
    ev = _reshape_stream(stream, n_rec, record_every)
    ts = jnp.arange(total_rounds, dtype=jnp.int32).reshape(
        n_rec, record_every)
    carry, outs = _joint_scenario_scan(
        w0, live0, theta0, K0, c, theta_sol, ev, ts, alpha=alpha,
        eta_graph=eta_graph, lam=lam, graph_every=graph_every,
        prune_eps=prune_eps, backend=backend, tel=tel)
    theta, K, _, w, live, suppressed = carry[:6]
    delivered, dropped, invalid = stream_totals(stream)
    active_hist = np.asarray(stream.active_frac).reshape(
        n_rec, record_every)[:, -1]
    frames = None
    if tel:
        hist, live_hist, obj_h, stale_h, upd_h, sup_h = outs
        frames = TelemetryFrames(
            rounds=(np.arange(n_rec) + 1) * record_every,
            objective=np.asarray(obj_h), staleness=np.asarray(stale_h),
            updates=np.asarray(upd_h, np.int64),
            suppressed=np.asarray(sup_h, np.int64),
            **tmetrics.stream_chunk_totals(stream, n_rec, record_every))
    else:
        hist, live_hist = outs
    return JointSimTrace(
        theta_hist=np.asarray(hist), active_hist=active_hist,
        delivered=delivered, dropped=dropped, rounds=total_rounds,
        events=total_rounds * batch, invalid=invalid,
        final_w=np.asarray(w), final_live=np.asarray(live),
        live_edges_hist=np.asarray(live_hist), suppressed=int(suppressed),
        telemetry=frames)

"""Training loop, personalized train_step factory, checkpointing."""

from .trainer import TrainConfig, TrainState, make_train_step, train_loop
from .checkpoint import save_checkpoint, load_checkpoint

__all__ = ["TrainConfig", "TrainState", "make_train_step", "train_loop",
           "save_checkpoint", "load_checkpoint"]

"""Personalized training: per-agent local steps + cross-agent coupling.

``make_train_step`` builds the jit-able step used by both the real training
loop and the multi-pod dry-run:

  1. reshape the global batch (B, ...) -> (A, b, ...) over the agent axis;
  2. per-agent loss/grad via jax.vmap over the stacked params
     (spmd_axis_name threads the agent mesh axes through the constraint
     system so GSPMD keeps everything agent-local);
  3. AdamW update (elementwise — agent dim transparent);
  4. coupling strategy (none / consensus / mp / cl) across the agent axis —
     the paper's technique as the replica-coordination collective.

The "solitary anchor" for MP coupling is a snapshot tree updated with an EMA
of each agent's own parameters (confidence-weighted), mirroring the paper's
theta_sol role without a second full training pass.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.coupling import CouplingConfig, CouplingState, make_coupling
from repro.models import Model
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    n_agents: int
    steps: int = 100
    optimizer: AdamWConfig = AdamWConfig()
    coupling: CouplingConfig = CouplingConfig(mode="mp")
    anchor_ema: float = 0.99       # solitary-anchor EMA rate
    log_every: int = 10


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class TrainState:
    params: Any          # agent-stacked (A, ...) tree
    opt_state: Any
    solitary: Any        # MP anchor tree (same structure)
    step: jnp.ndarray


def stack_params(params, n_agents: int, perturb: float = 0.0, key=None):
    """Replicate base params across agents (optionally de-correlated)."""
    def rep(leaf):
        return jnp.broadcast_to(leaf[None], (n_agents,) + leaf.shape)
    stacked = jax.tree_util.tree_map(rep, params)
    if perturb and key is not None:
        leaves, treedef = jax.tree_util.tree_flatten(stacked)
        keys = jax.random.split(key, len(leaves))
        leaves = [l + perturb * jax.random.normal(k, l.shape, l.dtype)
                  for l, k in zip(leaves, keys)]
        stacked = jax.tree_util.tree_unflatten(treedef, leaves)
    return stacked


def init_train_state(model: Model, tcfg: TrainConfig, key,
                     perturb: float = 0.0) -> TrainState:
    base = model.init(key)
    params = stack_params(base, tcfg.n_agents, perturb, key)
    opt_state = adamw_init(params, tcfg.optimizer)
    return TrainState(params=params, opt_state=opt_state,
                      solitary=params, step=jnp.zeros((), jnp.int32))


def make_train_step(model: Model, tcfg: TrainConfig,
                    coupling_state: CouplingState,
                    mesh=None, agent_axes: Tuple[str, ...] = ("pod", "data"),
                    spmd: bool = False, param_specs=None) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics).

    batch leaves are (B_global, ...) with B_global = A * b; they are
    reshaped to (A, b, ...) and vmapped over A. ``spmd=True`` threads the
    agent mesh axes through vmap (production / dry-run path);
    ``param_specs`` (stacked) enables the gossip coupling schedule to keep
    tensor-parallel shards local.
    """
    A = tcfg.n_agents
    names = tuple(a for a in agent_axes if mesh is None
                  or a in mesh.axis_names)
    couple = make_coupling(tcfg.coupling, coupling_state,
                           axis_names=names, mesh=mesh,
                           param_specs=param_specs)

    def per_agent_loss(params_a, batch_a):
        return model.loss(params_a, batch_a)

    vmap_kw = dict(spmd_axis_name=names) if spmd else {}
    grad_fn = jax.vmap(jax.value_and_grad(per_agent_loss, has_aux=True),
                       **vmap_kw)

    def split_batch(batch):
        def r(leaf):
            if leaf.ndim >= 1 and leaf.shape[0] % A == 0 and leaf.shape[0] >= A:
                return leaf.reshape((A, leaf.shape[0] // A) + leaf.shape[1:])
            return jnp.broadcast_to(leaf[None], (A,) + leaf.shape)
        out = {}
        for k, v in batch.items():
            if k == "positions3":   # (3, B, S) -> (A, 3, b, S)
                moved = jnp.moveaxis(v, 0, 1)                   # (B, 3, S)
                out[k] = jnp.moveaxis(r(moved), 2, 1)           # (A, 3, b, S)
            else:
                out[k] = r(v)
        return out

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        from repro.models.common import batch_axes
        batch_a = split_batch(batch)
        with batch_axes(()):   # agent axes live on the vmapped dim
            (loss, metrics), grads = grad_fn(state.params, batch_a)
        lr_scale = cosine_schedule(state.step, tcfg.steps,
                                   warmup=max(1, min(100, tcfg.steps // 10)))
        params, opt_state, gnorm = adamw_update(
            grads, state.opt_state, state.params, tcfg.optimizer, lr_scale)
        # solitary anchor: EMA of each agent's own trajectory
        ema = tcfg.anchor_ema
        solitary = jax.tree_util.tree_map(
            lambda s, p: (ema * s.astype(jnp.float32)
                          + (1 - ema) * p.astype(jnp.float32)).astype(s.dtype),
            state.solitary, params)
        params = couple(params, solitary, state.step)
        new_state = TrainState(params=params, opt_state=opt_state,
                               solitary=solitary, step=state.step + 1)
        out = {"loss": jnp.mean(loss), "loss_per_agent": loss,
               "grad_norm": gnorm,
               "ce": jnp.mean(metrics["ce"]), "aux": jnp.mean(metrics["aux"])}
        return new_state, out

    return train_step


def train_loop(model: Model, tcfg: TrainConfig, coupling_state: CouplingState,
               batches, key=None, state: Optional[TrainState] = None,
               mesh=None, log: Callable[[str], None] = print):
    """Simple host loop over a finite batch list / iterator."""
    key = key if key is not None else jax.random.PRNGKey(0)
    if state is None:
        state = init_train_state(model, tcfg, key)
    step_fn = jax.jit(make_train_step(model, tcfg, coupling_state, mesh=mesh))
    history = []
    t0 = time.time()
    for i, batch in enumerate(batches):
        if i >= tcfg.steps:
            break
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        state, metrics = step_fn(state, batch)
        if i % tcfg.log_every == 0 or i == tcfg.steps - 1:
            m = {k: float(v) for k, v in metrics.items()
                 if np.ndim(v) == 0}
            history.append({"step": i, **m})
            log(f"step {i:5d} loss {m['loss']:.4f} "
                f"ce {m['ce']:.4f} gnorm {m['grad_norm']:.2f} "
                f"({time.time() - t0:.1f}s)")
    return state, history

"""Checkpointing: npz shards + json manifest (no external deps).

Layout:  <dir>/step_<N>/arrays.npz + manifest.json
The manifest stores the flattened key paths + dtypes/shapes so restore can
rebuild the exact pytree (including TrainState dataclasses).
"""

from __future__ import annotations

import json
import os
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        # repro-lint: disable=RPL002  dict write keyed by tree path
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(tree, directory: str, step: int) -> str:
    path = os.path.join(directory, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    flat = _flatten_with_paths(tree)
    # bf16 is not a native npz dtype: store raw uint16 view + dtype tag
    arrays, meta = {}, {}
    for k, v in flat.items():
        if v.dtype == jnp.bfloat16:
            arrays[k] = v.view(np.uint16)
            meta[k] = {"dtype": "bfloat16", "shape": list(v.shape)}
        else:
            arrays[k] = v
            meta[k] = {"dtype": str(v.dtype), "shape": list(v.shape)}
    np.savez_compressed(os.path.join(path, "arrays.npz"), **arrays)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump({"step": step, "leaves": meta}, f, indent=1)
    return path


def load_checkpoint(tree_like, directory: str, step: int = -1):
    """Restore into the structure of ``tree_like`` (values replaced)."""
    if step < 0:
        steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                       if d.startswith("step_"))
        if not steps:
            raise FileNotFoundError(f"no checkpoints under {directory}")
        step = steps[-1]
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    flat = {}
    for k, meta in manifest["leaves"].items():
        arr = data[k]
        if meta["dtype"] == "bfloat16":
            arr = arr.view(jnp.bfloat16)
        flat[k] = jnp.asarray(arr)
    paths, treedef = jax.tree_util.tree_flatten_with_path(tree_like)
    leaves = []
    for path_t, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path_t)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        leaves.append(flat[key].reshape(leaf.shape).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["step"]

"""Serving demo: batched decode with slot-based continuous batching.

Trains nothing — initializes a small model, submits a mixed batch of
variable-length prompts, and decodes with the split-KV cache engine.

Run:  PYTHONPATH=src python examples/serve_demo.py
"""

import time

import jax
import numpy as np

from repro.models import Model, ModelConfig
from repro.serve import Engine, ServeConfig


def main():
    cfg = ModelConfig(name="serve-demo", family="dense", n_layers=4,
                      d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
                      vocab_size=512, attn_impl="ref", remat=False)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    print(f"model: {model.param_count()/1e6:.2f}M params")

    eng = Engine(model, params,
                 ServeConfig(batch_size=4, cache_len=128, max_new_tokens=24,
                             temperature=0.7, seed=0))
    rng = np.random.default_rng(0)
    t0 = time.time()
    rids = [eng.submit(rng.integers(0, cfg.vocab_size, (l,)))
            for l in (9, 17, 5, 30, 12, 3, 21, 8)]
    print(f"submitted {len(rids)} requests into 4 slots")
    results = eng.run()
    dt = time.time() - t0
    total_toks = sum(len(v) for v in results.values())
    for rid in rids:
        toks = results[rid]
        print(f" req {rid}: {len(toks)} tokens -> {toks[:10]}...")
    print(f"{total_toks} tokens in {dt:.1f}s "
          f"({total_toks/dt:.1f} tok/s, CPU, batched)")


if __name__ == "__main__":
    main()

"""Quickstart: the paper's two algorithms in 2 minutes on CPU.

1. Collaborative mean estimation (paper §5.1): solitary models, model
   propagation with confidence values (Prop. 1 + async gossip), and the
   errors of each.
2. Collaborative linear classification (paper §5.2): solitary vs consensus
   vs MP vs CL-ADMM accuracy.
3. Backend dispatch + vmapped sweeps: the same MP iterates under an
   explicit ReproBackend, and a (seed x alpha) grid in one jitted call.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.core import (closed_form, async_gossip, solitary_mean, solitary_gd,
                        confidences_from_counts, consensus_model, sync_admm,
                        synchronous)
from repro.data import (mean_estimation_problem,
                        linear_classification_problem, accuracy)
from repro.experiments import mean_estimation_trials, run_mp_sweep
from repro.kernels import ReproBackend


def mean_estimation():
    print("== collaborative mean estimation (n=100, eps=1) ==")
    g, data, targets, _ = mean_estimation_problem(n=100, eps=1.0, seed=0)
    sol = np.asarray(solitary_mean(data))
    conf = np.asarray(confidences_from_counts(data.counts))

    err = lambda th: float(np.mean((np.asarray(th)[:, 0] - targets) ** 2))
    star = closed_form(g, sol, conf, alpha=0.99)
    star_noc = closed_form(g, sol, np.ones(g.n), alpha=0.99)
    tr = async_gossip(g, sol, conf, alpha=0.99, steps=4000, record_every=500)

    print(f" solitary models        L2 = {err(sol):.4f}")
    print(f" MP closed form (no c)  L2 = {err(star_noc):.4f}")
    print(f" MP closed form (Prop1) L2 = {err(star):.4f}")
    print(f" MP async gossip        L2 = {err(tr.theta_hist[-1]):.4f} "
          f"after {tr.comms_hist[-1]} pairwise communications "
          f"(converging to the closed form; full curves in benchmarks)")


def linear_classification():
    print("== collaborative linear classification (n=60, p=30) ==")
    g, train, test, _ = linear_classification_problem(n=60, p=30, seed=0)
    sol = np.asarray(solitary_gd(train, "hinge", steps=250))
    conf = np.asarray(confidences_from_counts(train.counts))
    acc = lambda th: float(np.mean(accuracy(np.asarray(th), test)))

    cons = np.tile(np.asarray(consensus_model(train, "hinge")), (g.n, 1))
    mp = closed_form(g, sol, conf, alpha=0.99)
    cl = sync_admm(g, train, mu=0.05, rho=1.0, loss="hinge", steps=40,
                   k_steps=12, lr=0.05, theta_sol=sol).theta_hist[-1]

    print(f" solitary  acc = {acc(sol):.3f}")
    print(f" consensus acc = {acc(cons):.3f}   (Eq. 2 baseline)")
    print(f" MP        acc = {acc(mp):.3f}")
    print(f" CL (ADMM) acc = {acc(cl):.3f}")


def backends_and_sweeps():
    print("== backend dispatch + vmapped sweep ==")
    g, data, targets, _ = mean_estimation_problem(n=60, eps=1.0, seed=0)
    sol = np.asarray(solitary_mean(data))
    conf = np.asarray(confidences_from_counts(data.counts))

    # auto backend: fused XLA on CPU/GPU, Pallas compiled on TPU
    auto = synchronous(g, sol, conf, alpha=0.9, steps=300)
    # explicit override: validate the Pallas kernel via interpret mode
    # repro-lint: disable=RPL005  demo opts in to validate the kernel on CPU
    pallas_cpu = ReproBackend.using(mix="pallas", interpret=True)
    kern = synchronous(g, sol, conf, alpha=0.9, steps=300,
                       backend=pallas_cpu)
    print(f" |auto - pallas(interpret)| = "
          f"{float(np.abs(np.asarray(auto) - np.asarray(kern)).max()):.2e}")

    # 8 (seed, alpha) trials as ONE jitted program over the trial axis
    trials = mean_estimation_trials(seeds=range(4), alphas=[0.9, 0.99], n=60)
    res = run_mp_sweep(trials, sweeps=300)
    for a in (0.9, 0.99):
        sel = trials.alpha == np.float32(a)
        print(f" alpha={a}: mean final L2 over {int(sel.sum())} seeds = "
              f"{res.err_hist[sel, -1].mean():.4f}")


if __name__ == "__main__":
    mean_estimation()
    linear_classification()
    backends_and_sweeps()

"""Fault-scenario tour of the sparse network simulator.

Runs asynchronous model-propagation gossip (paper §3.2) over a clustered
topology under every registered fault scenario and reports how far each
run gets toward the synchronous fixed point — the paper's convergence
story (Theorem 1) stress-tested under message loss, stragglers, churn and
partitions.  Every run executes with the in-scan telemetry substrate
enabled (DESIGN.md §14): the per-scenario line is the telemetry report
row (objective, staleness p50/p99, drop attribution), and ``--out DIR``
records each scenario as a run directory (manifest.json + metrics.jsonl)
that ``tools/trace_report.py`` renders.

    PYTHONPATH=src python examples/network_sim_demo.py [--n 2000]
    PYTHONPATH=src python examples/network_sim_demo.py --smoke --out /tmp/runs
"""

import argparse
import os

import numpy as np

from repro.simulate import (ScenarioSpec, cluster_topology, get_scenario,
                            list_scenarios, run_scenario, sparse_sync_mp)
from repro.telemetry import (TelemetryConfig, build_manifest, format_row,
                             trace_rows, write_run)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--p", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=400)
    ap.add_argument("--alpha", type=float, default=0.9)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny problem (CI docs lane)")
    ap.add_argument("--out", default=None,
                    help="write one telemetry run directory per scenario "
                         "under this path (see tools/trace_report.py)")
    args = ap.parse_args()
    n = 300 if args.smoke else args.n
    rounds = 120 if args.smoke else args.rounds

    topo = cluster_topology(n, n_clusters=8, k_intra=5, bridges=6,
                            seed=args.seed)
    rng = np.random.default_rng(args.seed)
    # cluster-correlated targets: agents in a cluster share a model direction
    centers = rng.standard_normal((int(topo.groups.max()) + 1, args.p))
    theta_sol = (centers[topo.groups]
                 + 0.5 * rng.standard_normal((n, args.p))).astype(np.float32)
    c = rng.uniform(0.05, 1.0, n).astype(np.float32)

    print(f"topology: n={topo.n} k_max={topo.k_max} edges={topo.n_edges} "
          f"sparse_state={topo.state_bytes(args.p) / 2**20:.1f} MB "
          f"(dense would be {topo.dense_state_bytes(args.p) / 2**20:.0f} MB)")

    star = np.asarray(sparse_sync_mp(topo, theta_sol, c, args.alpha,
                                     sweeps=400))
    err0 = float(np.linalg.norm(theta_sol - star))

    batch = max(1, n // 10)
    for name in list_scenarios():
        sc = get_scenario(name)
        tr = run_scenario(ScenarioSpec(
            algo="mp", topology=topo, theta_sol=theta_sol, c=c,
            alpha=args.alpha, conditions=sc.make_conditions(rounds),
            rounds=rounds, batch=batch, seed=args.seed,
            record_every=max(1, rounds // 8),
            telemetry=TelemetryConfig(enabled=True)))
        err = float(np.linalg.norm(tr.theta_hist[-1] - star)) / err0
        rows = trace_rows(tr)
        print(f"{name:16s} rel_err={err:.3f}  {format_row(rows[-1])}")
        if args.out:
            d = write_run(os.path.join(args.out, name),
                          build_manifest(seed=args.seed, extra={
                              "scenario": name, "n": n, "rounds": rounds,
                              "alpha": args.alpha}),
                          rows)
            print(f"  -> {d}")
    print("\nrel_err = ||theta - theta*|| / ||theta_sol - theta*|| "
          "(lower is better; clean ~ the Theorem 1 limit)")


if __name__ == "__main__":
    main()

"""Fault-scenario tour of the sparse network simulator.

Runs asynchronous model-propagation gossip (paper §3.2) over a 2,000-agent
clustered topology under every registered fault scenario and reports how far
each run gets toward the synchronous fixed point — the paper's convergence
story (Theorem 1) stress-tested under message loss, stragglers, churn and
partitions.

    PYTHONPATH=src python examples/network_sim_demo.py [--n 2000]
"""

import argparse

import numpy as np

from repro.simulate import (cluster_topology, get_scenario, list_scenarios,
                            run_mp_scenario, sparse_sync_mp)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--p", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=400)
    ap.add_argument("--alpha", type=float, default=0.9)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    topo = cluster_topology(args.n, n_clusters=8, k_intra=5, bridges=6,
                            seed=args.seed)
    rng = np.random.default_rng(args.seed)
    # cluster-correlated targets: agents in a cluster share a model direction
    centers = rng.standard_normal((int(topo.groups.max()) + 1, args.p))
    theta_sol = (centers[topo.groups]
                 + 0.5 * rng.standard_normal((args.n, args.p))).astype(np.float32)
    c = rng.uniform(0.05, 1.0, args.n).astype(np.float32)

    print(f"topology: n={topo.n} k_max={topo.k_max} edges={topo.n_edges} "
          f"sparse_state={topo.state_bytes(args.p) / 2**20:.1f} MB "
          f"(dense would be {topo.dense_state_bytes(args.p) / 2**20:.0f} MB)")

    star = np.asarray(sparse_sync_mp(topo, theta_sol, c, args.alpha,
                                     sweeps=400))
    err0 = float(np.linalg.norm(theta_sol - star))

    batch = args.n // 10
    print(f"{'scenario':16s} {'rel_err':>8s} {'delivered':>10s} "
          f"{'dropped':>8s} {'active':>7s}")
    for name in list_scenarios():
        sc = get_scenario(name)
        tr = run_mp_scenario(topo, theta_sol, c, args.alpha,
                             sc.make_conditions(args.rounds),
                             rounds=args.rounds, batch=batch, seed=args.seed,
                             record_every=max(1, args.rounds // 8))
        err = float(np.linalg.norm(tr.theta_hist[-1] - star)) / err0
        print(f"{name:16s} {err:8.3f} {tr.delivered:10d} {tr.dropped:8d} "
              f"{tr.active_hist[-1]:7.2f}")
    print("\nrel_err = ||theta - theta*|| / ||theta_sol - theta*|| "
          "(lower is better; clean ~ the Theorem 1 limit)")


if __name__ == "__main__":
    main()

"""Joint graph + model learning recovering planted clusters (DESIGN.md §13).

Two clusters of agents estimate opposite means (the §5.1 mean-estimation
task with cluster structure planted in the targets).  The candidate
collaboration graph is deliberately polluted: every agent carries a few
links into the *wrong* cluster.  Running ``run_joint_scenario`` with graph
learning enabled, the agents re-estimate their outgoing edge weights from
local model distances (Zantedeschi et al. 2019-style sparse simplex
projection) while gossiping — and the learned graph drops the planted
inter-cluster edges while keeping >= 90% of the intra-cluster ones.

Runs execute with in-scan telemetry (DESIGN.md §14); the per-run metric
line is the telemetry report row, and ``--out DIR`` records each run for
``tools/trace_report.py``.

    PYTHONPATH=src python examples/joint_graph_demo.py            # full
    PYTHONPATH=src python examples/joint_graph_demo.py --smoke    # docs lane
"""

import argparse
import os

from repro.core.graph_learning import cluster_edge_recovery
from repro.data.synthetic import two_cluster_mean_problem
from repro.simulate import (NetworkConditions, ScenarioSpec,
                            planted_partition_topology, run_scenario)
from repro.telemetry import (TelemetryConfig, build_manifest, format_row,
                             trace_rows, write_run)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=200)
    ap.add_argument("--rounds", type=int, default=400)
    ap.add_argument("--eta", type=float, default=0.3)
    ap.add_argument("--lam", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny problem (CI docs lane)")
    ap.add_argument("--out", default=None,
                    help="write one telemetry run directory per eta under "
                         "this path (see tools/trace_report.py)")
    args = ap.parse_args()
    n = 60 if args.smoke else args.n
    rounds = 150 if args.smoke else args.rounds

    topo = planted_partition_topology(n, 2, k_intra=5, k_inter=2,
                                      seed=args.seed)
    labels, _, theta_sol, c = two_cluster_mean_problem(n, p=4,
                                                       seed=args.seed)
    tabs = topo.tables
    base = cluster_edge_recovery(tabs.nbr_idx, tabs.deg_count, tabs.nbr_p,
                                 labels)
    print(f"candidate graph: n={n} directed slots={int(tabs.deg_count.sum())}"
          f" intra={base.n_intra} inter={base.n_inter}"
          f" (inter weight mass before learning: {base.inter_mass:.2f})")

    for eta in (0.0, args.eta):
        tr = run_scenario(ScenarioSpec(
            algo="joint", topology=topo, theta_sol=theta_sol, c=c,
            alpha=0.9, conditions=NetworkConditions(), rounds=rounds,
            batch=n // 2, seed=args.seed, record_every=rounds // 3,
            eta_graph=eta, lam=args.lam, graph_every=5, prune_eps=1e-3,
            telemetry=TelemetryConfig(enabled=True)))
        rec = cluster_edge_recovery(tabs.nbr_idx, tabs.deg_count,
                                    tr.final_w, labels)
        rows = trace_rows(tr)
        tag = "frozen graph (eta=0)" if eta == 0 else f"learned (eta={eta})"
        print(f"{tag:22s} intra_recovered={rec.intra_recovered:5.1%} "
              f"inter_suppressed={rec.inter_suppressed:5.1%} "
              f"inter_mass={rec.inter_mass:.4f} "
              f"live_slots={int(tr.live_edges_hist[-1])}")
        print(f"{'':22s} {format_row(rows[-1])}")
        if args.out:
            d = write_run(os.path.join(args.out, f"eta-{eta:g}"),
                          build_manifest(seed=args.seed, extra={
                              "eta_graph": eta, "lam": args.lam, "n": n,
                              "rounds": rounds}),
                          rows)
            print(f"{'':22s} -> {d}")
    assert rec.intra_recovered >= 0.9, "cluster recovery regressed"
    print("OK: learned graph recovers the planted clusters")


if __name__ == "__main__":
    main()

"""Nonlinear personalized agents over the CL-ADMM substrate (DESIGN §18).

Each agent holds a tiny MLP whose flat parameter row rides the engines'
slot-row layout (models.flatten.ParamFlattener); the primal phase is B
AdamW steps on the reduced local Lagrangian (core.primal.InexactPrimal)
instead of the closed-form quadratic solve.  On federated_moons — one
rotated/flipped two-moons task per cluster, unbalanced per-agent sample
counts — collaboration beats purely-local training by a wide margin.

Run:  PYTHONPATH=src python examples/nonlinear_agents_demo.py [--smoke]
"""

import argparse

import numpy as np

from repro.core.primal import InexactPrimal, flat_predictor, solitary_adamw
from repro.data import federated_moons_problem, model_accuracy
from repro.models import MLPAgent
from repro.simulate import NetworkConditions, ScenarioSpec, run_scenario
from repro.telemetry import TelemetryConfig


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small/fast settings (docs + CI lanes)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    rounds, steps = (60, 100) if args.smoke else (300, 400)

    topo, train, test_x, test_y = federated_moons_problem(n=24,
                                                          seed=args.seed)
    model = MLPAgent(in_dim=2, hidden=(8,))
    predict = flat_predictor(model)

    sol = solitary_adamw(train, loss="logistic", model=model, steps=steps,
                         seed=args.seed)
    acc_sol = model_accuracy(sol, predict, test_x, test_y)
    print(f"purely-local AdamW accuracy: {float(acc_sol.mean()):.3f}")

    tr = run_scenario(ScenarioSpec(
        algo="cl", topology=topo, data=train, mu=0.5, rho=0.2,
        conditions=NetworkConditions(), rounds=rounds, batch=12,
        seed=args.seed, record_every=max(1, rounds // 3),
        theta_sol=np.asarray(sol),
        primal=InexactPrimal(loss="logistic", model=model, b_steps=10,
                             lr=0.1),
        telemetry=TelemetryConfig(enabled=True)))
    acc = model_accuracy(tr.theta_hist[-1], predict, test_x, test_y)
    obj = np.asarray(tr.telemetry.objective).sum(axis=1)
    print(f"collaborative accuracy:      {float(acc.mean()):.3f} "
          f"(+{100 * float(acc.mean() - acc_sol.mean()):.1f} points)")
    print(f"Eq.7 objective (telemetry):  {obj[0]:.1f} -> {obj[-1]:.1f}")
    assert float(acc.mean()) > float(acc_sol.mean())


if __name__ == "__main__":
    main()

"""The paper's mean-estimation scenario end-to-end, including the TPU-scale
coupling operator running the SAME problem (dense vs gossip schedules).

Shows that the framework's coupling layer (repro.coupling — the thing the
multi-pod dry-run shards across 256 chips) reproduces the paper's Prop. 1
optimum when iterated, and that the matching-gossip schedule is numerically
identical to the dense all-gather operator.

Run:  PYTHONPATH=src python examples/federated_moons.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import (closed_form, solitary_mean, confidences_from_counts)
from repro.coupling import CouplingConfig, make_state, dense_mix_tree
from repro.data import mean_estimation_problem


def main():
    g, data, targets, _ = mean_estimation_problem(n=60, eps=1.0, seed=0)
    sol = np.asarray(solitary_mean(data))
    conf = np.asarray(confidences_from_counts(data.counts))
    alpha = 0.9   # faster spectral convergence for the demo

    star = np.asarray(closed_form(g, sol, conf, alpha))
    err = lambda th: float(np.mean((np.asarray(th)[:, 0] - targets) ** 2))
    print(f"solitary L2  = {err(sol):.4f}")
    print(f"Prop.1 L2    = {err(star):.4f}")

    # the coupling layer's mixing operator, iterated == Eq. (5) iteration
    state = make_state(g, conf, alpha)
    cfg = CouplingConfig(mode="mp", alpha=alpha)
    theta = {"t": jnp.asarray(sol, jnp.float32)}
    anchor = {"t": jnp.asarray(sol, jnp.float32)}
    for i in range(400):
        theta = dense_mix_tree(theta, anchor, state, cfg)
    print(f"coupling-op  = {err(theta['t']):.4f} (400 iterates)")
    gap = float(np.abs(np.asarray(theta["t"]) - star).max())
    print(f"|coupling - closed_form|_max = {gap:.2e}")
    assert gap < 1e-3


if __name__ == "__main__":
    main()

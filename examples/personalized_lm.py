"""End-to-end driver (deliverable b): train personalized ~100M-param LMs for
a few hundred steps with graph coupling, comparing coupling modes.

8 agents on a random geometric graph; each agent's data comes from its own
2-gram token process (neighbors share structure). The run shows the paper's
central claim at LM scale: MP/CL coupling beats solitary training, while a
consensus model underfits the personalized distributions.

Run (CPU, ~10-20 min full / ~2 min with --tiny):
  PYTHONPATH=src python examples/personalized_lm.py [--tiny] [--steps N]
"""

import argparse
import time

import numpy as np

from repro.core import random_geometric_graph
from repro.coupling import CouplingConfig, make_state
from repro.data import PersonalizedLMConfig, personalized_token_stream
from repro.models import Model, ModelConfig
from repro.optim import AdamWConfig
from repro.train import TrainConfig, train_loop, save_checkpoint


def model_config(tiny: bool) -> ModelConfig:
    if tiny:
        return ModelConfig(name="plm-tiny", family="dense", n_layers=2,
                           d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                           vocab_size=256, attn_impl="ref", remat=False)
    # ~100M params: 12L x 512 with 32k vocab
    return ModelConfig(name="plm-100m", family="dense", n_layers=12,
                       d_model=512, n_heads=8, n_kv_heads=4, d_ff=1536,
                       vocab_size=32768, attn_impl="ref", remat=False)


def run(mode: str, args, graph, batches, model):
    tcfg = TrainConfig(
        n_agents=args.agents, steps=args.steps,
        optimizer=AdamWConfig(lr=1e-3, weight_decay=0.01),
        coupling=CouplingConfig(mode=mode, alpha=0.995, mu=0.02, every=4),
        log_every=max(args.steps // 10, 1))
    cstate = make_state(graph, np.ones(args.agents), tcfg.coupling.alpha)
    t0 = time.time()
    state, hist = train_loop(model, tcfg, cstate, batches,
                             log=lambda s: print(f"  [{mode}] {s}"))
    if args.ckpt:
        save_checkpoint(state, f"{args.ckpt}/{mode}", args.steps)
    return hist[-1]["loss"], time.time() - t0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--agents", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--modes", default="none,consensus,mp,cl")
    args = ap.parse_args()
    if args.tiny:
        args.steps = min(args.steps, 40)

    cfg = model_config(args.tiny)
    model = Model(cfg)
    print(f"model: {cfg.name} ({model.param_count()/1e6:.1f}M params), "
          f"{args.agents} agents, {args.steps} steps")
    graph = random_geometric_graph(args.agents, k=3, seed=0)
    lm = PersonalizedLMConfig(vocab_size=cfg.vocab_size,
                              n_agents=args.agents, seq_len=args.seq,
                              batch_per_agent=args.batch, seed=0)
    stream = personalized_token_stream(lm, graph)
    raw = [next(stream) for _ in range(args.steps)]
    B = args.agents * args.batch
    batches = [{"tokens": b[..., :-1].reshape(B, args.seq),
                "labels": b[..., 1:].reshape(B, args.seq)} for b in raw]

    results = {}
    for mode in args.modes.split(","):
        loss, dt = run(mode, args, graph, batches, model)
        results[mode] = loss
        print(f"{mode:10s} final loss {loss:.4f}  ({dt:.0f}s)")
    print("\nsummary (lower = better personalization):")
    for mode, loss in sorted(results.items(), key=lambda kv: kv[1]):
        print(f"  {mode:10s} {loss:.4f}")


if __name__ == "__main__":
    main()

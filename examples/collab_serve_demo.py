"""Gossip-backed personalization service demo (DESIGN.md §16).

Runs asynchronous MP gossip under faults with an inference-request
stream interleaved: per record chunk the scan commits a snapshot to the
agent-state store, the mixed-model cache is invalidated at exactly the
agents that round's deliveries rewrote, and every request arriving in
the chunk is served by batched decode from the committed personalized
rows.  Prints the service report (requests, cache hit rate, served
staleness percentiles) and proves the acceptance property: the gossip
trajectory is bit-for-bit identical to the serve-free run.

    PYTHONPATH=src python examples/collab_serve_demo.py            # full
    PYTHONPATH=src python examples/collab_serve_demo.py --smoke    # docs lane
"""

import argparse
import dataclasses

import numpy as np

from repro.simulate import (NetworkConditions, ScenarioSpec,
                            cluster_topology, precompute_serve_stream,
                            run_scenario)
from repro.telemetry import TelemetryConfig, format_row, trace_rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=2000)
    ap.add_argument("--p", type=int, default=16)
    ap.add_argument("--rounds", type=int, default=200)
    ap.add_argument("--rate", type=float, default=50.0,
                    help="inference requests per gossip round")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny problem (CI docs lane)")
    args = ap.parse_args()
    n = 300 if args.smoke else args.n
    rounds = 80 if args.smoke else args.rounds
    rate = 10.0 if args.smoke else args.rate

    topo = cluster_topology(n, n_clusters=8, k_intra=5, bridges=6,
                            seed=args.seed)
    rng = np.random.default_rng(args.seed)
    theta_sol = rng.standard_normal((n, args.p)).astype(np.float32)
    c = rng.uniform(0.05, 1.0, n).astype(np.float32)

    spec = ScenarioSpec(
        algo="mp", topology=topo, theta_sol=theta_sol, c=c, alpha=0.9,
        conditions=NetworkConditions(drop_prob=0.15, churn_rate=0.005),
        rounds=rounds, batch=max(1, n // 10), seed=args.seed,
        record_every=max(1, rounds // 8),
        telemetry=TelemetryConfig(enabled=True),
        serve=precompute_serve_stream(n, rounds, rate=rate, seed=args.seed),
        serve_batch=256)

    tr = run_scenario(spec)
    rep = tr.serve
    print(f"served {rep.requests} requests over {tr.rounds} rounds "
          f"({n} agents)")
    print(f"  cache: hit_rate={rep.hit_rate:.2%} hits={rep.hits} "
          f"misses={rep.misses} invalidations={rep.invalidations}")
    print(f"  served staleness: "
          f"p50={rep.staleness_percentile(50):.0f} "
          f"p99={rep.staleness_percentile(99):.0f} rounds")
    print(f"  last telemetry row: {format_row(trace_rows(tr)[-1])}")

    # acceptance: serving reads committed snapshots only — the gossip
    # trajectory must be bit-for-bit the serve-free one
    bare = run_scenario(dataclasses.replace(spec, serve=None,
                                            telemetry=None))
    assert np.array_equal(tr.theta_hist, bare.theta_hist)
    print("OK: gossip trajectory identical with and without serving")


if __name__ == "__main__":
    main()

"""Claims around §4: CL-ADMM (async + sync) reaches the minimizer of Q_CL."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (gaussian_kernel_graph, pad_datasets,
                        cl_objective, direct_minimize, async_admm, sync_admm,
                        solitary_mean, solitary_gd, LOSSES,
                        quadratic_loss)

jax.config.update("jax_enable_x64", False)


def mean_problem(seed=0, n=10):
    rng = np.random.default_rng(seed)
    pts = rng.standard_normal((n, 2)) * 0.5
    g = gaussian_kernel_graph(pts, sigma=1.0)
    targets = np.where(pts[:, 0] > 0, 1.0, -1.0)
    xs, ys = [], []
    for i in range(n):
        m = rng.integers(1, 15)
        xs.append(targets[i] + rng.standard_normal((m, 1)) * 2.0)
        ys.append(np.zeros(m))
    data = pad_datasets(xs, ys)
    return g, data


def hinge_problem(seed=0, n=8, p=5):
    rng = np.random.default_rng(seed)
    targets = np.zeros((n, p))
    targets[:, :2] = rng.standard_normal((n, 2))
    from repro.core import angular_kernel_graph
    g = angular_kernel_graph(targets, sigma=0.5, threshold=1e-4)
    xs, ys = [], []
    for i in range(n):
        m = rng.integers(3, 20)
        x = rng.uniform(-1, 1, (m, p))
        y = np.sign(x @ targets[i])
        y[y == 0] = 1.0
        xs.append(x)
        ys.append(y)
    return g, pad_datasets(xs, ys), targets


class TestQuadraticADMM:
    def test_sync_matches_direct(self):
        g, data = mean_problem(0)
        mu, rho = 0.1, 1.0
        star = np.asarray(direct_minimize(g, data, mu, "quadratic", steps=4000))
        sol = solitary_mean(data)
        tr = sync_admm(g, data, mu, rho, "quadratic", steps=150, theta_sol=sol)
        np.testing.assert_allclose(tr.theta_hist[-1], star, atol=2e-2)

    def test_async_matches_direct(self):
        g, data = mean_problem(1)
        mu, rho = 0.1, 1.0
        star = np.asarray(direct_minimize(g, data, mu, "quadratic", steps=4000))
        sol = solitary_mean(data)
        tr = async_admm(g, data, mu, rho, "quadratic", steps=4000,
                        record_every=500, theta_sol=sol)
        np.testing.assert_allclose(tr.theta_hist[-1], star, atol=5e-2)

    def test_objective_decreases(self):
        g, data = mean_problem(2)
        mu, rho = 0.2, 1.0
        sol = solitary_mean(data)
        tr = sync_admm(g, data, mu, rho, "quadratic", steps=60, theta_sol=sol)
        W = jnp.asarray(g.W, jnp.float32)
        q = [float(cl_objective(jnp.asarray(t), W, mu, quadratic_loss, data))
             for t in tr.theta_hist[::10]]
        assert q[-1] <= q[0] + 1e-6

    def test_cold_start_converges_too(self):
        """Paper: any init with Z(0) in C_E works; zeros is the simple option."""
        g, data = mean_problem(3)
        mu, rho = 0.1, 1.0
        star = np.asarray(direct_minimize(g, data, mu, "quadratic", steps=4000))
        zeros = np.zeros((g.n, 1))
        tr = sync_admm(g, data, mu, rho, "quadratic", steps=300, theta_sol=zeros)
        np.testing.assert_allclose(tr.theta_hist[-1], star, atol=3e-2)


class TestHingeADMM:
    def test_sync_approaches_direct_objective(self):
        g, data, _ = hinge_problem(0)
        mu, rho = 0.05, 1.0
        loss_fn = LOSSES["hinge"]
        W = jnp.asarray(g.W, jnp.float32)
        star = np.asarray(direct_minimize(g, data, mu, "hinge", steps=6000))
        q_star = float(cl_objective(jnp.asarray(star), W, mu, loss_fn, data))
        sol = solitary_gd(data, "hinge", steps=300)
        tr = sync_admm(g, data, mu, rho, "hinge", steps=120, k_steps=15,
                       lr=0.03, theta_sol=np.asarray(sol))
        q_admm = float(cl_objective(jnp.asarray(tr.theta_hist[-1]), W, mu,
                                    loss_fn, data))
        q_sol = float(cl_objective(jnp.asarray(sol), W, mu, loss_fn, data))
        # ADMM must close most of the gap between solitary init and optimum
        assert q_admm < q_star + 0.25 * (q_sol - q_star), (q_admm, q_star, q_sol)

    def test_async_improves_on_solitary(self):
        g, data, targets = hinge_problem(1)
        mu, rho = 0.05, 1.0
        sol = np.asarray(solitary_gd(data, "hinge", steps=300))
        tr = async_admm(g, data, mu, rho, "hinge", steps=2000, k_steps=10,
                        lr=0.03, record_every=500, theta_sol=sol)
        loss_fn = LOSSES["hinge"]
        W = jnp.asarray(g.W, jnp.float32)
        q_end = float(cl_objective(jnp.asarray(tr.theta_hist[-1]), W, mu,
                                   loss_fn, data))
        q_sol = float(cl_objective(jnp.asarray(sol), W, mu, loss_fn, data))
        assert q_end < q_sol


class TestPartialConsensus:
    def test_z_stays_in_constraint_set(self):
        """Z(t) in C_E by construction (paper step 2 maintains it)."""
        g, data = mean_problem(4)
        sol = solitary_mean(data)
        tr = sync_admm(g, data, 0.1, 1.0, "quadratic", steps=20, theta_sol=sol)
        st = tr.final
        Z_own, Z_nbr = np.asarray(st.Z_own), np.asarray(st.Z_nbr)
        for (i, j) in g.edges():
            np.testing.assert_allclose(Z_own[i, j], Z_nbr[j, i], atol=1e-5)
            np.testing.assert_allclose(Z_own[j, i], Z_nbr[i, j], atol=1e-5)

    def test_neighbor_copies_agree_at_convergence(self):
        """Partial consensus: Theta_i^j -> Theta_j^j."""
        g, data = mean_problem(5)
        sol = solitary_mean(data)
        tr = sync_admm(g, data, 0.1, 1.0, "quadratic", steps=200, theta_sol=sol)
        T = np.asarray(tr.final.T)
        for (i, j) in g.edges():
            np.testing.assert_allclose(T[i, j], T[j, j], atol=2e-2)
            np.testing.assert_allclose(T[j, i], T[i, i], atol=2e-2)

"""Backend dispatch registry: parity of every registered implementation
against ``reference`` (1e-5 on randomized inputs), selection rules, and the
no-direct-kernel-imports architecture invariant."""

import pathlib
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import dispatch, ref
from repro.kernels.dispatch import BackendUnavailable, ReproBackend, resolve


def _as_tuple(x):
    return x if isinstance(x, (tuple, list)) else (x,)


def _make_args(op, seed=0):
    """Randomized canonical-signature inputs (args, kwargs) for ``op``."""
    rng = np.random.default_rng(seed)
    f32 = jnp.float32
    if op == "mix":
        n, D = 12, 200
        return (jnp.asarray(rng.standard_normal((n, D)), f32),
                jnp.asarray(rng.standard_normal((n, D)), f32),
                jnp.asarray(rng.uniform(0, 1, (n, n)) / n, f32),
                jnp.asarray(rng.uniform(0, 1, n), f32)), {}
    if op == "sparse_mix":
        n, k, p = 50, 6, 40
        w = rng.uniform(0, 1, (n, k)).astype(np.float32)
        w[:, -1] = 0.0
        return (jnp.asarray(rng.standard_normal((n, p)), f32),
                jnp.asarray(rng.integers(0, n, (n, k)), jnp.int32),
                jnp.asarray(w),
                jnp.asarray(rng.uniform(0, 1, n), f32),
                jnp.asarray(rng.standard_normal((n, p)), f32)), {}
    if op == "admm_primal":
        k, p = 7, 20
        return (jnp.asarray(rng.uniform(0.1, 1, k), f32),
                jnp.asarray(rng.uniform(size=k) < 0.7),
                jnp.asarray(rng.standard_normal((k, p)), f32),
                jnp.asarray(rng.standard_normal((k, p)), f32),
                jnp.asarray(rng.standard_normal((k, p)), f32),
                jnp.asarray(rng.standard_normal((k, p)), f32),
                jnp.float32(2.5), jnp.float32(30.0),
                jnp.asarray(rng.standard_normal(p), f32),
                0.05, 1.3), {}
    if op == "admm_edge":
        E, p = 9, 33
        return tuple(jnp.asarray(rng.standard_normal((E, p)), f32)
                     for _ in range(8)), {"rho": 1.5}
    if op == "edge_reweight":
        B, k = 40, 7
        live = rng.uniform(size=(B, k)) < 0.8
        live[0] = False                       # an all-dead row stays zero
        w = rng.uniform(0, 1, (B, k)) * live
        w /= np.maximum(w.sum(axis=1, keepdims=True), 1e-9)
        return (jnp.asarray(rng.uniform(0, 4, (B, k)), f32),
                jnp.asarray(w, f32), jnp.asarray(live)), \
            {"eta": 0.3, "lam": 0.7}
    if op == "neighbor_aggregate":
        k, p = 9, 25
        return (jnp.asarray(rng.uniform(0, 1, k), f32),
                jnp.asarray(rng.standard_normal((k, p)), f32)), {}
    if op == "attention":
        B, S, H, hd = 1, 128, 2, 32
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        return (jax.random.normal(ks[0], (B, S, H, hd)),
                jax.random.normal(ks[1], (B, S, H, hd)),
                jax.random.normal(ks[2], (B, S, H, hd))), {"window": 48}
    if op == "round_step":
        n, k, p, m = 31, 5, 12, 40
        from repro.kernels import round_fuse
        K = jnp.asarray(rng.standard_normal((n, k, p)), f32)
        # collision-free scatter targets: duplicate (row, slot) targets with
        # conflicting payloads are resolution-order-dependent (a valid
        # realization either way) — duplicate semantics get their own
        # controlled tests in tests/test_round_fuse.py
        codes = rng.choice(n * k, size=m, replace=False)
        deliver = rng.uniform(size=m) < 0.7
        return (jnp.asarray(rng.standard_normal((n, p)), f32),
                round_fuse.encode_slots(K),
                jnp.asarray(rng.uniform(size=n) < 0.5),   # got_ever
                jnp.asarray(rng.standard_normal((m, p)), f32),     # msg
                jnp.asarray(np.where(deliver, codes // k, n), jnp.int32),
                jnp.asarray(np.where(deliver, codes, n * k), jnp.int32),
                jnp.asarray(rng.standard_normal((m, p)), f32),     # k_old
                jnp.asarray(rng.standard_normal((n, p)), f32),     # base
                jnp.asarray(rng.uniform(0.1, 1, n * k), f32)), {}  # a_w
    if op == "cl_edge_step":
        n, k, p, E = 23, 4, 10, 18
        arr3 = lambda: jnp.asarray(rng.standard_normal((n, k, p)), f32)
        arr2 = lambda: jnp.asarray(rng.standard_normal((n, p)), f32)
        codes = rng.choice(n * k, size=E, replace=False)  # collision-free
        return (arr2(), arr3(), arr3(), arr3(), arr3(), arr3(),
                arr2(), arr3(), arr3(), arr3(),
                jnp.asarray(codes // k, jnp.int32),
                jnp.asarray(codes % k, jnp.int32),
                jnp.asarray(rng.integers(0, n, E), jnp.int32),
                jnp.asarray(rng.integers(0, k, E), jnp.int32),
                jnp.asarray(rng.uniform(size=E) < 0.4),
                jnp.asarray(rng.uniform(size=E) < 0.7)), {"rho": 1.1}
    if op == "admm_primal_inexact":
        from repro.core.losses import guarded_loss
        from repro.optim.adamw import AdamWConfig
        k, p, m = 5, 6, 11
        mask = (rng.uniform(size=m) < 0.7).astype(np.float32)
        return (jnp.asarray(rng.uniform(0.1, 1, k), f32),
                jnp.asarray(rng.uniform(size=k) < 0.7),
                jnp.asarray(rng.standard_normal((k, p)), f32),
                jnp.asarray(rng.standard_normal((k, p)), f32),
                jnp.asarray(rng.standard_normal((k, p)), f32),
                jnp.asarray(rng.standard_normal((k, p)), f32),
                jnp.float32(2.0),
                jnp.asarray(rng.standard_normal((m, p)), f32),
                jnp.asarray(rng.standard_normal(m), f32),
                jnp.asarray(mask),
                jnp.asarray(rng.standard_normal(p), f32),
                0.3, 1.2), {"loss_fn": guarded_loss("quadratic"),
                            "b_steps": 4,
                            "opt": AdamWConfig(lr=0.1, weight_decay=0.0,
                                               grad_clip=0.0,
                                               moment_dtype=jnp.float32)}
    raise NotImplementedError(op)


@pytest.mark.parametrize("op", dispatch.ops())
def test_all_impls_match_reference(op):
    """Acceptance: every registered implementation of every op agrees with
    ``reference`` within 1e-5 on randomized inputs (Pallas via the explicit
    interpret opt-in off-TPU)."""
    args, kw = _make_args(op)
    want = _as_tuple(resolve(op, ReproBackend.using(**{op: "reference"}))(
        *args, **kw))
    for impl in dispatch.implementations(op):
        backend = ReproBackend.using(interpret=True, **{op: impl})
        got = _as_tuple(resolve(op, backend)(*args, **kw))
        assert len(got) == len(want), (op, impl)
        for g, w in zip(got, want):
            np.testing.assert_allclose(
                np.asarray(g, np.float32), np.asarray(w, np.float32),
                atol=1e-5, rtol=1e-5,
                err_msg=f"{op}/{impl} diverges from reference")


@pytest.mark.parametrize("op,seed", [(op, s) for op in ("mix", "sparse_mix",
                                                        "admm_primal")
                                     for s in (1, 2, 3)])
def test_parity_extra_random_draws(op, seed):
    args, kw = _make_args(op, seed=seed)
    want = _as_tuple(resolve(op, ReproBackend.using(**{op: "reference"}))(
        *args, **kw))
    for impl in dispatch.implementations(op):
        got = _as_tuple(resolve(
            op, ReproBackend.using(interpret=True, **{op: impl}))(*args, **kw))
        for g, w in zip(got, want):
            np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                       atol=1e-5, rtol=1e-5)


class TestSelectionRules:
    def test_every_op_has_reference_and_xla(self):
        for op in dispatch.ops():
            impls = dispatch.implementations(op)
            assert "reference" in impls, op
            assert "xla" in impls, op

    def test_auto_never_picks_interpret_silently(self):
        """Off-TPU, auto must resolve to the fused XLA impl, not Pallas
        interpret (the satellite fix: interpret is explicit opt-in only)."""
        if jax.default_backend() == "tpu":
            pytest.skip("auto picks compiled Pallas on TPU by design")
        for op in dispatch.ops():
            fn = resolve(op)
            entry = dispatch._REGISTRY[op]["xla"]
            assert fn is entry.make(False), op

    def test_pallas_off_tpu_requires_explicit_interpret(self):
        if jax.default_backend() == "tpu":
            pytest.skip("Pallas compiles on TPU")
        with pytest.raises(BackendUnavailable):
            resolve("mix", ReproBackend.using(mix="pallas"))
        # explicit opt-in works
        fn = resolve("mix", ReproBackend.using(mix="pallas", interpret=True))
        args, _ = _make_args("mix")
        want = ref.graph_mix(*args)
        np.testing.assert_allclose(np.asarray(fn(*args)), np.asarray(want),
                                   atol=1e-5, rtol=1e-5)

    def test_explicit_interpret_false_beats_env_opt_in(self, monkeypatch):
        """REPRO_PALLAS_INTERPRET=1 in the env never changes what auto
        selects — interpret mode is a property of *how* an explicitly
        requested Pallas impl runs, not a selection preference, so auto
        resolves to fused XLA with or without the env opt-in."""
        if jax.default_backend() == "tpu":
            pytest.skip("off-TPU selection rule")
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
        xla = dispatch._REGISTRY["mix"]["xla"].make(False)
        assert resolve("mix", ReproBackend(interpret=False)) is xla
        assert resolve("mix", ReproBackend()) is xla
        # the env opt-in still unlocks an explicitly requested Pallas impl
        fn = resolve("mix", ReproBackend.using(mix="pallas"))
        args, _ = _make_args("mix")
        np.testing.assert_allclose(np.asarray(fn(*args)),
                                   np.asarray(ref.graph_mix(*args)),
                                   atol=1e-5, rtol=1e-5)

    def test_auto_never_picks_interpret_impl_any_platform(self, monkeypatch):
        """The satellite rule, pinned for both platforms: auto resolution
        must never return an impl that would run in Pallas interpret mode —
        off-TPU it falls back to XLA even under the env opt-in, and on TPU
        it skips interpret-only registrations (admm_edge's Pallas kernel)."""
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
        for platform in ("cpu", "gpu", "tpu"):
            monkeypatch.setattr(dispatch, "_platform", lambda p=platform: p)
            for op in dispatch.ops():
                name = dispatch._auto_impl(op)
                entry = dispatch._REGISTRY[op][name]
                assert not entry.interpret_only, (platform, op, name)
                if platform != "tpu":
                    assert not entry.pallas, (platform, op, name)

    def test_interpret_only_impl_needs_opt_in_everywhere(self, monkeypatch):
        """admm_edge/pallas is interpret-only: unavailable and unresolvable
        without the interpret opt-in even on TPU, still usable as an
        explicit validation target with it."""
        entry = dispatch._REGISTRY["admm_edge"]["pallas"]
        assert entry.interpret_only
        for platform in ("cpu", "tpu"):
            monkeypatch.setattr(dispatch, "_platform", lambda p=platform: p)
            assert not dispatch.available("admm_edge", "pallas",
                                          interpret=False)
            assert dispatch.available("admm_edge", "pallas", interpret=True)
            with pytest.raises(BackendUnavailable):
                resolve("admm_edge", ReproBackend.using(
                    admm_edge="pallas", interpret=False))

    def test_override_and_default_selection(self):
        b = ReproBackend.using(mix="reference")
        assert resolve("mix", b) is ref.graph_mix
        b2 = ReproBackend(default="reference")
        assert resolve("sparse_mix", b2) is ref.sparse_gather_mix

    def test_unknown_op_and_impl_raise(self):
        with pytest.raises(KeyError):
            resolve("no_such_op")
        with pytest.raises(KeyError):
            resolve("mix", ReproBackend.using(mix="no_such_impl"))

    def test_backend_is_hashable_static_arg(self):
        b = ReproBackend.using(mix="xla", interpret=True)
        assert hash(b) == hash(ReproBackend.using(mix="xla", interpret=True))

    def test_register_new_impl(self):
        name = "test_tmp_impl"
        try:
            @dispatch.register("mix", name)
            def _mix_double_checked(theta, theta_sol, A, b):
                return ref.graph_mix(theta, theta_sol, A, b)

            args, _ = _make_args("mix")
            got = resolve("mix", ReproBackend.using(mix=name))(*args)
            np.testing.assert_allclose(np.asarray(got),
                                       np.asarray(ref.graph_mix(*args)),
                                       atol=1e-6)
        finally:
            dispatch._REGISTRY["mix"].pop(name, None)


def test_no_direct_kernel_imports_outside_kernels():
    """Acceptance: production call sites resolve kernels through dispatch —
    no module outside kernels/ imports a concrete kernel module.

    The check itself lives in the AST linter (tools/lint rule RPL001,
    which sees import *nodes* instead of regex-matching source lines);
    this test is a thin wrapper so the invariant still fails loudly in
    plain pytest runs without the CI static-analysis lane."""
    root = pathlib.Path(__file__).resolve().parent.parent
    if str(root) not in sys.path:
        sys.path.insert(0, str(root))
    from tools.lint import lint_paths

    findings = lint_paths(
        [str(root / p) for p in ("src/repro", "benchmarks", "examples")],
        select=["RPL001"], root=str(root))
    offenders = [f.format() for f in findings if not f.waived]
    assert not offenders, f"direct kernel imports: {offenders}"

"""Unified scenario API (``ScenarioSpec`` / ``run_scenario``): bit-for-bit
parity with the six undeprecated engine entry points across every
algo x sharding cell, deprecation of the legacy wrappers, and spec
validation."""

import dataclasses
import warnings

import numpy as np
import pytest

import repro.simulate as sim
from repro.core.losses import pad_datasets, solitary_mean
from repro.simulate import (NetworkConditions, ScenarioSpec,
                            random_geometric_topology, run_scenario)
from repro.simulate import engines as engines_mod
from repro.simulate import partition as partition_mod

COND = NetworkConditions(drop_prob=0.15, stale_prob=0.2)
RUN_KW = dict(rounds=40, batch=8, seed=3, record_every=10)
JOINT_KW = dict(eta_graph=0.3, lam=1.0, graph_every=5, prune_eps=1e-3)


@pytest.fixture(scope="module")
def problem():
    n = 60
    topo = random_geometric_topology(n, k=4, seed=0)
    rng = np.random.default_rng(0)
    xs = [rng.standard_normal((int(rng.integers(1, 8)), 3))
          for _ in range(n)]
    data = pad_datasets(xs, [np.zeros(len(x)) for x in xs])
    sol = np.asarray(solitary_mean(data), np.float32)
    c = np.full(n, 0.8, np.float32)
    return topo, data, sol, c


def _spec(problem, algo, sharded):
    topo, data, sol, c = problem
    kw = dict(algo=algo, topology=topo, conditions=COND, sharded=sharded,
              **RUN_KW)
    if algo == "cl":
        return ScenarioSpec(data=data, mu=0.1, rho=1.0, theta_sol=sol, **kw)
    kw.update(theta_sol=sol, c=c, alpha=0.9)
    if algo == "joint":
        kw.update(JOINT_KW)
    return ScenarioSpec(**kw)


def _legacy(problem, algo, sharded, runner):
    """Run the undeprecated implementation for one parity cell."""
    topo, data, sol, c = problem
    if algo == "cl":
        return runner(topo, data, 0.1, 1.0, COND, theta_sol=sol, **RUN_KW)
    if algo == "joint":
        return runner(topo, sol, c, 0.9, COND, **RUN_KW, **JOINT_KW)
    return runner(topo, sol, c, 0.9, COND, **RUN_KW)


CELLS = [
    ("mp", False, engines_mod.run_mp_scenario),
    ("cl", False, engines_mod.run_cl_scenario),
    ("joint", False, engines_mod.run_joint_scenario),
    ("mp", True, partition_mod.run_mp_scenario_sharded),
    ("cl", True, partition_mod.run_cl_scenario_sharded),
    ("joint", True, partition_mod.run_joint_scenario_sharded),
]


class TestSpecParity:
    @pytest.mark.parametrize("algo,sharded,runner",
                             CELLS, ids=lambda v: str(v))
    def test_bit_for_bit(self, problem, algo, sharded, runner):
        """Acceptance: run_scenario(spec) reproduces every legacy entry
        point exactly (maxerr 0.0) — the spec path is pure dispatch."""
        ref = _legacy(problem, algo, sharded, runner)
        tr = run_scenario(_spec(problem, algo, sharded))
        assert type(tr) is type(ref)
        assert np.array_equal(tr.theta_hist, ref.theta_hist)
        assert (tr.delivered, tr.dropped, tr.events, tr.invalid) \
            == (ref.delivered, ref.dropped, ref.events, ref.invalid)
        if algo == "joint":
            assert np.array_equal(tr.final_w, ref.final_w)
            assert np.array_equal(tr.final_live, ref.final_live)


class TestLegacyWrappers:
    @pytest.mark.parametrize("name", [
        "run_mp_scenario", "run_cl_scenario", "run_joint_scenario",
        "run_mp_scenario_sharded", "run_cl_scenario_sharded",
        "run_joint_scenario_sharded"])
    def test_package_name_is_deprecated_wrapper(self, problem, name):
        """The package-level names warn and reproduce the spec path."""
        algo = ("cl" if "cl" in name else
                "joint" if "joint" in name else "mp")
        sharded = name.endswith("_sharded")
        wrapper = getattr(sim, name)
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            tr = _legacy(problem, algo, sharded, wrapper)
        assert any(issubclass(w.category, DeprecationWarning)
                   for w in caught)
        ref = run_scenario(_spec(problem, algo, sharded))
        assert np.array_equal(tr.theta_hist, ref.theta_hist)

    def test_undeprecated_impls_do_not_warn(self, problem):
        with warnings.catch_warnings(record=True) as caught:
            warnings.simplefilter("always")
            _legacy(problem, "mp", False, engines_mod.run_mp_scenario)
        assert not any(issubclass(w.category, DeprecationWarning)
                       for w in caught)


class TestSpecValidation:
    def test_unknown_algo(self, problem):
        topo = problem[0]
        with pytest.raises(ValueError, match="algo"):
            ScenarioSpec(algo="sgd", topology=topo, conditions=COND,
                         rounds=10, batch=4)

    def test_mp_rejects_stream_override(self, problem):
        topo, data, sol, c = problem
        fake = object()  # rejected before it is ever inspected
        with pytest.raises(ValueError, match="inline"):
            ScenarioSpec(algo="mp", topology=topo, conditions=COND,
                         rounds=10, batch=4, theta_sol=sol, c=c,
                         stream=fake)

    def test_missing_payload(self, problem):
        topo = problem[0]
        spec = ScenarioSpec(algo="cl", topology=topo, conditions=COND,
                            rounds=10, batch=4)
        with pytest.raises(ValueError, match="requires ScenarioSpec.data"):
            run_scenario(spec)

    def test_run_scenario_sweep(self, problem):
        """experiments.run_scenario_sweep: cartesian grid over spec fields,
        each cell a plain run_scenario of the replaced spec."""
        from repro.experiments import run_scenario_sweep
        spec = _spec(problem, "mp", False)
        res = run_scenario_sweep(spec, seed=[0, 1], alpha=[0.5, 0.9])
        assert res.n_trials == 4
        assert res.cells[0] == {"seed": 0, "alpha": 0.5}
        direct = run_scenario(dataclasses.replace(spec, seed=0, alpha=0.5))
        assert np.array_equal(res.traces[0].theta_hist, direct.theta_hist)
        with pytest.raises(ValueError, match="no field"):
            run_scenario_sweep(spec, not_a_field=[1])

    def test_replace_sweeps_seeds(self, problem):
        """Frozen spec + dataclasses.replace is the sweep idiom: different
        seeds give different trajectories, same seed reproduces."""
        spec = _spec(problem, "mp", False)
        a = run_scenario(spec)
        b = run_scenario(dataclasses.replace(spec, seed=spec.seed + 1))
        a2 = run_scenario(spec)
        assert not np.array_equal(a.theta_hist, b.theta_hist)
        assert np.array_equal(a.theta_hist, a2.theta_hist)

"""Batched-event CL-ADMM scenario engine (``run_cl_scenario``): parity with
the exact one-event-per-tick engine on an identical schedule, fault-model
behavior (drops, staleness, churn, partitions), accounting invariants, and
the shared recording policy."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.losses import pad_datasets, solitary_mean
from repro.core.sparse import record_chunks, sample_event
from repro.simulate import (EventStream, NetworkConditions,
                            random_geometric_topology, run_cl_scenario,
                            sparse_async_admm)


def exact_admm_stream(topo, steps, record_every, seed) -> EventStream:
    """Replay ``sparse_async_admm``'s exact tick schedule as a B = 1 stream:
    same PRNG key tree (split per record chunk, then per tick), all
    deliveries clean."""
    tabs = topo.device_tables()
    n = topo.n
    re_, n_rec = record_chunks(steps, record_every)
    keys = jax.random.split(jax.random.PRNGKey(seed), n_rec)
    tick_keys = jnp.concatenate([jax.random.split(k, re_) for k in keys])
    i, s = jax.vmap(lambda k: sample_event(k, n, tabs.slot_cdf,
                                           tabs.deg_count))(tick_keys)
    i = np.asarray(i)[:, None]
    s = np.asarray(s)[:, None]
    j = np.asarray(tabs.nbr_idx)[i, s]
    r = np.asarray(tabs.rev_slot)[i, s]
    t = np.ones(i.shape, bool)
    return EventStream(i, s, j, r, t, t, ~t, ~t, t, ~t, ~t,
                       np.ones(i.shape[0], np.float32))


@pytest.fixture(scope="module")
def problem():
    topo = random_geometric_topology(150, k=4, seed=0)
    rng = np.random.default_rng(0)
    xs = [rng.standard_normal((int(rng.integers(1, 8)), 3))
          for _ in range(150)]
    data = pad_datasets(xs, [np.zeros(len(x)) for x in xs])
    sol = np.asarray(solitary_mean(data), np.float32)
    return topo, data, sol


class TestExactScheduleParity:
    def test_matches_sparse_async_admm(self, problem):
        """Tentpole acceptance: with all-default NetworkConditions and the
        exact engine's event schedule, the batched engine reproduces
        ``sparse_async_admm``'s trajectory (to f32 rounding — the batched
        phases vmap the identical per-row primal/edge expressions)."""
        topo, data, sol = problem
        steps, re_ = 300, 50
        stream = exact_admm_stream(topo, steps, re_, seed=5)
        exact = sparse_async_admm(topo, data, 0.1, 1.0, steps=steps, seed=5,
                                  record_every=re_, theta_sol=sol)
        batched = run_cl_scenario(topo, data, 0.1, 1.0, NetworkConditions(),
                                  rounds=steps, batch=1, seed=5,
                                  record_every=re_, theta_sol=sol,
                                  stream=stream)
        assert batched.theta_hist.shape == exact.theta_hist.shape
        np.testing.assert_allclose(batched.theta_hist, exact.theta_hist,
                                   atol=1e-5, rtol=1e-5)
        # full edge state agrees too, not just the self models
        for a, b in [(batched.final.theta, exact.final.theta),
                     (batched.final.K, exact.final.K),
                     (batched.final.Z_own, exact.final.Z_own),
                     (batched.final.Z_nbr, exact.final.Z_nbr),
                     (batched.final.L_own, exact.final.L_own),
                     (batched.final.L_nbr, exact.final.L_nbr)]:
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5, rtol=1e-5)

    def test_stream_shape_mismatch_raises(self, problem):
        topo, data, sol = problem
        stream = exact_admm_stream(topo, 20, 10, seed=0)
        with pytest.raises(ValueError, match="rounds"):
            run_cl_scenario(topo, data, 0.1, 1.0, NetworkConditions(),
                            rounds=40, batch=1, record_every=10,
                            theta_sol=sol, stream=stream)


class TestCLScenarioFaults:
    def test_clean_counters_and_convergence(self, problem):
        topo, data, sol = problem
        tr = run_cl_scenario(topo, data, 0.1, 1.0, NetworkConditions(),
                             rounds=200, batch=32, seed=0, record_every=50,
                             theta_sol=sol)
        assert tr.dropped == 0 and tr.invalid == 0
        assert tr.delivered == 2 * tr.events
        assert np.isfinite(tr.theta_hist).all()
        # CL-ADMM should move every agent off its solitary model and shrink
        # the neighbor disagreement term over the run
        d0 = np.linalg.norm(tr.theta_hist[0] - sol)
        assert d0 > 0
        tabs = topo.tables
        live = np.arange(topo.k_max)[None, :] < tabs.deg_count[:, None]

        def disagreement(theta):
            diff = theta[:, None, :] - theta[tabs.nbr_idx]
            return float((live[:, :, None] * diff ** 2).sum())

        assert disagreement(tr.theta_hist[-1]) \
            < 0.5 * disagreement(np.asarray(sol))

    def test_accounting_invariant_under_faults(self, problem):
        topo, data, sol = problem
        cond = NetworkConditions(drop_prob=0.2, stale_prob=0.3,
                                 straggler_frac=0.3, straggler_factor=0.1,
                                 churn_rate=0.02, partition_start=5,
                                 partition_end=25)
        tr = run_cl_scenario(topo, data, 0.1, 1.0, cond, rounds=60,
                             batch=32, seed=1, record_every=20,
                             theta_sol=sol)
        assert tr.dropped > 0
        assert tr.delivered + tr.dropped == 2 * (tr.events - tr.invalid)
        assert np.isfinite(tr.theta_hist).all()
        assert tr.active_hist[-1] <= 1.0

    def test_staleness_changes_trajectory(self, problem):
        topo, data, sol = problem
        kw = dict(rounds=80, batch=16, seed=3, record_every=20,
                  theta_sol=sol)
        clean = run_cl_scenario(topo, data, 0.1, 1.0, NetworkConditions(),
                                **kw)
        stale = run_cl_scenario(topo, data, 0.1, 1.0,
                                NetworkConditions(stale_prob=1.0), **kw)
        assert not np.array_equal(clean.theta_hist, stale.theta_hist)
        assert np.isfinite(stale.theta_hist).all()

    def test_drops_slow_consensus(self, problem):
        """Heavy loss must leave the run finite and measurably further from
        consensus than the clean run."""
        topo, data, sol = problem
        kw = dict(rounds=120, batch=16, seed=4, record_every=40,
                  theta_sol=sol)
        clean = run_cl_scenario(topo, data, 0.1, 1.0, NetworkConditions(),
                                **kw)
        lossy = run_cl_scenario(topo, data, 0.1, 1.0,
                                NetworkConditions(drop_prob=0.6), **kw)
        tabs = topo.tables
        live = np.arange(topo.k_max)[None, :] < tabs.deg_count[:, None]

        def disagreement(theta):
            diff = theta[:, None, :] - theta[tabs.nbr_idx]
            return float((live[:, :, None] * diff ** 2).sum())

        assert disagreement(lossy.theta_hist[-1]) \
            > disagreement(clean.theta_hist[-1])

    def test_recording_policy_clamped(self, problem):
        """rounds < record_every must not silently run zero rounds."""
        topo, data, sol = problem
        tr = run_cl_scenario(topo, data, 0.1, 1.0, NetworkConditions(),
                             rounds=5, batch=4, seed=0, record_every=100,
                             theta_sol=sol)
        assert tr.rounds == 5 and tr.events == 20
        assert tr.theta_hist.shape[0] == 1
        assert not np.array_equal(tr.theta_hist[-1], sol)

"""Padded-mask gradient hygiene (ISSUE satellite): under the inexact
primal's per-batch ``guarded_loss``, pad slots contribute exactly zero
value AND gradient — even when they hold non-finite garbage — and a
scenario's trajectory is invariant to how wide its datasets are padded."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.losses import (AgentData, LOSSES, guarded_loss, masked_sum,
                               pad_datasets)
from repro.core.primal import InexactPrimal, flat_predictor
from repro.models import MLPAgent
from repro.simulate import (NetworkConditions, ScenarioSpec,
                            random_geometric_topology, run_scenario)


def poisoned_pad(x, y, counts, fill=np.nan):
    """Padded (x, y, mask) whose pad slots hold ``fill`` garbage."""
    n, m = x.shape[:2]
    mask = (np.arange(m)[None] < np.asarray(counts)[:, None])
    xg = np.where(mask[..., None], x, fill).astype(np.float32)
    yg = np.where(mask, y, fill).astype(np.float32)
    return (jnp.asarray(xg), jnp.asarray(yg),
            jnp.asarray(mask, jnp.float32))


@pytest.mark.parametrize("loss", ["quadratic", "hinge", "logistic"])
@pytest.mark.parametrize("fill", [np.nan, np.inf])
class TestGuardedLoss:
    def test_pad_garbage_has_zero_value_and_gradient(self, loss, fill):
        rng = np.random.default_rng(0)
        m, q, m_i = 8, 3, 5
        x = rng.standard_normal((1, m, q))
        y = np.sign(rng.standard_normal((1, m))) + 0.0
        theta = jnp.asarray(rng.standard_normal(q), jnp.float32)
        xg, yg, mask = poisoned_pad(x, y, [m_i], fill)
        xz, yz, _ = poisoned_pad(x, y, [m_i], 0.0)
        fn = guarded_loss(loss)
        val_g, grad_g = jax.value_and_grad(fn)(theta, xg[0], yg[0], mask[0])
        val_z, grad_z = jax.value_and_grad(fn)(theta, xz[0], yz[0], mask[0])
        assert np.isfinite(float(val_g)) and np.isfinite(
            np.asarray(grad_g)).all()
        # the double-where makes garbage pads indistinguishable from zeros
        np.testing.assert_array_equal(np.asarray(val_g), np.asarray(val_z))
        np.testing.assert_array_equal(np.asarray(grad_g),
                                      np.asarray(grad_z))
        # and the unpadded dataset agrees (pad slots contribute nothing)
        val_u, grad_u = jax.value_and_grad(fn)(
            theta, jnp.asarray(x[0, :m_i], jnp.float32),
            jnp.asarray(y[0, :m_i], jnp.float32), jnp.ones(m_i))
        np.testing.assert_allclose(np.asarray(val_g), np.asarray(val_u),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(np.asarray(grad_g), np.asarray(grad_u),
                                   rtol=1e-6, atol=1e-6)

    def test_legacy_losses_need_zero_filled_pads(self, loss, fill):
        """The closed-form sums mask *after* the model: 0 * inf = nan, so
        they rely on pad_datasets zero-fill — the regression guarded_loss
        exists to close for the differentiating primal."""
        rng = np.random.default_rng(1)
        x = rng.standard_normal((1, 6, 3))
        y = np.ones((1, 6))
        xg, yg, mask = poisoned_pad(x, y, [4], fill)
        theta = jnp.asarray(rng.standard_normal(3), jnp.float32)
        legacy = float(LOSSES[loss](theta, xg[0], yg[0], mask[0]))
        guarded = float(guarded_loss(loss)(theta, xg[0], yg[0], mask[0]))
        assert not np.isfinite(legacy)
        assert np.isfinite(guarded)


class TestGuardedLossModels:
    def test_masked_sum_zeroes_pad_cotangent(self):
        vals = jnp.asarray([1.0, 2.0, 3.0])
        mask = jnp.asarray([1.0, 0.0, 1.0])
        grad = jax.grad(lambda v: masked_sum(v, mask))(vals)
        np.testing.assert_array_equal(np.asarray(grad), [1.0, 0.0, 1.0])

    def test_mlp_predictor_survives_poisoned_pads(self):
        model = MLPAgent(in_dim=2, hidden=(4,))
        flat = model.flattener()
        theta = flat.flatten(model.init(jax.random.PRNGKey(0)))
        rng = np.random.default_rng(2)
        x = rng.standard_normal((1, 5, 2))
        y = np.sign(rng.standard_normal((1, 5))) + 0.0
        xg, yg, mask = poisoned_pad(x, y, [3])
        fn = guarded_loss("logistic", flat_predictor(model))
        val, grad = jax.value_and_grad(fn)(theta, xg[0], yg[0], mask[0])
        assert np.isfinite(float(val))
        assert np.isfinite(np.asarray(grad)).all()

    def test_matches_legacy_on_clean_pads(self):
        """On zero-filled pads (the pad_datasets contract) guarded and
        legacy losses agree — guarding changes nothing but robustness."""
        rng = np.random.default_rng(3)
        data = pad_datasets(
            [rng.standard_normal((m, 3)) for m in (2, 5, 1)],
            [np.sign(rng.standard_normal(m)) for m in (2, 5, 1)])
        theta = jnp.asarray(rng.standard_normal(3), jnp.float32)
        for loss in ("quadratic", "hinge", "logistic"):
            for i in range(3):
                a = float(LOSSES[loss](theta, data.x[i], data.y[i],
                                       data.mask[i]))
                b = float(guarded_loss(loss)(theta, data.x[i], data.y[i],
                                             data.mask[i]))
                np.testing.assert_allclose(a, b, rtol=1e-6)


class TestUnbalancedAgentsThroughPrimal:
    def test_trajectory_invariant_to_padding_width(self):
        """m_i-unbalanced agents: widening every dataset with extra
        garbage pad columns leaves the inexact-primal scenario trajectory
        bit-identical — the engines only ever see the masked samples."""
        rng = np.random.default_rng(4)
        n, m, q = 12, 5, 3
        topo = random_geometric_topology(n, k=3, seed=0)
        x = rng.standard_normal((n, m, q))
        y = np.sign(rng.standard_normal((n, m))) + 0.0
        counts = rng.integers(1, m + 1, n)
        xg, yg, mask = poisoned_pad(x, y, counts, 0.0)
        narrow = AgentData(x=xg, y=yg, mask=mask)
        pad_x = np.concatenate(
            [np.asarray(xg), np.full((n, 3, q), np.nan, np.float32)], 1)
        pad_y = np.concatenate(
            [np.asarray(yg), np.full((n, 3), np.inf, np.float32)], 1)
        pad_m = np.concatenate(
            [np.asarray(mask), np.zeros((n, 3), np.float32)], 1)
        wide = AgentData(x=jnp.asarray(pad_x), y=jnp.asarray(pad_y),
                         mask=jnp.asarray(pad_m))
        sol = np.zeros((n, q), np.float32)
        base = dict(algo="cl", topology=topo, mu=0.5, rho=1.0,
                    conditions=NetworkConditions(drop_prob=0.2), rounds=15,
                    batch=4, seed=2, record_every=5, theta_sol=sol,
                    primal=InexactPrimal(loss="logistic", b_steps=6,
                                         lr=0.1))
        a = run_scenario(ScenarioSpec(**base, data=narrow))
        b = run_scenario(ScenarioSpec(**base, data=wide))
        assert np.isfinite(a.theta_hist).all()
        np.testing.assert_array_equal(a.theta_hist, b.theta_hist)

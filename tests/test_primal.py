"""Pluggable CL-ADMM primal solvers (DESIGN.md §18): flattener bijection,
the exact-solver and B->inf inexact anchors against the historical engine
(single-device bitwise; 8-fake-device subprocess to f32 rounding), finite-B
convergence ordering, and the federated_moons acceptance experiment where
collaborative nonlinear training beats purely-local AdamW by >= 5 points."""

import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core.losses import pad_datasets, solitary_mean
from repro.core.primal import (ExactQuadraticPrimal, InexactPrimal,
                               flat_predictor, solitary_adamw)
from repro.data import federated_moons_problem, model_accuracy
from repro.kernels.dispatch import implementations
from repro.models import LoRAAgent, MLPAgent, ParamFlattener
from repro.models.flatten import _lora_base
from repro.simulate import (NetworkConditions, ScenarioSpec,
                            random_geometric_topology, run_scenario)
from repro.telemetry import TelemetryConfig

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def quadratic_problem(n=24, q=3, seed=0):
    """Small mean-estimation instance with unbalanced per-agent counts."""
    rng = np.random.default_rng(seed)
    topo = random_geometric_topology(n, k=4, seed=seed)
    xs = [rng.standard_normal((int(rng.integers(2, 9)), q))
          for _ in range(n)]
    data = pad_datasets(xs, [np.zeros(len(x)) for x in xs])
    sol = np.asarray(solitary_mean(data), np.float32)
    return topo, data, sol


def base_spec(topo, data, sol, **kw):
    cfg = dict(algo="cl", topology=topo, data=data, mu=0.4, rho=1.0,
               conditions=NetworkConditions(drop_prob=0.1, stale_prob=0.2),
               rounds=30, batch=8, seed=3, record_every=10, theta_sol=sol)
    cfg.update(kw)
    return ScenarioSpec(**cfg)


class TestParamFlattener:
    def test_round_trip_is_bitwise(self):
        rng = np.random.default_rng(0)
        tree = {"a": rng.standard_normal((3, 4)).astype(np.float32),
                "b": (rng.standard_normal(5).astype(np.float32),
                      np.float32(rng.standard_normal()))}
        flat = ParamFlattener.from_template(tree)
        assert flat.dim == 3 * 4 + 5 + 1
        vec = flat.flatten(tree)
        assert vec.shape == (flat.dim,) and vec.dtype == np.float32
        back = flat.unflatten(vec)
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(np.asarray(flat.flatten(back)),
                                      np.asarray(vec))

    def test_mlp_agent_shapes_and_flat_apply(self):
        model = MLPAgent(in_dim=2, hidden=(4,))
        flat = model.flattener()
        assert flat.dim == 2 * 4 + 4 + 4 * 1 + 1
        params = model.init(jax.random.PRNGKey(0))
        x = np.random.default_rng(1).standard_normal((7, 2)).astype(
            np.float32)
        scores = model.apply(params, x)
        assert scores.shape == (7,)
        pred = flat_predictor(model)
        np.testing.assert_array_equal(
            np.asarray(pred(flat.flatten(params), x)), np.asarray(scores))

    def test_lora_agent_base_is_deterministic(self):
        model = LoRAAgent(in_dim=3, width=8, rank=2, base_seed=5)
        flat = model.flattener()
        assert flat.dim == 2 * (3 + 8) + 8 + 1
        w0, b0 = _lora_base(3, 8, 5)
        w0b, b0b = _lora_base(3, 8, 5)
        np.testing.assert_array_equal(np.asarray(w0), np.asarray(w0b))
        assert not np.array_equal(np.asarray(w0),
                                  np.asarray(_lora_base(3, 8, 6)[0]))
        # standard LoRA init: B = 0, so two agents with different adapters
        # but the same head start at the same function of the frozen layer
        pa = model.init(jax.random.PRNGKey(0))
        pb = dict(pa, a=model.init(jax.random.PRNGKey(9))["a"])
        x = np.random.default_rng(2).standard_normal((5, 3)).astype(
            np.float32)
        np.testing.assert_array_equal(np.asarray(model.apply(pa, x)),
                                      np.asarray(model.apply(pb, x)))

    def test_inexact_primal_validates_config(self):
        with pytest.raises(ValueError):
            InexactPrimal(loss="absolute")
        with pytest.raises(ValueError):
            InexactPrimal(loss="logistic", b_steps=None)
        with pytest.raises(ValueError):
            InexactPrimal(loss="quadratic", model=MLPAgent(in_dim=2))
        with pytest.raises(ValueError):
            ScenarioSpec(algo="mp", topology=None,
                         conditions=NetworkConditions(), rounds=1, batch=1,
                         primal=ExactQuadraticPrimal())

    def test_inexact_op_is_registered(self):
        impls = implementations("admm_primal_inexact")
        assert {"reference", "xla"} <= set(impls)


class TestPrimalAnchors:
    """The acceptance anchors: pluggable solvers vs the historical engine."""

    @pytest.fixture(scope="class")
    def runs(self):
        topo, data, sol = quadratic_problem()
        exact = run_scenario(base_spec(topo, data, sol))
        return topo, data, sol, exact

    def test_exact_solver_is_bitwise_primal_none(self, runs):
        topo, data, sol, exact = runs
        tr = run_scenario(base_spec(topo, data, sol,
                                    primal=ExactQuadraticPrimal()))
        assert np.array_equal(tr.theta_hist, exact.theta_hist)
        assert (tr.delivered, tr.dropped, tr.invalid) == \
            (exact.delivered, exact.dropped, exact.invalid)

    def test_b_inf_quadratic_reproduces_exact(self, runs):
        """The B->inf fixed point of the reduced Lagrangian IS the closed
        form (envelope argument, kernels.ref.inexact_primal docstring) —
        trajectories match to f32 rounding on the identical schedule."""
        topo, data, sol, exact = runs
        tr = run_scenario(base_spec(
            topo, data, sol,
            primal=InexactPrimal(loss="quadratic", b_steps=None)))
        assert np.abs(tr.theta_hist - exact.theta_hist).max() <= 1e-5

    def test_finite_b_converges_to_exact(self, runs):
        """More inner AdamW steps -> closer to the exact primal (the
        exact-vs-inexact ordering the differential harness also fuzzes)."""
        topo, data, sol, exact = runs
        errs = {}
        for b in (1, 8, 128):
            tr = run_scenario(base_spec(
                topo, data, sol,
                primal=InexactPrimal(loss="quadratic", b_steps=b, lr=0.2)))
            errs[b] = float(np.abs(tr.theta_hist - exact.theta_hist).max())
        assert errs[128] < errs[8] < errs[1]
        assert errs[128] <= 1e-3
        assert errs[1] > 1e-2      # B=1 is genuinely inexact, not a no-op

    def test_telemetry_does_not_perturb_inexact_trajectory(self, runs):
        """Telemetry-enabled nonlinear runs must leave theta bit-identical
        (the metrics read the carry; the loss-based objective replaces the
        sufficient-statistics path only outside the scan state)."""
        topo, data, sol, _ = runs
        primal = InexactPrimal(loss="quadratic", b_steps=4, lr=0.2)
        plain = run_scenario(base_spec(topo, data, sol, primal=primal))
        teled = run_scenario(base_spec(topo, data, sol, primal=primal,
                                       telemetry=TelemetryConfig(
                                           enabled=True)))
        assert np.array_equal(plain.theta_hist, teled.theta_hist)
        assert teled.telemetry is not None
        assert np.isfinite(np.asarray(teled.telemetry.objective)).all()


class TestFederatedMoons:
    """ISSUE acceptance: per-cluster nonlinear decision boundaries where
    collaboration beats purely-local training by >= 5 accuracy points."""

    def test_problem_shapes(self):
        topo, train, tx, ty = federated_moons_problem(n=12, n_clusters=2,
                                                      n_test=32, seed=1)
        assert topo.n == 12 and train.n == 12
        assert tx.shape == (12, 32, 2) and ty.shape == (12, 32)
        assert set(np.unique(ty).tolist()) == {-1.0, 1.0}
        counts = np.asarray(train.counts)
        assert counts.min() >= 3 and counts.max() <= 8

    def test_collaboration_beats_local_by_5_points(self):
        model = MLPAgent(in_dim=2, hidden=(8,))
        pred = flat_predictor(model)
        topo, train, tx, ty = federated_moons_problem(n=24, seed=0)
        sol = solitary_adamw(train, loss="logistic", model=model,
                             steps=400, seed=0)
        acc_sol = float(model_accuracy(sol, pred, tx, ty).mean())
        tr = run_scenario(ScenarioSpec(
            algo="cl", topology=topo, data=train, mu=0.5, rho=0.2,
            conditions=NetworkConditions(), rounds=300, batch=12, seed=0,
            record_every=100, theta_sol=np.asarray(sol),
            primal=InexactPrimal(loss="logistic", model=model,
                                 b_steps=10, lr=0.1),
            telemetry=TelemetryConfig(enabled=True)))
        acc = float(model_accuracy(tr.theta_hist[-1], pred, tx, ty).mean())
        # margin measured at ~+11 points (seeds 0-2); 5 is the ISSUE bar
        assert acc - acc_sol >= 0.05, (acc, acc_sol)
        # reported via telemetry frames: Eq. 7 objective decreases
        obj = np.asarray(tr.telemetry.objective).sum(axis=1)
        assert np.isfinite(obj).all() and obj[-1] < obj[0]


# ---------------------------------------------------------------------------
# 8-fake-device subprocess: the solver plug-ins under the real sharded mesh
# (the XLA device-count flag must precede jax init, already done by pytest)
# ---------------------------------------------------------------------------


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    assert jax.device_count() == 8
    from repro.core.losses import pad_datasets, solitary_mean
    from repro.core.primal import InexactPrimal, solitary_adamw
    from repro.models import MLPAgent
    from repro.data import federated_moons_problem
    from repro.simulate import (NetworkConditions, ScenarioSpec,
                                random_geometric_topology, run_scenario)

    # quadratic B->inf anchor: sharded inexact == single-device exact
    rng = np.random.default_rng(0)
    n = 203                           # not divisible by 8
    topo = random_geometric_topology(n, k=5, seed=0)
    xs = [rng.standard_normal((int(rng.integers(1, 8)), 4))
          for _ in range(n)]
    data = pad_datasets(xs, [np.zeros(len(x)) for x in xs])
    sol = np.asarray(solitary_mean(data), np.float32)
    cond = NetworkConditions(drop_prob=0.1, stale_prob=0.3, churn_rate=0.01,
                             straggler_frac=0.3, partition_start=5,
                             partition_end=20)
    base = dict(algo="cl", topology=topo, data=data, mu=0.1, rho=1.0,
                conditions=cond, rounds=40, batch=32, seed=3,
                record_every=10, theta_sol=sol)
    exact = run_scenario(ScenarioSpec(**base))
    sh = run_scenario(ScenarioSpec(
        **base, sharded=True,
        primal=InexactPrimal(loss="quadratic", b_steps=None)))
    assert sh.n_shards == 8 and sh.overflow == 0
    assert np.abs(sh.theta_hist - exact.theta_hist).max() <= 1e-5

    # nonlinear MLP agents: sharded == single-device inexact trajectories
    model = MLPAgent(in_dim=2, hidden=(4,))
    topo2, train, _, _ = federated_moons_problem(n=24, seed=0)
    sol2 = np.asarray(solitary_adamw(train, loss="logistic", model=model,
                                     steps=50, seed=0))
    base2 = dict(algo="cl", topology=topo2, data=train, mu=0.5, rho=0.5,
                 conditions=NetworkConditions(drop_prob=0.1), rounds=30,
                 batch=8, seed=1, record_every=10, theta_sol=sol2,
                 primal=InexactPrimal(loss="logistic", model=model,
                                      b_steps=4, lr=0.05))
    single = run_scenario(ScenarioSpec(**base2))
    shnl = run_scenario(ScenarioSpec(**base2, sharded=True))
    assert shnl.overflow == 0
    assert np.abs(shnl.theta_hist - single.theta_hist).max() <= 1e-5
    assert np.isfinite(shnl.theta_hist).all()
    print("PRIMAL-8DEV-OK")
""")


def test_eight_device_primal_parity_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SUBPROC], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "PRIMAL-8DEV-OK" in out.stdout

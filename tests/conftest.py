"""Shared test configuration: deterministic hypothesis profiles.

Hypothesis is an optional dev dependency (requirements-dev.txt): the suite
must import cleanly without it (property tests guard with
``pytest.importorskip``), so profile registration sits in a try/except.

Profiles:

* ``dev`` (default) — hypothesis defaults minus the deadline (jit
  compilation makes first examples orders of magnitude slower than the
  rest, so wall-clock deadlines only produce flaky failures).
* ``ci`` — what the workflow selects via ``HYPOTHESIS_PROFILE=ci``:
  derandomized (the same example sequence on every run, so a red CI lane
  is reproducible locally by exporting the same variable) with a bounded
  example count to keep the tier-1 lane fast.
"""

import os

try:
    from hypothesis import HealthCheck, settings

    settings.register_profile("dev", deadline=None)
    settings.register_profile(
        "ci", deadline=None, derandomize=True, max_examples=25,
        suppress_health_check=[HealthCheck.too_slow])
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "dev"))
except ImportError:  # pragma: no cover - exercised only without dev deps
    pass

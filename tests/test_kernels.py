"""Per-kernel validation: Pallas (interpret=True) vs pure-jnp oracle,
sweeping shapes/dtypes, plus hypothesis property tests (deliverable c)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# graph_mix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [4, 16, 32, 100])
@pytest.mark.parametrize("D", [64, 512, 1000])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_graph_mix_matches_ref(n, D, dtype):
    key = jax.random.PRNGKey(n * 1000 + D)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    theta = jax.random.normal(k1, (n, D), dtype)
    sol = jax.random.normal(k2, (n, D), dtype)
    A = jax.random.uniform(k3, (n, n), jnp.float32) / n
    b = jax.random.uniform(k4, (n,), jnp.float32)
    got = ops.graph_mix(theta, sol, A, b)
    want = ref.graph_mix(theta, sol, A, b)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


def test_graph_mix_is_mp_step():
    """The kernel computes exactly the paper's Eq. (5) iterate."""
    from repro.core import gaussian_kernel_graph, synchronous
    rng = np.random.default_rng(0)
    n, p = 12, 40
    g = gaussian_kernel_graph(rng.standard_normal((n, 2)), sigma=1.0)
    theta_sol = rng.standard_normal((n, p)).astype(np.float32)
    c = rng.uniform(0.1, 1.0, n).astype(np.float32)
    alpha = 0.9
    abar = 1 - alpha
    denom = alpha + abar * c
    A = (alpha / denom)[:, None] * np.asarray(g.P, np.float32)
    b = abar * c / denom
    one_step = ops.graph_mix(jnp.asarray(theta_sol), jnp.asarray(theta_sol),
                             jnp.asarray(A), jnp.asarray(b))
    want = synchronous(g, theta_sol, c, alpha, steps=1)
    np.testing.assert_allclose(np.asarray(one_step), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 24), D=st.integers(1, 300))
def test_graph_mix_property_random_shapes(n, D):
    key = jax.random.PRNGKey(n * 7 + D)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    theta = jax.random.normal(k1, (n, D))
    sol = jax.random.normal(k2, (n, D))
    A = jax.random.uniform(k3, (n, n)) / n
    b = jax.random.uniform(k4, (n,))
    got = ops.graph_mix(theta, sol, A, b)
    want = ref.graph_mix(theta, sol, A, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4,
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# flash_attention
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("S,block", [(128, 64), (256, 64), (512, 128)])
@pytest.mark.parametrize("window", [None, 64])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(S, block, window, dtype):
    B, H, hd = 2, 2, 64
    key = jax.random.PRNGKey(S + (window or 0))
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, S, H, hd), dtype)
    k = jax.random.normal(k2, (B, S, H, hd), dtype)
    v = jax.random.normal(k3, (B, S, H, hd), dtype)
    got = ops.flash_attention(q, k, v, window=window, block_q=block,
                              block_k=block)
    want = ref.flash_attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol(dtype))


def test_flash_attention_gqa_expansion():
    B, S, H, K, hd = 1, 128, 8, 2, 32
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, S, H, hd))
    k = jax.random.normal(k2, (B, S, K, hd))
    v = jax.random.normal(k3, (B, S, K, hd))
    got = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    kf = jnp.repeat(k, H // K, axis=2)
    vf = jnp.repeat(v, H // K, axis=2)
    want = ref.flash_attention(q, kf, vf)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4,
                               rtol=1e-4)


def test_flash_attention_matches_model_chunked_path():
    """Kernel vs the model engine's chunked_attention (same math)."""
    from repro.models.attention import chunked_attention
    B, S, H, hd = 1, 256, 4, 32
    key = jax.random.PRNGKey(7)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, S, H, hd))
    k = jax.random.normal(k2, (B, S, H, hd))
    v = jax.random.normal(k3, (B, S, H, hd))
    got = ops.flash_attention(q, k, v, block_q=64, block_k=64)
    want = chunked_attention(q, k, v, chunk=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4,
                               rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(sblk=st.sampled_from([(64, 32), (128, 64), (192, 64)]),
       window=st.sampled_from([None, 32, 100]),
       hd=st.sampled_from([16, 64]))
def test_flash_attention_property(sblk, window, hd):
    S, block = sblk
    B, H = 1, 2
    key = jax.random.PRNGKey(S + hd)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (B, S, H, hd))
    k = jax.random.normal(k2, (B, S, H, hd))
    v = jax.random.normal(k3, (B, S, H, hd))
    got = ops.flash_attention(q, k, v, window=window, block_q=block,
                              block_k=block)
    want = ref.flash_attention(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4,
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# admm_edge_update
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("E,p", [(1, 16), (8, 512), (13, 100), (64, 1000)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_admm_update_matches_ref(E, p, dtype):
    key = jax.random.PRNGKey(E * p)
    args = [jax.random.normal(k, (E, p), dtype)
            for k in jax.random.split(key, 8)]
    rho = 1.5
    got = ops.admm_edge_update(*args, rho=rho)
    want = ref.admm_edge_update(*args, rho=rho)
    for g, w in zip(got, want):
        np.testing.assert_allclose(np.asarray(g, np.float32),
                                   np.asarray(w, np.float32), **tol(dtype))


def test_admm_update_matches_core_algorithm():
    """Kernel == the reference decentralized ADMM edge update (step 2-3)."""
    from repro.core import gaussian_kernel_graph
    from repro.core.collaborative import init_state, _all_zl_update, ADMMState
    rng = np.random.default_rng(3)
    n, p = 6, 4
    g = gaussian_kernel_graph(rng.standard_normal((n, 2)), sigma=1.0)
    theta = rng.standard_normal((n, p)).astype(np.float32)
    st0 = init_state(g, theta)
    # randomize duals/copies to make the check non-trivial
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    st0 = ADMMState(st0.T + 0.1 * jax.random.normal(ks[0], st0.T.shape),
                    st0.Z_own, st0.Z_nbr,
                    0.1 * jax.random.normal(ks[1], st0.L_own.shape),
                    0.1 * jax.random.normal(ks[2], st0.L_nbr.shape))
    rho = 1.3
    mask = jnp.asarray(g.W > 0)
    st1 = _all_zl_update(st0, mask, rho)
    edges = g.edges()
    ii = np.array([e[0] for e in edges])
    jj = np.array([e[1] for e in edges])
    T = np.asarray(st0.T)
    z_i, z_j, loi, lnj, loj, lni = ops.admm_edge_update(
        jnp.asarray(T[ii, ii]), jnp.asarray(T[jj, ii]),
        jnp.asarray(T[jj, jj]), jnp.asarray(T[ii, jj]),
        jnp.asarray(np.asarray(st0.L_own)[ii, jj]),
        jnp.asarray(np.asarray(st0.L_nbr)[ii, jj]),
        jnp.asarray(np.asarray(st0.L_own)[jj, ii]),
        jnp.asarray(np.asarray(st0.L_nbr)[jj, ii]),
        rho=rho)
    np.testing.assert_allclose(np.asarray(z_i),
                               np.asarray(st1.Z_own)[ii, jj], atol=1e-5)
    np.testing.assert_allclose(np.asarray(z_j),
                               np.asarray(st1.Z_own)[jj, ii], atol=1e-5)
    np.testing.assert_allclose(np.asarray(loi),
                               np.asarray(st1.L_own)[ii, jj], atol=1e-5)
    np.testing.assert_allclose(np.asarray(loj),
                               np.asarray(st1.L_own)[jj, ii], atol=1e-5)
    np.testing.assert_allclose(np.asarray(lnj),
                               np.asarray(st1.L_nbr)[ii, jj], atol=1e-5)
    np.testing.assert_allclose(np.asarray(lni),
                               np.asarray(st1.L_nbr)[jj, ii], atol=1e-5)

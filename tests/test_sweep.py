"""Vmapped sweep runner: one jitted call over the trial axis must reproduce
the per-instance reference algorithms (synchronous MP iterates, Prop. 1
closed form, synchronous CL-ADMM)."""

import numpy as np
import pytest

from repro.core import closed_form, solitary_mean, confidences_from_counts, \
    sync_admm, synchronous
from repro.data import mean_estimation_problem
from repro.experiments import (admm_mean_estimation_trials,
                               closed_form_comparison,
                               mean_estimation_trials, run_admm_sweep,
                               run_mp_sweep)

SEEDS = [0, 1, 2, 3]
ALPHAS = [0.9, 0.99]


@pytest.fixture(scope="module")
def mp_trials():
    return mean_estimation_trials(seeds=SEEDS, alphas=ALPHAS, n=30)


def _instance(seed, n=30):
    g, data, targets, _ = mean_estimation_problem(n=n, eps=1.0, seed=seed)
    sol = np.asarray(solitary_mean(data))
    conf = np.asarray(confidences_from_counts(data.counts))
    return g, sol, conf, targets


def test_mp_sweep_has_at_least_8_trials(mp_trials):
    assert mp_trials.n_trials == len(SEEDS) * len(ALPHAS) >= 8


def test_mp_sweep_matches_reference_iterates(mp_trials):
    """Acceptance: the vmapped sweep reproduces the seed mean-estimation
    experiment over >= 8 (seed, alpha) trials in ONE jitted call — each
    trial's trajectory equals core.synchronous run per-instance."""
    sweeps = 150
    res = run_mp_sweep(mp_trials, sweeps=sweeps)
    assert res.objective_hist.shape == (mp_trials.n_trials, sweeps)
    assert res.err_hist.shape == (mp_trials.n_trials, sweeps)
    i = 0
    for seed in SEEDS:
        g, sol, conf, _ = _instance(seed)
        for alpha in ALPHAS:
            want = np.asarray(synchronous(g, sol, conf, alpha, steps=sweeps))
            np.testing.assert_allclose(res.theta_final[i], want,
                                       atol=1e-4, rtol=1e-4)
            i += 1


def test_mp_sweep_objective_monotone(mp_trials):
    res = run_mp_sweep(mp_trials, sweeps=100)
    diffs = np.diff(res.objective_hist, axis=1)
    assert np.all(diffs <= 1e-5)


def test_mp_sweep_converges_to_closed_form():
    trials = mean_estimation_trials(seeds=[0, 1], alphas=[0.9], n=30)
    res = run_mp_sweep(trials, sweeps=2000)
    for i, seed in enumerate([0, 1]):
        g, sol, conf, _ = _instance(seed)
        star = np.asarray(closed_form(g, sol, conf, 0.9))
        np.testing.assert_allclose(res.theta_final[i], star, atol=1e-3)


def test_closed_form_comparison_matches_per_instance(mp_trials):
    """The Fig. 2 experiment (with vs without confidences) as one vmapped
    solve matches looping core.closed_form per trial."""
    e_c, e_nc, win = closed_form_comparison(mp_trials)
    assert e_c.shape == (mp_trials.n_trials,)
    i = 0
    for seed in SEEDS:
        g, sol, conf, targets = _instance(seed)
        for alpha in ALPHAS:
            with_c = np.asarray(closed_form(g, sol, conf, alpha))
            no_c = np.asarray(closed_form(g, sol, np.ones(g.n), alpha))
            t = targets[:, None]
            np.testing.assert_allclose(
                e_c[i], np.mean(np.sum((with_c - t) ** 2, -1)), rtol=1e-3)
            np.testing.assert_allclose(
                e_nc[i], np.mean(np.sum((no_c - t) ** 2, -1)), rtol=1e-3)
            i += 1
    # unbalanced data (eps=1): confidences should win on most instances
    assert win.mean() >= 0.5


def test_graph_noise_axis_perturbs_instances():
    clean = mean_estimation_trials(seeds=[0], alphas=[0.9], n=20)
    noisy = mean_estimation_trials(seeds=[0], alphas=[0.9],
                                   graph_noises=(0.0, 0.2), n=20)
    assert noisy.n_trials == 2
    np.testing.assert_allclose(noisy.W[0], clean.W[0])
    assert np.abs(noisy.W[1] - noisy.W[0]).max() > 0
    np.testing.assert_allclose(noisy.W[1], noisy.W[1].T)  # still symmetric
    res = run_mp_sweep(noisy, sweeps=50)
    assert np.all(np.isfinite(res.objective_hist))


def test_admm_sweep_matches_sync_admm():
    """(seed × mu × rho) CL-ADMM sweep equals the reference synchronous
    engine per trial (quadratic loss, exact primal)."""
    seeds, mus, rhos, n, iters = [0, 1], [0.05, 0.2], [1.0], 12, 20
    trials = admm_mean_estimation_trials(seeds=seeds, mus=mus, rhos=rhos, n=n)
    assert trials.n_trials == 4
    res = run_admm_sweep(trials, iters=iters)
    assert res.objective_hist.shape == (4, iters)
    i = 0
    for seed in seeds:
        g, data, targets, _ = mean_estimation_problem(n=n, eps=1.0, seed=seed)
        sol = np.asarray(solitary_mean(data))
        for mu in mus:
            for rho in rhos:
                trc = sync_admm(g, data, mu=mu, rho=rho, loss="quadratic",
                                steps=iters, theta_sol=sol)
                np.testing.assert_allclose(res.theta_final[i],
                                           trc.theta_hist[-1],
                                           atol=1e-4, rtol=1e-4)
                i += 1


def test_inexact_primal_axis_sweeps_solver_configs():
    """A primal= axis over inner-step budgets: the b_steps=None column is
    the exact-engine anchor, finite columns are genuinely inexact."""
    from repro.core.losses import pad_datasets
    from repro.experiments import inexact_primal_axis, run_scenario_sweep
    from repro.simulate import (NetworkConditions, ScenarioSpec,
                                random_geometric_topology, run_scenario)

    rng = np.random.default_rng(0)
    n = 12
    topo = random_geometric_topology(n, k=3, seed=0)
    xs = [rng.standard_normal((4, 2)) for _ in range(n)]
    data = pad_datasets(xs, [np.zeros(4)] * n)
    sol = np.asarray(data.x.mean(axis=1), np.float32)
    base = ScenarioSpec(algo="cl", topology=topo, data=data, mu=0.4,
                        rho=1.0, conditions=NetworkConditions(), rounds=10,
                        batch=4, seed=1, record_every=5, theta_sol=sol)
    axis = inexact_primal_axis([2, None], loss="quadratic", lr=0.2)
    res = run_scenario_sweep(base, primal=axis)
    assert res.n_trials == 2
    assert res.cells[0]["primal"].b_steps == 2
    exact = run_scenario(base)
    err_b2 = np.abs(res.traces[0].theta_hist - exact.theta_hist).max()
    err_inf = np.abs(res.traces[1].theta_hist - exact.theta_hist).max()
    assert err_inf <= 1e-5 < err_b2

"""Lockstep (DUS) decode must equal the per-slot scatter path."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model


@pytest.mark.parametrize("arch", ["llama3_8b", "recurrentgemma_2b"])
def test_lockstep_equals_scatter(arch):
    cfg = dataclasses.replace(get_config(arch, "reduced"),
                              compute_dtype=jnp.float32)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(1), (2, 8), 0, cfg.vocab_size)
    batch = {"tokens": tok, "labels": tok}
    _, cache_a = m.prefill(params, batch, cache_len=16)
    cache_b = jax.tree_util.tree_map(lambda x: x, cache_a)
    nxt = jnp.zeros((2,), jnp.int32)
    for t in range(4):
        la, cache_a = m.decode_step(params, cache_a, {"token": nxt},
                                    lockstep=False)
        lb, cache_b = m.decode_step(params, cache_b, {"token": nxt},
                                    lockstep=True)
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=1e-5)
        nxt = jnp.argmax(la, -1).astype(jnp.int32)


def test_lockstep_ring_cache():
    cfg = dataclasses.replace(get_config("llama3_8b", "reduced"),
                              compute_dtype=jnp.float32, window=8)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    tok = jax.random.randint(jax.random.PRNGKey(2), (1, 12), 0,
                             cfg.vocab_size)
    batch = {"tokens": tok, "labels": tok}
    # ring cache shorter than the sequence: window-sized serving
    _, ca = m.prefill(params, batch, cache_len=8)
    cb = jax.tree_util.tree_map(lambda x: x, ca)
    nxt = jnp.zeros((1,), jnp.int32)
    for t in range(3):
        la, ca = m.decode_step(params, ca, {"token": nxt}, ring=True,
                               lockstep=False)
        lb, cb = m.decode_step(params, cb, {"token": nxt}, ring=True,
                               lockstep=True)
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=1e-5)
        nxt = jnp.argmax(la, -1).astype(jnp.int32)

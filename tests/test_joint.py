"""Joint model + collaboration-graph learning (DESIGN.md §13): the
edge_reweight op's simplex invariants, the rate-0 bit-for-bit equivalence of
``run_joint_scenario`` with ``run_mp_scenario``, planted-cluster recovery
(the >= 90% acceptance bar), sharded parity incl. halo re-compaction, and
the joint sweep's frozen-graph anchor — plus an 8-fake-device subprocess
acceptance run."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.graph_learning import (cluster_edge_recovery,
                                       learned_weight_tables, prune_rows,
                                       reweight_rows)
from repro.kernels import ref
from repro.simulate import (NetworkConditions, SparseTopology,
                            planted_partition_topology, run_joint_scenario,
                            run_joint_scenario_sharded, run_mp_scenario)
from repro.data.synthetic import two_cluster_mean_problem

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: tuned operating point for the two-cluster recovery runs (DESIGN.md §13)
LEARN_KW = dict(eta_graph=0.3, lam=1.0, graph_every=5, prune_eps=1e-3)

FAULTY = NetworkConditions(drop_prob=0.1, stale_prob=0.3, churn_rate=0.01,
                           straggler_frac=0.3, partition_start=10,
                           partition_end=30)


def _two_cluster(n=80, k_intra=5, k_inter=2, seed=0):
    topo = planted_partition_topology(n, 2, k_intra=k_intra,
                                      k_inter=k_inter, seed=seed)
    labels, targets, sol, c = two_cluster_mean_problem(n, p=4, seed=seed)
    assert np.array_equal(labels, topo.groups)
    return topo, labels, sol, c


# ---------------------------------------------------------------------------
# edge_reweight op invariants
# ---------------------------------------------------------------------------


class TestEdgeReweight:
    def _rows(self, seed=0, B=30, k=6):
        rng = np.random.default_rng(seed)
        live = rng.uniform(size=(B, k)) < 0.8
        live[0] = False
        w = rng.uniform(0, 1, (B, k)) * live
        w = w / np.maximum(w.sum(axis=1, keepdims=True), 1e-9)
        d = rng.uniform(0, 4, (B, k)).astype(np.float32)
        return jnp.asarray(d), jnp.asarray(w, jnp.float32), jnp.asarray(live)

    def test_rows_stay_on_simplex(self):
        d, w, live = self._rows()
        out = np.asarray(ref.edge_reweight(d, w, live, eta=0.5, lam=0.7))
        assert (out >= 0).all()
        assert (out[~np.asarray(live)] == 0).all()
        sums = out.sum(axis=1)
        has_live = np.asarray(live).any(axis=1)
        np.testing.assert_allclose(sums[has_live], 1.0, atol=1e-5)
        assert (sums[~has_live] == 0).all()

    def test_eta_zero_is_identity_eta_one_is_projection(self):
        d, w, live = self._rows(1)
        out0 = np.asarray(ref.edge_reweight(d, w, live, eta=0.0, lam=0.7))
        np.testing.assert_array_equal(out0, np.asarray(w))
        out1 = np.asarray(ref.edge_reweight(d, w, live, eta=1.0, lam=0.7))
        want = np.asarray(ref.simplex_project_rows(-d / 1.4, live))
        np.testing.assert_allclose(out1, want, atol=1e-6)

    def test_small_lam_concentrates_large_lam_spreads(self):
        d, w, live = self._rows(2)
        sharp = np.asarray(ref.edge_reweight(d, w, live, eta=1.0, lam=1e-3))
        flat = np.asarray(ref.edge_reweight(d, w, live, eta=1.0, lam=1e3))
        lv = np.asarray(live)
        # tiny lam: all mass on the closest live slot
        row = 1
        assert sharp[row].max() == pytest.approx(1.0)
        # huge lam: near-uniform over live slots
        deg = lv[row].sum()
        np.testing.assert_allclose(flat[row][lv[row]], 1.0 / deg, atol=1e-3)

    def test_projection_prefers_small_distances(self):
        d = jnp.asarray([[0.1, 0.2, 5.0, 5.0]], jnp.float32)
        live = jnp.ones((1, 4), bool)
        w = jnp.full((1, 4), 0.25, jnp.float32)
        out = np.asarray(ref.edge_reweight(d, w, live, eta=1.0, lam=0.5))
        assert out[0, :2].sum() == pytest.approx(1.0)
        assert (out[0, 2:] == 0).all()

    def test_prune_rows_monotone(self):
        w = jnp.asarray([[0.5, 0.4, 1e-5, 0.0]], jnp.float32)
        live = jnp.asarray([[True, True, True, False]])
        w2, live2 = prune_rows(w, live, 1e-3)
        assert np.array_equal(np.asarray(live2), [[True, True, False, False]])
        assert np.asarray(w2)[0, 2] == 0.0
        # a pruned slot never comes back, even at zero model distance
        out = reweight_rows(jnp.zeros((1, 2)), jnp.zeros((1, 4, 2)),
                            w2, live2, eta=1.0, lam=1.0)
        assert np.asarray(out)[0, 2] == 0.0


# ---------------------------------------------------------------------------
# single-device joint engine
# ---------------------------------------------------------------------------


class TestJointScenario:
    @pytest.mark.parametrize("cond", [NetworkConditions(), FAULTY],
                             ids=["clean", "faulty"])
    def test_rate_zero_reproduces_mp_bitwise(self, cond):
        """Acceptance: eta_graph=0 on an identical event schedule is
        bit-for-bit run_mp_scenario (the graph step is compiled out)."""
        topo, _, sol, c = _two_cluster()
        mp = run_mp_scenario(topo, sol, c, 0.9, cond, rounds=60, batch=24,
                             seed=3, record_every=20)
        jt = run_joint_scenario(topo, sol, c, 0.9, cond, rounds=60,
                                batch=24, seed=3, record_every=20)
        assert np.abs(jt.theta_hist - mp.theta_hist).max() == 0.0
        assert (jt.delivered, jt.dropped, jt.invalid, jt.rounds, jt.events) \
            == (mp.delivered, mp.dropped, mp.invalid, mp.rounds, mp.events)
        assert jt.suppressed == 0
        # the frozen graph is exactly the initial stochastic table
        np.testing.assert_array_equal(
            jt.final_w, np.asarray(topo.device_tables().nbr_p))

    def test_two_cluster_recovery(self):
        """Acceptance: >= 90% of planted intra-cluster candidate edges keep
        positive weight while inter-cluster edges are suppressed."""
        topo, labels, sol, c = _two_cluster()
        tr = run_joint_scenario(topo, sol, c, 0.9, NetworkConditions(),
                                rounds=300, batch=40, seed=1,
                                record_every=50, **LEARN_KW)
        rec = cluster_edge_recovery(topo.tables.nbr_idx,
                                    topo.tables.deg_count, tr.final_w,
                                    labels)
        assert rec.intra_recovered >= 0.9, rec
        assert rec.inter_suppressed >= 0.9, rec
        assert rec.inter_mass <= 0.05, rec
        # pruning shows up in the trace: live slots decrease, deliveries on
        # pruned slots are voided but stream-level accounting still holds
        assert tr.live_edges_hist[-1] < tr.live_edges_hist[0]
        assert tr.suppressed > 0
        assert tr.delivered + tr.dropped == 2 * (tr.events - tr.invalid)

    def test_learning_under_faults_still_recovers(self):
        topo, labels, sol, c = _two_cluster(seed=1)
        tr = run_joint_scenario(topo, sol, c, 0.9,
                                NetworkConditions(drop_prob=0.1,
                                                  stale_prob=0.2),
                                rounds=300, batch=40, seed=2,
                                record_every=100, **LEARN_KW)
        rec = cluster_edge_recovery(topo.tables.nbr_idx,
                                    topo.tables.deg_count, tr.final_w,
                                    labels)
        assert rec.intra_recovered >= 0.9, rec
        assert rec.inter_mass <= 0.1, rec

    def test_learned_tables_round_trip(self):
        """learned_weight_tables folds the learned rows back into
        NeighborTables usable by the fixed-graph engines."""
        topo, _, sol, c = _two_cluster()
        tr = run_joint_scenario(topo, sol, c, 0.9, NetworkConditions(),
                                rounds=100, batch=40, seed=1,
                                record_every=50, **LEARN_KW)
        tabs = learned_weight_tables(topo.tables, tr.final_w, tr.final_live)
        assert tabs.nbr_idx is topo.tables.nbr_idx     # candidate structure
        live = np.asarray(tr.final_live)
        assert (tabs.nbr_w[~live] == 0).all()
        topo2 = SparseTopology(tabs, topo.groups)
        tr2 = run_mp_scenario(topo2, sol, c, 0.9, NetworkConditions(),
                              rounds=20, batch=16, seed=0, record_every=20)
        assert np.isfinite(tr2.theta_hist).all()


# ---------------------------------------------------------------------------
# sharded joint engine
# ---------------------------------------------------------------------------


class TestJointSharded:
    @pytest.mark.parametrize("cond", [NetworkConditions(), FAULTY],
                             ids=["clean", "faulty"])
    def test_matches_single_device_bitwise(self, cond):
        """Acceptance: learned-graph runs match the single-device engine on
        whatever mesh this process has (8 devices in the CI lane)."""
        topo, _, sol, c = _two_cluster()
        kw = dict(rounds=120, batch=32, seed=3, record_every=40, **LEARN_KW)
        tr = run_joint_scenario(topo, sol, c, 0.9, cond, **kw)
        sh = run_joint_scenario_sharded(topo, sol, c, 0.9, cond, **kw)
        assert sh.overflow == 0
        assert sh.n_shards == jax.device_count()
        assert np.abs(sh.theta_hist - tr.theta_hist).max() == 0.0
        assert np.abs(sh.final_w - tr.final_w).max() == 0.0
        np.testing.assert_array_equal(sh.final_live, tr.final_live)
        np.testing.assert_array_equal(sh.live_edges_hist, tr.live_edges_hist)
        assert sh.suppressed == tr.suppressed

    def test_recompaction_shrinks_halo_and_preserves_trajectory(self):
        topo, _, sol, c = _two_cluster()
        kw = dict(rounds=300, batch=40, seed=1, record_every=50, **LEARN_KW)
        tr = run_joint_scenario(topo, sol, c, 0.9, NetworkConditions(), **kw)
        sh = run_joint_scenario_sharded(
            topo, sol, c, 0.9, NetworkConditions(), **kw,
            recompact_every=100, recompact_frac=0.05,
            n_shards=min(2, jax.device_count()))
        assert np.abs(sh.theta_hist - tr.theta_hist).max() == 0.0
        assert np.abs(sh.final_w - tr.final_w).max() == 0.0
        if jax.device_count() > 1:
            # cross edges were pruned, so re-compaction must have fired and
            # the final halo must be smaller than the full candidate halo
            full = run_joint_scenario_sharded(
                topo, sol, c, 0.9, NetworkConditions(), rounds=10, batch=8,
                seed=1, record_every=10,
                n_shards=min(2, jax.device_count()))
            assert sh.recompactions >= 1
            assert sh.halo_size < full.halo_size

    def test_rate_zero_matches_mp_sharded(self):
        topo, _, sol, c = _two_cluster()
        from repro.simulate import run_mp_scenario_sharded
        kw = dict(rounds=40, batch=16, seed=5, record_every=20)
        mp = run_mp_scenario_sharded(topo, sol, c, 0.9, FAULTY, **kw)
        jt = run_joint_scenario_sharded(topo, sol, c, 0.9, FAULTY, **kw)
        assert np.abs(jt.theta_hist - mp.theta_hist).max() == 0.0


# ---------------------------------------------------------------------------
# joint sweep
# ---------------------------------------------------------------------------


class TestJointSweep:
    def test_eta_zero_anchor_and_learning_helps(self):
        from repro.experiments import (joint_mean_estimation_trials,
                                       mean_estimation_trials,
                                       run_joint_sweep, run_mp_sweep)
        jt = joint_mean_estimation_trials(seeds=[0, 1], alphas=[0.9],
                                          etas=[0.0, 0.3], lams=[1.0], n=40)
        res = run_joint_sweep(jt, sweeps=60, graph_every=5)
        mp = run_mp_sweep(mean_estimation_trials(seeds=[0, 1], alphas=[0.9],
                                                 n=40), sweeps=60)
        # trials 0/2 are the eta=0 column for seeds 0/1: exact MP anchor
        # for the trajectory AND the objective
        np.testing.assert_array_equal(res.err_hist[[0, 2]], mp.err_hist)
        np.testing.assert_array_equal(res.objective_hist[[0, 2]],
                                      mp.objective_hist)
        # learning keeps at least as much weight on intra-cluster edges
        assert res.intra_mass_hist[1, -1] >= \
            res.intra_mass_hist[0, -1] - 1e-3
        assert np.isfinite(res.objective_hist).all()


# ---------------------------------------------------------------------------
# 8-fake-device subprocess acceptance (mirrors test_partition's pattern)
# ---------------------------------------------------------------------------


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    assert jax.device_count() == 8
    from repro.core.graph_learning import cluster_edge_recovery
    from repro.data.synthetic import two_cluster_mean_problem
    from repro.simulate import (NetworkConditions,
                                planted_partition_topology,
                                run_joint_scenario,
                                run_joint_scenario_sharded)

    # n = 203 not divisible by 8; two planted clusters
    n = 203
    topo = planted_partition_topology(n, 2, k_intra=5, k_inter=2, seed=0)
    labels, _, sol, c = two_cluster_mean_problem(n, p=4, seed=0)
    kw = dict(rounds=300, batch=64, seed=1, record_every=50,
              eta_graph=0.3, lam=1.0, graph_every=5, prune_eps=1e-3)
    tr = run_joint_scenario(topo, sol, c, 0.9, NetworkConditions(), **kw)
    sh = run_joint_scenario_sharded(topo, sol, c, 0.9, NetworkConditions(),
                                    recompact_every=100,
                                    recompact_frac=0.05, **kw)
    assert sh.n_shards == 8 and sh.overflow == 0
    assert np.abs(sh.theta_hist - tr.theta_hist).max() == 0.0
    assert np.abs(sh.final_w - tr.final_w).max() == 0.0
    assert sh.recompactions >= 1
    rec = cluster_edge_recovery(topo.tables.nbr_idx, topo.tables.deg_count,
                                sh.final_w, labels)
    assert rec.intra_recovered >= 0.9, rec
    assert rec.inter_mass <= 0.05, rec

    # rate 0 == MP, sharded, under faults
    from repro.simulate import run_mp_scenario_sharded
    cond = NetworkConditions(drop_prob=0.1, stale_prob=0.3,
                             churn_rate=0.01, straggler_frac=0.3,
                             partition_start=5, partition_end=20)
    mp = run_mp_scenario_sharded(topo, sol, c, 0.9, cond, rounds=40,
                                 batch=32, seed=3, record_every=10)
    jt = run_joint_scenario_sharded(topo, sol, c, 0.9, cond, rounds=40,
                                    batch=32, seed=3, record_every=10)
    assert np.abs(jt.theta_hist - mp.theta_hist).max() == 0.0
    print("JOINT-8DEV-OK", rec.intra_recovered)
""")


def test_eight_device_joint_subprocess():
    """Full 8-shard joint-learning acceptance in a subprocess (the XLA
    device-count flag must precede jax init, which pytest already did)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SUBPROC], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "JOINT-8DEV-OK" in out.stdout

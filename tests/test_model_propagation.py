"""Claims C1/C2: Prop. 1 closed form, Eq. (5) convergence, Theorem 1."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (gaussian_kernel_graph, two_moons,
                        closed_form, synchronous, async_gossip, mp_objective,
                        label_propagation)


@pytest.fixture(autouse=True, scope="module")
def _x64_scoped():
    """Enable f64 for this module only — leaking x64 into the rest of the
    suite changes index/literal dtypes session-wide."""
    jax.config.update("jax_enable_x64", True)
    yield
    jax.config.update("jax_enable_x64", False)


def small_problem(seed=0, n=12, p=3):
    rng = np.random.default_rng(seed)
    pts = rng.standard_normal((n, 2))
    g = gaussian_kernel_graph(pts, sigma=1.0)
    theta_sol = rng.standard_normal((n, p))
    c = rng.uniform(0.05, 1.0, n)
    return g, theta_sol, c


class TestClosedForm:
    def test_is_stationary_point_of_qmp(self):
        """C1: Prop. 1 output is the argmin of Q_MP (gradient ~ 0)."""
        g, theta_sol, c = small_problem()
        alpha = 0.9
        mu = (1 - alpha) / alpha
        theta_star = np.asarray(closed_form(g, theta_sol, c, alpha))
        grad = jax.grad(lambda th: mp_objective(th, jnp.asarray(theta_sol),
                                                jnp.asarray(g.W),
                                                jnp.asarray(c), mu))(
            jnp.asarray(theta_star))
        np.testing.assert_allclose(np.asarray(grad), 0.0, atol=1e-8)

    def test_beats_random_perturbations(self):
        g, theta_sol, c = small_problem(1)
        alpha = 0.8
        mu = (1 - alpha) / alpha
        theta_star = np.asarray(closed_form(g, theta_sol, c, alpha))
        q = lambda th: float(mp_objective(jnp.asarray(th), jnp.asarray(theta_sol),
                                          jnp.asarray(g.W), jnp.asarray(c), mu))
        q_star = q(theta_star)
        rng = np.random.default_rng(0)
        for _ in range(10):
            assert q(theta_star + 0.1 * rng.standard_normal(theta_star.shape)) > q_star

    def test_label_propagation_special_case(self):
        """C = I recovers Zhou et al. (2004) label propagation."""
        g, theta_sol, _ = small_problem(2)
        alpha = 0.95
        lp = np.asarray(label_propagation(g, theta_sol, alpha))
        # Zhou et al: F* = (1-alpha)(I - alpha S)^{-1} Y with S=P here
        n = g.n
        expect = (1 - alpha) * np.linalg.solve(np.eye(n) - alpha * g.P, theta_sol)
        np.testing.assert_allclose(lp, expect, rtol=1e-10)

    def test_confidence_strictly_more_general(self):
        """Unequal C cannot be absorbed into Theta_sol with C=I (paper §3.1)."""
        g, theta_sol, c = small_problem(3)
        alpha = 0.9
        with_c = np.asarray(closed_form(g, theta_sol, c, alpha))
        without_c = np.asarray(closed_form(g, theta_sol, np.ones(g.n), alpha))
        assert not np.allclose(with_c, without_c, atol=1e-6)


class TestSynchronous:
    def test_converges_to_closed_form(self):
        g, theta_sol, c = small_problem(4)
        alpha = 0.9
        star = np.asarray(closed_form(g, theta_sol, c, alpha))
        it = np.asarray(synchronous(g, theta_sol, c, alpha, steps=2000))
        np.testing.assert_allclose(it, star, rtol=0, atol=1e-5)

    def test_any_init(self):
        """Appendix B: convergence regardless of Theta(0)."""
        g, theta_sol, c = small_problem(5)
        alpha = 0.85
        star = np.asarray(closed_form(g, theta_sol, c, alpha))
        rng = np.random.default_rng(0)
        init = rng.standard_normal(star.shape) * 10
        it = np.asarray(synchronous(g, theta_sol, c, alpha, steps=3000,
                                    theta0=init))
        np.testing.assert_allclose(it, star, rtol=0, atol=1e-5)


class TestAsyncGossip:
    def test_theorem1_convergence_in_expectation(self):
        """C2/Thm 1: E[theta_i(t)] -> theta_i*; single long run gets close."""
        g, theta_sol, c = small_problem(6, n=10, p=2)
        alpha = 0.9
        star = np.asarray(closed_form(g, theta_sol, c, alpha))
        tr = async_gossip(g, theta_sol, c, alpha, steps=8000, seed=0,
                          record_every=500)
        final = tr.theta_hist[-1]
        err0 = np.linalg.norm(np.asarray(theta_sol) - star)
        err = np.linalg.norm(final - star)
        assert err < 0.05 * err0, (err, err0)

    def test_neighbor_knowledge_converges_too(self):
        """Thm 1 also covers Theta_tilde_i^j for j in N_i."""
        g, theta_sol, c = small_problem(7, n=8, p=2)
        alpha = 0.9
        star = np.asarray(closed_form(g, theta_sol, c, alpha))
        tr = async_gossip(g, theta_sol, c, alpha, steps=8000, seed=1,
                          record_every=1000)
        K = tr.final_knowledge
        for i in range(g.n):
            for j in list(g.neighbors(i)) + [i]:
                assert np.linalg.norm(K[i, j] - star[j]) < 0.15 * (
                    1.0 + np.linalg.norm(star[j]))

    def test_error_decreases(self):
        g, theta_sol, c = small_problem(8, n=10, p=1)
        alpha = 0.95
        star = np.asarray(closed_form(g, theta_sol, c, alpha))
        tr = async_gossip(g, theta_sol, c, alpha, steps=6000, seed=2,
                          record_every=1000)
        errs = np.linalg.norm(tr.theta_hist - star[None], axis=(1, 2))
        assert errs[-1] < errs[0]
        assert errs[-1] < 0.2 * errs[0]


class TestMeanEstimationSetup:
    """Sanity of the §5.1 experimental generator."""

    def test_two_moons_shapes(self):
        pts, labels = two_moons(300, seed=0)
        assert pts.shape == (300, 2)
        assert set(labels.tolist()) == {0, 1}

    def test_kernel_graph_connected(self):
        pts, _ = two_moons(50, seed=1)
        g = gaussian_kernel_graph(pts, sigma=0.1)
        assert g.is_connected()
        assert g.n == 50

"""Differential test harness over ScenarioSpec (ISSUE: nonlinear primal).

One invariant checker, two drivers: a pinned grid of cells that always
runs (clean/faulty x exact/inexact x single/sharded), and a
hypothesis-driven fuzzer (optional dev dep) that draws fault rates, RNG
seeds, ADMM constants, and solver configs.  Invariants, per cell:

* same-seed replay is bit-identical (theta history AND every counter);
* message accounting: delivered + dropped == 2 * (events - invalid);
* telemetry is observation-only — enabling it leaves theta bit-identical
  to the anchor run;
* exact-vs-inexact ordering: the B->inf quadratic configuration tracks
  the exact engine to f32 rounding, and B=1 is never closer than B=128.
"""

import numpy as np
import pytest

from repro.core.losses import AgentData
from repro.core.primal import ExactQuadraticPrimal, InexactPrimal
from repro.simulate import (NetworkConditions, ScenarioSpec,
                            random_geometric_topology, run_scenario)
from repro.telemetry import TelemetryConfig

N, M, Q = 16, 6, 3   # one static shape -> every cell shares the jit cache


def make_spec(data_seed=0, drop=0.0, stale=0.0, run_seed=0, mu=0.4,
              rho=1.0, rounds=12, batch=6, **kw):
    """One fuzzable scenario cell (fixed shapes, variable everything else)."""
    rng = np.random.default_rng(data_seed)
    topo = random_geometric_topology(N, k=4, seed=data_seed)
    x = rng.standard_normal((N, M, Q)).astype(np.float32)
    counts = rng.integers(1, M + 1, N)
    mask = (np.arange(M)[None] < counts[:, None]).astype(np.float32)
    data = AgentData(x=x, y=np.zeros((N, M), np.float32), mask=mask)
    sol = (np.sum(x * mask[..., None], 1)
           / np.maximum(counts, 1)[:, None]).astype(np.float32)
    cfg = dict(algo="cl", topology=topo, data=data, mu=mu, rho=rho,
               conditions=NetworkConditions(drop_prob=drop, stale_prob=stale),
               rounds=rounds, batch=batch, seed=run_seed, record_every=4,
               theta_sol=sol)
    cfg.update(kw)
    return ScenarioSpec(**cfg)


def check_invariants(spec: ScenarioSpec):
    """Run the cell twice (+ a telemetry twin) and assert the invariants."""
    tr = run_scenario(spec)
    assert tr.delivered + tr.dropped == 2 * (tr.events - tr.invalid)
    assert np.isfinite(tr.theta_hist).all()
    replay = run_scenario(spec)
    assert np.array_equal(replay.theta_hist, tr.theta_hist)
    assert (replay.delivered, replay.dropped, replay.invalid) == \
        (tr.delivered, tr.dropped, tr.invalid)
    import dataclasses
    teled = run_scenario(dataclasses.replace(
        spec, telemetry=TelemetryConfig(enabled=True)))
    assert np.array_equal(teled.theta_hist, tr.theta_hist)
    assert teled.telemetry is not None
    return tr


PRIMALS = {"none": None, "exact": ExactQuadraticPrimal(),
           "b4": InexactPrimal(loss="quadratic", b_steps=4, lr=0.2),
           "binf": InexactPrimal(loss="quadratic", b_steps=None)}


class TestPinnedCells:
    @pytest.mark.parametrize("primal", sorted(PRIMALS))
    @pytest.mark.parametrize("drop,stale", [(0.0, 0.0), (0.25, 0.3)])
    def test_invariants(self, primal, drop, stale):
        check_invariants(make_spec(drop=drop, stale=stale,
                                   primal=PRIMALS[primal]))

    @pytest.mark.parametrize("primal", ["none", "binf"])
    def test_invariants_sharded(self, primal):
        check_invariants(make_spec(drop=0.2, primal=PRIMALS[primal],
                                   sharded=True))

    def test_exact_vs_inexact_ordering(self):
        exact = run_scenario(make_spec(drop=0.2))
        err = {}
        for b in (None, 1, 128):
            tr = run_scenario(make_spec(
                drop=0.2,
                primal=InexactPrimal(loss="quadratic", b_steps=b, lr=0.2)))
            err[b] = float(np.abs(tr.theta_hist - exact.theta_hist).max())
        assert err[None] <= 1e-5
        assert err[128] <= err[1]


# ---------------------------------------------------------------------------
# hypothesis fuzzing (optional dev dep; profiles in tests/conftest.py)
# ---------------------------------------------------------------------------

try:                                 # pinned cells above still run without
    from hypothesis import given, settings, strategies as st
except ImportError:                  # pragma: no cover - no-dev-deps envs
    st = None

if st is not None:
    primal_st = st.one_of(
        st.none(),
        st.just(ExactQuadraticPrimal()),
        st.builds(InexactPrimal, loss=st.just("quadratic"),
                  b_steps=st.integers(1, 8),
                  lr=st.sampled_from([0.05, 0.2])),
        st.just(InexactPrimal(loss="quadratic", b_steps=None)))

    class TestFuzzedCells:
        @settings(max_examples=25, deadline=None)
        @given(data_seed=st.integers(0, 2**16),
               run_seed=st.integers(0, 2**16),
               drop=st.floats(0.0, 0.5), stale=st.floats(0.0, 0.5),
               mu=st.sampled_from([0.1, 0.4, 1.0]),
               rho=st.sampled_from([0.5, 1.0]), primal=primal_st)
        def test_invariants_hold_for_any_cell(self, data_seed, run_seed,
                                              drop, stale, mu, rho, primal):
            check_invariants(make_spec(
                data_seed=data_seed, run_seed=run_seed, drop=drop,
                stale=stale, mu=mu, rho=rho, primal=primal))

        @settings(max_examples=10, deadline=None)
        @given(data_seed=st.integers(0, 2**16),
               run_seed=st.integers(0, 2**16), drop=st.floats(0.0, 0.4))
        def test_b_inf_anchor_for_any_schedule(self, data_seed, run_seed,
                                               drop):
            exact = run_scenario(make_spec(data_seed=data_seed,
                                           run_seed=run_seed, drop=drop))
            inex = run_scenario(make_spec(
                data_seed=data_seed, run_seed=run_seed, drop=drop,
                primal=InexactPrimal(loss="quadratic", b_steps=None)))
            assert np.abs(inex.theta_hist - exact.theta_hist).max() <= 1e-5

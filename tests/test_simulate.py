"""Sparse event-driven simulator: sparse<->dense equivalence, topology
generators, fault scenarios, event-sampling edge cases (degree-0 agents,
all-churned wake draws, the shared recording policy), and the edge-coloring
matching property."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (async_admm, async_gossip, gaussian_kernel_graph,
                        pad_datasets, random_geometric_graph, ring_graph,
                        solitary_mean, synchronous)
from repro.core.sparse import (record_chunks, sample_event,
                               tables_from_adjacency)
from repro.kernels import ops, ref
from repro.simulate import (NetworkConditions, SparseTopology,
                            cluster_topology, draw_events, draw_slots,
                            draw_wakeups, get_scenario, list_scenarios,
                            precompute_event_stream,
                            random_geometric_topology, ring_topology,
                            run_mp_scenario, sparse_async_admm,
                            sparse_async_gossip, sparse_sync_mp)


def isolated_agent_topology(n: int = 12, iso: int = 5) -> SparseTopology:
    """A ring over all agents except ``iso``, which has degree 0."""
    nbrs, wts = [], []
    ring = [v for v in range(n) if v != iso]
    pos = {v: t for t, v in enumerate(ring)}
    m = len(ring)
    for v in range(n):
        if v == iso:
            nbrs.append(np.array([], np.int64))
            wts.append(np.ones(0))
            continue
        t = pos[v]
        nb = np.sort(np.unique([ring[(t - 1) % m], ring[(t + 1) % m]]))
        nbrs.append(nb)
        wts.append(np.ones(len(nb)))
    tabs = tables_from_adjacency(nbrs, wts, allow_isolated=True)
    return SparseTopology(tabs, (np.arange(n) * 2 >= n).astype(np.int32))


# ---------------------------------------------------------------------------
# sparse <-> dense trajectory equivalence (the tentpole invariant)
# ---------------------------------------------------------------------------


class TestSparseDenseEquivalence:
    def test_mp_gossip_bit_for_bit(self):
        """Same seed -> sparse engine reproduces the dense (n, n, p)
        async_gossip trajectory exactly, not just approximately."""
        g = random_geometric_graph(16, k=3, seed=1)
        rng = np.random.default_rng(0)
        sol = rng.standard_normal((16, 3)).astype(np.float32)
        c = rng.uniform(0.05, 1.0, 16).astype(np.float32)
        dense = async_gossip(g, sol, c, 0.9, steps=400, seed=3,
                             record_every=50)
        topo = SparseTopology.from_graph(g)
        sparse = sparse_async_gossip(topo, sol, c, 0.9, steps=400, seed=3,
                                     record_every=50)
        assert np.array_equal(dense.theta_hist, sparse.theta_hist)
        diag = dense.final_knowledge[np.arange(16), np.arange(16)]
        assert np.array_equal(diag, sparse.final_theta)
        # neighbor knowledge matches slot-for-slot too
        tabs = topo.tables
        for i in range(16):
            for s in range(tabs.deg_count[i]):
                assert np.array_equal(
                    dense.final_knowledge[i, tabs.nbr_idx[i, s]],
                    sparse.final_knowledge[i, s])

    def test_mp_gossip_record_every_one(self):
        g = ring_graph(8)
        rng = np.random.default_rng(1)
        sol = rng.standard_normal((8, 2)).astype(np.float32)
        c = np.ones(8, np.float32)
        dense = async_gossip(g, sol, c, 0.8, steps=64, seed=0, record_every=1)
        sparse = sparse_async_gossip(SparseTopology.from_graph(g), sol, c,
                                     0.8, steps=64, seed=0, record_every=1)
        assert np.array_equal(dense.theta_hist, sparse.theta_hist)

    def test_admm_bit_for_bit(self):
        """16-agent quadratic CL-ADMM: same-seed trajectories identical."""
        n = 16
        rng = np.random.default_rng(2)
        pts = rng.standard_normal((n, 2)) * 0.5
        g = gaussian_kernel_graph(pts, sigma=1.0)
        xs = [rng.standard_normal((int(rng.integers(1, 12)), 1))
              for _ in range(n)]
        data = pad_datasets(xs, [np.zeros(len(x)) for x in xs])
        sol = solitary_mean(data)
        dense = async_admm(g, data, 0.1, 1.0, "quadratic", steps=300, seed=5,
                           record_every=50, theta_sol=sol)
        sparse = sparse_async_admm(SparseTopology.from_graph(g), data, 0.1,
                                   1.0, steps=300, seed=5, record_every=50,
                                   theta_sol=sol)
        assert np.array_equal(dense.theta_hist, sparse.theta_hist)

    def test_sync_sweep_matches_dense_synchronous(self):
        g = random_geometric_graph(24, k=3, seed=2)
        rng = np.random.default_rng(3)
        sol = rng.standard_normal((24, 5)).astype(np.float32)
        c = rng.uniform(0.05, 1.0, 24).astype(np.float32)
        dense = np.asarray(synchronous(g, sol, c, 0.9, steps=50))
        sparse = np.asarray(sparse_sync_mp(SparseTopology.from_graph(g), sol,
                                           c, 0.9, sweeps=50))
        np.testing.assert_allclose(dense, sparse, atol=1e-5)


# ---------------------------------------------------------------------------
# topology container + generators
# ---------------------------------------------------------------------------


def _check_topology(topo):
    tabs = topo.tables
    n, k = tabs.n, tabs.k_max
    assert (tabs.deg_count >= 1).all()
    live = np.arange(k)[None, :] < tabs.deg_count[:, None]
    # pads carry zero weight and duplicate the last live neighbor
    assert (tabs.nbr_w[~live] == 0).all()
    assert (tabs.nbr_p[~live] == 0).all()
    # sorted, self-loop-free neighbor ids
    for i in range(n):
        d = tabs.deg_count[i]
        row = tabs.nbr_idx[i, :d]
        assert (np.diff(row) > 0).all()
        assert i not in row
    # rev_slot inverts the edge: nbr_idx[j, rev_slot[i, s]] == i
    i_idx = np.repeat(np.arange(n), k)
    s_idx = np.tile(np.arange(k), n)
    j_idx = tabs.nbr_idx[i_idx, s_idx]
    back = tabs.nbr_idx[j_idx, tabs.rev_slot[i_idx, s_idx]]
    assert (back == i_idx).all()
    # symmetric adjacency: j in N_i  =>  i in N_j (implied by rev check)
    # slot cdf is the uniform pi_i
    last = tabs.slot_cdf[np.arange(n), tabs.deg_count - 1]
    np.testing.assert_allclose(last, 1.0, atol=1e-5)


class TestTopology:
    def test_from_graph(self):
        _check_topology(SparseTopology.from_graph(
            random_geometric_graph(40, k=4, seed=0)))

    def test_ring(self):
        topo = ring_topology(64)
        assert topo.k_max == 2 and topo.n_edges == 64
        _check_topology(topo)

    def test_random_geometric_scales_without_dense_matrix(self):
        topo = random_geometric_topology(3000, k=6, seed=0)
        _check_topology(topo)
        assert topo.n == 3000
        assert topo.k_max < 64                      # O(n k) storage, not O(n^2)
        assert topo.state_bytes(32) < topo.dense_state_bytes(32) / 20

    def test_cluster(self):
        topo = cluster_topology(400, n_clusters=8, k_intra=4, bridges=3,
                                seed=0)
        _check_topology(topo)
        assert set(topo.groups.tolist()) == set(range(8))
        halves = topo.partition_halves()
        assert 0 < halves.sum() < 400

    def test_from_graph_matches_dense_quantities(self):
        g = gaussian_kernel_graph(np.random.default_rng(0).standard_normal(
            (12, 2)), sigma=1.0)
        tabs = SparseTopology.from_graph(g).tables
        np.testing.assert_allclose(tabs.deg_w, g.degrees)
        P = g.P
        for i in range(12):
            d = tabs.deg_count[i]
            np.testing.assert_allclose(tabs.nbr_p[i, :d],
                                       P[i, tabs.nbr_idx[i, :d]], rtol=1e-6)


# ---------------------------------------------------------------------------
# edge coloring: matchings are vertex-disjoint and cover E
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(8))
def test_edge_coloring_matchings_cover_and_disjoint(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(4, 24))
    kind = seed % 3
    if kind == 0:
        g = ring_graph(n)
    elif kind == 1:
        g = random_geometric_graph(n, k=min(3, n - 1), seed=seed)
    else:
        g = gaussian_kernel_graph(rng.standard_normal((n, 2)), sigma=1.0)
    matchings = g.edge_coloring()
    seen = set()
    for matching in matchings:
        busy = set()
        for (i, j) in matching:
            assert i not in busy and j not in busy, "matching not disjoint"
            busy.update((i, j))
            seen.add((min(i, j), max(i, j)))
    assert seen == set(g.edges()), "matchings must cover E exactly"


# ---------------------------------------------------------------------------
# scheduler + fault scenarios
# ---------------------------------------------------------------------------


class TestScenarios:
    @pytest.fixture(scope="class")
    def setup(self):
        topo = random_geometric_topology(192, k=5, seed=0)
        rng = np.random.default_rng(0)
        sol = rng.standard_normal((192, 4)).astype(np.float32)
        c = rng.uniform(0.05, 1.0, 192).astype(np.float32)
        star = np.asarray(sparse_sync_mp(topo, sol, c, 0.9, sweeps=300))
        return topo, sol, c, star

    def test_registry_complete(self):
        assert {"clean", "lossy-10", "straggler-tail", "churn-5",
                "partition-heal"} <= set(list_scenarios())
        with pytest.raises(KeyError):
            get_scenario("nope")

    def test_clean_converges_to_fixed_point(self, setup):
        topo, sol, c, star = setup
        tr = run_mp_scenario(topo, sol, c, 0.9,
                             NetworkConditions(), rounds=250, batch=48,
                             seed=0, record_every=50)
        e0 = np.linalg.norm(sol - star)
        e1 = np.linalg.norm(tr.theta_hist[-1] - star)
        assert e1 < 0.1 * e0
        assert tr.dropped == 0
        assert tr.delivered == 2 * tr.events

    def test_lossy_still_converges(self, setup):
        topo, sol, c, star = setup
        tr = run_mp_scenario(topo, sol, c, 0.9,
                             NetworkConditions(drop_prob=0.1, stale_prob=0.05),
                             rounds=250, batch=48, seed=0, record_every=50)
        e0 = np.linalg.norm(sol - star)
        e1 = np.linalg.norm(tr.theta_hist[-1] - star)
        assert e1 < 0.2 * e0
        assert tr.dropped > 0
        assert tr.delivered + tr.dropped == 2 * tr.events

    def test_churn_deactivates_agents(self, setup):
        topo, sol, c, star = setup
        tr = run_mp_scenario(topo, sol, c, 0.9,
                             NetworkConditions(churn_rate=0.002),
                             rounds=200, batch=32, seed=1, record_every=50)
        assert tr.active_hist[-1] < 1.0
        assert np.isfinite(tr.theta_hist).all()

    def test_partition_drops_cross_half_traffic_then_heals(self, setup):
        topo, sol, c, star = setup
        cond = NetworkConditions(partition_start=50, partition_end=150)
        tr = run_mp_scenario(topo, sol, c, 0.9, cond, rounds=300, batch=48,
                             seed=0, record_every=50)
        assert tr.dropped > 0                       # cut edges during window
        e0 = np.linalg.norm(sol - star)
        e1 = np.linalg.norm(tr.theta_hist[-1] - star)
        assert e1 < 0.15 * e0                       # heals and converges

    def test_staleness_changes_trajectory_but_converges(self, setup):
        """stale deliveries must actually deliver old models (regression:
        the pre-round snapshot, not the post-update one, is the payload)."""
        topo, sol, c, star = setup
        clean = run_mp_scenario(topo, sol, c, 0.9, NetworkConditions(),
                                rounds=150, batch=32, seed=3, record_every=50)
        stale = run_mp_scenario(topo, sol, c, 0.9,
                                NetworkConditions(stale_prob=1.0),
                                rounds=150, batch=32, seed=3, record_every=50)
        assert not np.array_equal(clean.theta_hist, stale.theta_hist)
        e0 = np.linalg.norm(sol - star)
        e1 = np.linalg.norm(stale.theta_hist[-1] - star)
        assert e1 < 0.5 * e0                      # old news still converges

    def test_short_horizon_not_exceeded(self):
        """rounds < record_every must not silently run extra rounds."""
        topo = ring_topology(32)
        sol = np.ones((32, 2), np.float32)
        c = np.ones(32, np.float32)
        tr = run_mp_scenario(topo, sol, c, 0.9, NetworkConditions(),
                             rounds=5, batch=4, seed=0, record_every=10)
        assert tr.rounds == 5
        assert tr.events == 20

    def test_straggler_slows_convergence(self, setup):
        topo, sol, c, star = setup
        fast = run_mp_scenario(topo, sol, c, 0.9, NetworkConditions(),
                               rounds=120, batch=32, seed=2, record_every=40)
        slow = run_mp_scenario(
            topo, sol, c, 0.9,
            NetworkConditions(straggler_frac=0.5, straggler_factor=0.02),
            rounds=120, batch=32, seed=2, record_every=40)
        e_fast = np.linalg.norm(fast.theta_hist[-1] - star)
        e_slow = np.linalg.norm(slow.theta_hist[-1] - star)
        assert e_slow > e_fast


# ---------------------------------------------------------------------------
# event-sampling edge cases (ISSUE 4 bugfixes) + accounting invariants
# ---------------------------------------------------------------------------


class TestDegreeZeroEvents:
    """A degree-0 agent's wake-up must be a no-op, not a phantom edge.

    Pre-fix, ``min(s, deg - 1) = -1`` wrapped via negative indexing into the
    last pad slot and fabricated an edge to whatever id the zero-initialized
    pad row held (agent 0)."""

    def test_sample_event_slot_never_negative(self):
        topo = isolated_agent_topology(12, iso=5)
        tabs = topo.device_tables()
        hit_iso = False
        for seed in range(200):
            i, s = sample_event(jax.random.PRNGKey(seed), 12, tabs.slot_cdf,
                                tabs.deg_count)
            assert int(s) >= 0, seed
            hit_iso |= int(i) == 5
        assert hit_iso          # the draw does reach the isolated agent

    def test_draw_slots_degree_zero_clamped(self):
        deg = jnp.asarray([3, 0, 1], jnp.int32)
        i = jnp.asarray([1, 1, 0, 2], jnp.int32)
        s = draw_slots(jax.random.PRNGKey(0), i, deg)
        assert (np.asarray(s) >= 0).all()

    def test_exact_gossip_isolated_agent_untouched(self):
        topo = isolated_agent_topology(12, iso=5)
        rng = np.random.default_rng(0)
        sol = rng.standard_normal((12, 3)).astype(np.float32)
        c = rng.uniform(0.1, 1.0, 12).astype(np.float32)
        tr = sparse_async_gossip(topo, sol, c, 0.9, steps=400, seed=0,
                                 record_every=100)
        # the isolated agent keeps its solitary model (pre-fix, its wake-ups
        # fabricated an edge to agent 0 — the zero-initialized pad row id —
        # and both endpoints' models moved)
        np.testing.assert_array_equal(tr.final_theta[5], sol[5])
        assert np.isfinite(tr.theta_hist).all()

    def test_exact_admm_isolated_agent_untouched(self):
        topo = isolated_agent_topology(10, iso=3)
        rng = np.random.default_rng(1)
        xs = [rng.standard_normal((int(rng.integers(1, 6)), 2))
              for _ in range(10)]
        data = pad_datasets(xs, [np.zeros(len(x)) for x in xs])
        sol = np.asarray(solitary_mean(data), np.float32)
        tr = sparse_async_admm(topo, data, 0.1, 1.0, steps=200, seed=0,
                               record_every=50, theta_sol=sol)
        np.testing.assert_array_equal(np.asarray(tr.final.theta)[3], sol[3])
        assert np.isfinite(tr.theta_hist).all()

    def test_scenario_isolated_agent_is_invalid_not_dropped(self):
        topo = isolated_agent_topology(12, iso=5)
        rng = np.random.default_rng(0)
        sol = rng.standard_normal((12, 2)).astype(np.float32)
        c = rng.uniform(0.1, 1.0, 12).astype(np.float32)
        tr = run_mp_scenario(topo, sol, c, 0.9, NetworkConditions(),
                             rounds=50, batch=8, seed=0, record_every=10)
        # the isolated agent wakes sometimes: those events are invalid, not
        # lost messages, and its model never moves
        assert tr.invalid > 0
        assert tr.dropped == 0
        assert tr.delivered + tr.dropped == 2 * (tr.events - tr.invalid)
        np.testing.assert_array_equal(tr.theta_hist[-1][5], sol[5])


class TestAllChurnedWakeups:
    """When every agent is churned out the wake CDF is all-zero; pre-fix
    searchsorted deterministically picked agent n-1 and the dead events
    inflated ``dropped``."""

    def test_draw_wakeups_flags_dead_network(self):
        i, alive = draw_wakeups(jax.random.PRNGKey(0), jnp.zeros(16), 8)
        assert not bool(alive)
        i2, alive2 = draw_wakeups(jax.random.PRNGKey(0), jnp.ones(16), 8)
        assert bool(alive2)

    def test_draw_events_all_inactive_marks_invalid(self):
        topo = ring_topology(16)
        tabs = topo.device_tables()
        ev = draw_events(jax.random.PRNGKey(1), NetworkConditions(), tabs,
                         jnp.asarray(topo.partition_halves()),
                         jnp.zeros(16, bool), jnp.ones(16), 0, 8)
        assert not np.asarray(ev.valid).any()
        assert not np.asarray(ev.deliver_ij).any()

    def test_emptied_network_excluded_from_counters(self):
        """churn_rate high enough to empty a 4-agent ring for some rounds:
        the dead-round draws are invalid and charged to neither counter."""
        topo = ring_topology(4)
        sol = np.ones((4, 2), np.float32)
        c = np.ones(4, np.float32)
        tr = run_mp_scenario(topo, sol, c, 0.9,
                             NetworkConditions(churn_rate=0.9), rounds=60,
                             batch=4, seed=0, record_every=10)
        assert tr.invalid > 0
        assert tr.delivered + tr.dropped == 2 * (tr.events - tr.invalid)
        assert np.isfinite(tr.theta_hist).all()
        # the materialized stream agrees event-for-event
        stream = precompute_event_stream(
            topo.device_tables(), jnp.asarray(topo.partition_halves()),
            NetworkConditions(churn_rate=0.9), 4, 0, tr.rounds)
        assert int((~np.asarray(stream.valid)).sum()) == tr.invalid
        delivered = int(np.asarray(stream.deliver_ij).sum()
                        + np.asarray(stream.deliver_ji).sum())
        assert delivered == tr.delivered


class TestRecordingPolicy:
    """All six ``// record_every`` sites share ``record_chunks``: clamp to
    [1, steps], floor to whole chunks — never zero steps, never an overrun."""

    def test_record_chunks_contract(self):
        assert record_chunks(5, 100) == (5, 1)      # clamp: steps < every
        assert record_chunks(7, 5) == (5, 1)        # floor: non-divisible
        assert record_chunks(100, 10) == (10, 10)   # divisible: unchanged
        assert record_chunks(1, 1) == (1, 1)
        with pytest.raises(ValueError):
            record_chunks(0, 10)

    def test_short_horizon_gossip_runs_steps_not_zero(self):
        """Pre-fix: steps < record_every silently ran ZERO steps and
        returned an empty history."""
        g = ring_graph(8)
        rng = np.random.default_rng(1)
        sol = rng.standard_normal((8, 2)).astype(np.float32)
        c = np.ones(8, np.float32)
        short = sparse_async_gossip(SparseTopology.from_graph(g), sol, c,
                                    0.8, steps=5, seed=0, record_every=100)
        explicit = sparse_async_gossip(SparseTopology.from_graph(g), sol, c,
                                       0.8, steps=5, seed=0, record_every=5)
        assert short.theta_hist.shape[0] == 1
        np.testing.assert_array_equal(short.theta_hist, explicit.theta_hist)
        assert not np.array_equal(short.theta_hist[-1], sol)   # it DID run
        dense = async_gossip(g, sol, c, 0.8, steps=5, seed=0,
                             record_every=100)
        np.testing.assert_array_equal(dense.theta_hist, short.theta_hist)

    def test_short_horizon_admm_does_not_overrun(self):
        """Pre-fix: ``max(1, steps // record_every)`` ran a full oversized
        chunk — 50 ticks for a 5-step request."""
        n = 8
        rng = np.random.default_rng(2)
        pts = rng.standard_normal((n, 2)) * 0.5
        g = gaussian_kernel_graph(pts, sigma=1.0)
        xs = [rng.standard_normal((int(rng.integers(1, 6)), 1))
              for _ in range(n)]
        data = pad_datasets(xs, [np.zeros(len(x)) for x in xs])
        sol = solitary_mean(data)
        topo = SparseTopology.from_graph(g)
        short = sparse_async_admm(topo, data, 0.1, 1.0, steps=5, seed=0,
                                  record_every=50, theta_sol=sol)
        explicit = sparse_async_admm(topo, data, 0.1, 1.0, steps=5, seed=0,
                                     record_every=5, theta_sol=sol)
        np.testing.assert_array_equal(short.theta_hist, explicit.theta_hist)
        dense = async_admm(g, data, 0.1, 1.0, "quadratic", steps=5, seed=0,
                           record_every=50, theta_sol=sol)
        np.testing.assert_array_equal(dense.theta_hist, short.theta_hist)

    def test_non_divisible_steps_floored(self):
        g = ring_graph(8)
        rng = np.random.default_rng(3)
        sol = rng.standard_normal((8, 2)).astype(np.float32)
        c = np.ones(8, np.float32)
        a = sparse_async_gossip(SparseTopology.from_graph(g), sol, c, 0.8,
                                steps=17, seed=0, record_every=5)
        b = sparse_async_gossip(SparseTopology.from_graph(g), sol, c, 0.8,
                                steps=15, seed=0, record_every=5)
        assert a.theta_hist.shape[0] == 3
        np.testing.assert_array_equal(a.theta_hist, b.theta_hist)


class TestAccountingInvariant:
    """delivered + dropped == 2 * (events - invalid) for ``run_mp_scenario``
    across every NetworkConditions field (satellite: test each in
    isolation; invalid == 0 whenever the network never empties)."""

    FIELD_CONDITIONS = {
        "clean": NetworkConditions(),
        "drop": NetworkConditions(drop_prob=0.3),
        "stale": NetworkConditions(stale_prob=0.5),
        "straggler": NetworkConditions(straggler_frac=0.4,
                                       straggler_factor=0.05),
        "churn": NetworkConditions(churn_rate=0.02),
        "partition": NetworkConditions(partition_start=5, partition_end=25),
        "all": NetworkConditions(drop_prob=0.15, stale_prob=0.2,
                                 straggler_frac=0.3, straggler_factor=0.1,
                                 churn_rate=0.02, partition_start=5,
                                 partition_end=25),
    }

    @pytest.mark.parametrize("name", sorted(FIELD_CONDITIONS))
    def test_invariant(self, name):
        cond = self.FIELD_CONDITIONS[name]
        topo = random_geometric_topology(150, k=4, seed=0)
        rng = np.random.default_rng(0)
        sol = rng.standard_normal((150, 3)).astype(np.float32)
        c = rng.uniform(0.05, 1.0, 150).astype(np.float32)
        tr = run_mp_scenario(topo, sol, c, 0.9, cond, rounds=40, batch=32,
                             seed=7, record_every=10)
        assert tr.delivered + tr.dropped == 2 * (tr.events - tr.invalid)
        assert tr.invalid == 0          # 150 agents never all churn out
        if name == "clean":
            assert tr.dropped == 0 and tr.delivered == 2 * tr.events
        if name in ("drop", "partition", "all"):
            assert tr.dropped > 0


# ---------------------------------------------------------------------------
# sparse gather-mix kernel
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,k,p", [(64, 4, 32), (100, 7, 40), (130, 2, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sparse_gather_mix_matches_ref(n, k, p, dtype):
    rng = np.random.default_rng(n + k)
    idx = jnp.asarray(rng.integers(0, n, (n, k)), jnp.int32)
    w = rng.uniform(0, 1, (n, k)).astype(np.float32)
    w[:, -1] = 0.0                                  # a pad column
    w = jnp.asarray(w)
    b = jnp.asarray(rng.uniform(0, 1, n), jnp.float32)
    table = jnp.asarray(rng.standard_normal((n, p)), dtype)
    sol = jnp.asarray(rng.standard_normal((n, p)), dtype)
    got = ops.sparse_gather_mix(table, idx, w, b, sol)
    want = ref.sparse_gather_mix(table, idx, w, b, sol)
    tol = dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else \
        dict(atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **tol)


def test_sync_sweep_kernel_path_matches_jnp_path():
    topo = random_geometric_topology(200, k=5, seed=1)
    rng = np.random.default_rng(4)
    sol = rng.standard_normal((200, 8)).astype(np.float32)
    c = rng.uniform(0.05, 1.0, 200).astype(np.float32)
    a = np.asarray(sparse_sync_mp(topo, sol, c, 0.9, sweeps=20))
    b = np.asarray(sparse_sync_mp(topo, sol, c, 0.9, sweeps=20,
                                  use_kernel=True))
    np.testing.assert_allclose(a, b, atol=1e-5)

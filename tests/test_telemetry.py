"""In-scan telemetry substrate: the disabled config is a bit-for-bit no-op
on every engine, enabled frames carry per-chunk objective/staleness/drop
attribution that matches host-side references, sharded frames match the
single-device engines exactly (in-process and on an 8-fake-device mesh),
and the manifest/JSONL/report layer round-trips."""

import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core.losses import pad_datasets, solitary_mean
from repro.kernels.dispatch import ReproBackend
from repro.simulate import (NetworkConditions, random_geometric_topology,
                            run_cl_scenario, run_cl_scenario_sharded,
                            run_joint_scenario, run_mp_scenario,
                            run_mp_scenario_sharded)
from repro.telemetry import (TelemetryConfig, backend_config_hash,
                             build_manifest, load_run, render_summary,
                             trace_rows, write_run)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

#: every fault mechanism active, so all three drop causes accumulate
FAULTY = NetworkConditions(drop_prob=0.1, stale_prob=0.3, churn_rate=0.01,
                           straggler_frac=0.3, partition_start=5,
                           partition_end=20)

ON = TelemetryConfig(enabled=True)
OFF = TelemetryConfig(enabled=False)


@pytest.fixture(scope="module")
def problem():
    topo = random_geometric_topology(120, k=4, seed=0)
    rng = np.random.default_rng(0)
    sol = rng.standard_normal((120, 4)).astype(np.float32)
    c = rng.uniform(0.05, 1.0, 120).astype(np.float32)
    xs = [rng.standard_normal((int(rng.integers(1, 6)), 4))
          for _ in range(120)]
    data = pad_datasets(xs, [np.zeros(len(x)) for x in xs])
    cl_sol = np.asarray(solitary_mean(data), np.float32)
    return topo, sol, c, data, cl_sol


MP_KW = dict(rounds=40, batch=16, seed=3, record_every=10)


class TestDisabledAnchor:
    """telemetry=None, enabled=False and enabled=True all run the same
    trajectory; only the enabled run attaches frames."""

    def test_mp(self, problem):
        topo, sol, c, _, _ = problem
        runs = [run_mp_scenario(topo, sol, c, 0.9, FAULTY, telemetry=t,
                                **MP_KW) for t in (None, OFF, ON)]
        assert runs[0].telemetry is None and runs[1].telemetry is None
        assert runs[2].telemetry is not None
        for r in runs[1:]:
            assert np.array_equal(r.theta_hist, runs[0].theta_hist)
            assert (r.delivered, r.dropped, r.invalid) == \
                (runs[0].delivered, runs[0].dropped, runs[0].invalid)

    def test_cl(self, problem):
        topo, _, _, data, cl_sol = problem
        runs = [run_cl_scenario(topo, data, 0.1, 1.0, FAULTY,
                                theta_sol=cl_sol, telemetry=t, **MP_KW)
                for t in (None, OFF, ON)]
        assert runs[2].telemetry is not None
        for r in runs[1:]:
            assert np.array_equal(r.theta_hist, runs[0].theta_hist)

    def test_joint(self, problem):
        topo, sol, c, _, _ = problem
        kw = dict(eta_graph=0.3, lam=1.0, graph_every=5, prune_eps=1e-3)
        runs = [run_joint_scenario(topo, sol, c, 0.9, FAULTY, telemetry=t,
                                   **kw, **MP_KW) for t in (None, OFF, ON)]
        assert runs[2].telemetry is not None
        for r in runs[1:]:
            assert np.array_equal(r.theta_hist, runs[0].theta_hist)
            assert np.array_equal(r.final_w, runs[0].final_w)

    def test_config_is_hashable_static(self):
        assert hash(ON) != hash(OFF) or ON != OFF
        assert {ON: 1, OFF: 2}[TelemetryConfig(enabled=True)] == 1


class TestFrames:
    def test_mp_attribution_invariants(self, problem):
        """Cumulative frame counters end at the trace totals, and the three
        drop causes partition the dropped count exactly."""
        topo, sol, c, _, _ = problem
        tr = run_mp_scenario(topo, sol, c, 0.9, FAULTY, telemetry=ON,
                             **MP_KW)
        f = tr.telemetry
        n_rec = 4
        assert f.objective.shape == (n_rec, topo.n)
        assert f.staleness.shape == (n_rec, topo.n)
        assert int(f.delivered[-1]) == tr.delivered
        assert int(f.invalid[-1]) == tr.invalid
        drops = f.drop_link + f.drop_churn + f.drop_partition
        assert int(drops[-1]) == tr.dropped
        assert int(f.drop_link[-1]) > 0          # every cause fired
        assert int(f.drop_churn[-1]) > 0
        assert int(f.drop_partition[-1]) > 0
        # cumulative columns are monotone
        for col in (f.delivered, drops, f.invalid, f.updates):
            assert np.all(np.diff(col) >= 0)
        assert tr.delivered + tr.dropped == 2 * (tr.events - tr.invalid)

    def test_mp_objective_decreases_on_clean_run(self, problem):
        topo, sol, c, _, _ = problem
        tr = run_mp_scenario(topo, sol, c, 0.9, NetworkConditions(),
                             rounds=120, batch=16, seed=0, record_every=30,
                             telemetry=ON)
        obj = tr.telemetry.objective.astype(np.float64).sum(axis=1)
        assert np.all(np.isfinite(obj))
        assert obj[-1] < obj[0]

    def test_staleness_matches_host_reference(self, problem):
        """Replay the recorded event stream in numpy: every tick ages every
        agent one round, delivered endpoints reset to zero."""
        from test_cl_scenario import exact_admm_stream
        topo, _, _, data, cl_sol = problem
        rounds, re_ = 40, 10
        stream = exact_admm_stream(topo, rounds, re_, seed=7)
        tr = run_cl_scenario(topo, data, 0.1, 1.0, NetworkConditions(),
                             rounds=rounds, batch=1, seed=7,
                             record_every=re_, theta_sol=cl_sol,
                             stream=stream, telemetry=ON)
        i = np.asarray(stream.i)[:, 0]
        j = np.asarray(stream.j)[:, 0]
        d_ij = np.asarray(stream.deliver_ij)[:, 0]
        d_ji = np.asarray(stream.deliver_ji)[:, 0]
        stale = np.zeros(topo.n, np.int64)
        want = []
        for t in range(rounds):
            stale += 1
            if d_ji[t]:
                stale[i[t]] = 0
            if d_ij[t]:
                stale[j[t]] = 0
            if (t + 1) % re_ == 0:
                want.append(stale.copy())
        assert np.array_equal(tr.telemetry.staleness, np.stack(want))

    def test_summary_percentiles(self, problem):
        topo, sol, c, _, _ = problem
        tr = run_mp_scenario(topo, sol, c, 0.9, FAULTY, telemetry=ON,
                             **MP_KW)
        rows = trace_rows(tr)
        assert len(rows) == 4
        last = rows[-1]
        s = tr.telemetry.staleness[-1]
        assert last["staleness_p50"] == float(np.percentile(s, 50))
        assert last["staleness_p99"] == float(np.percentile(s, 99))
        assert last["delivered"] == tr.delivered

    def test_trace_rows_fallback_without_frames(self, problem):
        topo, sol, c, _, _ = problem
        tr = run_mp_scenario(topo, sol, c, 0.9, FAULTY, **MP_KW)
        rows = trace_rows(tr)
        assert len(rows) == 1 and rows[0]["delivered"] == tr.delivered


class TestShardedParity:
    """In-process parity on however many devices exist (P >= 1); the real
    8-shard mesh runs in the subprocess test below."""

    def test_mp_frames_match_single_device(self, problem):
        topo, sol, c, _, _ = problem
        single = run_mp_scenario(topo, sol, c, 0.9, FAULTY, telemetry=ON,
                                 **MP_KW)
        shard = run_mp_scenario_sharded(topo, sol, c, 0.9, FAULTY,
                                        telemetry=ON, **MP_KW)
        a, b = single.telemetry, shard.telemetry
        for fld in ("objective", "staleness", "updates", "delivered",
                    "drop_link", "drop_churn", "drop_partition", "invalid"):
            assert np.array_equal(getattr(a, fld), getattr(b, fld)), fld
        assert b.halo_bytes is not None and b.overflow_per_shard is not None

    def test_cl_frames_match_single_device(self, problem):
        topo, _, _, data, cl_sol = problem
        single = run_cl_scenario(topo, data, 0.1, 1.0, FAULTY,
                                 theta_sol=cl_sol, telemetry=ON, **MP_KW)
        shard = run_cl_scenario_sharded(topo, data, 0.1, 1.0, FAULTY,
                                        theta_sol=cl_sol, telemetry=ON,
                                        **MP_KW)
        a, b = single.telemetry, shard.telemetry
        for fld in ("objective", "staleness", "updates", "delivered",
                    "drop_link", "drop_churn", "drop_partition", "invalid"):
            assert np.array_equal(getattr(a, fld), getattr(b, fld)), fld

    def test_sharded_disabled_is_bitwise_anchor(self, problem):
        topo, sol, c, _, _ = problem
        off = run_mp_scenario_sharded(topo, sol, c, 0.9, FAULTY, **MP_KW)
        on = run_mp_scenario_sharded(topo, sol, c, 0.9, FAULTY,
                                     telemetry=ON, **MP_KW)
        assert np.array_equal(off.theta_hist, on.theta_hist)
        assert off.telemetry is None


class TestManifestAndRuns:
    def test_manifest_keys_and_hash_stability(self):
        m = build_manifest(backend=ReproBackend.using(mix="reference"),
                           mesh_shape=(8,), seed=5,
                           extra={"scenario": "clean"})
        for key in ("backend_hash", "mesh_shape", "seed", "git_rev",
                    "jax_version", "platform", "device_count", "scenario"):
            assert key in m, key
        b1 = ReproBackend.using(mix="reference")
        b2 = ReproBackend.using(mix="xla")
        assert backend_config_hash(b1) == backend_config_hash(
            ReproBackend.using(mix="reference"))
        assert backend_config_hash(b1) != backend_config_hash(b2)
        assert len(m["backend_hash"]) == 12

    def test_run_dir_roundtrip(self, problem, tmp_path):
        topo, sol, c, _, _ = problem
        tr = run_mp_scenario(topo, sol, c, 0.9, FAULTY, telemetry=ON,
                             **MP_KW)
        manifest = build_manifest(seed=3, extra={"scenario": "faulty"})
        rows = trace_rows(tr)
        d = str(tmp_path / "run")
        write_run(d, manifest, rows)
        m2, rows2 = load_run(d)
        assert m2 == json.loads(json.dumps(manifest))
        assert rows2 == json.loads(json.dumps(rows))
        text = render_summary(m2, rows2)
        assert "final:" in text and "staleness:" in text

    def test_jsonl_one_row_per_chunk(self, problem, tmp_path):
        topo, sol, c, _, _ = problem
        tr = run_mp_scenario(topo, sol, c, 0.9, FAULTY, telemetry=ON,
                             **MP_KW)
        d = str(tmp_path / "run")
        write_run(d, build_manifest(), trace_rows(tr))
        with open(os.path.join(d, "metrics.jsonl")) as f:
            lines = [json.loads(line) for line in f]
        assert len(lines) == tr.telemetry.n_records
        assert lines[-1]["round"] == tr.rounds


# ---------------------------------------------------------------------------
# 8-fake-device subprocess: telemetry parity on a true multi-shard mesh
# ---------------------------------------------------------------------------


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    assert jax.device_count() == 8
    from repro.core.losses import pad_datasets, solitary_mean
    from repro.simulate import (NetworkConditions,
                                random_geometric_topology,
                                run_cl_scenario, run_cl_scenario_sharded,
                                run_joint_scenario,
                                run_joint_scenario_sharded,
                                run_mp_scenario, run_mp_scenario_sharded)
    from repro.telemetry import TelemetryConfig

    ON = TelemetryConfig(enabled=True)
    FIELDS = ("objective", "staleness", "updates", "delivered",
              "drop_link", "drop_churn", "drop_partition", "invalid")

    def check(a, b, tag):
        for fld in FIELDS:
            assert np.array_equal(getattr(a, fld), getattr(b, fld)), \\
                (tag, fld)
        assert b.halo_bytes is not None and np.all(b.halo_bytes >= 0), tag
        assert b.overflow_per_shard.shape == (8,), tag

    topo = random_geometric_topology(300, k=5, seed=2)
    rng = np.random.default_rng(0)
    sol = rng.standard_normal((300, 4)).astype(np.float32)
    c = rng.uniform(0.05, 1.0, 300).astype(np.float32)
    cond = NetworkConditions(drop_prob=0.1, stale_prob=0.3,
                             churn_rate=0.01, straggler_frac=0.3,
                             partition_start=10, partition_end=30)
    kw = dict(rounds=60, batch=16, seed=2, record_every=10, telemetry=ON)

    tr = run_mp_scenario(topo, sol, c, 0.9, cond, **kw)
    sh = run_mp_scenario_sharded(topo, sol, c, 0.9, cond, **kw)
    assert sh.n_shards == 8
    check(tr.telemetry, sh.telemetry, "mp")
    assert tr.telemetry.drop_partition[-1] > 0

    xs = [rng.standard_normal((int(rng.integers(1, 6)), 4))
          for _ in range(300)]
    data = pad_datasets(xs, [np.zeros(len(x)) for x in xs])
    cl_sol = np.asarray(solitary_mean(data), np.float32)
    cl = run_cl_scenario(topo, data, 0.1, 1.0, cond, theta_sol=cl_sol, **kw)
    cl_sh = run_cl_scenario_sharded(topo, data, 0.1, 1.0, cond,
                                    theta_sol=cl_sol, **kw)
    check(cl.telemetry, cl_sh.telemetry, "cl")

    # joint engine with re-compaction: telemetry state threads across the
    # segment boundaries (stale carries over, counters accumulate offsets)
    jkw = dict(eta_graph=0.3, lam=1.0, graph_every=5, prune_eps=1e-3)
    jt = run_joint_scenario(topo, sol, c, 0.9, cond, **jkw, **kw)
    jt_sh = run_joint_scenario_sharded(topo, sol, c, 0.9, cond, **jkw,
                                       recompact_every=20, **kw)
    check(jt.telemetry, jt_sh.telemetry, "joint")
    assert np.array_equal(jt.telemetry.suppressed, jt_sh.telemetry.suppressed)
    print("TELEMETRY-8DEV-OK")
""")


def test_eight_device_telemetry_subprocess():
    """Sharded telemetry equals single-device on a real 8-shard mesh (the
    XLA device-count flag must precede jax init, hence the subprocess)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SUBPROC], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "TELEMETRY-8DEV-OK" in out.stdout

"""Fused gossip-round megakernel (``kernels.round_fuse``, DESIGN.md §15):
parity of the fused-XLA and Pallas-interpret realizations against the
``ref.gossip_round_step`` oracle (incl. the acceptance maxerr <= 1e-6
bound), id-column winner resolution under duplicate targets, first-receipt
base-swap semantics, telescoped-update drift over chained prefetched
rounds, and engine-level agreement of the fused ``run_mp_scenario`` path
with the historic per-op program."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref, round_fuse
from repro.kernels.dispatch import ReproBackend
from repro.simulate import (NetworkConditions, random_geometric_topology,
                            ring_topology, run_mp_scenario)


def make_state(n, k, p, seed=0):
    """Random round_step state over the flat id-column slot table."""
    rng = np.random.default_rng(seed)
    f32 = jnp.float32
    K = jnp.asarray(rng.standard_normal((n, k, p)), f32)
    return dict(
        theta=jnp.asarray(rng.standard_normal((n, p)), f32),
        Ke=round_fuse.encode_slots(K),
        got_ever=jnp.asarray(rng.uniform(size=n) < 0.5)), rng


def make_events(rng, n, k, p, m, collision_free=True, deliver_frac=0.7):
    """Prefetched event operands; collision-free targets by default
    (duplicate-winner semantics get their own controlled tests)."""
    if collision_free:
        codes = rng.choice(n * k, size=m, replace=False)
    else:
        codes = rng.integers(0, n * k, m)
    deliver = rng.uniform(size=m) < deliver_frac
    f32, i32 = jnp.float32, jnp.int32
    return dict(
        msg=jnp.asarray(rng.standard_normal((m, p)), f32),
        tgt_row=jnp.asarray(np.where(deliver, codes // k, n), i32),
        enc=jnp.asarray(np.where(deliver, codes, n * k), i32),
        k_old=jnp.asarray(rng.standard_normal((m, p)), f32))


def make_consts(rng, n, k, p):
    f32 = jnp.float32
    return dict(theta_base=jnp.asarray(rng.standard_normal((n, p)), f32),
                a_w=jnp.asarray(rng.uniform(0.1, 1.0, n * k), f32))


def run_all(state, events, consts, block_b=128):
    args = (*state.values(), *events.values(), *consts.values())
    want = ref.gossip_round_step(*args)
    got_x = round_fuse.round_step_xla(*args)
    got_p = round_fuse.round_step_pallas(*args, block_b=block_b,
                                         interpret=True)
    return want, got_x, got_p


def assert_close(got, want, atol=1e-6):
    assert np.abs(np.asarray(got[0]) - np.asarray(want[0])).max() <= atol
    assert np.abs(np.asarray(got[1]) - np.asarray(want[1])).max() <= atol
    assert np.array_equal(np.asarray(got[2]), np.asarray(want[2]))  # got_ever
    assert np.array_equal(np.asarray(got[3]), np.asarray(want[3]))  # keep


class TestRoundStepParity:
    @pytest.mark.parametrize("seed", [0, 1, 2])
    def test_acceptance_maxerr(self, seed):
        """Acceptance: fused XLA and Pallas (interpret) within 1e-6 of the
        oracle on collision-free batches; K is exact (same landed rows)."""
        state, rng = make_state(41, 6, 9, seed=seed)
        events = make_events(rng, 41, 6, 9, 48)
        consts = make_consts(rng, 41, 6, 9)
        want, got_x, got_p = run_all(state, events, consts)
        for got in (got_x, got_p):
            assert np.abs(np.asarray(got[1]) - np.asarray(want[1])).max() \
                == 0.0                                       # Ke exact
            assert_close(got, want)

    def test_event_padding(self):
        """2B not a multiple of block_b (nor even): pads must be no-ops."""
        state, rng = make_state(23, 4, 5, seed=3)
        events = make_events(rng, 23, 4, 5, 13)
        consts = make_consts(rng, 23, 4, 5)
        want, got_x, got_p = run_all(state, events, consts, block_b=4)
        for got in (got_x, got_p):
            assert_close(got, want)

    def test_nothing_delivered_is_identity(self):
        """All targets at the sentinels: every output comes back
        bit-identical and no event keeps."""
        state, rng = make_state(17, 3, 4, seed=4)
        events = make_events(rng, 17, 3, 4, 10, deliver_frac=0.0)
        consts = make_consts(rng, 17, 3, 4)
        for got in run_all(state, events, consts)[1:]:
            for g, w in zip(got[:3], state.values()):
                assert np.abs(np.asarray(g).astype(np.float32)
                              - np.asarray(w).astype(np.float32)).max() \
                    == 0.0
            assert not np.asarray(got[3]).any()

    def test_first_receipt_swaps_in_base(self):
        """A row receiving for the first time telescopes from theta_base,
        not its warm-start theta; an already-seen row accumulates."""
        n, k, p = 9, 2, 3
        state, rng = make_state(n, k, p, seed=5)
        state["got_ever"] = jnp.asarray([False] * 5 + [True] * 4)
        consts = make_consts(rng, n, k, p)
        f32, i32 = jnp.float32, jnp.int32
        msg = jnp.asarray(rng.standard_normal((2, p)), f32)
        k_old = jnp.asarray(rng.standard_normal((2, p)), f32)
        events = dict(msg=msg,
                      tgt_row=jnp.asarray([2, 7], i32),     # fresh, seen
                      enc=jnp.asarray([2 * k, 7 * k + 1], i32),
                      k_old=k_old)
        for got in run_all(state, events, consts)[1:]:
            theta, _, got_ever, keep = got
            aw = np.asarray(consts["a_w"])
            d0 = aw[2 * k] * (np.asarray(msg[0]) - np.asarray(k_old[0]))
            d1 = aw[7 * k + 1] * (np.asarray(msg[1]) - np.asarray(k_old[1]))
            np.testing.assert_allclose(
                np.asarray(theta[2]),
                np.asarray(consts["theta_base"][2]) + d0, atol=1e-6)
            np.testing.assert_allclose(
                np.asarray(theta[7]),
                np.asarray(state["theta"][7]) + d1, atol=1e-6)
            assert np.asarray(got_ever)[[2, 7]].all()
            assert np.asarray(keep).all()

    def test_duplicate_targets_identical_payload(self):
        """Duplicate deliveries of the *same* payload to one slot:
        resolution order cannot matter, so every realization must agree
        with the oracle exactly (modulo which id survives)."""
        n, k, p = 13, 4, 5
        state, rng = make_state(n, k, p, seed=6)
        consts = make_consts(rng, n, k, p)
        f32, i32 = jnp.float32, jnp.int32
        one = jnp.asarray(rng.standard_normal((1, p)), f32)
        kold = jnp.asarray(rng.standard_normal((1, p)), f32)
        events = dict(msg=jnp.concatenate([one, one]),
                      tgt_row=jnp.asarray([5, 5], i32),
                      enc=jnp.asarray([5 * k + 3, 5 * k + 3], i32),
                      k_old=jnp.concatenate([kold, kold]))
        want, got_x, got_p = run_all(state, events, consts, block_b=1)
        for got in (got_x, got_p):
            for g, w in zip(got[:2], want[:2]):
                assert np.abs(np.asarray(g)[:, :p]
                              - np.asarray(w)[:, :p]).max() <= 1e-6
            assert np.asarray(got[3]).sum() == 1      # exactly one winner

    def test_duplicate_targets_conflicting_payload(self):
        """Conflicting duplicate deliveries to one slot: each realization
        must be *self-consistent* — the surviving id names the winner, the
        slot holds the winner's message, and theta telescopes the winner's
        delta — the documented divergence point between XLA scatter
        semantics and Pallas event-order resolution."""
        n, k, p = 13, 4, 5
        state, rng = make_state(n, k, p, seed=7)
        state["got_ever"] = jnp.ones((n,), bool)     # isolate the delta path
        consts = make_consts(rng, n, k, p)
        f32, i32 = jnp.float32, jnp.int32
        msg = jnp.asarray(rng.standard_normal((2, p)), f32)
        kold = np.asarray(state["Ke"])[5 * k + 3, :p][None]
        events = dict(msg=msg, tgt_row=jnp.asarray([5, 5], i32),
                      enc=jnp.asarray([5 * k + 3, 5 * k + 3], i32),
                      k_old=jnp.asarray(np.concatenate([kold, kold]), f32))
        want, got_x, got_p = run_all(state, events, consts, block_b=1)
        aw = float(np.asarray(consts["a_w"])[5 * k + 3])
        for got in (want, got_x, got_p):
            theta, Ke, _, keep = (np.asarray(a) for a in got)
            (win,) = np.nonzero(keep)
            assert Ke[5 * k + 3, p] == win[0]         # id names the winner
            np.testing.assert_array_equal(Ke[5 * k + 3, :p],
                                          np.asarray(msg[win[0]]))
            np.testing.assert_allclose(
                theta[5], np.asarray(state["theta"][5])
                + aw * (np.asarray(msg[win[0]]) - kold[0]), atol=1e-6)
        # the xla two-half scatter resolves like the oracle's keep-last
        assert np.abs(np.asarray(got_x[1]) - np.asarray(want[1])).max() == 0.0

    def test_winner_uniqueness_under_collisions(self):
        """Random colliding batch: exactly one keep per landed slot, none
        at the sentinel."""
        n, k, p = 11, 3, 4
        state, rng = make_state(n, k, p, seed=8)
        events = make_events(rng, n, k, p, 40, collision_free=False)
        consts = make_consts(rng, n, k, p)
        for got in run_all(state, events, consts)[1:]:
            keep = np.asarray(got[3])
            enc = np.asarray(events["enc"])
            for e in np.unique(enc[enc < n * k]):
                assert keep[enc == e].sum() == 1
            assert not keep[enc == n * k].any()

    def test_chained_rounds_stay_coherent(self):
        """30 rounds chained through the carry (the engine's layout): xla
        and the oracle stay within 1e-6 and the slot table stays exact,
        i.e. the telescoped theta does not drift."""
        n, k, p = 37, 5, 8
        state, rng = make_state(n, k, p, seed=9)
        consts = make_consts(rng, n, k, p)
        sx = so = tuple(state.values())
        for r in range(30):
            events = make_events(rng, n, k, p, 24)
            sx = round_fuse.round_step_xla(*sx, *events.values(),
                                           *consts.values())[:3]
            so = ref.gossip_round_step(*so, *events.values(),
                                       *consts.values())[:3]
        assert np.abs(np.asarray(sx[1]) - np.asarray(so[1])).max() == 0.0
        assert np.abs(np.asarray(sx[0]) - np.asarray(so[0])).max() <= 1e-6

    def test_round_prefetch_contract(self):
        """round_prefetch gathers stale senders from theta_prev, encodes
        undelivered targets at the sentinels, and reads pre-scatter slot
        values."""
        n, k, p = 7, 2, 3
        state, rng = make_state(n, k, p, seed=10)
        f32, i32 = jnp.float32, jnp.int32
        theta_prev = jnp.asarray(rng.standard_normal((n, p)), f32)
        msg, tgt_row, enc, k_old = round_fuse.round_prefetch(
            state["theta"], theta_prev, state["Ke"],
            jnp.asarray([1, 2], i32), jnp.asarray([3, 4], i32),   # i, j
            jnp.asarray([0, 1], i32), jnp.asarray([1, 0], i32),   # s, r
            jnp.asarray([True, False]), jnp.asarray([False, True]),
            jnp.asarray([False, True]), jnp.asarray([True, False]))
        # senders: [i0, i1, j0, j1]; stale i1 and j0 read theta_prev
        np.testing.assert_array_equal(np.asarray(msg), np.asarray(
            jnp.stack([state["theta"][1], theta_prev[2],
                       theta_prev[3], state["theta"][4]])))
        # delivered: i0 -> row 3 slot r=1, j1 -> row 2 slot s=1
        np.testing.assert_array_equal(np.asarray(tgt_row), [3, n, n, 2])
        np.testing.assert_array_equal(np.asarray(enc),
                                      [3 * k + 1, n * k, n * k, 2 * k + 1])
        np.testing.assert_array_equal(
            np.asarray(k_old[0]), np.asarray(state["Ke"])[3 * k + 1, :p])

    def test_slot_codecs_roundtrip(self):
        rng = np.random.default_rng(11)
        K = jnp.asarray(rng.standard_normal((6, 3, 4)), jnp.float32)
        Ke = round_fuse.encode_slots(K)
        assert Ke.shape == (18, 5)
        assert np.all(np.asarray(Ke[:, 4]) == -1.0)
        np.testing.assert_array_equal(
            np.asarray(round_fuse.decode_slots(Ke, 3)), np.asarray(K))


class TestClEdgeStepPallas:
    def test_parity_with_padding(self):
        """Pallas cl_edge_step vs the reference callable, E not a multiple
        of block_b (collision-free targets; engine-level duplicate handling
        rides the existing CL parity suites)."""
        n, k, p, E = 19, 4, 6, 11
        rng = np.random.default_rng(9)
        f32 = jnp.float32
        a3 = lambda: jnp.asarray(rng.standard_normal((n, k, p)), f32)
        a2 = lambda: jnp.asarray(rng.standard_normal((n, p)), f32)
        codes = rng.choice(n * k, size=E, replace=False)
        args = (a2(), a3(), a3(), a3(), a3(), a3(), a2(), a3(), a3(), a3(),
                jnp.asarray(codes // k, jnp.int32),
                jnp.asarray(codes % k, jnp.int32),
                jnp.asarray(rng.integers(0, n, E), jnp.int32),
                jnp.asarray(rng.integers(0, k, E), jnp.int32),
                jnp.asarray(rng.uniform(size=E) < 0.4),
                jnp.asarray(rng.uniform(size=E) < 0.7))
        want = round_fuse.cl_edge_step(*args, rho=1.3)
        got = round_fuse.cl_edge_step_pallas(*args, rho=1.3, block_b=4,
                                             interpret=True)
        for g, w in zip(got, want):
            assert np.abs(np.asarray(g) - np.asarray(w)).max() <= 1e-6


class TestEngineFusedPath:
    def test_fused_xla_matches_default_engine(self):
        """run_mp_scenario(backend=...) executes the same scenario through
        the fused round_step: identical counters, trajectory within fp
        rounding of the historic per-op program."""
        topo = random_geometric_topology(200, k=5, seed=0)
        rng = np.random.default_rng(0)
        sol = rng.standard_normal((200, 6)).astype(np.float32)
        c = rng.uniform(0.05, 1.0, 200).astype(np.float32)
        cond = NetworkConditions(drop_prob=0.1, stale_prob=0.3,
                                 churn_rate=0.01, straggler_frac=0.3,
                                 partition_start=10, partition_end=30)
        tr = run_mp_scenario(topo, sol, c, 0.9, cond, rounds=50, batch=32,
                             seed=3, record_every=10)
        fu = run_mp_scenario(topo, sol, c, 0.9, cond, rounds=50, batch=32,
                             seed=3, record_every=10,
                             backend=ReproBackend.using(round_step="xla"))
        np.testing.assert_allclose(fu.theta_hist, tr.theta_hist, atol=1e-5)
        np.testing.assert_allclose(fu.active_hist, tr.active_hist)
        assert (fu.delivered, fu.dropped, fu.invalid, fu.rounds, fu.events) \
            == (tr.delivered, tr.dropped, tr.invalid, tr.rounds, tr.events)

    def test_fused_pallas_interpret_matches_default_engine(self):
        """The Pallas megakernel (interpret mode) driving the engine on a
        small problem: same trajectory within fp rounding."""
        topo = ring_topology(40)
        rng = np.random.default_rng(1)
        sol = rng.standard_normal((40, 4)).astype(np.float32)
        c = rng.uniform(0.05, 1.0, 40).astype(np.float32)
        cond = NetworkConditions(drop_prob=0.1, stale_prob=0.2)
        tr = run_mp_scenario(topo, sol, c, 0.9, cond, rounds=10, batch=8,
                             seed=2, record_every=5)
        fu = run_mp_scenario(
            topo, sol, c, 0.9, cond, rounds=10, batch=8, seed=2,
            record_every=5,
            backend=ReproBackend.using(round_step="pallas", interpret=True))
        np.testing.assert_allclose(fu.theta_hist, tr.theta_hist, atol=1e-5)
        assert (fu.delivered, fu.dropped, fu.invalid) \
            == (tr.delivered, tr.dropped, tr.invalid)

    def test_fused_telemetry_matches_default_engine(self):
        """Telemetry accumulators ride the fused carry unchanged: frames
        agree with the default path (objective to fp rounding, counters
        exactly)."""
        from repro.telemetry import TelemetryConfig
        topo = random_geometric_topology(120, k=4, seed=2)
        rng = np.random.default_rng(4)
        sol = rng.standard_normal((120, 5)).astype(np.float32)
        c = rng.uniform(0.05, 1.0, 120).astype(np.float32)
        cond = NetworkConditions(drop_prob=0.15, stale_prob=0.2,
                                 churn_rate=0.02)
        kw = dict(rounds=40, batch=24, seed=5, record_every=10,
                  telemetry=TelemetryConfig(enabled=True))
        tr = run_mp_scenario(topo, sol, c, 0.9, cond, **kw)
        fu = run_mp_scenario(topo, sol, c, 0.9, cond, **kw,
                             backend=ReproBackend.using(round_step="xla"))
        np.testing.assert_allclose(fu.telemetry.objective,
                                   tr.telemetry.objective, rtol=1e-5)
        for f in ("staleness", "updates", "delivered", "drop_link",
                  "drop_churn", "drop_partition", "invalid"):
            np.testing.assert_array_equal(getattr(fu.telemetry, f),
                                          getattr(tr.telemetry, f), err_msg=f)

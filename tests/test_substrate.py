"""Substrate tests: optimizer, data pipeline, trainer, checkpoint, serving."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional dev dep (requirements-dev.txt)
from hypothesis import given, settings, strategies as st

from repro.core import ring_graph, random_geometric_graph
from repro.coupling import CouplingConfig, make_state
from repro.data import (PersonalizedLMConfig, make_lm_batches, delay_pattern,
                        undelay_pattern, mean_estimation_problem,
                        linear_classification_problem)
from repro.models import Model, ModelConfig
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_schedule
from repro.train import (TrainConfig, make_train_step, train_loop,
                         save_checkpoint, load_checkpoint)
from repro.train.trainer import init_train_state
from repro.serve import Engine, ServeConfig


def tiny_model(vocab=64):
    return Model(ModelConfig(name="t", family="dense", n_layers=2, d_model=32,
                             n_heads=2, n_kv_heads=2, d_ff=64,
                             vocab_size=vocab, attn_impl="ref", remat=False))


class TestOptimizer:
    def test_adamw_decreases_quadratic(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
        params = {"w": jnp.ones((4, 3)) * 5.0}
        opt = adamw_init(params, cfg)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, opt, _ = adamw_update(grads, opt, params, cfg)
        assert float(jnp.abs(params["w"]).max()) < 0.5

    def test_moments_are_bf16(self):
        cfg = AdamWConfig()
        params = {"w": jnp.ones((2, 2))}
        opt = adamw_init(params, cfg)
        assert opt["m"]["w"].dtype == jnp.bfloat16
        assert opt["v"]["w"].dtype == jnp.bfloat16

    @settings(max_examples=15, deadline=None)
    @given(step=st.integers(0, 10_000))
    def test_cosine_schedule_bounds(self, step):
        s = float(cosine_schedule(step, total_steps=10_000, warmup=100))
        assert 0.0 <= s <= 1.0 + 1e-6


class TestData:
    def test_lm_stream_shapes_and_agent_similarity(self):
        A = 8
        g = random_geometric_graph(A, k=2, seed=0)
        cfg = PersonalizedLMConfig(vocab_size=32, n_agents=A, seq_len=16,
                                   batch_per_agent=4, seed=0)
        batches = make_lm_batches(cfg, g, 2)
        assert batches[0].shape == (A, 4, 17)
        assert batches[0].max() < 32 and batches[0].min() >= 0

    def test_delay_pattern_roundtrip(self):
        rng = np.random.default_rng(0)
        toks = rng.integers(0, 100, (2, 4, 9)).astype(np.int32)
        d = delay_pattern(toks, pad_id=-1)
        assert d.shape == (2, 4, 12)
        np.testing.assert_array_equal(undelay_pattern(d), toks)

    def test_paper_problem_generators(self):
        g, data, targets, c = mean_estimation_problem(n=40, eps=1.0, seed=0)
        assert g.n == 40 and data.n == 40
        assert (np.asarray(data.counts) <= 100).all()
        g2, train, test, t = linear_classification_problem(n=20, p=10, seed=0)
        assert train.n == 20
        assert set(np.unique(np.asarray(train.y)[np.asarray(train.mask) > 0]
                             ).tolist()) <= {-1.0, 1.0}


class TestTrainer:
    def test_personalized_training_decreases_loss(self):
        A = 4
        g = ring_graph(A)
        model = tiny_model()
        tcfg = TrainConfig(n_agents=A, steps=30,
                           optimizer=AdamWConfig(lr=3e-3, weight_decay=0.0),
                           coupling=CouplingConfig(mode="mp", alpha=0.99,
                                                   every=2),
                           log_every=100)
        cstate = make_state(g, np.ones(A), tcfg.coupling.alpha)
        lm = PersonalizedLMConfig(vocab_size=64, n_agents=A, seq_len=16,
                                  batch_per_agent=4, seed=1)
        raw = make_lm_batches(lm, g, 30)
        batches = [{"tokens": b[..., :-1].reshape(A * 4, 16),
                    "labels": b[..., 1:].reshape(A * 4, 16)} for b in raw]
        state, hist = train_loop(model, tcfg, cstate, batches,
                                 log=lambda s: None)
        assert hist[-1]["loss"] < hist[0]["loss"]
        assert np.isfinite(hist[-1]["loss"])

    def test_coupling_modes_all_step(self):
        A = 4
        g = ring_graph(A)
        model = tiny_model()
        lm = PersonalizedLMConfig(vocab_size=64, n_agents=A, seq_len=8,
                                  batch_per_agent=2, seed=2)
        raw = make_lm_batches(lm, g, 1)[0]
        batch = {"tokens": jnp.asarray(raw[..., :-1].reshape(A * 2, 8)),
                 "labels": jnp.asarray(raw[..., 1:].reshape(A * 2, 8))}
        for mode in ("none", "consensus", "mp", "cl"):
            tcfg = TrainConfig(n_agents=A, steps=2,
                               coupling=CouplingConfig(mode=mode))
            cstate = make_state(g, np.ones(A), 0.99)
            state = init_train_state(model, tcfg, jax.random.PRNGKey(0))
            step = jax.jit(make_train_step(model, tcfg, cstate))
            state, m = step(state, batch)
            assert np.isfinite(m["loss"]), mode

    def test_consensus_coupling_equalizes_agents(self):
        A = 4
        g = ring_graph(A)
        model = tiny_model()
        tcfg = TrainConfig(n_agents=A, steps=2,
                           coupling=CouplingConfig(mode="consensus"))
        cstate = make_state(g, np.ones(A), 0.99)
        state = init_train_state(model, tcfg, jax.random.PRNGKey(0),
                                 perturb=0.01)
        lm = PersonalizedLMConfig(vocab_size=64, n_agents=A, seq_len=8,
                                  batch_per_agent=2, seed=3)
        raw = make_lm_batches(lm, g, 1)[0]
        batch = {"tokens": jnp.asarray(raw[..., :-1].reshape(A * 2, 8)),
                 "labels": jnp.asarray(raw[..., 1:].reshape(A * 2, 8))}
        step = jax.jit(make_train_step(model, tcfg, cstate))
        state, _ = step(state, batch)
        w = np.asarray(jax.tree_util.tree_leaves(state.params)[0])
        for a in range(1, A):
            np.testing.assert_allclose(w[0], w[a], atol=1e-6)


class TestCheckpoint:
    def test_roundtrip_trainstate(self):
        A = 2
        model = tiny_model()
        tcfg = TrainConfig(n_agents=A, steps=1)
        state = init_train_state(model, tcfg, jax.random.PRNGKey(0))
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(state, d, step=7)
            restored, step = load_checkpoint(state, d)
            assert step == 7
            for a, b in zip(jax.tree_util.tree_leaves(state),
                            jax.tree_util.tree_leaves(restored)):
                np.testing.assert_array_equal(np.asarray(a, np.float32),
                                              np.asarray(b, np.float32))


class TestServing:
    def test_engine_batched_requests(self):
        model = tiny_model(vocab=32)
        params = model.init(jax.random.PRNGKey(0))
        eng = Engine(model, params,
                     ServeConfig(batch_size=2, cache_len=64,
                                 max_new_tokens=8, temperature=0.0))
        rng = np.random.default_rng(0)
        rids = [eng.submit(rng.integers(0, 32, (l,))) for l in (5, 3, 7)]
        results = eng.run()
        assert set(results) == set(rids)
        for r in results.values():
            assert len(r) == 8
            assert all(0 <= t < 32 for t in r)

    def test_greedy_decode_matches_forward_argmax(self):
        """Engine greedy continuation == argmax teacher-forcing rollout."""
        model = tiny_model(vocab=32)
        params = model.init(jax.random.PRNGKey(1))
        prompt = np.asarray([3, 14, 15, 9], np.int32)
        eng = Engine(model, params,
                     ServeConfig(batch_size=1, cache_len=64, max_new_tokens=4))
        rid = eng.submit(prompt)
        out = eng.run()[rid]
        # reference: iterative full forward
        seq = list(prompt)
        want = []
        for _ in range(4):
            t = jnp.asarray(np.asarray(seq)[None])
            logits, _ = model.forward(params, {"tokens": t, "labels": t})
            nxt = int(jnp.argmax(logits[0, -1]))
            want.append(nxt)
            seq.append(nxt)
        assert out == want

"""core.graph constructors: kNN symmetry/self-loop invariants, kernel edge
cases (sigma -> 0, identical points), allow_isolated paths, fixed-seed
determinism, and the Graph.__post_init__ validation regressions (asymmetric
/ negative / non-finite W)."""

import numpy as np
import pytest

from repro.core.graph import (Graph, angular_kernel_graph,
                              gaussian_kernel_graph,
                              knn_graph_from_similarity,
                              random_geometric_graph, ring_graph)
from repro.core.sparse import padded_neighbor_tables


class TestGraphValidation:
    """Regressions for the silent-accept paths in Graph.__post_init__."""

    def test_exact_symmetric_accepted_unchanged(self):
        W = np.array([[0.0, 2.0], [2.0, 0.0]])
        g = Graph(W)
        assert np.array_equal(g.W, W)

    def test_asymmetric_within_tolerance_symmetrized_with_warning(self):
        """The bug: W asymmetric by ~1e-6 relative used to pass allclose and
        flow into P as-is, giving row-dependent mixing matrices."""
        W = np.array([[0.0, 1.0], [1.0 + 1e-9, 0.0]])
        with pytest.warns(UserWarning, match="symmetrizing"):
            g = Graph(W)
        assert np.array_equal(g.W, g.W.T)
        assert g.W[0, 1] == pytest.approx(1.0 + 5e-10)

    def test_asymmetric_beyond_tolerance_raises(self):
        W = np.array([[0.0, 1.0], [2.0, 0.0]])
        with pytest.raises(ValueError, match="symmetric"):
            Graph(W)

    def test_negative_raises(self):
        W = np.array([[0.0, -1.0], [-1.0, 0.0]])
        with pytest.raises(ValueError, match="nonnegative"):
            Graph(W)

    def test_nan_and_inf_raise(self):
        """NaN previously died inside allclose with a misleading 'must be
        symmetric'; inf sailed through entirely."""
        for bad in (np.nan, np.inf):
            W = np.array([[0.0, bad], [bad, 0.0]])
            with pytest.raises(ValueError, match="finite"):
                Graph(W)

    def test_nonsquare_raises(self):
        with pytest.raises(ValueError, match="square"):
            Graph(np.zeros((2, 3)))

    def test_diagonal_zeroed(self):
        g = Graph(np.array([[5.0, 1.0], [1.0, 7.0]]))
        assert np.array_equal(np.diag(g.W), [0.0, 0.0])


class TestKnnGraph:
    def test_symmetric_self_loop_free(self):
        rng = np.random.default_rng(0)
        s = rng.standard_normal((30, 30))
        g = knn_graph_from_similarity((s + s.T) / 2, k=4)
        assert np.array_equal(g.W, g.W.T)
        assert np.array_equal(np.diag(g.W), np.zeros(30))
        assert set(np.unique(g.W)) <= {0.0, 1.0}

    def test_every_agent_keeps_at_least_k_links(self):
        """Symmetrization can only add edges: degree >= k everywhere."""
        rng = np.random.default_rng(1)
        s = rng.standard_normal((25, 25))
        g = knn_graph_from_similarity(s, k=3)
        assert ((g.W > 0).sum(axis=1) >= 3).all()

    def test_k_one_is_nearest_neighbor_matching(self):
        sim = np.array([[0.0, 5.0, 1.0],
                        [5.0, 0.0, 2.0],
                        [1.0, 2.0, 0.0]])
        g = knn_graph_from_similarity(sim, k=1)
        assert g.W[0, 1] == 1.0 and g.W[1, 0] == 1.0
        assert g.W[2, 1] == 1.0          # 2's nearest, symmetrized back


class TestKernelGraphs:
    def test_gaussian_sigma_zero_raises(self):
        pts = np.random.default_rng(0).standard_normal((5, 2))
        with pytest.raises(ValueError, match="sigma"):
            gaussian_kernel_graph(pts, sigma=0.0)
        with pytest.raises(ValueError, match="sigma"):
            angular_kernel_graph(pts, sigma=-1.0)

    def test_gaussian_identical_points_get_unit_weight(self):
        pts = np.zeros((3, 2))
        g = gaussian_kernel_graph(pts, sigma=0.5)
        off = g.W[~np.eye(3, dtype=bool)]
        assert np.allclose(off, 1.0)

    def test_gaussian_threshold_can_isolate_and_tables_gate_it(self):
        """allow_isolated paths: a far-away point loses every edge under a
        threshold; the default table constructor rejects the graph, the
        explicit opt-in admits it as a degree-0 row."""
        pts = np.array([[0.0, 0.0], [0.1, 0.0], [100.0, 0.0]])
        g = gaussian_kernel_graph(pts, sigma=0.1, threshold=1e-6)
        assert (g.W[2] == 0).all()
        with pytest.raises(ValueError, match="isolated"):
            g.P
        with pytest.raises(ValueError, match="at least one neighbor"):
            padded_neighbor_tables(g)
        tabs = padded_neighbor_tables(g, allow_isolated=True)
        assert tabs.deg_count[2] == 0
        assert tabs.nbr_w[2].sum() == 0.0
        assert tabs.slot_cdf[2, -1] == 0.0      # flat cdf: never selected

    def test_angular_zero_norm_models_defined(self):
        m = np.array([[0.0, 0.0], [1.0, 0.0], [0.0, 1.0]])
        g = angular_kernel_graph(m, sigma=0.5, threshold=0.0)
        assert np.isfinite(g.W).all()


class TestDeterminism:
    def test_random_geometric_graph_fixed_seed(self):
        a = random_geometric_graph(50, k=3, seed=7)
        b = random_geometric_graph(50, k=3, seed=7)
        assert np.array_equal(a.W, b.W)

    def test_random_geometric_graph_seed_changes_graph(self):
        a = random_geometric_graph(50, k=3, seed=7)
        b = random_geometric_graph(50, k=3, seed=8)
        assert not np.array_equal(a.W, b.W)

    def test_ring_degrees(self):
        g = ring_graph(6, weight=2.0)
        assert np.allclose(g.degrees, 4.0)
        assert g.is_connected()

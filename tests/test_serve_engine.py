"""serve.Engine slot lifecycle: a request finishing by EOS vs. max_tokens
must free its slot, and a queued request spliced into the recycled slot must
decode from a clean cache region (same tokens as in a fresh engine)."""

import jax
import numpy as np
import pytest

from repro.models import Model, ModelConfig
from repro.serve import Engine, ServeConfig


@pytest.fixture(scope="module")
def model_and_params():
    cfg = ModelConfig(name="serve-test", family="dense", n_layers=2,
                      d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
                      vocab_size=128, attn_impl="ref", remat=False)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return model, params


def _prompts(k, lens=(7, 11, 5, 9)):
    rng = np.random.default_rng(42)
    return [rng.integers(0, 128, (lens[i % len(lens)],)) for i in range(k)]


def _run_alone(model, params, prompt, cfg: ServeConfig):
    """Reference decode of one prompt in a fresh engine (clean cache)."""
    eng = Engine(model, params, cfg)
    rid = eng.submit(prompt)
    return eng.run()[rid]


def test_max_tokens_frees_slot_and_queued_request_splices(model_and_params):
    """3 requests through 2 slots: the third runs in a recycled slot and
    must produce exactly what it produces in a fresh engine."""
    model, params = model_and_params
    cfg = ServeConfig(batch_size=2, cache_len=64, max_new_tokens=6,
                      temperature=0.0)
    prompts = _prompts(3)
    eng = Engine(model, params, cfg)
    rids = [eng.submit(p) for p in prompts]
    results = eng.run()
    assert set(results) == set(rids)
    # every request ran to its token budget (no EOS configured)
    for rid in rids:
        assert len(results[rid]) == cfg.max_new_tokens
    # all slots were freed at drain
    assert not any(s.active for s in eng.slots)
    assert not eng._pending
    # the spliced-in third request saw a clean cache region: its greedy
    # decode must match a fresh single-request engine bit-for-bit
    alone = _run_alone(model, params, prompts[2],
                       ServeConfig(batch_size=1, cache_len=64,
                                   max_new_tokens=6, temperature=0.0))
    assert results[rids[2]] == alone


def test_eos_frees_slot_early_and_next_request_is_clean(model_and_params):
    """Pick the EOS id from an unconstrained run so the first request
    terminates mid-budget; the queued request must then splice into the
    freed slot and decode cleanly."""
    model, params = model_and_params
    base = ServeConfig(batch_size=1, cache_len=64, max_new_tokens=8,
                       temperature=0.0)
    prompts = _prompts(2)
    free_run = _run_alone(model, params, prompts[0], base)
    assert len(free_run) == base.max_new_tokens
    eos = free_run[2]          # guaranteed to appear at decode step >= 1

    cfg = ServeConfig(batch_size=1, cache_len=64, max_new_tokens=8,
                      temperature=0.0, eos_id=int(eos))
    eng = Engine(model, params, cfg)
    rids = [eng.submit(p) for p in prompts]
    results = eng.run()

    # request 0 stopped at the first EOS emitted after the prefill token
    cut = next(i for i, t in enumerate(free_run[1:], start=1) if t == eos)
    assert results[rids[0]] == free_run[:cut + 1]
    assert len(results[rids[0]]) < cfg.max_new_tokens
    assert results[rids[0]][-1] == eos
    # slot was freed and reused; request 1's decode matches a fresh engine
    alone = _run_alone(model, params, prompts[1], cfg)
    assert results[rids[1]] == alone
    assert not any(s.active for s in eng.slots)


def test_exhausted_flag_on_tick_budget(model_and_params):
    """A run that hits max_ticks with work in flight must say so: the
    partial result dict used to be indistinguishable from a completed
    drain — ``engine.exhausted`` now flags it."""
    model, params = model_and_params
    cfg = ServeConfig(batch_size=1, cache_len=64, max_new_tokens=8,
                      temperature=0.0)
    prompts = _prompts(2)

    eng = Engine(model, params, cfg)
    for p in prompts:
        eng.submit(p)
    partial = eng.run(max_ticks=3)   # < 8 ticks: request 0 still decoding
    assert eng.exhausted
    assert partial == {}             # nothing finished yet
    assert any(s.active for s in eng.slots) or eng._pending

    # resuming the same engine drains the backlog and clears the flag
    results = eng.run()
    assert not eng.exhausted
    assert len(results) == len(prompts)
    assert not any(s.active for s in eng.slots)
    assert not eng._pending

    # a clean full run never sets the flag
    eng2 = Engine(model, params, cfg)
    rid = eng2.submit(prompts[0])
    out = eng2.run()
    assert not eng2.exhausted
    assert len(out[rid]) == cfg.max_new_tokens


def test_eos_on_first_decoded_token(model_and_params):
    """EOS as the very first decode-step token: one-token completion after
    the prefill sample, slot still recycles for the queued request."""
    model, params = model_and_params
    base = ServeConfig(batch_size=1, cache_len=64, max_new_tokens=8,
                       temperature=0.0)
    prompts = _prompts(2)
    free_run = _run_alone(model, params, prompts[0], base)
    eos = free_run[1]
    cfg = ServeConfig(batch_size=1, cache_len=64, max_new_tokens=8,
                      temperature=0.0, eos_id=int(eos))
    eng = Engine(model, params, cfg)
    rids = [eng.submit(p) for p in prompts]
    results = eng.run()
    cut = next(i for i, t in enumerate(free_run[1:], start=1) if t == eos)
    assert results[rids[0]] == free_run[:cut + 1]
    assert results[rids[1]] == _run_alone(model, params, prompts[1], cfg)

"""Gossip-backed personalization service (DESIGN.md §16): serving never
perturbs the gossip trajectory, cache invalidation tracks the engines'
model-update deliveries exactly, reads are never-torn snapshots, and the
sharded store routes bit-for-bit like the single-device one."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve import (AgentStateStore, CollabServeEngine,
                         ShardedAgentStateStore)
from repro.simulate import (NetworkConditions, ScenarioSpec,
                            precompute_event_stream, precompute_serve_stream,
                            random_geometric_topology, run_scenario,
                            serve_chunk_requests)
from repro.telemetry import TelemetryConfig
from repro.telemetry.metrics import (stream_dirty_chunks,
                                     stream_staleness_chunks)

N, P_DIM = 80, 3
COND = NetworkConditions(drop_prob=0.2, churn_rate=0.01)
RUN_KW = dict(rounds=60, batch=8, seed=7, record_every=10)


@pytest.fixture(scope="module")
def problem():
    topo = random_geometric_topology(N, k=4, seed=0)
    rng = np.random.default_rng(0)
    theta_sol = rng.normal(size=(N, P_DIM)).astype(np.float32)
    c = np.full(N, 0.8, np.float32)
    return topo, theta_sol, c


def _spec(problem, **over):
    topo, theta_sol, c = problem
    kw = dict(algo="mp", topology=topo, theta_sol=theta_sol, c=c,
              alpha=0.9, conditions=COND, **RUN_KW)
    kw.update(over)
    return ScenarioSpec(**kw)


@pytest.fixture(scope="module")
def base_trace(problem):
    return run_scenario(_spec(problem))


@pytest.fixture(scope="module")
def chunk_info(problem):
    """The stream-derived per-chunk dirty sets and staleness counters."""
    topo = problem[0]
    n_rec, re_ = 6, 10
    stream = precompute_event_stream(
        topo.device_tables(), jnp.asarray(topo.partition_halves()),
        COND, RUN_KW["batch"], RUN_KW["seed"], n_rec * re_)
    dirty = stream_dirty_chunks(stream, N, n_rec, re_)
    stal = stream_staleness_chunks(stream, N, n_rec, re_)
    return dirty, stal


class TestServingLeavesGossipUntouched:
    def test_single_device_bit_for_bit(self, problem, base_trace):
        """Acceptance: interleaving an inference-request stream leaves the
        gossip trajectory bit-for-bit identical to the serve-free run."""
        serve = precompute_serve_stream(N, RUN_KW["rounds"], rate=4.0,
                                        seed=5)
        tr = run_scenario(_spec(problem, serve=serve))
        assert np.array_equal(tr.theta_hist, base_trace.theta_hist)
        assert tr.serve is not None
        assert tr.serve.requests == serve.n_requests
        assert base_trace.serve is None

    def test_sharded_bit_for_bit(self, problem, base_trace):
        serve = precompute_serve_stream(N, RUN_KW["rounds"], rate=4.0,
                                        seed=5)
        tr = run_scenario(_spec(problem, sharded=True, serve=serve))
        assert np.array_equal(tr.theta_hist, base_trace.theta_hist)
        # and the sharded service serves identical staleness per request
        tr1 = run_scenario(_spec(problem, serve=serve))
        assert np.array_equal(tr.serve.served_staleness,
                              tr1.serve.served_staleness)
        assert tr.serve.requests == tr1.serve.requests


class TestDirtySetMatchesEngineScatter:
    def test_clean_rows_frozen_between_snapshots(self, base_trace,
                                                 chunk_info):
        """The invalidation signal is exactly the engines' update scatter:
        between consecutive snapshots, rows outside a chunk's dirty set
        are bit-identical — which is what makes a cache hit sound."""
        dirty, _ = chunk_info
        hist = base_trace.theta_hist
        changed_somewhere = False
        for ci in range(1, hist.shape[0]):
            clean = ~dirty[ci]
            assert np.array_equal(hist[ci][clean], hist[ci - 1][clean])
            changed_somewhere |= not np.array_equal(hist[ci], hist[ci - 1])
        assert changed_somewhere  # the test has teeth

    def test_request_after_delivery_sees_post_update_model(self, base_trace,
                                                           chunk_info):
        """Invalidation semantics: a user served before and after a chunk
        that rewrote its model must see the old row, then the new row."""
        dirty, stal = chunk_info
        hist = base_trace.theta_hist
        # a user whose model the second chunk rewrote to a new value
        cands = np.where(dirty[1]
                         & ~(hist[1] == hist[0]).all(axis=-1))[0]
        assert cands.size
        u = int(cands[0])
        store = AgentStateStore(N, P_DIM)
        eng = CollabServeEngine(store, N, P_DIM, batch_size=4)
        eng.commit(10, hist[0], stal[0], dirty[0])
        pred0, _ = eng.serve([u])
        eng.commit(20, hist[1], stal[1], dirty[1])   # invalidates u
        pred1, _ = eng.serve([u])
        assert np.isclose(pred0[0], hist[0, u].sum(), rtol=1e-5)
        assert np.isclose(pred1[0], hist[1, u].sum(), rtol=1e-5)
        assert pred0[0] != pred1[0]
        assert eng.cache.invalidations >= 1

    def test_cache_hit_staleness_is_exact(self, base_trace, chunk_info):
        """A clean agent's cached row stays valid across commits, but its
        staleness keeps aging — hits must serve the aged value
        bit-identically to a fresh store read."""
        dirty, stal = chunk_info
        hist = base_trace.theta_hist
        clean = np.where(~dirty[1])[0]
        assert clean.size
        users = clean[:8]
        eng = CollabServeEngine(AgentStateStore(N, P_DIM), N, P_DIM)
        eng.commit(10, hist[0], stal[0], dirty[0])
        eng.serve(users)                              # all misses: cached
        eng.commit(20, hist[1], stal[1], dirty[1])    # users stay clean
        _, served = eng.serve(users)                  # all hits
        assert eng.cache.hits == users.size
        assert np.array_equal(served, stal[1][users])


class TestSnapshotConsistency:
    def test_same_round_race_never_tears(self, base_trace, chunk_info):
        """A reader holding a snapshot sees all-old rows even if a commit
        lands mid-read; the next read sees all-new rows — never a mix."""
        _, stal = chunk_info
        hist = base_trace.theta_hist
        store = AgentStateStore(N, P_DIM)
        store.commit(10, hist[0], stal[0])
        held = store.snapshot()                 # reader grabs the tuple
        store.commit(20, hist[1], stal[1])      # writer races past it
        assert np.array_equal(held.theta, hist[0])
        assert held.round == 10
        after = store.read_rows(np.arange(N))
        assert np.array_equal(after.theta, hist[1])
        assert after.round == 20

    def test_batched_read_is_one_snapshot(self, base_trace, chunk_info):
        """read_rows gathers every row from a single committed tuple."""
        _, stal = chunk_info
        hist = base_trace.theta_hist
        store = AgentStateStore(N, P_DIM)
        store.commit(10, hist[0], stal[0])
        got = store.read_rows([3, 3, 7])
        assert np.array_equal(got.theta[0], got.theta[1])
        assert np.array_equal(got.theta, hist[0][[3, 3, 7]])


class TestShardedReadRouting:
    def test_matches_single_device_bit_for_bit(self, base_trace,
                                               chunk_info):
        dirty, stal = chunk_info
        hist = base_trace.theta_hist
        rng = np.random.default_rng(1)
        owner = rng.integers(0, 4, N).astype(np.int32)
        local_pos = np.zeros(N, np.int32)
        for q in range(4):
            idx = np.where(owner == q)[0]
            local_pos[idx] = np.arange(idx.size)
        single = AgentStateStore(N, P_DIM)
        sharded = ShardedAgentStateStore(owner, local_pos, P_DIM, 4)
        for ci in range(hist.shape[0]):
            single.commit((ci + 1) * 10, hist[ci], stal[ci])
            sharded.commit((ci + 1) * 10, hist[ci], stal[ci])
            users = rng.integers(0, N, 32)
            a = single.read_rows(users)
            b = sharded.read_rows(users)
            assert np.array_equal(a.theta, b.theta)
            assert np.array_equal(a.staleness, b.staleness)
            assert a.round == b.round


class TestTelemetryIntegration:
    def test_staleness_replay_matches_in_scan_counters(self, problem,
                                                       chunk_info):
        """stream_staleness_chunks is the host replay of the in-scan
        staleness counters — bit-identical, so served staleness needs no
        telemetry opt-in."""
        _, stal = chunk_info
        tr = run_scenario(_spec(problem,
                                telemetry=TelemetryConfig(enabled=True)))
        assert np.array_equal(tr.telemetry.staleness, stal)

    def test_serve_counters_reach_frames(self, problem):
        serve = precompute_serve_stream(N, RUN_KW["rounds"], rate=4.0,
                                        seed=5)
        tr = run_scenario(_spec(problem, serve=serve,
                                telemetry=TelemetryConfig(enabled=True)))
        tel = tr.telemetry
        assert tel.serve_requests is not None
        assert tel.serve_requests[-1] == tr.serve.requests
        assert tel.serve_hits[-1] == tr.serve.hits
        assert tel.serve_misses[-1] == tr.serve.misses
        assert tel.serve_invalidations[-1] == tr.serve.invalidations
        row = tel.summarize()[-1]
        assert row["serve_requests"] == tr.serve.requests
        assert row["serve_hits"] + row["serve_misses"] \
            == row["serve_requests"]
        # counters are cumulative
        assert (np.diff(tel.serve_requests) >= 0).all()


class TestServeStream:
    def test_chunk_assignment_boundaries(self):
        serve = precompute_serve_stream(N, 40, rate=3.0, seed=0)
        chunks = serve_chunk_requests(serve, 4, 10)
        assert len(chunks) == 4
        total = sum(u.size for u, _ in chunks)
        assert total == serve.n_requests
        for ci, (users, rounds) in enumerate(chunks):
            assert (rounds >= ci * 10).all()
            assert (rounds < (ci + 1) * 10).all()
            assert (users >= 0).all() and (users < N).all()

    def test_rng_independent_of_event_stream(self):
        """The request stream draws from its own numpy generator — same
        seed, different horizons never touch the gossip key schedule."""
        a = precompute_serve_stream(N, 40, rate=3.0, seed=0)
        b = precompute_serve_stream(N, 40, rate=3.0, seed=0)
        assert np.array_equal(a.user, b.user)
        assert np.array_equal(a.round, b.round)
        c = precompute_serve_stream(N, 40, rate=3.0, seed=1)
        assert not np.array_equal(a.user, c.user)

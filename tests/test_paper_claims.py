"""Integration tests pinning the paper's experimental claims at small scale
(fast versions of the benchmark suites; full curves in benchmarks/)."""

import numpy as np

from repro.core import (closed_form, solitary_mean, solitary_gd,
                        confidences_from_counts, consensus_model, sync_admm)
from repro.data import (mean_estimation_problem,
                        linear_classification_problem, accuracy)


class TestC3Confidence:
    def test_confidence_wins_under_unbalance(self):
        wins, flat_err = [], []
        for inst in range(10):
            g, data, targets, _ = mean_estimation_problem(n=100, eps=1.0,
                                                           seed=100 + inst)
            sol = np.asarray(solitary_mean(data))
            conf = np.asarray(confidences_from_counts(data.counts))
            with_c = np.asarray(closed_form(g, sol, conf, 0.99))[:, 0]
            no_c = np.asarray(closed_form(g, sol, np.ones(g.n), 0.99))[:, 0]
            e_c = np.mean((with_c - targets) ** 2)
            e_nc = np.mean((no_c - targets) ** 2)
            wins.append(e_c < e_nc)
            flat_err.append(e_c)
        assert np.mean(wins) >= 0.7          # paper: ~0.85 at eps=1
        assert np.mean(flat_err) < 0.2       # with-confidence error stays low

    def test_balanced_data_makes_no_difference(self):
        g, data, targets, _ = mean_estimation_problem(n=40, eps=0.0, seed=0)
        sol = np.asarray(solitary_mean(data))
        conf = np.asarray(confidences_from_counts(data.counts))
        with_c = np.asarray(closed_form(g, sol, conf, 0.99))
        no_c = np.asarray(closed_form(g, sol, np.ones(g.n), 0.99))
        np.testing.assert_allclose(with_c, no_c, atol=1e-6)


class TestC5Ordering:
    def test_cl_beats_solitary_beats_consensus(self):
        accs = {"sol": [], "cons": [], "mp": [], "cl": []}
        for inst in range(3):
            g, train, test, _ = linear_classification_problem(
                n=50, p=30, seed=inst * 13)
            sol = np.asarray(solitary_gd(train, "hinge", steps=250))
            conf = np.asarray(confidences_from_counts(train.counts))
            cons = np.tile(np.asarray(consensus_model(train, "hinge")),
                           (g.n, 1))
            mp = np.asarray(closed_form(g, sol, conf, 0.8))
            cl = np.asarray(sync_admm(g, train, 0.05, 1.0, "hinge", steps=40,
                                      k_steps=12, lr=0.05, theta_sol=sol
                                      ).theta_hist[-1])
            accs["sol"].append(np.mean(accuracy(sol, test)))
            accs["cons"].append(np.mean(accuracy(cons, test)))
            accs["mp"].append(np.mean(accuracy(mp, test)))
            accs["cl"].append(np.mean(accuracy(cl, test)))
        m = {k: float(np.mean(v)) for k, v in accs.items()}
        assert m["cl"] > m["sol"] > m["cons"], m
        assert m["mp"] > m["sol"], m
        assert m["cl"] > m["mp"] - 0.02, m   # CL >= MP (paper Fig 3)

    def test_c6_cl_equalizes_across_sizes(self):
        g, train, test, _ = linear_classification_problem(n=60, p=30, seed=7)
        sol = np.asarray(solitary_gd(train, "hinge", steps=250))
        cl = np.asarray(sync_admm(g, train, 0.05, 1.0, "hinge", steps=50,
                                  k_steps=12, lr=0.05, theta_sol=sol
                                  ).theta_hist[-1])
        acc = accuracy(cl, test)
        counts = np.asarray(train.counts)
        small = acc[counts <= 7]
        large = acc[counts >= 14]
        if len(small) and len(large):
            # data-poor agents end up within a few points of data-rich ones
            assert abs(float(np.mean(small)) - float(np.mean(large))) < 0.12

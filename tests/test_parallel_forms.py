"""Equivalence of alternative compute forms used by the dry-run cost
accounting: parallel mLSTM == recurrent scan; ref == chunked attention."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import Model, ModelConfig
from repro.models.attention import ref_attention, chunked_attention


class TestMLSTMParallel:
    @pytest.mark.parametrize("seed", [0, 1])
    def test_matches_scan_exactly(self, seed):
        base = ModelConfig(name="x", family="ssm", n_layers=2, d_model=64,
                           n_heads=4, n_kv_heads=4, d_ff=0, vocab_size=128,
                           pattern=("mlstm", "mlstm"),
                           compute_dtype=jnp.float32, remat=False)
        m1 = Model(base)
        m2 = Model(dataclasses.replace(base, mlstm_impl="parallel"))
        params = m1.init(jax.random.PRNGKey(seed))
        tok = jax.random.randint(jax.random.PRNGKey(seed + 10), (2, 24), 0,
                                 128)
        batch = {"tokens": tok, "labels": tok}
        l1, _ = m1.forward(params, batch)
        l2, _ = m2.forward(params, batch)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-4)

    def test_final_state_matches(self):
        base = ModelConfig(name="x", family="ssm", n_layers=1, d_model=32,
                           n_heads=2, n_kv_heads=2, d_ff=0, vocab_size=64,
                           pattern=("mlstm",), compute_dtype=jnp.float32,
                           remat=False)
        m1, m2 = Model(base), Model(dataclasses.replace(base,
                                                        mlstm_impl="parallel"))
        params = m1.init(jax.random.PRNGKey(3))
        tok = jax.random.randint(jax.random.PRNGKey(4), (2, 16), 0, 64)
        batch = {"tokens": tok, "labels": tok}
        _, c1 = m1.prefill(params, batch, cache_len=20)
        _, c2 = m2.prefill(params, batch, cache_len=20)
        for a, b in zip(jax.tree_util.tree_leaves(c1["layers"]),
                        jax.tree_util.tree_leaves(c2["layers"])):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-5)


class TestChunkedAttention:
    @pytest.mark.parametrize("window", [None, 48])
    def test_matches_ref(self, window):
        B, S, H, hd = 2, 128, 4, 32
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q, k, v = (jax.random.normal(kk, (B, S, H, hd)) for kk in ks)
        got = chunked_attention(q, k, v, window=window, chunk=32)
        want = ref_attention(q, k, v, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=1e-5, rtol=1e-4)


class TestMoEDispatch:
    def test_gather_equals_scatter(self):
        base = ModelConfig(name="m", family="moe", n_layers=2, d_model=64,
                           n_heads=4, n_kv_heads=4, d_ff=96, vocab_size=128,
                           n_experts=4, top_k=2, attn_impl="ref", remat=False,
                           compute_dtype=jnp.float32)
        m1 = Model(base)
        m2 = Model(dataclasses.replace(base, moe_impl="gather"))
        params = m1.init(jax.random.PRNGKey(0))
        tok = jax.random.randint(jax.random.PRNGKey(1), (2, 32), 0, 128)
        batch = {"tokens": tok, "labels": tok}
        l1, _ = m1.forward(params, batch)
        l2, _ = m2.forward(params, batch)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)

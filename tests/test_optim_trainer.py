"""Coverage for repro.optim + repro.train (CI enforces >= 85% per package):
AdamW parity against a hand-rolled numpy reference (clip + decay + bias
correction, step by step), decoupled weight decay semantics, bitwise
flattened-vs-pytree equivalence through ParamFlattener, schedule bounds,
and checkpoint round-trips (bf16 moments included)."""

import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ParamFlattener
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         cosine_schedule)
from repro.train import (TrainConfig, load_checkpoint, save_checkpoint)
from repro.train.trainer import init_train_state, stack_params


def reference_adamw(params, grads, m, v, count, cfg, lr_scale=1.0):
    """Hand-rolled numpy AdamW mirroring the documented update rule."""
    count = count + 1
    g = {k: np.asarray(x, np.float32) for k, x in grads.items()}
    if cfg.grad_clip:
        gn = np.sqrt(sum(np.sum(x * x) for x in g.values()))
        scale = min(1.0, cfg.grad_clip / max(gn, 1e-9))
        g = {k: x * np.float32(scale) for k, x in g.items()}
    bias1 = 1.0 - cfg.b1 ** count
    bias2 = 1.0 - cfg.b2 ** count
    new_p, new_m, new_v = {}, {}, {}
    for k in params:
        m32 = cfg.b1 * np.asarray(m[k], np.float32) + (1 - cfg.b1) * g[k]
        v32 = cfg.b2 * np.asarray(v[k], np.float32) \
            + (1 - cfg.b2) * g[k] * g[k]
        step = (m32 / bias1) / (np.sqrt(v32 / bias2) + cfg.eps)
        if cfg.weight_decay:
            step = step + cfg.weight_decay * np.asarray(params[k],
                                                        np.float32)
        new_p[k] = np.asarray(params[k], np.float32) \
            - cfg.lr * lr_scale * step
        new_m[k], new_v[k] = m32, v32
    return new_p, new_m, new_v, count


class TestAdamWParity:
    def test_matches_numpy_reference_step_by_step(self):
        cfg = AdamWConfig(lr=0.02, b1=0.9, b2=0.95, eps=1e-8,
                          weight_decay=0.1, grad_clip=0.5,
                          moment_dtype=jnp.float32)
        rng = np.random.default_rng(0)
        params = {"w": jnp.asarray(rng.standard_normal((4, 3)), jnp.float32),
                  "b": jnp.asarray(rng.standard_normal(3), jnp.float32)}
        opt = adamw_init(params, cfg)
        ref_p = {k: np.asarray(v) for k, v in params.items()}
        ref_m = {k: np.zeros_like(v) for k, v in ref_p.items()}
        ref_v = {k: np.zeros_like(v) for k, v in ref_p.items()}
        ref_c = 0
        for step in range(5):
            grads = {k: jnp.asarray(rng.standard_normal(v.shape) * 3.0,
                                    jnp.float32)
                     for k, v in params.items()}
            params, opt, gn = adamw_update(grads, opt, params, cfg,
                                           lr_scale=0.7)
            ref_p, ref_m, ref_v, ref_c = reference_adamw(
                ref_p, {k: np.asarray(g) for k, g in grads.items()},
                ref_m, ref_v, ref_c, cfg, lr_scale=0.7)
            assert int(opt["count"]) == ref_c == step + 1
            assert float(gn) > 0.0
            for k in params:
                np.testing.assert_allclose(np.asarray(params[k]), ref_p[k],
                                           rtol=1e-5, atol=1e-6)
                np.testing.assert_allclose(np.asarray(opt["m"][k]), ref_m[k],
                                           rtol=1e-5, atol=1e-6)
                np.testing.assert_allclose(np.asarray(opt["v"][k]), ref_v[k],
                                           rtol=1e-5, atol=1e-6)

    def test_weight_decay_is_decoupled(self):
        """Zero gradients: the only force is decay, newp = p(1 - lr*wd) —
        decay never passes through the moment/bias-correction machinery."""
        cfg = AdamWConfig(lr=0.1, weight_decay=0.5, grad_clip=1.0,
                          moment_dtype=jnp.float32)
        params = {"w": jnp.full((3, 2), 2.0)}
        opt = adamw_init(params, cfg)
        grads = {"w": jnp.zeros((3, 2))}
        new_p, opt, gn = adamw_update(grads, opt, params, cfg)
        np.testing.assert_allclose(np.asarray(new_p["w"]),
                                   2.0 * (1 - 0.1 * 0.5), rtol=1e-6)
        assert float(gn) == 0.0
        # no decay -> zero gradients are a fixed point
        cfg0 = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=1.0,
                           moment_dtype=jnp.float32)
        new_p0, _, _ = adamw_update(grads, adamw_init(params, cfg0), params,
                                    cfg0)
        np.testing.assert_array_equal(np.asarray(new_p0["w"]),
                                      np.asarray(params["w"]))

    def test_grad_clip_disabled_skips_norm(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0, grad_clip=0.0,
                          moment_dtype=jnp.float32)
        params = {"w": jnp.ones(4)}
        grads = {"w": jnp.full(4, 1e6)}
        _, _, gn = adamw_update(grads, adamw_init(params, cfg), params, cfg)
        assert float(gn) == 0.0    # sentinel: norm never computed

    def test_moments_cast_to_config_dtype(self):
        cfg = AdamWConfig(moment_dtype=jnp.bfloat16)
        params = {"w": jnp.ones((2, 2))}
        opt = adamw_init(params, cfg)
        grads = {"w": jnp.ones((2, 2))}
        _, opt, _ = adamw_update(grads, opt, params, cfg)
        assert opt["m"]["w"].dtype == jnp.bfloat16
        assert opt["v"]["w"].dtype == jnp.bfloat16

    def test_flattened_matches_pytree_through_flattener(self):
        """AdamW is elementwise, so running it on ParamFlattener rows must
        be bit-identical to running it on the pytree (the property the
        inexact primal's flat slot-row optimization rests on)."""
        cfg = AdamWConfig(lr=0.05, weight_decay=0.0, grad_clip=0.0,
                          moment_dtype=jnp.float32)
        rng = np.random.default_rng(3)
        tree = {"w": jnp.asarray(rng.standard_normal((3, 4)), jnp.float32),
                "b": jnp.asarray(rng.standard_normal(4), jnp.float32)}
        flat = ParamFlattener.from_template(tree)
        vec = flat.flatten(tree)
        opt_t, opt_f = adamw_init(tree, cfg), adamw_init(vec, cfg)
        for _ in range(3):
            gt = {"w": jnp.asarray(rng.standard_normal((3, 4)), jnp.float32),
                  "b": jnp.asarray(rng.standard_normal(4), jnp.float32)}
            tree, opt_t, _ = adamw_update(gt, opt_t, tree, cfg)
            vec, opt_f, _ = adamw_update(flat.flatten(gt), opt_f, vec, cfg)
            np.testing.assert_array_equal(np.asarray(flat.flatten(tree)),
                                          np.asarray(vec))


class TestCosineSchedule:
    def test_warmup_then_decay_to_floor(self):
        s = [float(cosine_schedule(t, total_steps=1000, warmup=100,
                                   min_frac=0.1))
             for t in range(0, 1001, 50)]
        assert s[0] == 0.0
        assert abs(s[2] - 1.0) < 1e-6            # end of warmup
        assert all(a >= b - 1e-6 for a, b in zip(s[2:], s[3:]))  # decay
        assert abs(s[-1] - 0.1) < 1e-6           # min_frac floor
        assert all(0.0 <= v <= 1.0 + 1e-6 for v in s)


class TestTrainStateAndCheckpoint:
    def test_stack_params_replicates_and_perturbs(self):
        base = {"w": jnp.ones((2, 3))}
        stacked = stack_params(base, 4)
        assert stacked["w"].shape == (4, 2, 3)
        np.testing.assert_array_equal(np.asarray(stacked["w"][0]),
                                      np.asarray(stacked["w"][3]))
        jig = stack_params(base, 4, perturb=0.1, key=jax.random.PRNGKey(0))
        assert not np.array_equal(np.asarray(jig["w"][0]),
                                  np.asarray(jig["w"][1]))

    def test_checkpoint_roundtrip_with_bf16_moments(self):
        tree = {"p": jnp.asarray([[1.5, -2.0]], jnp.float32),
                "m": jnp.asarray([0.25, 0.5], jnp.bfloat16),
                "c": jnp.asarray(7, jnp.int32)}
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(tree, d, step=3)
            save_checkpoint(tree, d, step=11)
            restored, step = load_checkpoint(tree, d)     # latest wins
            assert step == 11
            assert restored["m"].dtype == jnp.bfloat16
            for k in tree:
                np.testing.assert_array_equal(
                    np.asarray(tree[k], np.float32),
                    np.asarray(restored[k], np.float32))
            restored3, step3 = load_checkpoint(tree, d, step=3)
            assert step3 == 3

    def test_checkpoint_errors(self):
        tree = {"p": jnp.zeros(2)}
        with tempfile.TemporaryDirectory() as d:
            with pytest.raises(FileNotFoundError):
                load_checkpoint(tree, d)
            save_checkpoint(tree, d, step=0)
            with pytest.raises(KeyError):
                load_checkpoint({"other": jnp.zeros(2)}, d)

    def test_train_config_defaults_compose(self):
        tcfg = TrainConfig(n_agents=3, steps=5)
        assert tcfg.optimizer.lr > 0 and tcfg.coupling.mode == "mp"

"""repro-lint framework tests: per-rule fixtures + waiver semantics.

Each rule gets at least one true-positive fixture, one clean negative,
and one waived-positive; waivers without a justification string must
leave the finding unwaived and add an RPL000 finding.  Fixtures run
through :func:`tools.lint.lint_source` with virtual repo-relative paths
so per-rule path scoping (tests/ vs src/) is exercised too.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO not in sys.path:
    sys.path.insert(0, REPO)

from tools.lint import lint_source  # noqa: E402


def run(src, rel="src/repro/mod.py", select=None):
    return lint_source(textwrap.dedent(src), rel, select)


def codes(findings, unwaived_only=True):
    return [f.code for f in findings if not (unwaived_only and f.waived)]


# ---------------------------------------------------------------------------
# waiver machinery (RPL000)
# ---------------------------------------------------------------------------


def test_waiver_requires_justification():
    src = """
    import numpy as np

    def f(x, idx, mask, v):
        x[idx[mask]] = v  # repro-lint: disable=RPL002
        return x
    """
    out = run(src)
    # the waiver is inert AND reported: RPL002 stays unwaived, RPL000 fires
    assert sorted(codes(out)) == ["RPL000", "RPL002"]


def test_waiver_with_justification_waives():
    src = """
    import numpy as np

    def f(x, idx, mask, v):
        x[idx[mask]] = v  # repro-lint: disable=RPL002  index is a permutation
        return x
    """
    out = run(src)
    assert codes(out) == []
    waived = [f for f in out if f.waived]
    assert [f.code for f in waived] == ["RPL002"]
    assert waived[0].justification == "index is a permutation"


def test_waiver_standalone_comment_covers_next_line():
    src = """
    import numpy as np

    def f(x, idx, mask, v):
        # repro-lint: disable=RPL002  upstream dedup guarantees uniqueness
        x[idx[mask]] = v
        return x
    """
    assert codes(run(src)) == []


def test_waiver_covers_only_its_rule():
    src = """
    import numpy as np

    def f(x, idx, mask, v):
        x[idx[mask]] = v  # repro-lint: disable=RPL001  wrong rule cited
        return x
    """
    assert codes(run(src)) == ["RPL002"]


def test_syntax_error_reports_rpl000():
    out = lint_source("def broken(:\n", "src/repro/bad.py")
    assert codes(out) == ["RPL000"]


# ---------------------------------------------------------------------------
# RPL001 — concrete kernel imports outside kernels/
# ---------------------------------------------------------------------------


def test_rpl001_flags_concrete_import():
    src = "from repro.kernels.graph_mix import dense_mix_reference\n"
    assert codes(run(src, "src/repro/core/x.py")) == ["RPL001"]


def test_rpl001_dispatch_import_clean():
    src = "from repro.kernels import resolve, ReproBackend\n"
    assert codes(run(src, "src/repro/core/x.py")) == []


def test_rpl001_inside_kernels_exempt():
    src = "from repro.kernels.graph_mix import dense_mix_reference\n"
    assert codes(run(src, "src/repro/kernels/other.py")) == []


def test_rpl001_tests_exempt():
    src = "from repro.kernels.round_fuse import round_step\n"
    assert codes(run(src, "tests/test_x.py")) == []


def test_rpl001_waived():
    src = ("from repro.kernels.sparse_mix import sparse_gather_mix"
           "  # repro-lint: disable=RPL001  doc example, not dispatch\n")
    assert codes(run(src, "src/repro/core/x.py")) == []


# ---------------------------------------------------------------------------
# RPL002 — duplicate-capable scatters need a winner-policy marker
# ---------------------------------------------------------------------------


def test_rpl002_flags_at_set_with_array_index():
    src = """
    import jax.numpy as jnp

    def f(x, idx, v):
        return x.at[idx].set(v)
    """
    assert codes(run(src)) == ["RPL002"]


def test_rpl002_marker_silences():
    src = """
    import jax.numpy as jnp

    def f(x, idx, v):
        return x.at[idx].set(v)  # scatter: last-write-wins
    """
    assert codes(run(src)) == []


def test_rpl002_marker_block_above_counts():
    src = """
    import jax.numpy as jnp

    def f(x, idx, v):
        # scatter: idempotent — every written value is identical
        # (second comment line directly above the statement)
        y = x.at[idx].set(v)
        return y
    """
    assert codes(run(src)) == []


def test_rpl002_add_scatter_clean():
    # .add is order-independent: no marker needed
    src = """
    import jax.numpy as jnp

    def f(x, idx, v):
        return x.at[idx].add(v)
    """
    assert codes(run(src)) == []


def test_rpl002_scalar_loop_index_clean():
    src = """
    import numpy as np

    def f(x, vals):
        for i in range(len(vals)):
            x[i] = vals[i]
        return x
    """
    assert codes(run(src)) == []


def test_rpl002_numpy_fancy_assign_flagged():
    src = """
    import numpy as np

    def f(x, rows, v):
        idx = np.asarray(rows)
        x[idx] = v
        return x
    """
    assert codes(run(src)) == ["RPL002"]


def test_rpl002_bare_name_index_not_flagged():
    # the numpy branch fires only on *computed* indexes: a bare-name
    # subscript is indistinguishable from a dict write (d[key] = v), so
    # it stays out of scope by design
    src = """
    def f(d, key, v):
        d[key] = v
        return d
    """
    assert codes(run(src)) == []


def test_rpl002_numpy_augmented_fancy_assign_flagged():
    # numpy += silently drops duplicate targets — the worst offender
    src = """
    import numpy as np

    def f(x, rows, v):
        idx = np.asarray(rows)
        x[idx] += v
        return x
    """
    assert codes(run(src)) == ["RPL002"]


def test_rpl002_slice_assign_clean():
    src = """
    import numpy as np

    def f(x, v):
        x[2:5] = v
        return x
    """
    assert codes(run(src)) == []


def test_rpl002_waived():
    src = """
    import numpy as np

    def f(x, idx, mask, v):
        x[idx[mask]] = v  # repro-lint: disable=RPL002  idx unique by contract
        return x
    """
    assert codes(run(src)) == []


# ---------------------------------------------------------------------------
# RPL003 — host nondeterminism in traced scopes
# ---------------------------------------------------------------------------


def test_rpl003_np_random_in_jit():
    src = """
    import jax
    import numpy as np

    @jax.jit
    def f(x):
        return x + np.random.rand()
    """
    assert codes(run(src)) == ["RPL003"]


def test_rpl003_time_in_scan_body():
    src = """
    import time
    import jax

    def run(xs):
        def body(carry, x):
            return carry + x * time.time(), None
        return jax.lax.scan(body, 0.0, xs)
    """
    assert codes(run(src)) == ["RPL003"]


def test_rpl003_reachable_through_helper():
    src = """
    import jax
    import numpy as np

    def helper(x):
        return x * np.random.rand()

    @jax.jit
    def f(x):
        return helper(x)
    """
    assert codes(run(src)) == ["RPL003"]


def test_rpl003_host_side_random_clean():
    # nondeterminism outside any traced scope is fine (host setup code)
    src = """
    import numpy as np

    def make_data(n):
        return np.random.default_rng(0).normal(size=n)
    """
    assert codes(run(src)) == []


def test_rpl003_jax_random_in_jit_clean():
    src = """
    import jax

    @jax.jit
    def f(key, x):
        return x + jax.random.normal(key, x.shape)
    """
    assert codes(run(src)) == []


def test_rpl003_waived():
    src = """
    import jax
    import numpy as np

    @jax.jit
    def f(x):
        # repro-lint: disable=RPL003  traced once at compile time, constant
        return x + np.random.rand()
    """
    assert codes(run(src)) == []


# ---------------------------------------------------------------------------
# RPL004 — low-precision reductions need an f32 accumulator
# ---------------------------------------------------------------------------


def test_rpl004_bf16_sum_flagged():
    src = """
    import jax.numpy as jnp

    def f(x):
        y = x.astype(jnp.bfloat16)
        return jnp.sum(y)
    """
    assert codes(run(src)) == ["RPL004"]


def test_rpl004_dtype_kwarg_clean():
    src = """
    import jax.numpy as jnp

    def f(x):
        y = x.astype(jnp.bfloat16)
        return jnp.sum(y, dtype=jnp.float32)
    """
    assert codes(run(src)) == []


def test_rpl004_preferred_element_type_clean():
    src = """
    import jax.numpy as jnp

    def f(a, b):
        al = a.astype(jnp.bfloat16)
        return jnp.einsum("ij,jk->ik", al, b,
                          preferred_element_type=jnp.float32)
    """
    assert codes(run(src)) == []


def test_rpl004_config_dtype_taints():
    # *_dtype config knobs may resolve to bf16 at runtime — still flagged
    src = """
    import jax.numpy as jnp

    def f(x, cfg):
        y = x.astype(cfg.mix_dtype)
        return y.mean(axis=0)
    """
    assert codes(run(src)) == ["RPL004"]


def test_rpl004_f32_sum_clean():
    src = """
    import jax.numpy as jnp

    def f(x):
        return jnp.sum(x.astype(jnp.float32))
    """
    assert codes(run(src)) == []


def test_rpl004_tests_exempt():
    src = """
    import jax.numpy as jnp

    def f(x):
        return jnp.sum(x.astype(jnp.bfloat16))
    """
    assert codes(run(src, "tests/test_x.py")) == []


def test_rpl004_waived():
    src = """
    import jax.numpy as jnp

    def f(x):
        y = x.astype(jnp.bfloat16)
        return jnp.sum(y)  # repro-lint: disable=RPL004  tolerance-tested
    """
    assert codes(run(src)) == []


# ---------------------------------------------------------------------------
# RPL005 — interpret=True stays out of production
# ---------------------------------------------------------------------------


def test_rpl005_param_default_flagged():
    src = """
    def kernel(x, *, interpret=True):
        return x
    """
    assert codes(run(src)) == ["RPL005"]


def test_rpl005_call_site_flagged():
    src = """
    def g(pallas_call, x):
        return pallas_call(x, interpret=True)
    """
    assert codes(run(src)) == ["RPL005"]


def test_rpl005_false_default_clean():
    src = """
    def kernel(x, *, interpret=False):
        return x

    def g(x):
        return kernel(x, interpret=bool(x.size == 0))
    """
    assert codes(run(src)) == []


def test_rpl005_tests_and_benchmarks_exempt():
    src = """
    def g(pallas_call, x):
        return pallas_call(x, interpret=True)
    """
    assert codes(run(src, "tests/test_x.py")) == []
    assert codes(run(src, "benchmarks/bench_x.py")) == []


def test_rpl005_waived():
    src = """
    def g(pallas_call, x):
        return pallas_call(x, interpret=True)  # repro-lint: disable=RPL005  doc
    """
    assert codes(run(src, "examples/demo.py")) == []


# ---------------------------------------------------------------------------
# RPL006 — collectives must see their shard_map binding
# ---------------------------------------------------------------------------


def test_rpl006_unbound_collective_flagged():
    src = """
    import jax

    def f(x):
        return jax.lax.psum(x, "agents")
    """
    assert codes(run(src)) == ["RPL006"]


def test_rpl006_bound_by_module_shard_map_clean():
    src = """
    import jax
    from jax.experimental.shard_map import shard_map

    def body(x):
        return jax.lax.psum(x, "agents")

    def run(mesh, specs, x):
        return shard_map(body, mesh=mesh, in_specs=specs,
                         out_specs=specs)(x)
    """
    assert codes(run(src)) == []


def test_rpl006_docstring_contract_clean():
    src = '''
    import jax

    def halo(x):
        """Exchange halos.  Must be called inside a ``shard_map``."""
        return jax.lax.ppermute(x, "agents", [(0, 1)])
    '''
    assert codes(run(src)) == []


def test_rpl006_waived():
    src = """
    import jax

    def f(x):
        return jax.lax.psum(x, "agents")  # repro-lint: disable=RPL006  bound by caller
    """
    assert codes(run(src)) == []


# ---------------------------------------------------------------------------
# RPL007 — chunked recording goes through record_chunks
# ---------------------------------------------------------------------------


def test_rpl007_raw_division_flagged():
    src = """
    def run(steps, record_every):
        n_rec = steps // record_every
        return n_rec
    """
    assert codes(run(src)) == ["RPL007"]


def test_rpl007_record_chunks_impl_exempt():
    src = """
    def record_chunks(steps, record_every):
        record_every = max(1, min(record_every, steps))
        n_rec = steps // record_every
        return record_every, n_rec
    """
    assert codes(run(src)) == []


def test_rpl007_other_division_clean():
    src = """
    def halve(n):
        return n // 2
    """
    assert codes(run(src)) == []


def test_rpl007_waived():
    src = """
    def run(steps, record_every):
        n_rec = steps // record_every  # repro-lint: disable=RPL007  normalized upstream
        return n_rec
    """
    assert codes(run(src)) == []


# ---------------------------------------------------------------------------
# CLI: repo-wide run is clean, JSON report shape
# ---------------------------------------------------------------------------


def _run_cli(*args):
    return subprocess.run(
        [sys.executable, "-m", "tools.lint", *args],
        cwd=REPO, capture_output=True, text=True)


def test_cli_repo_clean():
    """The repo's own tree must lint clean — the CI gate in one test."""
    res = _run_cli("src", "tests", "benchmarks", "examples")
    assert res.returncode == 0, res.stdout + res.stderr


def test_cli_json_format():
    res = _run_cli("src/repro/telemetry", "--format", "json")
    assert res.returncode == 0, res.stdout + res.stderr
    report = json.loads(res.stdout)
    assert set(report) == {"findings", "counts"}
    c = report["counts"]
    assert c["unwaived"] == 0
    assert c["total"] == c["waived"]
    for f in report["findings"]:
        assert {"code", "path", "line", "col", "message",
                "waived", "justification"} <= set(f)


def test_cli_select_unknown_rule_is_usage_error():
    res = _run_cli("src/repro/telemetry", "--select", "RPL999")
    assert res.returncode == 2


def test_cli_list_rules():
    res = _run_cli("--list-rules")
    assert res.returncode == 0
    for code in ("RPL001", "RPL002", "RPL003", "RPL004",
                 "RPL005", "RPL006", "RPL007"):
        assert code in res.stdout

"""Quantized halo payloads (``launch.sim_mesh.HaloCodec``, DESIGN.md §15):
round-trip error bounds per codec (bf16 <= 2^-8 rel, int8 <= 2^-6 of the
row max), wire-size accounting, codec resolution, and — in an 8-fake-device
subprocess, since a P = 1 mesh has no halo to encode — the f32 bit-for-bit
anchor, the >= 2.8x int8 telemetry byte cut, and lossy-codec convergence of
the Eq. 3 objective on a planted two-cluster task."""

import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.sim_mesh import (HaloCodec, halo_payload_bytes,
                                   resolve_halo_codec)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def rows(seed=0, shape=(64, 32), scale=3.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(scale * rng.standard_normal(shape), jnp.float32)


class TestRoundTrip:
    def test_f32_is_identity(self):
        x = rows(0)
        (wire,) = HaloCodec("f32").encode(x)
        assert wire is x
        assert np.array_equal(np.asarray(HaloCodec("f32").decode((wire,))),
                              np.asarray(x))

    def test_bf16_relative_bound(self):
        """bf16 keeps f32's exponent, so the round-trip error is a plain
        relative bound: <= 2^-8 of each element."""
        codec = HaloCodec("bf16")
        for seed, scale in ((0, 1.0), (1, 1e-4), (2, 1e4)):
            x = rows(seed, scale=scale)
            parts = codec.encode(x)
            assert parts[0].dtype == jnp.bfloat16
            err = np.abs(np.asarray(codec.decode(parts)) - np.asarray(x))
            assert (err <= 2.0 ** -8 * np.abs(np.asarray(x))).all()

    def test_int8_row_relative_bound(self):
        """int8 quantizes against each trailing vector's max: the error
        bound is <= 2^-6 of that row max (half a step is max/254)."""
        codec = HaloCodec("int8")
        for seed, scale in ((0, 1.0), (1, 1e-4), (2, 1e4)):
            x = rows(seed, scale=scale)
            q, s = codec.encode(x)
            assert q.dtype == jnp.int8 and s.dtype == jnp.float32
            err = np.abs(np.asarray(codec.decode((q, s))) - np.asarray(x))
            amax = np.abs(np.asarray(x)).max(axis=-1, keepdims=True)
            assert (err <= 2.0 ** -6 * amax).all()

    def test_int8_zero_rows_exact(self):
        codec = HaloCodec("int8")
        x = jnp.zeros((5, 16), jnp.float32)
        out = np.asarray(codec.decode(codec.encode(x)))
        assert np.array_equal(out, np.zeros((5, 16), np.float32))

    def test_int8_handles_mixed_trailing_shapes(self):
        """The CL engine ships stacked (1 + 3k, p) payload rows through the
        same codec: per-vector scales must be independent."""
        codec = HaloCodec("int8")
        x = rows(3, shape=(8, 13, 16))
        x = x.at[:, 0].mul(1e3)           # one huge vector per row
        err = np.abs(np.asarray(codec.decode(codec.encode(x)))
                     - np.asarray(x))
        amax = np.abs(np.asarray(x)).max(axis=-1, keepdims=True)
        assert (err <= 2.0 ** -6 * amax).all()


class TestWireAccounting:
    def test_row_nbytes(self):
        assert HaloCodec("f32").row_nbytes((32,)) == 128
        assert HaloCodec("bf16").row_nbytes((32,)) == 64
        assert HaloCodec("int8").row_nbytes((32,)) == 36   # codes + 1 scale
        # CL stacked payload: one scale per trailing vector
        assert HaloCodec("int8").row_nbytes((25, 32)) == 25 * 32 + 4 * 25

    def test_acceptance_cut_ratios(self):
        """Acceptance: bf16 halves the wire, int8 cuts >= 2.8x at the
        benchmark's p = 32 rows (and stays > 2.8x for the CL payload)."""
        for shape in ((32,), (25, 32)):
            f32 = HaloCodec("f32").row_nbytes(shape)
            assert f32 / HaloCodec("bf16").row_nbytes(shape) == 2.0
            assert f32 / HaloCodec("int8").row_nbytes(shape) >= 2.8

    def test_halo_payload_bytes(self):
        assert halo_payload_bytes(8, 40, 36, halo_size=64) == 8 * 40 * 36
        assert halo_payload_bytes(8, 40, 36, halo_size=0) == 0


class TestResolve:
    def test_resolution(self):
        assert resolve_halo_codec(None) == HaloCodec("f32")
        assert resolve_halo_codec("int8") == HaloCodec("int8")
        codec = HaloCodec("bf16")
        assert resolve_halo_codec(codec) is codec

    def test_unknown_name_raises(self):
        with pytest.raises(ValueError, match="unknown halo codec"):
            resolve_halo_codec("fp4")

    def test_hashable_static_arg(self):
        assert hash(HaloCodec("int8")) == hash(HaloCodec("int8"))
        assert HaloCodec("f32").is_identity
        assert not HaloCodec("int8").is_identity


# ---------------------------------------------------------------------------
# 8-fake-device subprocess: a P = 1 mesh has halo_size 0 and never encodes
# a byte, so the codec-on-the-wire claims need a real multi-shard mesh
# ---------------------------------------------------------------------------


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    assert jax.device_count() == 8
    from repro.core.model_propagation import mp_objective
    from repro.simulate import (NetworkConditions,
                                planted_partition_topology,
                                run_mp_scenario, run_mp_scenario_sharded)
    from repro.telemetry import TelemetryConfig

    # planted two-cluster task: per-cluster centers plus per-agent noise
    topo = planted_partition_topology(120, n_clusters=2, k_intra=6,
                                      k_inter=2, seed=0)
    rng = np.random.default_rng(0)
    centers = np.asarray([[2.0] * 32, [-2.0] * 32], np.float32)
    sol = (centers[topo.groups]
           + 0.5 * rng.standard_normal((120, 32))).astype(np.float32)
    c = rng.uniform(0.3, 1.0, 120).astype(np.float32)
    cond = NetworkConditions(drop_prob=0.05, stale_prob=0.2)
    kw = dict(rounds=80, batch=48, seed=3, record_every=20,
              telemetry=TelemetryConfig(enabled=True))

    tr = run_mp_scenario(topo, sol, c, 0.9, cond, **kw)
    runs = {name: run_mp_scenario_sharded(topo, sol, c, 0.9, cond,
                                          halo_codec=name, **kw)
            for name in ("f32", "bf16", "int8")}
    for name, sh in runs.items():
        assert sh.n_shards == 8 and sh.overflow == 0, name
        assert sh.halo_size > 0, "partition must actually exchange halos"
        assert np.isfinite(sh.theta_hist).all(), name
        assert (sh.delivered, sh.dropped) == (tr.delivered, tr.dropped)

    # f32 codec is the bit-for-bit anchor vs the single-device trajectory
    assert np.abs(runs["f32"].theta_hist - tr.theta_hist).max() == 0.0

    # telemetry halo_bytes accounts the coded wire: bf16 exactly halves,
    # int8 cuts >= 2.8x (acceptance)
    bytes_of = {n: r.telemetry.halo_bytes[-1] for n, r in runs.items()}
    assert bytes_of["f32"] > 0
    assert bytes_of["f32"] / bytes_of["bf16"] == 2.0
    assert bytes_of["f32"] / bytes_of["int8"] >= 2.8, bytes_of

    # lossy codecs still converge: final Eq. 3 objective within 2% of the
    # f32 run's, and strictly below the warm start's (the mu-weighted
    # anchor keeps the optimum itself close to the warm start)
    W = np.zeros((120, 120), np.float32)
    tabs = topo.tables
    for i in range(120):
        d = int(tabs.deg_count[i])
        W[i, tabs.nbr_idx[i, :d]] = tabs.nbr_w[i, :d]
    obj = {n: float(mp_objective(r.theta_hist[-1], sol, W, c, mu=1.0))
           for n, r in runs.items()}
    obj0 = float(mp_objective(sol, sol, W, c, mu=1.0))
    for name in ("bf16", "int8"):
        assert obj[name] <= 1.02 * obj["f32"], (name, obj)
        assert obj[name] < obj0, (name, obj, obj0)
    print("HALO-CODEC-8DEV-OK", obj)
""")


def test_codec_parity_and_bytes_subprocess():
    """f32 anchor + byte accounting + lossy-codec convergence on a real
    8-shard mesh (device-count flag must precede jax init)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep \
        + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SUBPROC], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "HALO-CODEC-8DEV-OK" in out.stdout

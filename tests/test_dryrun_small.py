"""Dry-run machinery on a small fake-device mesh (subprocess: the 8-device
XLA flag must be set before jax init, which pytest has already done)."""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.launch import dryrun as dr
    from repro.launch.mesh import use_mesh
    from repro.launch.hlo_analysis import cost_dict
    from repro.launch.shapes import InputShape

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    out = {}
    for arch in ("llama3_8b", "olmoe_1b_7b", "xlstm_1_3b",
                 "recurrentgemma_2b", "qwen2_vl_7b", "musicgen_medium"):
        cfg = get_config(arch, "reduced")
        shape = InputShape("t", 64 if cfg.family != "vlm" else 64, 8, "train")
        jitted, args, model = dr.build_train(cfg, shape, mesh, "dense", "mp")
        with use_mesh(mesh):
            compiled = jitted.lower(*args).compile()
        c = cost_dict(compiled)
        out[arch + "/train"] = float(c.get("flops", 0))
        dshape = InputShape("d", 64, 8, "decode")
        jitted, args, model = dr.build_decode(cfg, dshape, mesh)
        with use_mesh(mesh):
            compiled = jitted.lower(*args).compile()
        out[arch + "/decode"] = float(cost_dict(compiled).get("flops", 0))
    # gossip schedule lowers too
    cfg = get_config("llama3_8b", "reduced")
    shape = InputShape("t", 64, 8, "train")
    jitted, args, model = dr.build_train(cfg, shape, mesh, "gossip", "mp")
    with use_mesh(mesh):
        compiled = jitted.lower(*args).compile()
    stats = __import__("repro.launch.hlo_analysis",
                       fromlist=["collective_stats"]).collective_stats(
        compiled.as_text())
    out["gossip/collective_permute"] = stats["collective-permute"]["count"]
    print("RESULT " + json.dumps(out))
""")


@pytest.mark.slow
def test_dryrun_small_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stderr[-3000:]
    line = [l for l in proc.stdout.splitlines() if l.startswith("RESULT ")]
    assert line, proc.stdout[-2000:]
    out = json.loads(line[0][len("RESULT "):])
    for k, v in out.items():
        if k.endswith(("train", "decode")):
            assert v > 0, (k, v)
    # the gossip schedule must actually emit collective_permutes
    assert out["gossip/collective_permute"] > 0

"""Per-arch smoke tests (deliverable f): REDUCED variant of each assigned
architecture runs one forward/train step on CPU; output shapes + no NaNs.
Also: decode-path consistency and param/pspec tree agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.models import Model
from repro.launch.shapes import SHAPES, plan_decode

B, S = 2, 32


def make_batch(m: Model, key, batch=B, seq=S):
    cfg = m.cfg
    specs = m.input_specs(batch, seq, "train")
    out = {}
    for k, s in specs.items():
        key, sub = jax.random.split(key)
        if s.dtype == jnp.int32:
            out[k] = jax.random.randint(sub, s.shape, 0, cfg.vocab_size)
        else:
            out[k] = (0.02 * jax.random.normal(sub, s.shape)).astype(s.dtype)
    if cfg.family == "vlm":
        pos = jnp.broadcast_to(jnp.arange(seq)[None], (batch, seq))
        out["positions3"] = jnp.stack([pos] * 3)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch):
    cfg = get_config(arch, "reduced")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(m, jax.random.PRNGKey(1))

    @jax.jit
    def step(params, batch):
        (loss, metrics), grads = jax.value_and_grad(m.loss, has_aux=True)(
            params, batch)
        params = jax.tree_util.tree_map(lambda p, g: p - 1e-3 * g.astype(p.dtype),
                                        params, grads)
        return loss, metrics, params

    loss, metrics, new_params = step(params, batch)
    assert jnp.isfinite(loss), (arch, loss)
    assert jnp.isfinite(metrics["ce"])
    # params actually moved
    moved = jax.tree_util.tree_reduce(
        lambda acc, pq: acc + float(jnp.abs(pq).sum()),
        jax.tree_util.tree_map(lambda a, b: a - b, params, new_params), 0.0)
    assert moved > 0.0

    logits, _ = jax.jit(m.forward)(params, batch)
    if cfg.family == "audio":
        assert logits.shape == (B, cfg.n_codebooks, S - cfg.n_cond_tokens,
                                cfg.vocab_size)
    elif cfg.family == "vlm":
        assert logits.shape == (B, S - cfg.n_media_tokens, cfg.vocab_size)
    else:
        assert logits.shape == (B, S, cfg.vocab_size)
    assert jnp.isfinite(logits).all()


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_decode_step(arch):
    cfg = get_config(arch, "reduced")
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = make_batch(m, jax.random.PRNGKey(1))
    _, cache = jax.jit(lambda p, b: m.prefill(p, b, cache_len=S + 8))(params,
                                                                      batch)
    tok_shape = (B, cfg.n_codebooks) if cfg.family == "audio" else (B,)
    tok = jnp.zeros(tok_shape, jnp.int32)
    logits, cache2 = jax.jit(lambda p, c, b: m.decode_step(p, c, b))(
        params, cache, {"token": tok})
    assert jnp.isfinite(logits).all(), arch
    assert int(cache2["pos"][0]) == S + 1


@pytest.mark.parametrize("arch", ARCHS)
def test_pspec_tree_matches_params(arch):
    """ParamDef single-sourcing: pspecs and params have identical treedefs,
    and every spec rank matches its leaf rank."""
    cfg = get_config(arch, "reduced")
    m = Model(cfg)
    params = jax.eval_shape(lambda k: m.init(k),
                            jax.ShapeDtypeStruct((2,), jnp.uint32))
    specs = m.param_pspecs()
    from jax.sharding import PartitionSpec as P
    pl, pt = jax.tree_util.tree_flatten(params)
    sl, st = jax.tree_util.tree_flatten(specs,
                                        is_leaf=lambda s: isinstance(s, P))
    assert pt == st
    for leaf, spec in zip(pl, sl):
        assert len(spec) <= len(leaf.shape), (leaf.shape, spec)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_metadata(arch):
    """The FULL configs match the assignment table exactly."""
    expect = {
        "deepseek_7b": (30, 4096, 32, 32, 11008, 102400),
        "starcoder2_15b": (40, 6144, 48, 4, 24576, 49152),
        "olmoe_1b_7b": (16, 2048, 16, 16, 1024, 50304),
        "xlstm_1_3b": (48, 2048, 4, 4, 0, 50304),
        "qwen2_vl_7b": (28, 3584, 28, 4, 18944, 152064),
        "recurrentgemma_2b": (26, 2560, 10, 1, 7680, 256000),
        "phi3_5_moe": (32, 4096, 32, 8, 6400, 32064),
        "llama3_8b": (32, 4096, 32, 8, 14336, 128256),
        "minitron_8b": (32, 4096, 32, 8, 16384, 256000),
        "musicgen_medium": (48, 1536, 24, 24, 6144, 2048),
    }[arch]
    cfg = get_config(arch, "full")
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab_size)
    assert got == expect, (arch, got, expect)
    if arch == "olmoe_1b_7b":
        assert (cfg.n_experts, cfg.top_k) == (64, 8)
    if arch == "phi3_5_moe":
        assert (cfg.n_experts, cfg.top_k) == (16, 2)


def test_scan_groups_cover_all_layers():
    for arch in ARCHS:
        cfg = get_config(arch, "full")
        groups = cfg.scan_groups()
        total = sum(len(unit) * reps for unit, reps in groups)
        assert total == cfg.n_layers, (arch, groups)
        flat = tuple(k for unit, reps in groups for k in unit * reps)
        assert flat == cfg.layer_kinds


def test_decode_plans():
    for arch in ARCHS:
        cfg = get_config(arch, "full")
        for shape_name in ("decode_32k", "long_500k"):
            plan = plan_decode(cfg, SHAPES[shape_name])
            assert plan.cache_len >= 1
            if shape_name == "long_500k":
                # sub-quadratic everywhere: no arch may keep a full 524k cache
                assert plan.cache_len <= 8192, (arch, plan)


class TestDecodeMatchesForward:
    """Teacher-forcing consistency: step-by-step decode == full forward."""

    @pytest.mark.parametrize("arch", ["llama3_8b", "recurrentgemma_2b",
                                      "xlstm_1_3b"])
    def test_consistency(self, arch):
        import dataclasses
        # f32 compute: the test checks *path* equivalence (scan vs step),
        # not bf16 rounding between different reduction orders.
        cfg = dataclasses.replace(get_config(arch, "reduced"),
                                  compute_dtype=jnp.float32)
        m = Model(cfg)
        params = m.init(jax.random.PRNGKey(0))
        seq = 12
        tok = jax.random.randint(jax.random.PRNGKey(1), (1, seq), 0,
                                 cfg.vocab_size)
        batch = {"tokens": tok, "labels": tok}
        full_logits, _ = m.forward(params, batch)
        # prefill the first 4 tokens, decode the rest one by one
        pre = {"tokens": tok[:, :4], "labels": tok[:, :4]}
        _, cache = m.prefill(params, pre, cache_len=seq + 2)
        dec = jax.jit(lambda p, c, b: m.decode_step(p, c, b))
        for t in range(4, seq):
            logits, cache = dec(params, cache, {"token": tok[:, t]})
            np.testing.assert_allclose(
                np.asarray(logits[0]), np.asarray(full_logits[0, t]),
                atol=0.05, rtol=0.05)

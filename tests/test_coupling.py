"""Coupling layer: gossip schedule == dense operator == paper's Eq. (5);
matchings cover the graph; consensus/cl operators behave as specified."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ring_graph, random_geometric_graph,
                        closed_form, synchronous)
from repro.coupling import (CouplingConfig, make_state, make_coupling,
                            dense_mix_tree, consensus_mean_tree,
                            laplacian_pull_tree)


def tiny_tree(A, key=0):
    rng = np.random.default_rng(key)
    return {"w": jnp.asarray(rng.standard_normal((A, 8, 16)), jnp.float32),
            "b": jnp.asarray(rng.standard_normal((A, 4)), jnp.float32)}


class TestMatchings:
    @pytest.mark.parametrize("n,k", [(8, 2), (16, 3), (32, 3)])
    def test_edge_coloring_covers_and_disjoint(self, n, k):
        g = random_geometric_graph(n, k=k, seed=1)
        matchings = g.edge_coloring()
        seen = set()
        for m in matchings:
            nodes = [x for e in m for x in e]
            assert len(nodes) == len(set(nodes)), "matching not disjoint"
            seen.update(frozenset(e) for e in m)
        assert seen == {frozenset(e) for e in g.edges()}


class TestMPCoupling:
    def test_dense_mix_is_eq5_iterate(self):
        """dense_mix_tree == one step of the paper's synchronous iteration."""
        A = 12
        g = random_geometric_graph(A, k=3, seed=0)
        conf = np.linspace(0.1, 1.0, A)
        alpha = 0.95
        state = make_state(g, conf, alpha)
        rng = np.random.default_rng(1)
        theta = rng.standard_normal((A, 24)).astype(np.float32)
        sol = rng.standard_normal((A, 24)).astype(np.float32)
        cfg = CouplingConfig(mode="mp", alpha=alpha)
        got = dense_mix_tree({"t": jnp.asarray(theta)}, {"t": jnp.asarray(sol)},
                             state, cfg)["t"]
        # Eq. (5) starting from theta with anchor sol
        want = np.asarray(synchronous(g, sol, conf, alpha, steps=1,
                                      theta0=theta))
        np.testing.assert_allclose(np.asarray(got), want, atol=1e-5)

    def test_repeated_mixing_converges_to_closed_form(self):
        """Iterating the coupling operator -> Prop. 1 optimum (C1 at scale)."""
        A = 16
        g = random_geometric_graph(A, k=3, seed=2)
        conf = np.linspace(0.2, 1.0, A)
        alpha = 0.9
        state = make_state(g, conf, alpha)
        cfg = CouplingConfig(mode="mp", alpha=alpha)
        rng = np.random.default_rng(3)
        sol = {"t": jnp.asarray(rng.standard_normal((A, 6)), jnp.float32)}
        theta = sol
        for _ in range(400):
            theta = dense_mix_tree(theta, sol, state, cfg)
        star = np.asarray(closed_form(g, np.asarray(sol["t"]), conf, alpha))
        np.testing.assert_allclose(np.asarray(theta["t"]), star, atol=1e-4)

    def test_kernel_path_matches_einsum_path(self):
        A = 8
        g = ring_graph(A)
        state = make_state(g, np.ones(A) * 0.5, 0.9)
        tree = tiny_tree(A)
        sol = tiny_tree(A, key=9)
        a = dense_mix_tree(tree, sol, state, CouplingConfig(mode="mp"))
        b = dense_mix_tree(tree, sol, state,
                           CouplingConfig(mode="mp", use_kernel=True))
        for k in tree:
            np.testing.assert_allclose(np.asarray(a[k]), np.asarray(b[k]),
                                       atol=1e-5)


class TestOtherModes:
    def test_consensus_is_uniform_mean(self):
        A = 6
        tree = tiny_tree(A)
        out = consensus_mean_tree(tree, CouplingConfig(mode="consensus"))
        for k in tree:
            want = np.mean(np.asarray(tree[k]), axis=0, keepdims=True)
            np.testing.assert_allclose(np.asarray(out[k]),
                                       np.broadcast_to(want, tree[k].shape),
                                       atol=1e-6)

    def test_laplacian_pull_is_qcl_smoothness_gradient(self):
        A = 10
        g = random_geometric_graph(A, k=3, seed=4)
        state = make_state(g, np.ones(A), 0.9)
        tree = tiny_tree(A)
        lr = 0.01
        out = laplacian_pull_tree(tree, state,
                                  CouplingConfig(mode="cl", mu=lr), lr=lr)
        W = jnp.asarray(g.W, jnp.float32)

        def smooth(t):
            diff = t[:, None] - t[None, :]
            return 0.5 * jnp.sum(W * jnp.sum(diff.reshape(A, A, -1) ** 2, -1))

        for k in tree:
            gexp = jax.grad(smooth)(tree[k])
            want = tree[k] - lr * gexp
            np.testing.assert_allclose(np.asarray(out[k]), np.asarray(want),
                                       atol=1e-5, rtol=1e-4)

    def test_every_k_gating(self):
        A = 4
        g = ring_graph(A)
        state = make_state(g, np.ones(A), 0.9)
        cfg = CouplingConfig(mode="mp", every=4)
        couple = make_coupling(cfg, state, ("data",))
        tree = tiny_tree(A)
        sol = tiny_tree(A, key=5)
        same = couple(tree, sol, jnp.asarray(1))
        mixed = couple(tree, sol, jnp.asarray(4))
        np.testing.assert_allclose(np.asarray(same["w"]),
                                   np.asarray(tree["w"]))
        assert not np.allclose(np.asarray(mixed["w"]), np.asarray(tree["w"]))

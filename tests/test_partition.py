"""Partitioned multi-device simulation: greedy edge-cut partitioner, shard/
halo layout invariants, sharded-engine parity with the single-device sparse
engine (in-process on however many devices exist, plus an 8-fake-device
subprocess), and partition edge cases (isolated agents, zero cross-edge
shards, n not divisible by P, fixed-seed determinism)."""

import math
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core.losses import pad_datasets, solitary_mean
from repro.core.sparse import tables_from_adjacency
from repro.simulate import (GraphPartition, NetworkConditions, SparseTopology,
                            block_partition, cluster_topology,
                            default_local_batch, default_local_events,
                            edge_cut, greedy_partition,
                            precompute_event_stream,
                            random_geometric_topology, ring_topology,
                            run_cl_scenario, run_cl_scenario_sharded,
                            run_mp_scenario, run_mp_scenario_sharded,
                            stream_totals)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def two_component_topology(half: int = 20) -> SparseTopology:
    """Two disjoint rings — a partition of it can have zero cross-edges."""
    nbrs, wts = [], []
    for comp in range(2):
        lo = comp * half
        for v in range(half):
            a, b = lo + (v - 1) % half, lo + (v + 1) % half
            nbrs.append(np.sort(np.unique([a, b])))
            wts.append(np.ones(len(nbrs[-1])))
    tabs = tables_from_adjacency(nbrs, wts)
    groups = (np.arange(2 * half) >= half).astype(np.int32)
    return SparseTopology(tabs, groups)


# ---------------------------------------------------------------------------
# greedy partitioner
# ---------------------------------------------------------------------------


class TestGreedyPartition:
    def test_balanced_and_complete(self):
        topo = random_geometric_topology(501, k=5, seed=0)   # n % P != 0
        a = greedy_partition(topo, 4)
        assert a.shape == (501,) and a.min() >= 0 and a.max() < 4
        cap = math.ceil(501 / 4)
        assert np.bincount(a, minlength=4).max() <= cap + max(1, cap // 16)

    def test_deterministic_under_fixed_seed(self):
        topo = random_geometric_topology(300, k=4, seed=1)
        a1 = greedy_partition(topo, 8, seed=7)
        a2 = greedy_partition(topo, 8, seed=7)
        assert np.array_equal(a1, a2)

    def test_beats_random_assignment(self):
        topo = random_geometric_topology(1000, k=6, seed=0)
        rng = np.random.default_rng(0)
        rand = rng.integers(0, 8, topo.n).astype(np.int32)
        assert edge_cut(topo, greedy_partition(topo, 8)) \
            < 0.5 * edge_cut(topo, rand)

    def test_recovers_cluster_structure(self):
        topo = cluster_topology(400, n_clusters=4, k_intra=4, bridges=2,
                                seed=0)
        cut = edge_cut(topo, greedy_partition(topo, 4, refine_passes=8))
        # clusters are contiguous ids, so block partition is near-optimal;
        # greedy must land in its ballpark, not at random-cut levels
        assert cut <= 4 * max(1, edge_cut(topo, block_partition(topo, 4)))

    def test_single_shard_is_trivial(self):
        topo = ring_topology(32)
        assert np.array_equal(greedy_partition(topo, 1), np.zeros(32))
        assert edge_cut(topo, greedy_partition(topo, 1)) == 0

    def test_isolated_agents_rejected_by_generators(self):
        """The topology layer guarantees no isolated agents, which the
        partition layout relies on (every halo id has a boundary source)."""
        with pytest.raises(ValueError, match="at least one neighbor"):
            tables_from_adjacency([np.array([1]), np.array([0]),
                                   np.array([], np.int64)],
                                  [np.ones(1), np.ones(1), np.ones(0)])


# ---------------------------------------------------------------------------
# shard/halo layout
# ---------------------------------------------------------------------------


class TestGraphPartitionLayout:
    def _check_layout(self, topo, part):
        tabs = topo.tables
        n, m, H = part.n, part.shard_size, part.halo_size
        # perm_slot inverts local placement
        assert np.array_equal(
            part.local_ids.reshape(-1)[part.perm_slot], np.arange(n))
        # every neighbor of a local agent is fetchable (local or halo)
        for q in range(part.n_shards):
            fetch = part.fetch[q]
            for v in np.where(part.owner == q)[0]:
                for u in tabs.nbr_idx[v, :tabs.deg_count[v]]:
                    assert fetch[u] < m + H, (q, v, u)
        # halo reconstruction: gathering boundary buffers lands each halo
        # agent's value at its fetch slot
        vals = np.arange(n, dtype=np.float32)[:, None]
        loc = part.shard_rows(vals).reshape(part.n_shards, m, 1)
        bufs = np.stack([loc[q, part.bnd_pos[q]]
                         for q in range(part.n_shards)])  # (P, B, 1)
        for q in range(part.n_shards):
            halo = bufs[part.halo_src_shard[q], part.halo_src_pos[q]]
            ext = np.concatenate([loc[q], halo, np.zeros((1, 1))])
            for a in range(n):
                if part.fetch[q, a] < m + H:
                    assert ext[part.fetch[q, a], 0] == a

    def test_layout_roundtrip(self):
        topo = random_geometric_topology(230, k=5, seed=2)   # 230 % 8 != 0
        part = GraphPartition.build(topo, greedy_partition(topo, 8), 8)
        assert part.shard_size * 8 >= 230
        self._check_layout(topo, part)

    def test_zero_cross_edge_shard(self):
        """Disjoint components on separate shards: no cut, no halo."""
        topo = two_component_topology(20)
        part = GraphPartition.build(topo, topo.groups, 2)
        assert part.edge_cut == 0
        assert part.halo_size == 0 and part.boundary_size == 0
        self._check_layout(topo, part)

    def test_unshard_inverts_shard(self):
        topo = random_geometric_topology(100, k=4, seed=3)
        part = GraphPartition.build(topo, greedy_partition(topo, 4), 4)
        x = np.random.default_rng(0).standard_normal((100, 7)) \
            .astype(np.float32)
        assert np.array_equal(part.unshard_rows(part.shard_rows(x)), x)

    def test_capacity_heuristics(self):
        assert default_local_batch(100, 1) == 200
        assert default_local_events(100, 1) == 100
        for P in (2, 4, 8):
            assert 2 * 100 // P < default_local_batch(100, P) <= 200
            assert default_local_events(100, P) <= 100


# ---------------------------------------------------------------------------
# event-stream replay + sharded engine parity (in-process device count)
# ---------------------------------------------------------------------------


CONDITIONS = {
    "clean": NetworkConditions(),
    "faulty": NetworkConditions(drop_prob=0.1, stale_prob=0.3,
                                churn_rate=0.01, straggler_frac=0.3,
                                partition_start=10, partition_end=30),
}


class TestShardedParity:
    @pytest.fixture(scope="class")
    def problem(self):
        topo = random_geometric_topology(300, k=5, seed=0)
        rng = np.random.default_rng(0)
        sol = rng.standard_normal((300, 4)).astype(np.float32)
        c = rng.uniform(0.05, 1.0, 300).astype(np.float32)
        return topo, sol, c

    def test_event_stream_totals(self, problem):
        topo, sol, c = problem
        cond = CONDITIONS["faulty"]
        stream = precompute_event_stream(
            topo.device_tables(), np.asarray(topo.partition_halves()),
            cond, 32, 5, 60)
        tr = run_mp_scenario(topo, sol, c, 0.9, cond, rounds=60, batch=32,
                             seed=5, record_every=60)
        delivered = int(np.asarray(stream.deliver_ij).sum()
                        + np.asarray(stream.deliver_ji).sum())
        assert delivered == tr.delivered
        assert 2 * 60 * 32 - delivered == tr.dropped

    @pytest.mark.parametrize("name", sorted(CONDITIONS))
    def test_matches_single_device(self, problem, name):
        """The tentpole acceptance: identical trajectory, counters, and
        activity history on whatever mesh this process has (1 device in the
        fast lane; 8 in the multi-device CI job)."""
        topo, sol, c = problem
        cond = CONDITIONS[name]
        tr = run_mp_scenario(topo, sol, c, 0.9, cond, rounds=60, batch=48,
                             seed=3, record_every=20)
        sh = run_mp_scenario_sharded(topo, sol, c, 0.9, cond, rounds=60,
                                     batch=48, seed=3, record_every=20)
        assert sh.overflow == 0
        assert sh.n_shards == jax.device_count()
        np.testing.assert_allclose(sh.theta_hist, tr.theta_hist, atol=1e-5)
        np.testing.assert_allclose(sh.active_hist, tr.active_hist)
        assert (sh.delivered, sh.dropped, sh.rounds, sh.events) \
            == (tr.delivered, tr.dropped, tr.rounds, tr.events)

    def test_ring_exchange_matches(self, problem):
        topo, sol, c = problem
        cond = CONDITIONS["faulty"]
        a = run_mp_scenario_sharded(topo, sol, c, 0.9, cond, rounds=40,
                                    batch=32, seed=1, record_every=20)
        b = run_mp_scenario_sharded(topo, sol, c, 0.9, cond, rounds=40,
                                    batch=32, seed=1, record_every=20,
                                    exchange="ring")
        assert np.array_equal(a.theta_hist, b.theta_hist)

    def test_overflow_counted_not_crashed(self, problem):
        """A deliberately tiny update buffer must degrade by *counting*
        dropped updates, never by crashing or silently diverging."""
        topo, sol, c = problem
        tr = run_mp_scenario_sharded(topo, sol, c, 0.9, CONDITIONS["clean"],
                                     rounds=20, batch=64, seed=0,
                                     record_every=20, local_batch=1)
        if jax.device_count() == 1:
            assert tr.overflow > 0          # U = 1 cannot hold 2B updates
        assert np.isfinite(tr.theta_hist).all()

    def test_assignment_exceeding_mesh_raises(self, problem):
        topo, sol, c = problem
        bad = np.arange(topo.n, dtype=np.int32) % (jax.device_count() + 3)
        with pytest.raises(ValueError, match="mesh"):
            run_mp_scenario_sharded(topo, sol, c, 0.9, CONDITIONS["clean"],
                                    rounds=10, batch=8, assignment=bad)

    def test_replay_parity_churn_and_partition_together(self, problem):
        """EventStream replay with churn AND a partition window active at
        once: the materialized stream and the inline engine agree on every
        counter and on the trajectory replayed from it."""
        topo, sol, c = problem
        cond = NetworkConditions(churn_rate=0.03, partition_start=5,
                                 partition_end=25)
        tr = run_mp_scenario(topo, sol, c, 0.9, cond, rounds=40, batch=32,
                             seed=11, record_every=10)
        stream = precompute_event_stream(
            topo.device_tables(), np.asarray(topo.partition_halves()),
            cond, 32, 11, 40)
        delivered, dropped, invalid = stream_totals(stream)
        assert (delivered, dropped, invalid) \
            == (tr.delivered, tr.dropped, tr.invalid)
        assert tr.delivered + tr.dropped == 2 * (tr.events - tr.invalid)
        sh = run_mp_scenario_sharded(topo, sol, c, 0.9, cond, rounds=40,
                                     batch=32, seed=11, record_every=10)
        assert sh.overflow == 0
        np.testing.assert_allclose(sh.theta_hist, tr.theta_hist, atol=1e-5)
        np.testing.assert_allclose(sh.active_hist, tr.active_hist)


# ---------------------------------------------------------------------------
# sharded CL-ADMM parity (in-process device count; 8 devices in the
# multi-device CI lane and in the subprocess below)
# ---------------------------------------------------------------------------


class TestShardedCLParity:
    @pytest.fixture(scope="class")
    def problem(self):
        topo = random_geometric_topology(300, k=5, seed=0)
        rng = np.random.default_rng(0)
        xs = [rng.standard_normal((int(rng.integers(1, 8)), 4))
              for _ in range(300)]
        data = pad_datasets(xs, [np.zeros(len(x)) for x in xs])
        sol = np.asarray(solitary_mean(data), np.float32)
        return topo, data, sol

    @pytest.mark.parametrize("name", sorted(CONDITIONS))
    def test_matches_single_device_bitwise(self, problem, name):
        """Tentpole acceptance: the sharded CL-ADMM trajectory is
        bit-for-bit the single-device one (maxerr 0.0) with zero buffer
        overflow, on whatever mesh this process has."""
        topo, data, sol = problem
        cond = CONDITIONS[name]
        tr = run_cl_scenario(topo, data, 0.1, 1.0, cond, rounds=60,
                             batch=48, seed=3, record_every=20,
                             theta_sol=sol)
        sh = run_cl_scenario_sharded(topo, data, 0.1, 1.0, cond, rounds=60,
                                     batch=48, seed=3, record_every=20,
                                     theta_sol=sol)
        assert sh.overflow == 0
        assert sh.n_shards == jax.device_count()
        assert np.abs(sh.theta_hist - tr.theta_hist).max() == 0.0
        np.testing.assert_allclose(sh.active_hist, tr.active_hist)
        assert (sh.delivered, sh.dropped, sh.invalid, sh.rounds, sh.events) \
            == (tr.delivered, tr.dropped, tr.invalid, tr.rounds, tr.events)

    def test_ring_exchange_matches(self, problem):
        topo, data, sol = problem
        kw = dict(rounds=40, batch=32, seed=1, record_every=20,
                  theta_sol=sol)
        a = run_cl_scenario_sharded(topo, data, 0.1, 1.0,
                                    CONDITIONS["faulty"], **kw)
        b = run_cl_scenario_sharded(topo, data, 0.1, 1.0,
                                    CONDITIONS["faulty"], exchange="ring",
                                    **kw)
        assert np.array_equal(a.theta_hist, b.theta_hist)

    def test_overflow_counted_not_crashed(self, problem):
        topo, data, sol = problem
        tr = run_cl_scenario_sharded(topo, data, 0.1, 1.0,
                                     CONDITIONS["clean"], rounds=20,
                                     batch=64, seed=0, record_every=20,
                                     local_batch=1, theta_sol=sol)
        if jax.device_count() == 1:
            assert tr.overflow > 0          # U = 1 cannot hold 2B updates
        assert np.isfinite(tr.theta_hist).all()


# ---------------------------------------------------------------------------
# 8-fake-device subprocess: true multi-shard execution wherever the suite
# runs (the in-process tests above only see this host's device count)
# ---------------------------------------------------------------------------


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    assert jax.device_count() == 8
    from repro.kernels.dispatch import ReproBackend
    from repro.simulate import (NetworkConditions, cluster_topology,
                                random_geometric_topology, run_mp_scenario,
                                run_mp_scenario_sharded, sparse_sync_mp)
    import test_partition as tp

    # n = 203 is not divisible by 8; faulty conditions hit every code path
    topo = random_geometric_topology(203, k=5, seed=0)
    rng = np.random.default_rng(0)
    sol = rng.standard_normal((203, 4)).astype(np.float32)
    c = rng.uniform(0.05, 1.0, 203).astype(np.float32)
    cond = NetworkConditions(drop_prob=0.1, stale_prob=0.3, churn_rate=0.01,
                             straggler_frac=0.3, partition_start=5,
                             partition_end=20)
    tr = run_mp_scenario(topo, sol, c, 0.9, cond, rounds=40, batch=32,
                         seed=3, record_every=10)
    for exchange in ("all_gather", "ring"):
        sh = run_mp_scenario_sharded(topo, sol, c, 0.9, cond, rounds=40,
                                     batch=32, seed=3, record_every=10,
                                     exchange=exchange)
        assert sh.n_shards == 8 and sh.overflow == 0, exchange
        assert np.abs(sh.theta_hist - tr.theta_hist).max() <= 1e-5, exchange

    # zero cross-edge shards: two disjoint components, explicit assignment
    topo2 = tp.two_component_topology(20)
    sol2 = rng.standard_normal((40, 3)).astype(np.float32)
    c2 = rng.uniform(0.1, 1.0, 40).astype(np.float32)
    tr2 = run_mp_scenario(topo2, sol2, c2, 0.8, NetworkConditions(),
                          rounds=30, batch=8, seed=1, record_every=10)
    sh2 = run_mp_scenario_sharded(topo2, sol2, c2, 0.8, NetworkConditions(),
                                  rounds=30, batch=8, seed=1,
                                  record_every=10,
                                  assignment=topo2.groups, n_shards=2)
    assert sh2.edge_cut == 0 and sh2.halo_size == 0
    assert np.array_equal(sh2.theta_hist, tr2.theta_hist)

    # sharded dispatch impls drive the sync sweep across all 8 devices
    topo3 = random_geometric_topology(300, k=5, seed=1)
    sol3 = rng.standard_normal((300, 8)).astype(np.float32)
    c3 = rng.uniform(0.05, 1.0, 300).astype(np.float32)
    want = np.asarray(sparse_sync_mp(topo3, sol3, c3, 0.9, sweeps=15))
    got = np.asarray(sparse_sync_mp(
        topo3, sol3, c3, 0.9, sweeps=15,
        backend=ReproBackend.using(sparse_mix="xla_sharded")))
    assert np.abs(got - want).max() <= 1e-5

    # sharded ADMM primal/edge dispatch impls match their row-wise forms
    import jax.numpy as jnp
    from repro.kernels.dispatch import resolve
    k_, p_ = 6, 16
    w_ = jnp.asarray(rng.uniform(0.1, 1, (40, k_)), jnp.float32)
    lv = jnp.asarray(rng.uniform(size=(40, k_)) < 0.8)
    zo, zn, lo, ln = (jnp.asarray(rng.standard_normal((40, k_, p_)),
                                  jnp.float32) for _ in range(4))
    D_ = jnp.asarray(rng.uniform(1, 4, 40), jnp.float32)
    m_ = jnp.asarray(rng.integers(1, 20, 40), jnp.float32)
    sx_ = jnp.asarray(rng.standard_normal((40, p_)), jnp.float32)
    xla = resolve("admm_primal", ReproBackend.using(admm_primal="xla"))
    shd = resolve("admm_primal",
                  ReproBackend.using(admm_primal="xla_sharded"))
    want_t, want_js = jax.vmap(
        lambda *a: xla(*a, 0.05, 1.0))(w_, lv, zo, zn, lo, ln, D_, m_, sx_)
    got_t, got_js = shd(w_, lv, zo, zn, lo, ln, D_, m_, sx_, 0.05, 1.0)
    assert np.abs(np.asarray(got_t) - np.asarray(want_t)).max() <= 1e-5
    assert np.abs(np.asarray(got_js) - np.asarray(want_js)).max() <= 1e-5
    e_args = tuple(jnp.asarray(rng.standard_normal((40, p_)), jnp.float32)
                   for _ in range(8))
    ref_e = resolve("admm_edge", ReproBackend.using(admm_edge="reference"))
    shd_e = resolve("admm_edge", ReproBackend.using(admm_edge="xla_sharded"))
    for a, b in zip(ref_e(*e_args, rho=1.5), shd_e(*e_args, rho=1.5)):
        assert np.abs(np.asarray(a) - np.asarray(b)).max() <= 1e-5

    # CL-ADMM sharded parity across the 8-shard mesh (faulty conditions)
    from repro.core.losses import pad_datasets, solitary_mean
    from repro.simulate import run_cl_scenario, run_cl_scenario_sharded
    xs = [rng.standard_normal((int(rng.integers(1, 8)), 4))
          for _ in range(203)]
    data = pad_datasets(xs, [np.zeros(len(x)) for x in xs])
    soln = np.asarray(solitary_mean(data), np.float32)
    cl = run_cl_scenario(topo, data, 0.1, 1.0, cond, rounds=40, batch=32,
                         seed=3, record_every=10, theta_sol=soln)
    for exchange in ("all_gather", "ring"):
        sh_cl = run_cl_scenario_sharded(topo, data, 0.1, 1.0, cond,
                                        rounds=40, batch=32, seed=3,
                                        record_every=10, theta_sol=soln,
                                        exchange=exchange)
        assert sh_cl.n_shards == 8 and sh_cl.overflow == 0, exchange
        assert np.abs(sh_cl.theta_hist - cl.theta_hist).max() == 0.0, exchange

    # acceptance run: n = 10k agents, 8 shards, bit-for-bit, zero overflow
    topo4 = random_geometric_topology(10000, k=6, seed=2)
    m4 = 3
    x4 = rng.standard_normal((10000, m4, 4)).astype(np.float32)
    data4 = pad_datasets(list(x4), [np.zeros(m4)] * 10000)
    sol4 = np.asarray(solitary_mean(data4), np.float32)
    cond4 = NetworkConditions(drop_prob=0.1, stale_prob=0.2,
                              churn_rate=0.005, straggler_frac=0.2,
                              partition_start=4, partition_end=12)
    cl4 = run_cl_scenario(topo4, data4, 0.1, 1.0, cond4, rounds=24,
                          batch=1000, seed=0, record_every=8,
                          theta_sol=sol4)
    sh4 = run_cl_scenario_sharded(topo4, data4, 0.1, 1.0, cond4, rounds=24,
                                  batch=1000, seed=0, record_every=8,
                                  theta_sol=sol4)
    assert sh4.n_shards == 8 and sh4.overflow == 0
    assert np.abs(sh4.theta_hist - cl4.theta_hist).max() == 0.0
    print("SHARDED-8DEV-OK")
""")


def test_eight_device_parity_subprocess():
    """Full 8-shard execution in a subprocess (the XLA device-count flag
    must precede jax init, which pytest has already done here)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep \
        + os.path.dirname(__file__) \
        + os.pathsep + env.get("PYTHONPATH", "")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", SUBPROC], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "SHARDED-8DEV-OK" in out.stdout
